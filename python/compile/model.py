"""L2: the JAX denoiser `p_θ(x̂0 | x_t, t[, src])` — build-time only.

Architecture mirrors the paper's §4 setup scaled to this testbed:
  * conditional (machine translation): transformer encoder–decoder with
    **bidirectional** self-attention (no causal mask) + cross-attention,
    the fairseq/RDM shape (Zheng et al. 2023) at d_model=128;
  * unconditional (text8/enwik8 analogs): decoder-only stack, the paper's
    12-layer GPT-like decoder scaled to 4 layers.

Timestep conditioning uses a sinusoidal embedding of normalized t ∈ [0, 1]
passed through a 2-layer MLP and added at every position — one network
serves both discrete grids (t = k/T for any T) and DNDM-C's continuous
timestamps, which is exactly what §3.3 / Table 12 need.

Attention routes through the L1 Pallas kernel (kernels/attention.py) so the
kernel lowers into the same HLO artifact rust executes; `use_pallas=False`
falls back to the pure-jnp oracle for debugging and A/B tests.
"""

from __future__ import annotations

from dataclasses import dataclass, asdict

import jax
import jax.numpy as jnp

from .kernels import attention as attn_kernel
from .kernels import ref as kref


@dataclass(frozen=True)
class ModelConfig:
    vocab: int
    seq_len: int                # target / unconditional length N
    src_len: int = 0            # 0 → unconditional (no encoder)
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 256
    enc_layers: int = 2
    dec_layers: int = 2

    @property
    def conditional(self) -> bool:
        return self.src_len > 0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def to_json(self) -> dict:
        return asdict(self)


# ---------------------------------------------------------------------------
# Parameter init (nested dicts; jax sorts dict keys → deterministic flatten)
# ---------------------------------------------------------------------------

def _dense(key, fan_in, fan_out):
    w = jax.random.normal(key, (fan_in, fan_out), jnp.float32)
    w = w * (1.0 / jnp.sqrt(fan_in))
    return {"w": w, "b": jnp.zeros((fan_out,), jnp.float32)}


def _ln_init(d):
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def _block(key, cfg: ModelConfig, cross: bool):
    ks = jax.random.split(key, 8)
    p = {
        "ln1": _ln_init(cfg.d_model),
        "attn": {
            "wq": _dense(ks[0], cfg.d_model, cfg.d_model),
            "wk": _dense(ks[1], cfg.d_model, cfg.d_model),
            "wv": _dense(ks[2], cfg.d_model, cfg.d_model),
            "wo": _dense(ks[3], cfg.d_model, cfg.d_model),
        },
        "ln2": _ln_init(cfg.d_model),
        "ffn": {
            "w1": _dense(ks[4], cfg.d_model, cfg.d_ff),
            "w2": _dense(ks[5], cfg.d_ff, cfg.d_model),
        },
    }
    if cross:
        p["lnx"] = _ln_init(cfg.d_model)
        p["xattn"] = {
            "wq": _dense(ks[6], cfg.d_model, cfg.d_model),
            "wk": _dense(ks[7], cfg.d_model, cfg.d_model),
            "wv": _dense(jax.random.fold_in(key, 99), cfg.d_model, cfg.d_model),
            "wo": _dense(jax.random.fold_in(key, 98), cfg.d_model, cfg.d_model),
        }
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 6 + cfg.enc_layers + cfg.dec_layers)
    params = {
        "tok_embed": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model)) * 0.02,
        "time_mlp": {
            "w1": _dense(ks[1], cfg.d_model, cfg.d_model),
            "w2": _dense(ks[2], cfg.d_model, cfg.d_model),
        },
        "dec": {
            f"layer_{i:02d}": _block(ks[6 + cfg.enc_layers + i], cfg, cfg.conditional)
            for i in range(cfg.dec_layers)
        },
        "ln_out": _ln_init(cfg.d_model),
        "head": _dense(ks[3], cfg.d_model, cfg.vocab),
    }
    if cfg.conditional:
        params["src_embed"] = jax.random.normal(ks[4], (cfg.vocab, cfg.d_model)) * 0.02
        params["enc"] = {
            f"layer_{i:02d}": _block(ks[6 + i], cfg, False)
            for i in range(cfg.enc_layers)
        }
        params["ln_enc"] = _ln_init(cfg.d_model)
    return params


def flatten_named(params) -> list:
    """[(dot.path, array)] in jax's canonical (sorted-key) order — the order
    weights.bin is written in and rust uploads device buffers in."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        name = ".".join(str(getattr(k, "key", k)) for k in path)
        out.append((name, leaf))
    return out


def unflatten_like(params_template, leaves):
    treedef = jax.tree_util.tree_structure(params_template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _layer_norm(p, x, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]


def _apply_dense(p, x):
    return x @ p["w"] + p["b"]


def _sinusoidal(pos: jnp.ndarray, dim: int, max_period: float = 10_000.0):
    """pos: [...] float → [..., dim] features."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(max_period) * jnp.arange(half) / half)
    args = pos[..., None] * freqs
    return jnp.concatenate([jnp.sin(args), jnp.cos(args)], axis=-1)


def _mha(p, cfg: ModelConfig, xq, xkv, use_pallas: bool):
    b, sq, d = xq.shape
    sk = xkv.shape[1]
    h, hd = cfg.n_heads, cfg.head_dim
    q = _apply_dense(p["wq"], xq).reshape(b, sq, h, hd).transpose(0, 2, 1, 3)
    k = _apply_dense(p["wk"], xkv).reshape(b, sk, h, hd).transpose(0, 2, 1, 3)
    v = _apply_dense(p["wv"], xkv).reshape(b, sk, h, hd).transpose(0, 2, 1, 3)
    o = attn_kernel.mha(q, k, v) if use_pallas else kref.mha_ref(q, k, v)
    o = o.transpose(0, 2, 1, 3).reshape(b, sq, d)
    return _apply_dense(p["wo"], o)


def _ffn(p, x):
    return _apply_dense(p["w2"], jax.nn.gelu(_apply_dense(p["w1"], x)))


def _run_block(p, cfg, x, ctx, temb, use_pallas):
    """Pre-LN transformer block; `temb` is added to the residual stream
    before self-attention so every layer sees the timestep; `ctx` is the
    encoder memory (None → decoder-only / encoder block)."""
    x = x + temb
    h = _layer_norm(p["ln1"], x)
    x = x + _mha(p["attn"], cfg, h, h, use_pallas)
    if ctx is not None:
        x = x + _mha(p["xattn"], cfg, _layer_norm(p["lnx"], x), ctx, use_pallas)
    x = x + _ffn(p["ffn"], _layer_norm(p["ln2"], x))
    return x


def encode(params, cfg: ModelConfig, src: jnp.ndarray, use_pallas: bool = True):
    """src: [B, M] int32 → memory [B, M, D]."""
    pos = jnp.arange(cfg.src_len, dtype=jnp.float32)
    h = params["src_embed"][src] + _sinusoidal(pos, cfg.d_model)
    zero = jnp.zeros((1, 1, cfg.d_model), jnp.float32)
    for i in range(cfg.enc_layers):
        h = _run_block(params["enc"][f"layer_{i:02d}"], cfg, h, None, zero, use_pallas)
    return _layer_norm(params["ln_enc"], h)


def apply(params, cfg: ModelConfig, x_t: jnp.ndarray, t: jnp.ndarray,
          src: jnp.ndarray | None = None, use_pallas: bool = True):
    """Denoiser forward.

    x_t: [B, N] int32 noisy tokens; t: [B] f32 normalized time ∈ [0,1];
    src: [B, M] int32 (conditional only). Returns logits [B, N, V].
    """
    pos = jnp.arange(cfg.seq_len, dtype=jnp.float32)
    h = params["tok_embed"][x_t] + _sinusoidal(pos, cfg.d_model)

    temb = _sinusoidal(t * 1000.0, cfg.d_model)          # [B, D]
    temb = _apply_dense(params["time_mlp"]["w2"],
                        jax.nn.silu(_apply_dense(params["time_mlp"]["w1"], temb)))
    temb = temb[:, None, :]                               # [B, 1, D]

    ctx = None
    if cfg.conditional:
        assert src is not None
        ctx = encode(params, cfg, src, use_pallas)

    for i in range(cfg.dec_layers):
        h = _run_block(params["dec"][f"layer_{i:02d}"], cfg, h, ctx, temb, use_pallas)

    h = _layer_norm(params["ln_out"], h)
    return _apply_dense(params["head"], h)


def apply_decode(params, cfg: ModelConfig, x_t: jnp.ndarray, t: jnp.ndarray,
                 memory: jnp.ndarray, use_pallas: bool = True):
    """Decoder-only forward against a precomputed encoder `memory`.

    The L2 perf split (EXPERIMENTS.md §Perf): in conditional sampling the
    source never changes across the reverse trajectory, so the coordinator
    runs `encode` once per batch and this decode-only graph once per NFE —
    removing the encoder's share of every subsequent call.
    """
    pos = jnp.arange(cfg.seq_len, dtype=jnp.float32)
    h = params["tok_embed"][x_t] + _sinusoidal(pos, cfg.d_model)
    temb = _sinusoidal(t * 1000.0, cfg.d_model)
    temb = _apply_dense(params["time_mlp"]["w2"],
                        jax.nn.silu(_apply_dense(params["time_mlp"]["w1"], temb)))
    temb = temb[:, None, :]
    for i in range(cfg.dec_layers):
        h = _run_block(params["dec"][f"layer_{i:02d}"], cfg, h, memory, temb, use_pallas)
    h = _layer_norm(params["ln_out"], h)
    return _apply_dense(params["head"], h)
