"""Post-hoc AOT split: add encode/decode HLO pairs to existing artifacts.

The L2 §Perf optimization (EXPERIMENTS.md): conditional sampling re-ran
the encoder on every NFE call although src is constant per request. This
script reconstructs each conditional model's params from weights.bin and
lowers two extra graphs per bucket:

  encode_b{B}: (w…, src i32[B,M])                      → (memory f32[B,M,D],)
  decode_b{B}: (w…, memory f32[B,M,D], x i32[B,N], t f32[B]) → (logits,)

and records them in the manifest as "hlo_enc" / "hlo_dec". The rust
runtime uses them transparently, caching the memory device buffer per
(src batch) — see runtime::model::ModelRuntime.

Usage: python -m compile.split --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .aot import to_hlo_text

try:  # readers for weights.bin live in the tests module's reference impl
    from tests.test_aot import read_weights  # type: ignore
except Exception:  # pragma: no cover - fallback copy
    import struct

    def read_weights(path):
        out = []
        with open(path, "rb") as f:
            assert f.read(6) == b"DNDW1\x00"
            (count,) = struct.unpack("<I", f.read(4))
            for _ in range(count):
                (nlen,) = struct.unpack("<I", f.read(4))
                name = f.read(nlen).decode()
                dt, ndim = struct.unpack("<BI", f.read(5))
                dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
                n = int(np.prod(dims)) if ndim else 1
                dtype = np.float32 if dt == 0 else np.int32
                data = np.frombuffer(f.read(4 * n), dtype=dtype).reshape(dims)
                out.append((name, data))
        return out


def rebuild_params(cfg: M.ModelConfig, weights_path: str):
    """Reconstruct the params pytree from the flat file (canonical order)."""
    template = M.init_params(jax.random.PRNGKey(0), cfg)
    leaves, treedef = jax.tree_util.tree_flatten(template)
    named = read_weights(weights_path)
    assert len(named) == len(leaves), f"{len(named)} vs {len(leaves)}"
    new_leaves = [jnp.asarray(a) for _, a in named]
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def lower_encode(cfg: M.ModelConfig, params, bucket: int) -> str:
    leaves, treedef = jax.tree_util.tree_flatten(params)
    n_leaves = len(leaves)

    def fn(*args):
        p = jax.tree_util.tree_unflatten(treedef, args[:n_leaves])
        return M.encode(p, cfg, args[n_leaves], use_pallas=True)

    ex = [jax.ShapeDtypeStruct(np.asarray(l).shape, np.asarray(l).dtype) for l in leaves]
    ex += [jax.ShapeDtypeStruct((bucket, cfg.src_len), jnp.int32)]
    # untupled: the memory buffer feeds decode_b directly on-device
    return to_hlo_text(jax.jit(fn, keep_unused=True).lower(*ex), return_tuple=False)


def lower_decode(cfg: M.ModelConfig, params, bucket: int) -> str:
    leaves, treedef = jax.tree_util.tree_flatten(params)
    n_leaves = len(leaves)

    def fn(*args):
        p = jax.tree_util.tree_unflatten(treedef, args[:n_leaves])
        mem, x, t = args[n_leaves], args[n_leaves + 1], args[n_leaves + 2]
        return M.apply_decode(p, cfg, x, t, mem, use_pallas=True)

    ex = [jax.ShapeDtypeStruct(np.asarray(l).shape, np.asarray(l).dtype) for l in leaves]
    ex += [jax.ShapeDtypeStruct((bucket, cfg.src_len, cfg.d_model), jnp.float32),
           jax.ShapeDtypeStruct((bucket, cfg.seq_len), jnp.int32),
           jax.ShapeDtypeStruct((bucket,), jnp.float32)]
    return to_hlo_text(jax.jit(fn, keep_unused=True).lower(*ex))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    out = args.out

    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)

    for entry in manifest["models"]:
        if entry["task"] != "cond":
            continue
        with open(os.path.join(out, entry["config"])) as f:
            cj = json.load(f)
        cfg = M.ModelConfig(
            vocab=cj["vocab"], seq_len=cj["seq_len"], src_len=cj["src_len"],
            d_model=cj["d_model"], n_heads=cj["n_heads"], d_ff=cj["d_ff"],
            enc_layers=cj["enc_layers"], dec_layers=cj["dec_layers"])
        params = rebuild_params(cfg, os.path.join(out, entry["weights"]))

        entry["hlo_enc"], entry["hlo_dec"] = {}, {}
        for b in (int(k) for k in entry["hlo"]):
            enc = lower_encode(cfg, params, b)
            dec = lower_decode(cfg, params, b)
            enc_rel = f"{entry['name']}/encode_b{b}.hlo.txt"
            dec_rel = f"{entry['name']}/decode_b{b}.hlo.txt"
            with open(os.path.join(out, enc_rel), "w") as f:
                f.write(enc)
            with open(os.path.join(out, dec_rel), "w") as f:
                f.write(dec)
            entry["hlo_enc"][str(b)] = enc_rel
            entry["hlo_dec"][str(b)] = dec_rel
        print(f"[split] {entry['name']}: encode/decode for buckets {list(entry['hlo'])}")

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("[split] manifest updated")


if __name__ == "__main__":
    main()
