"""Build-time training of the denoiser networks.

The paper evaluates *sampling* with pretrained RDM / multinomial-diffusion
checkpoints; those are not available here, so `make artifacts` trains the
same-shaped networks on the synthetic corpora (DESIGN.md §3). Training uses
the RDM-style reparameterized objective: sample t, corrupt x0 → x_t with the
forward marginal q(x_t|x0) = Cat(α_t·x0 + (1−α_t)·q_noise) (Thm 3.1 — shared
by the Markov and non-Markov processes, which is exactly why a
Markov-trained network drives DNDM sampling unchanged), then cross-entropy
of p_θ(x̂0|x_t, t) against x0, up-weighted on corrupted positions.

Two time regimes (§3.3 / Table 12):
  * discrete  : t drawn from the T=50 grid {1/T … 1} (the paper's checkpoints)
  * continuous: t ~ U(0, 1]                         (C-DNDM training)

Gradients flow through the pure-jnp oracle attention (`use_pallas=False`);
pallas_call has no registered VJP, and the oracle is numerically identical
(tested in python/tests/test_kernel.py). AOT export re-lowers the same
params with the Pallas kernels in the graph.
"""

from __future__ import annotations

import os
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import common
from . import model as M

# First vocab id that multinomial noise may produce (specials excluded so
# noise never injects <pad>/<unk>/<mask>); mirrored by rust diffusion::noise.
NOISE_LO = 3
MASK_ID = 2

TRAIN_T_GRID = 50  # discrete-training grid, as in the paper's checkpoints


@dataclass
class TrainSpec:
    name: str            # manifest key, e.g. "cond_multi_iwslt14"
    kind: str            # "multinomial" | "absorbing"
    task: str            # "cond" | "uncond"
    dataset: str         # synth-iwslt14 / synth-wmt14 / synth-wmt16 / synth-text8 / synth-enwik8
    continuous: bool = False  # continuous-time training (Table 12)
    schedule: str = "cosine_sq"
    steps: int = 800
    batch: int = 32
    lr: float = 2e-3


def alpha_of(schedule: str, t):
    """Continuous α(t), t ∈ [0,1]. Mirrored by rust schedule::alpha."""
    if schedule == "linear":
        return 1.0 - t
    if schedule == "cosine":
        return jnp.cos(jnp.pi * t / 2.0)
    if schedule == "cosine_sq":
        return jnp.cos(jnp.pi * t / 2.0) ** 2
    raise ValueError(schedule)


def make_config(spec: TrainSpec) -> M.ModelConfig:
    if spec.task == "cond":
        vocab = len(common.translation_vocab())
        return M.ModelConfig(vocab=vocab, seq_len=common.TGT_LEN,
                             src_len=common.SRC_LEN, d_model=128, n_heads=4,
                             d_ff=256, enc_layers=2, dec_layers=2)
    vocab = len(common.text8_vocab() if spec.dataset == "synth-text8"
                else common.enwik8_vocab())
    return M.ModelConfig(vocab=vocab, seq_len=common.UNCOND_LEN, src_len=0,
                         d_model=128, n_heads=4, d_ff=256,
                         enc_layers=0, dec_layers=4)


# ---------------------------------------------------------------------------
# Data pipelines (numpy, deterministic via common.Rng)
# ---------------------------------------------------------------------------

def cond_dataset(spec: TrainSpec, split: str, count: int):
    vocab = common.translation_vocab()
    pairs = common.gen_pairs(spec.dataset, split, count)
    src = np.array([vocab.encode(s, common.SRC_LEN) for s, _ in pairs], np.int32)
    tgt = np.array([vocab.encode(t, common.TGT_LEN) for _, t in pairs], np.int32)
    return src, tgt


def uncond_dataset(spec: TrainSpec, split: str, count: int):
    chunks = common.gen_text_chunks(spec.dataset, split, count, common.UNCOND_LEN)
    return None, np.array(chunks, np.int32)


# ---------------------------------------------------------------------------
# Corruption + loss
# ---------------------------------------------------------------------------

def corrupt(key, x0, t, kind: str, schedule: str, vocab: int):
    """Forward marginal q(x_t|x0): keep token w.p. α(t), else draw q_noise."""
    kb, kn = jax.random.split(key)
    a = alpha_of(schedule, t)[:, None]                      # [B,1]
    keep = jax.random.uniform(kb, x0.shape) < a
    if kind == "absorbing":
        noise = jnp.full_like(x0, MASK_ID)
    else:
        noise = jax.random.randint(kn, x0.shape, NOISE_LO, vocab)
    return jnp.where(keep, x0, noise.astype(x0.dtype))


def loss_fn(params, cfg, key, x0, src, kind, schedule, continuous):
    b = x0.shape[0]
    kt, kc = jax.random.split(key)
    if continuous:
        t = jax.random.uniform(kt, (b,), minval=1e-4, maxval=1.0)
    else:
        k = jax.random.randint(kt, (b,), 1, TRAIN_T_GRID + 1)
        t = k.astype(jnp.float32) / TRAIN_T_GRID
    x_t = corrupt(kc, x0, t, kind, schedule, cfg.vocab)
    logits = M.apply(params, cfg, x_t, t, src, use_pallas=False)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, x0[..., None], axis=-1)[..., 0]
    w = jnp.where(x_t == x0, 0.1, 1.0)                      # RDM-style reweighting
    return jnp.sum(nll * w) / jnp.sum(w)


# ---------------------------------------------------------------------------
# Hand-rolled Adam (optax is not in the image)
# ---------------------------------------------------------------------------

def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = 1.0 / (1 - b1 ** t)
    vh = 1.0 / (1 - b2 ** t)
    new = jax.tree_util.tree_map(
        lambda p, mm, vv: p - lr * (mm * mh) / (jnp.sqrt(vv * vh) + eps),
        params, m, v)
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Training loop
# ---------------------------------------------------------------------------

def train(spec: TrainSpec, verbose: bool = True):
    cfg = make_config(spec)
    if spec.task == "cond":
        src_all, tgt_all = cond_dataset(spec, "train", 4096)
    else:
        src_all, tgt_all = uncond_dataset(spec, "train", 2048)

    # deterministic per-model seed (python's str hash is salted per process)
    name_code = sum((i + 1) * b for i, b in enumerate(spec.name.encode())) & 0xFFFF
    key = jax.random.PRNGKey(common.Rng(name_code).next_u64() & 0x7FFFFFFF)
    key, ki = jax.random.split(key)
    params = M.init_params(ki, cfg)
    opt = adam_init(params)

    steps = int(os.environ.get("DNDM_TRAIN_STEPS", spec.steps))

    @jax.jit
    def step(params, opt, key, x0, src, lr):
        loss, grads = jax.value_and_grad(loss_fn)(
            params, cfg, key, x0, src, spec.kind, spec.schedule, spec.continuous)
        params, opt = adam_step(params, grads, opt, lr)
        return params, opt, loss

    def lr_at(i):
        """linear warmup (40 steps) then cosine decay to 10%."""
        warm = 40.0
        if i < warm:
            return spec.lr * (i + 1) / warm
        frac = (i - warm) / max(1.0, steps - warm)
        return spec.lr * (0.1 + 0.9 * 0.5 * (1 + np.cos(np.pi * frac)))

    n = tgt_all.shape[0]
    t0 = time.time()
    for i in range(steps):
        lo = (i * spec.batch) % n
        idx = np.arange(lo, lo + spec.batch) % n
        x0 = jnp.asarray(tgt_all[idx])
        src = jnp.asarray(src_all[idx]) if src_all is not None else None
        key, kk = jax.random.split(key)
        params, opt, loss = step(params, opt, kk, x0, src, lr_at(i))
        if verbose and (i % 50 == 0 or i == steps - 1):
            print(f"  [{spec.name}] step {i:4d} loss {float(loss):.4f} "
                  f"({time.time()-t0:.1f}s)")
    return cfg, params


def default_specs() -> list[TrainSpec]:
    """Every checkpoint the benches need (DESIGN.md §5)."""
    specs = []
    for ds in common.DATASETS:
        short = ds.replace("synth-", "")
        specs.append(TrainSpec(f"cond_multi_{short}", "multinomial", "cond", ds))
        specs.append(TrainSpec(f"cond_absorb_{short}", "absorbing", "cond", ds))
    # Table 12: continuous-time trained variants (IWSLT14 + WMT16)
    for ds in ("synth-iwslt14", "synth-wmt16"):
        short = ds.replace("synth-", "")
        specs.append(TrainSpec(f"cond_multi_{short}_cont", "multinomial", "cond",
                               ds, continuous=True))
        specs.append(TrainSpec(f"cond_absorb_{short}_cont", "absorbing", "cond",
                               ds, continuous=True))
    # Table 4: unconditional multinomial (vanilla-vs-DNDM comparison)
    specs.append(TrainSpec("uncond_multi_text8", "multinomial", "uncond",
                           "synth-text8", schedule="cosine", steps=600, batch=16))
    specs.append(TrainSpec("uncond_multi_enwik8", "multinomial", "uncond",
                           "synth-enwik8", schedule="cosine", steps=600, batch=16))
    return specs
