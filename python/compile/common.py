"""Shared deterministic substrate: PRNG, vocabularies, synthetic corpora.

Everything here is mirrored 1:1 in rust (`rust/src/data/`, `rust/src/text/`)
so that the model trained at build time (python) and the evaluation sets
generated at run time (rust) come from *exactly* the same distribution.
Cross-language parity is enforced by fixtures: `make artifacts` dumps sample
outputs into artifacts/fixtures.json, and `cargo test` re-generates them in
rust and compares.

The PRNG is splitmix64 — tiny, fast, and trivially portable.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

MASK64 = (1 << 64) - 1


class Rng:
    """splitmix64, mirrored by rust/src/schedule/rng.rs::SplitMix64."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64

    def uniform(self) -> float:
        """float64 in [0, 1): top 53 bits / 2^53 (same as rust)."""
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n: int) -> int:
        """integer in [0, n) — simple modulo (bias negligible for small n,
        and identical across both implementations, which is what matters)."""
        return self.next_u64() % n

    def coin(self, p: float) -> bool:
        return self.uniform() < p

    def choice(self, xs: List) -> object:
        return xs[self.below(len(xs))]

    def fork(self, stream: int) -> "Rng":
        """Derive an independent child stream (same rule in rust)."""
        return Rng((self.next_u64() ^ (0xA0761D6478BD642F * (stream + 1))) & MASK64)


# ---------------------------------------------------------------------------
# Source-language grammar (an English-like template PCFG)
# ---------------------------------------------------------------------------

DET = ["the", "a", "every", "some", "this"]
ADJ = ["quick", "old", "bright", "small", "happy", "green", "quiet", "strange"]
NOUN = [
    "fox", "city", "river", "teacher", "garden",
    "mountain", "child", "song", "road", "winter",
]
VERB = [
    "crosses", "finds", "watches", "builds",
    "sings", "follows", "keeps", "remembers",
]
ADV = ["slowly", "often", "quietly", "never", "always"]
PREP = ["near", "under", "over", "beside", "through"]

SRC_WORDS: List[str] = sorted(set(DET + ADJ + NOUN + VERB + ADV + PREP))

# Invented target-language surface forms: one pseudo-word per source word,
# built deterministically from syllables so examples look like a real
# translation task.  Index-aligned with SRC_WORDS.
_ONSET = ["b", "d", "g", "k", "l", "m", "n", "p", "r", "s", "t", "v", "z"]
_NUCLEUS = ["a", "e", "i", "o", "u"]
_CODA = ["", "n", "r", "s", "l", "k"]


def _pseudo_word(i: int) -> str:
    r = Rng(0xDA7A_0000 + i)
    n_syll = 1 + r.below(2)
    w = ""
    for _ in range(n_syll + 1):
        w += _ONSET[r.below(len(_ONSET))] + _NUCLEUS[r.below(len(_NUCLEUS))]
    w += _CODA[r.below(len(_CODA))]
    return w


TGT_WORDS: List[str] = []
_seen = set()
for _i in range(len(SRC_WORDS)):
    _w = _pseudo_word(_i)
    _j = 0
    while _w in _seen:  # ensure bijection
        _j += 1
        _w = _pseudo_word(1000 + 37 * _i + _j)
    _seen.add(_w)
    TGT_WORDS.append(_w)

# Ambiguous synonyms for the "hard" dataset: every 3rd source word gets a
# second valid target form.
TGT_SYNONYM = {
    i: _pseudo_word(5000 + i) + "x" for i in range(0, len(SRC_WORDS), 3)
}


def gen_sentence(rng: Rng) -> List[str]:
    """One source sentence from the template grammar (5..11 words)."""
    out = [rng.choice(DET)]
    if rng.coin(0.6):
        out.append(rng.choice(ADJ))
    out.append(rng.choice(NOUN))
    out.append(rng.choice(VERB))
    out.append(rng.choice(DET))
    if rng.coin(0.4):
        out.append(rng.choice(ADJ))
    out.append(rng.choice(NOUN))
    if rng.coin(0.5):
        out += [rng.choice(PREP), rng.choice(DET), rng.choice(NOUN)]
    if rng.coin(0.4):
        out.append(rng.choice(ADV))
    return out


# ---------------------------------------------------------------------------
# Translation tasks (synthetic IWSLT14 / WMT14 / WMT16 analogs)
# ---------------------------------------------------------------------------

SRC_INDEX = {w: i for i, w in enumerate(SRC_WORDS)}

DATASETS = ("synth-iwslt14", "synth-wmt14", "synth-wmt16")

# fixed per-dataset seeds; split seeds derived by fork()
DATASET_SEED = {
    "synth-iwslt14": 0x1E51_0014,
    "synth-wmt14": 0x3A7B_0014,
    "synth-wmt16": 0x3A7B_0016,
}
SPLIT_STREAM = {"train": 1, "valid": 2, "test": 3}


def translate(dataset: str, src: List[str], rng: Rng) -> List[str]:
    """Deterministic-modulo-rng mapping source→target.

    synth-iwslt14: word cipher, same order                (easy, high BLEU)
    synth-wmt16  : cipher + swap adjacent pairs           (medium)
    synth-wmt14  : cipher + full reversal + ambiguous
                   synonym choices drawn from rng         (hard, BLEU ceiling)
    """
    base = [TGT_WORDS[SRC_INDEX[w]] for w in src]
    if dataset == "synth-iwslt14":
        return base
    if dataset == "synth-wmt16":
        out = list(base)
        for i in range(0, len(out) - 1, 2):
            out[i], out[i + 1] = out[i + 1], out[i]
        return out
    if dataset == "synth-wmt14":
        out = []
        for w in reversed(src):
            i = SRC_INDEX[w]
            if i in TGT_SYNONYM and rng.coin(0.5):
                out.append(TGT_SYNONYM[i])
            else:
                out.append(TGT_WORDS[i])
        return out
    raise ValueError(f"unknown dataset {dataset}")


def gen_pairs(dataset: str, split: str, count: int) -> List[Tuple[List[str], List[str]]]:
    root = Rng(DATASET_SEED[dataset])
    rng = root.fork(SPLIT_STREAM[split])
    pairs = []
    for _ in range(count):
        src = gen_sentence(rng)
        tgt = translate(dataset, src, rng)
        pairs.append((src, tgt))
    return pairs


# ---------------------------------------------------------------------------
# Vocabulary (shared src+tgt, mirrored by rust/src/text/vocab.rs)
# ---------------------------------------------------------------------------

PAD, UNK, MASK = "<pad>", "<unk>", "<mask>"


@dataclass
class Vocab:
    tokens: List[str]
    index: dict = field(default_factory=dict)

    def __post_init__(self):
        self.index = {t: i for i, t in enumerate(self.tokens)}

    def __len__(self):
        return len(self.tokens)

    @property
    def pad_id(self) -> int:
        return self.index[PAD]

    @property
    def mask_id(self) -> int:
        return self.index[MASK]

    def encode(self, words: List[str], n: int) -> List[int]:
        ids = [self.index.get(w, self.index[UNK]) for w in words][:n]
        ids += [self.pad_id] * (n - len(ids))
        return ids

    def decode(self, ids: List[int]) -> List[str]:
        out = []
        for i in ids:
            t = self.tokens[i]
            if t == PAD:
                continue
            out.append(t)
        return out


def translation_vocab() -> Vocab:
    """specials + src words + tgt words + synonyms; MASK last-but-specials
    so absorbing models share ids with multinomial ones."""
    toks = [PAD, UNK, MASK]
    toks += SRC_WORDS
    toks += TGT_WORDS
    toks += [TGT_SYNONYM[k] for k in sorted(TGT_SYNONYM)]
    return Vocab(toks)


# ---------------------------------------------------------------------------
# Unconditional corpora (text8 / enwik8 analogs), char-level
# ---------------------------------------------------------------------------

TEXT8_CHARS = [PAD, UNK, MASK, " "] + [chr(c) for c in range(ord("a"), ord("z") + 1)]
ENWIK8_CHARS = (
    [PAD, UNK, MASK, " "]
    + [chr(c) for c in range(ord("a"), ord("z") + 1)]
    + list("0123456789")
    + list("<>/=&;.,")
)

UNCOND_SEED = {"synth-text8": 0x7E87_0008, "synth-enwik8": 0xE9B1_0008}


def text8_vocab() -> Vocab:
    return Vocab(list(TEXT8_CHARS))


def enwik8_vocab() -> Vocab:
    return Vocab(list(ENWIK8_CHARS))


def gen_text_stream(corpus: str, split: str, n_chars: int) -> str:
    """Character stream for the unconditional corpora.

    synth-text8 : grammar sentences, lowercase words + spaces only.
    synth-enwik8: same sentences but some wrapped in <p>..</p> / <b>..</b>
                  markup with occasional year digits — the 'messy bytes'
                  analog of enwik8.
    """
    root = Rng(UNCOND_SEED[corpus])
    rng = root.fork(SPLIT_STREAM[split])
    parts: List[str] = []
    total = 0
    while total < n_chars:
        words = gen_sentence(rng)
        s = " ".join(words)
        if corpus == "synth-enwik8":
            if rng.coin(0.3):
                tag = "p" if rng.coin(0.5) else "b"
                s = f"<{tag}>{s}</{tag}>"
            if rng.coin(0.2):
                year = 1900 + rng.below(120)
                s = s + f" {year};"
        parts.append(s)
        total += len(s) + 1
    return " ".join(parts)[:n_chars]


def gen_text_chunks(corpus: str, split: str, count: int, seq_len: int) -> List[List[int]]:
    vocab = text8_vocab() if corpus == "synth-text8" else enwik8_vocab()
    stream = gen_text_stream(corpus, split, count * seq_len + seq_len)
    chunks = []
    for i in range(count):
        seg = stream[i * seq_len : (i + 1) * seq_len]
        chunks.append([vocab.index.get(c, vocab.index[UNK]) for c in seg])
    return chunks


# ---------------------------------------------------------------------------
# Model / task geometry shared with rust (also serialized to config.json)
# ---------------------------------------------------------------------------

SRC_LEN = 16   # source tokens (conditional)
TGT_LEN = 16   # target tokens (conditional)
UNCOND_LEN = 64  # chars (unconditional)
BATCH_BUCKETS = (1, 4, 16)


def fixtures() -> dict:
    """Cross-language parity fixtures consumed by rust tests."""
    _r = Rng(42)
    fx = {"rng": [_r.next_u64() for _ in range(8)],
          "uniform": [round(Rng(7).uniform(), 12)],
          "datasets": {}}
    for d in DATASETS:
        pairs = gen_pairs(d, "test", 3)
        fx["datasets"][d] = [[" ".join(s), " ".join(t)] for s, t in pairs]
    fx["text8_head"] = gen_text_stream("synth-text8", "test", 64)
    fx["enwik8_head"] = gen_text_stream("synth-enwik8", "test", 64)
    fx["vocab_len"] = {
        "translation": len(translation_vocab()),
        "text8": len(text8_vocab()),
        "enwik8": len(enwik8_vocab()),
    }
    return fx
