"""L1 Pallas kernel: fused DNDM transition update (the paper-specific op).

Implements eq. (9) — the de-randomized reverse step that makes DNDM fast:

    x̂0        = argmax(logits + temperature·gumbel)      (Gumbel-max draw)
    x_{t-1,n} = 1(τ_n = t)·x̂0_n + 1(τ_n ≠ t)·x_{t,n}

plus the per-token log-prob `score` of the decoded token that the top-k
variants (DNDM-k, Alg. 4) rank on. Fusing the three passes (softmax
normalizer, gumbel-perturbed argmax, masked select) into one kernel means
the [N, V] logits tile is read from HBM exactly once.

GPU→TPU rethink (DESIGN.md §Hardware-Adaptation): the per-token curand +
reduction a CUDA port would use becomes a VPU row-reduction over a VMEM
tile of [block_n, V]; gumbel noise is pre-drawn host/device-side and
streamed in as an input so the kernel stays deterministic given its inputs
(which is exactly DNDM's predetermined-transition-time philosophy).

VMEM per grid step (f32): 2·block_n·V + O(block_n). With block_n=8 and
V=1024 that is 64 KiB — far under VMEM; block_n trades occupancy against
the V-width of the tile.

interpret=True always (see attention.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 8


def _transition_kernel(logits_ref, gumbel_ref, x_ref, move_ref,
                       newx_ref, x0_ref, score_ref, *, temperature: float):
    """One [block_n, V] tile: fused perturbation+argmax+logsumexp+select."""
    logits = logits_ref[...].astype(jnp.float32)     # [bn, V]
    pert = logits + temperature * gumbel_ref[...].astype(jnp.float32)

    x0 = jnp.argmax(pert, axis=-1).astype(jnp.int32)  # [bn]

    # log-prob of decoded token: picked - logsumexp(logits), single pass
    mx = jnp.max(logits, axis=-1)
    lse = jnp.log(jnp.sum(jnp.exp(logits - mx[:, None]), axis=-1)) + mx
    # gather via one-hot dot (VPU-friendly; avoids dynamic gather lowering)
    vocab = logits.shape[-1]
    cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    onehot = (cols == x0[:, None]).astype(jnp.float32)
    picked = jnp.sum(logits * onehot, axis=-1)

    move = move_ref[...] != 0
    newx_ref[...] = jnp.where(move, x0, x_ref[...]).astype(jnp.int32)
    x0_ref[...] = x0
    score_ref[...] = (picked - lse).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("temperature", "block_n"))
def transition_step(
    logits: jnp.ndarray,   # [B, N, V] f32
    x_t: jnp.ndarray,      # [B, N]    i32
    gumbel: jnp.ndarray,   # [B, N, V] f32
    move: jnp.ndarray,     # [B, N]    i32 (1 = τ_n == t)
    temperature: float = 1.0,
    block_n: int = DEFAULT_BLOCK_N,
):
    """Fused DNDM transition update. Returns (new_x, x0_hat, score)."""
    b, n, v = logits.shape
    lf = logits.reshape(b * n, v)
    gf = gumbel.reshape(b * n, v)
    xf = x_t.reshape(b * n)
    mf = move.reshape(b * n)

    bn = min(block_n, b * n)
    grid = (pl.cdiv(b * n, bn),)
    new_x, x0_hat, score = pl.pallas_call(
        functools.partial(_transition_kernel, temperature=temperature),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, v), lambda i: (i, 0)),
            pl.BlockSpec((bn, v), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * n,), jnp.int32),
            jax.ShapeDtypeStruct((b * n,), jnp.int32),
            jax.ShapeDtypeStruct((b * n,), jnp.float32),
        ],
        interpret=True,
    )(lf, gf, xf, mf)
    return new_x.reshape(b, n), x0_hat.reshape(b, n), score.reshape(b, n)
