"""L1 Pallas kernel: tiled fused multi-head attention (online softmax).

This is the denoiser's compute hot spot — every NFE the DNDM coordinator
spends is one forward pass dominated by attention + FFN matmuls. The paper
ran a fairseq transformer on an A6000; the TPU rethink (DESIGN.md
§Hardware-Adaptation) is:

  * the GPU shared-memory K/V tile becomes a BlockSpec-scheduled HBM→VMEM
    block: the grid walks (batch·head, q-block) and the kernel loops over
    k-blocks with `jax.lax.fori_loop`, carrying online-softmax state
    (m, l, acc) in VMEM scratch — the flash-attention recurrence;
  * matmuls are shaped for the MXU: block_q × d and block_k × d tiles with
    `preferred_element_type=float32` accumulation;
  * no causal mask — discrete-diffusion denoisers are bidirectional.

interpret=True always (CPU PJRT cannot run Mosaic custom-calls); the
structural tiling is what we optimize, wall-clock on CPU is incidental.

VMEM footprint per grid step (f32):
  q-block  : block_q·d
  k/v-block: 2·block_k·d
  acc      : block_q·d
  m, l     : 2·block_q
With the defaults (block_q=block_k=64, d≤64) that is ≈ 64 KiB ≪ 16 MiB VMEM,
leaving headroom for double-buffered HBM→VMEM prefetch on real hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, kv_len: int, scale: float):
    """One (batch·head, q-block) grid step.

    q_ref: [block_q, d] VMEM; k_ref/v_ref: [kv_len, d] VMEM (k streamed in
    block_k slices below); o_ref: [block_q, d].
    """
    q = q_ref[...].astype(jnp.float32) * scale
    block_q, d = q.shape

    n_kb = pl.cdiv(kv_len, block_k)

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        # MXU matmul: [block_q, d] x [d, block_k]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        # mask padded kv tail (kv_len may not divide block_k)
        kv_ids = i * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        s = jnp.where(kv_ids < kv_len, s, NEG_INF)

        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, acc0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def mha(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
) -> jnp.ndarray:
    """Fused bidirectional multi-head attention.

    q: [B, H, Sq, D]; k, v: [B, H, Sk, D] → [B, H, Sq, D].
    Self- and cross-attention share this entry (Sq ≠ Sk allowed).
    """
    b, h, sq, d = q.shape
    sk = k.shape[2]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    scale = 1.0 / (d ** 0.5)

    # Pad both sequence dims to block multiples: the kernel's k-loop uses
    # dynamic slices, and XLA clamps out-of-bounds starts (which would
    # silently misalign the tail block against its iota mask). Padded kv
    # columns are masked with NEG_INF via kv_len; padded q rows are sliced
    # off the output.
    sq_pad = pl.cdiv(sq, block_q) * block_q
    sk_pad = pl.cdiv(sk, block_k) * block_k
    qf = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad - sq), (0, 0))).reshape(b * h, sq_pad, d)
    kf = jnp.pad(k, ((0, 0), (0, 0), (0, sk_pad - sk), (0, 0))).reshape(b * h, sk_pad, d)
    vf = jnp.pad(v, ((0, 0), (0, 0), (0, sk_pad - sk), (0, 0))).reshape(b * h, sk_pad, d)

    grid = (b * h, sq_pad // block_q)
    out = pl.pallas_call(
        functools.partial(_attn_kernel, block_k=block_k, kv_len=sk, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((None, sk_pad, d), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((None, sk_pad, d), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_pad, d), q.dtype),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(qf, kf, vf)
    return out.reshape(b, h, sq_pad, d)[:, :, :sq, :]
