"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth that python/tests/test_kernel.py sweeps the
Pallas implementations against (hypothesis over shapes / dtypes / seeds).
They are also the `use_pallas=False` fallback inside model.py, which keeps
the L2 graph debuggable without the kernels in the loop.
"""

from __future__ import annotations

import jax.numpy as jnp


def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Multi-head attention oracle.

    q, k, v: [B, H, S, D]  →  out: [B, H, S, D]
    Bidirectional (no causal mask) — discrete-diffusion denoisers attend to
    both past and future positions (§4.1 of the paper). Cross-attention is
    the same math with k/v length ≠ q length.
    """
    d = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(d).astype(q.dtype)
    m = jnp.max(scores, axis=-1, keepdims=True)
    probs = jnp.exp(scores - m)
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def transition_ref(
    logits: jnp.ndarray,   # [B, N, V] denoiser output
    x_t: jnp.ndarray,      # [B, N]    current tokens (int32)
    gumbel: jnp.ndarray,   # [B, N, V] pre-drawn Gumbel(0,1) noise
    move: jnp.ndarray,     # [B, N]    1 where τ_n == t (token transitions now)
    temperature: float = 1.0,
):
    """DNDM transition update oracle — eq. (9) of the paper.

    x̂0 = argmax(logits + temperature·gumbel)   (Gumbel-max categorical draw;
                                                temperature=0 → greedy argmax)
    x_{t-1,n} = 1(move_n)·x̂0_n + 1(¬move_n)·x_{t,n}

    Returns (new_x [B,N] i32, x0_hat [B,N] i32, score [B,N] f32) where score
    is the log-probability of the decoded token under `logits` (used by the
    DNDM-k / RDM-k top-k selection rule, Appendix E).
    """
    pert = logits + jnp.asarray(temperature, logits.dtype) * gumbel
    x0_hat = jnp.argmax(pert, axis=-1).astype(jnp.int32)

    mx = jnp.max(logits, axis=-1)
    lse = jnp.log(jnp.sum(jnp.exp(logits - mx[..., None]), axis=-1)) + mx
    picked = jnp.take_along_axis(logits, x0_hat[..., None], axis=-1)[..., 0]
    score = (picked - lse).astype(jnp.float32)

    new_x = jnp.where(move.astype(bool), x0_hat, x_t).astype(jnp.int32)
    return new_x, x0_hat, score
