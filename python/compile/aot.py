"""AOT export: train checkpoints, lower to HLO text, write artifacts/.

Interchange is HLO **text** — the image's xla_extension 0.5.1 rejects
jax≥0.5 serialized HloModuleProto (64-bit instruction ids); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts layout (DESIGN.md §7):
  artifacts/manifest.json
  artifacts/fixtures.json              cross-language parity fixtures
  artifacts/<model>/config.json        geometry + tensor order
  artifacts/<model>/weights.bin        DNDW1 flat tensor file
  artifacts/<model>/model_b{B}.hlo.txt denoiser, weights as leading args
  artifacts/transition/n{N}_v{V}_b{B}.hlo.txt  fused L1 transition kernel

Denoiser HLO signature (1-tuple output, return_tuple=True):
  cond  : (w_0..w_{P-1}, src i32[B,M], x i32[B,N], t f32[B]) → (logits f32[B,N,V],)
  uncond: (w_0..w_{P-1},              x i32[B,N], t f32[B]) → (logits f32[B,N,V],)
Transition HLO signature:
  (logits f32[B,N,V], x i32[B,N], gumbel f32[B,N,V], move i32[B,N])
      → (new_x i32[B,N], x0_hat i32[B,N], score f32[B,N])

Usage: python -m compile.aot --out ../artifacts   (from python/)
Env:   DNDM_TRAIN_STEPS=8 for a fast smoke build; DNDM_ONLY=name1,name2.
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import common
from . import model as M
from . import trainer
from .kernels import transition as trans_kernel

WEIGHTS_MAGIC = b"DNDW1\x00"


def to_hlo_text(lowered, return_tuple: bool = True) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def write_weights(path: str, named_leaves) -> int:
    """DNDW1 format: magic, u32 count, then per tensor
    (u32 name_len, name, u8 dtype{0:f32,1:i32}, u32 ndim, u32 dims…, LE data)."""
    with open(path, "wb") as f:
        f.write(WEIGHTS_MAGIC)
        f.write(struct.pack("<I", len(named_leaves)))
        total = 0
        for name, leaf in named_leaves:
            arr = np.asarray(leaf)
            if arr.dtype == np.float32:
                dt = 0
            elif arr.dtype == np.int32:
                dt = 1
            else:
                arr = arr.astype(np.float32)
                dt = 0
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BI", dt, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes(order="C"))
            total += arr.size
    return total


def lower_model(cfg: M.ModelConfig, params, bucket: int) -> str:
    leaves, treedef = jax.tree_util.tree_flatten(params)
    n_leaves = len(leaves)

    if cfg.conditional:
        def fn(*args):
            p = jax.tree_util.tree_unflatten(treedef, args[:n_leaves])
            src, x, t = args[n_leaves], args[n_leaves + 1], args[n_leaves + 2]
            return M.apply(p, cfg, x, t, src, use_pallas=True)
        ex = [jax.ShapeDtypeStruct(np.asarray(l).shape, np.asarray(l).dtype) for l in leaves]
        ex += [jax.ShapeDtypeStruct((bucket, cfg.src_len), jnp.int32),
               jax.ShapeDtypeStruct((bucket, cfg.seq_len), jnp.int32),
               jax.ShapeDtypeStruct((bucket,), jnp.float32)]
    else:
        def fn(*args):
            p = jax.tree_util.tree_unflatten(treedef, args[:n_leaves])
            x, t = args[n_leaves], args[n_leaves + 1]
            return M.apply(p, cfg, x, t, None, use_pallas=True)
        ex = [jax.ShapeDtypeStruct(np.asarray(l).shape, np.asarray(l).dtype) for l in leaves]
        ex += [jax.ShapeDtypeStruct((bucket, cfg.seq_len), jnp.int32),
               jax.ShapeDtypeStruct((bucket,), jnp.float32)]

    lowered = jax.jit(fn).lower(*ex)
    return to_hlo_text(lowered)


def lower_transition(bucket: int, n: int, v: int) -> str:
    def fn(logits, x, gumbel, move):
        return trans_kernel.transition_step(logits, x, gumbel, move, temperature=1.0)

    ex = [jax.ShapeDtypeStruct((bucket, n, v), jnp.float32),
          jax.ShapeDtypeStruct((bucket, n), jnp.int32),
          jax.ShapeDtypeStruct((bucket, n, v), jnp.float32),
          jax.ShapeDtypeStruct((bucket, n), jnp.int32)]
    lowered = jax.jit(fn).lower(*ex)
    return to_hlo_text(lowered)


def export_model(out_dir: str, spec: trainer.TrainSpec, cfg, params,
                 buckets=common.BATCH_BUCKETS) -> dict:
    mdir = os.path.join(out_dir, spec.name)
    os.makedirs(mdir, exist_ok=True)

    named = M.flatten_named(params)
    n_params = write_weights(os.path.join(mdir, "weights.bin"), named)

    hlo_paths = {}
    for b in buckets:
        t0 = time.time()
        text = lower_model(cfg, params, b)
        rel = f"{spec.name}/model_b{b}.hlo.txt"
        with open(os.path.join(out_dir, rel), "w") as f:
            f.write(text)
        hlo_paths[str(b)] = rel
        print(f"  lowered {rel} ({len(text)//1024} KiB, {time.time()-t0:.1f}s)")

    config = {
        **cfg.to_json(),
        "kind": spec.kind,
        "task": spec.task,
        "dataset": spec.dataset,
        "continuous": spec.continuous,
        "schedule": spec.schedule,
        "tensor_order": [n for n, _ in named],
        "mask_id": trainer.MASK_ID,
        "noise_lo": trainer.NOISE_LO,
        "train_t_grid": trainer.TRAIN_T_GRID,
    }
    with open(os.path.join(mdir, "config.json"), "w") as f:
        json.dump(config, f, indent=1)

    return {
        "name": spec.name, "kind": spec.kind, "task": spec.task,
        "dataset": spec.dataset, "continuous": spec.continuous,
        "schedule": spec.schedule,
        "config": f"{spec.name}/config.json",
        "weights": f"{spec.name}/weights.bin",
        "hlo": hlo_paths,
        "transition": f"n{cfg.seq_len}_v{cfg.vocab}",
        "n_params": n_params,
        "n_tensors": len(named),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--buckets", default=",".join(map(str, common.BATCH_BUCKETS)))
    args = ap.parse_args()
    out = args.out
    buckets = tuple(int(b) for b in args.buckets.split(","))
    os.makedirs(out, exist_ok=True)
    os.makedirs(os.path.join(out, "transition"), exist_ok=True)

    only = os.environ.get("DNDM_ONLY")
    specs = trainer.default_specs()
    if only:
        keep = set(only.split(","))
        specs = [s for s in specs if s.name in keep]

    entries, shapes = [], set()
    for spec in specs:
        print(f"[aot] training {spec.name} ({spec.kind}, {spec.dataset}"
              f"{', continuous' if spec.continuous else ''})")
        cfg, params = trainer.train(spec)
        entries.append(export_model(out, spec, cfg, params, buckets))
        shapes.add((cfg.seq_len, cfg.vocab))

    trans = {}
    for (n, v) in sorted(shapes):
        tag = f"n{n}_v{v}"
        trans[tag] = {}
        for b in buckets:
            text = lower_transition(b, n, v)
            rel = f"transition/{tag}_b{b}.hlo.txt"
            with open(os.path.join(out, rel), "w") as f:
                f.write(text)
            trans[tag][str(b)] = rel
        print(f"  lowered transition {tag} for buckets {buckets}")

    manifest = {"version": 1, "buckets": list(buckets),
                "models": entries, "transition": trans}
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    with open(os.path.join(out, "fixtures.json"), "w") as f:
        json.dump(common.fixtures(), f, indent=1)
    print(f"[aot] wrote {len(entries)} models → {out}/manifest.json")


if __name__ == "__main__":
    main()
