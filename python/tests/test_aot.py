"""AOT export tests: weights format round-trip, HLO lowering sanity."""

import json
import os
import struct
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, common, model as M, trainer


def read_weights(path):
    """Reference reader for the DNDW1 format (mirrors rust runtime/weights.rs)."""
    out = []
    with open(path, "rb") as f:
        assert f.read(6) == aot.WEIGHTS_MAGIC
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode()
            dt, ndim = struct.unpack("<BI", f.read(5))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            n = int(np.prod(dims)) if ndim else 1
            dtype = np.float32 if dt == 0 else np.int32
            data = np.frombuffer(f.read(4 * n), dtype=dtype).reshape(dims)
            out.append((name, data))
    return out


@pytest.fixture(scope="module")
def tiny_model():
    cfg = M.ModelConfig(vocab=30, seq_len=8, src_len=8, d_model=32,
                        n_heads=2, d_ff=64, enc_layers=1, dec_layers=1)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_weights_roundtrip(tiny_model):
    cfg, params = tiny_model
    named = M.flatten_named(params)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "w.bin")
        n = aot.write_weights(path, named)
        back = read_weights(path)
    assert len(back) == len(named)
    assert n == sum(np.asarray(a).size for _, a in named)
    for (n1, a1), (n2, a2) in zip(named, back):
        assert n1 == n2
        np.testing.assert_array_equal(np.asarray(a1), a2)


def _entry_param_count(text: str) -> int:
    entry = text[text.index("ENTRY"):]
    entry = entry[: entry.index("\n}")]
    return sum(1 for line in entry.splitlines() if "parameter(" in line)


def test_lower_model_produces_entry_hlo(tiny_model):
    cfg, params = tiny_model
    text = aot.lower_model(cfg, params, bucket=2)
    assert "ENTRY" in text and "HloModule" in text
    # weights lead, then src, x, t: parameter count = n_leaves + 3
    n_leaves = len(jax.tree_util.tree_leaves(params))
    assert _entry_param_count(text) == n_leaves + 3


def test_lower_transition_signature():
    text = aot.lower_transition(bucket=2, n=8, v=30)
    assert "ENTRY" in text
    assert _entry_param_count(text) == 4


def test_lowered_model_matches_eager(tiny_model):
    """The lowered+compiled HLO must compute exactly what eager jax does —
    this is the python half of the AOT contract (rust re-checks its side)."""
    cfg, params = tiny_model
    leaves, treedef = jax.tree_util.tree_flatten(params)

    def fn(*args):
        p = jax.tree_util.tree_unflatten(treedef, args[:len(leaves)])
        return M.apply(p, cfg, args[-2], args[-1], args[-3], use_pallas=True)

    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32))
    x = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32))
    t = jnp.asarray([0.3, 0.8], jnp.float32)

    compiled = jax.jit(fn).lower(*leaves, src, x, t).compile()
    got = compiled(*leaves, src, x, t)
    exp = M.apply(params, cfg, x, t, src, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=1e-4, rtol=1e-4)


def test_manifest_written_by_export(tiny_model, tmp_path):
    cfg, params = tiny_model
    spec = trainer.TrainSpec("t_export", "multinomial", "cond", "synth-iwslt14")
    entry = aot.export_model(str(tmp_path), spec, cfg, params, buckets=(1,))
    assert entry["name"] == "t_export"
    assert os.path.exists(tmp_path / entry["weights"])
    assert os.path.exists(tmp_path / entry["hlo"]["1"])
    cfg_json = json.load(open(tmp_path / entry["config"]))
    assert cfg_json["vocab"] == cfg.vocab
    assert cfg_json["tensor_order"] == [n for n, _ in M.flatten_named(params)]
    assert cfg_json["mask_id"] == 2 and cfg_json["noise_lo"] == 3
