"""L1 kernel correctness: Pallas vs pure-jnp oracle.

This is the CORE correctness signal for the compute layer — hypothesis
sweeps shapes/dtypes/seeds and asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, ref, transition

SETTINGS = dict(max_examples=15, deadline=None)


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    sq=st.sampled_from([1, 5, 16, 33]),
    sk=st.sampled_from([1, 7, 16, 64]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mha_matches_ref(b, h, sq, sk, d, seed):
    rng = np.random.default_rng(seed)
    q = _rand(rng, (b, h, sq, d), jnp.float32)
    k = _rand(rng, (b, h, sk, d), jnp.float32)
    v = _rand(rng, (b, h, sk, d), jnp.float32)
    out = attention.mha(q, k, v)
    exp = ref.mha_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("block_q,block_k", [(4, 4), (8, 16), (64, 64), (16, 8)])
def test_mha_block_shapes(block_q, block_k):
    """Tiling must not change the numbers (online-softmax invariance)."""
    rng = np.random.default_rng(0)
    q = _rand(rng, (2, 2, 17, 16), jnp.float32)
    k = _rand(rng, (2, 2, 23, 16), jnp.float32)
    v = _rand(rng, (2, 2, 23, 16), jnp.float32)
    out = attention.mha(q, k, v, block_q=block_q, block_k=block_k)
    exp = ref.mha_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)


def test_mha_bf16_runs():
    rng = np.random.default_rng(1)
    q = _rand(rng, (1, 2, 16, 16), jnp.bfloat16)
    k = _rand(rng, (1, 2, 16, 16), jnp.bfloat16)
    v = _rand(rng, (1, 2, 16, 16), jnp.bfloat16)
    out = attention.mha(q, k, v)
    exp = ref.mha_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                      v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(exp),
                               atol=3e-2, rtol=3e-2)


def test_mha_softmax_rows_sum_to_one_property():
    """out of attention over constant V equals that constant (probs sum to 1)."""
    rng = np.random.default_rng(2)
    q = _rand(rng, (1, 1, 9, 8), jnp.float32)
    k = _rand(rng, (1, 1, 21, 8), jnp.float32)
    v = jnp.ones((1, 1, 21, 8), jnp.float32) * 3.5
    out = attention.mha(q, k, v)
    np.testing.assert_allclose(np.asarray(out), 3.5, atol=1e-5)


# ---------------------------------------------------------------------------
# transition update
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    n=st.sampled_from([1, 4, 16, 64]),
    v=st.sampled_from([5, 27, 99, 130]),
    temp=st.sampled_from([0.0, 0.7, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_transition_matches_ref(b, n, v, temp, seed):
    rng = np.random.default_rng(seed)
    logits = _rand(rng, (b, n, v), jnp.float32)
    gumbel = jnp.asarray(rng.gumbel(size=(b, n, v)).astype(np.float32))
    x_t = jnp.asarray(rng.integers(0, v, size=(b, n)).astype(np.int32))
    move = jnp.asarray(rng.integers(0, 2, size=(b, n)).astype(np.int32))
    got = transition.transition_step(logits, x_t, gumbel, move, temperature=temp)
    exp = ref.transition_ref(logits, x_t, gumbel, move, temperature=temp)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(exp[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(exp[1]))
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(exp[2]),
                               atol=2e-5, rtol=2e-5)


def test_transition_move_semantics():
    """move=0 must copy x_t verbatim; move=1 must install x̂0 (eq. 9)."""
    b, n, v = 2, 8, 13
    rng = np.random.default_rng(3)
    logits = _rand(rng, (b, n, v), jnp.float32)
    x_t = jnp.asarray(rng.integers(0, v, size=(b, n)).astype(np.int32))
    zeros = jnp.zeros((b, n, v), jnp.float32)

    frozen, _, _ = transition.transition_step(
        logits, x_t, zeros, jnp.zeros((b, n), jnp.int32))
    np.testing.assert_array_equal(np.asarray(frozen), np.asarray(x_t))

    moved, x0_hat, _ = transition.transition_step(
        logits, x_t, zeros, jnp.ones((b, n), jnp.int32))
    np.testing.assert_array_equal(np.asarray(moved), np.asarray(x0_hat))
    np.testing.assert_array_equal(
        np.asarray(x0_hat), np.asarray(jnp.argmax(logits, -1)))


def test_transition_scores_are_logprobs():
    """scores must be valid log-probabilities of the decoded token."""
    rng = np.random.default_rng(4)
    logits = _rand(rng, (1, 4, 11), jnp.float32)
    x_t = jnp.zeros((1, 4), jnp.int32)
    zeros = jnp.zeros_like(logits)
    _, x0_hat, score = transition.transition_step(
        logits, x_t, zeros, jnp.ones((1, 4), jnp.int32))
    logp = jax.nn.log_softmax(logits, -1)
    exp = np.take_along_axis(np.asarray(logp), np.asarray(x0_hat)[..., None], -1)[..., 0]
    np.testing.assert_allclose(np.asarray(score), exp, atol=2e-5)
    assert (np.asarray(score) <= 1e-6).all()


def test_transition_gumbel_max_is_categorical():
    """Gumbel-max sampling frequencies ≈ softmax probabilities."""
    v = 4
    logits = jnp.asarray([[np.log([0.1, 0.2, 0.3, 0.4]).astype(np.float32)]])
    rng = np.random.default_rng(5)
    counts = np.zeros(v)
    trials = 800
    g = jnp.asarray(rng.gumbel(size=(trials, 1, 1, v)).astype(np.float32))
    for i in range(trials):
        _, x0, _ = transition.transition_step(
            logits, jnp.zeros((1, 1), jnp.int32), g[i],
            jnp.ones((1, 1), jnp.int32))
        counts[int(x0[0, 0])] += 1
    freq = counts / trials
    np.testing.assert_allclose(freq, [0.1, 0.2, 0.3, 0.4], atol=0.06)
