"""Deterministic data substrate tests (the python half of the parity pact
with rust/src/data — rust re-generates the fixtures and compares)."""

import numpy as np

from compile import common


def test_rng_reference_values():
    """Pin splitmix64 outputs — rust mirrors these exact numbers."""
    r = common.Rng(42)
    vals = [r.next_u64() for _ in range(4)]
    # splitmix64(42) reference sequence
    assert vals[0] == 13679457532755275413
    r2 = common.Rng(42)
    assert [r2.next_u64() for _ in range(4)] == vals


def test_uniform_in_range_and_deterministic():
    r = common.Rng(7)
    us = [r.uniform() for _ in range(1000)]
    assert all(0.0 <= u < 1.0 for u in us)
    assert abs(np.mean(us) - 0.5) < 0.05


def test_fork_streams_are_independent():
    root = common.Rng(1)
    a = root.fork(1)
    root2 = common.Rng(1)
    b = root2.fork(2)
    assert a.next_u64() != b.next_u64()


def test_gen_pairs_deterministic_and_split_disjointness():
    p1 = common.gen_pairs("synth-iwslt14", "test", 5)
    p2 = common.gen_pairs("synth-iwslt14", "test", 5)
    assert p1 == p2
    tr = common.gen_pairs("synth-iwslt14", "train", 5)
    assert tr != p1


def test_translate_iwslt_is_positionwise_cipher():
    rng = common.Rng(0)
    src = common.gen_sentence(rng)
    tgt = common.translate("synth-iwslt14", src, rng)
    assert len(tgt) == len(src)
    for s, t in zip(src, tgt):
        assert t == common.TGT_WORDS[common.SRC_INDEX[s]]


def test_translate_wmt16_swaps_pairs():
    rng = common.Rng(0)
    src = ["the", "fox", "crosses", "a", "river"]
    tgt = common.translate("synth-wmt16", src, rng)
    base = [common.TGT_WORDS[common.SRC_INDEX[w]] for w in src]
    assert tgt[0] == base[1] and tgt[1] == base[0]
    assert tgt[4] == base[4]  # odd tail unswapped


def test_translate_wmt14_reverses_and_is_ambiguous():
    rng1, rng2 = common.Rng(1), common.Rng(2)
    src = common.gen_sentence(common.Rng(3))
    t1 = common.translate("synth-wmt14", src, rng1)
    assert len(t1) == len(src)
    # ambiguity: across many rng draws at least one differing output
    outs = {tuple(common.translate("synth-wmt14", src, common.Rng(i)))
            for i in range(20)}
    assert len(outs) >= 1  # (≥2 whenever src hits a synonym word)
    any_syn = any(common.SRC_INDEX[w] in common.TGT_SYNONYM for w in src)
    if any_syn:
        assert len(outs) >= 2


def test_vocab_encode_decode_roundtrip():
    v = common.translation_vocab()
    words = ["the", "quick", "fox"]
    ids = v.encode(words, 8)
    assert len(ids) == 8
    assert ids[3:] == [v.pad_id] * 5
    assert v.decode(ids) == words


def test_vocab_bijection():
    v = common.translation_vocab()
    assert len(set(v.tokens)) == len(v.tokens)
    assert v.tokens[0] == common.PAD and v.tokens[2] == common.MASK


def test_text_stream_charsets():
    s8 = common.gen_text_stream("synth-text8", "test", 500)
    assert set(s8) <= set(" abcdefghijklmnopqrstuvwxyz")
    e8 = common.gen_text_stream("synth-enwik8", "test", 2000)
    allowed = set(" abcdefghijklmnopqrstuvwxyz0123456789<>/=&;.,")
    assert set(e8) <= allowed
    assert "<" in e8  # markup actually appears


def test_text_chunks_shape_and_ids():
    chunks = common.gen_text_chunks("synth-text8", "valid", 4, 64)
    arr = np.array(chunks)
    assert arr.shape == (4, 64)
    v = common.text8_vocab()
    assert (arr >= 0).all() and (arr < len(v)).all()


def test_fixtures_structure():
    fx = common.fixtures()
    assert len(fx["rng"]) == 8
    assert set(fx["datasets"]) == set(common.DATASETS)
    for d in common.DATASETS:
        assert len(fx["datasets"][d]) == 3
    assert len(fx["text8_head"]) == 64
