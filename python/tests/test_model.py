"""L2 model tests: shapes, conditioning, pallas/oracle parity, trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import common, model as M, trainer


def tiny_cond_cfg():
    return M.ModelConfig(vocab=40, seq_len=8, src_len=8, d_model=32,
                         n_heads=2, d_ff=64, enc_layers=1, dec_layers=1)


def tiny_uncond_cfg():
    return M.ModelConfig(vocab=20, seq_len=12, src_len=0, d_model=32,
                         n_heads=2, d_ff=64, enc_layers=0, dec_layers=2)


@pytest.fixture(scope="module")
def cond_setup():
    cfg = tiny_cond_cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def uncond_setup():
    cfg = tiny_uncond_cfg()
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    return cfg, params


def test_cond_shapes(cond_setup):
    cfg, params = cond_setup
    b = 3
    src = jnp.zeros((b, cfg.src_len), jnp.int32)
    x = jnp.zeros((b, cfg.seq_len), jnp.int32)
    t = jnp.full((b,), 0.5, jnp.float32)
    logits = M.apply(params, cfg, x, t, src, use_pallas=False)
    assert logits.shape == (b, cfg.seq_len, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_uncond_shapes(uncond_setup):
    cfg, params = uncond_setup
    x = jnp.zeros((2, cfg.seq_len), jnp.int32)
    t = jnp.full((2,), 0.25, jnp.float32)
    logits = M.apply(params, cfg, x, t, None, use_pallas=False)
    assert logits.shape == (2, cfg.seq_len, cfg.vocab)


def test_pallas_oracle_parity(cond_setup):
    """use_pallas=True and False must produce the same logits — this is
    what guarantees the AOT artifact (pallas path) equals the trained net
    (oracle path)."""
    cfg, params = cond_setup
    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.integers(0, cfg.vocab, (2, cfg.src_len)).astype(np.int32))
    x = jnp.asarray(rng.integers(0, cfg.vocab, (2, cfg.seq_len)).astype(np.int32))
    t = jnp.asarray([0.1, 0.9], jnp.float32)
    a = M.apply(params, cfg, x, t, src, use_pallas=True)
    b = M.apply(params, cfg, x, t, src, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)


def test_time_conditioning_changes_output(cond_setup):
    cfg, params = cond_setup
    src = jnp.zeros((1, cfg.src_len), jnp.int32)
    x = jnp.ones((1, cfg.seq_len), jnp.int32)
    a = M.apply(params, cfg, x, jnp.asarray([0.05]), src, use_pallas=False)
    b = M.apply(params, cfg, x, jnp.asarray([0.95]), src, use_pallas=False)
    assert float(jnp.abs(a - b).max()) > 1e-3


def test_src_conditioning_changes_output(cond_setup):
    cfg, params = cond_setup
    x = jnp.ones((1, cfg.seq_len), jnp.int32)
    t = jnp.asarray([0.5])
    a = M.apply(params, cfg, x, t, jnp.zeros((1, cfg.src_len), jnp.int32), use_pallas=False)
    b = M.apply(params, cfg, x, t, jnp.ones((1, cfg.src_len), jnp.int32), use_pallas=False)
    assert float(jnp.abs(a - b).max()) > 1e-3


def test_flatten_order_is_deterministic(cond_setup):
    cfg, params = cond_setup
    n1 = [n for n, _ in M.flatten_named(params)]
    n2 = [n for n, _ in M.flatten_named(M.init_params(jax.random.PRNGKey(9), cfg))]
    assert n1 == n2
    assert len(n1) == len(set(n1))


def test_alpha_schedules_boundaries():
    for s in ("linear", "cosine", "cosine_sq"):
        a0 = float(trainer.alpha_of(s, jnp.asarray(0.0)))
        a1 = float(trainer.alpha_of(s, jnp.asarray(1.0)))
        assert abs(a0 - 1.0) < 1e-6 and abs(a1) < 1e-6
        ts = jnp.linspace(0, 1, 11)
        av = np.asarray(trainer.alpha_of(s, ts))
        assert (np.diff(av) <= 1e-9).all(), f"{s} not decreasing"


def test_corrupt_multinomial_marginal():
    """q(x_t|x0) keep-rate must track α(t) (Thm 3.1's marginal)."""
    key = jax.random.PRNGKey(0)
    x0 = jnp.full((64, 32), 7, jnp.int32)
    t = jnp.full((64,), 0.4, jnp.float32)
    x_t = trainer.corrupt(key, x0, t, "multinomial", "linear", vocab=50)
    keep = float((x_t == 7).mean())
    a = 0.6 + 0.4 / 50  # α + (1-α)/Kish: noise can also hit 7 (uniform incl. 7)
    assert abs(keep - a) < 0.05


def test_corrupt_absorbing_uses_mask():
    key = jax.random.PRNGKey(0)
    x0 = jnp.full((64, 32), 7, jnp.int32)
    t = jnp.full((64,), 0.7, jnp.float32)
    x_t = trainer.corrupt(key, x0, t, "absorbing", "linear", vocab=50)
    vals = set(np.unique(np.asarray(x_t)).tolist())
    assert vals <= {7, trainer.MASK_ID}
    frac_mask = float((x_t == trainer.MASK_ID).mean())
    assert abs(frac_mask - 0.7) < 0.06


def test_short_training_reduces_loss():
    spec = trainer.TrainSpec("t_smoke", "absorbing", "cond", "synth-iwslt14",
                             steps=30, batch=16)
    cfg = tiny_cond_cfg()

    # use the real pipeline but with the tiny config by monkey-patching
    orig = trainer.make_config
    trainer.make_config = lambda s: cfg
    try:
        src, tgt = trainer.cond_dataset(spec, "train", 64)
        # shrink real data to the tiny geometry (8 tokens, vocab 40)
        src = np.minimum(src[:, : cfg.src_len], cfg.vocab - 1)
        tgt = np.minimum(tgt[:, : cfg.seq_len], cfg.vocab - 1)
        key = jax.random.PRNGKey(0)
        params = M.init_params(key, cfg)
        opt = trainer.adam_init(params)

        @jax.jit
        def step(params, opt, key, x0, s):
            loss, grads = jax.value_and_grad(trainer.loss_fn)(
                params, cfg, key, x0, s, spec.kind, spec.schedule, False)
            params, opt = trainer.adam_step(params, grads, opt, 2e-3)
            return params, opt, loss

        losses = []
        for i in range(30):
            key, kk = jax.random.split(key)
            idx = np.arange((i * 16) % 64, (i * 16) % 64 + 16) % 64
            params, opt, loss = step(params, opt, kk,
                                     jnp.asarray(tgt[idx]), jnp.asarray(src[idx]))
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.8, losses
    finally:
        trainer.make_config = orig
