#!/usr/bin/env python3
"""Check that relative markdown links resolve to real files.

Usage: check_doc_links.py FILE_OR_DIR [FILE_OR_DIR ...]

Scans every given markdown file (directories are scanned for *.md,
recursively) for inline links/images `[text](target)` and reference
definitions `[label]: target`, and verifies that each relative target —
after stripping any #fragment — exists on disk, resolved against the
containing file's directory. External links (http/https/mailto),
pure-fragment links (#section), and absolute paths are skipped: CI has
no network, and the repo pins only its own cross-file structure.

Exit status: 0 when every link resolves, 1 otherwise (each broken link
is listed as file:line: target).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

# inline [text](target) / ![alt](target); target ends at the first
# unescaped ')' — markdown in this repo uses no nested parens in URLs
INLINE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
# reference definitions: [label]: target
REFDEF = re.compile(r"^\s*\[[^\]]+\]:\s+(\S+)")

SKIP_PREFIXES = ("http://", "https://", "mailto:", "#", "/")


def md_files(args: list[str]) -> list[Path]:
    files: list[Path] = []
    for a in args:
        p = Path(a)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        else:
            files.append(p)
    return files


def targets_in(line: str) -> list[str]:
    found = [m.group(1) for m in INLINE.finditer(line)]
    m = REFDEF.match(line)
    if m:
        found.append(m.group(1))
    return found


def strip_code_spans(line: str) -> str:
    # `…` spans may contain link-shaped rust code (e.g. vec![x](y) never
    # happens, but doc text quotes markdown syntax itself)
    return re.sub(r"`[^`]*`", "`code`", line)


def main(argv: list[str]) -> int:
    if not argv:
        print(__doc__, file=sys.stderr)
        return 2
    broken: list[str] = []
    checked = 0
    for f in md_files(argv):
        if not f.exists():
            broken.append(f"{f}: file not found")
            continue
        in_fence = False
        for lineno, line in enumerate(f.read_text(encoding="utf-8").splitlines(), 1):
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for target in targets_in(strip_code_spans(line)):
                if target.startswith(SKIP_PREFIXES):
                    continue
                path = target.split("#", 1)[0]
                if not path:
                    continue
                checked += 1
                if not (f.parent / path).exists():
                    broken.append(f"{f}:{lineno}: {target}")
    if broken:
        print("broken relative links:")
        for b in broken:
            print(f"  {b}")
        return 1
    print(f"doc link check OK ({checked} relative links resolved)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
