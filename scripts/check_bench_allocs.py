#!/usr/bin/env python3
"""CI gate: fail when allocs/call in a serving bench run regresses past the
committed ceiling, when any row fired a ghost event, or when any row saw a
fatal fault or an open circuit breaker.

Usage: check_bench_allocs.py BENCH_serving.json serving_allocs_baseline.json

The bench JSON is what `cargo bench --bench serving_throughput` emits; the
baseline maps each policy row to a ceiling on `allocs_per_call`. Throughput
and latency are NOT gated (too noisy on shared runners) — heap acquisitions
per denoiser call are deterministic enough to hold a line on, and they are
the flat-data-path metric the repo actually optimizes (docs/perf.md).

`ghost_events_fired` (a denoiser call at which zero rows moved — only
possible if lane narrowing fails to retire a departed row's transition
times) is gated at exactly 0 on EVERY row that reports it, including rows
with no allocs ceiling: per-row event ladders make ghosts structurally
impossible, so any nonzero value is a correctness bug, not noise. The
bench's narrowing scenario cancels requests mid-flight specifically to
exercise this.

`faults_fatal` and `breaker_open` are likewise gated at exactly 0 on every
row that reports them: the chaos scenario injects transient faults only, at
a rate far below the breaker threshold, so the retry policy must absorb all
of them (docs/robustness.md). A fatal fault or an open breaker on any bench
row means fault classification or the retry ladder regressed.

`rejected_rate_limit` / `rejected_deadline` are gated both ways
(docs/http.md): rows whose policy name does not contain "admission" run
with no admission controller in front, so any nonzero rejection count
there means accounting leaked across scenarios. The "admission" row runs a
deterministic over-capacity burst (no-refill token bucket + a deadline the
exact-cost projection cannot meet once the backlog grows), so BOTH
counters must be strictly positive — zero means the shed path silently
stopped shedding.

`early_retired` / `turbo_truncated_nfe` are gated both ways the same way
(docs/tiers.md): only the "tiered" row submits Balanced/Turbo requests,
so both must be strictly positive there (a zero means truncation or
confidence-based retirement silently stopped firing) and exactly 0 on
every other row (Quality-path requests must never be truncated or
retired early — that would break the byte-identity guarantee).

Ratchet policy (see the baseline file): ceilings start generous; once the
uploaded BENCH_serving.json artifacts record a stable trajectory, lower
each ceiling to ~1.5x the observed steady value.
"""

import json
import sys


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        bench = json.load(f)
    with open(sys.argv[2]) as f:
        base = json.load(f)
    ceilings = base["max_allocs_per_call"]
    if bench.get("backend") != base.get("backend", "mock"):
        print(
            f"note: bench backend '{bench.get('backend')}' != baseline backend "
            f"'{base.get('backend', 'mock')}' — gating anyway"
        )
    failures = []
    seen = set()
    for row in bench["rows"]:
        policy = row["policy"]
        seen.add(policy)
        ghosts = row.get("ghost_events_fired")
        if ghosts is not None and ghosts != 0:
            print(f"{policy:28s} ghost_events_fired {ghosts}  GHOST EVENTS (must be 0)")
            failures.append(policy)
        for field in ("faults_fatal", "breaker_open"):
            bad = row.get(field)
            if bad is not None and bad != 0:
                print(f"{policy:28s} {field} {bad}  FAULT ESCALATION (must be 0)")
                failures.append(policy)
        is_admission = "admission" in policy
        for field in ("rejected_rate_limit", "rejected_deadline"):
            count = row.get(field)
            if count is None:
                continue
            if is_admission and count == 0:
                print(f"{policy:28s} {field} {count}  ADMISSION DID NOT SHED (must be > 0)")
                failures.append(policy)
            elif not is_admission and count != 0:
                print(f"{policy:28s} {field} {count}  REJECTION LEAK (must be 0)")
                failures.append(policy)
        is_tiered = "tiered" in policy
        for field in ("early_retired", "turbo_truncated_nfe"):
            count = row.get(field)
            if count is None:
                continue
            if is_tiered and count == 0:
                print(f"{policy:28s} {field} {count}  TIER PATH INERT (must be > 0)")
                failures.append(policy)
            elif not is_tiered and count != 0:
                print(f"{policy:28s} {field} {count}  TIER LEAK (must be 0)")
                failures.append(policy)
        value = row["allocs_per_call"]
        if policy not in ceilings:
            print(f"{policy:28s} allocs/call {value:9.1f}  (no ceiling — not gated)")
            continue
        limit = ceilings[policy]
        ok = value <= limit
        print(
            f"{policy:28s} allocs/call {value:9.1f}  ceiling {limit:9.1f}  "
            f"{'ok' if ok else 'REGRESSION'}"
        )
        if not ok:
            failures.append(policy)
    missing = sorted(set(ceilings) - seen)
    if missing:
        print(f"\nbaseline rows missing from the bench output: {', '.join(missing)}")
        failures.extend(missing)
    if failures:
        print(f"\nbench gate failed for: {', '.join(sorted(set(failures)))}")
        print("If an allocs/call regression is intentional, raise the ceiling in")
        print(f"{sys.argv[2]} in the same PR and say why in its comment field.")
        print("A nonzero ghost_events_fired has no ceiling to raise — it is a")
        print("lane-narrowing correctness bug; fix it. Likewise faults_fatal /")
        print("breaker_open: the bench injects transient faults only, so either")
        print("means fault classification or the retry ladder regressed.")
        print("rejected_* counts must be 0 off the admission row and > 0 on it:")
        print("the admission burst is sized to shed deterministically (docs/http.md).")
        print("early_retired / turbo_truncated_nfe must be 0 off the tiered row and")
        print("> 0 on it: only Balanced/Turbo requests may be retired or truncated")
        print("(docs/tiers.md).")
        return 1
    print(
        "\nbench gate passed (allocs/call ceilings + ghost_events_fired == 0"
        " + faults_fatal == 0 + breaker_open == 0 + admission sheds, tiers"
        " retire/truncate, others don't)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
