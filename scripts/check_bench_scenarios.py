#!/usr/bin/env python3
"""CI gate for the scenario-mix load harness (`docs/scenarios.md`).

Usage:
  check_bench_scenarios.py BENCH_scenarios.json scenarios_latency_baseline.json [COMMITTED.json]

The bench JSON is what `cargo bench --bench bench_scenarios` emits; the
baseline maps each scenario to ratchetable ceilings on the e2e latency
percentiles. With the optional third argument, the fresh run is also
compared against the committed BENCH_scenarios.json on its
*deterministic* fields (scenario set, request counts, `nfe_exact`
flags) — wall-clock fields are machine-dependent and never diffed.

Hard invariant gates (exact, every row):

* `ghost_events_fired == 0` — a denoiser call at which zero rows moved
  is only possible if lane narrowing failed to retire a departed row's
  transition times; the cancel storm exists to exercise this.
* `faults_fatal == 0` and `breaker_open == 0` — the chaos scenario
  injects transient faults only, at a rate far below the breaker
  threshold; either nonzero means fault classification or the retry
  ladder regressed.
* `deadline_exceeded == 0` — no scenario submits deadlines.
* NFE conservation: on rows flagged `nfe_exact`, `served_nfe ==
  expected_nfe` exactly — |T| is predetermined at admission, so the
  sequence-evaluation tally has an exact expectation. On `cancel_storm`
  (the one row where cancellation legitimately reduces served work)
  `served_nfe` must stay strictly below the uncancelled expectation.

Both-ways scenario gates (a counter leaking across scenarios is an
accounting bug, a missing one is a silently-inert path):

* `cancel_storm` — `cancelled > 0`; every other row exactly 0.
* `chaos_transient` — `retries > 0` and `faults_transient > 0`; every
  other row exactly 0.
* `tiered_mix` — `early_retired > 0` and `turbo_truncated_nfe > 0`;
  every other row exactly 0 (Quality-path requests must never be
  truncated or retired early).
* `skewed_tenant` — `tenant_total == requests` and `tenant_count == 4`;
  every other row submits no attribution (`tenant_total == 0`).

Ratchet policy (see the baseline file): latency ceilings start generous
— shared runners are noisy — and only ratchet down once the uploaded
BENCH_scenarios artifacts record a stable trajectory; lower each
ceiling to ~2x the observed steady p99/p999.
"""

import json
import sys

REQUIRED = [
    "poisson_burst",
    "mixed_spec",
    "cancel_storm",
    "skewed_tenant",
    "tiered_mix",
    "chaos_transient",
]

# field -> scenario that must be strictly positive there, zero elsewhere
BOTH_WAYS = {
    "cancelled": "cancel_storm",
    "retries": "chaos_transient",
    "faults_transient": "chaos_transient",
    "early_retired": "tiered_mix",
    "turbo_truncated_nfe": "tiered_mix",
    "tenant_total": "skewed_tenant",
}


def gate_rows(bench, base):
    failures = []
    rows = {r["scenario"]: r for r in bench["rows"]}
    missing = [s for s in REQUIRED if s not in rows]
    if missing:
        print(f"required scenarios missing from the bench output: {', '.join(missing)}")
        failures.extend(missing)
    if len(bench["rows"]) < 6:
        print(f"expected >= 6 scenario rows, got {len(bench['rows'])}")
        failures.append("row-count")
    for name, row in rows.items():
        for field in ("ghost_events_fired", "faults_fatal", "breaker_open", "deadline_exceeded"):
            if row.get(field, 0) != 0:
                print(f"{name:16s} {field} {row[field]}  INVARIANT VIOLATION (must be 0)")
                failures.append(name)
        if row.get("nfe_exact") and row["served_nfe"] != row["expected_nfe"]:
            print(
                f"{name:16s} served_nfe {row['served_nfe']} != expected_nfe "
                f"{row['expected_nfe']}  NFE NOT CONSERVED"
            )
            failures.append(name)
        if name == "cancel_storm" and row["served_nfe"] >= row["expected_nfe"]:
            print(
                f"{name:16s} served_nfe {row['served_nfe']} >= uncancelled expectation "
                f"{row['expected_nfe']}  CANCELLATION DID NOT SHED WORK"
            )
            failures.append(name)
        for field, home in BOTH_WAYS.items():
            count = row.get(field)
            if count is None:
                continue
            if name == home and count == 0:
                print(f"{name:16s} {field} {count}  PATH INERT (must be > 0)")
                failures.append(name)
            elif name != home and count != 0:
                print(f"{name:16s} {field} {count}  COUNTER LEAK (must be 0)")
                failures.append(name)
        if name == "skewed_tenant":
            if row.get("tenant_total") != row["requests"]:
                print(f"{name:16s} tenant_total {row.get('tenant_total')}  != requests")
                failures.append(name)
            if row.get("tenant_count") != 4:
                print(f"{name:16s} tenant_count {row.get('tenant_count')}  != 4 Zipf ranks")
                failures.append(name)
        for pct in ("e2e_p99_ms", "e2e_p999_ms"):
            ceilings = base.get(f"max_{pct}", {})
            if name not in ceilings:
                print(f"{name:16s} {pct} {row[pct]:9.1f}  (no ceiling — not gated)")
                failures.append(f"{name}:no-ceiling:{pct}")
                continue
            limit = ceilings[name]
            ok = row[pct] <= limit
            print(f"{name:16s} {pct} {row[pct]:9.1f}  ceiling {limit:9.1f}  {'ok' if ok else 'REGRESSION'}")
            if not ok:
                failures.append(name)
    return failures


def compare_deterministic(fresh, committed):
    """The committed-JSON diff, restricted to fields that are identical on
    every machine: scenario set, request counts, nfe_exact flags."""
    failures = []
    f_rows = {r["scenario"]: r for r in fresh["rows"]}
    c_rows = {r["scenario"]: r for r in committed["rows"]}
    if set(f_rows) != set(c_rows):
        print(
            "scenario set drifted from the committed BENCH_scenarios.json: "
            f"fresh {sorted(f_rows)} vs committed {sorted(c_rows)}"
        )
        failures.append("scenario-set")
    for name in sorted(set(f_rows) & set(c_rows)):
        for field in ("requests", "nfe_exact"):
            if f_rows[name].get(field) != c_rows[name].get(field):
                print(
                    f"{name:16s} {field}: fresh {f_rows[name].get(field)} != committed "
                    f"{c_rows[name].get(field)}  (update the committed JSON in this PR)"
                )
                failures.append(name)
    return failures


def main() -> int:
    if len(sys.argv) not in (3, 4):
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        bench = json.load(f)
    with open(sys.argv[2]) as f:
        base = json.load(f)
    if bench.get("backend") != "mock":
        print(f"scenario harness must be mock-backed, got backend '{bench.get('backend')}'")
        return 1
    failures = gate_rows(bench, base)
    if len(sys.argv) == 4:
        with open(sys.argv[3]) as f:
            committed = json.load(f)
        failures += compare_deterministic(bench, committed)
    if failures:
        print(f"\nscenario gate failed for: {', '.join(sorted(set(str(f) for f in failures)))}")
        print("If a latency regression is intentional, raise the ceiling in")
        print(f"{sys.argv[2]} in the same PR and say why in its comment field.")
        print("ghost_events_fired / faults_fatal / breaker_open / NFE conservation")
        print("have no ceilings to raise — each is a correctness invariant; fix it.")
        return 1
    print("\nscenario gate passed (invariants exact, latency under ceilings)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
