//! Server-Sent Events framing over the [`Ticket`] lifecycle stream.
//!
//! A [`Ticket`] already holds one **coalescing snapshot** per request —
//! bounded memory no matter how slow the reader — so the SSE layer is a
//! thin poll loop: drain `try_next_event()`, frame each [`Event`] as one
//! SSE event with a JSON `data:` payload, and sleep briefly when nothing
//! is new (emitting a heartbeat comment on an interval so proxies and
//! clients can tell a quiet stream from a dead one).
//!
//! The one piece of real logic is disconnect handling: any write error
//! (the client went away) calls [`Ticket::cancel`], so the request's lane
//! slot is freed at the next transition-time boundary instead of burning
//! denoiser calls for a reader that no longer exists.
//!
//! Event grammar (documented in `docs/http.md`):
//!
//! ```text
//! event: queued | admitted | progress | done | cancelled
//!      | deadline_exceeded | failed
//! data: <one-line JSON object>
//! ```

use std::collections::BTreeMap;
use std::io;
use std::time::{Duration, Instant};

use crate::coordinator::{Event, Ticket, TierDecision};
use crate::util::json::Json;

/// Poll interval while the snapshot has nothing new. Event latency under
/// streaming is bounded by this plus the scheduler's boundary cadence.
const IDLE_POLL: Duration = Duration::from_millis(10);

/// Heartbeat comment frame — a no-op for SSE clients, a liveness probe
/// for everything in between.
pub const HEARTBEAT: &str = ": hb\n\n";

/// Frame one SSE event: optional `event:` name, then the payload split
/// into one `data:` line per payload line (the SSE spec's multi-line
/// encoding — the client's EventSource rejoins them with `\n`).
pub fn frame(event: Option<&str>, data: &str) -> String {
    let mut out = String::new();
    if let Some(name) = event {
        out.push_str("event: ");
        out.push_str(name);
        out.push('\n');
    }
    for line in data.split('\n') {
        out.push_str("data: ");
        out.push_str(line);
        out.push('\n');
    }
    out.push('\n');
    out
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

/// JSON rendering of a [`TierDecision`], shared by the SSE `admitted`
/// event and the blocking response's `"tier"` field.
pub fn tier_json(d: &TierDecision) -> String {
    obj(vec![
        ("chosen_spec", Json::Str(d.chosen_spec.clone())),
        ("projected_nfe", Json::Num(d.projected_nfe as f64)),
        ("projected_ms", Json::Num(d.projected_ms as f64)),
    ])
    .to_string()
}

/// Frame one lifecycle [`Event`] as an SSE event with a JSON payload.
pub fn event_frame(ev: &Event) -> String {
    match ev {
        Event::Admitted { decision } => {
            let fields = match decision {
                Some(d) => vec![(
                    "tier",
                    Json::Obj(
                        [
                            ("chosen_spec".to_string(), Json::Str(d.chosen_spec.clone())),
                            ("projected_nfe".to_string(), Json::Num(d.projected_nfe as f64)),
                            ("projected_ms".to_string(), Json::Num(d.projected_ms as f64)),
                        ]
                        .into_iter()
                        .collect::<BTreeMap<_, _>>(),
                    ),
                )],
                None => vec![],
            };
            frame(Some("admitted"), &obj(fields).to_string())
        }
        Event::Progress { nfe_done, nfe_total, partial_tokens } => {
            let mut fields = vec![
                ("nfe_done", Json::Num(*nfe_done as f64)),
                ("nfe_total", Json::Num(*nfe_total as f64)),
            ];
            if !partial_tokens.is_empty() {
                fields.push((
                    "partial_tokens",
                    Json::Arr(partial_tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
                ));
            }
            frame(Some("progress"), &obj(fields).to_string())
        }
        Event::Done(out) => frame(
            Some("done"),
            &obj(vec![
                ("text", Json::Str(out.text.clone())),
                ("tokens", Json::Arr(out.tokens.iter().map(|&t| Json::Num(t as f64)).collect())),
                ("nfe", Json::Num(out.nfe as f64)),
                ("elapsed_us", Json::Num(out.elapsed.as_micros() as f64)),
            ])
            .to_string(),
        ),
        Event::Cancelled => frame(Some("cancelled"), &obj(vec![]).to_string()),
        Event::DeadlineExceeded => frame(Some("deadline_exceeded"), &obj(vec![]).to_string()),
        Event::Failed(msg) => {
            frame(Some("failed"), &obj(vec![("error", Json::Str(msg.clone()))]).to_string())
        }
    }
}

/// How a streamed ticket ended.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StreamEnd {
    /// Generation finished; carries the final NFE and wall time (µs) for
    /// the admission EWMA.
    Done { nfe: usize, elapsed_us: u64 },
    Cancelled,
    DeadlineExceeded,
    Failed,
    /// The client went away mid-stream; the ticket was cancelled so the
    /// scheduler frees the lane slot at the next boundary.
    Disconnected,
}

/// Pump a ticket's events into `write` as SSE frames until the stream
/// ends one way or the other. `write` is called once per frame (the HTTP
/// layer's [`ChunkSink`](super::http::ChunkSink) flushes per call, so a
/// dead client surfaces here as an `Err`).
pub fn stream_ticket(
    ticket: &mut Ticket,
    heartbeat: Duration,
    mut write: impl FnMut(&str) -> io::Result<()>,
) -> StreamEnd {
    let mut last_write = Instant::now();
    loop {
        match ticket.try_next_event() {
            Some(ev) => {
                let end = match &ev {
                    Event::Done(out) => Some(StreamEnd::Done {
                        nfe: out.nfe,
                        elapsed_us: out.elapsed.as_micros() as u64,
                    }),
                    Event::Cancelled => Some(StreamEnd::Cancelled),
                    Event::DeadlineExceeded => Some(StreamEnd::DeadlineExceeded),
                    Event::Failed(_) => Some(StreamEnd::Failed),
                    Event::Admitted { .. } | Event::Progress { .. } => None,
                };
                if write(&event_frame(&ev)).is_err() {
                    ticket.cancel();
                    return StreamEnd::Disconnected;
                }
                last_write = Instant::now();
                if let Some(end) = end {
                    return end;
                }
            }
            None => {
                if ticket.finished() {
                    // terminal already delivered before we got here
                    return StreamEnd::Failed;
                }
                if last_write.elapsed() >= heartbeat {
                    if write(HEARTBEAT).is_err() {
                        ticket.cancel();
                        return StreamEnd::Disconnected;
                    }
                    last_write = Instant::now();
                }
                std::thread::sleep(IDLE_POLL);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::GenOutput;
    use std::sync::{Arc, Mutex};
    use std::time::Duration;

    #[test]
    fn frame_escapes_multiline_data_one_prefix_per_line() {
        let f = frame(Some("done"), "line one\nline two\nline three");
        assert_eq!(f, "event: done\ndata: line one\ndata: line two\ndata: line three\n\n");
    }

    #[test]
    fn frame_without_event_name_is_data_only() {
        assert_eq!(frame(None, "x"), "data: x\n\n");
    }

    #[test]
    fn heartbeat_is_a_comment_frame() {
        assert!(HEARTBEAT.starts_with(':'));
        assert!(HEARTBEAT.ends_with("\n\n"));
    }

    #[test]
    fn progress_frame_carries_nfe_and_tokens() {
        let f = event_frame(&Event::Progress {
            nfe_done: 3,
            nfe_total: 8,
            partial_tokens: vec![4, 7],
        });
        assert!(f.starts_with("event: progress\n"), "{f}");
        assert!(f.contains("\"nfe_done\":3"), "{f}");
        assert!(f.contains("\"nfe_total\":8"), "{f}");
        assert!(f.contains("\"partial_tokens\":[4,7]"), "{f}");
    }

    #[test]
    fn unsubscribed_progress_omits_tokens() {
        let f = event_frame(&Event::Progress { nfe_done: 1, nfe_total: 2, partial_tokens: vec![] });
        assert!(!f.contains("partial_tokens"), "{f}");
    }

    #[test]
    fn admitted_frame_echoes_the_tier_decision() {
        use crate::coordinator::TierDecision;
        let d = TierDecision {
            chosen_spec: "dndm:beta:15:7@25".into(),
            projected_nfe: 8,
            projected_ms: 12,
        };
        let f = event_frame(&Event::Admitted { decision: Some(d.clone()) });
        assert!(f.starts_with("event: admitted\n"), "{f}");
        let data = f.lines().find(|l| l.starts_with("data: ")).unwrap();
        let json = Json::parse(&data["data: ".len()..]).expect("payload parses");
        let tier = json.get("tier").expect("tier object");
        assert_eq!(tier.str_field("chosen_spec").unwrap(), "dndm:beta:15:7@25");
        assert_eq!(tier.num_field("projected_nfe").unwrap(), 8.0);
        assert_eq!(tier.num_field("projected_ms").unwrap(), 12.0);
        // the blocking path splices the same JSON under "tier"
        assert!(Json::parse(&tier_json(&d)).is_ok());
        // untiered requests keep the old empty payload
        let f = event_frame(&Event::Admitted { decision: None });
        assert!(f.contains("data: {}"), "{f}");
    }

    #[test]
    fn done_frame_is_parseable_json_with_the_output() {
        let f = event_frame(&Event::Done(GenOutput {
            text: "a \"quoted\" line".into(),
            tokens: vec![1, 2, 3],
            nfe: 5,
            elapsed: Duration::from_micros(1234),
        }));
        let data = f.lines().find(|l| l.starts_with("data: ")).unwrap();
        let json = Json::parse(&data["data: ".len()..]).expect("payload parses");
        assert_eq!(json.str_field("text").unwrap(), "a \"quoted\" line");
        assert_eq!(json.num_field("nfe").unwrap(), 5.0);
        assert_eq!(json.num_field("elapsed_us").unwrap(), 1234.0);
        assert_eq!(json.get("tokens").and_then(Json::as_arr).unwrap().len(), 3);
    }

    #[test]
    fn stream_delivers_lifecycle_then_done() {
        let (mut t, sink) = Ticket::detached(false);
        sink.set_admitted();
        sink.progress(2, 2, None);
        sink.finish_done(GenOutput {
            text: "out".into(),
            tokens: vec![9],
            nfe: 2,
            elapsed: Duration::from_micros(10),
        });
        let frames: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        let sunk = frames.clone();
        let end = stream_ticket(&mut t, Duration::from_secs(60), move |f| {
            sunk.lock().unwrap().push(f.to_string());
            Ok(())
        });
        assert_eq!(end, StreamEnd::Done { nfe: 2, elapsed_us: 10 });
        let frames = frames.lock().unwrap();
        assert!(frames[0].starts_with("event: admitted\n"));
        assert!(frames[1].starts_with("event: progress\n"));
        assert!(frames[2].starts_with("event: done\n"));
    }

    #[test]
    fn write_error_cancels_the_ticket() {
        let (mut t, sink) = Ticket::detached(false);
        sink.set_admitted();
        let end = stream_ticket(&mut t, Duration::from_secs(60), |_| {
            Err(io::Error::new(io::ErrorKind::BrokenPipe, "client gone"))
        });
        assert_eq!(end, StreamEnd::Disconnected);
        // the serving side now sees the cancel flag and frees the lane
        // slot at the next boundary
        assert!(sink.is_cancelled());
    }

    #[test]
    fn quiet_stream_emits_heartbeats() {
        let (mut t, sink) = Ticket::detached(false);
        let finisher = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(80));
            sink.finish_cancelled();
        });
        let frames: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        let sunk = frames.clone();
        let end = stream_ticket(&mut t, Duration::from_millis(20), move |f| {
            sunk.lock().unwrap().push(f.to_string());
            Ok(())
        });
        assert_eq!(end, StreamEnd::Cancelled);
        finisher.join().unwrap();
        let frames = frames.lock().unwrap();
        assert!(
            frames.iter().any(|f| f == HEARTBEAT),
            "expected a heartbeat among {frames:?}"
        );
    }
}
