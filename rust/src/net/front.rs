//! The network front door: routes HTTP requests onto the serving stack.
//!
//! ```text
//!            POST /v1/generate            GET /metrics   GET /healthz
//!                  │                            │             │
//!  parse JSON ──▶ exact_cost (host-only 𝒯) ──▶ render       health
//!                  │                        ServerStats
//!          Admission::admit  ──▶ 429 / 503 + Retry-After (never submits)
//!                  │
//!        Router::submit_request_routed ──▶ charge(actual shard)
//!                  │
//!        stream? ──┴─▶ SSE (chunked)  else  block on the ticket
//! ```
//!
//! The admission check happens **before** submit, on the shard
//! [`Router::peek_placement`] projects; the charge happens **after**, on
//! the shard the router actually picked (a rebalance can race the
//! submit). A rejected request therefore never consumes a denoiser call,
//! a lane slot, or even a queue entry — the acceptance test pins this by
//! asserting `nn_calls == 0` after a burst of unmeetable requests.

use std::io;
use std::net::ToSocketAddrs;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::{Event, GenRequest, Priority, Router, Ticket};
use crate::runtime::ModelConfig;
use crate::sampler::{SamplerConfig, SamplerKind};
use crate::schedule::{TransitionOrder, TransitionSpec};
use crate::util::json::Json;

use super::admission::{exact_cost, Admission, AdmissionPolicy};
use super::http::{HttpOptions, HttpServer, Request, Response};
use super::metrics::{render, FrontGauges};
use super::sse::{event_frame, frame, stream_ticket, StreamEnd};

/// Default heartbeat interval on quiet SSE streams.
const HEARTBEAT_EVERY: Duration = Duration::from_secs(5);

/// Everything the HTTP handler needs, shared across connection workers.
/// The admission controller sits behind an `Arc` because SSE streaming
/// closures (which must be `'static`) carry their own handle to it for
/// end-of-stream accounting.
pub struct FrontDoor {
    router: Arc<Router>,
    mcfg: ModelConfig,
    default_cfg: SamplerConfig,
    admission: Arc<Admission>,
    connections: Arc<AtomicU64>,
    heartbeat: Duration,
}

/// Bind the front door on `addr` and start serving. The returned
/// [`HttpServer`] owns the listener; dropping it stops serving (the
/// router is left running — it belongs to the caller).
pub fn serve<A: ToSocketAddrs>(
    addr: A,
    router: Arc<Router>,
    mcfg: ModelConfig,
    default_cfg: SamplerConfig,
    policy: AdmissionPolicy,
    opts: HttpOptions,
) -> io::Result<HttpServer> {
    let connections = Arc::new(AtomicU64::new(0));
    let door = FrontDoor {
        admission: Arc::new(Admission::new(policy, router.num_shards())),
        router,
        mcfg,
        default_cfg,
        connections: connections.clone(),
        heartbeat: HEARTBEAT_EVERY,
    };
    HttpServer::bind_gauged(addr, opts, move |req: Request| door.route(req), connections)
}

/// Parsed body of `POST /v1/generate`.
struct GenBody {
    seed: u64,
    src: Option<String>,
    cfg: Option<SamplerConfig>,
    deadline: Option<Duration>,
    priority: Priority,
    tenant: Option<String>,
    stream: bool,
    partial_tokens: bool,
}

fn err_json(status: u16, msg: &str) -> Response {
    Response::json(status, format!("{{\"error\":{}}}", Json::Str(msg.to_string())))
}

impl FrontDoor {
    fn route(&self, req: Request) -> Response {
        match (req.method.as_str(), req.path()) {
            ("POST", "/v1/generate") => self.generate(&req),
            ("GET", "/metrics") => self.metrics(),
            ("GET", "/healthz") => self.healthz(),
            (_, "/v1/generate") | (_, "/metrics") | (_, "/healthz") => {
                err_json(405, "method not allowed")
            }
            _ => err_json(404, "not found"),
        }
    }

    /// Build the effective sampler config: the server default, overridden
    /// field-by-field where the body names one. `None` = no override at
    /// all, so the request inherits future server-side default changes.
    fn build_cfg(&self, body: &Json) -> Result<Option<SamplerConfig>, String> {
        let has_override = ["sampler", "steps", "spec", "order", "temperature"]
            .iter()
            .any(|k| body.get(k).is_some());
        if !has_override {
            return Ok(None);
        }
        let mut cfg = self.default_cfg.clone();
        if let Some(v) = body.get("sampler") {
            let name = v.as_str().ok_or("'sampler' must be a string")?;
            cfg.kind =
                SamplerKind::parse(name).ok_or_else(|| format!("unknown sampler {name:?}"))?;
        }
        if let Some(v) = body.get("steps") {
            cfg.steps = v.as_usize().ok_or("'steps' must be a number")?;
        }
        if let Some(v) = body.get("spec") {
            let s = v.as_str().ok_or("'spec' must be a string")?;
            cfg.spec = TransitionSpec::parse(s).ok_or_else(|| format!("unknown spec {s:?}"))?;
        }
        if let Some(v) = body.get("order") {
            cfg.order = match v.as_str().ok_or("'order' must be a string")? {
                "random" => TransitionOrder::Random,
                "l2r" => TransitionOrder::LeftToRight,
                "r2l" => TransitionOrder::RightToLeft,
                other => return Err(format!("unknown order {other:?} (random|l2r|r2l)")),
            };
        }
        if let Some(v) = body.get("temperature") {
            cfg.temperature = v.as_f64().ok_or("'temperature' must be a number")? as f32;
        }
        Ok(Some(cfg))
    }

    fn parse_body(&self, raw: &[u8]) -> Result<GenBody, String> {
        let text = std::str::from_utf8(raw).map_err(|_| "body is not UTF-8".to_string())?;
        let body = Json::parse(text).map_err(|e| format!("body is not JSON: {e}"))?;
        let seed = body.get("seed").and_then(Json::as_f64).ok_or("missing number field 'seed'")?;
        if seed < 0.0 || seed.fract() != 0.0 {
            return Err("'seed' must be a non-negative integer".into());
        }
        let priority = match body.get("priority").map(|v| v.as_str()) {
            None => Priority::Normal,
            Some(Some("low")) => Priority::Low,
            Some(Some("normal")) => Priority::Normal,
            Some(Some("high")) => Priority::High,
            Some(other) => {
                return Err(format!("unknown priority {other:?} (low|normal|high)"));
            }
        };
        Ok(GenBody {
            seed: seed as u64,
            src: body.get("src").and_then(Json::as_str).map(str::to_string),
            cfg: self.build_cfg(&body)?,
            deadline: body
                .get("deadline_ms")
                .and_then(Json::as_f64)
                .map(|ms| Duration::from_micros((ms * 1000.0) as u64)),
            priority,
            tenant: body.get("tenant").and_then(Json::as_str).map(str::to_string),
            stream: body.get("stream").and_then(Json::as_bool).unwrap_or(false),
            partial_tokens: body.get("partial_tokens").and_then(Json::as_bool).unwrap_or(false),
        })
    }

    fn generate(&self, req: &Request) -> Response {
        let body = match self.parse_body(&req.body) {
            Ok(b) => b,
            Err(msg) => return err_json(400, &msg),
        };

        // exact pre-compute cost: |𝒯| from a host-only session build
        let cfg_used = body.cfg.clone().unwrap_or_else(|| self.default_cfg.clone());
        let cost = match exact_cost(&self.mcfg, &cfg_used, body.seed) {
            Ok(c) => c,
            Err(e) => return err_json(400, &format!("invalid sampler config: {e}")),
        };

        let mut gen = GenRequest::new(body.seed).priority(body.priority);
        if let Some(src) = &body.src {
            gen = gen.src(src.clone());
        }
        if let Some(cfg) = body.cfg {
            gen = gen.config(cfg);
        }
        if let Some(d) = body.deadline {
            gen = gen.deadline(d);
        }
        if let Some(t) = &body.tenant {
            gen = gen.tenant(t.clone());
        }
        if body.partial_tokens {
            gen = gen.stream_partials();
        }

        // admission: check on the projected shard, never submit on reject
        let projected = self.router.peek_placement(&gen);
        if let Err(rej) =
            self.admission.admit(body.tenant.as_deref(), projected, cost, body.deadline)
        {
            let retry = rej.retry_after_secs();
            let reason = match &rej {
                super::admission::Rejection::RateLimited { .. } => {
                    "tenant rate limit exceeded".to_string()
                }
                super::admission::Rejection::DeadlineUnmeetable { projected, deadline, .. } => {
                    format!(
                        "deadline unmeetable: projected {} ms for {} calls, deadline {} ms",
                        projected.as_millis(),
                        cost,
                        deadline.as_millis()
                    )
                }
            };
            return err_json(rej.status(), &reason).header("retry-after", retry.to_string());
        }

        let (ticket, shard) = match self.router.submit_request_routed(gen) {
            Ok(pair) => pair,
            Err(e) => return err_json(500, &format!("submit failed: {e}")),
        };
        self.admission.charge(shard, cost);

        if body.stream {
            self.stream_response(ticket, shard, cost)
        } else {
            self.block_response(ticket, shard, cost)
        }
    }

    /// SSE path: first a `queued` frame carrying the exact cost, then the
    /// ticket's lifecycle. Runs on the connection worker; a write error
    /// (client gone) cancels the ticket and releases the admission
    /// charge.
    fn stream_response(&self, mut ticket: Ticket, shard: usize, cost: u64) -> Response {
        // Response::stream's closure must be 'static, so it carries its
        // own admission handle for the end-of-stream accounting
        let admission = self.admission.clone();
        let heartbeat = self.heartbeat;
        let queued = frame(Some("queued"), &format!("{{\"nfe_total\":{cost}}}"));
        Response::stream(200, "text/event-stream", move |sink| {
            sink.send(queued.as_bytes())?;
            let end = stream_ticket(&mut ticket, heartbeat, |f| sink.send(f.as_bytes()));
            match end {
                StreamEnd::Done { nfe, elapsed_us } => {
                    admission.observe(shard, nfe as u64, Duration::from_micros(elapsed_us));
                }
                StreamEnd::Cancelled
                | StreamEnd::DeadlineExceeded
                | StreamEnd::Failed
                | StreamEnd::Disconnected => admission.release(shard, cost),
            }
            Ok(())
        })
        .header("cache-control", "no-store")
    }

    /// Blocking path: drive the ticket to its terminal event and answer
    /// with one JSON body.
    fn block_response(&self, mut ticket: Ticket, shard: usize, cost: u64) -> Response {
        loop {
            match ticket.next_event() {
                Some(Event::Done(out)) => {
                    self.admission.observe(shard, out.nfe as u64, out.elapsed);
                    // reuse the SSE JSON payload: same fields, same writer
                    let f = event_frame(&Event::Done(out));
                    let json = f
                        .lines()
                        .find_map(|l| l.strip_prefix("data: "))
                        .unwrap_or("{}")
                        .to_string();
                    return Response::json(200, json);
                }
                Some(Event::DeadlineExceeded) => {
                    self.admission.release(shard, cost);
                    return err_json(504, "deadline exceeded in flight");
                }
                Some(Event::Cancelled) => {
                    self.admission.release(shard, cost);
                    return err_json(500, "request cancelled");
                }
                Some(Event::Failed(msg)) => {
                    self.admission.release(shard, cost);
                    return err_json(500, &msg);
                }
                Some(Event::Admitted | Event::Progress { .. }) => continue,
                None => {
                    self.admission.release(shard, cost);
                    return err_json(500, "event stream ended without a result");
                }
            }
        }
    }

    fn metrics(&self) -> Response {
        let stats = match self.router.stats() {
            Ok(s) => s,
            Err(e) => return err_json(500, &format!("stats unavailable: {e}")),
        };
        let front = FrontGauges {
            rejected_rate_limit: self.admission.rejected_rate_limit(),
            rejected_deadline: self.admission.rejected_deadline(),
            connections_open: self.connections.load(Ordering::Relaxed),
        };
        Response::new(200)
            .header("content-type", "text/plain; version=0.0.4; charset=utf-8")
            .with_body(render(&stats, &front).into_bytes())
    }

    fn healthz(&self) -> Response {
        match self.router.stats() {
            Ok(s) if s.healthy => Response::text(200, "ok\n"),
            Ok(_) => Response::text(503, "unhealthy\n"),
            Err(e) => Response::text(503, format!("stats unavailable: {e}\n")),
        }
    }
}
