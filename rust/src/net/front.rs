//! The network front door: routes HTTP requests onto the serving stack.
//!
//! ```text
//!            POST /v1/generate            GET /metrics   GET /healthz
//!                  │                            │             │
//!  parse JSON ──▶ exact_cost (host-only 𝒯) ──▶ render       health
//!                  │                        ServerStats
//!      Admission::resolve_tier  ──▶ 503 + Retry-After (SLO unmeetable)
//!                  │   (tiered: spec search over exact costs)
//!      Admission::place_and_charge ──▶ 429 / 503 (never submits)
//!                  │   (lowest projected-wait shard, charged atomically)
//!        Router::submit_request_to(shard)
//!                  │
//!        stream? ──┴─▶ SSE (chunked)  else  block on the ticket
//! ```
//!
//! Placement and admission are one decision:
//! [`Admission::place_and_charge`] picks the shard with the lowest
//! *projected wait* (backlog NFE × that shard's measured µs/NFE),
//! checks the deadline against that exact projection, and charges it —
//! then the request is pinned there with
//! [`Router::submit_request_to`], so the account can never drift from
//! placement. A rejected request never consumes a denoiser call, a lane
//! slot, or even a queue entry — the acceptance test pins this by
//! asserting `nn_calls == 0` after a burst of unmeetable requests.
//!
//! Serving tiers (`docs/tiers.md`): a request may carry `"tier"` —
//! `"quality"` (default, config untouched), `"balanced"` + `"slo_ms"`
//! (cheapest-adequate schedule picked at admission), or `"turbo"` +
//! `"max_nfe"` (hard NFE cap via deterministic ladder truncation). The
//! chosen schedule and its projections are echoed back as a
//! [`TierDecision`] in the SSE `admitted` event and the blocking JSON
//! body.

use std::io;
use std::net::ToSocketAddrs;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::{Event, GenRequest, Priority, Router, Ticket, Tier, TierDecision};
use crate::runtime::ModelConfig;
use crate::sampler::{SamplerConfig, SamplerKind};
use crate::schedule::{TransitionOrder, TransitionSpec};
use crate::util::json::Json;

use super::admission::{exact_cost, Admission, AdmissionPolicy, Rejection};
use super::http::{HttpOptions, HttpServer, Request, Response};
use super::metrics::{render, FrontGauges};
use super::sse::{event_frame, frame, stream_ticket, tier_json, StreamEnd};

/// Default heartbeat interval on quiet SSE streams.
const HEARTBEAT_EVERY: Duration = Duration::from_secs(5);

/// Everything the HTTP handler needs, shared across connection workers.
/// The admission controller sits behind an `Arc` because SSE streaming
/// closures (which must be `'static`) carry their own handle to it for
/// end-of-stream accounting.
pub struct FrontDoor {
    router: Arc<Router>,
    mcfg: ModelConfig,
    default_cfg: SamplerConfig,
    admission: Arc<Admission>,
    connections: Arc<AtomicU64>,
    heartbeat: Duration,
}

/// Bind the front door on `addr` and start serving. The returned
/// [`HttpServer`] owns the listener; dropping it stops serving (the
/// router is left running — it belongs to the caller).
pub fn serve<A: ToSocketAddrs>(
    addr: A,
    router: Arc<Router>,
    mcfg: ModelConfig,
    default_cfg: SamplerConfig,
    policy: AdmissionPolicy,
    opts: HttpOptions,
) -> io::Result<HttpServer> {
    let connections = Arc::new(AtomicU64::new(0));
    let admission = Arc::new(Admission::new(policy, router.num_shards()));
    // index-aligned boards for the opt-in engine-measured pace
    // (`AdmissionPolicy::use_board_pace`); attaching is free otherwise
    admission.attach_boards(router.boards());
    let door = FrontDoor {
        admission,
        router,
        mcfg,
        default_cfg,
        connections: connections.clone(),
        heartbeat: HEARTBEAT_EVERY,
    };
    HttpServer::bind_gauged(addr, opts, move |req: Request| door.route(req), connections)
}

/// Parsed body of `POST /v1/generate`.
struct GenBody {
    seed: u64,
    src: Option<String>,
    cfg: Option<SamplerConfig>,
    deadline: Option<Duration>,
    priority: Priority,
    tenant: Option<String>,
    stream: bool,
    partial_tokens: bool,
    tier: Option<Tier>,
}

/// Parse the tier surface. `"tier"`, `"slo_ms"` and `"max_nfe"` are one
/// coherent knob: `"balanced"` requires `slo_ms`, `"turbo"` requires
/// `max_nfe`, and a bare `slo_ms` / `max_nfe` implies its tier. Balanced
/// and Turbo pick the schedule themselves, so explicit `steps`/`spec`
/// overrides conflict with them → 400.
fn parse_tier(body: &Json) -> Result<Option<Tier>, String> {
    let slo_ms = match body.get("slo_ms") {
        None => None,
        Some(v) => {
            let ms = v.as_f64().ok_or("'slo_ms' must be a number")?;
            if ms < 1.0 {
                return Err("'slo_ms' must be >= 1".into());
            }
            Some(ms as u64)
        }
    };
    let max_nfe = match body.get("max_nfe") {
        None => None,
        Some(v) => match v.as_usize() {
            Some(n) if n >= 1 => Some(n),
            _ => return Err("'max_nfe' must be a positive integer".into()),
        },
    };
    let tier = match body.get("tier") {
        None => match (slo_ms, max_nfe) {
            (None, None) => return Ok(None),
            (Some(ms), None) => Tier::Balanced { slo_ms: ms },
            (None, Some(n)) => Tier::Turbo { max_nfe: n },
            (Some(_), Some(_)) => {
                return Err("'slo_ms' and 'max_nfe' are mutually exclusive".into());
            }
        },
        Some(v) => match (v.as_str().ok_or("'tier' must be a string")?, slo_ms, max_nfe) {
            ("quality", None, None) => Tier::Quality,
            ("quality", ..) => {
                return Err("tier \"quality\" takes neither 'slo_ms' nor 'max_nfe'".into());
            }
            ("balanced", Some(ms), None) => Tier::Balanced { slo_ms: ms },
            ("balanced", ..) => {
                return Err("tier \"balanced\" requires 'slo_ms' (and no 'max_nfe')".into());
            }
            ("turbo", None, Some(n)) => Tier::Turbo { max_nfe: n },
            ("turbo", ..) => {
                return Err("tier \"turbo\" requires 'max_nfe' (and no 'slo_ms')".into());
            }
            (other, ..) => return Err(format!("unknown tier {other:?} (quality|balanced|turbo)")),
        },
    };
    if !matches!(tier, Tier::Quality) && ["steps", "spec"].iter().any(|k| body.get(k).is_some()) {
        return Err("tier-driven schedule selection conflicts with explicit 'steps'/'spec'".into());
    }
    Ok(Some(tier))
}

fn err_json(status: u16, msg: &str) -> Response {
    Response::json(status, format!("{{\"error\":{}}}", Json::Str(msg.to_string())))
}

impl FrontDoor {
    fn route(&self, req: Request) -> Response {
        match (req.method.as_str(), req.path()) {
            ("POST", "/v1/generate") => self.generate(&req),
            ("GET", "/metrics") => self.metrics(),
            ("GET", "/healthz") => self.healthz(),
            (_, "/v1/generate") | (_, "/metrics") | (_, "/healthz") => {
                err_json(405, "method not allowed")
            }
            _ => err_json(404, "not found"),
        }
    }

    /// Build the effective sampler config: the server default, overridden
    /// field-by-field where the body names one. `None` = no override at
    /// all, so the request inherits future server-side default changes.
    fn build_cfg(&self, body: &Json) -> Result<Option<SamplerConfig>, String> {
        let has_override = ["sampler", "steps", "spec", "order", "temperature"]
            .iter()
            .any(|k| body.get(k).is_some());
        if !has_override {
            return Ok(None);
        }
        let mut cfg = self.default_cfg.clone();
        if let Some(v) = body.get("sampler") {
            let name = v.as_str().ok_or("'sampler' must be a string")?;
            cfg.kind =
                SamplerKind::parse(name).ok_or_else(|| format!("unknown sampler {name:?}"))?;
        }
        if let Some(v) = body.get("steps") {
            cfg.steps = v.as_usize().ok_or("'steps' must be a number")?;
        }
        if let Some(v) = body.get("spec") {
            let s = v.as_str().ok_or("'spec' must be a string")?;
            cfg.spec = TransitionSpec::parse(s).ok_or_else(|| format!("unknown spec {s:?}"))?;
        }
        if let Some(v) = body.get("order") {
            cfg.order = match v.as_str().ok_or("'order' must be a string")? {
                "random" => TransitionOrder::Random,
                "l2r" => TransitionOrder::LeftToRight,
                "r2l" => TransitionOrder::RightToLeft,
                other => return Err(format!("unknown order {other:?} (random|l2r|r2l)")),
            };
        }
        if let Some(v) = body.get("temperature") {
            cfg.temperature = v.as_f64().ok_or("'temperature' must be a number")? as f32;
        }
        Ok(Some(cfg))
    }

    fn parse_body(&self, raw: &[u8]) -> Result<GenBody, String> {
        let text = std::str::from_utf8(raw).map_err(|_| "body is not UTF-8".to_string())?;
        let body = Json::parse(text).map_err(|e| format!("body is not JSON: {e}"))?;
        let seed = body.get("seed").and_then(Json::as_f64).ok_or("missing number field 'seed'")?;
        if seed < 0.0 || seed.fract() != 0.0 {
            return Err("'seed' must be a non-negative integer".into());
        }
        let priority = match body.get("priority").map(|v| v.as_str()) {
            None => Priority::Normal,
            Some(Some("low")) => Priority::Low,
            Some(Some("normal")) => Priority::Normal,
            Some(Some("high")) => Priority::High,
            Some(other) => {
                return Err(format!("unknown priority {other:?} (low|normal|high)"));
            }
        };
        Ok(GenBody {
            seed: seed as u64,
            src: body.get("src").and_then(Json::as_str).map(str::to_string),
            cfg: self.build_cfg(&body)?,
            deadline: body
                .get("deadline_ms")
                .and_then(Json::as_f64)
                .map(|ms| Duration::from_micros((ms * 1000.0) as u64)),
            priority,
            tenant: body.get("tenant").and_then(Json::as_str).map(str::to_string),
            stream: body.get("stream").and_then(Json::as_bool).unwrap_or(false),
            partial_tokens: body.get("partial_tokens").and_then(Json::as_bool).unwrap_or(false),
            tier: parse_tier(&body)?,
        })
    }

    /// Render a [`Rejection`] as the HTTP error response, `Retry-After`
    /// included. `cost` is the exact NFE the projection priced.
    fn reject(&self, rej: &Rejection, cost: u64) -> Response {
        let retry = rej.retry_after_secs();
        let reason = match rej {
            Rejection::RateLimited { .. } => "tenant rate limit exceeded".to_string(),
            Rejection::DeadlineUnmeetable { projected, deadline, .. } => {
                format!(
                    "deadline unmeetable: projected {} ms for {} calls, deadline {} ms",
                    projected.as_millis(),
                    cost,
                    deadline.as_millis()
                )
            }
        };
        err_json(rej.status(), &reason).header("retry-after", retry.to_string())
    }

    fn generate(&self, req: &Request) -> Response {
        let body = match self.parse_body(&req.body) {
            Ok(b) => b,
            Err(msg) => return err_json(400, &msg),
        };

        // exact pre-compute cost: |𝒯| from a host-only session build —
        // an invalid config is a 400 regardless of tier
        let cfg_used = body.cfg.clone().unwrap_or_else(|| self.default_cfg.clone());
        let base_cost = match exact_cost(&self.mcfg, &cfg_used, body.seed) {
            Ok(c) => c,
            Err(e) => return err_json(400, &format!("invalid sampler config: {e}")),
        };

        // tier resolution: pure host-side spec search; an unmeetable
        // Balanced SLO rejects here, before any compute
        let (cfg_override, decision, cost) = match body.tier {
            Some(tier) => {
                match self.admission.resolve_tier(&self.mcfg, &cfg_used, body.seed, tier) {
                    Ok((cfg, d)) => {
                        let cost = d.projected_nfe;
                        // Quality serves the config untouched — keep the
                        // body's override (or None, inheriting future
                        // server-default changes); the cheaper tiers pin
                        // the schedule they chose
                        let cfg = match tier {
                            Tier::Quality => body.cfg.clone(),
                            _ => Some(cfg),
                        };
                        (cfg, Some(d), cost)
                    }
                    Err(rej) => return self.reject(&rej, base_cost),
                }
            }
            None => (body.cfg.clone(), None, base_cost),
        };

        // one placement decision: lowest projected-wait shard, deadline
        // checked against that exact projection, charged atomically
        let shard = match self.admission.place_and_charge(body.tenant.as_deref(), cost, body.deadline)
        {
            Ok(s) => s,
            Err(rej) => return self.reject(&rej, cost),
        };

        let mut gen = GenRequest::new(body.seed).priority(body.priority);
        if let Some(src) = &body.src {
            gen = gen.src(src.clone());
        }
        if let Some(cfg) = cfg_override {
            gen = gen.config(cfg);
        }
        if let Some(d) = body.deadline {
            gen = gen.deadline(d);
        }
        if let Some(t) = &body.tenant {
            gen = gen.tenant(t.clone());
        }
        if body.partial_tokens {
            gen = gen.stream_partials();
        }
        if let Some(tier) = body.tier {
            gen = gen.tier(tier);
        }
        gen.decision = decision.clone();

        let ticket = match self.router.submit_request_to(shard, gen) {
            Ok(t) => t,
            Err(e) => {
                self.admission.release(shard, cost);
                return err_json(500, &format!("submit failed: {e}"));
            }
        };

        if body.stream {
            self.stream_response(ticket, shard, cost)
        } else {
            self.block_response(ticket, shard, cost, decision)
        }
    }

    /// SSE path: first a `queued` frame carrying the exact cost, then the
    /// ticket's lifecycle. Runs on the connection worker; a write error
    /// (client gone) cancels the ticket and releases the admission
    /// charge.
    fn stream_response(&self, mut ticket: Ticket, shard: usize, cost: u64) -> Response {
        // Response::stream's closure must be 'static, so it carries its
        // own admission handle for the end-of-stream accounting
        let admission = self.admission.clone();
        let heartbeat = self.heartbeat;
        let queued = frame(Some("queued"), &format!("{{\"nfe_total\":{cost}}}"));
        Response::stream(200, "text/event-stream", move |sink| {
            sink.send(queued.as_bytes())?;
            let end = stream_ticket(&mut ticket, heartbeat, |f| sink.send(f.as_bytes()));
            match end {
                StreamEnd::Done { nfe, elapsed_us } => {
                    // release the full admission charge; early-retired
                    // requests served fewer NFE than they were charged
                    admission.observe_served(
                        shard,
                        cost,
                        nfe as u64,
                        Duration::from_micros(elapsed_us),
                    );
                }
                StreamEnd::Cancelled
                | StreamEnd::DeadlineExceeded
                | StreamEnd::Failed
                | StreamEnd::Disconnected => admission.release(shard, cost),
            }
            Ok(())
        })
        .header("cache-control", "no-store")
    }

    /// Blocking path: drive the ticket to its terminal event and answer
    /// with one JSON body. A tier decision is echoed as a `"tier"` field
    /// alongside the result, mirroring the SSE `admitted` event.
    fn block_response(
        &self,
        mut ticket: Ticket,
        shard: usize,
        cost: u64,
        decision: Option<TierDecision>,
    ) -> Response {
        loop {
            match ticket.next_event() {
                Some(Event::Done(out)) => {
                    self.admission.observe_served(shard, cost, out.nfe as u64, out.elapsed);
                    // reuse the SSE JSON payload: same fields, same writer
                    let f = event_frame(&Event::Done(out));
                    let json = f
                        .lines()
                        .find_map(|l| l.strip_prefix("data: "))
                        .unwrap_or("{}")
                        .to_string();
                    let json = match &decision {
                        Some(d) if json.len() > 2 => {
                            format!("{{\"tier\":{},{}", tier_json(d), &json[1..])
                        }
                        Some(d) => format!("{{\"tier\":{}}}", tier_json(d)),
                        None => json,
                    };
                    return Response::json(200, json);
                }
                Some(Event::DeadlineExceeded) => {
                    self.admission.release(shard, cost);
                    return err_json(504, "deadline exceeded in flight");
                }
                Some(Event::Cancelled) => {
                    self.admission.release(shard, cost);
                    return err_json(500, "request cancelled");
                }
                Some(Event::Failed(msg)) => {
                    self.admission.release(shard, cost);
                    return err_json(500, &msg);
                }
                Some(Event::Admitted { .. } | Event::Progress { .. }) => continue,
                None => {
                    self.admission.release(shard, cost);
                    return err_json(500, "event stream ended without a result");
                }
            }
        }
    }

    fn metrics(&self) -> Response {
        // Served from the shards' lock-free boards, not `Msg::Stats`
        // round-trips: a scrape never blocks on a breaker-parked or dead
        // shard's message loop (it reads that shard's last published
        // snapshot), and cannot fail. tests/http.rs pins board == channel
        // at quiesce; tests/scenarios.rs pins the parked-shard scrape.
        let stats = self.router.board_stats();
        let front = FrontGauges {
            rejected_rate_limit: self.admission.rejected_rate_limit(),
            rejected_deadline: self.admission.rejected_deadline(),
            connections_open: self.connections.load(Ordering::Relaxed),
            shard_ewma_us_per_nfe: self.admission.shard_ewmas(),
            shard_queued_nfe: self.admission.shard_queued(),
            tenant_pace: self.admission.tenant_pace(),
        };
        Response::new(200)
            .header("content-type", "text/plain; version=0.0.4; charset=utf-8")
            .with_body(render(&stats, &front).into_bytes())
    }

    fn healthz(&self) -> Response {
        // board-backed like /metrics: health checks keep answering while
        // a shard is parked (reporting it unhealthy) instead of hanging
        if self.router.board_stats().healthy {
            Response::text(200, "ok\n")
        } else {
            Response::text(503, "unhealthy\n")
        }
    }
}
