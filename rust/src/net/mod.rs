//! The network front door: HTTP/1.1 + SSE serving with exact-cost
//! admission control.
//!
//! Everything under the coordinator speaks Rust types; this module is
//! the wire boundary. It is deliberately layered so each piece tests in
//! isolation and none knows about the ones above it:
//!
//! * [`http`] — dependency-free HTTP/1.1 transport: parsing, bodies,
//!   chunked streaming, keep-alive, timeouts, a bounded worker pool.
//! * [`sse`] — [`Ticket`](crate::coordinator::Ticket) lifecycle events as
//!   Server-Sent Events, with disconnect-driven cancellation.
//! * [`admission`] — per-tenant token buckets plus **exact** deadline
//!   load shedding: a request's denoiser-call cost is the size of its
//!   predetermined transition set, known before any compute, so
//!   rejections are proofs, not guesses.
//! * [`metrics`] — Prometheus text exposition over
//!   [`ServerStats`](crate::coordinator::ServerStats).
//! * [`front`] — the routes: `POST /v1/generate` (JSON in, JSON or SSE
//!   out), `GET /metrics`, `GET /healthz` — wired together by
//!   [`front::serve`].
//!
//! `docs/http.md` is the wire-level reference (endpoint table, request
//! schema, SSE grammar, the admission-control math); `cargo run -- serve
//! --listen 127.0.0.1:8484 --mock` brings the whole thing up without
//! artifacts.

pub mod admission;
pub mod front;
pub mod http;
pub mod metrics;
pub mod sse;

pub use admission::{exact_cost, Admission, AdmissionPolicy, RateLimit, Rejection};
pub use front::{serve, FrontDoor};
pub use http::{HttpOptions, HttpServer};
pub use sse::StreamEnd;
