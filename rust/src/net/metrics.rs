//! Prometheus text exposition for the `/metrics` endpoint.
//!
//! Renders the merged [`ServerStats`] (every counter the serving stack
//! already tracks), the front door's admission counters
//! (`rejected_rate_limit` / `rejected_deadline`), and the transport's
//! `connections_open` gauge as `text/plain; version=0.0.4` — the
//! Prometheus exposition format. No client library exists in-tree, so a
//! tiny [`parse_text`] validator rides along for tests (and doubles as a
//! grammar check: the E2E suite asserts a scrape round-trips).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::coordinator::ServerStats;

/// One metric family: `# HELP` + `# TYPE` + one sample line.
fn sample(out: &mut String, name: &str, kind: &str, help: &str, value: f64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
    let _ = writeln!(out, "{name} {}", fmt_value(value));
}

/// Format a value the way Prometheus expects: integers bare, floats as
/// printed by Rust (both parse fine on the scrape side).
fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Escape a label value per the exposition format: backslash, quote,
/// newline.
fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Extra gauges owned by the front door rather than the router.
#[derive(Debug, Clone, Default)]
pub struct FrontGauges {
    pub rejected_rate_limit: u64,
    pub rejected_deadline: u64,
    pub connections_open: u64,
    /// per-shard measured pace (µs per denoiser call), index = shard —
    /// the number admission projections multiply backlog by
    pub shard_ewma_us_per_nfe: Vec<f64>,
    /// per-shard NFE admitted but not yet retired, index = shard
    pub shard_queued_nfe: Vec<u64>,
    /// per-tenant token-bucket level (requests of burst remaining),
    /// sorted by tenant; empty when rate limiting is off
    pub tenant_pace: Vec<(String, f64)>,
}

/// Render one scrape. `stats` is the router-merged view; per-tenant
/// submit counts become a labelled `dndm_tenant_requests_total` family.
pub fn render(stats: &ServerStats, front: &FrontGauges) -> String {
    let mut out = String::with_capacity(4096);
    let s = stats;

    // cumulative counters
    sample(&mut out, "dndm_requests_total", "counter", "requests submitted", s.requests as f64);
    sample(&mut out, "dndm_batches_total", "counter", "denoiser batches formed", s.batches as f64);
    sample(&mut out, "dndm_nn_calls_total", "counter", "denoiser (NN) calls", s.nn_calls as f64);
    sample(&mut out, "dndm_cancelled_total", "counter", "requests cancelled", s.cancelled as f64);
    sample(
        &mut out,
        "dndm_deadline_exceeded_total",
        "counter",
        "requests dropped past their deadline",
        s.deadline_exceeded as f64,
    );
    sample(
        &mut out,
        "dndm_stolen_total",
        "counter",
        "requests donated to other shards",
        s.stolen as f64,
    );
    sample(
        &mut out,
        "dndm_rebalances_total",
        "counter",
        "rebalance actions executed",
        s.rebalances as f64,
    );
    sample(
        &mut out,
        "dndm_lanes_donated_total",
        "counter",
        "in-flight lanes donated",
        s.lanes_donated as f64,
    );
    sample(
        &mut out,
        "dndm_lanes_split_total",
        "counter",
        "in-flight lanes split",
        s.lanes_split as f64,
    );
    sample(
        &mut out,
        "dndm_lanes_salvaged_total",
        "counter",
        "lanes evacuated during failover",
        s.lanes_salvaged as f64,
    );
    sample(
        &mut out,
        "dndm_ghost_events_fired_total",
        "counter",
        "denoiser calls advancing an event with zero live rows (must stay 0)",
        s.ghost_events_fired as f64,
    );
    sample(&mut out, "dndm_retries_total", "counter", "transient-fault retries", s.retries as f64);
    sample(
        &mut out,
        "dndm_faults_transient_total",
        "counter",
        "transient denoiser faults",
        s.faults_transient as f64,
    );
    sample(
        &mut out,
        "dndm_faults_fatal_total",
        "counter",
        "fatal denoiser faults",
        s.faults_fatal as f64,
    );
    sample(
        &mut out,
        "dndm_rejected_rate_limit_total",
        "counter",
        "requests rejected at admission by the per-tenant token bucket (HTTP 429)",
        front.rejected_rate_limit as f64,
    );
    sample(
        &mut out,
        "dndm_rejected_deadline_total",
        "counter",
        "requests rejected at admission because the exact cost projection exceeds the deadline (HTTP 503)",
        front.rejected_deadline as f64,
    );
    sample(
        &mut out,
        "dndm_early_retired_total",
        "counter",
        "requests retired early because their remaining transitions were provably no-ops (NFE refund)",
        s.early_retired as f64,
    );
    sample(
        &mut out,
        "dndm_turbo_truncated_nfe_total",
        "counter",
        "ladder events dropped by Turbo tier truncation",
        s.turbo_truncated_nfe as f64,
    );

    // instantaneous gauges
    sample(
        &mut out,
        "dndm_connections_open",
        "gauge",
        "open HTTP connections",
        front.connections_open as f64,
    );
    sample(
        &mut out,
        "dndm_queued_low",
        "gauge",
        "queued low-priority requests",
        s.queued_low as f64,
    );
    sample(
        &mut out,
        "dndm_queued_normal",
        "gauge",
        "queued normal-priority requests",
        s.queued_normal as f64,
    );
    sample(
        &mut out,
        "dndm_queued_high",
        "gauge",
        "queued high-priority requests",
        s.queued_high as f64,
    );
    sample(&mut out, "dndm_lanes", "gauge", "in-flight lanes", s.lanes as f64);
    sample(&mut out, "dndm_in_flight", "gauge", "in-flight sequences", s.in_flight as f64);
    sample(&mut out, "dndm_mean_batch", "gauge", "mean denoiser batch width", s.mean_batch);
    sample(
        &mut out,
        "dndm_avg_request_nfe",
        "gauge",
        "mean per-request NFE over retired requests",
        s.avg_request_nfe,
    );
    sample(&mut out, "dndm_occupancy", "gauge", "in-flight width / slot capacity", s.occupancy);
    sample(
        &mut out,
        "dndm_breaker_open",
        "gauge",
        "1 while any shard's circuit breaker is open",
        if s.breaker_open { 1.0 } else { 0.0 },
    );
    sample(
        &mut out,
        "dndm_healthy",
        "gauge",
        "1 while every shard can serve",
        if s.healthy { 1.0 } else { 0.0 },
    );

    // latency percentiles, in seconds per Prometheus convention
    sample(
        &mut out,
        "dndm_queue_seconds_p95",
        "gauge",
        "queue wait p95",
        s.queue_p95.as_secs_f64(),
    );
    sample(
        &mut out,
        "dndm_e2e_seconds_p50",
        "gauge",
        "end-to-end latency p50",
        s.e2e_p50.as_secs_f64(),
    );
    sample(
        &mut out,
        "dndm_e2e_seconds_p95",
        "gauge",
        "end-to-end latency p95",
        s.e2e_p95.as_secs_f64(),
    );
    sample(
        &mut out,
        "dndm_e2e_seconds_p99",
        "gauge",
        "end-to-end latency p99",
        s.e2e_p99.as_secs_f64(),
    );
    sample(
        &mut out,
        "dndm_e2e_seconds_p999",
        "gauge",
        "end-to-end latency p999 (reservoir-limited below ~1000 samples)",
        s.e2e.p999.as_secs_f64(),
    );

    // per-shard admission gauges as labelled families, index = shard
    let _ = writeln!(
        out,
        "# HELP dndm_shard_ewma_us_per_nfe measured pace per shard (µs per denoiser call)"
    );
    let _ = writeln!(out, "# TYPE dndm_shard_ewma_us_per_nfe gauge");
    for (i, v) in front.shard_ewma_us_per_nfe.iter().enumerate() {
        let _ = writeln!(out, "dndm_shard_ewma_us_per_nfe{{shard=\"{i}\"}} {}", fmt_value(*v));
    }
    let _ = writeln!(
        out,
        "# HELP dndm_shard_queued_nfe NFE admitted but not yet retired per shard"
    );
    let _ = writeln!(out, "# TYPE dndm_shard_queued_nfe gauge");
    for (i, v) in front.shard_queued_nfe.iter().enumerate() {
        let _ = writeln!(out, "dndm_shard_queued_nfe{{shard=\"{i}\"}} {}", fmt_value(*v as f64));
    }

    // per-tenant pace: current token-bucket level
    let _ = writeln!(
        out,
        "# HELP dndm_tenant_pace_tokens per-tenant token-bucket level (requests remaining)"
    );
    let _ = writeln!(out, "# TYPE dndm_tenant_pace_tokens gauge");
    for (tenant, v) in &front.tenant_pace {
        let _ = writeln!(
            out,
            "dndm_tenant_pace_tokens{{tenant=\"{}\"}} {}",
            escape_label(tenant),
            fmt_value(*v)
        );
    }

    // per-tenant submit counts as one labelled family
    let _ = writeln!(out, "# HELP dndm_tenant_requests_total requests submitted per tenant");
    let _ = writeln!(out, "# TYPE dndm_tenant_requests_total counter");
    for (tenant, n) in &s.tenant_requests {
        let _ = writeln!(
            out,
            "dndm_tenant_requests_total{{tenant=\"{}\"}} {}",
            escape_label(tenant),
            n
        );
    }
    out
}

/// Parse exposition text back into `name{labels} → value` — the test-side
/// half of [`render`]. Rejects anything that doesn't look like the
/// format: a parse `Err` in a test means the renderer broke grammar.
pub fn parse_text(text: &str) -> Result<BTreeMap<String, f64>, String> {
    let mut out = BTreeMap::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("line {}: no sample value: {line:?}", lineno + 1))?;
        if name.is_empty()
            || !name.chars().next().unwrap_or(' ').is_ascii_alphabetic()
            || name.contains(' ') && !name.contains('{')
        {
            return Err(format!("line {}: bad metric name: {name:?}", lineno + 1));
        }
        let value: f64 = value
            .parse()
            .map_err(|_| format!("line {}: bad sample value: {value:?}", lineno + 1))?;
        out.insert(name.to_string(), value);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::LatencySnapshot;
    use std::time::Duration;

    fn stats() -> ServerStats {
        ServerStats {
            requests: 12,
            batches: 3,
            nn_calls: 40,
            mean_batch: 2.5,
            queue_p95: Duration::from_millis(10),
            e2e_p95: Duration::from_millis(200),
            e2e_p50: Duration::from_millis(100),
            e2e_p99: Duration::from_millis(300),
            e2e: LatencySnapshot {
                count: 12,
                mean: Duration::from_millis(120),
                p50: Duration::from_millis(100),
                p95: Duration::from_millis(200),
                p99: Duration::from_millis(300),
                p999: Duration::from_millis(450),
                min: Duration::from_millis(50),
                max: Duration::from_millis(500),
            },
            avg_request_nfe: 8.0,
            occupancy: 0.75,
            cancelled: 1,
            deadline_exceeded: 2,
            queued_low: 0,
            queued_normal: 4,
            queued_high: 1,
            stolen: 0,
            lanes: 2,
            in_flight: 5,
            rebalances: 0,
            lanes_donated: 0,
            lanes_split: 0,
            ghost_events_fired: 0,
            retries: 0,
            faults_transient: 0,
            faults_fatal: 0,
            breaker_open: false,
            lanes_salvaged: 0,
            early_retired: 6,
            turbo_truncated_nfe: 17,
            healthy: true,
            tenant_requests: vec![("acme".into(), 7), ("z\"inc\\".into(), 5)],
        }
    }

    #[test]
    fn render_parses_and_counters_round_trip() {
        let front = FrontGauges {
            rejected_rate_limit: 3,
            rejected_deadline: 4,
            connections_open: 2,
            shard_ewma_us_per_nfe: vec![1000.0, 1250.5],
            shard_queued_nfe: vec![0, 42],
            tenant_pace: vec![("acme".into(), 3.5)],
        };
        let text = render(&stats(), &front);
        let parsed = parse_text(&text).expect("renderer output must parse");
        assert_eq!(parsed["dndm_requests_total"], 12.0);
        assert_eq!(parsed["dndm_nn_calls_total"], 40.0);
        assert_eq!(parsed["dndm_rejected_rate_limit_total"], 3.0);
        assert_eq!(parsed["dndm_rejected_deadline_total"], 4.0);
        assert_eq!(parsed["dndm_connections_open"], 2.0);
        assert_eq!(parsed["dndm_mean_batch"], 2.5);
        assert_eq!(parsed["dndm_occupancy"], 0.75);
        assert_eq!(parsed["dndm_e2e_seconds_p50"], 0.1);
        assert_eq!(parsed["dndm_e2e_seconds_p999"], 0.45);
        assert_eq!(parsed["dndm_healthy"], 1.0);
        assert_eq!(parsed["dndm_breaker_open"], 0.0);
        assert_eq!(parsed["dndm_tenant_requests_total{tenant=\"acme\"}"], 7.0);
        assert_eq!(parsed["dndm_early_retired_total"], 6.0);
        assert_eq!(parsed["dndm_turbo_truncated_nfe_total"], 17.0);
        assert_eq!(parsed["dndm_shard_ewma_us_per_nfe{shard=\"0\"}"], 1000.0);
        assert_eq!(parsed["dndm_shard_ewma_us_per_nfe{shard=\"1\"}"], 1250.5);
        assert_eq!(parsed["dndm_shard_queued_nfe{shard=\"1\"}"], 42.0);
        assert_eq!(parsed["dndm_tenant_pace_tokens{tenant=\"acme\"}"], 3.5);
    }

    #[test]
    fn every_sample_has_help_and_type() {
        let text = render(&stats(), &FrontGauges::default());
        let mut declared = std::collections::BTreeSet::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                declared.insert(rest.split(' ').next().unwrap().to_string());
            } else if !line.is_empty() && !line.starts_with('#') {
                let family = line.split(['{', ' ']).next().unwrap();
                assert!(declared.contains(family), "undeclared family {family}");
            }
        }
    }

    #[test]
    fn label_values_are_escaped() {
        let text = render(&stats(), &FrontGauges::default());
        assert!(text.contains(r#"tenant="z\"inc\\""#), "{text}");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_text("dndm_x not_a_number").is_err());
        assert!(parse_text("just one token? no:").is_err());
        assert!(parse_text("# a comment\n\ndndm_ok 1\n").is_ok());
    }
}
