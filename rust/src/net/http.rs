//! Minimal HTTP/1.1 server over `std::net::TcpListener` — the transport
//! under the network front door ([`super::front`]).
//!
//! Deliberately small and dependency-free (like the rest of the crate):
//! request-line + header parsing with obs-fold unfolding, Content-Length
//! bodies, fixed and chunked responses, keep-alive with pipelining,
//! per-connection read/write timeouts, and a **bounded worker pool** — a
//! fixed number of connection threads fed through a bounded channel, so a
//! connection flood degrades into immediate `503`s instead of unbounded
//! thread growth.
//!
//! The layer knows nothing about routes or the serving stack: a
//! [`Handler`] maps one parsed [`Request`] to one [`Response`], which is
//! either a full body (written with `Content-Length`) or a stream (written
//! as chunked transfer coding through a [`ChunkSink`] — this is how SSE
//! rides on top, see [`super::sse`]). Protocol errors are answered by this
//! layer directly: `400` malformed, `411` missing `Content-Length`, `413`
//! body too large, `431` header block too large, `501` request
//! transfer-codings.

use std::io::{self, BufRead, BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

/// Transport limits and pool sizing. The defaults suit loopback tests and
/// modest deployments; every field is public so the CLI can expose flags
/// later without an options rebuild.
#[derive(Debug, Clone)]
pub struct HttpOptions {
    /// connection worker threads (each serves one connection at a time)
    pub workers: usize,
    /// accepted connections that may wait for a worker before the
    /// acceptor answers `503` directly
    pub backlog: usize,
    /// per-connection socket read timeout (also bounds keep-alive idle)
    pub read_timeout: Duration,
    /// per-connection socket write timeout (bounds a stalled client on
    /// the streaming path)
    pub write_timeout: Duration,
    /// total request-line + header bytes before `431`
    pub max_header_bytes: usize,
    /// body bytes before `413`
    pub max_body_bytes: usize,
    /// requests served per connection before the server closes it (bounds
    /// how long one client can pin a pool worker)
    pub max_requests_per_conn: usize,
}

impl Default for HttpOptions {
    fn default() -> Self {
        HttpOptions {
            workers: 8,
            backlog: 64,
            read_timeout: Duration::from_secs(10),
            write_timeout: Duration::from_secs(10),
            max_header_bytes: 16 * 1024,
            max_body_bytes: 1024 * 1024,
            max_requests_per_conn: 1024,
        }
    }
}

/// One parsed HTTP request. Header names are lowercased at parse time;
/// values keep their bytes (trimmed, obs-folds unfolded with one space).
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// origin-form target as received: path plus optional `?query`
    pub target: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First header with this (case-insensitive) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// Target with any query string stripped.
    pub fn path(&self) -> &str {
        self.target.split(['?', '#']).next().unwrap_or(&self.target)
    }
}

/// Response body: fixed (written with `Content-Length`) or streamed
/// (chunked transfer coding; the closure runs on the connection worker
/// and writes through a [`ChunkSink`] until it returns).
pub enum Body {
    Full(Vec<u8>),
    Stream(Box<dyn FnOnce(&mut ChunkSink<'_>) -> io::Result<()> + Send>),
}

/// One HTTP response.
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Body,
}

impl Response {
    pub fn new(status: u16) -> Response {
        Response { status, headers: Vec::new(), body: Body::Full(Vec::new()) }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response::new(status)
            .header("content-type", "text/plain; charset=utf-8")
            .with_body(body.into().into_bytes())
    }

    pub fn json(status: u16, body: impl Into<String>) -> Response {
        Response::new(status)
            .header("content-type", "application/json")
            .with_body(body.into().into_bytes())
    }

    /// A chunked streaming response; `f` runs on the connection worker.
    /// An `Err` from `f` (typically a disconnected client) abandons the
    /// stream and closes the connection — the terminating zero chunk is
    /// only written after `Ok`.
    pub fn stream<F>(status: u16, content_type: &str, f: F) -> Response
    where
        F: FnOnce(&mut ChunkSink<'_>) -> io::Result<()> + Send + 'static,
    {
        Response {
            status,
            headers: vec![("content-type".into(), content_type.into())],
            body: Body::Stream(Box::new(f)),
        }
    }

    pub fn header(mut self, name: &str, value: impl Into<String>) -> Response {
        self.headers.push((name.to_ascii_lowercase(), value.into()));
        self
    }

    pub fn with_body(mut self, body: Vec<u8>) -> Response {
        self.body = Body::Full(body);
        self
    }
}

/// Maps one request to one response. Implemented for plain closures.
pub trait Handler: Send + Sync + 'static {
    fn handle(&self, req: Request) -> Response;
}

impl<F> Handler for F
where
    F: Fn(Request) -> Response + Send + Sync + 'static,
{
    fn handle(&self, req: Request) -> Response {
        self(req)
    }
}

/// Outcome of parsing one request off a connection.
enum Parsed {
    Request(Request),
    /// clean EOF between requests (client closed a keep-alive connection)
    Eof,
    /// protocol error answered with this status, then the connection
    /// closes
    Error { status: u16, msg: String },
}

/// Read one CRLF (or bare-LF) line, charging its bytes against `budget`.
/// `Ok(None)` = EOF before any byte.
fn read_line(
    r: &mut impl BufRead,
    budget: &mut usize,
) -> io::Result<Option<Result<String, ()>>> {
    let mut line = Vec::new();
    loop {
        let mut byte = [0u8; 1];
        match r.read(&mut byte) {
            Ok(0) => {
                if line.is_empty() {
                    return Ok(None);
                }
                break;
            }
            Ok(_) => {
                if *budget == 0 {
                    return Ok(Some(Err(()))); // header block too large
                }
                *budget -= 1;
                if byte[0] == b'\n' {
                    break;
                }
                line.push(byte[0]);
            }
            Err(e) => return Err(e),
        }
    }
    if line.last() == Some(&b'\r') {
        line.pop();
    }
    match String::from_utf8(line) {
        Ok(s) => Ok(Some(Ok(s))),
        Err(_) => Ok(Some(Ok(String::from("\u{fffd}")))), // poisoned line → parse error later
    }
}

/// Parse one request (request line, headers with obs-fold unfolding, and
/// a Content-Length body) off the connection.
fn parse_request(r: &mut impl BufRead, opts: &HttpOptions) -> io::Result<Parsed> {
    let mut budget = opts.max_header_bytes;
    let line = match read_line(r, &mut budget)? {
        None => return Ok(Parsed::Eof),
        Some(Err(())) => {
            return Ok(Parsed::Error {
                status: 431,
                msg: "request header block too large".into(),
            })
        }
        Some(Ok(l)) => l,
    };
    let mut parts = line.split(' ');
    let (method, target, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => {
                (m.to_string(), t.to_string(), v.to_string())
            }
            _ => {
                return Ok(Parsed::Error {
                    status: 400,
                    msg: format!("malformed request line: {line:?}"),
                })
            }
        };
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Ok(Parsed::Error { status: 505, msg: format!("unsupported {version}") });
    }

    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let line = match read_line(r, &mut budget)? {
            None => {
                return Ok(Parsed::Error {
                    status: 400,
                    msg: "connection closed mid-headers".into(),
                })
            }
            Some(Err(())) => {
                return Ok(Parsed::Error {
                    status: 431,
                    msg: "request header block too large".into(),
                })
            }
            Some(Ok(l)) => l,
        };
        if line.is_empty() {
            break;
        }
        if line.starts_with(' ') || line.starts_with('\t') {
            // obs-fold continuation: RFC 7230 §3.2.4 says unfold or
            // reject — unfold with a single space onto the prior value
            match headers.last_mut() {
                Some((_, v)) => {
                    v.push(' ');
                    v.push_str(line.trim());
                }
                None => {
                    return Ok(Parsed::Error {
                        status: 400,
                        msg: "header continuation without a header".into(),
                    })
                }
            }
            continue;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Ok(Parsed::Error { status: 400, msg: format!("malformed header {line:?}") });
        };
        if name.is_empty() || name.contains(' ') {
            return Ok(Parsed::Error {
                status: 400,
                msg: format!("malformed header name {name:?}"),
            });
        }
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }

    let mut req = Request { method, target, headers, body: Vec::new() };
    // keep-alive default differs by version; record it as a synthetic
    // header only if the client didn't send one
    if req.header("connection").is_none() && version == "HTTP/1.0" {
        req.headers.push(("connection".into(), "close".into()));
    }

    if req.header("transfer-encoding").is_some() {
        // request bodies are Content-Length only in this server
        return Ok(Parsed::Error {
            status: 501,
            msg: "request transfer-encoding not supported".into(),
        });
    }
    let len = match req.header("content-length") {
        Some(v) => match v.parse::<usize>() {
            Ok(n) => Some(n),
            Err(_) => {
                return Ok(Parsed::Error {
                    status: 400,
                    msg: format!("bad content-length {v:?}"),
                })
            }
        },
        None => None,
    };
    match (req.method.as_str(), len) {
        ("POST" | "PUT" | "PATCH", None) => {
            return Ok(Parsed::Error {
                status: 411,
                msg: "content-length required".into(),
            })
        }
        (_, None) | (_, Some(0)) => {}
        (_, Some(n)) if n > opts.max_body_bytes => {
            return Ok(Parsed::Error {
                status: 413,
                msg: format!("body of {n} bytes exceeds limit {}", opts.max_body_bytes),
            })
        }
        (_, Some(n)) => {
            let mut body = vec![0u8; n];
            r.read_exact(&mut body)?;
            req.body = body;
        }
    }
    Ok(Parsed::Request(req))
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        204 => "No Content",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        505 => "HTTP Version Not Supported",
        _ => "",
    }
}

/// Writer handed to streaming bodies: each [`ChunkSink::send`] becomes
/// one chunk, flushed immediately so event frames reach the client (and
/// a disconnected client surfaces as an `Err` here, not at some buffered
/// later point).
pub struct ChunkSink<'a> {
    w: &'a mut dyn Write,
}

impl ChunkSink<'_> {
    pub fn send(&mut self, data: &[u8]) -> io::Result<()> {
        if data.is_empty() {
            // a zero-length chunk would terminate the stream
            return Ok(());
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }
}

fn write_response(w: &mut impl Write, resp: Response, keep_alive: bool) -> io::Result<bool> {
    let mut head = format!("HTTP/1.1 {} {}\r\n", resp.status, reason(resp.status));
    for (k, v) in &resp.headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    if !keep_alive {
        head.push_str("connection: close\r\n");
    }
    match resp.body {
        Body::Full(body) => {
            head.push_str(&format!("content-length: {}\r\n\r\n", body.len()));
            w.write_all(head.as_bytes())?;
            w.write_all(&body)?;
            w.flush()?;
            Ok(keep_alive)
        }
        Body::Stream(f) => {
            head.push_str("transfer-encoding: chunked\r\n\r\n");
            w.write_all(head.as_bytes())?;
            w.flush()?;
            let mut sink = ChunkSink { w };
            f(&mut sink)?;
            // stream completed: terminate the chunk sequence so a
            // keep-alive client knows the body ended
            w.write_all(b"0\r\n\r\n")?;
            w.flush()?;
            Ok(keep_alive)
        }
    }
}

fn error_response(status: u16, msg: &str) -> Response {
    Response::text(status, format!("{msg}\n"))
}

/// Serve one connection: parse → dispatch → write, looping while
/// keep-alive holds. Pipelined requests queue in the read buffer and are
/// served back-to-back in order.
fn handle_conn(stream: TcpStream, handler: &dyn Handler, opts: &HttpOptions) {
    let _ = stream.set_read_timeout(Some(opts.read_timeout));
    let _ = stream.set_write_timeout(Some(opts.write_timeout));
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else { return };
    let mut r = BufReader::new(read_half);
    let mut w = BufWriter::new(stream);
    for served in 0..opts.max_requests_per_conn {
        let req = match parse_request(&mut r, opts) {
            Ok(Parsed::Request(req)) => req,
            Ok(Parsed::Eof) => return,
            Ok(Parsed::Error { status, msg }) => {
                let _ = write_response(&mut w, error_response(status, &msg), false);
                return;
            }
            // read timeout on an idle keep-alive connection, or a
            // half-sent request: close quietly either way
            Err(_) => return,
        };
        let close_requested =
            req.header("connection").is_some_and(|v| v.eq_ignore_ascii_case("close"));
        let keep_alive = !close_requested && served + 1 < opts.max_requests_per_conn;
        let resp = handler.handle(req);
        match write_response(&mut w, resp, keep_alive) {
            Ok(true) => continue,
            _ => return,
        }
    }
}

/// A running HTTP server: an acceptor thread plus a bounded worker pool.
/// Dropping (or [`HttpServer::shutdown`]) stops the acceptor, drains the
/// workers, and joins every thread.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
    /// currently open (accepted, not yet finished) connections
    open: Arc<AtomicU64>,
}

impl HttpServer {
    /// Bind and start serving `handler` on `addr` (use port 0 to let the
    /// OS pick; read it back with [`Self::local_addr`]).
    pub fn bind<A, H>(addr: A, opts: HttpOptions, handler: H) -> io::Result<HttpServer>
    where
        A: ToSocketAddrs,
        H: Handler,
    {
        HttpServer::bind_gauged(addr, opts, handler, Arc::new(AtomicU64::new(0)))
    }

    /// [`Self::bind`] with a caller-owned open-connections gauge — the
    /// front door shares this gauge with its `/metrics` renderer so
    /// `connections_open` is scrapeable.
    pub fn bind_gauged<A, H>(
        addr: A,
        opts: HttpOptions,
        handler: H,
        open: Arc<AtomicU64>,
    ) -> io::Result<HttpServer>
    where
        A: ToSocketAddrs,
        H: Handler,
    {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let handler: Arc<dyn Handler> = Arc::new(handler);
        let (tx, rx) = std::sync::mpsc::sync_channel::<TcpStream>(opts.backlog);
        let rx = Arc::new(Mutex::new(rx));

        let mut threads = Vec::with_capacity(opts.workers + 1);
        for _ in 0..opts.workers.max(1) {
            let rx = rx.clone();
            let handler = handler.clone();
            let opts = opts.clone();
            let open = open.clone();
            threads.push(std::thread::spawn(move || worker_loop(&rx, &*handler, &opts, &open)));
        }
        {
            let stop = stop.clone();
            let open = open.clone();
            // `opts` moves into the acceptor — the workers cloned theirs
            threads.push(std::thread::spawn(move || {
                acceptor_loop(listener, &stop, tx, &opts, &open)
            }));
        }
        Ok(HttpServer { addr, stop, threads, open })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Accepted connections currently being served (the gauge behind the
    /// `connections_open` metric).
    pub fn connections_open(&self) -> u64 {
        self.open.load(Ordering::Relaxed)
    }

    /// Stop accepting, drain the workers, join every thread. Idempotent.
    pub fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // unblock the acceptor's blocking accept() with a throwaway
        // connection to ourselves
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(
    rx: &Mutex<Receiver<TcpStream>>,
    handler: &dyn Handler,
    opts: &HttpOptions,
    open: &AtomicU64,
) {
    loop {
        let conn = {
            let guard = rx.lock().unwrap_or_else(PoisonError::into_inner);
            guard.recv()
        };
        match conn {
            Ok(stream) => {
                handle_conn(stream, handler, opts);
                open.fetch_sub(1, Ordering::Relaxed);
            }
            Err(_) => return, // acceptor gone: shutdown
        }
    }
}

fn acceptor_loop(
    listener: TcpListener,
    stop: &AtomicBool,
    tx: SyncSender<TcpStream>,
    opts: &HttpOptions,
    open: &AtomicU64,
) {
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = conn else { continue };
        open.fetch_add(1, Ordering::Relaxed);
        match tx.try_send(stream) {
            Ok(()) => {}
            Err(TrySendError::Full(stream)) => {
                // pool saturated and backlog full: shed at the door
                open.fetch_sub(1, Ordering::Relaxed);
                let _ = stream.set_write_timeout(Some(opts.write_timeout));
                let mut w = BufWriter::new(stream);
                let _ = write_response(
                    &mut w,
                    error_response(503, "server overloaded").header("retry-after", "1"),
                    false,
                );
            }
            Err(TrySendError::Disconnected(_)) => {
                open.fetch_sub(1, Ordering::Relaxed);
                break;
            }
        }
    }
    // dropping tx wakes every idle worker out of recv()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(bytes: &[u8]) -> Parsed {
        let mut r = BufReader::new(Cursor::new(bytes.to_vec()));
        parse_request(&mut r, &HttpOptions::default()).expect("io on cursor")
    }

    #[test]
    fn parses_request_line_headers_and_body() {
        let p = parse(
            b"POST /v1/generate?x=1 HTTP/1.1\r\nHost: a\r\nContent-Length: 4\r\n\r\nbody",
        );
        let Parsed::Request(req) = p else { panic!("expected request") };
        assert_eq!(req.method, "POST");
        assert_eq!(req.target, "/v1/generate?x=1");
        assert_eq!(req.path(), "/v1/generate");
        assert_eq!(req.header("host"), Some("a"));
        assert_eq!(req.header("HOST"), Some("a"), "lookup is case-insensitive");
        assert_eq!(req.body, b"body");
    }

    #[test]
    fn unfolds_obs_fold_header_continuations() {
        let p = parse(b"GET / HTTP/1.1\r\nX-Long: first\r\n  second\r\n\tthird\r\n\r\n");
        let Parsed::Request(req) = p else { panic!("expected request") };
        assert_eq!(req.header("x-long"), Some("first second third"));
    }

    #[test]
    fn continuation_before_any_header_is_400() {
        let p = parse(b"GET / HTTP/1.1\r\n  floating\r\n\r\n");
        let Parsed::Error { status, .. } = p else { panic!("expected error") };
        assert_eq!(status, 400);
    }

    #[test]
    fn post_without_content_length_is_411() {
        let p = parse(b"POST /v1/generate HTTP/1.1\r\nHost: a\r\n\r\n");
        let Parsed::Error { status, .. } = p else { panic!("expected error") };
        assert_eq!(status, 411);
    }

    #[test]
    fn get_without_content_length_is_fine() {
        let p = parse(b"GET /metrics HTTP/1.1\r\n\r\n");
        assert!(matches!(p, Parsed::Request(_)));
    }

    #[test]
    fn oversized_header_block_is_431() {
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        raw.extend_from_slice(format!("X-Big: {}\r\n\r\n", "v".repeat(20 * 1024)).as_bytes());
        let Parsed::Error { status, .. } = parse(&raw) else { panic!("expected error") };
        assert_eq!(status, 431);
    }

    #[test]
    fn oversized_body_is_413_and_transfer_encoding_501() {
        let p = parse(b"POST / HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n");
        let Parsed::Error { status, .. } = p else { panic!("expected error") };
        assert_eq!(status, 413);
        let p = parse(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n");
        let Parsed::Error { status, .. } = p else { panic!("expected error") };
        assert_eq!(status, 501);
    }

    #[test]
    fn bad_request_line_is_400_and_eof_is_clean() {
        assert!(matches!(parse(b"NONSENSE\r\n\r\n"), Parsed::Error { status: 400, .. }));
        assert!(matches!(parse(b""), Parsed::Eof));
    }

    #[test]
    fn http10_defaults_to_close_http11_to_keep_alive() {
        let Parsed::Request(req) = parse(b"GET / HTTP/1.0\r\n\r\n") else { panic!() };
        assert_eq!(req.header("connection"), Some("close"));
        let Parsed::Request(req) = parse(b"GET / HTTP/1.1\r\n\r\n") else { panic!() };
        assert_eq!(req.header("connection"), None);
    }

    #[test]
    fn pipelined_requests_parse_back_to_back() {
        let raw = b"GET /a HTTP/1.1\r\n\r\nPOST /b HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi";
        let mut r = BufReader::new(Cursor::new(raw.to_vec()));
        let opts = HttpOptions::default();
        let Ok(Parsed::Request(a)) = parse_request(&mut r, &opts) else { panic!() };
        assert_eq!(a.target, "/a");
        let Ok(Parsed::Request(b)) = parse_request(&mut r, &opts) else { panic!() };
        assert_eq!((b.target.as_str(), b.body.as_slice()), ("/b", b"hi".as_slice()));
        assert!(matches!(parse_request(&mut r, &opts), Ok(Parsed::Eof)));
    }

    #[test]
    fn chunked_writer_frames_and_terminates() {
        let mut out = Vec::new();
        {
            let mut w = BufWriter::new(Cursor::new(&mut out));
            let resp = Response::stream(200, "text/event-stream", |sink| {
                sink.send(b"hello")?;
                sink.send(b"")?; // empty send is a no-op, not a terminator
                sink.send(b"world!")
            });
            write_response(&mut w, resp, true).unwrap();
        }
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("transfer-encoding: chunked"));
        assert!(text.contains("5\r\nhello\r\n"));
        assert!(text.contains("6\r\nworld!\r\n"));
        assert!(text.ends_with("0\r\n\r\n"));
    }

    #[test]
    fn full_body_gets_content_length() {
        let mut out = Vec::new();
        {
            let mut w = BufWriter::new(Cursor::new(&mut out));
            write_response(&mut w, Response::text(200, "ok"), false).unwrap();
        }
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("content-length: 2\r\n"));
        assert!(text.contains("connection: close\r\n"));
        assert!(text.ends_with("\r\nok"));
    }
}
