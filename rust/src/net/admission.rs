//! Exact-cost admission control: token-bucket rate limiting plus
//! deadline-aware load shedding at the front door.
//!
//! The whole point of a DNDM front door is that **the denoiser-call cost
//! of a request is exactly known before any compute happens**: the
//! predetermined transition set 𝒯 is a pure function of (model config,
//! sampler config, seed), so [`exact_cost`] builds a throwaway
//! [`SamplerSession`] on the host — no denoiser call, no device — and
//! reads `total_events()`. Continuous serving runs each request in its
//! own width-1 lane (`shared_tau_groups: false`), so this admission-time
//! number equals the served lane's total and the final `Progress`
//! event's `nfe_total` exactly. Load shedding here is therefore not a
//! heuristic: a rejected request *provably* could not have met its
//! deadline, and `Retry-After` is derived from the same arithmetic.
//!
//! The projection: completion time for a new request of cost `c` landing
//! on a shard with `backlog` queued-but-unfinished NFE is
//!
//! ```text
//! projected_us = (backlog + c) × ewma_us_per_nfe
//! ```
//!
//! where `ewma_us_per_nfe` is an exponentially-weighted average of
//! measured wall-µs per denoiser call, fed by [`Admission::observe`] on
//! every retirement. If `projected_us` exceeds the request's deadline the
//! request is rejected with `503` before consuming anything.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::{StatsBoard, Tier, TierDecision};
use crate::runtime::ModelConfig;
use crate::sampler::{SamplerConfig, SamplerKind, SamplerSession};
use crate::schedule::{AlphaSchedule, TransitionSpec};

/// Exact denoiser-call cost of one request: the size of its
/// predetermined transition set, computed host-side before any compute.
/// Errors only when the sampler config itself is invalid (which would
/// also fail at serving time — rejecting here with `400` is strictly
/// earlier, never different).
pub fn exact_cost(mcfg: &ModelConfig, cfg: &SamplerConfig, seed: u64) -> Result<u64> {
    Ok(SamplerSession::new(mcfg, cfg, 1, seed)?.total_events() as u64)
}

/// Per-tenant token-bucket parameters.
#[derive(Debug, Clone, Copy)]
pub struct RateLimit {
    /// bucket capacity — the largest instantaneous burst of requests
    pub burst: f64,
    /// refill rate, requests per second (0 disables refill: `burst`
    /// requests total, ever — useful in tests)
    pub per_sec: f64,
}

/// Front-door policy knobs.
#[derive(Debug, Clone)]
pub struct AdmissionPolicy {
    /// per-tenant token bucket; `None` disables rate limiting
    pub rate_limit: Option<RateLimit>,
    /// seed for the µs/NFE EWMA before the first measurement arrives
    pub initial_us_per_nfe: f64,
    /// EWMA smoothing factor in (0, 1]: weight of each new sample
    pub ewma_alpha: f64,
    /// Prefer the engine-measured µs/NFE EWMA from the shards' lock-free
    /// [`StatsBoard`]s (attached via [`Admission::attach_boards`]) over
    /// this controller's own front-door EWMA. The board's pace is fed by
    /// **every** terminal the engine delivers — including requests
    /// submitted straight to the router, which the front door never
    /// observes — so it converges on mixed-ingress deployments where the
    /// front-door EWMA stays blind. Off by default: the front-door EWMA
    /// is the pinned arithmetic existing projections (and their tests)
    /// are calibrated against, and a shard that has not yet retired a
    /// request publishes `0.0`, which always falls back here anyway.
    pub use_board_pace: bool,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy {
            rate_limit: Some(RateLimit { burst: 32.0, per_sec: 16.0 }),
            initial_us_per_nfe: 1000.0,
            ewma_alpha: 0.2,
            use_board_pace: false,
        }
    }
}

/// Why a request was turned away at the door.
#[derive(Debug, Clone, PartialEq)]
pub enum Rejection {
    /// Tenant bucket empty → HTTP `429`. `retry_after` is the exact time
    /// until one token refills.
    RateLimited { retry_after: Duration },
    /// The exact projection says the deadline cannot be met → HTTP
    /// `503`. `projected` is the projected completion time,
    /// `retry_after` the exact backlog-drain time needed before this
    /// request would fit.
    DeadlineUnmeetable { projected: Duration, deadline: Duration, retry_after: Duration },
}

impl Rejection {
    pub fn status(&self) -> u16 {
        match self {
            Rejection::RateLimited { .. } => 429,
            Rejection::DeadlineUnmeetable { .. } => 503,
        }
    }

    /// Seconds for the `Retry-After` header, rounded up so retrying at
    /// the advertised time actually succeeds.
    pub fn retry_after_secs(&self) -> u64 {
        let d = match self {
            Rejection::RateLimited { retry_after }
            | Rejection::DeadlineUnmeetable { retry_after, .. } => *retry_after,
        };
        d.as_secs() + u64::from(d.subsec_nanos() > 0)
    }
}

/// One tenant's token bucket. Refill is computed lazily from elapsed
/// time — no background thread.
struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Per-shard load account: NFE admitted but not yet retired, plus the
/// measured pace. `ewma_us_per_nfe` is stored as f64 bits in an
/// `AtomicU64` and updated with a CAS loop so `observe` never takes a
/// lock on the retirement path.
struct ShardLoad {
    queued_nfe: AtomicU64,
    ewma_us_bits: AtomicU64,
}

/// The admission controller. One instance fronts one [`Router`]; all
/// methods take `&self` and are safe to call from every connection
/// worker concurrently.
///
/// [`Router`]: crate::coordinator::Router
pub struct Admission {
    policy: AdmissionPolicy,
    shards: Vec<ShardLoad>,
    buckets: Mutex<HashMap<String, Bucket>>,
    rejected_rate_limit: AtomicU64,
    rejected_deadline: AtomicU64,
    /// per-shard lock-free boards, attached after construction (the
    /// router exists before the controller does); consulted by pace
    /// queries only under [`AdmissionPolicy::use_board_pace`]
    boards: Mutex<Vec<Arc<StatsBoard>>>,
}

impl Admission {
    pub fn new(policy: AdmissionPolicy, num_shards: usize) -> Admission {
        let shards = (0..num_shards.max(1))
            .map(|_| ShardLoad {
                queued_nfe: AtomicU64::new(0),
                ewma_us_bits: AtomicU64::new(policy.initial_us_per_nfe.to_bits()),
            })
            .collect();
        Admission {
            policy,
            shards,
            buckets: Mutex::new(HashMap::new()),
            rejected_rate_limit: AtomicU64::new(0),
            rejected_deadline: AtomicU64::new(0),
            boards: Mutex::new(Vec::new()),
        }
    }

    /// Attach the shards' lock-free boards
    /// ([`Router::boards`](crate::coordinator::Router::boards)),
    /// index-aligned with this controller's shard accounts. Pace queries
    /// prefer a board's engine-measured EWMA only when
    /// [`AdmissionPolicy::use_board_pace`] is set **and** that board has
    /// observed at least one terminal (its EWMA is nonzero); otherwise
    /// the front-door EWMA keeps deciding, so attaching is always safe.
    pub fn attach_boards(&self, boards: Vec<Arc<StatsBoard>>) {
        *self.boards.lock().unwrap_or_else(PoisonError::into_inner) = boards;
    }

    /// The µs/NFE pace a projection for `shard` should multiply by:
    /// the board's engine-measured EWMA when enabled and warmed up, the
    /// front-door EWMA otherwise.
    fn pace_us(&self, shard: usize) -> f64 {
        if self.policy.use_board_pace {
            let boards = self.boards.lock().unwrap_or_else(PoisonError::into_inner);
            if let Some(b) = boards.get(shard) {
                let p = b.pace();
                if p.ewma_us_per_nfe > 0.0 {
                    return p.ewma_us_per_nfe;
                }
            }
        }
        f64::from_bits(self.shard(shard).ewma_us_bits.load(Ordering::Relaxed))
    }

    /// Check-only gate: may this request of exactly `cost` denoiser
    /// calls, projected onto `shard`, be admitted? On `Err` the matching
    /// rejection counter has been bumped and nothing else changed; on
    /// `Ok` the caller submits to the router and then calls
    /// [`Self::charge`] with the shard the router actually picked.
    pub fn admit(
        &self,
        tenant: Option<&str>,
        shard: usize,
        cost: u64,
        deadline: Option<Duration>,
    ) -> std::result::Result<(), Rejection> {
        if let Some(limit) = self.policy.rate_limit {
            if let Err(wait) = self.take_token(tenant.unwrap_or(""), limit) {
                self.rejected_rate_limit.fetch_add(1, Ordering::Relaxed);
                return Err(Rejection::RateLimited { retry_after: wait });
            }
        }
        if let Some(deadline) = deadline {
            let idx = shard.min(self.shards.len() - 1);
            let backlog = self.shards[idx].queued_nfe.load(Ordering::Relaxed);
            let pace = self.pace_us(idx);
            let projected_us = (backlog + cost) as f64 * pace;
            let deadline_us = deadline.as_micros() as f64;
            if projected_us > deadline_us {
                self.rejected_deadline.fetch_add(1, Ordering::Relaxed);
                // the shard must drain enough NFE that (backlog' + cost)
                // × pace fits the deadline; that drain takes excess ×
                // pace µs at the measured rate
                let fits = (deadline_us / pace.max(1e-9)) as u64;
                let excess = (backlog + cost).saturating_sub(fits);
                let retry_after = Duration::from_micros((excess as f64 * pace) as u64);
                return Err(Rejection::DeadlineUnmeetable {
                    projected: Duration::from_micros(projected_us as u64),
                    deadline,
                    retry_after,
                });
            }
        }
        Ok(())
    }

    /// Record `cost` NFE as queued on `shard` — call with the shard the
    /// router actually placed the request on (placement may differ from
    /// the projection shard if a rebalance raced the submit; charging the
    /// real shard keeps the account consistent either way).
    pub fn charge(&self, shard: usize, cost: u64) {
        self.shard(shard).queued_nfe.fetch_add(cost, Ordering::Relaxed);
    }

    /// Release `cost` NFE from `shard` without a pace measurement — for
    /// requests that ended without finishing (cancelled, deadline-dropped,
    /// failed, client disconnected).
    pub fn release(&self, shard: usize, cost: u64) {
        let q = &self.shard(shard).queued_nfe;
        let mut cur = q.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(cost);
            match q.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Retirement hook: release the request's NFE and fold its measured
    /// wall time into the shard's µs/NFE EWMA.
    pub fn observe(&self, shard: usize, nfe: u64, elapsed: Duration) {
        self.observe_served(shard, nfe, nfe, elapsed);
    }

    /// Retirement hook for tiered requests, where the NFE *charged* at
    /// admission may exceed the NFE actually *served* (early retirement
    /// refunds the difference). Releases the full charge; the pace
    /// sample uses served NFE, since that is what the wall time bought.
    pub fn observe_served(&self, shard: usize, charged: u64, served_nfe: u64, elapsed: Duration) {
        self.release(shard, charged);
        let sample = elapsed.as_micros() as f64 / served_nfe.max(1) as f64;
        let alpha = self.policy.ewma_alpha.clamp(0.0, 1.0);
        let bits = &self.shard(shard).ewma_us_bits;
        let mut cur = bits.load(Ordering::Relaxed);
        loop {
            let next = (alpha * sample + (1.0 - alpha) * f64::from_bits(cur)).to_bits();
            match bits.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Admission-aware placement: pick the shard with the lowest
    /// *projected wait* — `(queued_nfe + cost) × that shard's EWMA` —
    /// check the deadline against that projection, and charge the cost
    /// there, all in one call. Replaces the peek-placement-then-charge
    /// dance: the shard returned is the shard charged, so the account
    /// cannot drift from placement. On `Err` nothing was charged (the
    /// rate-limit token, if any, is spent — the request did arrive).
    ///
    /// The caller routes with [`Router::submit_request_to`] so the lane
    /// lands exactly where the projection said.
    ///
    /// [`Router::submit_request_to`]: crate::coordinator::Router::submit_request_to
    pub fn place_and_charge(
        &self,
        tenant: Option<&str>,
        cost: u64,
        deadline: Option<Duration>,
    ) -> std::result::Result<usize, Rejection> {
        if let Some(limit) = self.policy.rate_limit {
            if let Err(wait) = self.take_token(tenant.unwrap_or(""), limit) {
                self.rejected_rate_limit.fetch_add(1, Ordering::Relaxed);
                return Err(Rejection::RateLimited { retry_after: wait });
            }
        }
        let (shard, projected_us) = self.best_projection(cost);
        if let Some(deadline) = deadline {
            let deadline_us = deadline.as_micros() as f64;
            if projected_us > deadline_us {
                self.rejected_deadline.fetch_add(1, Ordering::Relaxed);
                let pace = self.ewma_us_per_nfe(shard);
                let fits = (deadline_us / pace.max(1e-9)) as u64;
                let excess = (self.queued_nfe(shard) + cost).saturating_sub(fits);
                return Err(Rejection::DeadlineUnmeetable {
                    projected: Duration::from_micros(projected_us as u64),
                    deadline,
                    retry_after: Duration::from_micros((excess as f64 * pace) as u64),
                });
            }
        }
        self.charge(shard, cost);
        Ok(shard)
    }

    /// Resolve a serving tier against the current cluster state: returns
    /// the sampler config to actually serve plus the [`TierDecision`]
    /// echoed to the client. Pure host-side arithmetic — every candidate
    /// is priced with [`exact_cost`], never a denoiser call.
    ///
    /// - [`Tier::Quality`]: the config is served untouched.
    /// - [`Tier::Turbo`]: DNDM ladder kinds get `max_nfe` (deterministic
    ///   truncation of the transition set, `docs/tiers.md`); step-marching
    ///   kinds are capped by lowering `steps` instead.
    /// - [`Tier::Balanced`]: if the base config's best-shard projection
    ///   meets the SLO it is kept; otherwise a deterministic grid of
    ///   cheaper candidates (step counts `{T, 3T/4, T/2, T/4, T/8}`,
    ///   crossed with `{base, Uniform, Exact(cos²)}` specs for DNDM
    ///   kinds) is searched and the **highest-NFE** candidate that fits
    ///   wins — degrade as little as the SLO allows. No candidate fits →
    ///   `503` with the base projection, before any compute.
    pub fn resolve_tier(
        &self,
        mcfg: &ModelConfig,
        base_cfg: &SamplerConfig,
        seed: u64,
        tier: Tier,
    ) -> std::result::Result<(SamplerConfig, TierDecision), Rejection> {
        match tier {
            Tier::Quality => {
                let cost = exact_cost(mcfg, base_cfg, seed).unwrap_or(0);
                let (_, projected_us) = self.best_projection(cost);
                Ok((base_cfg.clone(), decision_for(base_cfg, cost, projected_us)))
            }
            Tier::Turbo { max_nfe } => {
                let cap = max_nfe.max(1);
                let mut cfg = base_cfg.clone();
                match cfg.kind {
                    // ladder kinds: truncate the transition set itself —
                    // exact_cost prices the capped ladder because the
                    // session truncates at construction
                    SamplerKind::Dndm | SamplerKind::DndmV2 => cfg = cfg.with_max_nfe(cap),
                    _ => cfg.steps = cfg.steps.min(cap),
                }
                let cost = exact_cost(mcfg, &cfg, seed).unwrap_or(0);
                let (_, projected_us) = self.best_projection(cost);
                Ok((cfg, decision_for(&cfg, cost, projected_us)))
            }
            Tier::Balanced { slo_ms } => {
                let slo_us = slo_ms as f64 * 1000.0;
                let base_cost = exact_cost(mcfg, base_cfg, seed).unwrap_or(0);
                let (_, base_proj) = self.best_projection(base_cost);
                if base_proj <= slo_us {
                    return Ok((base_cfg.clone(), decision_for(base_cfg, base_cost, base_proj)));
                }
                let t = base_cfg.steps;
                let step_grid = [t, t * 3 / 4, t / 2, t / 4, (t / 8).max(2)];
                let mut specs = vec![base_cfg.spec.clone()];
                if base_cfg.kind.is_dndm() {
                    specs.push(TransitionSpec::Uniform);
                    specs.push(TransitionSpec::Exact(AlphaSchedule::CosineSq));
                }
                // best = highest projected NFE that fits the SLO; the
                // grid order breaks ties deterministically (strict >)
                let mut best: Option<(SamplerConfig, u64, f64)> = None;
                let mut cheapest = base_cost;
                for &steps in &step_grid {
                    if steps == 0 {
                        continue;
                    }
                    for spec in &specs {
                        let mut cand = base_cfg.clone();
                        cand.steps = steps;
                        cand.spec = spec.clone();
                        let Ok(cost) = exact_cost(mcfg, &cand, seed) else { continue };
                        cheapest = cheapest.min(cost);
                        let (_, proj) = self.best_projection(cost);
                        if proj > slo_us {
                            continue;
                        }
                        if best.as_ref().map_or(true, |(_, c, _)| cost > *c) {
                            best = Some((cand, cost, proj));
                        }
                    }
                }
                match best {
                    Some((cfg, cost, proj)) => {
                        let d = decision_for(&cfg, cost, proj);
                        Ok((cfg, d))
                    }
                    None => {
                        self.rejected_deadline.fetch_add(1, Ordering::Relaxed);
                        let (shard, _) = self.best_projection(cheapest);
                        let pace = self.ewma_us_per_nfe(shard);
                        let fits = (slo_us / pace.max(1e-9)) as u64;
                        let excess = (self.queued_nfe(shard) + cheapest).saturating_sub(fits);
                        Err(Rejection::DeadlineUnmeetable {
                            projected: Duration::from_micros(base_proj as u64),
                            deadline: Duration::from_millis(slo_ms),
                            retry_after: Duration::from_micros((excess as f64 * pace) as u64),
                        })
                    }
                }
            }
        }
    }

    /// `(shard, projected_us)` of the lowest-projected-wait shard for a
    /// request of exactly `cost` denoiser calls.
    fn best_projection(&self, cost: u64) -> (usize, f64) {
        let mut best = (0usize, f64::INFINITY);
        for (i, s) in self.shards.iter().enumerate() {
            let backlog = s.queued_nfe.load(Ordering::Relaxed);
            let pace = self.pace_us(i);
            let projected = (backlog + cost) as f64 * pace;
            if projected < best.1 {
                best = (i, projected);
            }
        }
        best
    }

    /// Current µs/NFE estimate for a shard (scraped into `/metrics`):
    /// the same value projections multiply by, so under
    /// [`AdmissionPolicy::use_board_pace`] this reflects the attached
    /// board's engine-measured EWMA once it has warmed up.
    pub fn ewma_us_per_nfe(&self, shard: usize) -> f64 {
        self.pace_us(shard)
    }

    /// NFE admitted but not yet retired on a shard.
    pub fn queued_nfe(&self, shard: usize) -> u64 {
        self.shard(shard).queued_nfe.load(Ordering::Relaxed)
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// µs/NFE EWMA of every shard, for the `/metrics` gauge family.
    pub fn shard_ewmas(&self) -> Vec<f64> {
        (0..self.shards.len()).map(|i| self.ewma_us_per_nfe(i)).collect()
    }

    /// Queued-but-unretired NFE of every shard, for `/metrics`.
    pub fn shard_queued(&self) -> Vec<u64> {
        (0..self.shards.len()).map(|i| self.queued_nfe(i)).collect()
    }

    /// Per-tenant pace: each known tenant's current token-bucket level
    /// (refreshed to now), sorted by tenant for stable scrape output.
    /// Empty when rate limiting is disabled.
    pub fn tenant_pace(&self) -> Vec<(String, f64)> {
        let Some(limit) = self.policy.rate_limit else { return Vec::new() };
        let mut buckets = self.buckets.lock().unwrap_or_else(PoisonError::into_inner);
        let now = Instant::now();
        let mut out: Vec<(String, f64)> = buckets
            .iter_mut()
            .map(|(tenant, b)| {
                if limit.per_sec > 0.0 {
                    let refill = now.duration_since(b.last).as_secs_f64() * limit.per_sec;
                    b.tokens = (b.tokens + refill).min(limit.burst);
                    b.last = now;
                }
                (tenant.clone(), b.tokens)
            })
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Requests rejected by the rate limiter since construction.
    pub fn rejected_rate_limit(&self) -> u64 {
        self.rejected_rate_limit.load(Ordering::Relaxed)
    }

    /// Requests rejected by the deadline projection since construction.
    pub fn rejected_deadline(&self) -> u64 {
        self.rejected_deadline.load(Ordering::Relaxed)
    }

    fn shard(&self, shard: usize) -> &ShardLoad {
        &self.shards[shard.min(self.shards.len() - 1)]
    }

    /// Take one token from `tenant`'s bucket, or return the exact wait
    /// until a token refills.
    fn take_token(&self, tenant: &str, limit: RateLimit) -> std::result::Result<(), Duration> {
        let mut buckets = self.buckets.lock().unwrap_or_else(PoisonError::into_inner);
        let now = Instant::now();
        let bucket = buckets
            .entry(tenant.to_string())
            .or_insert_with(|| Bucket { tokens: limit.burst, last: now });
        if limit.per_sec > 0.0 {
            let refill = now.duration_since(bucket.last).as_secs_f64() * limit.per_sec;
            bucket.tokens = (bucket.tokens + refill).min(limit.burst);
        }
        bucket.last = now;
        if bucket.tokens >= 1.0 {
            bucket.tokens -= 1.0;
            Ok(())
        } else if limit.per_sec > 0.0 {
            Err(Duration::from_secs_f64((1.0 - bucket.tokens) / limit.per_sec))
        } else {
            // no refill configured: "retry" is really "never"; advertise
            // a flat minute so clients back off hard
            Err(Duration::from_secs(60))
        }
    }
}

/// Label of the spec actually served, echoed in [`TierDecision`]:
/// `kind:spec@steps`, plus `#capN` when a Turbo ladder cap is set.
fn spec_label(cfg: &SamplerConfig) -> String {
    match cfg.max_nfe {
        Some(cap) => format!("{}:{}@{}#cap{}", cfg.kind.name(), cfg.spec.name(), cfg.steps, cap),
        None => format!("{}:{}@{}", cfg.kind.name(), cfg.spec.name(), cfg.steps),
    }
}

fn decision_for(cfg: &SamplerConfig, cost: u64, projected_us: f64) -> TierDecision {
    TierDecision {
        chosen_spec: spec_label(cfg),
        projected_nfe: cost,
        projected_ms: (projected_us / 1000.0).ceil() as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn no_limit() -> AdmissionPolicy {
        AdmissionPolicy { rate_limit: None, ..AdmissionPolicy::default() }
    }

    #[test]
    fn admits_when_projection_fits_the_deadline() {
        // pace 1000 µs/NFE, cost 8, empty backlog → 8 ms projected
        let adm = Admission::new(no_limit(), 2);
        assert!(adm.admit(None, 0, 8, Some(Duration::from_millis(100))).is_ok());
        assert!(adm.admit(None, 0, 8, None).is_ok(), "no deadline, no shedding");
    }

    #[test]
    fn rejects_exactly_when_projection_exceeds_the_deadline() {
        let adm = Admission::new(no_limit(), 1);
        // projected = 8 × 1000 µs = 8 ms; a 7 ms deadline must reject,
        // an 8 ms one must pass (the projection is exact, not padded)
        assert!(adm.admit(None, 0, 8, Some(Duration::from_millis(7))).is_err());
        assert!(adm.admit(None, 0, 8, Some(Duration::from_millis(8))).is_ok());
        assert_eq!(adm.rejected_deadline(), 1);
        assert_eq!(adm.rejected_rate_limit(), 0);
    }

    #[test]
    fn backlog_counts_against_the_projection() {
        let adm = Admission::new(no_limit(), 1);
        adm.charge(0, 100);
        // (100 + 8) × 1000 µs = 108 ms > 50 ms
        let err = adm.admit(None, 0, 8, Some(Duration::from_millis(50))).unwrap_err();
        let Rejection::DeadlineUnmeetable { projected, retry_after, .. } = err else {
            panic!("expected deadline rejection");
        };
        assert_eq!(projected, Duration::from_millis(108));
        // fits = 50ms/1000µs = 50 NFE; excess = 108 - 50 = 58 → 58 ms
        assert_eq!(retry_after, Duration::from_millis(58));
        // draining the backlog re-opens the door
        adm.release(0, 100);
        assert!(adm.admit(None, 0, 8, Some(Duration::from_millis(50))).is_ok());
    }

    #[test]
    fn observe_releases_and_moves_the_ewma() {
        let adm = Admission::new(no_limit(), 1);
        adm.charge(0, 10);
        assert_eq!(adm.queued_nfe(0), 10);
        // 10 NFE in 50 ms → 5000 µs/NFE sample; α = 0.2 over seed 1000
        adm.observe(0, 10, Duration::from_millis(50));
        assert_eq!(adm.queued_nfe(0), 0);
        let ewma = adm.ewma_us_per_nfe(0);
        assert!((ewma - (0.2 * 5000.0 + 0.8 * 1000.0)).abs() < 1e-6, "{ewma}");
    }

    #[test]
    fn release_saturates_at_zero() {
        let adm = Admission::new(no_limit(), 1);
        adm.charge(0, 3);
        adm.release(0, 100);
        assert_eq!(adm.queued_nfe(0), 0);
    }

    #[test]
    fn token_bucket_limits_per_tenant_bursts() {
        let policy = AdmissionPolicy {
            rate_limit: Some(RateLimit { burst: 2.0, per_sec: 0.0 }),
            ..AdmissionPolicy::default()
        };
        let adm = Admission::new(policy, 1);
        assert!(adm.admit(Some("a"), 0, 1, None).is_ok());
        assert!(adm.admit(Some("a"), 0, 1, None).is_ok());
        let err = adm.admit(Some("a"), 0, 1, None).unwrap_err();
        assert_eq!(err.status(), 429);
        assert!(err.retry_after_secs() >= 1);
        // tenant buckets are independent — and the anonymous bucket is
        // its own tenant
        assert!(adm.admit(Some("b"), 0, 1, None).is_ok());
        assert!(adm.admit(None, 0, 1, None).is_ok());
        assert_eq!(adm.rejected_rate_limit(), 1);
    }

    #[test]
    fn rate_limit_retry_after_is_the_exact_refill_time() {
        let policy = AdmissionPolicy {
            rate_limit: Some(RateLimit { burst: 1.0, per_sec: 2.0 }),
            ..AdmissionPolicy::default()
        };
        let adm = Admission::new(policy, 1);
        assert!(adm.admit(Some("t"), 0, 1, None).is_ok());
        let Err(Rejection::RateLimited { retry_after }) = adm.admit(Some("t"), 0, 1, None) else {
            panic!("expected rate limit");
        };
        // one token at 2/s refills in ≤ 500 ms
        assert!(retry_after <= Duration::from_millis(500), "{retry_after:?}");
    }

    fn model() -> ModelConfig {
        crate::runtime::MockDenoiser::test_config(20, 8, 0, "absorbing")
    }

    #[test]
    fn place_and_charge_picks_the_lowest_projected_wait_shard() {
        let adm = Admission::new(no_limit(), 2);
        adm.charge(0, 100);
        // shard 0 projects (100+8)×1000 µs, shard 1 projects 8×1000 µs
        let shard = adm.place_and_charge(None, 8, None).unwrap();
        assert_eq!(shard, 1);
        assert_eq!(adm.queued_nfe(1), 8, "the charge landed on the placed shard");
        // make shard 1's measured pace terrible: 8 NFE in 8 s → 1e6
        // µs/NFE sample, EWMA 0.2·1e6 + 0.8·1000 = 200 800
        adm.observe(1, 8, Duration::from_secs(8));
        // now (100+8)×1000 = 108 ms beats (0+8)×200 800 ≈ 1.6 s
        let shard = adm.place_and_charge(None, 8, None).unwrap();
        assert_eq!(shard, 0, "projected wait, not raw backlog, decides placement");
        // unmeetable deadline on the best shard rejects without charging
        let before = adm.queued_nfe(0) + adm.queued_nfe(1);
        let err = adm.place_and_charge(None, 8, Some(Duration::from_millis(1))).unwrap_err();
        assert_eq!(err.status(), 503);
        assert_eq!(adm.queued_nfe(0) + adm.queued_nfe(1), before, "rejected → nothing charged");
    }

    #[test]
    fn observe_served_releases_the_full_charge_at_the_served_pace() {
        let adm = Admission::new(no_limit(), 1);
        adm.charge(0, 30);
        // early retirement: charged 30, served 10 in 50 ms → full charge
        // released, pace sample 5000 µs/NFE (not 50 ms / 30)
        adm.observe_served(0, 30, 10, Duration::from_millis(50));
        assert_eq!(adm.queued_nfe(0), 0);
        let ewma = adm.ewma_us_per_nfe(0);
        assert!((ewma - (0.2 * 5000.0 + 0.8 * 1000.0)).abs() < 1e-6, "{ewma}");
    }

    #[test]
    fn quality_tier_serves_the_config_untouched() {
        let adm = Admission::new(no_limit(), 1);
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50);
        let (resolved, d) = adm.resolve_tier(&model(), &cfg, 7, Tier::Quality).unwrap();
        assert_eq!(resolved.steps, cfg.steps);
        assert_eq!(resolved.spec, cfg.spec);
        assert!(resolved.max_nfe.is_none());
        assert_eq!(d.projected_nfe, exact_cost(&model(), &cfg, 7).unwrap());
        assert!(d.chosen_spec.starts_with("dndm:"), "{}", d.chosen_spec);
    }

    #[test]
    fn turbo_tier_caps_cost_for_ladder_and_step_kinds() {
        let adm = Admission::new(no_limit(), 1);
        let dndm = SamplerConfig::new(SamplerKind::Dndm, 1000);
        let (r, d) = adm.resolve_tier(&model(), &dndm, 3, Tier::Turbo { max_nfe: 3 }).unwrap();
        assert_eq!(r.max_nfe, Some(3), "ladder kinds truncate the transition set");
        assert!(d.projected_nfe <= 3, "{}", d.projected_nfe);
        assert_eq!(
            d.projected_nfe,
            exact_cost(&model(), &r, 3).unwrap(),
            "the projection is the served cost, exactly"
        );
        let d3pm = SamplerConfig::new(SamplerKind::D3pm, 100);
        let (r, d) = adm.resolve_tier(&model(), &d3pm, 3, Tier::Turbo { max_nfe: 5 }).unwrap();
        assert_eq!(r.steps, 5, "step-marching kinds are capped by lowering steps");
        assert!(r.max_nfe.is_none());
        assert_eq!(d.projected_nfe, 5);
    }

    #[test]
    fn balanced_tier_downshifts_to_meet_the_slo_or_503s() {
        let adm = Admission::new(no_limit(), 1);
        // pace 1000 µs/NFE → a 3000-step D3PM projects 3 s
        let cfg = SamplerConfig::new(SamplerKind::D3pm, 3000);
        // generous SLO: the base config is kept
        let (r, _) = adm.resolve_tier(&model(), &cfg, 7, Tier::Balanced { slo_ms: 10_000 }).unwrap();
        assert_eq!(r.steps, 3000);
        // tight SLO: the largest grid candidate that fits wins —
        // grid {3000, 2250, 1500, 750, 375}, 1.6 s at 1000 µs/NFE → 1500
        let (r, d) = adm.resolve_tier(&model(), &cfg, 7, Tier::Balanced { slo_ms: 1600 }).unwrap();
        assert_eq!(r.steps, 1500);
        assert_eq!(d.projected_nfe, 1500);
        assert!(d.projected_ms <= 1600, "{}", d.projected_ms);
        // unmeetable: even the cheapest candidate (375) exceeds the SLO
        let err = adm.resolve_tier(&model(), &cfg, 7, Tier::Balanced { slo_ms: 1 }).unwrap_err();
        assert_eq!(err.status(), 503);
        assert_eq!(adm.rejected_deadline(), 1, "503 before any compute, counted");
    }

    #[test]
    fn balanced_tier_searches_specs_for_dndm_kinds() {
        let adm = Admission::new(no_limit(), 1);
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 40);
        let base_cost = exact_cost(&model(), &cfg, 9).unwrap();
        // SLO just under the base projection forces a downshift; the
        // chosen candidate must fit and stay as close to base as possible
        let slo_ms = base_cost.saturating_sub(1).max(1);
        match adm.resolve_tier(&model(), &cfg, 9, Tier::Balanced { slo_ms }) {
            Ok((r, d)) => {
                assert!(d.projected_nfe < base_cost, "{} < {base_cost}", d.projected_nfe);
                assert_eq!(d.projected_nfe, exact_cost(&model(), &r, 9).unwrap());
                assert!(d.projected_ms <= slo_ms, "{} <= {slo_ms}", d.projected_ms);
            }
            Err(e) => assert_eq!(e.status(), 503),
        }
    }

    #[test]
    fn metric_accessors_snapshot_shards_and_tenants() {
        let policy = AdmissionPolicy {
            rate_limit: Some(RateLimit { burst: 4.0, per_sec: 0.0 }),
            ..AdmissionPolicy::default()
        };
        let adm = Admission::new(policy, 2);
        adm.charge(1, 7);
        assert!(adm.admit(Some("b"), 0, 1, None).is_ok());
        assert!(adm.admit(Some("a"), 0, 1, None).is_ok());
        assert!(adm.admit(Some("a"), 0, 1, None).is_ok());
        assert_eq!(adm.shard_queued(), vec![0, 7]);
        assert_eq!(adm.shard_ewmas(), vec![1000.0, 1000.0]);
        let pace = adm.tenant_pace();
        assert_eq!(pace.len(), 2, "sorted tenants: {pace:?}");
        assert_eq!(pace[0].0, "a");
        assert!((pace[0].1 - 2.0).abs() < 1e-9, "{pace:?}");
        assert_eq!(pace[1].0, "b");
        assert!((pace[1].1 - 3.0).abs() < 1e-9, "{pace:?}");
    }

    #[test]
    fn board_pace_is_opt_in_and_prefers_warmed_boards() {
        let board = Arc::new(StatsBoard::new());
        // engine-side observation: 10 NFE in 50 ms → 5000 µs/NFE (first
        // sample seeds the board EWMA outright)
        board.observe_pace(10, Duration::from_millis(50));

        // off by default: attaching changes nothing
        let adm = Admission::new(no_limit(), 1);
        adm.attach_boards(vec![board.clone()]);
        assert_eq!(adm.ewma_us_per_nfe(0), 1000.0);

        // opted in: the board's measured pace drives projections...
        let policy = AdmissionPolicy { use_board_pace: true, ..no_limit() };
        let adm = Admission::new(policy.clone(), 2);
        adm.attach_boards(vec![board, Arc::new(StatsBoard::new())]);
        assert_eq!(adm.ewma_us_per_nfe(0), 5000.0);
        // ...while a cold board (no terminal yet → 0.0) falls back to
        // the front-door EWMA, as does a shard with no board at all
        assert_eq!(adm.ewma_us_per_nfe(1), 1000.0);
        let adm = Admission::new(policy, 1);
        assert_eq!(adm.ewma_us_per_nfe(0), 1000.0);
    }

    #[test]
    fn rejection_status_codes_and_rounding() {
        let r = Rejection::RateLimited { retry_after: Duration::from_millis(1) };
        assert_eq!(r.status(), 429);
        assert_eq!(r.retry_after_secs(), 1, "sub-second waits round up, not to 0");
        let r = Rejection::DeadlineUnmeetable {
            projected: Duration::from_secs(2),
            deadline: Duration::from_secs(1),
            retry_after: Duration::ZERO,
        };
        assert_eq!(r.status(), 503);
        assert_eq!(r.retry_after_secs(), 0);
    }
}
