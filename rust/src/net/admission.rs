//! Exact-cost admission control: token-bucket rate limiting plus
//! deadline-aware load shedding at the front door.
//!
//! The whole point of a DNDM front door is that **the denoiser-call cost
//! of a request is exactly known before any compute happens**: the
//! predetermined transition set 𝒯 is a pure function of (model config,
//! sampler config, seed), so [`exact_cost`] builds a throwaway
//! [`SamplerSession`] on the host — no denoiser call, no device — and
//! reads `total_events()`. Continuous serving runs each request in its
//! own width-1 lane (`shared_tau_groups: false`), so this admission-time
//! number equals the served lane's total and the final `Progress`
//! event's `nfe_total` exactly. Load shedding here is therefore not a
//! heuristic: a rejected request *provably* could not have met its
//! deadline, and `Retry-After` is derived from the same arithmetic.
//!
//! The projection: completion time for a new request of cost `c` landing
//! on a shard with `backlog` queued-but-unfinished NFE is
//!
//! ```text
//! projected_us = (backlog + c) × ewma_us_per_nfe
//! ```
//!
//! where `ewma_us_per_nfe` is an exponentially-weighted average of
//! measured wall-µs per denoiser call, fed by [`Admission::observe`] on
//! every retirement. If `projected_us` exceeds the request's deadline the
//! request is rejected with `503` before consuming anything.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, PoisonError};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::ModelConfig;
use crate::sampler::{SamplerConfig, SamplerSession};

/// Exact denoiser-call cost of one request: the size of its
/// predetermined transition set, computed host-side before any compute.
/// Errors only when the sampler config itself is invalid (which would
/// also fail at serving time — rejecting here with `400` is strictly
/// earlier, never different).
pub fn exact_cost(mcfg: &ModelConfig, cfg: &SamplerConfig, seed: u64) -> Result<u64> {
    Ok(SamplerSession::new(mcfg, cfg, 1, seed)?.total_events() as u64)
}

/// Per-tenant token-bucket parameters.
#[derive(Debug, Clone, Copy)]
pub struct RateLimit {
    /// bucket capacity — the largest instantaneous burst of requests
    pub burst: f64,
    /// refill rate, requests per second (0 disables refill: `burst`
    /// requests total, ever — useful in tests)
    pub per_sec: f64,
}

/// Front-door policy knobs.
#[derive(Debug, Clone)]
pub struct AdmissionPolicy {
    /// per-tenant token bucket; `None` disables rate limiting
    pub rate_limit: Option<RateLimit>,
    /// seed for the µs/NFE EWMA before the first measurement arrives
    pub initial_us_per_nfe: f64,
    /// EWMA smoothing factor in (0, 1]: weight of each new sample
    pub ewma_alpha: f64,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy {
            rate_limit: Some(RateLimit { burst: 32.0, per_sec: 16.0 }),
            initial_us_per_nfe: 1000.0,
            ewma_alpha: 0.2,
        }
    }
}

/// Why a request was turned away at the door.
#[derive(Debug, Clone, PartialEq)]
pub enum Rejection {
    /// Tenant bucket empty → HTTP `429`. `retry_after` is the exact time
    /// until one token refills.
    RateLimited { retry_after: Duration },
    /// The exact projection says the deadline cannot be met → HTTP
    /// `503`. `projected` is the projected completion time,
    /// `retry_after` the exact backlog-drain time needed before this
    /// request would fit.
    DeadlineUnmeetable { projected: Duration, deadline: Duration, retry_after: Duration },
}

impl Rejection {
    pub fn status(&self) -> u16 {
        match self {
            Rejection::RateLimited { .. } => 429,
            Rejection::DeadlineUnmeetable { .. } => 503,
        }
    }

    /// Seconds for the `Retry-After` header, rounded up so retrying at
    /// the advertised time actually succeeds.
    pub fn retry_after_secs(&self) -> u64 {
        let d = match self {
            Rejection::RateLimited { retry_after }
            | Rejection::DeadlineUnmeetable { retry_after, .. } => *retry_after,
        };
        d.as_secs() + u64::from(d.subsec_nanos() > 0)
    }
}

/// One tenant's token bucket. Refill is computed lazily from elapsed
/// time — no background thread.
struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Per-shard load account: NFE admitted but not yet retired, plus the
/// measured pace. `ewma_us_per_nfe` is stored as f64 bits in an
/// `AtomicU64` and updated with a CAS loop so `observe` never takes a
/// lock on the retirement path.
struct ShardLoad {
    queued_nfe: AtomicU64,
    ewma_us_bits: AtomicU64,
}

/// The admission controller. One instance fronts one [`Router`]; all
/// methods take `&self` and are safe to call from every connection
/// worker concurrently.
///
/// [`Router`]: crate::coordinator::Router
pub struct Admission {
    policy: AdmissionPolicy,
    shards: Vec<ShardLoad>,
    buckets: Mutex<HashMap<String, Bucket>>,
    rejected_rate_limit: AtomicU64,
    rejected_deadline: AtomicU64,
}

impl Admission {
    pub fn new(policy: AdmissionPolicy, num_shards: usize) -> Admission {
        let shards = (0..num_shards.max(1))
            .map(|_| ShardLoad {
                queued_nfe: AtomicU64::new(0),
                ewma_us_bits: AtomicU64::new(policy.initial_us_per_nfe.to_bits()),
            })
            .collect();
        Admission {
            policy,
            shards,
            buckets: Mutex::new(HashMap::new()),
            rejected_rate_limit: AtomicU64::new(0),
            rejected_deadline: AtomicU64::new(0),
        }
    }

    /// Check-only gate: may this request of exactly `cost` denoiser
    /// calls, projected onto `shard`, be admitted? On `Err` the matching
    /// rejection counter has been bumped and nothing else changed; on
    /// `Ok` the caller submits to the router and then calls
    /// [`Self::charge`] with the shard the router actually picked.
    pub fn admit(
        &self,
        tenant: Option<&str>,
        shard: usize,
        cost: u64,
        deadline: Option<Duration>,
    ) -> std::result::Result<(), Rejection> {
        if let Some(limit) = self.policy.rate_limit {
            if let Err(wait) = self.take_token(tenant.unwrap_or(""), limit) {
                self.rejected_rate_limit.fetch_add(1, Ordering::Relaxed);
                return Err(Rejection::RateLimited { retry_after: wait });
            }
        }
        if let Some(deadline) = deadline {
            let shard = &self.shards[shard.min(self.shards.len() - 1)];
            let backlog = shard.queued_nfe.load(Ordering::Relaxed);
            let pace = f64::from_bits(shard.ewma_us_bits.load(Ordering::Relaxed));
            let projected_us = (backlog + cost) as f64 * pace;
            let deadline_us = deadline.as_micros() as f64;
            if projected_us > deadline_us {
                self.rejected_deadline.fetch_add(1, Ordering::Relaxed);
                // the shard must drain enough NFE that (backlog' + cost)
                // × pace fits the deadline; that drain takes excess ×
                // pace µs at the measured rate
                let fits = (deadline_us / pace.max(1e-9)) as u64;
                let excess = (backlog + cost).saturating_sub(fits);
                let retry_after = Duration::from_micros((excess as f64 * pace) as u64);
                return Err(Rejection::DeadlineUnmeetable {
                    projected: Duration::from_micros(projected_us as u64),
                    deadline,
                    retry_after,
                });
            }
        }
        Ok(())
    }

    /// Record `cost` NFE as queued on `shard` — call with the shard the
    /// router actually placed the request on (placement may differ from
    /// the projection shard if a rebalance raced the submit; charging the
    /// real shard keeps the account consistent either way).
    pub fn charge(&self, shard: usize, cost: u64) {
        self.shard(shard).queued_nfe.fetch_add(cost, Ordering::Relaxed);
    }

    /// Release `cost` NFE from `shard` without a pace measurement — for
    /// requests that ended without finishing (cancelled, deadline-dropped,
    /// failed, client disconnected).
    pub fn release(&self, shard: usize, cost: u64) {
        let q = &self.shard(shard).queued_nfe;
        let mut cur = q.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(cost);
            match q.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Retirement hook: release the request's NFE and fold its measured
    /// wall time into the shard's µs/NFE EWMA.
    pub fn observe(&self, shard: usize, nfe: u64, elapsed: Duration) {
        self.release(shard, nfe);
        let sample = elapsed.as_micros() as f64 / nfe.max(1) as f64;
        let alpha = self.policy.ewma_alpha.clamp(0.0, 1.0);
        let bits = &self.shard(shard).ewma_us_bits;
        let mut cur = bits.load(Ordering::Relaxed);
        loop {
            let next = (alpha * sample + (1.0 - alpha) * f64::from_bits(cur)).to_bits();
            match bits.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Current µs/NFE estimate for a shard (scraped into `/metrics`).
    pub fn ewma_us_per_nfe(&self, shard: usize) -> f64 {
        f64::from_bits(self.shard(shard).ewma_us_bits.load(Ordering::Relaxed))
    }

    /// NFE admitted but not yet retired on a shard.
    pub fn queued_nfe(&self, shard: usize) -> u64 {
        self.shard(shard).queued_nfe.load(Ordering::Relaxed)
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Requests rejected by the rate limiter since construction.
    pub fn rejected_rate_limit(&self) -> u64 {
        self.rejected_rate_limit.load(Ordering::Relaxed)
    }

    /// Requests rejected by the deadline projection since construction.
    pub fn rejected_deadline(&self) -> u64 {
        self.rejected_deadline.load(Ordering::Relaxed)
    }

    fn shard(&self, shard: usize) -> &ShardLoad {
        &self.shards[shard.min(self.shards.len() - 1)]
    }

    /// Take one token from `tenant`'s bucket, or return the exact wait
    /// until a token refills.
    fn take_token(&self, tenant: &str, limit: RateLimit) -> std::result::Result<(), Duration> {
        let mut buckets = self.buckets.lock().unwrap_or_else(PoisonError::into_inner);
        let now = Instant::now();
        let bucket = buckets
            .entry(tenant.to_string())
            .or_insert_with(|| Bucket { tokens: limit.burst, last: now });
        if limit.per_sec > 0.0 {
            let refill = now.duration_since(bucket.last).as_secs_f64() * limit.per_sec;
            bucket.tokens = (bucket.tokens + refill).min(limit.burst);
        }
        bucket.last = now;
        if bucket.tokens >= 1.0 {
            bucket.tokens -= 1.0;
            Ok(())
        } else if limit.per_sec > 0.0 {
            Err(Duration::from_secs_f64((1.0 - bucket.tokens) / limit.per_sec))
        } else {
            // no refill configured: "retry" is really "never"; advertise
            // a flat minute so clients back off hard
            Err(Duration::from_secs(60))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn no_limit() -> AdmissionPolicy {
        AdmissionPolicy { rate_limit: None, ..AdmissionPolicy::default() }
    }

    #[test]
    fn admits_when_projection_fits_the_deadline() {
        // pace 1000 µs/NFE, cost 8, empty backlog → 8 ms projected
        let adm = Admission::new(no_limit(), 2);
        assert!(adm.admit(None, 0, 8, Some(Duration::from_millis(100))).is_ok());
        assert!(adm.admit(None, 0, 8, None).is_ok(), "no deadline, no shedding");
    }

    #[test]
    fn rejects_exactly_when_projection_exceeds_the_deadline() {
        let adm = Admission::new(no_limit(), 1);
        // projected = 8 × 1000 µs = 8 ms; a 7 ms deadline must reject,
        // an 8 ms one must pass (the projection is exact, not padded)
        assert!(adm.admit(None, 0, 8, Some(Duration::from_millis(7))).is_err());
        assert!(adm.admit(None, 0, 8, Some(Duration::from_millis(8))).is_ok());
        assert_eq!(adm.rejected_deadline(), 1);
        assert_eq!(adm.rejected_rate_limit(), 0);
    }

    #[test]
    fn backlog_counts_against_the_projection() {
        let adm = Admission::new(no_limit(), 1);
        adm.charge(0, 100);
        // (100 + 8) × 1000 µs = 108 ms > 50 ms
        let err = adm.admit(None, 0, 8, Some(Duration::from_millis(50))).unwrap_err();
        let Rejection::DeadlineUnmeetable { projected, retry_after, .. } = err else {
            panic!("expected deadline rejection");
        };
        assert_eq!(projected, Duration::from_millis(108));
        // fits = 50ms/1000µs = 50 NFE; excess = 108 - 50 = 58 → 58 ms
        assert_eq!(retry_after, Duration::from_millis(58));
        // draining the backlog re-opens the door
        adm.release(0, 100);
        assert!(adm.admit(None, 0, 8, Some(Duration::from_millis(50))).is_ok());
    }

    #[test]
    fn observe_releases_and_moves_the_ewma() {
        let adm = Admission::new(no_limit(), 1);
        adm.charge(0, 10);
        assert_eq!(adm.queued_nfe(0), 10);
        // 10 NFE in 50 ms → 5000 µs/NFE sample; α = 0.2 over seed 1000
        adm.observe(0, 10, Duration::from_millis(50));
        assert_eq!(adm.queued_nfe(0), 0);
        let ewma = adm.ewma_us_per_nfe(0);
        assert!((ewma - (0.2 * 5000.0 + 0.8 * 1000.0)).abs() < 1e-6, "{ewma}");
    }

    #[test]
    fn release_saturates_at_zero() {
        let adm = Admission::new(no_limit(), 1);
        adm.charge(0, 3);
        adm.release(0, 100);
        assert_eq!(adm.queued_nfe(0), 0);
    }

    #[test]
    fn token_bucket_limits_per_tenant_bursts() {
        let policy = AdmissionPolicy {
            rate_limit: Some(RateLimit { burst: 2.0, per_sec: 0.0 }),
            ..AdmissionPolicy::default()
        };
        let adm = Admission::new(policy, 1);
        assert!(adm.admit(Some("a"), 0, 1, None).is_ok());
        assert!(adm.admit(Some("a"), 0, 1, None).is_ok());
        let err = adm.admit(Some("a"), 0, 1, None).unwrap_err();
        assert_eq!(err.status(), 429);
        assert!(err.retry_after_secs() >= 1);
        // tenant buckets are independent — and the anonymous bucket is
        // its own tenant
        assert!(adm.admit(Some("b"), 0, 1, None).is_ok());
        assert!(adm.admit(None, 0, 1, None).is_ok());
        assert_eq!(adm.rejected_rate_limit(), 1);
    }

    #[test]
    fn rate_limit_retry_after_is_the_exact_refill_time() {
        let policy = AdmissionPolicy {
            rate_limit: Some(RateLimit { burst: 1.0, per_sec: 2.0 }),
            ..AdmissionPolicy::default()
        };
        let adm = Admission::new(policy, 1);
        assert!(adm.admit(Some("t"), 0, 1, None).is_ok());
        let Err(Rejection::RateLimited { retry_after }) = adm.admit(Some("t"), 0, 1, None) else {
            panic!("expected rate limit");
        };
        // one token at 2/s refills in ≤ 500 ms
        assert!(retry_after <= Duration::from_millis(500), "{retry_after:?}");
    }

    #[test]
    fn rejection_status_codes_and_rounding() {
        let r = Rejection::RateLimited { retry_after: Duration::from_millis(1) };
        assert_eq!(r.status(), 429);
        assert_eq!(r.retry_after_secs(), 1, "sub-second waits round up, not to 0");
        let r = Rejection::DeadlineUnmeetable {
            projected: Duration::from_secs(2),
            deadline: Duration::from_secs(1),
            retry_after: Duration::ZERO,
        };
        assert_eq!(r.status(), 503);
        assert_eq!(r.retry_after_secs(), 0);
    }
}
