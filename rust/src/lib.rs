//! # DNDM — Discrete Non-Markov Diffusion Models, served from Rust
//!
//! Reproduction of *"Fast Sampling via Discrete Non-Markov Diffusion Models
//! with Predetermined Transition Time"* (Chen et al., NeurIPS 2024) as a
//! deployable three-layer serving stack:
//!
//! * **L3 (this crate)** — the coordinator: request queue, continuous
//!   NFE-aligned scheduler (requests join in-flight batches at
//!   transition-time boundaries; see `docs/serving.md`), all sampling
//!   algorithms as per-NFE `SamplerSession` state machines (DNDM
//!   Alg. 1/2/3/4 plus the D3PM / RDM / Mask-Predict baselines),
//!   schedules, metrics, and the PJRT runtime that executes the AOT
//!   artifacts.
//! * **L2 (python/compile/model.py, build time)** — the JAX denoiser
//!   `p_θ(x̂0 | x_t, t[, src])`, lowered once to HLO text.
//! * **L1 (python/compile/kernels/, build time)** — Pallas kernels (fused
//!   attention + the fused DNDM transition update) inside that HLO.
//!
//! Python never runs on the request path: after `make artifacts` the rust
//! binary is self-contained.
//!
//! ## Quick tour
//!
//! ```no_run
//! use dndm::runtime::Artifacts;
//! use dndm::sampler::{SamplerKind, SamplerConfig};
//! use dndm::coordinator::Engine;
//!
//! let arts = Artifacts::load("artifacts").unwrap();
//! let engine = Engine::new(&arts, "cond_absorb_iwslt14").unwrap();
//! let out = engine.generate_one(
//!     Some("the quick fox crosses a river"),
//!     &SamplerConfig::new(SamplerKind::Dndm, 50),
//!     7,
//! ).unwrap();
//! println!("{} (NFE {})", out.text, out.nfe);
//! ```

pub mod coordinator;
pub mod data;
pub mod diffusion;
pub mod exp;
pub mod metrics;
pub mod runtime;
pub mod sampler;
pub mod schedule;
pub mod tensor;
pub mod text;
pub mod util;

pub use anyhow::{anyhow, bail, Context, Result};
