//! # DNDM — Discrete Non-Markov Diffusion Models, served from Rust
//!
//! Reproduction of *"Fast Sampling via Discrete Non-Markov Diffusion Models
//! with Predetermined Transition Time"* (Chen et al., NeurIPS 2024) as a
//! deployable three-layer serving stack:
//!
//! * **L3 (this crate)** — the coordinator: request queue, continuous
//!   NFE-aligned scheduler (requests join in-flight batches at
//!   transition-time boundaries; see `docs/serving.md`), all sampling
//!   algorithms as per-NFE `SamplerSession` state machines (DNDM
//!   Alg. 1/2/3/4 plus the D3PM / RDM / Mask-Predict baselines),
//!   schedules, metrics, and the PJRT runtime that executes the AOT
//!   artifacts.
//! * **L2 (python/compile/model.py, build time)** — the JAX denoiser
//!   `p_θ(x̂0 | x_t, t[, src])`, lowered once to HLO text.
//! * **L1 (python/compile/kernels/, build time)** — Pallas kernels (fused
//!   attention + the fused DNDM transition update) inside that HLO.
//!
//! Python never runs on the request path: after `make artifacts` the rust
//! binary is self-contained.
//!
//! ## The serving stack
//!
//! ```text
//!  GenRequest ──▶ Router (ServeBuilder) ── spec-affinity / least-loaded ──┐
//!      │              │                                                   │
//!   Ticket ◀── events │ rebalancer: background cadence loop               │
//!  (Admitted/         │   steal queued runs / donate in-flight lanes      │
//!   Progress/Done)    ▼   between shards at 𝒯-boundaries                  ▼
//!               ┌─ shard 0 ─────────────┐   DonateLane    ┌─ shard 1 ──────┐
//!               │ Server (engine thread)│ ◀═════════════▶ │ Server …       │
//!               │   └ Scheduler         │                 │   └ Scheduler  │
//!               │      lanes ⇆ queue    │                 │                │
//!               │      └ SamplerSession │                 │                │
//!               │         └ Denoiser ───┼── PJRT / mock   │                │
//!               └───────────────────────┘                 └────────────────┘
//! ```
//!
//! Every arrow that crosses into a scheduler lands on a **transition-time
//! boundary**: admission, retirement, cancellation, progress emission,
//! and cross-shard movement all happen between two denoiser calls, which
//! the paper's predetermined 𝒯 makes exact (see `docs/serving.md` and
//! `docs/rebalancing.md`; the repo-level map is in the root README).
//!
//! ## Quick tour
//!
//! Serving goes through one builder: [`coordinator::ServeBuilder`] starts
//! N sharded server threads (continuous NFE-aligned scheduling by
//! default), and each submitted [`coordinator::GenRequest`] returns a
//! [`coordinator::Ticket`] — a per-NFE event stream with boundary
//! cancellation:
//!
//! ```no_run
//! use dndm::coordinator::{Engine, Event, GenRequest, ServeBuilder};
//! use dndm::runtime::Artifacts;
//! use dndm::sampler::{SamplerConfig, SamplerKind};
//!
//! let router = ServeBuilder::new(
//!     || Engine::new(&Artifacts::load("artifacts")?, "cond_absorb_iwslt14"),
//!     SamplerConfig::new(SamplerKind::Dndm, 50),
//! )
//! .shards(2)
//! .start();
//!
//! let mut ticket = router
//!     .submit_request(GenRequest::new(7).src("the quick fox crosses a river").stream_partials())
//!     .unwrap();
//! while let Some(event) = ticket.next_event() {
//!     match event {
//!         Event::Progress { nfe_done, nfe_total, .. } => {
//!             println!("boundary {nfe_done}/{nfe_total}");
//!         }
//!         Event::Done(out) => println!("{} (NFE {})", out.text, out.nfe),
//!         _ => {}
//!     }
//! }
//! router.shutdown();
//! ```
//!
//! For one-off generation without a server thread, [`coordinator::Engine`]
//! still exposes `generate_one` / `generate_batch` directly.
//!
//! Over the wire, the [`net`] module fronts the same router with
//! HTTP/1.1 + Server-Sent Events and **exact-cost admission control**:
//! because a request's denoiser-call count is the size of its
//! predetermined transition set — computable on the host before any
//! compute — the front door rejects unmeetable deadlines with `503`
//! before they consume anything (`docs/http.md`).

pub mod coordinator;
pub mod data;
pub mod diffusion;
pub mod exp;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod sampler;
pub mod schedule;
pub mod tensor;
pub mod text;
pub mod util;

pub use anyhow::{anyhow, bail, Context, Result};
