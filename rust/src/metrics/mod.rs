//! Evaluation metrics: BLEU (translation quality), n-gram LM perplexity
//! (unconditional fluency), NFE accounting, latency/throughput statistics.

pub mod bleu;
pub mod latency;
pub mod ngram;
pub mod nfe;

pub use bleu::{corpus_bleu, sentence_bleu};
pub use latency::{LatencySnapshot, LatencyStats};
pub use ngram::NgramLm;
pub use nfe::NfeCounter;
