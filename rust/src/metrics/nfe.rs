//! NFE accounting — the paper's efficiency currency.
//!
//! Tables 7/8 report "average NFE": total denoiser calls divided by the
//! number of batches. This counter distinguishes *calls* (one batched
//! forward = one call, the wall-clock-relevant number) from *sequence
//! evaluations* (calls × batch size).

use std::sync::atomic::{AtomicU64, Ordering};

/// Thread-safe NFE counter shared between samplers and the coordinator.
#[derive(Debug, Default)]
pub struct NfeCounter {
    calls: AtomicU64,
    seqs: AtomicU64,
    batches: AtomicU64,
}

impl NfeCounter {
    pub fn new() -> Self {
        Self::default()
    }

    /// One denoiser invocation over `batch` sequences.
    pub fn record_call(&self, batch: usize) {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.seqs.fetch_add(batch as u64, Ordering::Relaxed);
    }

    /// One generation batch finished (the denominator in Tables 7/8).
    pub fn record_batch(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    pub fn seq_evals(&self) -> u64 {
        self.seqs.load(Ordering::Relaxed)
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// Average NFE per batch — the Tables 7/8 statistic.
    pub fn avg_nfe(&self) -> f64 {
        let b = self.batches();
        if b == 0 {
            0.0
        } else {
            self.calls() as f64 / b as f64
        }
    }

    pub fn reset(&self) {
        self.calls.store(0, Ordering::Relaxed);
        self.seqs.store(0, Ordering::Relaxed);
        self.batches.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_average() {
        let c = NfeCounter::new();
        c.record_call(16);
        c.record_call(16);
        c.record_batch();
        c.record_call(8);
        c.record_batch();
        assert_eq!(c.calls(), 3);
        assert_eq!(c.seq_evals(), 40);
        assert_eq!(c.batches(), 2);
        assert!((c.avg_nfe() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn zero_batches_is_zero_avg() {
        let c = NfeCounter::new();
        c.record_call(4);
        assert_eq!(c.avg_nfe(), 0.0);
    }

    #[test]
    fn reset_clears() {
        let c = NfeCounter::new();
        c.record_call(1);
        c.record_batch();
        c.reset();
        assert_eq!(c.calls() + c.seq_evals() + c.batches(), 0);
    }

    #[test]
    fn concurrent_updates() {
        let c = std::sync::Arc::new(NfeCounter::new());
        let mut handles = vec![];
        for _ in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.record_call(2);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.calls(), 4000);
        assert_eq!(c.seq_evals(), 8000);
    }
}
