//! NFE accounting — the paper's efficiency currency.
//!
//! Tables 7/8 report "average NFE": total denoiser calls divided by the
//! number of batches. This counter distinguishes *calls* (one batched
//! forward = one call, the wall-clock-relevant number) from *sequence
//! evaluations* (calls × batch size).
//!
//! The continuous scheduler adds per-request accounting on top: each
//! retired request records its own NFE (= |𝒯| of its session) and its
//! queue wait, and every call records the in-flight width so occupancy
//! (mean width / capacity) is observable.
//!
//! One counter lives per engine (= per shard), and in-flight lane
//! donation (`coordinator::rebalancer`) can split a request's life
//! across two of them: *calls* land on whichever engine executed them,
//! while the *per-request* records (`record_request`, `record_batch`)
//! land on the engine that retired the lane — with the request's **full**
//! NFE, donor-side calls included, since that is what the request cost
//! end to end. Per-shard `avg_request_nfe` can therefore disagree with
//! that shard's own `nn_calls` under donation; the router-level merge
//! weighs each shard's average by its *retired*-request count (not its
//! submit count), so the merged figure is the true per-request mean
//! across the fleet, and total calls remain conserved across shards.

use std::sync::atomic::{AtomicU64, Ordering};

/// Thread-safe NFE counter shared between samplers and the coordinator.
#[derive(Debug, Default)]
pub struct NfeCounter {
    calls: AtomicU64,
    seqs: AtomicU64,
    batches: AtomicU64,
    /// Σ per-request NFE over retired requests (continuous scheduler).
    request_nfe: AtomicU64,
    /// retired requests (denominator of `avg_request_nfe`).
    requests: AtomicU64,
    /// Σ queue wait in microseconds over retired requests.
    wait_us: AtomicU64,
}

impl NfeCounter {
    pub fn new() -> Self {
        Self::default()
    }

    /// One denoiser invocation over `batch` sequences.
    pub fn record_call(&self, batch: usize) {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.seqs.fetch_add(batch as u64, Ordering::Relaxed);
    }

    /// One generation batch finished (the denominator in Tables 7/8).
    pub fn record_batch(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    /// One request retired from the continuous scheduler: its own NFE
    /// (= denoiser calls while it was in flight = |𝒯| of its session)
    /// and how long it waited in the queue before admission.
    pub fn record_request(&self, nfe: usize, wait: std::time::Duration) {
        self.request_nfe.fetch_add(nfe as u64, Ordering::Relaxed);
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.wait_us.fetch_add(wait.as_micros() as u64, Ordering::Relaxed);
    }

    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    pub fn seq_evals(&self) -> u64 {
        self.seqs.load(Ordering::Relaxed)
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    /// Average NFE per batch — the Tables 7/8 statistic.
    pub fn avg_nfe(&self) -> f64 {
        let b = self.batches();
        if b == 0 {
            0.0
        } else {
            self.calls() as f64 / b as f64
        }
    }

    /// Mean per-request NFE over retired requests.
    pub fn avg_request_nfe(&self) -> f64 {
        let r = self.requests();
        if r == 0 {
            0.0
        } else {
            self.request_nfe.load(Ordering::Relaxed) as f64 / r as f64
        }
    }

    /// Mean queue wait over retired requests.
    pub fn avg_wait(&self) -> std::time::Duration {
        let r = self.requests();
        if r == 0 {
            std::time::Duration::ZERO
        } else {
            std::time::Duration::from_micros(self.wait_us.load(Ordering::Relaxed) / r)
        }
    }

    /// Mean in-flight width per call (sequence evaluations / calls) —
    /// divide by slot capacity for occupancy in [0, 1].
    pub fn mean_width(&self) -> f64 {
        let c = self.calls();
        if c == 0 {
            0.0
        } else {
            self.seq_evals() as f64 / c as f64
        }
    }

    /// Fraction of slot capacity in use, averaged over calls.
    pub fn occupancy(&self, capacity: usize) -> f64 {
        if capacity == 0 {
            0.0
        } else {
            self.mean_width() / capacity as f64
        }
    }

    pub fn reset(&self) {
        self.calls.store(0, Ordering::Relaxed);
        self.seqs.store(0, Ordering::Relaxed);
        self.batches.store(0, Ordering::Relaxed);
        self.request_nfe.store(0, Ordering::Relaxed);
        self.requests.store(0, Ordering::Relaxed);
        self.wait_us.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn counts_and_average() {
        let c = NfeCounter::new();
        c.record_call(16);
        c.record_call(16);
        c.record_batch();
        c.record_call(8);
        c.record_batch();
        assert_eq!(c.calls(), 3);
        assert_eq!(c.seq_evals(), 40);
        assert_eq!(c.batches(), 2);
        assert!((c.avg_nfe() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn zero_batches_is_zero_avg() {
        let c = NfeCounter::new();
        c.record_call(4);
        assert_eq!(c.avg_nfe(), 0.0);
        assert_eq!(c.avg_request_nfe(), 0.0);
        assert_eq!(c.avg_wait(), Duration::ZERO);
    }

    #[test]
    fn per_request_accounting() {
        let c = NfeCounter::new();
        c.record_request(6, Duration::from_micros(100));
        c.record_request(10, Duration::from_micros(300));
        assert_eq!(c.requests(), 2);
        assert!((c.avg_request_nfe() - 8.0).abs() < 1e-12);
        assert_eq!(c.avg_wait(), Duration::from_micros(200));
    }

    #[test]
    fn occupancy_from_call_widths() {
        let c = NfeCounter::new();
        c.record_call(4);
        c.record_call(2);
        assert!((c.mean_width() - 3.0).abs() < 1e-12);
        assert!((c.occupancy(4) - 0.75).abs() < 1e-12);
        assert_eq!(c.occupancy(0), 0.0);
    }

    #[test]
    fn reset_clears() {
        let c = NfeCounter::new();
        c.record_call(1);
        c.record_batch();
        c.record_request(3, Duration::from_micros(7));
        c.reset();
        assert_eq!(c.calls() + c.seq_evals() + c.batches() + c.requests(), 0);
        assert_eq!(c.avg_request_nfe(), 0.0);
    }

    #[test]
    fn concurrent_updates() {
        let c = std::sync::Arc::new(NfeCounter::new());
        let mut handles = vec![];
        for _ in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.record_call(2);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.calls(), 4000);
        assert_eq!(c.seq_evals(), 8000);
    }
}
