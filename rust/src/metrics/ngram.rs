//! Interpolated Kneser–Ney n-gram LM — the perplexity scorer for
//! unconditional generation (Table 4).
//!
//! The paper scores text8/enwik8 samples with GPT-2 (an *external* LM
//! measuring fluency). GPT-2 isn't available offline, so we fit a
//! character-level KN LM on held-out real corpus text and score generated
//! samples with it (DESIGN.md §3). What Table 4 claims — vanilla vs DNDM
//! ordering and the speedup — is preserved under any external LM.

use std::collections::HashMap;

/// Interpolated Kneser–Ney LM over u32 token ids, order `n`.
pub struct NgramLm {
    n: usize,
    /// `counts[k][context ++ token]` for k-grams (k = 1..=n)
    counts: Vec<HashMap<Vec<u32>, usize>>,
    /// context totals per order
    ctx_totals: Vec<HashMap<Vec<u32>, usize>>,
    /// distinct continuations per context (for the KN λ weights)
    ctx_types: Vec<HashMap<Vec<u32>, usize>>,
    /// continuation counts (unique left contexts) for the unigram base
    continuation: HashMap<u32, usize>,
    total_bigram_types: usize,
    vocab: usize,
    discount: f64,
}

impl NgramLm {
    pub fn new(n: usize, vocab: usize) -> Self {
        assert!(n >= 2);
        Self {
            n,
            counts: vec![HashMap::new(); n],
            ctx_totals: vec![HashMap::new(); n],
            ctx_types: vec![HashMap::new(); n],
            continuation: HashMap::new(),
            total_bigram_types: 0,
            vocab,
            discount: 0.75,
        }
    }

    /// Train on one token stream.
    pub fn fit(&mut self, stream: &[u32]) {
        for k in 1..=self.n {
            for w in stream.windows(k) {
                let e = self.counts[k - 1].entry(w.to_vec()).or_insert(0);
                *e += 1;
                let ctx = w[..k - 1].to_vec();
                *self.ctx_totals[k - 1].entry(ctx.clone()).or_insert(0) += 1;
                if *e == 1 {
                    *self.ctx_types[k - 1].entry(ctx).or_insert(0) += 1;
                    if k == 2 {
                        *self.continuation.entry(w[1]).or_insert(0) += 1;
                        self.total_bigram_types += 1;
                    }
                }
            }
        }
    }

    /// p(token | context) with interpolated KN smoothing.
    pub fn prob(&self, context: &[u32], token: u32) -> f64 {
        let ctx = if context.len() > self.n - 1 {
            &context[context.len() - (self.n - 1)..]
        } else {
            context
        };
        self.prob_order(ctx, token, ctx.len() + 1)
    }

    fn prob_order(&self, ctx: &[u32], token: u32, k: usize) -> f64 {
        if k == 1 {
            // KN continuation unigram, interpolated with uniform for OOV
            let cont = self.continuation.get(&token).copied().unwrap_or(0) as f64;
            let base = if self.total_bigram_types > 0 {
                cont / self.total_bigram_types as f64
            } else {
                0.0
            };
            return 0.9 * base + 0.1 / self.vocab as f64;
        }
        let total = self.ctx_totals[k - 1].get(ctx).copied().unwrap_or(0);
        let lower = self.prob_order(&ctx[1..], token, k - 1);
        if total == 0 {
            return lower; // unseen context: full backoff
        }
        let mut gram = ctx.to_vec();
        gram.push(token);
        let c = self.counts[k - 1].get(&gram).copied().unwrap_or(0) as f64;
        let types = self.ctx_types[k - 1].get(ctx).copied().unwrap_or(0) as f64;
        let d = self.discount;
        let lambda = d * types / total as f64;
        ((c - d).max(0.0)) / total as f64 + lambda * lower
    }

    /// Perplexity of a token stream: exp(mean NLL).
    pub fn perplexity(&self, stream: &[u32]) -> f64 {
        if stream.is_empty() {
            return f64::INFINITY;
        }
        let mut nll = 0.0;
        for i in 0..stream.len() {
            let lo = i.saturating_sub(self.n - 1);
            let p = self.prob(&stream[lo..i], stream[i]).max(1e-12);
            nll -= p.ln();
        }
        (nll / stream.len() as f64).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{corpus, translation::Split, UncondCorpus};

    fn fit_text8(n_chars: usize) -> (NgramLm, Vec<u32>) {
        let vocab = UncondCorpus::Text8.vocab();
        let stream: Vec<u32> = corpus::gen_text_stream(UncondCorpus::Text8, Split::Train, n_chars)
            .chars()
            .map(|c| vocab.id(&c.to_string()).unwrap())
            .collect();
        let mut lm = NgramLm::new(4, vocab.len());
        lm.fit(&stream);
        (lm, stream)
    }

    #[test]
    fn probs_normalize_over_vocab() {
        let (lm, stream) = fit_text8(5_000);
        let ctx = &stream[10..13];
        let total: f64 = (0..lm.vocab as u32).map(|t| lm.prob(ctx, t)).sum();
        assert!((total - 1.0).abs() < 0.02, "Σp = {total}");
    }

    #[test]
    fn real_text_scores_better_than_random() {
        let (lm, _) = fit_text8(20_000);
        let vocab = UncondCorpus::Text8.vocab();
        let held: Vec<u32> = corpus::gen_text_stream(UncondCorpus::Text8, Split::Test, 2_000)
            .chars()
            .map(|c| vocab.id(&c.to_string()).unwrap())
            .collect();
        let mut rng = crate::schedule::SplitMix64::new(1);
        let random: Vec<u32> = (0..2_000).map(|_| 3 + rng.below(27) as u32).collect();
        let ppl_real = lm.perplexity(&held);
        let ppl_rand = lm.perplexity(&random);
        assert!(
            ppl_real * 2.0 < ppl_rand,
            "real {ppl_real} should be ≪ random {ppl_rand}"
        );
        assert!(ppl_real < 10.0, "held-out ppl {ppl_real}");
    }

    #[test]
    fn unseen_context_backs_off_not_zero() {
        let (lm, _) = fit_text8(2_000);
        let p = lm.prob(&[29, 29, 29], 5);
        assert!(p > 0.0 && p < 1.0);
    }

    #[test]
    fn perplexity_of_training_text_is_low() {
        let (lm, stream) = fit_text8(10_000);
        let ppl = lm.perplexity(&stream[..2_000]);
        assert!(ppl < 8.0, "{ppl}");
    }

    #[test]
    fn empty_stream_is_infinite() {
        let (lm, _) = fit_text8(1_000);
        assert!(lm.perplexity(&[]).is_infinite());
    }
}
