//! Latency / throughput statistics for the serving benches.

use std::time::Duration;

/// Collects durations; reports mean / percentiles / throughput.
#[derive(Debug, Default, Clone)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    pub fn mean(&self) -> Duration {
        if self.samples_us.is_empty() {
            return Duration::ZERO;
        }
        let sum: u64 = self.samples_us.iter().sum();
        Duration::from_micros(sum / self.samples_us.len() as u64)
    }

    /// q ∈ [0, 1]; nearest-rank percentile.
    pub fn percentile(&self, q: f64) -> Duration {
        if self.samples_us.is_empty() {
            return Duration::ZERO;
        }
        let mut s = self.samples_us.clone();
        s.sort_unstable();
        let idx = ((q * s.len() as f64).ceil() as usize).clamp(1, s.len()) - 1;
        Duration::from_micros(s[idx])
    }

    pub fn p50(&self) -> Duration {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> Duration {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> Duration {
        self.percentile(0.99)
    }

    pub fn min(&self) -> Duration {
        Duration::from_micros(self.samples_us.iter().copied().min().unwrap_or(0))
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.samples_us.iter().copied().max().unwrap_or(0))
    }

    /// items/sec given total wall-clock time.
    pub fn throughput(items: usize, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        items as f64 / wall.as_secs_f64()
    }

    pub fn summary(&self, label: &str) -> String {
        format!(
            "{label}: n={} mean={:.1}ms p50={:.1}ms p95={:.1}ms p99={:.1}ms max={:.1}ms",
            self.len(),
            self.mean().as_secs_f64() * 1e3,
            self.p50().as_secs_f64() * 1e3,
            self.p95().as_secs_f64() * 1e3,
            self.p99().as_secs_f64() * 1e3,
            self.max().as_secs_f64() * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut s = LatencyStats::new();
        for i in 1..=100u64 {
            s.record(Duration::from_micros(i * 100));
        }
        assert!(s.p50() <= s.p95());
        assert!(s.p95() <= s.p99());
        assert!(s.p99() <= s.max());
        assert_eq!(s.p50(), Duration::from_micros(5000));
        assert_eq!(s.min(), Duration::from_micros(100));
    }

    #[test]
    fn mean_correct() {
        let mut s = LatencyStats::new();
        s.record(Duration::from_micros(100));
        s.record(Duration::from_micros(300));
        assert_eq!(s.mean(), Duration::from_micros(200));
    }

    #[test]
    fn empty_is_zero() {
        let s = LatencyStats::new();
        assert_eq!(s.mean(), Duration::ZERO);
        assert_eq!(s.p95(), Duration::ZERO);
    }

    #[test]
    fn throughput_math() {
        let t = LatencyStats::throughput(50, Duration::from_secs(2));
        assert!((t - 25.0).abs() < 1e-12);
    }
}
