//! Latency / throughput statistics for the serving benches.
//!
//! Bounded memory: samples land in a fixed-capacity **reservoir**
//! (Vitter's Algorithm R over a deterministic [`SplitMix64`] stream), so a
//! long-running server's stats stay O(capacity) instead of growing one
//! `u64` per request forever. Count, sum, min, and max are tracked
//! exactly; percentiles are computed over the reservoir — exact until
//! `RESERVOIR_CAP` samples, a uniform subsample after — from a cached
//! sorted view that is invalidated on record and rebuilt at most once per
//! run of percentile queries (the old code cloned and re-sorted the full
//! history on *every* percentile call; `summary()` did it four times).
//!
//! Percentile accessors take `&self`: the sorted view lives behind a
//! `RefCell`, so read paths (stats snapshots, the `/metrics` scrape, the
//! benches' report tables) never need a mutable borrow or a
//! clone-and-sort. `LatencyStats` stays `Send` (each server thread owns
//! its own instance); it is not `Sync`, which nothing relies on — shards
//! answer stats requests from their own thread. [`LatencyStats::freeze`]
//! captures an immutable [`LatencySnapshot`] for callers that want plain
//! `Copy` data with no cell at all.

use std::cell::RefCell;
use std::time::Duration;

use crate::schedule::SplitMix64;

/// Reservoir capacity. Nearest-rank percentiles up to p99 need ~100
/// samples for one rank of resolution; 4096 keeps p99 stable to well
/// under a rank while costing 32 KiB per stats instance.
const RESERVOIR_CAP: usize = 4096;

/// Lazily rebuilt sorted view of the reservoir (interior state of
/// [`LatencyStats`]; callers never see it).
#[derive(Debug, Clone, Default)]
struct SortedView {
    us: Vec<u64>,
    dirty: bool,
}

/// Collects durations; reports mean / percentiles / throughput.
///
/// Recording takes `&mut self` and stays amortized O(1); every accessor
/// (including percentiles) takes `&self`.
#[derive(Debug, Clone)]
pub struct LatencyStats {
    /// reservoir of at most [`RESERVOIR_CAP`] samples
    samples_us: Vec<u64>,
    /// sorted copy of the reservoir, rebuilt lazily when `dirty`
    sorted: RefCell<SortedView>,
    /// total samples ever recorded (not just retained)
    count: u64,
    sum_us: u128,
    min_us: u64,
    max_us: u64,
    /// deterministic replacement stream: stats stay reproducible for a
    /// given record sequence (no ambient randomness)
    rng: SplitMix64,
}

impl Default for LatencyStats {
    fn default() -> Self {
        LatencyStats {
            samples_us: Vec::new(),
            sorted: RefCell::new(SortedView::default()),
            count: 0,
            sum_us: 0,
            min_us: u64::MAX,
            max_us: 0,
            rng: SplitMix64::new(0x1A7E_11C7_57A7_5EED),
        }
    }
}

/// An immutable point-in-time summary of a [`LatencyStats`] — plain
/// `Copy` data, no interior cell, safe to ship across threads or embed in
/// a stats struct. Produced by [`LatencyStats::freeze`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LatencySnapshot {
    /// total samples recorded (not just the reservoir-retained subset)
    pub count: u64,
    /// exact mean over all recorded samples
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    /// exact minimum over all recorded samples
    pub min: Duration,
    /// exact maximum over all recorded samples
    pub max: Duration,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        self.count += 1;
        self.sum_us += us as u128;
        self.min_us = self.min_us.min(us);
        self.max_us = self.max_us.max(us);
        if self.samples_us.len() < RESERVOIR_CAP {
            self.samples_us.push(us);
            self.sorted.get_mut().dirty = true;
        } else {
            // Algorithm R: sample i (0-based i = count-1) replaces a
            // random reservoir slot with probability CAP / count
            let j = (self.rng.next_u64() % self.count) as usize;
            if j < RESERVOIR_CAP {
                self.samples_us[j] = us;
                self.sorted.get_mut().dirty = true;
            }
        }
    }

    /// Total samples recorded (not just the ≤ `RESERVOIR_CAP` retained).
    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact mean over **all** recorded samples.
    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros((self.sum_us / self.count as u128) as u64)
    }

    /// q ∈ [0, 1]; nearest-rank percentile over the reservoir (exact
    /// while ≤ [`RESERVOIR_CAP`] samples have been recorded).
    pub fn percentile(&self, q: f64) -> Duration {
        if self.samples_us.is_empty() {
            return Duration::ZERO;
        }
        let mut view = self.sorted.borrow_mut();
        if view.dirty {
            view.us.clone_from(&self.samples_us);
            view.us.sort_unstable();
            view.dirty = false;
        }
        let n = view.us.len();
        let idx = ((q * n as f64).ceil() as usize).clamp(1, n) - 1;
        Duration::from_micros(view.us[idx])
    }

    pub fn p50(&self) -> Duration {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> Duration {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> Duration {
        self.percentile(0.99)
    }

    /// Exact minimum over all recorded samples.
    pub fn min(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.min_us)
    }

    /// Exact maximum over all recorded samples.
    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Capture an immutable [`LatencySnapshot`] (one sort at most, then
    /// plain `Copy` reads). This is what stats snapshots and the
    /// `/metrics` renderer embed.
    pub fn freeze(&self) -> LatencySnapshot {
        LatencySnapshot {
            count: self.count,
            mean: self.mean(),
            p50: self.p50(),
            p95: self.p95(),
            p99: self.p99(),
            min: self.min(),
            max: self.max(),
        }
    }

    /// items/sec given total wall-clock time.
    pub fn throughput(items: usize, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        items as f64 / wall.as_secs_f64()
    }

    pub fn summary(&self, label: &str) -> String {
        format!(
            "{label}: n={} mean={:.1}ms p50={:.1}ms p95={:.1}ms p99={:.1}ms max={:.1}ms",
            self.len(),
            self.mean().as_secs_f64() * 1e3,
            self.p50().as_secs_f64() * 1e3,
            self.p95().as_secs_f64() * 1e3,
            self.p99().as_secs_f64() * 1e3,
            self.max().as_secs_f64() * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut s = LatencyStats::new();
        for i in 1..=100u64 {
            s.record(Duration::from_micros(i * 100));
        }
        assert!(s.p50() <= s.p95());
        assert!(s.p95() <= s.p99());
        assert!(s.p99() <= s.max());
        assert_eq!(s.p50(), Duration::from_micros(5000));
        assert_eq!(s.min(), Duration::from_micros(100));
    }

    #[test]
    fn mean_correct() {
        let mut s = LatencyStats::new();
        s.record(Duration::from_micros(100));
        s.record(Duration::from_micros(300));
        assert_eq!(s.mean(), Duration::from_micros(200));
    }

    #[test]
    fn empty_is_zero() {
        let s = LatencyStats::new();
        assert_eq!(s.mean(), Duration::ZERO);
        assert_eq!(s.p95(), Duration::ZERO);
        assert_eq!(s.min(), Duration::ZERO);
        assert_eq!(s.freeze(), LatencySnapshot::default());
    }

    #[test]
    fn throughput_math() {
        let t = LatencyStats::throughput(50, Duration::from_secs(2));
        assert!((t - 25.0).abs() < 1e-12);
    }

    #[test]
    fn reservoir_stays_bounded_with_exact_extremes_and_mean() {
        let mut s = LatencyStats::new();
        // 3 × capacity samples: 1..=3·CAP µs
        let n = (RESERVOIR_CAP * 3) as u64;
        for i in 1..=n {
            s.record(Duration::from_micros(i));
        }
        assert_eq!(s.len(), n as usize, "count is exact");
        assert_eq!(s.samples_us.len(), RESERVOIR_CAP, "memory is bounded");
        assert_eq!(s.min(), Duration::from_micros(1), "min is exact, not sampled");
        assert_eq!(s.max(), Duration::from_micros(n), "max is exact, not sampled");
        assert_eq!(s.mean(), Duration::from_micros((n + 1) / 2));
        // the subsampled median of a uniform ramp stays near the middle
        let p50 = s.p50().as_micros() as f64;
        let mid = n as f64 / 2.0;
        assert!(
            (p50 - mid).abs() < mid * 0.10,
            "reservoir median {p50} strayed from {mid}"
        );
        // percentile caching: repeated queries agree without re-recording
        assert_eq!(s.p95(), s.p95());
    }

    #[test]
    fn cached_sort_invalidates_on_record() {
        let mut s = LatencyStats::new();
        s.record(Duration::from_micros(100));
        assert_eq!(s.p99(), Duration::from_micros(100));
        s.record(Duration::from_micros(900));
        assert_eq!(s.p99(), Duration::from_micros(900), "new sample visible");
        s.record(Duration::from_micros(50));
        assert_eq!(s.p50(), Duration::from_micros(100));
    }

    #[test]
    fn shared_reference_percentiles_need_no_mut() {
        let mut s = LatencyStats::new();
        for i in 1..=10u64 {
            s.record(Duration::from_micros(i));
        }
        // the whole read API works through &LatencyStats
        let r: &LatencyStats = &s;
        assert_eq!(r.p50(), Duration::from_micros(5));
        assert_eq!(r.percentile(1.0), Duration::from_micros(10));
        let _ = r.summary("ro");
    }

    #[test]
    fn freeze_matches_live_accessors_and_stays_fixed() {
        let mut s = LatencyStats::new();
        for i in 1..=100u64 {
            s.record(Duration::from_micros(i));
        }
        let snap = s.freeze();
        assert_eq!(snap.count, 100);
        assert_eq!(snap.p50, s.p50());
        assert_eq!(snap.p95, s.p95());
        assert_eq!(snap.mean, s.mean());
        s.record(Duration::from_micros(10_000));
        assert_eq!(snap.max, Duration::from_micros(100), "snapshot is immutable");
        assert_eq!(s.max(), Duration::from_micros(10_000));
    }

    #[test]
    fn clone_carries_the_cache_independently() {
        let mut s = LatencyStats::new();
        s.record(Duration::from_micros(7));
        let c = s.clone();
        s.record(Duration::from_micros(9));
        assert_eq!(c.p99(), Duration::from_micros(7));
        assert_eq!(s.p99(), Duration::from_micros(9));
    }
}
