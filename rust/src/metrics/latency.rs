//! Latency / throughput statistics for the serving benches.
//!
//! Bounded memory: samples land in a fixed-capacity **reservoir**
//! (Vitter's Algorithm R over a deterministic [`SplitMix64`] stream), so a
//! long-running server's stats stay O(capacity) instead of growing one
//! `u64` per request forever. Count, sum, min, and max are tracked
//! exactly; percentiles are computed over the reservoir — exact until
//! `RESERVOIR_CAP` samples, a uniform subsample after — from a cached
//! sorted view that is invalidated on record and rebuilt at most once per
//! run of percentile queries (the old code cloned and re-sorted the full
//! history on *every* percentile call; `summary()` did it four times).
//!
//! Percentile accessors take `&self`: the sorted view lives behind a
//! `RefCell`, so read paths (stats snapshots, the `/metrics` scrape, the
//! benches' report tables) never need a mutable borrow or a
//! clone-and-sort. `LatencyStats` stays `Send` (each server thread owns
//! its own instance); it is not `Sync`, which nothing relies on — shards
//! answer stats requests from their own thread. [`LatencyStats::freeze`]
//! captures an immutable [`LatencySnapshot`] for callers that want plain
//! `Copy` data with no cell at all.

use std::cell::RefCell;
use std::time::Duration;

use crate::schedule::SplitMix64;

/// Reservoir capacity. Nearest-rank percentiles up to p99 need ~100
/// samples for one rank of resolution; 4096 keeps p99 stable to well
/// under a rank while costing 32 KiB per stats instance. p999 needs
/// ~1000 samples for its first rank of resolution — below that it
/// degrades gracefully to the reservoir maximum.
const RESERVOIR_CAP: usize = 4096;

/// Lazily rebuilt sorted view of the reservoir (interior state of
/// [`LatencyStats`]; callers never see it).
#[derive(Debug, Clone, Default)]
struct SortedView {
    us: Vec<u64>,
    dirty: bool,
}

/// Collects durations; reports mean / percentiles / throughput.
///
/// Recording takes `&mut self` and stays amortized O(1); every accessor
/// (including percentiles) takes `&self`.
#[derive(Debug, Clone)]
pub struct LatencyStats {
    /// reservoir of at most [`RESERVOIR_CAP`] samples
    samples_us: Vec<u64>,
    /// sorted copy of the reservoir, rebuilt lazily when `dirty`
    sorted: RefCell<SortedView>,
    /// total samples ever recorded (not just retained)
    count: u64,
    sum_us: u128,
    min_us: u64,
    max_us: u64,
    /// deterministic replacement stream: stats stay reproducible for a
    /// given record sequence (no ambient randomness)
    rng: SplitMix64,
}

impl Default for LatencyStats {
    fn default() -> Self {
        LatencyStats {
            samples_us: Vec::new(),
            sorted: RefCell::new(SortedView::default()),
            count: 0,
            sum_us: 0,
            min_us: u64::MAX,
            max_us: 0,
            rng: SplitMix64::new(0x1A7E_11C7_57A7_5EED),
        }
    }
}

/// An immutable point-in-time summary of a [`LatencyStats`] — plain
/// `Copy` data, no interior cell, safe to ship across threads or embed in
/// a stats struct. Produced by [`LatencyStats::freeze`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LatencySnapshot {
    /// total samples recorded (not just the reservoir-retained subset)
    pub count: u64,
    /// exact mean over all recorded samples
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    /// tail percentile for the scenario harness' latency trajectory
    /// (`docs/scenarios.md`); resolution-limited by the reservoir below
    /// ~1000 samples, where it equals the retained maximum
    pub p999: Duration,
    /// exact minimum over all recorded samples
    pub min: Duration,
    /// exact maximum over all recorded samples
    pub max: Duration,
}

impl LatencySnapshot {
    /// Merge per-shard snapshots into one cross-shard snapshot with
    /// **pinned weighted-marker semantics** (`docs/scenarios.md`).
    ///
    /// Each input contributes five `(value, mass)` markers under an
    /// upper-endpoint convention — a marker carries the probability mass
    /// of the quantile segment it closes:
    ///
    /// ```text
    /// (p50, 0.500·count)   closes [0,     0.50 ]
    /// (p95, 0.450·count)   closes (0.50,  0.95 ]
    /// (p99, 0.040·count)   closes (0.95,  0.99 ]
    /// (p999, 0.009·count)  closes (0.99,  0.999]
    /// (max, 0.001·count)   closes (0.999, 1    ]
    /// ```
    ///
    /// The merged percentile at `q` is the smallest marker value whose
    /// cumulative mass (markers sorted by value) reaches `q·Σcount`. For
    /// a single input this reproduces its own p50/p95/p99/p999 exactly;
    /// across inputs the result is always some shard's marker value, and
    /// the true union quantile lies inside that donor's closing segment —
    /// i.e. the error is bounded by one marker segment per shard, on top
    /// of each shard's own reservoir error. `count` is exact, `mean` is
    /// count-weighted and exact, `min`/`max` are exact.
    pub fn merged(parts: &[LatencySnapshot]) -> LatencySnapshot {
        let total: u64 = parts.iter().map(|p| p.count).sum();
        if total == 0 {
            return LatencySnapshot::default();
        }
        let mut sum_us: u128 = 0;
        let mut min = Duration::MAX;
        let mut max = Duration::ZERO;
        let mut markers: Vec<(Duration, f64)> = Vec::with_capacity(parts.len() * 5);
        for p in parts.iter().filter(|p| p.count > 0) {
            sum_us += p.mean.as_micros() * p.count as u128;
            min = min.min(p.min);
            max = max.max(p.max);
            let c = p.count as f64;
            markers.push((p.p50, 0.500 * c));
            markers.push((p.p95, 0.450 * c));
            markers.push((p.p99, 0.040 * c));
            markers.push((p.p999, 0.009 * c));
            markers.push((p.max, 0.001 * c));
        }
        markers.sort_unstable_by_key(|&(d, _)| d);
        let pick = |q: f64| -> Duration {
            let target = q * total as f64;
            let mut acc = 0.0;
            for &(d, w) in &markers {
                acc += w;
                // tolerance absorbs float rounding so a single input's
                // own markers land exactly on their ranks
                if acc >= target - 1e-9 {
                    return d;
                }
            }
            markers.last().map(|&(d, _)| d).unwrap_or_default()
        };
        LatencySnapshot {
            count: total,
            mean: Duration::from_micros((sum_us / total as u128) as u64),
            p50: pick(0.50),
            p95: pick(0.95),
            p99: pick(0.99),
            p999: pick(0.999),
            min,
            max,
        }
    }
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        self.count += 1;
        self.sum_us += us as u128;
        self.min_us = self.min_us.min(us);
        self.max_us = self.max_us.max(us);
        if self.samples_us.len() < RESERVOIR_CAP {
            self.samples_us.push(us);
            self.sorted.get_mut().dirty = true;
        } else {
            // Algorithm R: sample i (0-based i = count-1) replaces a
            // random reservoir slot with probability CAP / count
            let j = (self.rng.next_u64() % self.count) as usize;
            if j < RESERVOIR_CAP {
                self.samples_us[j] = us;
                self.sorted.get_mut().dirty = true;
            }
        }
    }

    /// Total samples recorded (not just the ≤ `RESERVOIR_CAP` retained).
    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact mean over **all** recorded samples.
    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros((self.sum_us / self.count as u128) as u64)
    }

    /// q ∈ [0, 1]; nearest-rank percentile over the reservoir (exact
    /// while ≤ [`RESERVOIR_CAP`] samples have been recorded).
    pub fn percentile(&self, q: f64) -> Duration {
        if self.samples_us.is_empty() {
            return Duration::ZERO;
        }
        let mut view = self.sorted.borrow_mut();
        if view.dirty {
            view.us.clone_from(&self.samples_us);
            view.us.sort_unstable();
            view.dirty = false;
        }
        let n = view.us.len();
        let idx = ((q * n as f64).ceil() as usize).clamp(1, n) - 1;
        Duration::from_micros(view.us[idx])
    }

    pub fn p50(&self) -> Duration {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> Duration {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> Duration {
        self.percentile(0.99)
    }

    pub fn p999(&self) -> Duration {
        self.percentile(0.999)
    }

    /// Exact minimum over all recorded samples.
    pub fn min(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.min_us)
    }

    /// Exact maximum over all recorded samples.
    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Capture an immutable [`LatencySnapshot`] (one sort at most, then
    /// plain `Copy` reads). This is what stats snapshots and the
    /// `/metrics` renderer embed.
    pub fn freeze(&self) -> LatencySnapshot {
        LatencySnapshot {
            count: self.count,
            mean: self.mean(),
            p50: self.p50(),
            p95: self.p95(),
            p99: self.p99(),
            p999: self.p999(),
            min: self.min(),
            max: self.max(),
        }
    }

    /// items/sec given total wall-clock time.
    pub fn throughput(items: usize, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        items as f64 / wall.as_secs_f64()
    }

    pub fn summary(&self, label: &str) -> String {
        format!(
            "{label}: n={} mean={:.1}ms p50={:.1}ms p95={:.1}ms p99={:.1}ms max={:.1}ms",
            self.len(),
            self.mean().as_secs_f64() * 1e3,
            self.p50().as_secs_f64() * 1e3,
            self.p95().as_secs_f64() * 1e3,
            self.p99().as_secs_f64() * 1e3,
            self.max().as_secs_f64() * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut s = LatencyStats::new();
        for i in 1..=100u64 {
            s.record(Duration::from_micros(i * 100));
        }
        assert!(s.p50() <= s.p95());
        assert!(s.p95() <= s.p99());
        assert!(s.p99() <= s.max());
        assert_eq!(s.p50(), Duration::from_micros(5000));
        assert_eq!(s.min(), Duration::from_micros(100));
    }

    #[test]
    fn mean_correct() {
        let mut s = LatencyStats::new();
        s.record(Duration::from_micros(100));
        s.record(Duration::from_micros(300));
        assert_eq!(s.mean(), Duration::from_micros(200));
    }

    #[test]
    fn empty_is_zero() {
        let s = LatencyStats::new();
        assert_eq!(s.mean(), Duration::ZERO);
        assert_eq!(s.p95(), Duration::ZERO);
        assert_eq!(s.min(), Duration::ZERO);
        assert_eq!(s.freeze(), LatencySnapshot::default());
    }

    #[test]
    fn throughput_math() {
        let t = LatencyStats::throughput(50, Duration::from_secs(2));
        assert!((t - 25.0).abs() < 1e-12);
    }

    #[test]
    fn reservoir_stays_bounded_with_exact_extremes_and_mean() {
        let mut s = LatencyStats::new();
        // 3 × capacity samples: 1..=3·CAP µs
        let n = (RESERVOIR_CAP * 3) as u64;
        for i in 1..=n {
            s.record(Duration::from_micros(i));
        }
        assert_eq!(s.len(), n as usize, "count is exact");
        assert_eq!(s.samples_us.len(), RESERVOIR_CAP, "memory is bounded");
        assert_eq!(s.min(), Duration::from_micros(1), "min is exact, not sampled");
        assert_eq!(s.max(), Duration::from_micros(n), "max is exact, not sampled");
        assert_eq!(s.mean(), Duration::from_micros((n + 1) / 2));
        // the subsampled median of a uniform ramp stays near the middle
        let p50 = s.p50().as_micros() as f64;
        let mid = n as f64 / 2.0;
        assert!(
            (p50 - mid).abs() < mid * 0.10,
            "reservoir median {p50} strayed from {mid}"
        );
        // percentile caching: repeated queries agree without re-recording
        assert_eq!(s.p95(), s.p95());
    }

    #[test]
    fn cached_sort_invalidates_on_record() {
        let mut s = LatencyStats::new();
        s.record(Duration::from_micros(100));
        assert_eq!(s.p99(), Duration::from_micros(100));
        s.record(Duration::from_micros(900));
        assert_eq!(s.p99(), Duration::from_micros(900), "new sample visible");
        s.record(Duration::from_micros(50));
        assert_eq!(s.p50(), Duration::from_micros(100));
    }

    #[test]
    fn shared_reference_percentiles_need_no_mut() {
        let mut s = LatencyStats::new();
        for i in 1..=10u64 {
            s.record(Duration::from_micros(i));
        }
        // the whole read API works through &LatencyStats
        let r: &LatencyStats = &s;
        assert_eq!(r.p50(), Duration::from_micros(5));
        assert_eq!(r.percentile(1.0), Duration::from_micros(10));
        let _ = r.summary("ro");
    }

    #[test]
    fn freeze_matches_live_accessors_and_stays_fixed() {
        let mut s = LatencyStats::new();
        for i in 1..=100u64 {
            s.record(Duration::from_micros(i));
        }
        let snap = s.freeze();
        assert_eq!(snap.count, 100);
        assert_eq!(snap.p50, s.p50());
        assert_eq!(snap.p95, s.p95());
        assert_eq!(snap.mean, s.mean());
        s.record(Duration::from_micros(10_000));
        assert_eq!(snap.max, Duration::from_micros(100), "snapshot is immutable");
        assert_eq!(s.max(), Duration::from_micros(10_000));
    }

    #[test]
    fn p999_resolves_past_p99_with_enough_samples() {
        let mut s = LatencyStats::new();
        for i in 1..=2000u64 {
            s.record(Duration::from_micros(i));
        }
        assert_eq!(s.p99(), Duration::from_micros(1980));
        assert_eq!(s.p999(), Duration::from_micros(1998));
        assert!(s.p999() <= s.max());
        let snap = s.freeze();
        assert_eq!(snap.p999, s.p999());
    }

    #[test]
    fn p999_degrades_to_retained_max_on_few_samples() {
        let mut s = LatencyStats::new();
        for i in 1..=10u64 {
            s.record(Duration::from_micros(i));
        }
        assert_eq!(s.p999(), Duration::from_micros(10));
    }

    #[test]
    fn merged_single_input_is_exact() {
        let mut s = LatencyStats::new();
        for i in 1..=2000u64 {
            s.record(Duration::from_micros(i * 3));
        }
        let snap = s.freeze();
        let m = LatencySnapshot::merged(&[snap]);
        assert_eq!(m, snap, "one-shard merge must be the identity");
    }

    #[test]
    fn merged_empty_and_zero_count_inputs() {
        assert_eq!(LatencySnapshot::merged(&[]), LatencySnapshot::default());
        let mut s = LatencyStats::new();
        s.record(Duration::from_micros(500));
        let snap = s.freeze();
        let m = LatencySnapshot::merged(&[LatencySnapshot::default(), snap]);
        assert_eq!(m.count, 1);
        assert_eq!(m.p50, Duration::from_micros(500));
        assert_eq!(m.min, Duration::from_micros(500));
    }

    #[test]
    fn merged_disjoint_shards_split_at_the_weight_boundary() {
        // shard A: 1000 samples at 1ms; shard B: 1000 samples at 100ms.
        // Union ground truth: p50 = 1ms (rank 1000 of 2000), p95/p99/p999
        // all 100ms.
        let mut a = LatencyStats::new();
        let mut b = LatencyStats::new();
        for _ in 0..1000 {
            a.record(Duration::from_millis(1));
            b.record(Duration::from_millis(100));
        }
        let m = LatencySnapshot::merged(&[a.freeze(), b.freeze()]);
        assert_eq!(m.count, 2000);
        assert_eq!(m.p50, Duration::from_millis(1));
        assert_eq!(m.p95, Duration::from_millis(100));
        assert_eq!(m.p99, Duration::from_millis(100));
        assert_eq!(m.p999, Duration::from_millis(100));
        assert_eq!(m.min, Duration::from_millis(1));
        assert_eq!(m.max, Duration::from_millis(100));
        assert_eq!(m.mean, Duration::from_micros(50_500));
    }

    #[test]
    fn merged_tracks_ground_truth_union_within_marker_tolerance() {
        // Three shards over different ranges of one uniform ramp; compare
        // the weighted-marker merge against exact nearest-rank percentiles
        // over the union of every recorded duration. All counts stay below
        // RESERVOIR_CAP so per-shard snapshots are reservoir-exact and the
        // only error is the documented marker-segment band.
        let ranges: [(u64, u64); 3] = [(1, 1200), (1201, 2400), (2401, 3600)];
        let mut union: Vec<u64> = Vec::new();
        let mut parts = Vec::new();
        for (lo, hi) in ranges {
            let mut s = LatencyStats::new();
            for v in lo..=hi {
                s.record(Duration::from_micros(v));
                union.push(v);
            }
            parts.push(s.freeze());
        }
        union.sort_unstable();
        let truth = |q: f64| -> u64 {
            let n = union.len();
            union[((q * n as f64).ceil() as usize).clamp(1, n) - 1]
        };
        let m = LatencySnapshot::merged(&parts);
        assert_eq!(m.count, union.len() as u64);
        assert_eq!(m.min.as_micros() as u64, 1);
        assert_eq!(m.max.as_micros() as u64, 3600);
        // documented tolerance: the merged value is some shard's marker and
        // the true union quantile lies inside that marker's closing segment
        // — for this union (three equal shards covering disjoint thirds of
        // a ramp) every segment spans < 50% of one shard's range.
        for (q, got, band) in
            [(0.50, m.p50, 600), (0.95, m.p95, 600), (0.99, m.p99, 150), (0.999, m.p999, 150)]
        {
            let got = got.as_micros() as i64;
            let want = truth(q) as i64;
            assert!(
                (got - want).abs() <= band,
                "q={q}: merged {got}µs vs truth {want}µs (band {band}µs)"
            );
        }
        // and the pinned headline property: ordering is preserved
        assert!(m.p50 <= m.p95 && m.p95 <= m.p99 && m.p99 <= m.p999 && m.p999 <= m.max);
    }

    #[test]
    fn clone_carries_the_cache_independently() {
        let mut s = LatencyStats::new();
        s.record(Duration::from_micros(7));
        let c = s.clone();
        s.record(Duration::from_micros(9));
        assert_eq!(c.p99(), Duration::from_micros(7));
        assert_eq!(s.p99(), Duration::from_micros(9));
    }
}
