//! BLEU-4 (Papineni et al. 2002) — the paper's translation metric.
//!
//! Corpus BLEU with the standard brevity penalty and (for sentence-level
//! diagnostics) exponential smoothing of empty n-gram counts. Scores are
//! reported on the 0–100 scale like the paper's tables.

use std::collections::HashMap;

const MAX_N: usize = 4;

fn ngram_counts<'a>(tokens: &[&'a str], n: usize) -> HashMap<Vec<&'a str>, usize> {
    let mut m = HashMap::new();
    if tokens.len() >= n {
        for w in tokens.windows(n) {
            *m.entry(w.to_vec()).or_insert(0) += 1;
        }
    }
    m
}

/// Clipped n-gram matches + candidate total for one sentence at order n.
fn clipped_matches(cand: &[&str], refs: &[Vec<&str>], n: usize) -> (usize, usize) {
    let cc = ngram_counts(cand, n);
    let total: usize = cc.values().sum();
    let mut matched = 0usize;
    for (gram, &count) in &cc {
        let max_ref = refs
            .iter()
            .map(|r| ngram_counts(r, n).get(gram).copied().unwrap_or(0))
            .max()
            .unwrap_or(0);
        matched += count.min(max_ref);
    }
    (matched, total)
}

/// Corpus BLEU-4 over (candidate, references) pairs; 0–100.
pub fn corpus_bleu(cands: &[Vec<&str>], refs: &[Vec<Vec<&str>>]) -> f64 {
    assert_eq!(cands.len(), refs.len());
    let mut matched = [0usize; MAX_N];
    let mut totals = [0usize; MAX_N];
    let mut cand_len = 0usize;
    let mut ref_len = 0usize;
    for (c, rs) in cands.iter().zip(refs) {
        cand_len += c.len();
        // closest reference length (standard BLEU tie-break: shorter)
        ref_len += rs
            .iter()
            .map(|r| r.len())
            .min_by_key(|&l| (l.abs_diff(c.len()), l))
            .unwrap_or(0);
        for n in 1..=MAX_N {
            let (m, t) = clipped_matches(c, rs, n);
            matched[n - 1] += m;
            totals[n - 1] += t;
        }
    }
    bleu_from_stats(&matched, &totals, cand_len, ref_len, false)
}

/// Sentence BLEU with exp smoothing (useful for Figure 2's trajectory).
pub fn sentence_bleu(cand: &[&str], refs: &[Vec<&str>]) -> f64 {
    let mut matched = [0usize; MAX_N];
    let mut totals = [0usize; MAX_N];
    for n in 1..=MAX_N {
        let (m, t) = clipped_matches(cand, refs, n);
        matched[n - 1] = m;
        totals[n - 1] = t;
    }
    let ref_len = refs
        .iter()
        .map(|r| r.len())
        .min_by_key(|&l| (l.abs_diff(cand.len()), l))
        .unwrap_or(0);
    bleu_from_stats(&matched, &totals, cand.len(), ref_len, true)
}

fn bleu_from_stats(
    matched: &[usize; MAX_N],
    totals: &[usize; MAX_N],
    cand_len: usize,
    ref_len: usize,
    smooth: bool,
) -> f64 {
    if cand_len == 0 {
        return 0.0;
    }
    let mut log_p = 0.0f64;
    let mut smooth_inv = 1.0f64;
    for n in 0..MAX_N {
        let (m, t) = (matched[n] as f64, totals[n] as f64);
        let p = if totals[n] == 0 {
            if smooth {
                // no n-grams of this order at all: skip (short sentences)
                continue;
            }
            return 0.0;
        } else if matched[n] == 0 {
            if smooth {
                smooth_inv *= 2.0;
                1.0 / (smooth_inv * t) // exp smoothing (chencherry method 3-ish)
            } else {
                return 0.0;
            }
        } else {
            m / t
        };
        log_p += p.ln() / MAX_N as f64;
    }
    let bp = if cand_len >= ref_len {
        1.0
    } else {
        (1.0 - ref_len as f64 / cand_len as f64).exp()
    };
    100.0 * bp * log_p.exp()
}

/// Convenience: BLEU over whitespace-tokenized strings, one ref each.
pub fn corpus_bleu_str(cands: &[String], refs: &[String]) -> f64 {
    let c: Vec<Vec<&str>> = cands.iter().map(|s| s.split_whitespace().collect()).collect();
    let r: Vec<Vec<Vec<&str>>> = refs
        .iter()
        .map(|s| vec![s.split_whitespace().collect()])
        .collect();
    corpus_bleu(&c, &r)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<&str> {
        s.split_whitespace().collect()
    }

    #[test]
    fn perfect_match_is_100() {
        let c = vec![toks("the quick fox crosses the river today ok")];
        let r = vec![vec![toks("the quick fox crosses the river today ok")]];
        assert!((corpus_bleu(&c, &r) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn disjoint_is_zero() {
        let c = vec![toks("a b c d e")];
        let r = vec![vec![toks("v w x y z")]];
        assert_eq!(corpus_bleu(&c, &r), 0.0);
    }

    #[test]
    fn partial_overlap_between() {
        let c = vec![toks("the quick fox crosses a road")];
        let r = vec![vec![toks("the quick fox crosses the river")]];
        let b = corpus_bleu(&c, &r);
        assert!(b > 0.0 && b < 100.0, "{b}");
    }

    #[test]
    fn clipping_penalizes_repetition() {
        // "the the the ..." must not get unigram credit beyond ref count
        let c = vec![toks("the the the the the the")];
        let r = vec![vec![toks("the cat sat on the mat")]];
        let b = corpus_bleu(&c, &r);
        assert_eq!(b, 0.0); // no bigram matches → 0 without smoothing
        let sb = sentence_bleu(&toks("the the the the the the"), &[toks("the cat sat on the mat")]);
        assert!(sb < 15.0);
    }

    #[test]
    fn brevity_penalty_hurts_short_candidates() {
        let full = corpus_bleu(
            &[toks("a b c d e f g h")],
            &[vec![toks("a b c d e f g h")]],
        );
        let short = corpus_bleu(&[toks("a b c d")], &[vec![toks("a b c d e f g h")]]);
        assert!(short < full);
        assert!(short < 60.0);
    }

    #[test]
    fn multi_reference_takes_best() {
        let c = vec![toks("the small fox sings a song")];
        let single = corpus_bleu(&c, &[vec![toks("a large dog eats the bone")]]);
        let multi = corpus_bleu(
            &c,
            &[vec![
                toks("a large dog eats the bone"),
                toks("the small fox sings a song"),
            ]],
        );
        assert!(multi > single);
        assert!((multi - 100.0).abs() < 1e-9);
    }

    #[test]
    fn corpus_is_not_mean_of_sentences() {
        // corpus BLEU pools statistics — a known property worth pinning
        let c = vec![toks("a b c d e"), toks("v w x y z")];
        let r = vec![vec![toks("a b c d e")], vec![toks("a b c q q")]];
        let pooled = corpus_bleu(&c, &r);
        assert!(pooled > 0.0 && pooled < 100.0);
    }

    #[test]
    fn str_helper_agrees() {
        let b1 = corpus_bleu_str(
            &["the quick fox crosses a river".into()],
            &["the quick fox crosses a river".into()],
        );
        assert!((b1 - 100.0).abs() < 1e-9);
    }

    #[test]
    fn empty_candidate_is_zero() {
        assert_eq!(corpus_bleu(&[vec![]], &[vec![toks("a b")]]), 0.0);
    }
}
