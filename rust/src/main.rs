//! dndm — CLI for the DNDM serving stack.
//!
//! Subcommands:
//!   inspect                         list models in artifacts/
//!   generate   --model M [...]      unconditional generation
//!   translate  --dataset D [...]    translate the synthetic test split + BLEU
//!   serve      [...]                run the server against a synthetic workload
//!   nfe        --steps T --n N      print E|𝒯| (Theorem D.1) per 𝒟_τ
//!
//! Common flags: --artifacts PATH (default: artifacts), --sampler NAME,
//! --steps T, --batch B, --seed S, --spec exact:cosine_sq | beta:15:7,
//! --order random|l2r|r2l, --temperature X, --count N.

use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use dndm::coordinator::{BatchPolicy, Engine, GenRequest, SchedPolicy, ServeBuilder};
use dndm::data::{gen_pairs, Dataset, Split};
use dndm::metrics::bleu::corpus_bleu_str;
use dndm::runtime::Artifacts;
use dndm::sampler::{SamplerConfig, SamplerKind};
use dndm::schedule::{TransitionOrder, TransitionSpec};
use dndm::util::args::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let r = match cmd {
        "inspect" => inspect(&args),
        "generate" => generate(&args),
        "translate" => translate(&args),
        "serve" => serve(&args),
        "nfe" => nfe(&args),
        "validate" => validate(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "dndm — Discrete Non-Markov Diffusion Model serving stack\n\n\
         USAGE: dndm <inspect|validate|generate|translate|serve|nfe> [flags]\n\n\
         inspect    --artifacts PATH\n\
         generate   --model NAME --sampler dndm --steps 50 --batch 4 --count 4 --seed 0\n\
         translate  --dataset iwslt14 --kind absorbing --sampler dndm-k --steps 50 --count 64\n\
         serve      --dataset iwslt14 --kind absorbing --requests 64 --max-batch 16 --window-ms 20\n\
                    [--shards N] [--fixed]   (continuous NFE-aligned scheduling by default)\n\
                    [--listen ADDR [--mock]] serve HTTP/1.1 + SSE instead of the synthetic\n\
                    workload: POST /v1/generate, GET /metrics, GET /healthz (docs/http.md);\n\
                    [--rate-burst N --rate-per-sec X | --no-rate-limit] [--us-per-nfe X]\n\
                    [--board-pace] project from the engine-measured µs/NFE boards\n\
         nfe        --steps 1000 --n 16 --spec beta:15:7\n\n\
         common flags: --artifacts PATH  --spec exact:cosine_sq|beta:A:B\n\
                       --order random|l2r|r2l  --temperature X  --seed N\n\
                       --sampler dndm|dndm-v2|dndm-k|dndm-c|d3pm|rdm|rdm-k|mask-predict"
    );
}

fn sampler_config(args: &Args) -> Result<SamplerConfig> {
    let kind = SamplerKind::parse(args.get_or("sampler", "dndm"))
        .ok_or_else(|| anyhow!("unknown sampler"))?;
    let mut cfg = SamplerConfig::new(kind, args.usize_or("steps", 50));
    if let Some(spec) = args.get("spec") {
        cfg.spec = TransitionSpec::parse(spec).ok_or_else(|| anyhow!("bad --spec"))?;
    }
    cfg.order = match args.get_or("order", "random") {
        "random" => TransitionOrder::Random,
        "l2r" => TransitionOrder::LeftToRight,
        "r2l" => TransitionOrder::RightToLeft,
        o => bail!("bad --order {o}"),
    };
    cfg.temperature = args.f64_or("temperature", 0.0) as f32;
    if args.has("trace") {
        cfg = cfg.with_trace();
    }
    Ok(cfg)
}

fn load_artifacts(args: &Args) -> Result<Artifacts> {
    Artifacts::load(args.get_or("artifacts", "artifacts"))
}

fn inspect(args: &Args) -> Result<()> {
    let arts = load_artifacts(args)?;
    println!("artifacts root : {:?}", arts.root);
    println!("batch buckets  : {:?}", arts.buckets);
    println!("{:<28} {:>11} {:>8} {:>9}  dataset", "model", "kind", "params", "tensors");
    for m in &arts.models {
        println!(
            "{:<28} {:>11} {:>8} {:>9}  {}{}",
            m.name,
            m.kind,
            m.n_params,
            m.n_tensors,
            m.dataset,
            if m.continuous { " (continuous-trained)" } else { "" }
        );
    }
    println!("transition kernels: {:?}", arts.transition.keys().collect::<Vec<_>>());
    Ok(())
}

fn model_for(args: &Args, arts: &Artifacts) -> Result<String> {
    if let Some(m) = args.get("model") {
        return Ok(m.to_string());
    }
    let ds = Dataset::parse(args.get_or("dataset", "iwslt14"))
        .ok_or_else(|| anyhow!("bad --dataset"))?;
    let kind = args.get_or("kind", "absorbing");
    let continuous = args.has("continuous");
    arts.find(kind, ds.name(), continuous)
        .map(|m| m.name.clone())
        .ok_or_else(|| anyhow!("no model for {kind}/{}", ds.name()))
}

fn generate(args: &Args) -> Result<()> {
    let arts = load_artifacts(args)?;
    let model = args
        .get("model")
        .ok_or_else(|| anyhow!("--model required (see `dndm inspect`)"))?;
    let eng = Engine::new(&arts, model)?;
    let cfg = sampler_config(args)?;
    let count = args.usize_or("count", 4);
    let batch = args.usize_or("batch", count.min(4));
    let seed = args.u64_or("seed", 0);

    let t0 = Instant::now();
    let mut done = 0usize;
    while done < count {
        let b = batch.min(count - done);
        let (outs, res) = eng.generate_batch(None, b, &cfg, seed + done as u64)?;
        for o in outs {
            println!("[nfe={:>3}] {}", res.nfe, o.text);
        }
        done += b;
    }
    println!(
        "generated {count} sequences in {:.2}s (avg NFE {:.1})",
        t0.elapsed().as_secs_f64(),
        eng.nfe.avg_nfe()
    );
    Ok(())
}

fn translate(args: &Args) -> Result<()> {
    let arts = load_artifacts(args)?;
    let ds = Dataset::parse(args.get_or("dataset", "iwslt14"))
        .ok_or_else(|| anyhow!("bad --dataset"))?;
    let model = model_for(args, &arts)?;
    let eng = Engine::new(&arts, &model)?;
    let cfg = sampler_config(args)?;
    let count = args.usize_or("count", 64);
    let batch = args.usize_or("batch", 16);
    let seed = args.u64_or("seed", 0);
    let verbose = args.has("verbose");

    let pairs = gen_pairs(ds, Split::Test, count);
    let mut hyps = Vec::with_capacity(count);
    let mut refs = Vec::with_capacity(count);
    let t0 = Instant::now();
    for chunk in pairs.chunks(batch) {
        let srcs: Vec<String> = chunk.iter().map(|(s, _)| s.join(" ")).collect();
        let (outs, _) = eng.generate_batch(Some(&srcs), srcs.len(), &cfg, seed)?;
        for ((src, tgt), out) in chunk.iter().zip(outs) {
            if verbose {
                println!("SRC {}\nREF {}\nHYP {}\n", src.join(" "), tgt.join(" "), out.text);
            }
            hyps.push(out.text);
            refs.push(tgt.join(" "));
        }
    }
    let elapsed = t0.elapsed();
    println!(
        "model={model} sampler={} steps={} : BLEU {:.2}  time {:.2}s  avg NFE {:.2}",
        cfg.kind.name(),
        cfg.steps,
        corpus_bleu_str(&hyps, &refs),
        elapsed.as_secs_f64(),
        eng.nfe.avg_nfe(),
    );
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    if let Some(listen) = args.get("listen") {
        let listen = listen.to_string();
        return serve_http(args, &listen);
    }
    let arts_path = args.get_or("artifacts", "artifacts").to_string();
    let arts = load_artifacts(args)?;
    let ds = Dataset::parse(args.get_or("dataset", "iwslt14"))
        .ok_or_else(|| anyhow!("bad --dataset"))?;
    let model = model_for(args, &arts)?;
    let cfg = sampler_config(args)?;
    let max_batch = args.usize_or("max-batch", 16);
    let window = std::time::Duration::from_millis(args.u64_or("window-ms", 20));
    let shards = args.usize_or("shards", 1);
    let fixed = args.has("fixed");
    let n_requests = args.usize_or("requests", 64);

    println!(
        "starting {} server: model={model} sampler={} max_batch={max_batch} \
         window={window:?} shards={shards}",
        if fixed { "fixed-batch" } else { "continuous" },
        cfg.kind.name()
    );
    let model2 = model.clone();
    let factory = move || {
        let arts = Artifacts::load(&arts_path)?;
        let eng = Engine::new(&arts, &model2)?;
        eng.warmup(&[1, 4, 16])?;
        Ok(eng)
    };
    let builder = ServeBuilder::new(factory, cfg).shards(shards);
    let router = if fixed {
        builder.fixed(BatchPolicy { max_batch, window }).start()
    } else {
        builder
            .continuous(SchedPolicy { max_batch, window, shared_tau_groups: true })
            .start()
    };

    // synthetic client load: the test split as concurrent requests
    let pairs = gen_pairs(ds, Split::Test, n_requests);
    let t0 = Instant::now();
    let tickets: Vec<_> = pairs
        .iter()
        .enumerate()
        .map(|(i, (s, _))| {
            router.submit_request(GenRequest::new(i as u64).src(s.join(" "))).unwrap()
        })
        .collect();
    let mut hyps = Vec::new();
    for t in tickets {
        hyps.push(t.wait()?.text);
    }
    let wall = t0.elapsed();
    let refs: Vec<String> = pairs.iter().map(|(_, t)| t.join(" ")).collect();
    let stats = router.stats()?;
    println!(
        "served {} requests in {:.2}s ({:.1} req/s)\n  batches {} (mean size {:.1})  NN calls {}\n  \
         queue p95 {:.1}ms  e2e p50 {:.1}ms  p95 {:.1}ms  p99 {:.1}ms\n  \
         cancelled {}  deadline-exceeded {}\n  BLEU {:.2}",
        n_requests,
        wall.as_secs_f64(),
        n_requests as f64 / wall.as_secs_f64(),
        stats.batches,
        stats.mean_batch,
        stats.nn_calls,
        stats.queue_p95.as_secs_f64() * 1e3,
        stats.e2e_p50.as_secs_f64() * 1e3,
        stats.e2e_p95.as_secs_f64() * 1e3,
        stats.e2e_p99.as_secs_f64() * 1e3,
        stats.cancelled,
        stats.deadline_exceeded,
        corpus_bleu_str(&hyps, &refs),
    );
    router.shutdown();
    router.join();
    Ok(())
}

/// `serve --listen ADDR`: the network front door — HTTP/1.1 + SSE over
/// the same router, with exact-cost admission control (`docs/http.md`).
/// `--mock` serves the artifact-free cipher mock; otherwise the model is
/// resolved exactly like the synthetic-workload path. Runs until killed.
fn serve_http(args: &Args, listen: &str) -> Result<()> {
    use dndm::net::{self, AdmissionPolicy, HttpOptions, RateLimit};
    use dndm::runtime::Denoiser;

    let cfg = sampler_config(args)?;
    let max_batch = args.usize_or("max-batch", 16);
    let window = std::time::Duration::from_millis(args.u64_or("window-ms", 20));
    let shards = args.usize_or("shards", 1);
    // per-request lanes: admission's host-side |𝒯| equals each request's
    // served NFE exactly (shared lanes would re-seed from the group head)
    let policy = SchedPolicy { max_batch, window, shared_tau_groups: false };

    let (router, mcfg, model) = if args.has("mock") {
        let seq_len = args.usize_or("seq-len", 16);
        let mcfg = dndm::coordinator::cipher_mock_denoiser(seq_len).config().clone();
        let factory = move || Ok(dndm::coordinator::cipher_mock_engine(seq_len));
        let router =
            ServeBuilder::new(factory, cfg.clone()).shards(shards).continuous(policy).start();
        (router, mcfg, "cipher-mock".to_string())
    } else {
        let arts_path = args.get_or("artifacts", "artifacts").to_string();
        let arts = load_artifacts(args)?;
        let model = model_for(args, &arts)?;
        let manifest = arts
            .models
            .iter()
            .find(|m| m.name == model)
            .ok_or_else(|| anyhow!("model {model} not in manifest"))?;
        let mcfg = arts.config(manifest)?;
        let model2 = model.clone();
        let factory = move || {
            let arts = Artifacts::load(&arts_path)?;
            let eng = Engine::new(&arts, &model2)?;
            eng.warmup(&[1, 4, 16])?;
            Ok(eng)
        };
        let router =
            ServeBuilder::new(factory, cfg.clone()).shards(shards).continuous(policy).start();
        (router, mcfg, model)
    };

    let admission = AdmissionPolicy {
        rate_limit: (!args.has("no-rate-limit")).then(|| RateLimit {
            burst: args.f64_or("rate-burst", 32.0),
            per_sec: args.f64_or("rate-per-sec", 16.0),
        }),
        initial_us_per_nfe: args.f64_or("us-per-nfe", 1000.0),
        ewma_alpha: 0.2,
        // engine-measured pace: the boards see every terminal, so the
        // live server's projections converge even on direct-router mixes
        use_board_pace: args.has("board-pace"),
    };
    let server = net::serve(
        listen,
        std::sync::Arc::new(router),
        mcfg,
        cfg,
        admission,
        HttpOptions::default(),
    )
    .map_err(|e| anyhow!("bind {listen}: {e}"))?;
    println!(
        "front door listening on http://{} (model={model}, shards={shards})\n  \
         POST /v1/generate   GET /metrics   GET /healthz   (docs/http.md)",
        server.local_addr()
    );
    loop {
        std::thread::park();
    }
}

/// Artifact self-check: every HLO parses+compiles, every weights file
/// matches its config's tensor order, every model answers a denoise call.
fn validate(args: &Args) -> Result<()> {
    use dndm::runtime::{ModelRuntime, WeightsFile};
    let arts = load_artifacts(args)?;
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT: {e}"))?;
    let mut failures = 0;
    for m in &arts.models {
        print!("{:<28} ", m.name);
        let check = (|| -> Result<()> {
            let wf = WeightsFile::read(&arts.root.join(&m.weights_path))?;
            if wf.total_params() != m.n_params {
                bail!("param count {} != manifest {}", wf.total_params(), m.n_params);
            }
            let rt = ModelRuntime::load(&arts, &client, &m.name)?;
            let cfg = rt.config.clone();
            let x = dndm::tensor::TokenBatch::filled(1, cfg.seq_len, cfg.noise_lo);
            let src = cfg
                .conditional()
                .then(|| dndm::tensor::TokenBatch::filled(1, cfg.src_len, cfg.noise_lo));
            let logits = dndm::runtime::Denoiser::denoise(&rt, &x, &[0.5], src.as_ref())?;
            if logits.flat().iter().any(|v| !v.is_finite()) {
                bail!("non-finite logits");
            }
            Ok(())
        })();
        match check {
            Ok(()) => println!("OK ({} params, {} buckets)", m.n_params, m.hlo.len()),
            Err(e) => {
                failures += 1;
                println!("FAIL: {e:#}");
            }
        }
    }
    if failures > 0 {
        bail!("{failures} model(s) failed validation");
    }
    println!("all {} models valid", arts.models.len());
    Ok(())
}

fn nfe(args: &Args) -> Result<()> {
    let t = args.usize_or("steps", 1000);
    let n = args.usize_or("n", 16);
    let specs = [
        TransitionSpec::Exact(dndm::schedule::AlphaSchedule::Linear),
        TransitionSpec::Exact(dndm::schedule::AlphaSchedule::Cosine),
        TransitionSpec::Exact(dndm::schedule::AlphaSchedule::CosineSq),
        TransitionSpec::Beta { a: 15.0, b: 7.0 },
    ];
    println!("T={t} N={n}  (baselines: NFE = {t})");
    for spec in specs {
        println!("  {:<18} E|𝒯| = {:.2}", spec.name(), spec.expected_nfe(t, n));
    }
    if let Some(s) = args.get("spec") {
        let spec = TransitionSpec::parse(s).ok_or_else(|| anyhow!("bad --spec"))?;
        println!("  {:<18} E|𝒯| = {:.2}", spec.name(), spec.expected_nfe(t, n));
    }
    Ok(())
}
