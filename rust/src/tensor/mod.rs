//! Flat, reusable batch tensors — the zero-copy data path between the
//! scheduler, the sampler sessions, and the denoiser.
//!
//! DNDM's cost model is |𝒯| denoiser calls, so anything the host does
//! *per call* is pure overhead on the paper's headline metric. Before this
//! module existed, every NFE boundary re-cloned every token row into a
//! `Vec<Vec<u32>>`, collected logits into a `Vec<Vec<f32>>` row by row,
//! and dropped it all on the floor one call later. The three types here
//! replace that with contiguous storage that is allocated once and reused:
//!
//! * [`TokenBatch`] — flat `u32` storage with `[B, N]` dims: cheap row
//!   views, in-place row writes, `extend_from` for gathering lanes into a
//!   batch without per-row clones, and `narrow_remove` for compacting a
//!   row out of a live batch (slot eviction) without reallocating.
//! * [`LogitsBuf`] — flat `f32` `[B, N, V]` storage the denoiser writes
//!   into (`Denoiser::denoise_into`); `reset` keeps capacity across calls.
//! * [`LogitsView`] — a borrowed, `Copy` window over a `LogitsBuf` (or any
//!   flat logits), with per-sequence/per-position slice accessors and
//!   `narrow` for handing each lane exactly its rows of a shared batch.
//!
//! Ownership rules (see `docs/perf.md`): buffers live with the outermost
//! loop — the scheduler's `StepScratch`, `session::drive`'s locals — and
//! everything below them borrows.
//!
//! Because the storage is flat and owned, these buffers also *move*
//! cheaply: when the rebalancer donates an in-flight lane to another
//! shard (`coordinator::rebalancer`, `docs/rebalancing.md`), the lane's
//! token state and pre-flattened source rows travel as whole
//! [`TokenBatch`]es — one pointer move each, no per-row repacking on
//! either side of the handoff.

/// A `[B, N]` batch of token ids in one contiguous allocation.
///
/// `cols` (N) is fixed per use; rows are appended with [`Self::push_row`]
/// / [`Self::extend_from`] and reused across calls via [`Self::reset`],
/// which clears the rows but keeps both the capacity and nothing else.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TokenBatch {
    data: Vec<u32>,
    cols: usize,
}

impl TokenBatch {
    /// Empty batch with row width `cols` (N).
    pub fn new(cols: usize) -> TokenBatch {
        TokenBatch { data: Vec::new(), cols }
    }

    /// `rows × cols` batch filled with `val`.
    pub fn filled(rows: usize, cols: usize, val: u32) -> TokenBatch {
        TokenBatch { data: vec![val; rows * cols], cols }
    }

    /// Copy a row-of-rows into flat storage. All rows must share a length.
    pub fn from_rows(rows: &[Vec<u32>]) -> TokenBatch {
        let cols = rows.first().map(|r| r.len()).unwrap_or(0);
        let mut tb = TokenBatch { data: Vec::with_capacity(rows.len() * cols), cols };
        for r in rows {
            tb.push_row(r);
        }
        tb
    }

    /// Number of rows (B).
    pub fn rows(&self) -> usize {
        if self.cols == 0 {
            0
        } else {
            self.data.len() / self.cols
        }
    }

    /// Row width (N).
    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Drop all rows and set the row width, keeping the allocation.
    pub fn reset(&mut self, cols: usize) {
        self.data.clear();
        self.cols = cols;
    }

    /// Append one row (must match the row width).
    pub fn push_row(&mut self, row: &[u32]) {
        assert_eq!(row.len(), self.cols, "row width {} != batch width {}", row.len(), self.cols);
        self.data.extend_from_slice(row);
    }

    /// Append every row of `other` (one memcpy, no per-row clones).
    pub fn extend_from(&mut self, other: &TokenBatch) {
        assert_eq!(other.cols, self.cols, "column widths differ");
        self.data.extend_from_slice(&other.data);
    }

    pub fn row(&self, i: usize) -> &[u32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [u32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, row: usize, col: usize) -> u32 {
        self.data[row * self.cols + col]
    }

    #[inline]
    pub fn set(&mut self, row: usize, col: usize, val: u32) {
        self.data[row * self.cols + col] = val;
    }

    /// Remove row `i` in place, compacting the rows above it down by one
    /// (`copy_within` + truncate — no heap traffic), so a live batch can
    /// shrink at a transition-time boundary without rebuilding. O(rows
    /// after `i`); the allocation is kept.
    pub fn narrow_remove(&mut self, i: usize) {
        let rows = self.rows();
        assert!(i < rows, "row {i} out of bounds for {rows} rows");
        let start = i * self.cols;
        self.data.copy_within(start + self.cols.., start);
        self.data.truncate((rows - 1) * self.cols);
    }

    /// The whole `[B * N]` storage, row-major.
    pub fn flat(&self) -> &[u32] {
        &self.data
    }

    /// Iterate rows as slices.
    pub fn iter_rows(&self) -> impl Iterator<Item = &[u32]> {
        self.data.chunks_exact(self.cols.max(1))
    }

    /// Convert into a row-of-rows (result materialization only — never on
    /// the per-NFE hot path).
    pub fn into_rows(self) -> Vec<Vec<u32>> {
        if self.cols == 0 {
            return Vec::new();
        }
        self.data.chunks_exact(self.cols).map(|c| c.to_vec()).collect()
    }
}

/// Owned flat `[B, N, V]` logits storage the denoiser writes into.
///
/// [`Self::reset`] re-dims and zeroes without shrinking capacity, so a
/// buffer held across NFE calls stops allocating after the first call.
#[derive(Debug, Clone, Default)]
pub struct LogitsBuf {
    data: Vec<f32>,
    n: usize,
    v: usize,
}

impl LogitsBuf {
    pub fn new() -> LogitsBuf {
        LogitsBuf::default()
    }

    /// Re-dimension to `[batch, n, v]` and zero the contents, keeping the
    /// allocation when capacity suffices. For writers that accumulate into
    /// a zeroed background (e.g. `MockDenoiser`).
    pub fn reset(&mut self, batch: usize, n: usize, v: usize) {
        self.n = n;
        self.v = v;
        self.data.clear();
        self.data.resize(batch * n * v, 0.0);
    }

    /// Re-dimension to `[batch, n, v]` **without** zeroing retained
    /// elements — for implementations that fully overwrite the buffer
    /// (`ModelRuntime` memcpys the whole `[B, N, V]` block), where the
    /// `reset` memset would be pure wasted memory traffic per NFE call.
    /// Newly grown elements are zero-filled; previously used ones keep
    /// stale values until overwritten.
    pub fn reset_for_overwrite(&mut self, batch: usize, n: usize, v: usize) {
        self.n = n;
        self.v = v;
        self.data.resize(batch * n * v, 0.0);
    }

    pub fn batch(&self) -> usize {
        let stride = self.n * self.v;
        if stride == 0 {
            0
        } else {
            self.data.len() / stride
        }
    }

    pub fn seq_len(&self) -> usize {
        self.n
    }

    pub fn vocab(&self) -> usize {
        self.v
    }

    /// Logits of sequence `i`: an `[N * V]` row-major slice.
    pub fn seq(&self, i: usize) -> &[f32] {
        let stride = self.n * self.v;
        &self.data[i * stride..(i + 1) * stride]
    }

    pub fn seq_mut(&mut self, i: usize) -> &mut [f32] {
        let stride = self.n * self.v;
        &mut self.data[i * stride..(i + 1) * stride]
    }

    /// Vocab-sized logits row of (sequence `i`, position `pos`).
    pub fn row(&self, i: usize, pos: usize) -> &[f32] {
        self.view().row(i, pos)
    }

    /// Remove sequence `i`'s `[N, V]` block in place, compacting the
    /// sequences above it down (no heap traffic, allocation kept) — the
    /// logits-side twin of [`TokenBatch::narrow_remove`]. The scheduler
    /// itself narrows *before* the denoiser call and refills logits at
    /// the new width, so this exists for callers that hold logits across
    /// an eviction (and to keep the two flat types' APIs symmetric).
    pub fn narrow_remove(&mut self, i: usize) {
        let batch = self.batch();
        assert!(i < batch, "sequence {i} out of bounds for batch {batch}");
        let stride = self.n * self.v;
        let start = i * stride;
        self.data.copy_within(start + stride.., start);
        self.data.truncate((batch - 1) * stride);
    }

    pub fn flat(&self) -> &[f32] {
        &self.data
    }

    pub fn flat_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn view(&self) -> LogitsView<'_> {
        LogitsView { data: &self.data, n: self.n, v: self.v }
    }
}

/// A borrowed `[B, N, V]` window over flat logits. `Copy`, so it threads
/// through the sampler call tree without lifetime gymnastics; `narrow`
/// hands each lane of a shared batch exactly its rows, which is how one
/// scheduler-level denoiser call feeds many sessions without copying.
#[derive(Debug, Clone, Copy)]
pub struct LogitsView<'a> {
    data: &'a [f32],
    n: usize,
    v: usize,
}

impl<'a> LogitsView<'a> {
    pub fn batch(&self) -> usize {
        let stride = self.n * self.v;
        if stride == 0 {
            0
        } else {
            self.data.len() / stride
        }
    }

    pub fn seq_len(&self) -> usize {
        self.n
    }

    pub fn vocab(&self) -> usize {
        self.v
    }

    /// Sub-batch window of `count` sequences starting at `start`.
    pub fn narrow(&self, start: usize, count: usize) -> LogitsView<'a> {
        let stride = self.n * self.v;
        LogitsView { data: &self.data[start * stride..(start + count) * stride], n: self.n, v: self.v }
    }

    /// Logits of sequence `i`: an `[N * V]` row-major slice.
    pub fn seq(&self, i: usize) -> &'a [f32] {
        let stride = self.n * self.v;
        &self.data[i * stride..(i + 1) * stride]
    }

    /// Vocab-sized logits row of (sequence `i`, position `pos`).
    #[inline]
    pub fn row(&self, i: usize, pos: usize) -> &'a [f32] {
        let start = i * self.n * self.v + pos * self.v;
        &self.data[start..start + self.v]
    }

    pub fn flat(&self) -> &'a [f32] {
        self.data
    }
}

impl<'a> From<&'a LogitsBuf> for LogitsView<'a> {
    fn from(buf: &'a LogitsBuf) -> LogitsView<'a> {
        buf.view()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_batch_rows_and_flat_agree() {
        let tb = TokenBatch::from_rows(&[vec![1, 2, 3], vec![4, 5, 6]]);
        assert_eq!(tb.rows(), 2);
        assert_eq!(tb.cols(), 3);
        assert_eq!(tb.row(1), &[4, 5, 6]);
        assert_eq!(tb.flat(), &[1, 2, 3, 4, 5, 6]);
        assert_eq!(tb.get(1, 0), 4);
        let rows: Vec<&[u32]> = tb.iter_rows().collect();
        assert_eq!(rows, vec![&[1u32, 2, 3][..], &[4, 5, 6][..]]);
        assert_eq!(tb.clone().into_rows(), vec![vec![1, 2, 3], vec![4, 5, 6]]);
    }

    #[test]
    fn token_batch_reset_keeps_capacity() {
        let mut tb = TokenBatch::new(4);
        tb.push_row(&[1, 2, 3, 4]);
        tb.push_row(&[5, 6, 7, 8]);
        let cap = tb.data.capacity();
        tb.reset(4);
        assert_eq!(tb.rows(), 0);
        assert!(tb.is_empty());
        assert_eq!(tb.data.capacity(), cap, "reset must not free");
        tb.push_row(&[9, 9, 9, 9]);
        assert_eq!(tb.row(0), &[9, 9, 9, 9]);
    }

    #[test]
    fn token_batch_set_and_row_mut_write_in_place() {
        let mut tb = TokenBatch::filled(2, 3, 7);
        tb.set(0, 1, 42);
        tb.row_mut(1)[2] = 9;
        assert_eq!(tb.row(0), &[7, 42, 7]);
        assert_eq!(tb.row(1), &[7, 7, 9]);
    }

    #[test]
    fn token_batch_extend_from_concatenates() {
        let mut a = TokenBatch::from_rows(&[vec![1, 1]]);
        let b = TokenBatch::from_rows(&[vec![2, 2], vec![3, 3]]);
        a.extend_from(&b);
        assert_eq!(a.rows(), 3);
        assert_eq!(a.row(2), &[3, 3]);
    }

    #[test]
    fn token_batch_narrow_remove_compacts_without_realloc() {
        let mut tb = TokenBatch::from_rows(&[vec![1, 1], vec![2, 2], vec![3, 3]]);
        let cap = tb.data.capacity();
        tb.narrow_remove(1);
        assert_eq!(tb.rows(), 2);
        assert_eq!(tb.row(0), &[1, 1]);
        assert_eq!(tb.row(1), &[3, 3]);
        assert_eq!(tb.data.capacity(), cap, "narrowing must not touch the heap");
        tb.narrow_remove(1); // last row
        assert_eq!(tb.rows(), 1);
        assert_eq!(tb.row(0), &[1, 1]);
        tb.narrow_remove(0); // down to empty
        assert_eq!(tb.rows(), 0);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn token_batch_narrow_remove_rejects_bad_row() {
        let mut tb = TokenBatch::from_rows(&[vec![1, 1]]);
        tb.narrow_remove(1);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn token_batch_rejects_ragged_rows() {
        let mut tb = TokenBatch::new(2);
        tb.push_row(&[1, 2, 3]);
    }

    #[test]
    fn logits_buf_reset_dims_and_zeroes() {
        let mut lb = LogitsBuf::new();
        lb.reset(2, 3, 4);
        assert_eq!(lb.batch(), 2);
        assert_eq!(lb.seq(1).len(), 12);
        lb.seq_mut(1)[0] = 5.0;
        assert_eq!(lb.row(1, 0)[0], 5.0);
        let cap = lb.data.capacity();
        lb.reset(2, 3, 4);
        assert_eq!(lb.data.capacity(), cap, "reset must not free");
        assert!(lb.flat().iter().all(|&x| x == 0.0), "reset must zero");
    }

    #[test]
    fn reset_for_overwrite_keeps_stale_data_but_redims() {
        let mut lb = LogitsBuf::new();
        lb.reset(2, 2, 2);
        lb.flat_mut().fill(7.0);
        lb.reset_for_overwrite(2, 2, 2);
        assert_eq!(lb.batch(), 2);
        assert!(lb.flat().iter().all(|&x| x == 7.0), "same size: no memset");
        lb.reset_for_overwrite(3, 2, 2);
        assert_eq!(lb.batch(), 3);
        assert!(lb.flat()[8..].iter().all(|&x| x == 0.0), "growth zero-fills");
    }

    #[test]
    fn logits_view_rows_and_narrow() {
        let mut lb = LogitsBuf::new();
        lb.reset(3, 2, 2);
        for (i, x) in lb.flat_mut().iter_mut().enumerate() {
            *x = i as f32;
        }
        let v = lb.view();
        assert_eq!(v.batch(), 3);
        assert_eq!(v.seq(1), &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(v.row(1, 1), &[6.0, 7.0]);
        let w = v.narrow(1, 2);
        assert_eq!(w.batch(), 2);
        assert_eq!(w.seq(0), v.seq(1));
        assert_eq!(w.row(1, 0), v.row(2, 0));
        // views are Copy
        let w2 = w;
        assert_eq!(w2.flat(), w.flat());
    }

    #[test]
    fn logits_buf_narrow_remove_compacts_sequences() {
        let mut lb = LogitsBuf::new();
        lb.reset(3, 2, 2);
        for (i, x) in lb.flat_mut().iter_mut().enumerate() {
            *x = i as f32;
        }
        let keep0 = lb.seq(0).to_vec();
        let keep2 = lb.seq(2).to_vec();
        let cap = lb.data.capacity();
        lb.narrow_remove(1);
        assert_eq!(lb.batch(), 2);
        assert_eq!(lb.seq(0), &keep0[..]);
        assert_eq!(lb.seq(1), &keep2[..]);
        assert_eq!(lb.data.capacity(), cap, "narrowing must not touch the heap");
    }

    #[test]
    fn logits_view_from_buf_ref() {
        let mut lb = LogitsBuf::new();
        lb.reset(1, 2, 3);
        let v: LogitsView = (&lb).into();
        assert_eq!(v.batch(), 1);
        assert_eq!(v.seq_len(), 2);
        assert_eq!(v.vocab(), 3);
    }
}
