//! Deterministic fault injection for the serving stack.
//!
//! [`ChaosDenoiser`] wraps any [`Denoiser`] and injects faults *before*
//! the inner network runs: scripted (fail on exactly the nth call, fail
//! from the nth call on, fail on specific batch widths), probabilistic
//! (seeded transient/fatal rates drawn from a [`SplitMix64`] stream), or
//! externally armed (a shared [`ChaosSwitch`] an observer thread can flip
//! mid-run). Because a faulted attempt never reaches the inner denoiser,
//! the inner call count — and therefore `Engine::nfe` — only ever counts
//! calls that actually produced logits, which is what makes the exact
//! NFE-conservation pins in `tests/chaos.rs` possible.
//!
//! Fault classification is a message convention, not a type: the vendored
//! `anyhow` has no downcast, so an error is *transient* (retryable) iff
//! some message in its `chain()` contains [`TRANSIENT_MARKER`]. The
//! injected errors follow the convention; a production backend opts its
//! own recoverable errors into retry by including the same word. Anything
//! else is fatal. See `docs/robustness.md` for the full taxonomy.

use std::sync::atomic::{AtomicU8, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::schedule::SplitMix64;
use crate::tensor::{LogitsBuf, TokenBatch};

use super::artifact::ModelConfig;
use super::denoiser::Denoiser;

/// The classification convention: an error whose `chain()` mentions this
/// substring is transient (safe to retry); everything else is fatal.
pub const TRANSIENT_MARKER: &str = "transient";

/// True iff any message in the error chain marks the fault as transient.
///
/// A denoiser call is a pure function of `(x, t, src)` — every sequence
/// samples from its own forked RNG stream and the logits buffer is fully
/// overwritten — so retrying a transient fault is byte-identical to the
/// fault never having happened.
pub fn is_transient(e: &anyhow::Error) -> bool {
    e.chain().any(|msg| msg.contains(TRANSIENT_MARKER))
}

/// Which class of fault to inject.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Recoverable: the scheduler retries the call under its `FaultPolicy`.
    Transient,
    /// Unrecoverable: no retry; the affected lane is isolated and failed.
    Fatal,
}

impl FaultKind {
    fn error(self, attempt: u64) -> anyhow::Error {
        match self {
            FaultKind::Transient => anyhow!("injected transient fault (call {attempt})"),
            FaultKind::Fatal => anyhow!("injected fatal fault (call {attempt})"),
        }
    }
}

/// A cloneable lever that arms/disarms fault injection from outside the
/// serving thread — e.g. a test that wants a shard to start failing *now*,
/// after its engine factory has long since been cloned away.
#[derive(Debug, Clone, Default)]
pub struct ChaosSwitch(Arc<AtomicU8>);

impl ChaosSwitch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Every subsequent attempt faults with `kind` until [`Self::disarm`].
    pub fn arm(&self, kind: FaultKind) {
        let v = match kind {
            FaultKind::Transient => 1,
            FaultKind::Fatal => 2,
        };
        self.0.store(v, Ordering::SeqCst);
    }

    /// Stop injecting; attempts pass through to the inner denoiser again.
    pub fn disarm(&self) {
        self.0.store(0, Ordering::SeqCst);
    }

    fn get(&self) -> Option<FaultKind> {
        match self.0.load(Ordering::SeqCst) {
            1 => Some(FaultKind::Transient),
            2 => Some(FaultKind::Fatal),
            _ => None,
        }
    }
}

struct ChaosScript {
    rng: SplitMix64,
    /// one-shot faults keyed by 1-based attempt number
    one_shot: Vec<(u64, FaultKind)>,
    /// every attempt `>= n` faults
    fail_from: Option<(u64, FaultKind)>,
}

/// Deterministic fault-injecting wrapper around any [`Denoiser`].
///
/// All decisions derive from the constructor seed and the attempt counter,
/// so a chaos run is exactly reproducible: same seed + same call sequence
/// → same faults. Faulted attempts return an error *without* invoking the
/// inner denoiser.
pub struct ChaosDenoiser<D> {
    inner: D,
    script: Mutex<ChaosScript>,
    /// total `denoise_into` attempts observed, including faulted ones
    attempts: AtomicU64,
    transient_rate: f64,
    fatal_rate: f64,
    /// fault any attempt whose batch width is in this set
    fail_widths: Vec<usize>,
    fail_widths_kind: FaultKind,
    latency: Duration,
    switch: Option<ChaosSwitch>,
}

impl<D> ChaosDenoiser<D> {
    pub fn new(inner: D, seed: u64) -> Self {
        ChaosDenoiser {
            inner,
            script: Mutex::new(ChaosScript {
                rng: SplitMix64::new(seed),
                one_shot: Vec::new(),
                fail_from: None,
            }),
            attempts: AtomicU64::new(0),
            transient_rate: 0.0,
            fatal_rate: 0.0,
            fail_widths: Vec::new(),
            fail_widths_kind: FaultKind::Fatal,
            latency: Duration::ZERO,
            switch: None,
        }
    }

    /// Probability that any given attempt faults transiently.
    pub fn transient_rate(mut self, p: f64) -> Self {
        self.transient_rate = p;
        self
    }

    /// Probability that any given attempt faults fatally.
    pub fn fatal_rate(mut self, p: f64) -> Self {
        self.fatal_rate = p;
        self
    }

    /// Fault exactly the `n`th attempt (1-based), once.
    pub fn fail_on_call(self, n: u64, kind: FaultKind) -> Self {
        self.script.lock().expect("chaos script lock").one_shot.push((n, kind));
        self
    }

    /// Fault every attempt from the `n`th (1-based) onward.
    pub fn fail_from_call(self, n: u64, kind: FaultKind) -> Self {
        self.script.lock().expect("chaos script lock").fail_from = Some((n, kind));
        self
    }

    /// Fault every attempt whose batch width (rows of `x`) is in `widths`.
    ///
    /// This is how a test makes a fault *lane-attributable*: the scheduler
    /// retries a failed batched call lane-by-lane, and only the lane whose
    /// width is in the set keeps failing.
    pub fn fail_on_widths(mut self, widths: &[usize], kind: FaultKind) -> Self {
        self.fail_widths = widths.to_vec();
        self.fail_widths_kind = kind;
        self
    }

    /// Sleep this long at the top of every attempt (timeout-path testing).
    pub fn latency(mut self, d: Duration) -> Self {
        self.latency = d;
        self
    }

    /// Attach an external arm/disarm lever (checked before everything else).
    pub fn with_switch(mut self, s: ChaosSwitch) -> Self {
        self.switch = Some(s);
        self
    }

    /// Total attempts observed, including faulted ones that never reached
    /// the inner denoiser. `calls()` (delegated to the inner denoiser)
    /// counts only successful calls; the difference is the injected-fault
    /// count.
    pub fn attempts(&self) -> u64 {
        self.attempts.load(Ordering::Relaxed)
    }

    /// Decide whether this attempt faults. At most one RNG draw per
    /// attempt, taken iff a probabilistic rate is configured, so the fault
    /// pattern is a pure function of (seed, attempt index).
    fn maybe_fault(&self, width: usize) -> Result<()> {
        let attempt = self.attempts.fetch_add(1, Ordering::Relaxed) + 1;
        let mut script = self.script.lock().expect("chaos script lock");
        // keep stream consumption independent of the scripted faults below
        let u = if self.transient_rate > 0.0 || self.fatal_rate > 0.0 {
            Some(script.rng.uniform())
        } else {
            None
        };
        if let Some(kind) = self.switch.as_ref().and_then(ChaosSwitch::get) {
            return Err(kind.error(attempt));
        }
        if let Some(i) = script.one_shot.iter().position(|(n, _)| *n == attempt) {
            let (_, kind) = script.one_shot.swap_remove(i);
            return Err(kind.error(attempt));
        }
        if let Some((n, kind)) = script.fail_from {
            if attempt >= n {
                return Err(kind.error(attempt));
            }
        }
        if self.fail_widths.contains(&width) {
            return Err(self.fail_widths_kind.error(attempt));
        }
        if let Some(u) = u {
            if u < self.fatal_rate {
                return Err(FaultKind::Fatal.error(attempt));
            }
            if u < self.fatal_rate + self.transient_rate {
                return Err(FaultKind::Transient.error(attempt));
            }
        }
        Ok(())
    }
}

impl<D: Denoiser> Denoiser for ChaosDenoiser<D> {
    fn config(&self) -> &ModelConfig {
        self.inner.config()
    }

    fn denoise_into(
        &self,
        x: &TokenBatch,
        t: &[f32],
        src: Option<&TokenBatch>,
        out: &mut LogitsBuf,
    ) -> Result<()> {
        if self.latency > Duration::ZERO {
            std::thread::sleep(self.latency);
        }
        self.maybe_fault(x.rows())?;
        self.inner.denoise_into(x, t, src, out)
    }

    fn calls(&self) -> u64 {
        self.inner.calls()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockDenoiser;

    fn mock() -> MockDenoiser {
        let cfg = MockDenoiser::test_config(10, 4, 0, "multinomial");
        MockDenoiser::fixed(cfg, vec![5, 6, 7, 8])
    }

    fn call(d: &dyn Denoiser, rows: usize) -> Result<()> {
        let x = TokenBatch::filled(rows, 4, 3);
        let mut out = LogitsBuf::new();
        d.denoise_into(&x, &vec![0.5; rows], None, &mut out)
    }

    #[test]
    fn scripted_one_shot_fault_skips_inner() {
        let d = ChaosDenoiser::new(mock(), 1).fail_on_call(2, FaultKind::Transient);
        assert!(call(&d, 1).is_ok());
        let err = call(&d, 1).unwrap_err();
        assert!(is_transient(&err), "one-shot fault must classify transient: {err:#}");
        assert!(call(&d, 1).is_ok(), "one-shot means exactly once");
        assert_eq!(d.attempts(), 3);
        assert_eq!(d.calls(), 2, "faulted attempt must not reach the inner denoiser");
    }

    #[test]
    fn fail_from_is_permanent_and_fatal_is_not_transient() {
        let d = ChaosDenoiser::new(mock(), 1).fail_from_call(2, FaultKind::Fatal);
        assert!(call(&d, 1).is_ok());
        for _ in 0..3 {
            let err = call(&d, 1).unwrap_err();
            assert!(!is_transient(&err), "fatal must not classify transient: {err:#}");
        }
        assert_eq!(d.calls(), 1);
    }

    #[test]
    fn seeded_rates_are_reproducible() {
        let pattern = |seed: u64| -> Vec<bool> {
            let d = ChaosDenoiser::new(mock(), seed).transient_rate(0.4);
            (0..64).map(|_| call(&d, 1).is_err()).collect()
        };
        let a = pattern(7);
        assert_eq!(a, pattern(7), "same seed, same fault pattern");
        assert!(a.iter().any(|f| *f) && !a.iter().all(|f| *f), "rate 0.4 mixes over 64 draws");
        assert_ne!(a, pattern(8), "different seed, different pattern");
    }

    #[test]
    fn width_scoped_faults_hit_only_matching_batches() {
        let d = ChaosDenoiser::new(mock(), 1).fail_on_widths(&[3], FaultKind::Fatal);
        assert!(call(&d, 2).is_ok());
        assert!(call(&d, 3).is_err());
        assert!(call(&d, 4).is_ok());
        assert!(call(&d, 3).is_err(), "width faults are permanent");
    }

    #[test]
    fn switch_arms_and_disarms_externally() {
        let sw = ChaosSwitch::new();
        let d = ChaosDenoiser::new(mock(), 1).with_switch(sw.clone());
        assert!(call(&d, 1).is_ok());
        sw.arm(FaultKind::Transient);
        let err = call(&d, 1).unwrap_err();
        assert!(is_transient(&err));
        sw.arm(FaultKind::Fatal);
        assert!(!is_transient(&call(&d, 1).unwrap_err()));
        sw.disarm();
        assert!(call(&d, 1).is_ok());
    }

    #[test]
    fn classification_survives_context_wrapping() {
        let base = FaultKind::Transient.error(5);
        let wrapped = base.context("denoiser call failed at boundary 12");
        assert!(is_transient(&wrapped), "chain scan must see through context");
        assert!(!is_transient(&FaultKind::Fatal.error(5).context("wrapped")));
    }
}
