//! artifacts/manifest.json + per-model config.json (written by aot.py).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::Json;

/// One model entry in the manifest.
#[derive(Debug, Clone)]
pub struct ManifestModel {
    pub name: String,
    /// "multinomial" | "absorbing"
    pub kind: String,
    /// "cond" | "uncond"
    pub task: String,
    pub dataset: String,
    pub continuous: bool,
    pub schedule: String,
    pub config_path: String,
    pub weights_path: String,
    /// bucket (batch size) → HLO path
    pub hlo: BTreeMap<usize, String>,
    /// optional split graphs (compile/split.py): encoder-only…
    pub hlo_enc: BTreeMap<usize, String>,
    /// …and decoder-against-memory, enabling the per-request memory cache
    pub hlo_dec: BTreeMap<usize, String>,
    /// transition-kernel tag, e.g. "n16_v99"
    pub transition_tag: String,
    pub n_params: usize,
    pub n_tensors: usize,
}

/// Per-model geometry (config.json ∪ manifest fields rust needs).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub vocab: usize,
    pub seq_len: usize,
    pub src_len: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub enc_layers: usize,
    pub dec_layers: usize,
    pub kind: String,
    pub dataset: String,
    pub schedule: String,
    pub continuous: bool,
    pub mask_id: u32,
    pub noise_lo: u32,
    pub train_t_grid: usize,
    pub tensor_order: Vec<String>,
}

impl ModelConfig {
    pub fn conditional(&self) -> bool {
        self.src_len > 0
    }
}

/// The loaded artifacts directory.
#[derive(Debug)]
pub struct Artifacts {
    pub root: PathBuf,
    pub buckets: Vec<usize>,
    pub models: Vec<ManifestModel>,
    /// tag → bucket → transition HLO path
    pub transition: BTreeMap<String, BTreeMap<usize, String>>,
}

impl Artifacts {
    pub fn load(root: impl AsRef<Path>) -> Result<Artifacts> {
        let root = root.as_ref().to_path_buf();
        let manifest_path = root.join("manifest.json");
        let j = Json::parse_file(&manifest_path)
            .with_context(|| format!("loading {manifest_path:?} — run `make artifacts` first"))?;

        let buckets: Vec<usize> = j
            .get("buckets")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing buckets"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();

        let mut models = Vec::new();
        for m in j
            .get("models")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing models"))?
        {
            let parse_map = |key: &str| -> BTreeMap<usize, String> {
                m.get(key)
                    .and_then(Json::as_obj)
                    .map(|o| {
                        o.iter()
                            .filter_map(|(k, v)| Some((k.parse().ok()?, v.as_str()?.to_string())))
                            .collect()
                    })
                    .unwrap_or_default()
            };
            let hlo = parse_map("hlo");
            if hlo.is_empty() {
                return Err(anyhow!("model missing hlo map"));
            }
            let hlo_enc = parse_map("hlo_enc");
            let hlo_dec = parse_map("hlo_dec");
            models.push(ManifestModel {
                name: m.str_field("name")?.to_string(),
                kind: m.str_field("kind")?.to_string(),
                task: m.str_field("task")?.to_string(),
                dataset: m.str_field("dataset")?.to_string(),
                continuous: m.get("continuous").and_then(Json::as_bool).unwrap_or(false),
                schedule: m.str_field("schedule")?.to_string(),
                config_path: m.str_field("config")?.to_string(),
                weights_path: m.str_field("weights")?.to_string(),
                hlo,
                hlo_enc,
                hlo_dec,
                transition_tag: m.str_field("transition")?.to_string(),
                n_params: m.num_field("n_params")? as usize,
                n_tensors: m.num_field("n_tensors")? as usize,
            });
        }

        let mut transition = BTreeMap::new();
        if let Some(t) = j.get("transition").and_then(Json::as_obj) {
            for (tag, buckets_map) in t {
                let inner: BTreeMap<usize, String> = buckets_map
                    .as_obj()
                    .map(|m| {
                        m.iter()
                            .filter_map(|(k, v)| Some((k.parse().ok()?, v.as_str()?.to_string())))
                            .collect()
                    })
                    .unwrap_or_default();
                transition.insert(tag.clone(), inner);
            }
        }

        Ok(Artifacts { root, buckets, models, transition })
    }

    pub fn model(&self, name: &str) -> Result<&ManifestModel> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| {
                anyhow!(
                    "model '{name}' not in manifest (have: {})",
                    self.models.iter().map(|m| m.name.as_str()).collect::<Vec<_>>().join(", ")
                )
            })
    }

    /// Models filtered by (kind, task, dataset, continuous).
    pub fn find(
        &self,
        kind: &str,
        dataset: &str,
        continuous: bool,
    ) -> Option<&ManifestModel> {
        self.models
            .iter()
            .find(|m| m.kind == kind && m.dataset == dataset && m.continuous == continuous)
    }

    pub fn config(&self, model: &ManifestModel) -> Result<ModelConfig> {
        let path = self.root.join(&model.config_path);
        let j = Json::parse_file(&path).with_context(|| format!("loading {path:?}"))?;
        Ok(ModelConfig {
            vocab: j.num_field("vocab")? as usize,
            seq_len: j.num_field("seq_len")? as usize,
            src_len: j.num_field("src_len")? as usize,
            d_model: j.num_field("d_model")? as usize,
            n_heads: j.num_field("n_heads")? as usize,
            d_ff: j.num_field("d_ff")? as usize,
            enc_layers: j.num_field("enc_layers")? as usize,
            dec_layers: j.num_field("dec_layers")? as usize,
            kind: j.str_field("kind")?.to_string(),
            dataset: j.str_field("dataset")?.to_string(),
            schedule: j.str_field("schedule")?.to_string(),
            continuous: j.get("continuous").and_then(Json::as_bool).unwrap_or(false),
            mask_id: j.num_field("mask_id")? as u32,
            noise_lo: j.num_field("noise_lo")? as u32,
            train_t_grid: j.num_field("train_t_grid")? as usize,
            tensor_order: j
                .get("tensor_order")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
                .unwrap_or_default(),
        })
    }

    /// Pick the smallest compiled bucket that fits `batch`.
    pub fn bucket_for(&self, batch: usize) -> Result<usize> {
        self.buckets
            .iter()
            .copied()
            .filter(|&b| b >= batch)
            .min()
            .or_else(|| self.buckets.iter().copied().max())
            .ok_or_else(|| anyhow!("no buckets in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn fake_artifacts() -> (tempdir::TempDir, Artifacts) {
        let dir = tempdir::TempDir::new();
        std::fs::create_dir_all(dir.path().join("m1")).unwrap();
        let manifest = r#"{
          "version": 1, "buckets": [1, 4, 16],
          "models": [{
            "name": "m1", "kind": "multinomial", "task": "cond",
            "dataset": "synth-iwslt14", "continuous": false,
            "schedule": "cosine_sq",
            "config": "m1/config.json", "weights": "m1/weights.bin",
            "hlo": {"1": "m1/model_b1.hlo.txt", "4": "m1/model_b4.hlo.txt"},
            "transition": "n16_v99", "n_params": 100, "n_tensors": 3
          }],
          "transition": {"n16_v99": {"1": "transition/n16_v99_b1.hlo.txt"}}
        }"#;
        let config = r#"{
          "vocab": 99, "seq_len": 16, "src_len": 16, "d_model": 128,
          "n_heads": 4, "d_ff": 256, "enc_layers": 2, "dec_layers": 2,
          "kind": "multinomial", "task": "cond", "dataset": "synth-iwslt14",
          "continuous": false, "schedule": "cosine_sq",
          "tensor_order": ["a", "b", "c"], "mask_id": 2, "noise_lo": 3,
          "train_t_grid": 50
        }"#;
        write!(std::fs::File::create(dir.path().join("manifest.json")).unwrap(), "{manifest}")
            .unwrap();
        write!(std::fs::File::create(dir.path().join("m1/config.json")).unwrap(), "{config}")
            .unwrap();
        let arts = Artifacts::load(dir.path()).unwrap();
        (dir, arts)
    }

    // std-only tempdir
    mod tempdir {
        pub struct TempDir(std::path::PathBuf);
        impl TempDir {
            pub fn new() -> TempDir {
                let p = std::env::temp_dir().join(format!(
                    "dndm-test-{}-{:?}",
                    std::process::id(),
                    std::thread::current().id()
                ));
                std::fs::create_dir_all(&p).unwrap();
                TempDir(p)
            }
            pub fn path(&self) -> &std::path::Path {
                &self.0
            }
        }
        impl Drop for TempDir {
            fn drop(&mut self) {
                let _ = std::fs::remove_dir_all(&self.0);
            }
        }
    }

    #[test]
    fn loads_manifest_and_config() {
        let (_d, arts) = fake_artifacts();
        assert_eq!(arts.buckets, vec![1, 4, 16]);
        let m = arts.model("m1").unwrap();
        assert_eq!(m.kind, "multinomial");
        assert_eq!(m.hlo[&4], "m1/model_b4.hlo.txt");
        let cfg = arts.config(m).unwrap();
        assert_eq!(cfg.vocab, 99);
        assert!(cfg.conditional());
        assert_eq!(cfg.tensor_order.len(), 3);
        assert_eq!(arts.transition["n16_v99"][&1], "transition/n16_v99_b1.hlo.txt");
    }

    #[test]
    fn find_by_kind_dataset() {
        let (_d, arts) = fake_artifacts();
        assert!(arts.find("multinomial", "synth-iwslt14", false).is_some());
        assert!(arts.find("absorbing", "synth-iwslt14", false).is_none());
        assert!(arts.find("multinomial", "synth-iwslt14", true).is_none());
    }

    #[test]
    fn bucket_selection() {
        let (_d, arts) = fake_artifacts();
        assert_eq!(arts.bucket_for(1).unwrap(), 1);
        assert_eq!(arts.bucket_for(3).unwrap(), 4);
        assert_eq!(arts.bucket_for(16).unwrap(), 16);
        assert_eq!(arts.bucket_for(99).unwrap(), 16); // clamp to largest
    }

    #[test]
    fn missing_model_errors_helpfully() {
        let (_d, arts) = fake_artifacts();
        let e = arts.model("nope").unwrap_err().to_string();
        assert!(e.contains("m1"), "{e}");
    }
}
