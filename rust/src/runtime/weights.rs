//! Reader for the DNDW1 flat tensor file written by python/compile/aot.py.
//!
//! Layout: magic "DNDW1\0", u32 tensor count, then per tensor
//! (u32 name_len, name bytes, u8 dtype{0:f32,1:i32}, u32 ndim, u32 dims…,
//! raw little-endian data). Tensor order is the jax canonical flatten
//! order — the exact order the HLO's leading parameters expect.

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

pub const MAGIC: &[u8; 6] = b"DNDW1\x00";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub dtype: Dtype,
    pub dims: Vec<usize>,
    /// raw little-endian payload, 4 bytes per element
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn elem_count(&self) -> usize {
        self.dims.iter().product::<usize>().max(if self.dims.is_empty() { 1 } else { 0 })
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != Dtype::F32 {
            bail!("tensor {} is not f32", self.name);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != Dtype::I32 {
            bail!("tensor {} is not i32", self.name);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

#[derive(Debug)]
pub struct WeightsFile {
    pub tensors: Vec<Tensor>,
}

impl WeightsFile {
    pub fn read(path: &Path) -> Result<WeightsFile> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        Self::parse(&bytes).with_context(|| format!("parsing {path:?}"))
    }

    pub fn parse(bytes: &[u8]) -> Result<WeightsFile> {
        let mut r = bytes;
        let mut magic = [0u8; 6];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad magic {magic:?}");
        }
        let count = read_u32(&mut r)? as usize;
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let name_len = read_u32(&mut r)? as usize;
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let mut dt = [0u8; 1];
            r.read_exact(&mut dt)?;
            let dtype = match dt[0] {
                0 => Dtype::F32,
                1 => Dtype::I32,
                d => bail!("unknown dtype {d}"),
            };
            let ndim = read_u32(&mut r)? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u32(&mut r)? as usize);
            }
            let n: usize = dims.iter().product::<usize>().max(usize::from(ndim == 0));
            let mut data = vec![0u8; 4 * n];
            r.read_exact(&mut data)?;
            tensors.push(Tensor { name: String::from_utf8(name)?, dtype, dims, data });
        }
        if !r.is_empty() {
            bail!("{} trailing bytes", r.len());
        }
        Ok(WeightsFile { tensors })
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(Tensor::elem_count).sum()
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.iter().map(|t| t.name.as_str()).collect()
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tensor(out: &mut Vec<u8>, name: &str, dtype: u8, dims: &[u32], data: &[u8]) {
        out.extend((name.len() as u32).to_le_bytes());
        out.extend(name.as_bytes());
        out.push(dtype);
        out.extend((dims.len() as u32).to_le_bytes());
        for d in dims {
            out.extend(d.to_le_bytes());
        }
        out.extend(data);
    }

    fn sample_file() -> Vec<u8> {
        let mut out = Vec::new();
        out.extend(MAGIC);
        out.extend(2u32.to_le_bytes());
        let f: Vec<u8> = [1.0f32, -2.5, 3.0, 0.0, 5.5, 6.25]
            .iter()
            .flat_map(|x| x.to_le_bytes())
            .collect();
        write_tensor(&mut out, "a.w", 0, &[2, 3], &f);
        let i: Vec<u8> = [7i32, -8].iter().flat_map(|x| x.to_le_bytes()).collect();
        write_tensor(&mut out, "b", 1, &[2], &i);
        out
    }

    #[test]
    fn parses_reference_file() {
        let wf = WeightsFile::parse(&sample_file()).unwrap();
        assert_eq!(wf.tensors.len(), 2);
        assert_eq!(wf.tensors[0].name, "a.w");
        assert_eq!(wf.tensors[0].dims, vec![2, 3]);
        assert_eq!(wf.tensors[0].as_f32().unwrap(), vec![1.0, -2.5, 3.0, 0.0, 5.5, 6.25]);
        assert_eq!(wf.tensors[1].as_i32().unwrap(), vec![7, -8]);
        assert_eq!(wf.total_params(), 8);
    }

    #[test]
    fn scalar_tensor_has_one_element() {
        let mut out = Vec::new();
        out.extend(MAGIC);
        out.extend(1u32.to_le_bytes());
        write_tensor(&mut out, "s", 0, &[], &1.5f32.to_le_bytes());
        let wf = WeightsFile::parse(&out).unwrap();
        assert_eq!(wf.tensors[0].elem_count(), 1);
        assert_eq!(wf.tensors[0].as_f32().unwrap(), vec![1.5]);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(WeightsFile::parse(b"NOPE").is_err());
        let f = sample_file();
        assert!(WeightsFile::parse(&f[..f.len() - 2]).is_err());
        let mut extra = f.clone();
        extra.push(0);
        assert!(WeightsFile::parse(&extra).is_err());
    }

    #[test]
    fn wrong_dtype_access_fails() {
        let wf = WeightsFile::parse(&sample_file()).unwrap();
        assert!(wf.tensors[0].as_i32().is_err());
        assert!(wf.tensors[1].as_f32().is_err());
    }
}
