//! PJRT-backed model execution: compile HLO text once per (model, bucket),
//! upload weights once, run `execute_b` per NFE.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{anyhow, bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::tensor::{LogitsBuf, TokenBatch};

use super::artifact::{Artifacts, ManifestModel, ModelConfig};
use super::denoiser::{denoise_chunked, Denoiser};
use super::weights::{Dtype, WeightsFile};

/// Compile an HLO text file on the given client.
pub fn compile_hlo(client: &PjRtClient, path: &std::path::Path) -> Result<PjRtLoadedExecutable> {
    let proto = HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
    )
    .with_context(|| format!("parsing HLO text {path:?}"))?;
    let comp = XlaComputation::from_proto(&proto);
    client.compile(&comp).with_context(|| format!("compiling {path:?}"))
}

/// One servable model: config + weights-on-device + per-bucket executables.
///
/// Executables compile lazily on first use of a bucket (compiling all
/// buckets up front costs seconds each; most workloads touch one or two).
pub struct ModelRuntime {
    pub name: String,
    pub config: ModelConfig,
    client: PjRtClient,
    weights: Vec<PjRtBuffer>,
    hlo_paths: HashMap<usize, PathBuf>,
    execs: RefCell<HashMap<usize, PjRtLoadedExecutable>>,
    /// split graphs (compile/split.py): encoder-only / decoder-vs-memory.
    enc_paths: HashMap<usize, PathBuf>,
    dec_paths: HashMap<usize, PathBuf>,
    enc_execs: RefCell<HashMap<usize, PjRtLoadedExecutable>>,
    dec_execs: RefCell<HashMap<usize, PjRtLoadedExecutable>>,
    /// encoder-memory device buffer, keyed by (hash(src), bucket). One
    /// entry: sampling loops re-use the same src batch for every NFE call.
    memory_cache: RefCell<Option<(u64, usize, PjRtBuffer)>>,
    /// toggle for the §Perf ablation (true when split artifacts exist).
    use_split: std::cell::Cell<bool>,
    buckets: Vec<usize>,
    calls: std::cell::Cell<u64>,
    enc_calls: std::cell::Cell<u64>,
}

impl ModelRuntime {
    pub fn load(arts: &Artifacts, client: &PjRtClient, name: &str) -> Result<ModelRuntime> {
        let entry: &ManifestModel = arts.model(name)?;
        let config = arts.config(entry)?;

        let wf = WeightsFile::read(&arts.root.join(&entry.weights_path))?;
        if wf.tensors.len() != config.tensor_order.len() {
            bail!(
                "weights/tensor_order mismatch: {} vs {}",
                wf.tensors.len(),
                config.tensor_order.len()
            );
        }
        for (t, expect) in wf.tensors.iter().zip(&config.tensor_order) {
            if &t.name != expect {
                bail!("weights order mismatch: {} vs {expect}", t.name);
            }
        }

        // Upload each tensor once; the buffers live for the model lifetime.
        let mut weights = Vec::with_capacity(wf.tensors.len());
        for t in &wf.tensors {
            let buf = match t.dtype {
                Dtype::F32 => client.buffer_from_host_buffer(&t.as_f32()?, &t.dims, None)?,
                Dtype::I32 => client.buffer_from_host_buffer(&t.as_i32()?, &t.dims, None)?,
            };
            weights.push(buf);
        }

        let to_paths = |m: &std::collections::BTreeMap<usize, String>| -> HashMap<usize, PathBuf> {
            m.iter().map(|(b, p)| (*b, arts.root.join(p))).collect()
        };
        let hlo_paths = to_paths(&entry.hlo);
        let enc_paths = to_paths(&entry.hlo_enc);
        let dec_paths = to_paths(&entry.hlo_dec);
        let mut buckets: Vec<usize> = entry.hlo.keys().copied().collect();
        buckets.sort_unstable();

        let has_split = !enc_paths.is_empty() && !dec_paths.is_empty();
        Ok(ModelRuntime {
            name: name.to_string(),
            config,
            client: client.clone(),
            weights,
            hlo_paths,
            execs: RefCell::new(HashMap::new()),
            enc_paths,
            dec_paths,
            enc_execs: RefCell::new(HashMap::new()),
            dec_execs: RefCell::new(HashMap::new()),
            memory_cache: RefCell::new(None),
            use_split: std::cell::Cell::new(has_split),
            buckets,
            calls: std::cell::Cell::new(0),
            enc_calls: std::cell::Cell::new(0),
        })
    }

    /// Enable/disable the split encode/decode path (§Perf ablation; only
    /// effective when split artifacts exist).
    pub fn set_split(&self, on: bool) {
        self.use_split
            .set(on && !self.enc_paths.is_empty() && !self.dec_paths.is_empty());
        *self.memory_cache.borrow_mut() = None;
    }

    pub fn split_enabled(&self) -> bool {
        self.use_split.get()
    }

    /// Encoder invocations (cache misses) — for tests/benches.
    pub fn encoder_calls(&self) -> u64 {
        self.enc_calls.get()
    }

    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn bucket_for(&self, batch: usize) -> usize {
        self.buckets
            .iter()
            .copied()
            .find(|&b| b >= batch)
            .unwrap_or_else(|| *self.buckets.last().expect("no buckets"))
    }

    fn ensure_compiled(&self, bucket: usize) -> Result<()> {
        if self.execs.borrow().contains_key(&bucket) {
            return Ok(());
        }
        let path = self
            .hlo_paths
            .get(&bucket)
            .ok_or_else(|| anyhow!("model {} has no bucket {bucket}", self.name))?;
        let exe = compile_hlo(&self.client, path)?;
        self.execs.borrow_mut().insert(bucket, exe);
        Ok(())
    }

    /// Pre-compile specific buckets (the serving warmup path).
    pub fn warmup(&self, buckets: &[usize]) -> Result<()> {
        for &b in buckets {
            if self.hlo_paths.contains_key(&b) {
                self.ensure_compiled(b)?;
            }
        }
        Ok(())
    }

    /// Make sure the encoder memory for this padded src batch is on
    /// device; re-encodes only on (src, bucket) change.
    fn ensure_memory(&self, s_flat: &[i32], bucket: usize) -> Result<()> {
        // FNV-1a over the padded ids — cheap and collision-safe enough for
        // a single-entry cache (a false hit needs a hash collision *and*
        // an identical bucket within one sampler loop).
        let mut h = 0xcbf29ce484222325u64;
        for &v in s_flat {
            h = (h ^ v as u64).wrapping_mul(0x100000001b3);
        }
        if let Some((ch, cb, _)) = self.memory_cache.borrow().as_ref() {
            if *ch == h && *cb == bucket {
                return Ok(());
            }
        }
        if !self.enc_execs.borrow().contains_key(&bucket) {
            let exe = compile_hlo(&self.client, &self.enc_paths[&bucket])?;
            self.enc_execs.borrow_mut().insert(bucket, exe);
        }
        let m = self.config.src_len;
        let src_buf = self.client.buffer_from_host_buffer(s_flat, &[bucket, m], None)?;
        let enc_execs = self.enc_execs.borrow();
        let exe = enc_execs.get(&bucket).unwrap();
        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        args.push(&src_buf);
        // encode is lowered *untupled* (split.py) so the output buffer is
        // the raw f32[B,M,D] array, directly consumable by decode_b.
        let mut out = exe.execute_b(&args)?;
        let mem = out.remove(0).remove(0);
        self.enc_calls.set(self.enc_calls.get() + 1);
        *self.memory_cache.borrow_mut() = Some((h, bucket, mem));
        Ok(())
    }

    /// Run one denoiser call over `x.rows() <= bucket` sequences, writing
    /// the `[B, N, V]` logits straight into the caller-owned `out` slice
    /// (no per-row `Vec` collection on the way back from PJRT).
    fn run_bucket(
        &self,
        x: &TokenBatch,
        t: &[f32],
        src: Option<&TokenBatch>,
        out: &mut [f32],
    ) -> Result<()> {
        let b = x.rows();
        let bucket = self.bucket_for(b);
        let n = self.config.seq_len;
        let v = self.config.vocab;
        let split = self.config.conditional() && self.use_split.get();
        if !split {
            self.ensure_compiled(bucket)?;
        }

        // pad to the bucket by repeating row 0 (content irrelevant, sliced off)
        let pad = |rows: &TokenBatch, len: usize| -> Vec<i32> {
            debug_assert_eq!(rows.cols(), len);
            let mut flat = Vec::with_capacity(bucket * len);
            flat.extend(rows.flat().iter().map(|&u| u as i32));
            for _ in b..bucket {
                flat.extend(rows.row(0).iter().map(|&u| u as i32));
            }
            flat
        };

        let x_flat = pad(x, n);
        let mut t_pad: Vec<f32> = t.to_vec();
        t_pad.resize(bucket, t[0]);

        let x_buf = self.client.buffer_from_host_buffer(&x_flat, &[bucket, n], None)?;
        let t_buf = self.client.buffer_from_host_buffer(&t_pad, &[bucket], None)?;

        // Split path (conditional models with encode/decode artifacts):
        // encode once per src batch, keep the memory on device, then run
        // the decoder-only graph per NFE call.
        let res = if split {
            let s = src.ok_or_else(|| anyhow!("conditional model requires src"))?;
            let s_flat = pad(s, self.config.src_len);
            self.ensure_memory(&s_flat, bucket)?;
            let cache = self.memory_cache.borrow();
            let (_, _, mem_buf) = cache.as_ref().unwrap();
            if !self.dec_execs.borrow().contains_key(&bucket) {
                let exe = compile_hlo(&self.client, &self.dec_paths[&bucket])?;
                self.dec_execs.borrow_mut().insert(bucket, exe);
            }
            let dec_execs = self.dec_execs.borrow();
            let exe = dec_execs.get(&bucket).unwrap();
            let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
            args.push(mem_buf);
            args.push(&x_buf);
            args.push(&t_buf);
            exe.execute_b(&args)?
        } else {
            let execs = self.execs.borrow();
            let exe = execs.get(&bucket).unwrap();
            let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
            let src_buf;
            if self.config.conditional() {
                let s = src.ok_or_else(|| anyhow!("conditional model requires src"))?;
                let m = self.config.src_len;
                let s_flat = pad(s, m);
                src_buf = self.client.buffer_from_host_buffer(&s_flat, &[bucket, m], None)?;
                args.push(&src_buf);
            }
            args.push(&x_buf);
            args.push(&t_buf);
            exe.execute_b(&args)?
        };
        self.calls.set(self.calls.get() + 1);
        let lit: Literal = res[0][0].to_literal_sync()?.to_tuple1()?;
        let flat: Vec<f32> = lit.to_vec()?;
        debug_assert_eq!(flat.len(), bucket * n * v);

        out.copy_from_slice(&flat[..b * n * v]);
        Ok(())
    }
}

impl Denoiser for ModelRuntime {
    fn config(&self) -> &ModelConfig {
        &self.config
    }

    fn denoise_into(
        &self,
        x: &TokenBatch,
        t: &[f32],
        src: Option<&TokenBatch>,
        out: &mut LogitsBuf,
    ) -> Result<()> {
        let b = x.rows();
        let (n, v) = (self.config.seq_len, self.config.vocab);
        let max_bucket = *self.buckets.last().expect("no buckets");
        if b > max_bucket {
            // chunk oversized batches through the largest bucket
            return denoise_chunked(self, max_bucket, x, t, src, out);
        }
        // run_bucket fully overwrites [B, N, V] — skip the reset memset
        out.reset_for_overwrite(b, n, v);
        if b == 0 {
            return Ok(());
        }
        self.run_bucket(x, t, src, out.flat_mut())
    }

    fn calls(&self) -> u64 {
        self.calls.get()
    }
}

/// The AOT-exported fused L1 transition kernel, runnable from rust.
///
/// This is the in-HLO alternative to the native rust transition update in
/// `sampler::common` — benched against each other in perf_criterion
/// (DESIGN.md ablation #2).
pub struct TransitionRuntime {
    client: PjRtClient,
    hlo_paths: HashMap<usize, PathBuf>,
    execs: RefCell<HashMap<usize, PjRtLoadedExecutable>>,
    pub seq_len: usize,
    pub vocab: usize,
}

impl TransitionRuntime {
    pub fn load(arts: &Artifacts, client: &PjRtClient, tag: &str) -> Result<TransitionRuntime> {
        let map = arts
            .transition
            .get(tag)
            .ok_or_else(|| anyhow!("no transition kernel tag {tag}"))?;
        // tag format nN_vV
        let (n, v) = tag
            .strip_prefix('n')
            .and_then(|s| s.split_once("_v"))
            .and_then(|(n, v)| Some((n.parse().ok()?, v.parse().ok()?)))
            .ok_or_else(|| anyhow!("bad transition tag {tag}"))?;
        Ok(TransitionRuntime {
            client: client.clone(),
            hlo_paths: map.iter().map(|(b, p)| (*b, arts.root.join(p))).collect(),
            execs: RefCell::new(HashMap::new()),
            seq_len: n,
            vocab: v,
        })
    }

    /// (logits, x_t, gumbel, move) → (new_x, x0_hat, score), all batch-major.
    #[allow(clippy::type_complexity)]
    pub fn step(
        &self,
        logits: &[f32],
        x_t: &[i32],
        gumbel: &[f32],
        mv: &[i32],
    ) -> Result<(Vec<i32>, Vec<i32>, Vec<f32>)> {
        let (n, v) = (self.seq_len, self.vocab);
        let b = x_t.len() / n;
        let bucket = self
            .hlo_paths
            .keys()
            .copied()
            .filter(|&k| k >= b)
            .min()
            .ok_or_else(|| anyhow!("batch {b} exceeds transition buckets"))?;
        if !self.execs.borrow().contains_key(&bucket) {
            let exe = compile_hlo(&self.client, &self.hlo_paths[&bucket])?;
            self.execs.borrow_mut().insert(bucket, exe);
        }

        let pad_f = |d: &[f32], row: usize| {
            let mut out = d.to_vec();
            out.resize(bucket * row, 0.0);
            out
        };
        let pad_i = |d: &[i32], row: usize| {
            let mut out = d.to_vec();
            out.resize(bucket * row, 0);
            out
        };
        let l = self
            .client
            .buffer_from_host_buffer(&pad_f(logits, n * v), &[bucket, n, v], None)?;
        let x = self
            .client
            .buffer_from_host_buffer(&pad_i(x_t, n), &[bucket, n], None)?;
        let g = self
            .client
            .buffer_from_host_buffer(&pad_f(gumbel, n * v), &[bucket, n, v], None)?;
        let m = self
            .client
            .buffer_from_host_buffer(&pad_i(mv, n), &[bucket, n], None)?;

        let execs = self.execs.borrow();
        let exe = execs.get(&bucket).unwrap();
        let out = exe.execute_b(&[&l, &x, &g, &m])?;
        let (a, b_, c) = out[0][0].to_literal_sync()?.to_tuple3()?;
        let mut new_x: Vec<i32> = a.to_vec()?;
        let mut x0: Vec<i32> = b_.to_vec()?;
        let mut score: Vec<f32> = c.to_vec()?;
        new_x.truncate(b * n);
        x0.truncate(b * n);
        score.truncate(b * n);
        Ok((new_x, x0, score))
    }
}
