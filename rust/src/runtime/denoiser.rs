//! The denoiser abstraction the samplers run against.
//!
//! `ModelRuntime` (PJRT-backed) is the production implementation; the
//! `MockDenoiser` gives tests and CI a deterministic, artifact-free
//! network with the same interface, so every sampling algorithm is unit-
//! tested without compiled HLO.

use anyhow::Result;

use super::artifact::ModelConfig;

/// Batched denoiser `p_θ(x̂0 | x_t, t[, src])`.
///
/// * `x`: B sequences of N token ids (the noisy x_t)
/// * `t`: B normalized times in [0, 1]
/// * `src`: B source sequences (conditional models only)
///
/// Returns per-sequence logits, each of length `seq_len * vocab`
/// (row-major `[n][v]`).
pub trait Denoiser {
    fn config(&self) -> &ModelConfig;

    fn denoise(
        &self,
        x: &[Vec<u32>],
        t: &[f32],
        src: Option<&[Vec<u32>]>,
    ) -> Result<Vec<Vec<f32>>>;

    /// Total denoiser invocations (for NFE accounting hooks).
    fn calls(&self) -> u64 {
        0
    }
}

/// Deterministic test double: produces logits that put `peak` mass on the
/// output of a target function of (src, position) and a small bump on the
/// current token — enough structure to exercise every sampler branch.
pub struct MockDenoiser {
    pub cfg: ModelConfig,
    /// (src, position) → target token id
    target: Box<dyn Fn(Option<&[u32]>, usize) -> u32 + Send + Sync>,
    pub peak: f32,
    calls: std::sync::atomic::AtomicU64,
}

impl MockDenoiser {
    /// Target = fixed sequence, independent of src.
    pub fn fixed(cfg: ModelConfig, target: Vec<u32>) -> Self {
        Self::with_fn(cfg, move |_, n| target[n % target.len()])
    }

    /// Target derived from src (e.g. the cipher task itself).
    pub fn with_fn(
        cfg: ModelConfig,
        f: impl Fn(Option<&[u32]>, usize) -> u32 + Send + Sync + 'static,
    ) -> Self {
        MockDenoiser {
            cfg,
            target: Box::new(f),
            peak: 8.0,
            calls: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// A ModelConfig for tests, no artifacts needed.
    pub fn test_config(vocab: usize, seq_len: usize, src_len: usize, kind: &str) -> ModelConfig {
        ModelConfig {
            vocab,
            seq_len,
            src_len,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            enc_layers: 1,
            dec_layers: 1,
            kind: kind.to_string(),
            dataset: "mock".to_string(),
            schedule: "cosine_sq".to_string(),
            continuous: false,
            mask_id: 2,
            noise_lo: 3,
            train_t_grid: 50,
            tensor_order: vec![],
        }
    }
}

impl Denoiser for MockDenoiser {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn denoise(
        &self,
        x: &[Vec<u32>],
        t: &[f32],
        src: Option<&[Vec<u32>]>,
    ) -> Result<Vec<Vec<f32>>> {
        assert_eq!(x.len(), t.len());
        self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (n, v) = (self.cfg.seq_len, self.cfg.vocab);
        let mut out = Vec::with_capacity(x.len());
        for (b, xb) in x.iter().enumerate() {
            let sb = src.map(|s| s[b].as_slice());
            let mut logits = vec![0.0f32; n * v];
            for pos in 0..n {
                let tgt = (self.target)(sb, pos);
                logits[pos * v + tgt as usize] = self.peak;
                // mild self-affinity so untrained-like behaviour is covered
                let cur = xb[pos] as usize % v;
                logits[pos * v + cur] += 0.5;
            }
            out.push(logits);
        }
        Ok(out)
    }

    fn calls(&self) -> u64 {
        self.calls.load(std::sync::atomic::Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_shapes_and_peak() {
        let cfg = MockDenoiser::test_config(10, 4, 0, "multinomial");
        let m = MockDenoiser::fixed(cfg, vec![5, 6, 7, 8]);
        let logits = m
            .denoise(&[vec![3, 3, 3, 3], vec![4, 4, 4, 4]], &[0.5, 0.5], None)
            .unwrap();
        assert_eq!(logits.len(), 2);
        assert_eq!(logits[0].len(), 40);
        // argmax at position 0 must be token 5
        let row = &logits[0][0..10];
        let arg = row.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
        assert_eq!(arg, 5);
        assert_eq!(m.calls(), 1);
    }

    #[test]
    fn src_dependent_target() {
        let cfg = MockDenoiser::test_config(10, 3, 3, "absorbing");
        let m = MockDenoiser::with_fn(cfg, |src, pos| src.unwrap()[pos] + 1);
        let logits = m
            .denoise(&[vec![2, 2, 2]], &[1.0], Some(&[vec![4, 5, 6]]))
            .unwrap();
        for (pos, want) in [(0usize, 5usize), (1, 6), (2, 7)] {
            let row = &logits[0][pos * 10..(pos + 1) * 10];
            let arg = row.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
            assert_eq!(arg, want);
        }
    }
}
