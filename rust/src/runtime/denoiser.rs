//! The denoiser abstraction the samplers run against.
//!
//! `ModelRuntime` (PJRT-backed) is the production implementation; the
//! `MockDenoiser` gives tests and CI a deterministic, artifact-free
//! network with the same interface, so every sampling algorithm is unit-
//! tested without compiled HLO.
//!
//! The primary entry point is [`Denoiser::denoise_into`]: the caller owns
//! the output [`LogitsBuf`] and reuses it across NFE calls, so the host
//! side of a denoiser call performs no steady-state heap allocation (the
//! flat data path, `docs/perf.md`). [`Denoiser::denoise`] is the
//! convenience shim that allocates a fresh buffer per call.

use anyhow::Result;

use crate::tensor::{LogitsBuf, TokenBatch};

use super::artifact::ModelConfig;

/// Batched denoiser `p_θ(x̂0 | x_t, t[, src])`.
///
/// * `x`: `[B, N]` token ids (the noisy x_t)
/// * `t`: B normalized times in [0, 1]
/// * `src`: `[B, M]` source ids (conditional models only)
///
/// Output logits are `[B, N, V]` row-major in a flat buffer.
pub trait Denoiser {
    fn config(&self) -> &ModelConfig;

    /// Run the network and write the `[B, N, V]` logits into `out`
    /// (re-dimensioned by the implementation; capacity is reused).
    fn denoise_into(
        &self,
        x: &TokenBatch,
        t: &[f32],
        src: Option<&TokenBatch>,
        out: &mut LogitsBuf,
    ) -> Result<()>;

    /// Allocating convenience wrapper over [`Self::denoise_into`] — for
    /// call sites outside the per-NFE hot path (tests, warmup, ELBO).
    fn denoise(&self, x: &TokenBatch, t: &[f32], src: Option<&TokenBatch>) -> Result<LogitsBuf> {
        let mut out = LogitsBuf::new();
        self.denoise_into(x, t, src, &mut out)?;
        Ok(out)
    }

    /// Total denoiser invocations (for NFE accounting hooks).
    fn calls(&self) -> u64 {
        0
    }
}

/// Split an oversized batch into `chunk`-row sub-batches and run each
/// through `den`, reassembling the `[B, N, V]` logits in `out`.
///
/// This is the shared implementation of the "batch > largest bucket" path:
/// `ModelRuntime` calls it with its largest compiled bucket, and tests
/// drive it directly over `MockDenoiser` to pin the sub-slicing (including
/// the conditional-src case) against the unchunked result.
pub fn denoise_chunked(
    den: &dyn Denoiser,
    chunk: usize,
    x: &TokenBatch,
    t: &[f32],
    src: Option<&TokenBatch>,
    out: &mut LogitsBuf,
) -> Result<()> {
    assert!(chunk >= 1, "chunk size must be >= 1");
    let b = x.rows();
    let cfg = den.config();
    let (n, v) = (cfg.seq_len, cfg.vocab);
    // every element is overwritten by a chunk copy below — no memset needed
    out.reset_for_overwrite(b, n, v);
    let mut cx = TokenBatch::new(x.cols());
    let mut cs = src.map(|s| TokenBatch::new(s.cols()));
    let mut cout = LogitsBuf::new();
    let mut start = 0;
    while start < b {
        let end = (start + chunk).min(b);
        cx.reset(x.cols());
        for i in start..end {
            cx.push_row(x.row(i));
        }
        if let (Some(cs), Some(s)) = (cs.as_mut(), src) {
            cs.reset(s.cols());
            for i in start..end {
                cs.push_row(s.row(i));
            }
        }
        den.denoise_into(&cx, &t[start..end], cs.as_ref(), &mut cout)?;
        out.flat_mut()[start * n * v..end * n * v].copy_from_slice(cout.flat());
        start = end;
    }
    Ok(())
}

/// Deterministic test double: produces logits that put `peak` mass on the
/// output of a target function of (src, position) and a small bump on the
/// current token — enough structure to exercise every sampler branch.
pub struct MockDenoiser {
    pub cfg: ModelConfig,
    /// (src, position) → target token id
    target: Box<dyn Fn(Option<&[u32]>, usize) -> u32 + Send + Sync>,
    pub peak: f32,
    calls: std::sync::atomic::AtomicU64,
}

impl MockDenoiser {
    /// Target = fixed sequence, independent of src.
    pub fn fixed(cfg: ModelConfig, target: Vec<u32>) -> Self {
        Self::with_fn(cfg, move |_, n| target[n % target.len()])
    }

    /// Target derived from src (e.g. the cipher task itself).
    pub fn with_fn(
        cfg: ModelConfig,
        f: impl Fn(Option<&[u32]>, usize) -> u32 + Send + Sync + 'static,
    ) -> Self {
        MockDenoiser {
            cfg,
            target: Box::new(f),
            // sharp enough that temperature-1 Gumbel draws essentially
            // never override the target (flip mass ≈ V·e^{-peak}), so
            // exact-convergence assertions don't ride on seed luck
            peak: 12.0,
            calls: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// A ModelConfig for tests, no artifacts needed.
    pub fn test_config(vocab: usize, seq_len: usize, src_len: usize, kind: &str) -> ModelConfig {
        ModelConfig {
            vocab,
            seq_len,
            src_len,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            enc_layers: 1,
            dec_layers: 1,
            kind: kind.to_string(),
            dataset: "mock".to_string(),
            schedule: "cosine_sq".to_string(),
            continuous: false,
            mask_id: 2,
            noise_lo: 3,
            train_t_grid: 50,
            tensor_order: vec![],
        }
    }
}

impl Denoiser for MockDenoiser {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn denoise_into(
        &self,
        x: &TokenBatch,
        t: &[f32],
        src: Option<&TokenBatch>,
        out: &mut LogitsBuf,
    ) -> Result<()> {
        assert_eq!(x.rows(), t.len());
        self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (n, v) = (self.cfg.seq_len, self.cfg.vocab);
        out.reset(x.rows(), n, v);
        for b in 0..x.rows() {
            let sb = src.map(|s| s.row(b));
            let xb = x.row(b);
            let logits = out.seq_mut(b);
            for pos in 0..n {
                let tgt = (self.target)(sb, pos);
                logits[pos * v + tgt as usize] = self.peak;
                // mild self-affinity so untrained-like behaviour is covered
                let cur = xb[pos] as usize % v;
                logits[pos * v + cur] += 0.5;
            }
        }
        Ok(())
    }

    fn calls(&self) -> u64 {
        self.calls.load(std::sync::atomic::Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_shapes_and_peak() {
        let cfg = MockDenoiser::test_config(10, 4, 0, "multinomial");
        let m = MockDenoiser::fixed(cfg, vec![5, 6, 7, 8]);
        let x = TokenBatch::from_rows(&[vec![3, 3, 3, 3], vec![4, 4, 4, 4]]);
        let logits = m.denoise(&x, &[0.5, 0.5], None).unwrap();
        assert_eq!(logits.batch(), 2);
        assert_eq!(logits.seq(0).len(), 40);
        // argmax at position 0 must be token 5
        let row = logits.row(0, 0);
        let arg = row.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
        assert_eq!(arg, 5);
        assert_eq!(m.calls(), 1);
    }

    #[test]
    fn src_dependent_target() {
        let cfg = MockDenoiser::test_config(10, 3, 3, "absorbing");
        let m = MockDenoiser::with_fn(cfg, |src, pos| src.unwrap()[pos] + 1);
        let x = TokenBatch::from_rows(&[vec![2, 2, 2]]);
        let src = TokenBatch::from_rows(&[vec![4, 5, 6]]);
        let logits = m.denoise(&x, &[1.0], Some(&src)).unwrap();
        for (pos, want) in [(0usize, 5usize), (1, 6), (2, 7)] {
            let row = logits.row(0, pos);
            let arg = row.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
            assert_eq!(arg, want);
        }
    }

    #[test]
    fn denoise_into_reuses_the_buffer() {
        let cfg = MockDenoiser::test_config(10, 4, 0, "multinomial");
        let m = MockDenoiser::fixed(cfg, vec![5, 6, 7, 8]);
        let x = TokenBatch::filled(2, 4, 3);
        let mut out = LogitsBuf::new();
        m.denoise_into(&x, &[0.5, 0.5], None, &mut out).unwrap();
        let first = out.flat().to_vec();
        // second call must fully overwrite (reset zeroes before writing)
        m.denoise_into(&x, &[0.1, 0.1], None, &mut out).unwrap();
        assert_eq!(out.flat(), &first[..], "mock is time-independent");
        assert_eq!(m.calls(), 2);
    }
}
