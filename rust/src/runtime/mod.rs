//! The PJRT runtime: loads `artifacts/` (HLO text + weights) produced by
//! `make artifacts` and executes the denoiser from the rust hot path.
//!
//! Flow (see /opt/xla-example/load_hlo for the reference wiring):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute_b` with weights pre-uploaded as device
//! buffers (uploaded once per model, reused for every NFE call).

pub mod artifact;
pub mod chaos;
pub mod denoiser;
pub mod model;
pub mod weights;

pub use artifact::{Artifacts, ManifestModel, ModelConfig};
pub use chaos::{is_transient, ChaosDenoiser, ChaosSwitch, FaultKind, TRANSIENT_MARKER};
pub use denoiser::{denoise_chunked, Denoiser, MockDenoiser};
pub use model::{ModelRuntime, TransitionRuntime};
pub use weights::{Dtype, Tensor, WeightsFile};
