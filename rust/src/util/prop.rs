//! Mini property-testing harness (proptest is unreachable offline).
//!
//! `check(name, cases, |g| { ... })` runs a closure over `cases` randomized
//! inputs drawn through a [`Gen`]; on failure it panics with the seed so
//! the exact case replays with `check_seeded`. No shrinking — failing
//! inputs here are small by construction.

use crate::schedule::SplitMix64;

/// Randomized input source handed to each property case.
pub struct Gen {
    pub rng: SplitMix64,
    /// the per-case seed (printed on failure)
    pub seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.uniform() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.coin(0.5)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    pub fn vec_u32(&mut self, len: usize, lo: u32, hi: u32) -> Vec<u32> {
        (0..len)
            .map(|_| lo + self.rng.below((hi - lo) as u64 + 1) as u32)
            .collect()
    }
}

/// Run `cases` random cases of `prop`. Panics with the replay seed on the
/// first failure (assert inside the closure).
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut prop: F) {
    let mut meta = SplitMix64::new(0x5EED ^ name.len() as u64);
    for case in 0..cases {
        let seed = meta.next_u64();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen { rng: SplitMix64::new(seed), seed };
            prop(&mut g);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (replay seed {seed:#x}):\n{msg}"
            );
        }
    }
}

/// Replay a single failing case.
pub fn check_seeded<F: FnMut(&mut Gen)>(seed: u64, mut prop: F) {
    let mut g = Gen { rng: SplitMix64::new(seed), seed };
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("count", 50, |g| {
            n += 1;
            let x = g.usize_in(1, 10);
            assert!((1..=10).contains(&x));
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check("fails", 10, |g| {
            let x = g.usize_in(0, 100);
            assert!(x > 1000, "x={x}");
        });
    }

    #[test]
    fn seeded_replay_is_deterministic() {
        let mut a = Vec::new();
        check_seeded(42, |g| a.push(g.usize_in(0, 1_000_000)));
        let mut b = Vec::new();
        check_seeded(42, |g| b.push(g.usize_in(0, 1_000_000)));
        assert_eq!(a, b);
    }

    #[test]
    fn generators_cover_ranges() {
        check("ranges", 100, |g| {
            let f = g.f64_in(-2.0, 3.0);
            assert!((-2.0..=3.0).contains(&f));
            let v = g.vec_u32(5, 10, 20);
            assert_eq!(v.len(), 5);
            assert!(v.iter().all(|&x| (10..=20).contains(&x)));
            let _ = g.bool();
            let p = *g.pick(&[1, 2, 3]);
            assert!((1..=3).contains(&p));
        });
    }
}
