//! Tiny CLI argument parser (clap is unreachable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::HashMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn parses_mixed_forms() {
        let a = args(&["serve", "--steps", "50", "--fast", "--beta=15:7", "extra"]);
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.usize_or("steps", 0), 50);
        assert!(a.has("fast"));
        assert_eq!(a.get("beta"), Some("15:7"));
        assert_eq!(a.get_or("missing", "d"), "d");
    }

    #[test]
    fn boolean_flag_before_flag() {
        let a = args(&["--fast", "--steps", "10"]);
        assert!(a.has("fast"));
        assert_eq!(a.usize_or("steps", 0), 10);
    }

    #[test]
    fn defaults() {
        let a = args(&[]);
        assert_eq!(a.f64_or("x", 1.5), 1.5);
        assert_eq!(a.u64_or("seed", 7), 7);
    }
}
