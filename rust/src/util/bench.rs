//! Mini bench harness (criterion is unreachable offline): warmup +
//! timed iterations, mean/median/stddev, a table printer shared by the
//! per-paper-table bench binaries, and (under `cfg(test)`) a counting
//! global allocator that lets unit tests assert a code path performs no
//! heap allocation.

use std::time::{Duration, Instant};

/// Heap-allocation counting for unit tests.
///
/// Registers a [`std::alloc::GlobalAlloc`] wrapper around the system
/// allocator **in the library's unit-test binary only** (`cfg(test)`, so
/// release builds and integration tests are untouched). Counts are kept
/// per thread, which makes [`thread_allocs`] deltas immune to the other
/// unit tests `cargo test` runs concurrently.
///
/// This is how the flat-data-path guarantee is enforced: the scheduler's
/// `steady_state_tick_is_allocation_free` test snapshots the counter
/// around `Scheduler::tick` and asserts a zero delta (`docs/perf.md`).
#[cfg(test)]
pub mod alloc_count {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::cell::Cell;

    thread_local! {
        static ALLOCS: Cell<u64> = const { Cell::new(0) };
    }

    pub struct CountingAlloc;

    #[inline]
    fn bump() {
        // try_with: never panic from inside the allocator, even during
        // thread teardown
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
    }

    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            bump();
            System.alloc(layout)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            bump();
            System.realloc(ptr, layout, new_size)
        }

        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            bump();
            System.alloc_zeroed(layout)
        }
    }

    #[global_allocator]
    static COUNTER: CountingAlloc = CountingAlloc;

    /// Heap allocations made by the current thread since it started
    /// (deallocations are not counted — only acquisition matters for the
    /// churn guarantee).
    pub fn thread_allocs() -> u64 {
        ALLOCS.with(|c| c.get())
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn counter_observes_allocations_on_this_thread() {
            let before = thread_allocs();
            let v: Vec<u64> = Vec::with_capacity(32);
            std::hint::black_box(&v);
            let after = thread_allocs();
            assert!(after > before, "Vec::with_capacity must register");
        }

        #[test]
        fn no_alloc_code_registers_zero() {
            let mut acc = 0u64;
            let before = thread_allocs();
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            std::hint::black_box(acc);
            assert_eq!(thread_allocs(), before);
        }
    }
}

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub stddev: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} {:>10} {:>10} ± {:>8}  ({} iters)",
            self.name,
            fmt_dur(self.min),
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.stddev),
            self.iters
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

/// Criterion-style: warm up, then run ≥`min_iters` or until `min_time`.
pub fn bench<F: FnMut()>(name: &str, min_iters: usize, min_time: Duration, mut f: F) -> BenchResult {
    // warmup
    for _ in 0..2.min(min_iters) {
        f();
    }
    let mut times = Vec::new();
    let start = Instant::now();
    while times.len() < min_iters || (start.elapsed() < min_time && times.len() < 10_000) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    summarize(name, times)
}

/// Fixed iteration count (for expensive end-to-end cells).
pub fn bench_n<F: FnMut()>(name: &str, iters: usize, mut f: F) -> BenchResult {
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    summarize(name, times)
}

fn summarize(name: &str, mut times: Vec<Duration>) -> BenchResult {
    times.sort();
    let n = times.len();
    let mean_s = times.iter().map(Duration::as_secs_f64).sum::<f64>() / n as f64;
    let var = times
        .iter()
        .map(|t| (t.as_secs_f64() - mean_s).powi(2))
        .sum::<f64>()
        / n as f64;
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean: Duration::from_secs_f64(mean_s),
        median: times[n / 2],
        stddev: Duration::from_secs_f64(var.sqrt()),
        min: times[0],
    }
}

/// Simple aligned table printer for paper-table reproduction output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", line(&self.headers));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for r in &self.rows {
            println!("{}", line(r));
        }
    }

    /// Also emit machine-readable TSV (appended to EXPERIMENTS data files).
    pub fn to_tsv(&self) -> String {
        let mut out = self.headers.join("\t");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join("\t"));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_enough_iters() {
        let r = bench("noop", 10, Duration::from_millis(1), || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 10);
        assert!(r.min <= r.median && r.median <= r.mean + r.stddev * 3);
    }

    #[test]
    fn bench_n_exact() {
        let r = bench_n("sleepless", 5, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        let tsv = t.to_tsv();
        assert_eq!(tsv, "a\tbb\n1\t2\n");
        t.print();
    }

    #[test]
    fn fmt_dur_scales() {
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_micros(5)).ends_with("µs"));
    }
}
