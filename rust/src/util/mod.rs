//! In-tree utilities replacing crates that are unreachable offline
//! (serde_json → `json`, clap → `args`, criterion → `bench`,
//! proptest → `prop`).

pub mod args;
pub mod bench;
pub mod json;
pub mod prop;

pub use json::Json;
