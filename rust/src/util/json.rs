//! Minimal JSON parser + writer — enough for artifacts/manifest.json,
//! config.json and fixtures.json (all of which we author ourselves in
//! python/compile/aot.py). serde_json is not available offline.
//!
//! Supports the full JSON grammar (objects, arrays, strings with the
//! common escapes incl. \uXXXX, numbers, bools, null); numbers are f64.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let s = std::fs::read_to_string(path)?;
        Json::parse(&s)
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.get(key).as_str()` with a good error.
    pub fn str_field(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing string field '{key}'"))
    }

    pub fn num_field(&self, key: &str) -> Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing number field '{key}'"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => bail!("unexpected byte at {}", self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 char
                    let s = std::str::from_utf8(&self.b[self.i..])?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shaped_document() {
        let s = r#"{
          "version": 1,
          "buckets": [1, 4, 16],
          "models": [{"name": "m", "hlo": {"1": "m/model_b1.hlo.txt"},
                      "continuous": false, "n_params": 1587823}],
          "note": "α ≤ 1 — unicode ok A"
        }"#;
        let j = Json::parse(s).unwrap();
        assert_eq!(j.num_field("version").unwrap(), 1.0);
        assert_eq!(j.get("buckets").unwrap().idx(2).unwrap().as_usize(), Some(16));
        let m = j.get("models").unwrap().idx(0).unwrap();
        assert_eq!(m.str_field("name").unwrap(), "m");
        assert_eq!(m.get("continuous").unwrap().as_bool(), Some(false));
        assert_eq!(
            m.get("hlo").unwrap().str_field("1").unwrap(),
            "m/model_b1.hlo.txt"
        );
        assert!(j.str_field("note").unwrap().contains('A'));
        assert!(j.str_field("note").unwrap().contains('α'));
    }

    #[test]
    fn numbers_and_literals() {
        assert_eq!(Json::parse("-3.25e2").unwrap().as_f64(), Some(-325.0));
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
    }

    #[test]
    fn roundtrip_display() {
        let s = r#"{"a":[1,2.5,"x\ny"],"b":{"c":null,"d":true}}"#;
        let j = Json::parse(s).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
