//! α(t) noise schedules (Appendix C of the paper).
//!
//! All schedules are expressed as a continuous, scale-invariant α(t) over
//! t ∈ [0, 1] (footnote 1: α_t(T) = g(t/T) with α_{ct}(cT) = α_t(T)), which
//! serves both the discrete grid (α_k = α(k/T)) and DNDM-C's continuous
//! sampling. Mirrors `python/compile/trainer.py::alpha_of`.

/// Continuous α schedule; decreasing from α(0)=1 to α(1)=0.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AlphaSchedule {
    /// α(t) = 1 − t (Austin et al. 2021). Uniform 𝒟_τ.
    Linear,
    /// α(t) = cos(πt/2) (Hoogeboom et al. 2021b). τ mass shifts late.
    Cosine,
    /// α(t) = cos²(πt/2) (Zheng et al. 2023 / Nichol & Dhariwal). τ mass
    /// concentrates mid-range.
    CosineSq,
    /// Cosine with the numerical offset s: α(t) = f(t)/f(0),
    /// f(t) = cos(((s + t)/(1 + s))·π/2).
    CosineOffset { s: f64 },
}

impl AlphaSchedule {
    /// α(t) for t ∈ [0, 1].
    pub fn alpha(&self, t: f64) -> f64 {
        let t = t.clamp(0.0, 1.0);
        match self {
            AlphaSchedule::Linear => 1.0 - t,
            AlphaSchedule::Cosine => (std::f64::consts::FRAC_PI_2 * t).cos(),
            AlphaSchedule::CosineSq => {
                let c = (std::f64::consts::FRAC_PI_2 * t).cos();
                c * c
            }
            AlphaSchedule::CosineOffset { s } => {
                let f = |x: f64| (((s + x) / (1.0 + s)) * std::f64::consts::FRAC_PI_2).cos();
                f(t) / f(0.0)
            }
        }
    }

    /// Discrete α_k on a T-step grid; α_0 = 1, α_T = 0 exactly.
    pub fn alpha_discrete(&self, k: usize, t_max: usize) -> f64 {
        if k == 0 {
            return 1.0;
        }
        if k >= t_max {
            return 0.0;
        }
        self.alpha(k as f64 / t_max as f64)
    }

    /// β_k = α_k / α_{k−1} — the per-step keep probability of eq. (1)/(6).
    pub fn beta_discrete(&self, k: usize, t_max: usize) -> f64 {
        let prev = self.alpha_discrete(k - 1, t_max);
        if prev <= 0.0 {
            return 0.0;
        }
        (self.alpha_discrete(k, t_max) / prev).clamp(0.0, 1.0)
    }

    /// −α′(t), the continuous transition-time density of §3.3 (numerical).
    pub fn neg_alpha_prime(&self, t: f64) -> f64 {
        let h = 1e-6;
        let lo = (t - h).max(0.0);
        let hi = (t + h).min(1.0);
        ((self.alpha(lo) - self.alpha(hi)) / (hi - lo)).max(0.0)
    }

    /// ℙ(τ = k) = α_{k−1} − α_k for k = 1..=T (Theorem 3.6).
    pub fn tau_pmf(&self, t_max: usize) -> Vec<f64> {
        (1..=t_max)
            .map(|k| self.alpha_discrete(k - 1, t_max) - self.alpha_discrete(k, t_max))
            .collect()
    }

    pub fn parse(name: &str) -> Option<AlphaSchedule> {
        match name {
            "linear" => Some(AlphaSchedule::Linear),
            "cosine" => Some(AlphaSchedule::Cosine),
            "cosine_sq" => Some(AlphaSchedule::CosineSq),
            _ => name
                .strip_prefix("cosine_offset:")
                .and_then(|s| s.parse().ok())
                .map(|s| AlphaSchedule::CosineOffset { s }),
        }
    }

    pub fn name(&self) -> String {
        match self {
            AlphaSchedule::Linear => "linear".into(),
            AlphaSchedule::Cosine => "cosine".into(),
            AlphaSchedule::CosineSq => "cosine_sq".into(),
            AlphaSchedule::CosineOffset { s } => format!("cosine_offset:{s}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL: [AlphaSchedule; 4] = [
        AlphaSchedule::Linear,
        AlphaSchedule::Cosine,
        AlphaSchedule::CosineSq,
        AlphaSchedule::CosineOffset { s: 0.008 },
    ];

    #[test]
    fn boundaries_and_monotonicity() {
        for s in ALL {
            assert!((s.alpha(0.0) - 1.0).abs() < 1e-12, "{s:?}");
            assert!(s.alpha(1.0).abs() < 0.05, "{s:?} α(1)={}", s.alpha(1.0));
            let mut prev = 1.0;
            for i in 1..=100 {
                let a = s.alpha(i as f64 / 100.0);
                assert!(a <= prev + 1e-12, "{s:?} not decreasing at {i}");
                prev = a;
            }
        }
    }

    #[test]
    fn tau_pmf_sums_to_one_and_nonnegative() {
        // Theorem 3.6 validity: Σ ℙ(τ=t) = α_0 − α_T = 1
        for s in ALL {
            for t_max in [1, 2, 10, 50, 1000] {
                let pmf = s.tau_pmf(t_max);
                assert_eq!(pmf.len(), t_max);
                assert!(pmf.iter().all(|&p| p >= -1e-12), "{s:?}");
                let sum: f64 = pmf.iter().sum();
                assert!((sum - 1.0).abs() < 1e-9, "{s:?} T={t_max} sum={sum}");
            }
        }
    }

    #[test]
    fn linear_gives_uniform_tau() {
        let pmf = AlphaSchedule::Linear.tau_pmf(50);
        for p in pmf {
            assert!((p - 0.02).abs() < 1e-12);
        }
    }

    #[test]
    fn beta_discrete_reconstructs_alpha() {
        // α_k = Π β_s (definition under Theorem 3.1)
        for s in ALL {
            let t_max = 50;
            let mut prod = 1.0;
            for k in 1..=t_max {
                prod *= s.beta_discrete(k, t_max);
                assert!(
                    (prod - s.alpha_discrete(k, t_max)).abs() < 1e-9,
                    "{s:?} k={k}"
                );
            }
        }
    }

    #[test]
    fn neg_alpha_prime_matches_pmf_shape() {
        // ℙ(τ=t) ≈ (1/T)·|g′(t/T)| (§3.2). Check against the T=1000 pmf.
        let s = AlphaSchedule::CosineSq;
        let t_max = 1000;
        let pmf = s.tau_pmf(t_max);
        for &k in &[100usize, 500, 900] {
            let approx = s.neg_alpha_prime(k as f64 / t_max as f64) / t_max as f64;
            assert!((pmf[k - 1] - approx).abs() < 1e-5);
        }
    }

    #[test]
    fn parse_roundtrip() {
        for s in ALL {
            assert_eq!(AlphaSchedule::parse(&s.name()), Some(s));
        }
        assert_eq!(AlphaSchedule::parse("nope"), None);
    }
}
