//! Schedules: α(t) noise schedules, the transition-time distribution 𝒟_τ,
//! and the deterministic RNG shared with the python build layer.

pub mod alpha;
pub mod rng;
pub mod transition;

pub use alpha::AlphaSchedule;
pub use rng::SplitMix64;
pub use transition::{TransitionOrder, TransitionSpec, TransitionTimes};
