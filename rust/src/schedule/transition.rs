//! The predetermined transition-time set 𝒯 — the paper's core object.
//!
//! Definition 3.2: τ_n = min{t : b_t = 0} is the (single) step at which
//! token n flips from data to noise in the non-Markov forward process (6);
//! in reverse, the only step at which it flips back (eq. 9). Theorem 3.6
//! gives the exact law ℙ(τ = t) = α_{t−1} − α_t; §3.2/Appendix C show a
//! reshaped Beta(a, b) approximation works as well or better in practice.
//!
//! Sampling 𝒯 = {τ_n} *before* the reverse loop de-randomizes it: the
//! denoiser only runs at t ∈ 𝒯, so NFE = |𝒯| ≤ min(N, T) (Theorem D.1).

use super::alpha::AlphaSchedule;
use super::rng::SplitMix64;

/// Positional assignment of sampled transition times (Table 6 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransitionOrder {
    /// i.i.d. per position — the paper's default.
    Random,
    /// Left tokens transition (= are decoded) earliest in the reverse pass.
    LeftToRight,
    /// Right tokens decoded earliest.
    RightToLeft,
}

/// How 𝒟_τ is sampled.
#[derive(Debug, Clone, PartialEq)]
pub enum TransitionSpec {
    /// Exact law from the α schedule: ℙ(τ=t) = α_{t−1} − α_t (Thm 3.6).
    Exact(AlphaSchedule),
    /// Reshaped Beta(a, b): draw u ~ Beta, τ = clamp(round(u·T), 1, T).
    Beta { a: f64, b: f64 },
    /// τ ~ U{1..T} — the exact law of the linear α schedule, but sampled
    /// directly (one RNG draw, no inverse-CDF search); the continuous
    /// analogue is U(0, 1].
    Uniform,
}

impl TransitionSpec {
    /// ℙ(τ = k), k = 1..=T.
    pub fn pmf(&self, t_max: usize) -> Vec<f64> {
        match self {
            TransitionSpec::Exact(s) => s.tau_pmf(t_max),
            TransitionSpec::Beta { a, b } => {
                // Monte-Carlo–free: integrate the Beta density over the
                // rounding cells [ (k−½)/T, (k+½)/T ).
                let mut pmf = vec![0.0; t_max];
                let steps = 64;
                for k in 1..=t_max {
                    let lo = ((k as f64 - 0.5) / t_max as f64).max(0.0);
                    let hi = ((k as f64 + 0.5) / t_max as f64).min(1.0);
                    let mut acc = 0.0;
                    for i in 0..steps {
                        let x = lo + (hi - lo) * (i as f64 + 0.5) / steps as f64;
                        acc += beta_pdf(x, *a, *b);
                    }
                    pmf[k - 1] = acc * (hi - lo) / steps as f64;
                }
                // cell k=1 also absorbs the [0, 1/(2T)) tail (clamp), k=T the top
                let mut acc = 0.0;
                for i in 0..steps {
                    let x = (0.5 / t_max as f64) * (i as f64 + 0.5) / steps as f64;
                    acc += beta_pdf(x, *a, *b);
                }
                pmf[0] += acc * (0.5 / t_max as f64) / steps as f64;
                let sum: f64 = pmf.iter().sum();
                for p in pmf.iter_mut() {
                    *p /= sum;
                }
                pmf
            }
            TransitionSpec::Uniform => vec![1.0 / t_max as f64; t_max],
        }
    }

    /// Draw one τ ∈ 1..=T.
    pub fn sample_discrete(&self, t_max: usize, rng: &mut SplitMix64) -> usize {
        match self {
            TransitionSpec::Exact(s) => {
                // inverse-CDF on the closed form: ℙ(τ ≤ k) = 1 − α_k
                let u = rng.uniform();
                // find smallest k with 1 − α_k ≥ u  ⇔  α_k ≤ 1 − u
                let target = 1.0 - u;
                let (mut lo, mut hi) = (1usize, t_max);
                while lo < hi {
                    let mid = (lo + hi) / 2;
                    if s.alpha_discrete(mid, t_max) <= target {
                        hi = mid;
                    } else {
                        lo = mid + 1;
                    }
                }
                lo
            }
            TransitionSpec::Beta { a, b } => {
                let u = rng.beta(*a, *b);
                ((u * t_max as f64).round() as usize).clamp(1, t_max)
            }
            TransitionSpec::Uniform => 1 + rng.below(t_max as u64) as usize,
        }
    }

    /// Draw one continuous τ ∈ (0, 1] (DNDM-C, §3.3: density −α′(t)).
    pub fn sample_continuous(&self, rng: &mut SplitMix64) -> f64 {
        match self {
            TransitionSpec::Exact(s) => {
                // τ = α⁻¹(1 − u): bisection on the monotone α(t)
                let u = rng.uniform();
                let target = 1.0 - u;
                let (mut lo, mut hi) = (0.0f64, 1.0f64);
                for _ in 0..60 {
                    let mid = 0.5 * (lo + hi);
                    if s.alpha(mid) <= target {
                        hi = mid;
                    } else {
                        lo = mid;
                    }
                }
                0.5 * (lo + hi)
            }
            TransitionSpec::Beta { a, b } => rng.beta(*a, *b).clamp(1e-9, 1.0),
            TransitionSpec::Uniform => rng.uniform().clamp(1e-9, 1.0),
        }
    }

    /// Sample the full set 𝒯 for an N-token sequence (discrete grid).
    pub fn sample_times(
        &self,
        t_max: usize,
        n_tokens: usize,
        order: TransitionOrder,
        rng: &mut SplitMix64,
    ) -> TransitionTimes {
        let mut taus: Vec<usize> = (0..n_tokens)
            .map(|_| self.sample_discrete(t_max, rng))
            .collect();
        apply_order(&mut taus, order);
        TransitionTimes::new(taus, t_max)
    }

    /// Sample continuous 𝒯 (DNDM-C). Returned per-position.
    pub fn sample_times_continuous(
        &self,
        n_tokens: usize,
        order: TransitionOrder,
        rng: &mut SplitMix64,
    ) -> Vec<f64> {
        let mut taus: Vec<f64> = (0..n_tokens)
            .map(|_| self.sample_continuous(rng))
            .collect();
        match order {
            TransitionOrder::Random => {}
            TransitionOrder::LeftToRight => {
                taus.sort_by(|x, y| y.partial_cmp(x).unwrap());
            }
            TransitionOrder::RightToLeft => {
                taus.sort_by(|x, y| x.partial_cmp(y).unwrap());
            }
        }
        taus
    }

    /// E[|𝒯|] = Σ_i [1 − (1 − p_i)^N] (Theorem D.1).
    pub fn expected_nfe(&self, t_max: usize, n_tokens: usize) -> f64 {
        self.pmf(t_max)
            .iter()
            .map(|&p| 1.0 - (1.0 - p).powi(n_tokens as i32))
            .sum()
    }

    pub fn name(&self) -> String {
        match self {
            TransitionSpec::Exact(s) => format!("exact:{}", s.name()),
            TransitionSpec::Beta { a, b } => format!("beta:{a}:{b}"),
            TransitionSpec::Uniform => "uniform".to_string(),
        }
    }

    pub fn parse(s: &str) -> Option<TransitionSpec> {
        if s == "uniform" {
            return Some(TransitionSpec::Uniform);
        }
        if let Some(rest) = s.strip_prefix("exact:") {
            return AlphaSchedule::parse(rest).map(TransitionSpec::Exact);
        }
        if let Some(rest) = s.strip_prefix("beta:") {
            let mut it = rest.split(':');
            let a = it.next()?.parse().ok()?;
            let b = it.next()?.parse().ok()?;
            return Some(TransitionSpec::Beta { a, b });
        }
        None
    }
}

fn apply_order(taus: &mut [usize], order: TransitionOrder) {
    match order {
        TransitionOrder::Random => {}
        // reverse-time generation: a *larger* τ is decoded *earlier*,
        // so left-to-right decode order = descending τ by position.
        TransitionOrder::LeftToRight => taus.sort_by(|a, b| b.cmp(a)),
        TransitionOrder::RightToLeft => taus.sort(),
    }
}

fn beta_pdf(x: f64, a: f64, b: f64) -> f64 {
    if x <= 0.0 || x >= 1.0 {
        return 0.0;
    }
    ((a - 1.0) * x.ln() + (b - 1.0) * (1.0 - x).ln() - ln_beta(a, b)).exp()
}

fn ln_beta(a: f64, b: f64) -> f64 {
    ln_gamma(a) + ln_gamma(b) - ln_gamma(a + b)
}

/// Lanczos ln Γ.
pub fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // reflection
        return std::f64::consts::PI.ln()
            - (std::f64::consts::PI * x).sin().ln()
            - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = G[0];
    let t = x + 7.5;
    for (i, &g) in G.iter().enumerate().skip(1) {
        a += g / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// The sampled set 𝒯 with the event structure the samplers iterate over.
#[derive(Debug, Clone)]
pub struct TransitionTimes {
    /// τ_n per position, values in 1..=T.
    pub taus: Vec<usize>,
    pub t_max: usize,
    /// distinct transition times, descending — the reverse-loop event list.
    events: Vec<usize>,
}

impl TransitionTimes {
    pub fn new(taus: Vec<usize>, t_max: usize) -> Self {
        let mut events: Vec<usize> = taus.clone();
        events.sort_unstable_by(|a, b| b.cmp(a));
        events.dedup();
        Self { taus, t_max, events }
    }

    /// |𝒯| — exactly the number of function evaluations Algorithm 1 makes.
    pub fn nfe(&self) -> usize {
        self.events.len()
    }

    /// Distinct transition times, descending (reverse-time order).
    pub fn events(&self) -> &[usize] {
        &self.events
    }

    pub fn is_event(&self, t: usize) -> bool {
        self.events.binary_search_by(|e| t.cmp(e)).is_ok()
    }

    /// Positions with τ_n == t (they flip to x̂0 at step t; eq. 9).
    pub fn moves_at(&self, t: usize) -> Vec<usize> {
        (0..self.taus.len()).filter(|&n| self.taus[n] == t).collect()
    }

    /// Positions with τ_n ≥ t (Algorithm 3's re-update set).
    pub fn moved_by(&self, t: usize) -> Vec<usize> {
        (0..self.taus.len()).filter(|&n| self.taus[n] >= t).collect()
    }

    /// K_t = Σ_n 1(τ_n ≥ t) — the top-k count sequence of Algorithm 4.
    pub fn k_t(&self, t: usize) -> usize {
        self.taus.iter().filter(|&&tau| tau >= t).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> SplitMix64 {
        SplitMix64::new(0xD17F)
    }

    #[test]
    fn exact_sampler_matches_pmf() {
        // Theorem 3.6: empirical τ frequencies ≈ α_{t−1} − α_t
        for sched in [AlphaSchedule::Linear, AlphaSchedule::CosineSq] {
            let spec = TransitionSpec::Exact(sched);
            let t_max = 10;
            let pmf = spec.pmf(t_max);
            let mut counts = vec![0usize; t_max];
            let mut r = rng();
            let trials = 60_000;
            for _ in 0..trials {
                counts[spec.sample_discrete(t_max, &mut r) - 1] += 1;
            }
            for k in 0..t_max {
                let f = counts[k] as f64 / trials as f64;
                assert!((f - pmf[k]).abs() < 0.012, "{sched:?} k={} {f} vs {}", k + 1, pmf[k]);
            }
        }
    }

    #[test]
    fn beta_sampler_in_range_and_shaped() {
        let spec = TransitionSpec::Beta { a: 15.0, b: 7.0 };
        let mut r = rng();
        let t_max = 50;
        let mut counts = vec![0usize; t_max];
        for _ in 0..20_000 {
            let k = spec.sample_discrete(t_max, &mut r);
            assert!((1..=t_max).contains(&k));
            counts[k - 1] += 1;
        }
        // mode should be near T·a/(a+b) ≈ 34
        let mode = counts.iter().enumerate().max_by_key(|(_, &c)| c).unwrap().0 + 1;
        assert!((28..=40).contains(&mode), "mode {mode}");
    }

    #[test]
    fn beta_pmf_normalizes_and_matches_sampler() {
        let spec = TransitionSpec::Beta { a: 3.0, b: 3.0 };
        let t_max = 25;
        let pmf = spec.pmf(t_max);
        assert!((pmf.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        let mut r = rng();
        let mut counts = vec![0usize; t_max];
        let trials = 60_000;
        for _ in 0..trials {
            counts[spec.sample_discrete(t_max, &mut r) - 1] += 1;
        }
        for k in 0..t_max {
            let f = counts[k] as f64 / trials as f64;
            assert!((f - pmf[k]).abs() < 0.012, "k={} {f} vs {}", k + 1, pmf[k]);
        }
    }

    #[test]
    fn continuous_sampler_matches_alpha_cdf() {
        let spec = TransitionSpec::Exact(AlphaSchedule::CosineSq);
        let mut r = rng();
        let n = 40_000;
        let mut below_half = 0;
        for _ in 0..n {
            let tau = spec.sample_continuous(&mut r);
            assert!((0.0..=1.0).contains(&tau));
            if tau <= 0.5 {
                below_half += 1;
            }
        }
        // ℙ(τ ≤ 0.5) = 1 − α(0.5) = 1 − cos²(π/4) = 0.5
        let f = below_half as f64 / n as f64;
        assert!((f - 0.5).abs() < 0.01, "{f}");
    }

    #[test]
    fn expected_nfe_bounds_thm_d1() {
        let spec = TransitionSpec::Exact(AlphaSchedule::Linear);
        for (t_max, n) in [(25usize, 16usize), (50, 16), (1000, 16), (16, 16)] {
            let e = spec.expected_nfe(t_max, n);
            assert!(e >= 1.0 && e <= t_max.min(n) as f64 + 1e-9, "T={t_max} N={n} E={e}");
        }
        // uniform case closed form: E = T·[1 − (1−1/T)^N]
        let e = spec.expected_nfe(50, 16);
        let closed = 50.0 * (1.0 - (1.0 - 0.02f64).powi(16));
        assert!((e - closed).abs() < 1e-9);
    }

    #[test]
    fn empirical_nfe_matches_expectation() {
        let spec = TransitionSpec::Exact(AlphaSchedule::Linear);
        let (t_max, n) = (50, 16);
        let mut r = rng();
        let mut total = 0usize;
        let reps = 4000;
        for _ in 0..reps {
            total += spec
                .sample_times(t_max, n, TransitionOrder::Random, &mut r)
                .nfe();
        }
        let emp = total as f64 / reps as f64;
        let exp = spec.expected_nfe(t_max, n);
        assert!((emp - exp).abs() < 0.15, "{emp} vs {exp}");
    }

    #[test]
    fn order_assignment() {
        let spec = TransitionSpec::Exact(AlphaSchedule::Linear);
        let mut r = rng();
        let tt = spec.sample_times(100, 10, TransitionOrder::LeftToRight, &mut r);
        for w in tt.taus.windows(2) {
            assert!(w[0] >= w[1], "L2R must decode left first (descending τ)");
        }
        let tt = spec.sample_times(100, 10, TransitionOrder::RightToLeft, &mut r);
        for w in tt.taus.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn event_structure() {
        let tt = TransitionTimes::new(vec![5, 3, 5, 9, 1], 10);
        assert_eq!(tt.nfe(), 4);
        assert_eq!(tt.events(), &[9, 5, 3, 1]);
        assert!(tt.is_event(5) && !tt.is_event(4));
        assert_eq!(tt.moves_at(5), vec![0, 2]);
        assert_eq!(tt.moved_by(5), vec![0, 2, 3]);
        assert_eq!(tt.k_t(5), 3);
        assert_eq!(tt.k_t(1), 5);
        assert_eq!(tt.k_t(10), 0);
    }

    #[test]
    fn nfe_capped_by_min_n_t() {
        let spec = TransitionSpec::Beta { a: 5.0, b: 3.0 };
        let mut r = rng();
        for (t_max, n) in [(8usize, 32usize), (1000, 4)] {
            for _ in 0..200 {
                let tt = spec.sample_times(t_max, n, TransitionOrder::Random, &mut r);
                assert!(tt.nfe() >= 1 && tt.nfe() <= t_max.min(n));
            }
        }
    }

    #[test]
    fn uniform_spec_matches_linear_exact_law() {
        // ℙ(τ=t) under Uniform equals the linear-schedule exact law: 1/T.
        let t_max = 20;
        let uni = TransitionSpec::Uniform.pmf(t_max);
        let lin = TransitionSpec::Exact(AlphaSchedule::Linear).pmf(t_max);
        for (u, l) in uni.iter().zip(&lin) {
            assert!((u - l).abs() < 1e-9, "{u} vs {l}");
        }
        let mut r = rng();
        let mut counts = vec![0usize; t_max];
        let trials = 40_000;
        for _ in 0..trials {
            let k = TransitionSpec::Uniform.sample_discrete(t_max, &mut r);
            assert!((1..=t_max).contains(&k));
            counts[k - 1] += 1;
        }
        for (k, &c) in counts.iter().enumerate() {
            let f = c as f64 / trials as f64;
            assert!((f - 1.0 / t_max as f64).abs() < 0.01, "k={} f={f}", k + 1);
        }
        let tau = TransitionSpec::Uniform.sample_continuous(&mut r);
        assert!((0.0..=1.0).contains(&tau));
        assert_eq!(TransitionSpec::parse("uniform"), Some(TransitionSpec::Uniform));
        assert_eq!(TransitionSpec::Uniform.name(), "uniform");
    }

    #[test]
    fn ln_gamma_known_values() {
        assert!((ln_gamma(1.0)).abs() < 1e-10);
        assert!((ln_gamma(5.0) - (24.0f64).ln()).abs() < 1e-9);
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-9);
    }
}
