//! splitmix64 PRNG + the sampling distributions the samplers need.
//!
//! Mirrors `python/compile/common.py::Rng` bit-for-bit (pinned by the
//! fixtures test in `rust/tests/parity.rs`): the synthetic corpora are
//! generated from the same streams on both sides of the build.

/// splitmix64 — 64-bit state, passes BigCrush, two lines of code.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// f64 in [0, 1): top 53 bits / 2^53 (identical to python).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// integer in [0, n) — modulo, same (negligible, identical) bias as python.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Derive an independent child stream (same rule as python's fork()).
    pub fn fork(&mut self, stream: u64) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ 0xA076_1D64_78BD_642Fu64.wrapping_mul(stream + 1))
    }

    // -- distributions used by the samplers (rust-only; no parity needed) --

    /// Gumbel(0,1): −ln(−ln U) with U clamped away from {0,1}.
    #[inline]
    pub fn gumbel(&mut self) -> f64 {
        let u = self.uniform().max(1e-300);
        -(-(u.ln())).ln()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (with the α<1 boost).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Gamma(a) = Gamma(a+1) * U^{1/a}
            let g = self.gamma(shape + 1.0);
            let u = self.uniform().max(1e-300);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.uniform().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v3 + d * v3.ln() {
                return d * v3;
            }
        }
    }

    /// Beta(a, b) via two gammas.
    pub fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a);
        let y = self.gamma(b);
        x / (x + y)
    }

    /// Draw an index from an unnormalized weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "categorical with zero mass");
        let mut r = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pinned_reference_sequence() {
        // same numbers as python/tests/test_data.py::test_rng_reference_values
        let mut r = SplitMix64::new(42);
        assert_eq!(r.next_u64(), 13679457532755275413);
        assert_eq!(r.next_u64(), 2949826092126892291);
        assert_eq!(r.next_u64(), 5139283748462763858);
        assert_eq!(r.next_u64(), 6349198060258255764);
    }

    #[test]
    fn uniform_matches_python_and_stays_in_range() {
        let mut r = SplitMix64::new(7);
        let u = r.uniform();
        assert!((u - 0.389829748391).abs() < 1e-12);
        let mut r = SplitMix64::new(123);
        let us: Vec<f64> = (0..10_000).map(|_| r.uniform()).collect();
        assert!(us.iter().all(|&u| (0.0..1.0).contains(&u)));
        let mean = us.iter().sum::<f64>() / us.len() as f64;
        assert!((mean - 0.5).abs() < 0.02);
    }

    #[test]
    fn fork_streams_diverge() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(1);
        let mut fa = a.fork(1);
        let mut fb = b.fork(2);
        assert_ne!(fa.next_u64(), fb.next_u64());
    }

    #[test]
    fn gumbel_max_trick_matches_softmax() {
        // argmax(logit + G) frequencies ≈ softmax(logits)
        let logits = [0.0f64, (2.0f64).ln(), (3.0f64).ln()];
        let mut r = SplitMix64::new(99);
        let mut counts = [0usize; 3];
        let trials = 60_000;
        for _ in 0..trials {
            let mut best = f64::NEG_INFINITY;
            let mut arg = 0;
            for (i, &l) in logits.iter().enumerate() {
                let v = l + r.gumbel();
                if v > best {
                    best = v;
                    arg = i;
                }
            }
            counts[arg] += 1;
        }
        let exp = [1.0 / 6.0, 2.0 / 6.0, 3.0 / 6.0];
        for i in 0..3 {
            let f = counts[i] as f64 / trials as f64;
            assert!((f - exp[i]).abs() < 0.01, "cat {i}: {f} vs {}", exp[i]);
        }
    }

    #[test]
    fn beta_moments() {
        let (a, b) = (15.0, 7.0);
        let mut r = SplitMix64::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.beta(a, b)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let em = a / (a + b);
        let ev = a * b / ((a + b) * (a + b) * (a + b + 1.0));
        assert!((mean - em).abs() < 0.01, "mean {mean} vs {em}");
        assert!((var - ev).abs() < 0.005, "var {var} vs {ev}");
        assert!(xs.iter().all(|&x| (0.0..=1.0).contains(&x)));
    }

    #[test]
    fn gamma_small_shape_is_finite_positive() {
        let mut r = SplitMix64::new(11);
        for _ in 0..2_000 {
            let g = r.gamma(0.3);
            assert!(g.is_finite() && g >= 0.0);
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = SplitMix64::new(3);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let f0 = counts[0] as f64 / 40_000.0;
        assert!((f0 - 0.25).abs() < 0.01);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(8);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
