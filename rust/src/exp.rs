//! Experiment harness shared by the paper-table/figure benches
//! (`rust/benches/`) and the CLI.
//!
//! Each function evaluates one *cell* of a paper table: (model, sampler,
//! steps) → (quality, wall-clock, avg NFE). Scaling note: the paper
//! evaluates 2k–6.75k sentences per cell on an A6000; this testbed is one
//! CPU core, so cells default to `DNDM_BENCH_COUNT` (16) sentences and the
//! step grid swaps {25, 50, 1000} for {10, 25, 50} on baseline samplers —
//! the 1000-step and ∞ rows stay exact for the DNDM family, whose cost is
//! |𝒯| ≤ N regardless of T (that asymmetry is the paper's point). Ratios,
//! orderings and curve shapes are what we reproduce, not absolute seconds.

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::Engine;
use crate::data::{corpus, gen_pairs, Dataset, Split, UncondCorpus};
use crate::metrics::bleu::corpus_bleu_str;
use crate::metrics::NgramLm;
use crate::runtime::Artifacts;
use crate::sampler::SamplerConfig;

/// One table cell.
#[derive(Debug, Clone)]
pub struct Cell {
    pub quality: f64, // BLEU or perplexity
    pub time_s: f64,
    pub avg_nfe: f64,
}

/// Env-tunable eval size (sentences per cell).
pub fn bench_count() -> usize {
    std::env::var("DNDM_BENCH_COUNT").ok().and_then(|v| v.parse().ok()).unwrap_or(16)
}

pub fn bench_batch() -> usize {
    std::env::var("DNDM_BENCH_BATCH").ok().and_then(|v| v.parse().ok()).unwrap_or(16)
}

/// Load artifacts from the conventional location, or explain how to build.
pub fn artifacts() -> Result<Artifacts> {
    let root = std::env::var("DNDM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    Artifacts::load(Path::new(&root))
}

/// Skip-or-panic helper for bench binaries: benches print a skip note and
/// exit 0 when artifacts are absent (so `cargo bench` works pre-build).
pub fn artifacts_or_skip(bench: &str) -> Option<Artifacts> {
    match artifacts() {
        Ok(a) => Some(a),
        Err(e) => {
            println!("[{bench}] SKIP — no artifacts ({e}); run `make artifacts` first");
            None
        }
    }
}


/// Engine with its batch buckets pre-compiled (keeps XLA compile time out
/// of the timed region of every table cell).
pub fn engine_warm(arts: &Artifacts, name: &str, batch: usize) -> Result<Engine> {
    let eng = Engine::new(arts, name)?;
    eng.warmup(&[1, batch])?;
    Ok(eng)
}

/// Evaluate one translation cell: BLEU over the synthetic test split.
pub fn eval_translation(
    eng: &Engine,
    ds: Dataset,
    cfg: &SamplerConfig,
    count: usize,
    batch: usize,
    seed: u64,
) -> Result<Cell> {
    eng.nfe.reset();
    let pairs = gen_pairs(ds, Split::Test, count);
    let mut hyps = Vec::with_capacity(count);
    let mut refs = Vec::with_capacity(count);
    let t0 = Instant::now();
    for (ci, chunk) in pairs.chunks(batch).enumerate() {
        let srcs: Vec<String> = chunk.iter().map(|(s, _)| s.join(" ")).collect();
        let (outs, _) = eng.generate_batch(Some(&srcs), srcs.len(), cfg, seed + ci as u64)?;
        for ((_, tgt), out) in chunk.iter().zip(outs) {
            hyps.push(out.text);
            refs.push(tgt.join(" "));
        }
    }
    Ok(Cell {
        quality: corpus_bleu_str(&hyps, &refs),
        time_s: t0.elapsed().as_secs_f64(),
        avg_nfe: eng.nfe.avg_nfe(),
    })
}

/// Evaluate one unconditional cell: n-gram-LM perplexity of generated text.
/// The LM is fit on held-out *real* corpus text (the GPT-2 substitute).
pub fn eval_unconditional(
    eng: &Engine,
    corpus_kind: UncondCorpus,
    cfg: &SamplerConfig,
    count: usize,
    batch: usize,
    seed: u64,
) -> Result<Cell> {
    eng.nfe.reset();
    let lm = scorer_for(corpus_kind);
    let vocab = corpus_kind.vocab();

    let mut all_ids: Vec<u32> = Vec::new();
    let t0 = Instant::now();
    let mut done = 0usize;
    let mut ci = 0u64;
    while done < count {
        let b = batch.min(count - done);
        let (outs, _) = eng.generate_batch(None, b, cfg, seed + ci)?;
        for o in outs {
            // score the characters actually emitted (specials dropped)
            for ch in o.text.chars() {
                if let Some(id) = vocab.id(&ch.to_string()) {
                    all_ids.push(id);
                }
            }
        }
        done += b;
        ci += 1;
    }
    Ok(Cell {
        quality: lm.perplexity(&all_ids),
        time_s: t0.elapsed().as_secs_f64(),
        avg_nfe: eng.nfe.avg_nfe(),
    })
}

/// The external-LM scorer (Table 4's GPT-2 stand-in): char-4-gram KN LM
/// fit on 60k chars of held-out real corpus text.
pub fn scorer_for(corpus_kind: UncondCorpus) -> NgramLm {
    let vocab = corpus_kind.vocab();
    let stream: Vec<u32> = corpus::gen_text_stream(corpus_kind, Split::Valid, 60_000)
        .chars()
        .map(|c| vocab.id(&c.to_string()).unwrap_or(vocab.unk_id()))
        .collect();
    let mut lm = NgramLm::new(4, vocab.len());
    lm.fit(&stream);
    lm
}

/// Append a TSV block to `bench_data/<name>.tsv` (EXPERIMENTS.md source).
pub fn save_tsv(name: &str, tsv: &str) {
    let dir = Path::new("bench_data");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(format!("{name}.tsv"));
    if let Err(e) = std::fs::write(&path, tsv) {
        eprintln!("[exp] could not write {path:?}: {e}");
    } else {
        println!("[exp] wrote {path:?}");
    }
}

/// fmt helper: "31.45" / "-" for missing.
pub fn fmt_q(q: f64) -> String {
    if q.is_finite() {
        format!("{q:.2}")
    } else {
        "-".into()
    }
}

/// The paper's validated Beta(a, b) 𝒟_τ choices (Appendix F.1).
pub fn paper_beta(kind: &str, ds: Dataset) -> crate::schedule::TransitionSpec {
    use crate::schedule::TransitionSpec as S;
    match (kind, ds) {
        ("multinomial", Dataset::Iwslt14) => S::Beta { a: 15.0, b: 7.0 },
        ("multinomial", Dataset::Wmt14) => S::Beta { a: 5.0, b: 3.0 },
        ("multinomial", Dataset::Wmt16) => S::Beta { a: 20.0, b: 7.0 },
        ("absorbing", Dataset::Wmt16) => S::Beta { a: 5.0, b: 3.0 },
        _ => S::Beta { a: 3.0, b: 3.0 }, // absorbing iwslt14 / wmt14
    }
}

/// Continuous-time Beta choices (Appendix F.1: Beta(17,4) IWSLT, else (100,4)).
pub fn paper_beta_continuous(ds: Dataset) -> crate::schedule::TransitionSpec {
    use crate::schedule::TransitionSpec as S;
    match ds {
        Dataset::Iwslt14 => S::Beta { a: 17.0, b: 4.0 },
        _ => S::Beta { a: 100.0, b: 4.0 },
    }
}

/// Step grid for baseline-inclusive rows. The paper uses {25, 50, 1000};
/// on one CPU core a 1000-step baseline cell costs ~10 min, so the default
/// grid is {10, 25, 50} and 1000-step rows run DNDM-family only (their
/// cost is |𝒯| ≤ N regardless of T — the asymmetry under study).
/// DNDM_BENCH_FULL=1 restores the paper grid for everything.
pub fn step_grid_baseline() -> Vec<usize> {
    if std::env::var("DNDM_BENCH_FULL").is_ok() {
        vec![25, 50, 1000]
    } else {
        vec![10, 25, 50]
    }
}

pub fn step_grid_dndm() -> Vec<usize> {
    if std::env::var("DNDM_BENCH_FULL").is_ok() {
        vec![25, 50, 1000]
    } else {
        vec![10, 25, 50, 1000]
    }
}

/// Shared driver for Tables 2 (multinomial) and 3 (absorbing), with the
/// avg-NFE columns of Tables 7/8 folded in.
pub fn run_translation_table(kind: &str, table: &str) -> Result<()> {
    use crate::sampler::{SamplerConfig, SamplerKind};
    use crate::util::bench::Table;

    let arts = artifacts()?;
    let (count, batch) = (bench_count(), bench_batch());
    let mut out = Table::new(&[
        "dataset", "steps", "sampler", "BLEU", "time(s)", "avgNFE",
    ]);

    for ds in Dataset::ALL {
        let Some(m) = arts.find(kind, ds.name(), false) else {
            println!("[{table}] no {kind} model for {}", ds.name());
            continue;
        };
        let eng = engine_warm(&arts, &m.name, batch)?;
        let spec = paper_beta(kind, ds);

        // baselines: RDM / RDM-k at the baseline grid
        for &steps in &step_grid_baseline() {
            for sk in [SamplerKind::Rdm, SamplerKind::RdmTopK] {
                let cfg = SamplerConfig::new(sk, steps);
                let cell = eval_translation(&eng, ds, &cfg, count, batch, 0)?;
                out.row(&[
                    ds.short().into(),
                    steps.to_string(),
                    sk.name().into(),
                    fmt_q(cell.quality),
                    format!("{:.2}", cell.time_s),
                    format!("{:.2}", cell.avg_nfe),
                ]);
            }
        }
        // DNDM family: full grid + ∞
        for &steps in &step_grid_dndm() {
            for sk in [SamplerKind::Dndm, SamplerKind::DndmTopK] {
                let cfg = SamplerConfig::new(sk, steps).with_spec(spec.clone());
                let cell = eval_translation(&eng, ds, &cfg, count, batch, 0)?;
                out.row(&[
                    ds.short().into(),
                    steps.to_string(),
                    sk.name().into(),
                    fmt_q(cell.quality),
                    format!("{:.2}", cell.time_s),
                    format!("{:.2}", cell.avg_nfe),
                ]);
            }
        }
        for sk in [SamplerKind::DndmC, SamplerKind::DndmTopK] {
            // ∞ row: DNDM-C (and its top-k analog approximated by 𝒯 from
            // the continuous Beta at T=4000)
            let (cfg, label) = if sk == SamplerKind::DndmC {
                (
                    SamplerConfig::new(SamplerKind::DndmC, 0)
                        .with_spec(paper_beta_continuous(ds)),
                    "dndm(∞)",
                )
            } else {
                (
                    SamplerConfig::new(SamplerKind::DndmTopK, 4000)
                        .with_spec(paper_beta_continuous(ds)),
                    "dndm-k(∞)",
                )
            };
            let cell = eval_translation(&eng, ds, &cfg, count, batch, 0)?;
            out.row(&[
                ds.short().into(),
                "inf".into(),
                label.into(),
                fmt_q(cell.quality),
                format!("{:.2}", cell.time_s),
                format!("{:.2}", cell.avg_nfe),
            ]);
        }
    }

    println!("\n== {table} ({kind} diffusion): BLEU / time / avg NFE ==");
    println!("   (count={count} batch={batch}; paper Tables 7/8 = the avgNFE column)");
    out.print();
    save_tsv(table, &out.to_tsv());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::Engine;
    use crate::data::words;
    use crate::runtime::MockDenoiser;
    use crate::sampler::SamplerKind;

    fn mock_engine(kind: &str) -> Engine {
        let vocab = words::translation_vocab();
        let cfg = MockDenoiser::test_config(vocab.len(), 16, 16, kind);
        // perfect iwslt cipher: src id + 41
        let den = MockDenoiser::with_fn(cfg, |src, pos| {
            let s = src.map(|s| s[pos]).unwrap_or(0);
            if s >= 3 && (s as usize) < 3 + 41 {
                s + 41
            } else {
                0 // pad stays pad
            }
        });
        Engine::from_denoiser(Box::new(den), vocab, "mock")
    }

    #[test]
    fn perfect_mock_gets_bleu_100_on_iwslt() {
        let eng = mock_engine("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50);
        let cell = eval_translation(&eng, Dataset::Iwslt14, &cfg, 8, 4, 0).unwrap();
        assert!(cell.quality > 99.0, "BLEU {}", cell.quality);
        assert!(cell.avg_nfe >= 1.0 && cell.avg_nfe <= 16.0);
        assert!(cell.time_s > 0.0);
    }

    #[test]
    fn perfect_iwslt_mock_fails_wmt14() {
        // the same cipher is wrong for wmt14 (reversed + synonyms) — the
        // difficulty ordering the datasets are designed for.
        let eng = mock_engine("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50);
        let easy = eval_translation(&eng, Dataset::Iwslt14, &cfg, 8, 4, 0).unwrap();
        let hard = eval_translation(&eng, Dataset::Wmt14, &cfg, 8, 4, 0).unwrap();
        assert!(hard.quality < easy.quality);
    }

    #[test]
    fn nfe_resets_between_cells() {
        let eng = mock_engine("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50);
        let a = eval_translation(&eng, Dataset::Iwslt14, &cfg, 4, 4, 0).unwrap();
        let b = eval_translation(&eng, Dataset::Iwslt14, &cfg, 4, 4, 0).unwrap();
        assert!((a.avg_nfe - b.avg_nfe).abs() < 1e-9);
    }

    #[test]
    fn scorer_prefers_real_text() {
        let lm = scorer_for(UncondCorpus::Text8);
        let vocab = UncondCorpus::Text8.vocab();
        let real: Vec<u32> = corpus::gen_text_stream(UncondCorpus::Text8, Split::Test, 1000)
            .chars()
            .map(|c| vocab.id(&c.to_string()).unwrap())
            .collect();
        let garbage: Vec<u32> = (0..1000).map(|i| 3 + (i * 7 % 27) as u32).collect();
        assert!(lm.perplexity(&real) < lm.perplexity(&garbage));
    }
}
