//! Shared decode-loop machinery: the native transition update (the rust
//! twin of the fused L1 Pallas kernel), x̂0 draws, noise init.

use crate::diffusion::NoiseKind;
use crate::runtime::ModelConfig;
use crate::schedule::SplitMix64;
use crate::tensor::TokenBatch;

/// q_noise from a model config.
pub fn noise_of(cfg: &ModelConfig) -> NoiseKind {
    if cfg.kind == "absorbing" {
        NoiseKind::Absorbing { mask_id: cfg.mask_id }
    } else {
        NoiseKind::Multinomial { lo: cfg.noise_lo, vocab: cfg.vocab as u32 }
    }
}

/// Draw x̂0 for one position from its logits row.
///
/// temperature > 0: Gumbel-max categorical draw at that temperature;
/// temperature = 0: greedy argmax. Returns (token, log-prob score) where
/// the score is log p(token | logits) — the ranking signal of DNDM-k /
/// RDM-k (Appendix E).
#[inline]
pub fn sample_x0(logits: &[f32], temperature: f32, rng: &mut SplitMix64) -> (u32, f32) {
    debug_assert!(!logits.is_empty());
    let mut best = f32::NEG_INFINITY;
    let mut arg = 0usize;
    if temperature > 0.0 {
        for (i, &l) in logits.iter().enumerate() {
            let val = l + temperature * rng.gumbel() as f32;
            if val > best {
                best = val;
                arg = i;
            }
        }
    } else {
        for (i, &l) in logits.iter().enumerate() {
            if l > best {
                best = l;
                arg = i;
            }
        }
    }
    (arg as u32, log_prob(logits, arg))
}

/// `log softmax(logits)[idx]`, numerically stable single pass.
#[inline]
pub fn log_prob(logits: &[f32], idx: usize) -> f32 {
    let mut mx = f32::NEG_INFINITY;
    for &l in logits {
        mx = mx.max(l);
    }
    let mut sum = 0.0f32;
    for &l in logits {
        sum += (l - mx).exp();
    }
    logits[idx] - mx - sum.ln()
}

/// Per-position logits row accessor for flattened [N*V] logits.
#[inline]
pub fn row(logits: &[f32], pos: usize, vocab: usize) -> &[f32] {
    &logits[pos * vocab..(pos + 1) * vocab]
}

/// Initialize x_T ~ q_noise for a batch. Rows are drawn in batch order so
/// the RNG stream is identical to the historical row-of-rows init.
pub fn init_noise(batch: usize, n: usize, noise: NoiseKind, rng: &mut SplitMix64) -> TokenBatch {
    let mut x = TokenBatch::filled(batch, n, 0);
    for b in 0..batch {
        for tok in x.row_mut(b) {
            *tok = noise.sample(rng);
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax_and_score_is_logprob() {
        let logits = [0.0f32, 3.0, 1.0];
        let mut rng = SplitMix64::new(1);
        let (tok, score) = sample_x0(&logits, 0.0, &mut rng);
        assert_eq!(tok, 1);
        let z: f32 = logits.iter().map(|l| l.exp()).sum();
        assert!((score - (3.0f32.exp() / z).ln()).abs() < 1e-5);
        assert!(score <= 0.0);
    }

    #[test]
    fn temperature_sampling_matches_softmax_frequencies() {
        let logits = [0.0f32, (2.0f32).ln(), (3.0f32).ln()];
        let mut rng = SplitMix64::new(2);
        let mut counts = [0usize; 3];
        let n = 30_000;
        for _ in 0..n {
            counts[sample_x0(&logits, 1.0, &mut rng).0 as usize] += 1;
        }
        for (i, want) in [1.0 / 6.0, 2.0 / 6.0, 3.0 / 6.0].iter().enumerate() {
            let f = counts[i] as f64 / n as f64;
            assert!((f - want).abs() < 0.015, "cat {i}: {f} vs {want}");
        }
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let logits = [0.0f32, 2.0, 1.0];
        let mut rng = SplitMix64::new(3);
        let hits = (0..1000)
            .filter(|_| sample_x0(&logits, 0.05, &mut rng).0 == 1)
            .count();
        assert!(hits > 990, "{hits}");
    }

    #[test]
    fn row_indexing() {
        let logits: Vec<f32> = (0..12).map(|x| x as f32).collect();
        assert_eq!(row(&logits, 1, 4), &[4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn noise_of_maps_kinds() {
        let mut cfg = crate::runtime::MockDenoiser::test_config(30, 4, 0, "absorbing");
        assert_eq!(noise_of(&cfg), NoiseKind::Absorbing { mask_id: 2 });
        cfg.kind = "multinomial".into();
        assert_eq!(noise_of(&cfg), NoiseKind::Multinomial { lo: 3, vocab: 30 });
    }
}
