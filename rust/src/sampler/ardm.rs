//! ARDM-style baseline (Hoogeboom et al. 2021a) — Remark 3.7's comparator.
//!
//! The autoregressive diffusion model is equivalent to continuous-time
//! absorbing diffusion decoded one position per step in a random order:
//! exactly N network calls for N tokens. DNDM-C also reaches N calls in
//! the T→∞ limit, but covers multinomial noise too and accelerates
//! *finite*-T sampling — this baseline makes that comparison runnable.
//!
//! `parallel` > 1 implements the spirit of ARDM's parallelized variant:
//! decode k positions per call, trading NFE for quality.

use anyhow::{bail, Result};

use crate::runtime::Denoiser;
use crate::tensor::{LogitsView, TokenBatch};

use super::common::sample_x0;
use super::session::{self, AlgState, Core, SamplerSession};
use super::{GenResult, SamplerConfig};

/// Session state: one shared random decode order (σ in ARDM, like DNDM's
/// shared 𝒯), advanced `parallel` positions per event.
pub(crate) struct ArdmState {
    order: Vec<usize>,
    done: usize,
    parallel: usize,
}

impl ArdmState {
    pub(crate) fn new(core: &mut Core, parallel: usize) -> ArdmState {
        let mut order: Vec<usize> = (0..core.n).collect();
        core.rng.shuffle(&mut order);
        ArdmState { order, done: 0, parallel: parallel.max(1) }
    }
}

impl AlgState for ArdmState {
    fn next_t(&self, core: &Core) -> Option<(f32, f64)> {
        if self.done < core.n {
            // time = fraction of tokens still masked (the absorbing coupling)
            let t_norm = 1.0 - self.done as f32 / core.n as f32;
            Some((t_norm, t_norm as f64))
        } else {
            None
        }
    }

    fn advance(&mut self, core: &mut Core, logits: LogitsView<'_>) -> usize {
        let end = (self.done + self.parallel).min(core.n);
        let t_norm = 1.0 - self.done as f32 / core.n as f32;
        let moved = core.x.rows();
        for b in 0..moved {
            for &pos in &self.order[self.done..end] {
                let (tok, _) =
                    sample_x0(logits.row(b, pos), core.temperature, &mut core.row_rngs[b]);
                core.x.set(b, pos, tok);
            }
        }
        self.done = end;
        core.finish_event(t_norm as f64);
        moved
    }

    fn total_events(&self) -> usize {
        // ⌈N / parallel⌉ calls decode all N positions
        self.order.len().div_ceil(self.parallel)
    }

    fn split_rows(&mut self, _rows: &[usize]) -> Box<dyn AlgState> {
        // the decode order σ is shared (like DNDM's shared 𝒯); every row
        // decodes the same positions at the same events
        Box::new(ArdmState {
            order: self.order.clone(),
            done: self.done,
            parallel: self.parallel,
        })
    }
}

/// Run-to-completion wrapper with an explicit `parallel` (the `generate()`
/// dispatch uses 1 through `SamplerSession`; the unit tests below probe
/// the parallelized variant).
pub fn run(
    den: &dyn Denoiser,
    cfg: &SamplerConfig,
    src: Option<&[Vec<u32>]>,
    batch: usize,
    seed: u64,
    parallel: usize,
) -> Result<GenResult> {
    let mcfg = den.config();
    if mcfg.kind != "absorbing" {
        bail!("ardm baseline requires an absorbing model");
    }
    let mut core = session::build_core(mcfg, cfg, batch, seed, true);
    let alg = Box::new(ArdmState::new(&mut core, parallel));
    let src_tb = src.map(TokenBatch::from_rows);
    session::drive(den, SamplerSession::from_parts(core, alg, batch), src_tb.as_ref())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockDenoiser;
    use crate::sampler::SamplerKind;

    const TARGET: [u32; 8] = [10, 11, 12, 13, 14, 15, 16, 17];

    fn mock() -> MockDenoiser {
        let cfg = MockDenoiser::test_config(20, 8, 0, "absorbing");
        MockDenoiser::fixed(cfg, TARGET.to_vec())
    }

    #[test]
    fn ardm_uses_exactly_n_calls_and_converges() {
        let den = mock();
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 0);
        let out = run(&den, &cfg, None, 2, 5, 1).unwrap();
        assert_eq!(out.nfe, 8, "one call per token");
        for seq in &out.tokens {
            assert_eq!(seq, &TARGET.to_vec());
        }
    }

    #[test]
    fn parallel_variant_reduces_nfe() {
        let den = mock();
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 0);
        let out = run(&den, &cfg, None, 1, 5, 4).unwrap();
        assert_eq!(out.nfe, 2);
        assert_eq!(out.tokens[0], TARGET.to_vec());
    }

    #[test]
    fn decode_order_is_a_permutation() {
        let den = mock();
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 0).with_trace();
        let out = run(&den, &cfg, None, 1, 9, 1).unwrap();
        // masks strictly decrease by one per event
        let mut prev = 8;
        for tp in &out.trace {
            let masks = tp.tokens.iter().filter(|&&t| t == 2).count();
            assert_eq!(masks, prev - 1);
            prev = masks;
        }
    }

    #[test]
    fn rejects_multinomial() {
        let cfg_m = MockDenoiser::test_config(20, 8, 0, "multinomial");
        let den = MockDenoiser::fixed(cfg_m, TARGET.to_vec());
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 0);
        assert!(run(&den, &cfg, None, 1, 1, 1).is_err());
    }
}
