//! DDIM-discrete comparator (Appendix B.1 of the paper).
//!
//! Song et al. (2020a), Appendix A, sketches a non-Markov *multinomial*
//! process whose reverse kernel is
//!
//!   q(x_{t−1}|x_t, x̂0) = Cat(σ_t·x_t + (α_{t−1} − σ_t·α_t)·x̂0
//!                             + ((1−α_{t−1}) − (1−α_t)·σ_t)·𝟙/K).
//!
//! With the "deterministic" choice σ_t = (1−α_{t−1})/(1−α_t) this becomes
//! Cat(σ_t·x_t + (1−σ_t)·x̂0): **still stochastic at every step** — it
//! cannot tell whether x_t already equals x0, so it keeps re-drawing.
//! That is exactly the paper's point of contrast (Remark 3.5 / B.1):
//! DDIM needs a network call every step (NFE = T), while DNDM's
//! predetermined τ de-randomizes the walk to |𝒯| calls.
//!
//! Implemented as an extra baseline so the contrast is measurable, not
//! just asserted: see the `ablation_comparators` bench rows.

use anyhow::{bail, Result};

use crate::diffusion::NoiseKind;
use crate::runtime::Denoiser;
use crate::schedule::AlphaSchedule;
use crate::tensor::{LogitsView, TokenBatch};

use super::common::sample_x0;
use super::session::{self, AlgState, Core, SamplerSession};
use super::{GenResult, SamplerConfig};

/// Session state for the DDIM-discrete walk; one event per step T..1.
pub(crate) struct DdimState {
    t: usize,
    t_max: usize,
    sched: AlphaSchedule,
    noise: NoiseKind,
    /// σ_t interpolation knob: 1.0 = the paper's "deterministic" DDIM
    /// choice σ_t = (1−α_{t−1})/(1−α_t); 0.0 = fully stochastic.
    eta: f64,
}

impl DdimState {
    pub(crate) fn new(
        cfg: &SamplerConfig,
        sched: AlphaSchedule,
        noise: NoiseKind,
        eta: f64,
    ) -> DdimState {
        DdimState { t: cfg.steps, t_max: cfg.steps, sched, noise, eta }
    }
}

impl AlgState for DdimState {
    fn next_t(&self, _core: &Core) -> Option<(f32, f64)> {
        if self.t >= 1 {
            let t_norm = self.t as f32 / self.t_max as f32;
            Some((t_norm, t_norm as f64))
        } else {
            None
        }
    }

    fn advance(&mut self, core: &mut Core, logits: LogitsView<'_>) -> usize {
        let t = self.t;
        let t_norm = t as f32 / self.t_max as f32;
        let a_t = self.sched.alpha_discrete(t, self.t_max);
        let a_prev = self.sched.alpha_discrete(t - 1, self.t_max);
        let sigma_max = if a_t >= 1.0 { 0.0 } else { (1.0 - a_prev) / (1.0 - a_t) };
        let sigma = self.eta * sigma_max;
        // mixture weights over {x_t, x̂0, uniform}
        let w_xt = sigma;
        let w_x0 = a_prev - sigma * a_t;
        let w_uni = ((1.0 - a_prev) - (1.0 - a_t) * sigma).max(0.0);
        let moved = core.x.rows();

        for b in 0..moved {
            for pos in 0..core.n {
                let (x0_hat, _) = sample_x0(
                    logits.row(b, pos),
                    core.temperature.max(1.0),
                    &mut core.row_rngs[b],
                );
                let u = core.row_rngs[b].uniform() * (w_xt + w_x0 + w_uni);
                let next = if u < w_xt {
                    core.x.get(b, pos)
                } else if u < w_xt + w_x0 {
                    x0_hat
                } else {
                    self.noise.sample(&mut core.row_rngs[b])
                };
                core.x.set(b, pos, next);
            }
        }
        self.t -= 1;
        core.finish_event(t_norm as f64);
        moved
    }

    fn total_events(&self) -> usize {
        self.t_max
    }

    fn split_rows(&mut self, _rows: &[usize]) -> Box<dyn AlgState> {
        // the countdown is the whole state and it is shared across rows
        Box::new(DdimState {
            t: self.t,
            t_max: self.t_max,
            sched: self.sched,
            noise: self.noise,
            eta: self.eta,
        })
    }
}

/// Run-to-completion wrapper with an explicit η (the `generate()` dispatch
/// uses η = 1.0 through `SamplerSession`; the unit tests below and future
/// ablations probe other values).
pub fn run(
    den: &dyn Denoiser,
    cfg: &SamplerConfig,
    src: Option<&[Vec<u32>]>,
    batch: usize,
    seed: u64,
    eta: f64,
) -> Result<GenResult> {
    let mcfg = den.config();
    if mcfg.kind != "multinomial" {
        bail!("ddim-discrete is defined for multinomial diffusion");
    }
    let sched = AlphaSchedule::parse(&mcfg.schedule).unwrap_or(AlphaSchedule::CosineSq);
    let noise = super::common::noise_of(mcfg);
    let core = session::build_core(mcfg, cfg, batch, seed, false);
    let alg = Box::new(DdimState::new(cfg, sched, noise, eta));
    let src_tb = src.map(TokenBatch::from_rows);
    session::drive(den, SamplerSession::from_parts(core, alg, batch), src_tb.as_ref())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockDenoiser;
    use crate::sampler::SamplerKind;

    const TARGET: [u32; 8] = [10, 11, 12, 13, 14, 15, 16, 17];

    fn mock(kind: &str) -> MockDenoiser {
        let cfg = MockDenoiser::test_config(20, 8, 0, kind);
        let mut m = MockDenoiser::fixed(cfg, TARGET.to_vec());
        m.peak = 14.0;
        m
    }

    #[test]
    fn ddim_converges_with_t_nfe() {
        let den = mock("multinomial");
        let cfg = SamplerConfig::new(SamplerKind::Rdm, 40); // kind unused here
        let out = run(&den, &cfg, None, 2, 7, 1.0).unwrap();
        assert_eq!(out.nfe, 40);
        for seq in &out.tokens {
            let hits = seq.iter().zip(TARGET.iter()).filter(|(a, b)| a == b).count();
            assert!(hits >= 7, "{seq:?}");
        }
    }

    #[test]
    fn ddim_rejects_absorbing() {
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::Rdm, 10);
        assert!(run(&den, &cfg, None, 1, 1, 1.0).is_err());
    }

    #[test]
    fn mixture_weights_are_a_distribution() {
        // internal invariant: at every t, w_xt + w_x0 + w_uni == 1 (η=1)
        let sched = AlphaSchedule::CosineSq;
        let t_max = 50;
        for t in 1..=t_max {
            let a_t = sched.alpha_discrete(t, t_max);
            let a_prev = sched.alpha_discrete(t - 1, t_max);
            let sigma = if a_t >= 1.0 { 0.0 } else { (1.0 - a_prev) / (1.0 - a_t) };
            let total = sigma + (a_prev - sigma * a_t) + ((1.0 - a_prev) - (1.0 - a_t) * sigma);
            assert!((total - 1.0).abs() < 1e-9, "t={t}: {total}");
            assert!(a_prev - sigma * a_t >= -1e-12, "x̂0 weight negative at t={t}");
        }
    }

    #[test]
    fn ddim_remains_stochastic_even_deterministic_sigma() {
        // Remark 3.5: with σ_t = (1−α_{t−1})/(1−α_t) the kernel still mixes
        // x_t and x̂0 — two seeds should diverge somewhere mid-trajectory.
        let den = mock("multinomial");
        let cfg = SamplerConfig::new(SamplerKind::Rdm, 30).with_trace();
        let a = run(&den, &cfg, None, 1, 1, 1.0).unwrap();
        let b = run(&den, &cfg, None, 1, 2, 1.0).unwrap();
        let mid_differs = a
            .trace
            .iter()
            .zip(&b.trace)
            .take(20)
            .any(|(x, y)| x.tokens != y.tokens);
        assert!(mid_differs);
    }
}
