//! DNDM — Algorithms 1, 3 (discrete) and 2 (continuous).
//!
//! The whole point of the paper in one state machine: sample the
//! transition-time set 𝒯 up front, then walk the *event list* (distinct τ
//! values, descending) instead of all T steps. The denoiser runs once per
//! event; every other step is the identity `x_{t−1} = x_t` and costs
//! nothing. `DndmState` / `DndmCState` hold 𝒯 and the event cursor;
//! `session::drive` (or the coordinator's continuous scheduler) supplies
//! the logits one event at a time.

use crate::tensor::LogitsView;

use super::common::sample_x0;
use super::session::{AlgState, Core};
use super::SamplerConfig;

/// Algorithms 1 (`v2 = false`) and 3 (`v2 = true`), batched.
///
/// With `cfg.shared_tau` one 𝒯 is drawn per batch and broadcast over
/// sequences (the paper's batched implementation — NFE per batch = |𝒯|);
/// otherwise each sequence draws its own 𝒯 and the event list is the
/// union (ablation; more calls, finer per-sequence schedules).
pub(crate) struct DndmState {
    /// τ per (sequence, position)
    taus: Vec<Vec<usize>>,
    /// distinct transition times over the whole batch, descending
    events: Vec<usize>,
    idx: usize,
    t_max: usize,
    v2: bool,
}

impl DndmState {
    pub(crate) fn new(core: &mut Core, cfg: &SamplerConfig, batch: usize, v2: bool) -> DndmState {
        let t_max = cfg.steps;
        let taus: Vec<Vec<usize>> = if cfg.shared_tau {
            let tt = cfg.spec.sample_times(t_max, core.n, cfg.order, &mut core.rng);
            vec![tt.taus; batch]
        } else {
            (0..batch)
                .map(|_| cfg.spec.sample_times(t_max, core.n, cfg.order, &mut core.rng).taus)
                .collect()
        };
        let mut events: Vec<usize> = taus.iter().flatten().copied().collect();
        events.sort_unstable_by(|a, b| b.cmp(a));
        events.dedup();
        DndmState { taus, events, idx: 0, t_max, v2 }
    }
}

impl AlgState for DndmState {
    fn next_t(&self, _core: &Core) -> Option<(f32, f64)> {
        self.events.get(self.idx).map(|&t| {
            let t_norm = t as f32 / self.t_max as f32;
            (t_norm, t_norm as f64)
        })
    }

    fn advance(&mut self, core: &mut Core, logits: LogitsView<'_>) {
        let t = self.events[self.idx];
        let t_norm = t as f32 / self.t_max as f32;
        for b in 0..core.x.rows() {
            for pos in 0..core.n {
                let moves =
                    if self.v2 { self.taus[b][pos] >= t } else { self.taus[b][pos] == t };
                if moves {
                    let (tok, _) =
                        sample_x0(logits.row(b, pos), core.temperature, &mut core.row_rngs[b]);
                    core.x.set(b, pos, tok);
                }
            }
        }
        self.idx += 1;
        core.finish_event(t_norm as f64);
    }

    fn taus(&self) -> Option<&[Vec<usize>]> {
        Some(&self.taus)
    }

    fn total_events(&self) -> usize {
        self.events.len()
    }

    fn evict_row(&mut self, row: usize) {
        // the event ladder stays as admitted (see the trait docs); only
        // the per-row τ assignment goes
        self.taus.remove(row);
    }
}

/// Algorithm 2 — DNDM-C (continuous time / infinite steps).
///
/// Transition timestamps are drawn from the continuous 𝒟_τ (density
/// −α′(t), or the Beta approximation) and visited in descending order;
/// ties (which have probability 0 in the continuum but can occur with the
/// rounded Beta) collapse into one event. NFE → N as T → ∞ (Remark D.4).
pub(crate) struct DndmCState {
    /// shared continuous 𝒯 (same broadcast convention as the discrete path)
    taus: Vec<f64>,
    /// position indices, descending by timestamp
    order: Vec<usize>,
    /// cursor into `order`; ties are grouped per event
    k: usize,
    /// distinct events over the whole walk (ties pre-counted with the same
    /// grouping rule `advance` uses)
    total: usize,
}

impl DndmCState {
    pub(crate) fn new(core: &mut Core, cfg: &SamplerConfig) -> DndmCState {
        let taus: Vec<f64> = cfg.spec.sample_times_continuous(core.n, cfg.order, &mut core.rng);
        let mut order: Vec<usize> = (0..core.n).collect();
        order.sort_by(|&a, &b| taus[b].partial_cmp(&taus[a]).unwrap());
        let mut total = 0usize;
        let mut k = 0usize;
        while k < order.len() {
            let t = taus[order[k]];
            let mut j = k + 1;
            while j < order.len() && (taus[order[j]] - t).abs() < 1e-12 {
                j += 1;
            }
            total += 1;
            k = j;
        }
        DndmCState { taus, order, k: 0, total }
    }
}

impl AlgState for DndmCState {
    fn next_t(&self, core: &Core) -> Option<(f32, f64)> {
        if self.k < core.n {
            let t = self.taus[self.order[self.k]];
            Some((t as f32, t))
        } else {
            None
        }
    }

    fn advance(&mut self, core: &mut Core, logits: LogitsView<'_>) {
        let t = self.taus[self.order[self.k]];
        // all positions sharing this timestamp transition together
        let mut j = self.k + 1;
        while j < core.n && (self.taus[self.order[j]] - t).abs() < 1e-12 {
            j += 1;
        }
        for b in 0..core.x.rows() {
            for &pos in &self.order[self.k..j] {
                let (tok, _) =
                    sample_x0(logits.row(b, pos), core.temperature, &mut core.row_rngs[b]);
                core.x.set(b, pos, tok);
            }
        }
        self.k = j;
        core.finish_event(t);
    }

    fn total_events(&self) -> usize {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use crate::runtime::{Denoiser, MockDenoiser};
    use crate::sampler::{generate, SamplerConfig, SamplerKind};
    use crate::schedule::{AlphaSchedule, TransitionSpec};

    fn mock(kind: &str) -> MockDenoiser {
        let cfg = MockDenoiser::test_config(20, 8, 0, kind);
        MockDenoiser::fixed(cfg, vec![10, 11, 12, 13, 14, 15, 16, 17])
    }

    #[test]
    fn converges_to_mock_target_absorbing() {
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50);
        let out = generate(&den, &cfg, None, 2, 7, None).unwrap();
        for seq in &out.tokens {
            assert_eq!(seq, &vec![10, 11, 12, 13, 14, 15, 16, 17]);
        }
    }

    #[test]
    fn converges_to_mock_target_multinomial() {
        let den = mock("multinomial");
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50)
            .with_spec(TransitionSpec::Exact(AlphaSchedule::CosineSq));
        let out = generate(&den, &cfg, None, 3, 9, None).unwrap();
        for seq in &out.tokens {
            assert_eq!(seq, &vec![10, 11, 12, 13, 14, 15, 16, 17]);
        }
    }

    #[test]
    fn nfe_bounded_by_min_n_t_and_calls_match() {
        let den = mock("absorbing");
        for steps in [5usize, 50, 1000] {
            let den = mock("absorbing");
            let cfg = SamplerConfig::new(SamplerKind::Dndm, steps);
            let out = generate(&den, &cfg, None, 4, 3, None).unwrap();
            assert!(out.nfe >= 1 && out.nfe <= steps.min(8), "T={steps} nfe={}", out.nfe);
            assert_eq!(den.calls() as usize, out.nfe, "NN calls must equal |𝒯|");
        }
        let _ = den;
    }

    #[test]
    fn v2_also_converges() {
        let den = mock("multinomial");
        let cfg = SamplerConfig::new(SamplerKind::DndmV2, 50);
        let out = generate(&den, &cfg, None, 2, 5, None).unwrap();
        for seq in &out.tokens {
            assert_eq!(seq, &vec![10, 11, 12, 13, 14, 15, 16, 17]);
        }
    }

    #[test]
    fn continuous_nfe_is_n_when_no_ties() {
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::DndmC, 0)
            .with_spec(TransitionSpec::Exact(AlphaSchedule::Linear));
        let out = generate(&den, &cfg, None, 2, 11, None).unwrap();
        assert_eq!(out.nfe, 8, "continuous τ are a.s. distinct → NFE = N");
        assert_eq!(out.tokens[0], vec![10, 11, 12, 13, 14, 15, 16, 17]);
    }

    #[test]
    fn per_seq_tau_unions_events() {
        let den = mock("absorbing");
        let mut cfg = SamplerConfig::new(SamplerKind::Dndm, 1000);
        cfg.shared_tau = false;
        let out = generate(&den, &cfg, None, 4, 13, None).unwrap();
        // union over 4 sequences ≥ single-sequence NFE, still ≤ 4·N
        assert!(out.nfe <= 32);
        assert_eq!(out.tokens[2], vec![10, 11, 12, 13, 14, 15, 16, 17]);
    }

    #[test]
    fn trace_records_events() {
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50).with_trace();
        let out = generate(&den, &cfg, None, 1, 17, None).unwrap();
        assert_eq!(out.trace.len(), out.nfe);
        // times strictly decreasing
        for w in out.trace.windows(2) {
            assert!(w[0].t > w[1].t);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let den = mock("multinomial");
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50).with_temperature(1.0);
        let a = generate(&den, &cfg, None, 2, 23, None).unwrap();
        let b = generate(&den, &cfg, None, 2, 23, None).unwrap();
        assert_eq!(a.tokens, b.tokens);
        let c = generate(&den, &cfg, None, 2, 24, None).unwrap();
        // different seed → different 𝒯 (tokens may or may not differ, but
        // nfe/trace-level equality would be a miracle with temp 1.0)
        assert!(a.tokens != c.tokens || a.nfe != c.nfe);
    }

    #[test]
    fn absorbing_untouched_positions_stay_masked_midway() {
        // run with only 2 steps so some τ collide; before finishing,
        // positions with τ below the last processed event must be MASK.
        // (We verify the final output instead: after the full run nothing
        // should remain MASK because every τ ∈ 1..=T is processed.)
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 3);
        let out = generate(&den, &cfg, None, 2, 29, None).unwrap();
        for seq in &out.tokens {
            assert!(seq.iter().all(|&t| t != 2), "mask must be fully resolved");
        }
    }
}
