//! DNDM — Algorithms 1, 3 (discrete) and 2 (continuous).
//!
//! The whole point of the paper in one state machine: sample the
//! transition-time set 𝒯 up front, then walk the *event list* (distinct τ
//! values, descending) instead of all T steps. The denoiser runs once per
//! event; every other step is the identity `x_{t−1} = x_t` and costs
//! nothing. `DndmState` / `DndmCState` hold 𝒯 and the event cursor;
//! `session::drive` (or the coordinator's continuous scheduler) supplies
//! the logits one event at a time.

use crate::tensor::LogitsView;

use super::common::sample_x0;
use super::session::{AlgState, Core};
use super::SamplerConfig;

/// Algorithms 1 (`v2 = false`) and 3 (`v2 = true`), batched.
///
/// With `cfg.shared_tau` one 𝒯 is drawn per batch and broadcast over
/// sequences (the paper's batched implementation — NFE per batch = |𝒯|);
/// otherwise each sequence draws its own 𝒯 (ablation; more calls, finer
/// per-sequence schedules).
///
/// Events are scheduled **per row**: each sequence keeps its own ladder
/// of distinct τ values (descending) plus a cursor, and `next_t` merges
/// the survivors lazily by taking the max over the rows' current events.
/// A row fires only at its own ladder events, so evicting or splitting a
/// row retires the events unique to it and `total_events` stays exact —
/// the merged schedule is always the *current* rows' union-|𝒯|.
pub(crate) struct DndmState {
    /// τ per (sequence, position)
    taus: Vec<Vec<usize>>,
    /// per-row event ladders: each row's distinct τ values, descending
    ladders: Vec<Vec<usize>>,
    /// per-row cursor into that row's ladder
    cursors: Vec<usize>,
    /// merged events fired so far (== core.nfe, kept locally for totals)
    fired: usize,
    /// `fired` + distinct events remaining in the current rows' ladders;
    /// recomputed only on eviction / split, so it is exact after both
    total: usize,
    /// merged events dropped by Turbo truncation at construction
    /// (`cfg.max_nfe`); 0 on every untiered session
    truncated: usize,
    t_max: usize,
    v2: bool,
}

/// Distinct event times in the union of every row's remaining ladder
/// suffix. Allocates — called only at construction, eviction, and splits,
/// never on the per-event path (the scheduler's steady-state ticks are
/// pinned allocation-free).
fn merged_remaining(ladders: &[Vec<usize>], cursors: &[usize]) -> usize {
    let mut rest: Vec<usize> = ladders
        .iter()
        .zip(cursors)
        .flat_map(|(l, &c)| l[c..].iter().copied())
        .collect();
    rest.sort_unstable();
    rest.dedup();
    rest.len()
}

/// Turbo truncation (`docs/tiers.md`): cap one row's distinct transition
/// times at `cap` by dropping the lowest-impact events. Impact of an
/// event time is the number of positions firing at it; ties drop the
/// *smaller* t first, so the early reverse-time events (which unmask
/// first and anchor the sequence) survive. Positions whose τ was dropped
/// are remapped to the nearest kept time (ties toward the larger t), so
/// every position still transitions exactly once. This is a pure
/// function of the already-sampled taus — no RNG draws — which is what
/// makes Turbo byte-reproducible under a pinned seed.
fn truncate_row_taus(taus: &mut [usize], cap: usize) {
    let cap = cap.max(1);
    let mut times: Vec<usize> = taus.to_vec();
    times.sort_unstable();
    times.dedup();
    if times.len() <= cap {
        return;
    }
    let counts: Vec<usize> =
        times.iter().map(|&t| taus.iter().filter(|&&tau| tau == t).count()).collect();
    // rank: fewest positions first, then smaller t first
    let mut ranked: Vec<usize> = (0..times.len()).collect();
    ranked.sort_by_key(|&i| (counts[i], times[i]));
    let kept: Vec<usize> = {
        let mut k: Vec<usize> =
            ranked[times.len() - cap..].iter().map(|&i| times[i]).collect();
        k.sort_unstable();
        k
    };
    for tau in taus.iter_mut() {
        if kept.binary_search(tau).is_ok() {
            continue;
        }
        // nearest kept time; on a distance tie take the larger t
        let mut best = kept[0];
        for &k in &kept {
            let (d, bd) = (k.abs_diff(*tau), best.abs_diff(*tau));
            if d < bd || (d == bd && k > best) {
                best = k;
            }
        }
        *tau = best;
    }
}

impl DndmState {
    pub(crate) fn new(core: &mut Core, cfg: &SamplerConfig, batch: usize, v2: bool) -> DndmState {
        let t_max = cfg.steps;
        let mut taus: Vec<Vec<usize>> = if cfg.shared_tau {
            let tt = cfg.spec.sample_times(t_max, core.n, cfg.order, &mut core.rng);
            vec![tt.taus; batch]
        } else {
            (0..batch)
                .map(|_| cfg.spec.sample_times(t_max, core.n, cfg.order, &mut core.rng).taus)
                .collect()
        };
        let build_ladders = |taus: &[Vec<usize>]| -> Vec<Vec<usize>> {
            taus.iter()
                .map(|row| {
                    let mut l = row.clone();
                    l.sort_unstable_by(|a, b| b.cmp(a));
                    l.dedup();
                    l
                })
                .collect()
        };
        let cursors = vec![0; batch];
        let mut ladders = build_ladders(&taus);
        let mut truncated = 0;
        if let Some(cap) = cfg.max_nfe {
            // Turbo: truncate *after* sampling, so the RNG stream (and
            // everything drawn later from it) is identical to the
            // uncapped run — only the ladder shrinks
            let before = merged_remaining(&ladders, &cursors);
            for row in taus.iter_mut() {
                truncate_row_taus(row, cap);
            }
            ladders = build_ladders(&taus);
            truncated = before - merged_remaining(&ladders, &cursors);
        }
        let total = merged_remaining(&ladders, &cursors);
        DndmState { taus, ladders, cursors, fired: 0, total, truncated, t_max, v2 }
    }

    /// The next merged event time: max over the rows' current ladder
    /// entries. Allocation-free (ran every `next_event`).
    fn merged_next(&self) -> Option<usize> {
        self.ladders
            .iter()
            .zip(&self.cursors)
            .filter_map(|(l, &c)| l.get(c).copied())
            .max()
    }
}

impl AlgState for DndmState {
    fn next_t(&self, _core: &Core) -> Option<(f32, f64)> {
        self.merged_next().map(|t| {
            let t_norm = t as f32 / self.t_max as f32;
            (t_norm, t_norm as f64)
        })
    }

    fn advance(&mut self, core: &mut Core, logits: LogitsView<'_>) -> usize {
        let t = self.merged_next().expect("advance called on a completed session");
        let t_norm = t as f32 / self.t_max as f32;
        let mut moved = 0usize;
        for b in 0..core.x.rows() {
            // rows whose next event is later (a smaller t) sit this call
            // out; their RNG streams are untouched, which is why the
            // survivors of an eviction stay byte-identical
            if self.ladders[b].get(self.cursors[b]) != Some(&t) {
                continue;
            }
            for pos in 0..core.n {
                let fires =
                    if self.v2 { self.taus[b][pos] >= t } else { self.taus[b][pos] == t };
                if fires {
                    let (tok, _) =
                        sample_x0(logits.row(b, pos), core.temperature, &mut core.row_rngs[b]);
                    core.x.set(b, pos, tok);
                }
            }
            self.cursors[b] += 1;
            moved += 1;
        }
        self.fired += 1;
        core.finish_event(t_norm as f64);
        moved
    }

    fn taus(&self) -> Option<&[Vec<usize>]> {
        Some(&self.taus)
    }

    fn total_events(&self) -> usize {
        self.total
    }

    fn truncated_events(&self) -> usize {
        self.truncated
    }

    fn evict_row(&mut self, row: usize) {
        self.taus.remove(row);
        self.ladders.remove(row);
        self.cursors.remove(row);
        // events unique to the departed row are retired with it
        self.total = self.fired + merged_remaining(&self.ladders, &self.cursors);
    }

    fn split_rows(&mut self, rows: &[usize]) -> Box<dyn AlgState> {
        let mut taus = Vec::with_capacity(rows.len());
        let mut ladders = Vec::with_capacity(rows.len());
        let mut cursors = Vec::with_capacity(rows.len());
        for &r in rows {
            taus.push(self.taus[r].clone());
            ladders.push(self.ladders[r].clone());
            cursors.push(self.cursors[r]);
        }
        for &r in rows.iter().rev() {
            self.taus.remove(r);
            self.ladders.remove(r);
            self.cursors.remove(r);
        }
        // each half re-merges over its own rows; both totals stay exact
        self.total = self.fired + merged_remaining(&self.ladders, &self.cursors);
        let total = self.fired + merged_remaining(&ladders, &cursors);
        Box::new(DndmState {
            taus,
            ladders,
            cursors,
            fired: self.fired,
            total,
            truncated: 0, // the donor keeps the construction-time stat
            t_max: self.t_max,
            v2: self.v2,
        })
    }
}

/// Algorithm 2 — DNDM-C (continuous time / infinite steps).
///
/// Transition timestamps are drawn from the continuous 𝒟_τ (density
/// −α′(t), or the Beta approximation) and visited in descending order;
/// ties (which have probability 0 in the continuum but can occur with the
/// rounded Beta) collapse into one event. NFE → N as T → ∞ (Remark D.4).
pub(crate) struct DndmCState {
    /// shared continuous 𝒯 (same broadcast convention as the discrete path)
    taus: Vec<f64>,
    /// position indices, descending by timestamp
    order: Vec<usize>,
    /// cursor into `order`; ties are grouped per event
    k: usize,
    /// distinct events over the whole walk (ties pre-counted with the same
    /// grouping rule `advance` uses)
    total: usize,
}

/// End (exclusive) of the tie group starting at `order[k]`: positions
/// whose timestamps sit within 1e-12 of `taus[order[k]]` collapse into
/// one event. The single grouping rule shared by `DndmCState::new`
/// (pre-counting `total`) and its `advance` (walking the cursor) — with
/// one implementation the two can never disagree on what counts as an
/// event, so `total_events` always matches the calls actually made.
fn tie_group_end(taus: &[f64], order: &[usize], k: usize) -> usize {
    let t = taus[order[k]];
    let mut j = k + 1;
    while j < order.len() && (taus[order[j]] - t).abs() < 1e-12 {
        j += 1;
    }
    j
}

impl DndmCState {
    pub(crate) fn new(core: &mut Core, cfg: &SamplerConfig) -> DndmCState {
        let taus: Vec<f64> = cfg.spec.sample_times_continuous(core.n, cfg.order, &mut core.rng);
        let mut order: Vec<usize> = (0..core.n).collect();
        order.sort_by(|&a, &b| taus[b].partial_cmp(&taus[a]).unwrap());
        let mut total = 0usize;
        let mut k = 0usize;
        while k < order.len() {
            k = tie_group_end(&taus, &order, k);
            total += 1;
        }
        DndmCState { taus, order, k: 0, total }
    }
}

impl AlgState for DndmCState {
    fn next_t(&self, core: &Core) -> Option<(f32, f64)> {
        if self.k < core.n {
            let t = self.taus[self.order[self.k]];
            Some((t as f32, t))
        } else {
            None
        }
    }

    fn advance(&mut self, core: &mut Core, logits: LogitsView<'_>) -> usize {
        let t = self.taus[self.order[self.k]];
        // all positions sharing this timestamp transition together
        let j = tie_group_end(&self.taus, &self.order, self.k);
        let moved = core.x.rows();
        for b in 0..moved {
            for &pos in &self.order[self.k..j] {
                let (tok, _) =
                    sample_x0(logits.row(b, pos), core.temperature, &mut core.row_rngs[b]);
                core.x.set(b, pos, tok);
            }
        }
        self.k = j;
        core.finish_event(t);
        moved
    }

    fn total_events(&self) -> usize {
        self.total
    }

    // no `evict_row` override: the timestamp walk is per *position*, not
    // per row — every row fires at every event, so nothing can ghost

    fn split_rows(&mut self, _rows: &[usize]) -> Box<dyn AlgState> {
        // 𝒯 is shared across rows; both halves walk the same schedule
        Box::new(DndmCState {
            taus: self.taus.clone(),
            order: self.order.clone(),
            k: self.k,
            total: self.total,
        })
    }
}

#[cfg(test)]
mod tests {
    use crate::runtime::{Denoiser, MockDenoiser};
    use crate::sampler::{generate, SamplerConfig, SamplerKind};
    use crate::schedule::{AlphaSchedule, TransitionSpec};

    fn mock(kind: &str) -> MockDenoiser {
        let cfg = MockDenoiser::test_config(20, 8, 0, kind);
        MockDenoiser::fixed(cfg, vec![10, 11, 12, 13, 14, 15, 16, 17])
    }

    #[test]
    fn converges_to_mock_target_absorbing() {
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50);
        let out = generate(&den, &cfg, None, 2, 7, None).unwrap();
        for seq in &out.tokens {
            assert_eq!(seq, &vec![10, 11, 12, 13, 14, 15, 16, 17]);
        }
    }

    #[test]
    fn converges_to_mock_target_multinomial() {
        let den = mock("multinomial");
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50)
            .with_spec(TransitionSpec::Exact(AlphaSchedule::CosineSq));
        let out = generate(&den, &cfg, None, 3, 9, None).unwrap();
        for seq in &out.tokens {
            assert_eq!(seq, &vec![10, 11, 12, 13, 14, 15, 16, 17]);
        }
    }

    #[test]
    fn nfe_bounded_by_min_n_t_and_calls_match() {
        for steps in [5usize, 50, 1000] {
            let den = mock("absorbing");
            let cfg = SamplerConfig::new(SamplerKind::Dndm, steps);
            let out = generate(&den, &cfg, None, 4, 3, None).unwrap();
            assert!(out.nfe >= 1 && out.nfe <= steps.min(8), "T={steps} nfe={}", out.nfe);
            assert_eq!(den.calls() as usize, out.nfe, "NN calls must equal |𝒯|");
        }
    }

    #[test]
    fn v2_also_converges() {
        let den = mock("multinomial");
        let cfg = SamplerConfig::new(SamplerKind::DndmV2, 50);
        let out = generate(&den, &cfg, None, 2, 5, None).unwrap();
        for seq in &out.tokens {
            assert_eq!(seq, &vec![10, 11, 12, 13, 14, 15, 16, 17]);
        }
    }

    #[test]
    fn continuous_nfe_is_n_when_no_ties() {
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::DndmC, 0)
            .with_spec(TransitionSpec::Exact(AlphaSchedule::Linear));
        let out = generate(&den, &cfg, None, 2, 11, None).unwrap();
        assert_eq!(out.nfe, 8, "continuous τ are a.s. distinct → NFE = N");
        assert_eq!(out.tokens[0], vec![10, 11, 12, 13, 14, 15, 16, 17]);
    }

    #[test]
    fn per_seq_tau_unions_events() {
        let den = mock("absorbing");
        let mut cfg = SamplerConfig::new(SamplerKind::Dndm, 1000);
        cfg.shared_tau = false;
        let out = generate(&den, &cfg, None, 4, 13, None).unwrap();
        // union over 4 sequences ≥ single-sequence NFE, still ≤ 4·N
        assert!(out.nfe <= 32);
        assert_eq!(out.tokens[2], vec![10, 11, 12, 13, 14, 15, 16, 17]);
    }

    #[test]
    fn continuous_tied_timestamps_keep_total_and_cursor_in_agreement() {
        use super::{tie_group_end, DndmCState};
        use crate::sampler::session::{build_core, SamplerSession};

        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::DndmC, 0);
        let core = build_core(den.config(), &cfg, 1, 7, false);
        // Beta-rounded draws can collide: positions {0,3} and {2,5} tie
        // within the 1e-12 grouping tolerance, so 8 positions → 6 events.
        // Before the shared helper, `new` and `advance` each hand-rolled
        // this scan and a drift between them would skew total_events.
        let taus = vec![0.5, 0.9, 0.25, 0.5 + 1e-13, 0.75, 0.25 - 1e-13, 0.1, 0.6];
        let mut order: Vec<usize> = (0..8).collect();
        order.sort_by(|&a, &b| taus[b].partial_cmp(&taus[a]).unwrap());
        let mut total = 0usize;
        let mut k = 0usize;
        while k < order.len() {
            k = tie_group_end(&taus, &order, k);
            total += 1;
        }
        assert_eq!(total, 6, "two tie pairs collapse into one event each");
        let state = DndmCState { taus, order, k: 0, total };
        let mut sess = SamplerSession::from_parts(core, Box::new(state), 1);
        assert_eq!(sess.total_events(), 6);
        let mut calls = 0usize;
        while let Some(call) = sess.next_event() {
            let logits = den.denoise(sess.x(), &vec![call.t; 1], None).unwrap();
            sess.advance(&logits).unwrap();
            calls += 1;
        }
        assert_eq!(calls, 6, "advance fires exactly the pre-counted events");
        assert_eq!(sess.nfe(), sess.total_events());
    }

    #[test]
    fn evicting_a_row_retires_its_unique_events() {
        use crate::sampler::session::SamplerSession;

        // per-seq 𝒯 with a large grid: rows almost surely hold τ values
        // no other row shares, so eviction must shrink total_events to
        // the survivors' union (plus what already fired)
        let den = mock("absorbing");
        let mut cfg = SamplerConfig::new(SamplerKind::Dndm, 100_000);
        cfg.shared_tau = false;
        for seed in 0..32u64 {
            let mut sess = SamplerSession::new(den.config(), &cfg, 3, seed).unwrap();
            let taus = sess.taus().unwrap();
            let union = |rows: &[usize]| {
                let mut u: Vec<usize> =
                    rows.iter().flat_map(|&r| taus[r].iter().copied()).collect();
                u.sort_unstable();
                u.dedup();
                u.len()
            };
            let before = union(&[0, 1, 2]);
            let survivors = union(&[0, 2]);
            assert_eq!(sess.total_events(), before);
            if survivors == before {
                continue; // row 1 held nothing unique for this seed
            }
            sess.evict_slot(1).unwrap();
            assert_eq!(
                sess.total_events(),
                survivors,
                "seed {seed}: total must re-merge over the survivors"
            );
            // and the session actually stops after that many calls
            let mut calls = 0usize;
            while let Some(call) = sess.next_event() {
                let logits = den.denoise(sess.x(), &vec![call.t; 2], None).unwrap();
                assert!(sess.advance(&logits).unwrap() >= 1, "no ghost events");
                calls += 1;
            }
            assert_eq!(calls, survivors);
        }
    }

    #[test]
    fn turbo_truncation_caps_events_and_is_deterministic() {
        use crate::sampler::session::SamplerSession;

        let den = mock("absorbing");
        for seed in 0..16u64 {
            let base = SamplerConfig::new(SamplerKind::Dndm, 1000);
            let full = SamplerSession::new(den.config(), &base, 1, seed).unwrap();
            let cap = 3;
            let turbo = base.clone().with_max_nfe(cap);
            let a = SamplerSession::new(den.config(), &turbo, 1, seed).unwrap();
            let b = SamplerSession::new(den.config(), &turbo, 1, seed).unwrap();
            assert!(a.total_events() <= cap, "seed {seed}: cap not honoured");
            assert_eq!(
                a.total_events() + a.truncated_events(),
                full.total_events(),
                "seed {seed}: truncated + remaining must equal the uncapped |𝒯|"
            );
            assert_eq!(
                a.taus().unwrap(),
                b.taus().unwrap(),
                "seed {seed}: Turbo truncation must be byte-reproducible"
            );
            // every position still transitions exactly once, at a kept time
            let taus = a.taus().unwrap();
            assert!(taus[0].iter().all(|&t| (1..=1000).contains(&t)));
        }
    }

    #[test]
    fn turbo_truncated_session_still_converges() {
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50).with_max_nfe(2);
        let out = generate(&den, &cfg, None, 2, 7, None).unwrap();
        assert!(out.nfe <= 2, "Turbo cap must bound NFE, got {}", out.nfe);
        for seq in &out.tokens {
            assert_eq!(seq, &vec![10, 11, 12, 13, 14, 15, 16, 17]);
        }
    }

    #[test]
    fn no_cap_means_byte_identical_taus() {
        use crate::sampler::session::SamplerSession;
        let den = mock("absorbing");
        let base = SamplerConfig::new(SamplerKind::Dndm, 100);
        let loose = base.clone().with_max_nfe(10_000); // cap above |𝒯|: no-op
        let a = SamplerSession::new(den.config(), &base, 2, 5).unwrap();
        let b = SamplerSession::new(den.config(), &loose, 2, 5).unwrap();
        assert_eq!(a.taus().unwrap(), b.taus().unwrap());
        assert_eq!(b.truncated_events(), 0);
    }

    #[test]
    fn trace_records_events() {
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50).with_trace();
        let out = generate(&den, &cfg, None, 1, 17, None).unwrap();
        assert_eq!(out.trace.len(), out.nfe);
        // times strictly decreasing
        for w in out.trace.windows(2) {
            assert!(w[0].t > w[1].t);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let den = mock("multinomial");
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50).with_temperature(1.0);
        let a = generate(&den, &cfg, None, 2, 23, None).unwrap();
        let b = generate(&den, &cfg, None, 2, 23, None).unwrap();
        assert_eq!(a.tokens, b.tokens);
        let c = generate(&den, &cfg, None, 2, 24, None).unwrap();
        // different seed → different 𝒯 (tokens may or may not differ, but
        // nfe/trace-level equality would be a miracle with temp 1.0)
        assert!(a.tokens != c.tokens || a.nfe != c.nfe);
    }

    #[test]
    fn absorbing_untouched_positions_stay_masked_midway() {
        // run with only 2 steps so some τ collide; before finishing,
        // positions with τ below the last processed event must be MASK.
        // (We verify the final output instead: after the full run nothing
        // should remain MASK because every τ ∈ 1..=T is processed.)
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 3);
        let out = generate(&den, &cfg, None, 2, 29, None).unwrap();
        for seq in &out.tokens {
            assert!(seq.iter().all(|&t| t != 2), "mask must be fully resolved");
        }
    }
}
