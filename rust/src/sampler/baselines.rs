//! Baseline samplers the paper compares against.
//!
//! All of these call the denoiser **once per step** (NFE = T) — that is
//! the cost DNDM removes. Implementations follow Appendix B.1 (D3PM) and
//! Zheng et al. 2023 (RDM), plus Mask-Predict for Table 13.

use anyhow::{bail, Result};

use crate::diffusion::{absorbing_reverse_step, multinomial_reverse_step, NoiseKind};
use crate::runtime::Denoiser;
use crate::schedule::{AlphaSchedule, SplitMix64};

use super::common::{init_noise, noise_of, row, sample_x0};
use super::{GenResult, SamplerConfig, TracePoint};

fn schedule_of(den: &dyn Denoiser) -> AlphaSchedule {
    AlphaSchedule::parse(&den.config().schedule).unwrap_or(AlphaSchedule::CosineSq)
}

/// Vanilla D3PM ancestral sampling (Hoogeboom 2021b / Austin 2021):
/// every step t draws x̂0 ~ p_θ(·|x_t) then x_{t−1} ~ q(x_{t−1}|x_t, x̂0).
pub fn d3pm(
    den: &dyn Denoiser,
    cfg: &SamplerConfig,
    src: Option<&[Vec<u32>]>,
    batch: usize,
    seed: u64,
) -> Result<GenResult> {
    let mcfg = den.config().clone();
    let (n, v, t_max) = (mcfg.seq_len, mcfg.vocab, cfg.steps);
    let noise = noise_of(&mcfg);
    let sched = schedule_of(den);
    let mut rng = SplitMix64::new(seed);

    let mut x = init_noise(batch, n, noise, &mut rng);
    let mut trace = Vec::new();

    for t in (1..=t_max).rev() {
        let t_norm = t as f32 / t_max as f32;
        let logits = den.denoise(&x, &vec![t_norm; batch], src)?;
        for b in 0..batch {
            for pos in 0..n {
                let (x0_hat, _) = sample_x0(row(&logits[b], pos, v), cfg.temperature.max(1.0), &mut rng);
                x[b][pos] = match noise {
                    NoiseKind::Absorbing { mask_id } => absorbing_reverse_step(
                        x[b][pos], x0_hat, t, t_max, sched, mask_id, &mut rng,
                    ),
                    NoiseKind::Multinomial { .. } => multinomial_reverse_step(
                        x[b][pos], x0_hat, t, t_max, sched, noise, v, &mut rng,
                    ),
                };
            }
        }
        if cfg.trace {
            trace.push(TracePoint { t: t_norm as f64, tokens: x[0].clone() });
        }
    }

    Ok(GenResult { tokens: x, nfe: t_max, trace })
}

/// RDM reparameterized sampling (Zheng et al. 2023).
///
/// RDM tracks a per-token "decoded" indicator v_t. At each step the
/// expected number of newly revealed tokens follows the schedule
/// (α_{t−1} − α_t)/(1 − α_t) over still-noisy tokens; `topk=false`
/// reveals a Bernoulli-random subset (vanilla RDM), `topk=true` reveals
/// the highest-scoring ones (RDM-k, their best variant). Revealed tokens
/// are *re-predicted* every step (RDM re-decodes, unlike D3PM-Absorb).
pub fn rdm(
    den: &dyn Denoiser,
    cfg: &SamplerConfig,
    src: Option<&[Vec<u32>]>,
    batch: usize,
    seed: u64,
    topk: bool,
) -> Result<GenResult> {
    let mcfg = den.config().clone();
    let (n, v, t_max) = (mcfg.seq_len, mcfg.vocab, cfg.steps);
    let noise = noise_of(&mcfg);
    let sched = schedule_of(den);
    let mut rng = SplitMix64::new(seed);

    let mut x = init_noise(batch, n, noise, &mut rng);
    let mut revealed = vec![vec![false; n]; batch];
    let mut trace = Vec::new();

    for t in (1..=t_max).rev() {
        let t_norm = t as f32 / t_max as f32;
        let logits = den.denoise(&x, &vec![t_norm; batch], src)?;
        let a_t = sched.alpha_discrete(t, t_max);
        let a_prev = sched.alpha_discrete(t - 1, t_max);
        let p_reveal = if a_t >= 1.0 { 0.0 } else { (a_prev - a_t) / (1.0 - a_t) };

        for b in 0..batch {
            let mut decoded: Vec<(usize, u32, f32)> = Vec::with_capacity(n);
            for pos in 0..n {
                let (tok, score) = sample_x0(row(&logits[b], pos, v), cfg.temperature, &mut rng);
                decoded.push((pos, tok, score));
            }
            // re-predict already-revealed tokens (RDM re-decoding)
            for &(pos, tok, _) in &decoded {
                if revealed[b][pos] {
                    x[b][pos] = tok;
                }
            }
            let noisy: Vec<usize> = (0..n).filter(|&p| !revealed[b][p]).collect();
            if topk {
                // reveal count = Binomial expectation, positions by score
                let k = ((noisy.len() as f64) * p_reveal).round() as usize;
                let k = if t == 1 { noisy.len() } else { k };
                let mut ranked: Vec<&(usize, u32, f32)> = decoded
                    .iter()
                    .filter(|(p, _, _)| !revealed[b][*p])
                    .collect();
                ranked.sort_by(|a, b| b.2.total_cmp(&a.2));
                for &&(pos, tok, _) in ranked.iter().take(k) {
                    x[b][pos] = tok;
                    revealed[b][pos] = true;
                }
            } else {
                for &pos in &noisy {
                    if t == 1 || rng.coin(p_reveal) {
                        let (_, tok, _) = decoded[pos];
                        x[b][pos] = tok;
                        revealed[b][pos] = true;
                    }
                }
            }
        }
        if cfg.trace {
            trace.push(TracePoint { t: t_norm as f64, tokens: x[0].clone() });
        }
    }

    Ok(GenResult { tokens: x, nfe: t_max, trace })
}

/// Mask-Predict (Ghazvininejad et al. 2019) — Table 13's comparator.
///
/// Absorbing models only: start fully masked; at iteration i of S, predict
/// everything, then re-mask the ⌈N·(S−i−1)/S⌉ lowest-scoring tokens.
pub fn mask_predict(
    den: &dyn Denoiser,
    cfg: &SamplerConfig,
    src: Option<&[Vec<u32>]>,
    batch: usize,
    seed: u64,
) -> Result<GenResult> {
    let mcfg = den.config().clone();
    if mcfg.kind != "absorbing" {
        bail!("mask-predict requires an absorbing model");
    }
    let (n, v, iters) = (mcfg.seq_len, mcfg.vocab, cfg.steps);
    let mask = mcfg.mask_id;
    let mut rng = SplitMix64::new(seed);

    let mut x = vec![vec![mask; n]; batch];
    let mut trace = Vec::new();

    for i in 0..iters {
        // feed a time proportional to the masked fraction for conditioning
        let t_norm = 1.0 - (i as f32 / iters as f32);
        let logits = den.denoise(&x, &vec![t_norm; batch], src)?;
        let n_mask = (n * (iters - i - 1)) / iters;
        for b in 0..batch {
            let mut scored: Vec<(usize, u32, f32)> = (0..n)
                .map(|pos| {
                    let (tok, s) = sample_x0(row(&logits[b], pos, v), cfg.temperature, &mut rng);
                    (pos, tok, s)
                })
                .collect();
            for &(pos, tok, _) in &scored {
                x[b][pos] = tok;
            }
            if n_mask > 0 {
                scored.sort_by(|a, b| a.2.total_cmp(&b.2)); // ascending score
                for &(pos, _, _) in scored.iter().take(n_mask) {
                    x[b][pos] = mask;
                }
            }
        }
        if cfg.trace {
            trace.push(TracePoint { t: t_norm as f64, tokens: x[0].clone() });
        }
    }

    Ok(GenResult { tokens: x, nfe: iters, trace })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockDenoiser;
    use crate::sampler::{generate, SamplerConfig, SamplerKind};

    const TARGET: [u32; 8] = [10, 11, 12, 13, 14, 15, 16, 17];

    fn mock(kind: &str) -> MockDenoiser {
        let cfg = MockDenoiser::test_config(20, 8, 0, kind);
        MockDenoiser::fixed(cfg, TARGET.to_vec())
    }

    #[test]
    fn d3pm_absorbing_converges_with_t_nfe() {
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::D3pm, 30);
        let out = generate(&den, &cfg, None, 2, 7, None).unwrap();
        assert_eq!(out.nfe, 30);
        assert_eq!(den.calls(), 30);
        for seq in &out.tokens {
            assert_eq!(seq, &TARGET.to_vec());
        }
    }

    #[test]
    fn d3pm_multinomial_converges() {
        let den = mock("multinomial");
        let cfg = SamplerConfig::new(SamplerKind::D3pm, 40);
        let out = generate(&den, &cfg, None, 2, 3, None).unwrap();
        // posterior sampling is stochastic but the mock's peak dominates
        let hits: usize = out.tokens[0]
            .iter()
            .zip(TARGET.iter())
            .filter(|(a, b)| a == b)
            .count();
        assert!(hits >= 7, "{:?}", out.tokens[0]);
    }

    #[test]
    fn rdm_variants_converge_and_reveal_everything() {
        for topk in [false, true] {
            for kind in ["absorbing", "multinomial"] {
                let den = mock(kind);
                let cfg = SamplerConfig::new(
                    if topk { SamplerKind::RdmTopK } else { SamplerKind::Rdm },
                    25,
                );
                let out = generate(&den, &cfg, None, 2, 11, None).unwrap();
                assert_eq!(out.nfe, 25);
                for seq in &out.tokens {
                    assert_eq!(seq, &TARGET.to_vec(), "kind={kind} topk={topk}");
                }
            }
        }
    }

    #[test]
    fn mask_predict_converges_and_requires_absorbing() {
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::MaskPredict, 10);
        let out = generate(&den, &cfg, None, 2, 5, None).unwrap();
        assert_eq!(out.nfe, 10);
        for seq in &out.tokens {
            assert_eq!(seq, &TARGET.to_vec());
        }
        let den = mock("multinomial");
        assert!(generate(&den, &cfg, None, 1, 5, None).is_err());
    }

    #[test]
    fn mask_predict_intermediate_has_masks() {
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::MaskPredict, 5).with_trace();
        let out = generate(&den, &cfg, None, 1, 5, None).unwrap();
        let masked_first = out.trace[0].tokens.iter().filter(|&&t| t == 2).count();
        let masked_last = out.trace.last().unwrap().tokens.iter().filter(|&&t| t == 2).count();
        assert!(masked_first > 0, "early iterations re-mask low scores");
        assert_eq!(masked_last, 0);
    }
}
