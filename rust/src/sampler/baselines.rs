//! Baseline samplers the paper compares against, as session states.
//!
//! All of these call the denoiser **once per step** (NFE = T) — that is
//! the cost DNDM removes. Implementations follow Appendix B.1 (D3PM) and
//! Zheng et al. 2023 (RDM), plus Mask-Predict for Table 13. Where DNDM
//! sessions own a predetermined 𝒯, these own the per-step schedule: a
//! countdown t = T..1 (or the iteration ladder for Mask-Predict).

use crate::diffusion::{absorbing_reverse_step, multinomial_reverse_step, NoiseKind};
use crate::schedule::AlphaSchedule;
use crate::tensor::LogitsView;

use super::common::sample_x0;
use super::session::{AlgState, Core};
use super::SamplerConfig;

/// Alloc-free argmax over one position's logits (early-retirement probes).
fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &l) in logits.iter().enumerate() {
        if l > logits[best] {
            best = i;
        }
    }
    best as u32
}

/// Vanilla D3PM ancestral sampling (Hoogeboom 2021b / Austin 2021):
/// every step t draws x̂0 ~ p_θ(·|x_t) then x_{t−1} ~ q(x_{t−1}|x_t, x̂0).
pub(crate) struct D3pmState {
    /// current step, counting down T..=1; 0 = done
    t: usize,
    t_max: usize,
    sched: AlphaSchedule,
    noise: NoiseKind,
}

impl D3pmState {
    pub(crate) fn new(cfg: &SamplerConfig, sched: AlphaSchedule, noise: NoiseKind) -> D3pmState {
        D3pmState { t: cfg.steps, t_max: cfg.steps, sched, noise }
    }
}

impl AlgState for D3pmState {
    fn next_t(&self, _core: &Core) -> Option<(f32, f64)> {
        if self.t >= 1 {
            let t_norm = self.t as f32 / self.t_max as f32;
            Some((t_norm, t_norm as f64))
        } else {
            None
        }
    }

    fn advance(&mut self, core: &mut Core, logits: LogitsView<'_>) -> usize {
        let t = self.t;
        let t_norm = t as f32 / self.t_max as f32;
        let moved = core.x.rows();
        for b in 0..moved {
            for pos in 0..core.n {
                let (x0_hat, _) = sample_x0(
                    logits.row(b, pos),
                    core.temperature.max(1.0),
                    &mut core.row_rngs[b],
                );
                let next = match self.noise {
                    NoiseKind::Absorbing { mask_id } => absorbing_reverse_step(
                        core.x.get(b, pos),
                        x0_hat,
                        t,
                        self.t_max,
                        self.sched,
                        mask_id,
                        &mut core.row_rngs[b],
                    ),
                    NoiseKind::Multinomial { .. } => multinomial_reverse_step(
                        core.x.get(b, pos),
                        x0_hat,
                        t,
                        self.t_max,
                        self.sched,
                        self.noise,
                        core.v,
                        &mut core.row_rngs[b],
                    ),
                };
                core.x.set(b, pos, next);
            }
        }
        self.t -= 1;
        core.finish_event(t_norm as f64);
        moved
    }

    fn total_events(&self) -> usize {
        self.t_max
    }

    fn row_settled(&self, core: &Core, row: usize, _logits: LogitsView<'_>) -> bool {
        // Absorbing chains only: `absorbing_reverse_step` is the identity
        // on unmasked tokens, so a row with no `[MASK]` left is settled
        // *structurally* — every remaining step is provably a no-op,
        // whatever the logits or the temperature. The multinomial
        // posterior keeps resampling tokens, so it never settles early.
        match self.noise {
            NoiseKind::Absorbing { mask_id } => {
                self.t >= 1 && core.x.row(row).iter().all(|&tok| tok != mask_id)
            }
            NoiseKind::Multinomial { .. } => false,
        }
    }

    fn split_rows(&mut self, _rows: &[usize]) -> Box<dyn AlgState> {
        // the countdown is the whole state and it is shared: both halves
        // keep marching the same step grid
        Box::new(D3pmState { t: self.t, t_max: self.t_max, sched: self.sched, noise: self.noise })
    }
}

/// RDM reparameterized sampling (Zheng et al. 2023).
///
/// RDM tracks a per-token "decoded" indicator v_t. At each step the
/// expected number of newly revealed tokens follows the schedule
/// (α_{t−1} − α_t)/(1 − α_t) over still-noisy tokens; `topk=false`
/// reveals a Bernoulli-random subset (vanilla RDM), `topk=true` reveals
/// the highest-scoring ones (RDM-k, their best variant). Revealed tokens
/// are *re-predicted* every step (RDM re-decodes, unlike D3PM-Absorb).
pub(crate) struct RdmState {
    revealed: Vec<Vec<bool>>,
    t: usize,
    t_max: usize,
    sched: AlphaSchedule,
    topk: bool,
    /// per-advance (pos, token, score) scratch, indexable by position;
    /// reused across steps to avoid per-step Vec churn (the top-k variant
    /// still pays std's stable-sort merge buffer at n > 20)
    decoded: Vec<(usize, u32, f32)>,
    /// indices into `decoded`, score-ranked (top-k variant scratch)
    ranked: Vec<usize>,
}

impl RdmState {
    pub(crate) fn new(
        cfg: &SamplerConfig,
        sched: AlphaSchedule,
        batch: usize,
        n: usize,
        topk: bool,
    ) -> RdmState {
        RdmState {
            revealed: vec![vec![false; n]; batch],
            t: cfg.steps,
            t_max: cfg.steps,
            sched,
            topk,
            decoded: Vec::with_capacity(n),
            ranked: Vec::with_capacity(n),
        }
    }
}

impl AlgState for RdmState {
    fn next_t(&self, _core: &Core) -> Option<(f32, f64)> {
        if self.t >= 1 {
            let t_norm = self.t as f32 / self.t_max as f32;
            Some((t_norm, t_norm as f64))
        } else {
            None
        }
    }

    fn advance(&mut self, core: &mut Core, logits: LogitsView<'_>) -> usize {
        let t = self.t;
        let t_norm = t as f32 / self.t_max as f32;
        let a_t = self.sched.alpha_discrete(t, self.t_max);
        let a_prev = self.sched.alpha_discrete(t - 1, self.t_max);
        let p_reveal = if a_t >= 1.0 { 0.0 } else { (a_prev - a_t) / (1.0 - a_t) };
        let moved = core.x.rows();

        for b in 0..moved {
            self.decoded.clear();
            for pos in 0..core.n {
                let (tok, score) =
                    sample_x0(logits.row(b, pos), core.temperature, &mut core.row_rngs[b]);
                self.decoded.push((pos, tok, score));
            }
            // re-predict already-revealed tokens (RDM re-decoding)
            for &(pos, tok, _) in &self.decoded {
                if self.revealed[b][pos] {
                    core.x.set(b, pos, tok);
                }
            }
            let noisy_count = (0..core.n).filter(|&p| !self.revealed[b][p]).count();
            if self.topk {
                // reveal count = Binomial expectation, positions by score
                let k = ((noisy_count as f64) * p_reveal).round() as usize;
                let k = if t == 1 { noisy_count } else { k };
                self.ranked.clear();
                self.ranked.extend((0..core.n).filter(|&p| !self.revealed[b][p]));
                let decoded = &self.decoded;
                self.ranked.sort_by(|&i, &j| decoded[j].2.total_cmp(&decoded[i].2));
                for &ri in self.ranked.iter().take(k) {
                    let (pos, tok, _) = self.decoded[ri];
                    core.x.set(b, pos, tok);
                    self.revealed[b][pos] = true;
                }
            } else {
                for pos in 0..core.n {
                    if self.revealed[b][pos] {
                        continue;
                    }
                    if t == 1 || core.row_rngs[b].coin(p_reveal) {
                        let (_, tok, _) = self.decoded[pos];
                        core.x.set(b, pos, tok);
                        self.revealed[b][pos] = true;
                    }
                }
            }
        }
        self.t -= 1;
        core.finish_event(t_norm as f64);
        moved
    }

    fn total_events(&self) -> usize {
        self.t_max
    }

    fn row_settled(&self, core: &Core, row: usize, logits: LogitsView<'_>) -> bool {
        // RDM re-decodes revealed tokens every step. At temperature 0 the
        // decode is argmax, so a fully-revealed row whose every position
        // already holds its argmax is a fixed point of the update *for
        // these logits*. (The denoiser's t-conditioning can still shift
        // logits at later steps — `docs/tiers.md` spells out why tiers
        // accept this; `Quality` never asks.)
        core.temperature == 0.0
            && self.revealed[row].iter().all(|&r| r)
            && (0..core.n).all(|pos| argmax(logits.row(row, pos)) == core.x.get(row, pos))
    }

    fn evict_row(&mut self, row: usize) {
        // the step grid is shared (every row reveals on every step), so
        // only the reveal indicators go
        self.revealed.remove(row);
    }

    fn split_rows(&mut self, rows: &[usize]) -> Box<dyn AlgState> {
        let mut revealed = Vec::with_capacity(rows.len());
        for &r in rows {
            revealed.push(self.revealed[r].clone());
        }
        for &r in rows.iter().rev() {
            self.revealed.remove(r);
        }
        Box::new(RdmState {
            revealed,
            t: self.t,
            t_max: self.t_max,
            sched: self.sched,
            topk: self.topk,
            decoded: Vec::with_capacity(self.decoded.capacity()),
            ranked: Vec::with_capacity(self.ranked.capacity()),
        })
    }
}

/// Mask-Predict (Ghazvininejad et al. 2019) — Table 13's comparator.
///
/// Absorbing models only: start fully masked; at iteration i of S, predict
/// everything, then re-mask the ⌈N·(S−i−1)/S⌉ lowest-scoring tokens.
pub(crate) struct MaskPredictState {
    i: usize,
    iters: usize,
    mask: u32,
    /// per-advance (pos, token, score) scratch, reused across iterations
    scored: Vec<(usize, u32, f32)>,
}

impl MaskPredictState {
    pub(crate) fn new(cfg: &SamplerConfig, mask: u32) -> MaskPredictState {
        MaskPredictState { i: 0, iters: cfg.steps, mask, scored: Vec::new() }
    }
}

impl AlgState for MaskPredictState {
    fn next_t(&self, _core: &Core) -> Option<(f32, f64)> {
        if self.i < self.iters {
            // feed a time proportional to the masked fraction for conditioning
            let t_norm = 1.0 - (self.i as f32 / self.iters as f32);
            Some((t_norm, t_norm as f64))
        } else {
            None
        }
    }

    fn advance(&mut self, core: &mut Core, logits: LogitsView<'_>) -> usize {
        let i = self.i;
        let t_norm = 1.0 - (i as f32 / self.iters as f32);
        let n_mask = (core.n * (self.iters - i - 1)) / self.iters;
        let moved = core.x.rows();
        for b in 0..moved {
            self.scored.clear();
            for pos in 0..core.n {
                let (tok, s) =
                    sample_x0(logits.row(b, pos), core.temperature, &mut core.row_rngs[b]);
                self.scored.push((pos, tok, s));
            }
            for &(pos, tok, _) in &self.scored {
                core.x.set(b, pos, tok);
            }
            if n_mask > 0 {
                self.scored.sort_by(|a, b| a.2.total_cmp(&b.2)); // ascending score
                for &(pos, _, _) in self.scored.iter().take(n_mask) {
                    core.x.set(b, pos, self.mask);
                }
            }
        }
        self.i += 1;
        core.finish_event(t_norm as f64);
        moved
    }

    fn total_events(&self) -> usize {
        self.iters
    }

    fn row_settled(&self, core: &Core, row: usize, logits: LogitsView<'_>) -> bool {
        // Called right after `advance` bumped `self.i`, so `self.i` is the
        // *next* iteration. Once its re-mask count hits 0 it stays 0 (the
        // count is decreasing in i), so every remaining iteration only
        // re-predicts. At temperature 0 that predict is argmax: a mask-free
        // row whose every position holds its argmax is a fixed point for
        // these logits (same t-conditioning caveat as RDM, `docs/tiers.md`).
        let next_remask =
            (core.n * self.iters.saturating_sub(self.i + 1)) / self.iters;
        core.temperature == 0.0
            && next_remask == 0
            && core.x.row(row).iter().all(|&tok| tok != self.mask)
            && (0..core.n).all(|pos| argmax(logits.row(row, pos)) == core.x.get(row, pos))
    }

    fn split_rows(&mut self, _rows: &[usize]) -> Box<dyn AlgState> {
        // the iteration ladder is shared; the scratch is per-advance only
        Box::new(MaskPredictState {
            i: self.i,
            iters: self.iters,
            mask: self.mask,
            scored: Vec::new(),
        })
    }
}

#[cfg(test)]
mod tests {
    use crate::runtime::{Denoiser, MockDenoiser};
    use crate::sampler::{generate, SamplerConfig, SamplerKind};

    const TARGET: [u32; 8] = [10, 11, 12, 13, 14, 15, 16, 17];

    fn mock(kind: &str) -> MockDenoiser {
        let cfg = MockDenoiser::test_config(20, 8, 0, kind);
        MockDenoiser::fixed(cfg, TARGET.to_vec())
    }

    #[test]
    fn d3pm_absorbing_converges_with_t_nfe() {
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::D3pm, 30);
        let out = generate(&den, &cfg, None, 2, 7, None).unwrap();
        assert_eq!(out.nfe, 30);
        assert_eq!(den.calls(), 30);
        for seq in &out.tokens {
            assert_eq!(seq, &TARGET.to_vec());
        }
    }

    #[test]
    fn d3pm_multinomial_converges() {
        let den = mock("multinomial");
        let cfg = SamplerConfig::new(SamplerKind::D3pm, 40);
        let out = generate(&den, &cfg, None, 2, 3, None).unwrap();
        // posterior sampling is stochastic but the mock's peak dominates
        let hits: usize = out.tokens[0]
            .iter()
            .zip(TARGET.iter())
            .filter(|(a, b)| a == b)
            .count();
        assert!(hits >= 7, "{:?}", out.tokens[0]);
    }

    #[test]
    fn rdm_variants_converge_and_reveal_everything() {
        for topk in [false, true] {
            for kind in ["absorbing", "multinomial"] {
                let den = mock(kind);
                let cfg = SamplerConfig::new(
                    if topk { SamplerKind::RdmTopK } else { SamplerKind::Rdm },
                    25,
                );
                let out = generate(&den, &cfg, None, 2, 11, None).unwrap();
                assert_eq!(out.nfe, 25);
                for seq in &out.tokens {
                    assert_eq!(seq, &TARGET.to_vec(), "kind={kind} topk={topk}");
                }
            }
        }
    }

    #[test]
    fn mask_predict_converges_and_requires_absorbing() {
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::MaskPredict, 10);
        let out = generate(&den, &cfg, None, 2, 5, None).unwrap();
        assert_eq!(out.nfe, 10);
        for seq in &out.tokens {
            assert_eq!(seq, &TARGET.to_vec());
        }
        let den = mock("multinomial");
        assert!(generate(&den, &cfg, None, 1, 5, None).is_err());
    }

    #[test]
    fn mask_predict_intermediate_has_masks() {
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::MaskPredict, 5).with_trace();
        let out = generate(&den, &cfg, None, 1, 5, None).unwrap();
        let masked_first = out.trace[0].tokens.iter().filter(|&&t| t == 2).count();
        let masked_last = out.trace.last().unwrap().tokens.iter().filter(|&&t| t == 2).count();
        assert!(masked_first > 0, "early iterations re-mask low scores");
        assert_eq!(masked_last, 0);
    }
}
