//! The per-NFE sampling state machine — every sampler as a resumable
//! session instead of a closed run-to-completion loop.
//!
//! DNDM's predetermined transition set 𝒯 fixes every denoiser call before
//! sampling begins (Algorithm 1), so a sampler is naturally a sequence of
//! (call time, state update) events. A [`SamplerSession`] exposes exactly
//! that structure:
//!
//! ```text
//! let mut sess = SamplerSession::new(den.config(), &cfg, batch, seed)?;
//! let mut ts = vec![0.0; batch];
//! let mut logits = LogitsBuf::new();
//! while let Some(call) = sess.next_event() {
//!     ts.fill(call.t);
//!     den.denoise_into(sess.x(), &ts, src, &mut logits)?;
//!     sess.advance(&logits)?;
//! }
//! let result = sess.into_result();
//! ```
//!
//! Yielding control to the caller at every NFE boundary is what lets the
//! coordinator's continuous scheduler merge new requests into an in-flight
//! batch between calls (`coordinator::scheduler`) — the serving-side
//! analogue of the paper's |𝒯|-call speedup. The legacy [`generate`]
//! dispatch is now just [`drive`] over a session, so closed-loop and
//! hand-stepped sampling are the same code path and produce byte-identical
//! outputs (pinned by `tests/determinism.rs`).
//!
//! Data flow is flat end to end: session state is a [`TokenBatch`], logits
//! arrive as a [`LogitsView`] (possibly a `narrow`ed window of a larger
//! scheduler batch), and no tokens or logits are copied per NFE outside
//! the denoiser itself (`docs/perf.md`).
//!
//! Sessions can also **shrink**: [`SamplerSession::evict_slot`] removes
//! one sequence mid-flight (cancellation inside a shared-𝒯 lane) while
//! leaving every survivor byte-identical, because each row samples from
//! its own forked RNG stream (see the `Core` docs). Event scheduling is
//! **per row**: each sequence carries its own descending event ladder
//! (its own 𝒯 for the DNDM family, the step grid / decode order for the
//! baselines) and [`SamplerSession::next_event`] merges the survivors'
//! ladders lazily, so evicting a row also retires every event only that
//! row needed — the lane's remaining denoiser calls drop to exactly the
//! survivors' union-|𝒯| and [`SamplerSession::total_events`] stays
//! exact after narrowing.
//!
//! And sessions can **move** — or **split**: a `SamplerSession` is `Send`
//! (its state is pure host data — tokens, RNG streams, the predetermined
//! per-row event ladders and their cursors), so the serving layer can
//! hand a live session to another engine thread at an NFE boundary and
//! resume it there with the exact bytes it would have produced in place,
//! or carve a subset of rows out with [`SamplerSession::split_rows`] and
//! resume the two halves independently. The coordinator's lane donation
//! and lane splitting (`coordinator::rebalancer`, `docs/rebalancing.md`)
//! are built on this.
//!
//! [`generate`]: super::generate

use anyhow::{bail, Result};

use crate::runtime::{Denoiser, ModelConfig};
use crate::schedule::{AlphaSchedule, SplitMix64};
use crate::tensor::{LogitsBuf, LogitsView, TokenBatch};

use super::common::{init_noise, noise_of};
use super::{ardm, baselines, ddim, dndm, dndm_topk};
use super::{GenResult, SamplerConfig, SamplerKind, TracePoint};

/// The denoiser call a session needs next.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PendingCall {
    /// Normalized time in [0, 1] to feed the denoiser for every sequence
    /// in this session (sessions are time-aligned internally).
    pub t: f32,
    /// Exact event time — identical to `t` for discrete samplers, the
    /// full-precision timestamp for DNDM-C (trace resolution).
    pub t_exact: f64,
    /// 0-based index of this call within the session (== NFE so far).
    pub index: usize,
}

/// State shared by every algorithm: current tokens, the RNG streams, and
/// per-event accounting.
///
/// Randomness is split into two kinds of streams, both derived
/// deterministically from the session seed:
///
/// * `rng` — the **lane stream**: everything drawn once per session and
///   shared across sequences (x_T init, the predetermined 𝒯, ARDM's
///   decode order).
/// * `row_rngs[b]` — one **per-sequence stream** per batch row, forked
///   from the lane stream at construction. Every per-(row, position) draw
///   inside `advance` uses its own row's stream, so a sequence's sampled
///   tokens never depend on how many neighbours share its batch. That
///   independence is what makes [`SamplerSession::evict_slot`] exact:
///   removing a row removes its stream, and every survivor's remaining
///   draws are byte-for-byte the draws it would have made anyway.
pub(crate) struct Core {
    /// current tokens x_t, flat `[B, N]`
    pub x: TokenBatch,
    /// lane stream: session-level draws shared by all rows
    pub rng: SplitMix64,
    /// per-sequence streams, index-aligned with the rows of `x`
    pub row_rngs: Vec<SplitMix64>,
    pub temperature: f32,
    /// sequence length N
    pub n: usize,
    /// vocab size V
    pub v: usize,
    pub trace_on: bool,
    pub trace: Vec<TracePoint>,
    /// denoiser calls completed
    pub nfe: usize,
}

impl Core {
    /// Book-keeping after one denoiser call has been applied.
    pub fn finish_event(&mut self, t: f64) {
        self.nfe += 1;
        if self.trace_on {
            self.trace.push(TracePoint { t, tokens: self.x.row(0).to_vec() });
        }
    }

    /// Drop row `i`: its tokens compact out of `x` and its RNG stream is
    /// discarded. Survivor streams are untouched.
    fn evict_row(&mut self, i: usize) {
        self.x.narrow_remove(i);
        self.row_rngs.remove(i);
    }

    /// Carve `rows` (strictly ascending) out into a new core, removing
    /// them from `self`. Moved rows keep their tokens and their forked
    /// RNG streams byte-for-byte; the lane stream is cloned into both
    /// halves (it is drawn only at construction — x_T init, 𝒯, ARDM's
    /// decode order — so the copies never diverge). The split half never
    /// traces (serving sessions don't trace).
    fn split_rows(&mut self, rows: &[usize]) -> Core {
        let mut x = TokenBatch::new(self.n);
        let mut row_rngs = Vec::with_capacity(rows.len());
        for &r in rows {
            x.push_row(self.x.row(r));
            row_rngs.push(self.row_rngs[r].clone());
        }
        for &r in rows.iter().rev() {
            self.x.narrow_remove(r);
            self.row_rngs.remove(r);
        }
        Core {
            x,
            rng: self.rng.clone(),
            row_rngs,
            temperature: self.temperature,
            n: self.n,
            v: self.v,
            trace_on: false,
            trace: Vec::new(),
            nfe: self.nfe,
        }
    }
}

/// One sampling algorithm's private state. Implementations live next to
/// the algorithms they refactor (`dndm.rs`, `baselines.rs`, …).
///
/// `Send` is a supertrait by design: every implementation is plain host
/// data (token buffers, RNG streams, the predetermined event ladder and
/// its cursor), so a whole [`SamplerSession`] can be *moved* between
/// engine threads at an NFE boundary. That is what lets the coordinator
/// donate an in-flight lane to another shard
/// (`coordinator::rebalancer`) with byte-exact resumption — unlike the
/// PJRT handles, which stay pinned to their thread, session state is
/// pure data and travels freely.
pub(crate) trait AlgState: Send {
    /// `(t_for_denoiser, exact_event_time)` of the next call, or `None`
    /// when sampling is complete.
    fn next_t(&self, core: &Core) -> Option<(f32, f64)>;

    /// Apply the logits of the pending call: update `core.x`, consume RNG,
    /// and finish with `core.finish_event(..)`. Returns how many rows
    /// moved at this event (sampled at least one position, or — for the
    /// step-marching baselines — took part in the step). A return of 0 is
    /// a **ghost event**: a denoiser call no surviving row needed, which
    /// the per-row ladders exist to eliminate (the serving layer counts
    /// these as `ghost_events_fired` and CI gates them at zero).
    fn advance(&mut self, core: &mut Core, logits: LogitsView<'_>) -> usize;

    /// The discrete per-position transition times, for samplers that
    /// predetermine them (the DNDM family).
    fn taus(&self) -> Option<&[Vec<usize>]> {
        None
    }

    /// Total denoiser calls this session will make over its whole life:
    /// events already fired plus the merged remainder of the *current*
    /// rows' ladders (|∪𝒯| for the DNDM family, T for the step-marching
    /// baselines, ⌈N/k⌉ for ARDM). Exact at admission **and after every
    /// eviction or split** — powers `nfe_total` in serving progress
    /// events and the rebalancer's remaining-work cost model.
    fn total_events(&self) -> usize;

    /// Remove sequence `row`'s per-row state (called by
    /// [`SamplerSession::evict_slot`] after the core row is gone). The
    /// default is for algorithms whose state is fully shared across rows
    /// (every row participates in every event, so nothing per-row needs
    /// dropping and no event can become a ghost). Algorithms with
    /// per-row event ladders (the DNDM family) drop the departed row's
    /// ladder here: events unique to that row are retired with it, and
    /// `total_events` shrinks to the count already fired plus the
    /// survivors' merged remainder. Survivors stay byte-identical either
    /// way — per-row draws come from per-row streams, so a retired event
    /// changes no survivor's RNG sequence.
    fn evict_row(&mut self, _row: usize) {}

    /// Merged events dropped at construction by Turbo truncation
    /// (`SamplerConfig::max_nfe`, `docs/tiers.md`). 0 for every untiered
    /// session and every algorithm without per-row ladders.
    fn truncated_events(&self) -> usize {
        0
    }

    /// Early-retirement probe (serving tiers, `docs/tiers.md`): is row
    /// `row` **settled** — are all of its remaining transitions provably
    /// no-ops, so the serving layer may retire it now and refund the
    /// leftover denoiser calls? Called at NFE boundaries right after
    /// [`Self::advance`], with the same logits that call consumed.
    /// Implementations must be conservative (`false` when in doubt) and
    /// allocation-free — the scheduler probes on its steady-state path.
    /// The default `false` keeps every algorithm without a settlement
    /// proof (the DNDM family: each remaining ladder event still unmasks
    /// at least one position) on the exact full schedule.
    fn row_settled(&self, _core: &Core, _row: usize, _logits: LogitsView<'_>) -> bool {
        false
    }

    /// Carve the per-row state of `rows` (strictly ascending, validated
    /// by [`SamplerSession::split_rows`]) out into a state for a new
    /// `rows.len()`-sequence session, removing it from `self`. Shared
    /// state (step grids, schedules, the 𝒯 spec) is cloned; per-row state
    /// (event ladders, reveal masks) is partitioned. Both halves must
    /// resume byte-exactly — the serving layer splits one wide lane
    /// across two shards on top of this (`docs/rebalancing.md`).
    fn split_rows(&mut self, rows: &[usize]) -> Box<dyn AlgState>;
}

/// Construct the shared core: the lane RNG from the seed, x_T (from
/// q_noise, or all-`[MASK]` for the mask-seeded algorithms, which draw
/// nothing for x_T), then one forked per-sequence stream per row. Forking
/// happens *before* the algorithm state draws its 𝒯 from the lane stream,
/// so (seed, batch) fully determines every stream.
pub(crate) fn build_core(
    mcfg: &ModelConfig,
    cfg: &SamplerConfig,
    batch: usize,
    seed: u64,
    masked_init: bool,
) -> Core {
    let n = mcfg.seq_len;
    let mut rng = SplitMix64::new(seed);
    let x = if masked_init {
        TokenBatch::filled(batch, n, mcfg.mask_id)
    } else {
        init_noise(batch, n, noise_of(mcfg), &mut rng)
    };
    let row_rngs = (0..batch).map(|b| rng.fork(b as u64)).collect();
    Core {
        x,
        rng,
        row_rngs,
        temperature: cfg.temperature,
        n,
        v: mcfg.vocab,
        trace_on: cfg.trace,
        trace: Vec::new(),
        nfe: 0,
    }
}

/// A batched sampling run, advanced one NFE at a time by the caller.
pub struct SamplerSession {
    core: Core,
    alg: Box<dyn AlgState>,
    batch: usize,
}

impl SamplerSession {
    /// Build a session for `cfg.kind`. Fails fast on model/sampler
    /// mismatches (mask-predict & ARDM need absorbing, DDIM multinomial).
    pub fn new(
        mcfg: &ModelConfig,
        cfg: &SamplerConfig,
        batch: usize,
        seed: u64,
    ) -> Result<SamplerSession> {
        match cfg.kind {
            SamplerKind::MaskPredict | SamplerKind::Ardm if mcfg.kind != "absorbing" => {
                bail!("{} requires an absorbing model", cfg.kind.name());
            }
            SamplerKind::Ddim if mcfg.kind != "multinomial" => {
                bail!("ddim-discrete is defined for multinomial diffusion");
            }
            // τ is drawn from 1..=T, so the discrete DNDM family needs a
            // non-empty grid (the step-marching baselines treat T = 0 as a
            // no-op instead; DNDM-C ignores `steps` entirely)
            SamplerKind::Dndm | SamplerKind::DndmV2 | SamplerKind::DndmTopK
                if cfg.steps == 0 =>
            {
                bail!("{} requires steps >= 1", cfg.kind.name());
            }
            _ => {}
        }
        let masked_init =
            matches!(cfg.kind, SamplerKind::MaskPredict | SamplerKind::Ardm);
        let mut core = build_core(mcfg, cfg, batch, seed, masked_init);
        let sched = AlphaSchedule::parse(&mcfg.schedule).unwrap_or(AlphaSchedule::CosineSq);
        let noise = noise_of(mcfg);
        let alg: Box<dyn AlgState> = match cfg.kind {
            SamplerKind::Dndm => Box::new(dndm::DndmState::new(&mut core, cfg, batch, false)),
            SamplerKind::DndmV2 => Box::new(dndm::DndmState::new(&mut core, cfg, batch, true)),
            SamplerKind::DndmC => Box::new(dndm::DndmCState::new(&mut core, cfg)),
            SamplerKind::DndmTopK => Box::new(dndm_topk::TopKState::new(&mut core, cfg, batch)),
            SamplerKind::D3pm => Box::new(baselines::D3pmState::new(cfg, sched, noise)),
            SamplerKind::Rdm => {
                Box::new(baselines::RdmState::new(cfg, sched, batch, core.n, false))
            }
            SamplerKind::RdmTopK => {
                Box::new(baselines::RdmState::new(cfg, sched, batch, core.n, true))
            }
            SamplerKind::MaskPredict => {
                Box::new(baselines::MaskPredictState::new(cfg, mcfg.mask_id))
            }
            SamplerKind::Ddim => Box::new(ddim::DdimState::new(cfg, sched, noise, 1.0)),
            SamplerKind::Ardm => Box::new(ardm::ArdmState::new(&mut core, 1)),
        };
        Ok(SamplerSession { core, alg, batch })
    }

    /// Assemble a session from a pre-built core + algorithm state (the
    /// escape hatch for non-default knobs: DDIM's η, ARDM's parallel k).
    pub(crate) fn from_parts(core: Core, alg: Box<dyn AlgState>, batch: usize) -> SamplerSession {
        SamplerSession { core, alg, batch }
    }

    /// Number of sequences in this session.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Current tokens (x_t) as a flat `[B, N]` batch — what the next
    /// denoiser call must see, borrowable without per-row clones.
    pub fn x(&self) -> &TokenBatch {
        &self.core.x
    }

    /// Denoiser calls completed so far (== |𝒯| events fired for DNDM).
    pub fn nfe(&self) -> usize {
        self.core.nfe
    }

    /// Total denoiser calls this session makes over its whole life:
    /// |∪𝒯| over the current rows for the DNDM family (the paper's
    /// headline quantity), T for the step-marching baselines, ⌈N/k⌉ for
    /// ARDM. Predetermined at construction and kept **exact** across
    /// [`Self::evict_slot`] / [`Self::split_rows`] — after narrowing it
    /// shrinks to the calls already made plus the survivors' merged
    /// remainder. Equals [`Self::nfe`] once the session is done; serving
    /// uses it as `nfe_total` in streamed progress events and the
    /// rebalancer prices lanes with it.
    pub fn total_events(&self) -> usize {
        self.alg.total_events()
    }

    /// Merged events dropped at construction by Turbo truncation
    /// (`SamplerConfig::max_nfe`); 0 everywhere else. The serving layer
    /// surfaces the lane-level sum as `turbo_truncated_nfe`.
    pub fn truncated_events(&self) -> usize {
        self.alg.truncated_events()
    }

    /// Early-retirement probe (`docs/tiers.md`): `true` when row `row`'s
    /// remaining transitions are provably no-ops given the logits of the
    /// call just applied — for absorbing D3PM, no `[MASK]` left in the
    /// row (the absorbing reverse step is the identity on unmasked
    /// tokens); for the re-prediction baselines (RDM / Mask-Predict) at
    /// temperature 0, every position already holds its argmax and no
    /// re-masking remains. Allocation-free; call right after
    /// [`Self::advance`] with the same logits view.
    pub fn row_settled<'a>(&self, row: usize, logits: impl Into<LogitsView<'a>>) -> bool {
        row < self.batch && self.alg.row_settled(&self.core, row, logits.into())
    }

    pub fn is_done(&self) -> bool {
        self.alg.next_t(&self.core).is_none()
    }

    /// The next denoiser call this session needs, or `None` when finished.
    pub fn next_event(&self) -> Option<PendingCall> {
        self.alg
            .next_t(&self.core)
            .map(|(t, t_exact)| PendingCall { t, t_exact, index: self.core.nfe })
    }

    /// Apply the logits answering [`Self::next_event`]'s call. Accepts a
    /// `&LogitsBuf` or a [`LogitsView`] (e.g. a `narrow`ed window of a
    /// scheduler-level batch). Returns how many rows moved at this event;
    /// 0 marks a ghost event — a denoiser call no row needed, which
    /// per-row ladders make impossible within one session (the serving
    /// layer still counts the return to prove that in CI).
    pub fn advance<'a>(&mut self, logits: impl Into<LogitsView<'a>>) -> Result<usize> {
        let view: LogitsView<'a> = logits.into();
        if self.alg.next_t(&self.core).is_none() {
            bail!("session is already complete");
        }
        if view.batch() != self.batch {
            bail!("logits batch {} != session batch {}", view.batch(), self.batch);
        }
        if view.seq_len() != self.core.n || view.vocab() != self.core.v {
            bail!(
                "logits dims [{}, {}] != model dims [{}, {}]",
                view.seq_len(),
                view.vocab(),
                self.core.n,
                self.core.v
            );
        }
        Ok(self.alg.advance(&mut self.core, view))
    }

    /// Drop sequence `i` from the session mid-flight: its token row
    /// compacts out of `x()`, its RNG stream and per-row algorithm state
    /// — including its event ladder — are discarded, and the next
    /// denoiser call is one row narrower.
    ///
    /// Survivors are **byte-exact**: each sequence samples from its own
    /// forked stream, so every remaining row produces exactly the tokens
    /// it would have produced had the evicted row stayed (pinned per kind
    /// by `tests/narrowing.rs`). Events only the evicted row needed are
    /// retired with it: the remaining schedule re-merges from the
    /// survivors' ladders, [`Self::total_events`] shrinks to the calls
    /// already made plus the survivors' union-|𝒯|, and the lane never
    /// pays a ghost denoiser call for a departed row. This is what lets
    /// the scheduler free a cancelled request's slot at the next
    /// transition-time boundary instead of riding it to lane retirement.
    ///
    /// The last row cannot be evicted — drop the whole session instead.
    /// With tracing on, the trace follows whichever row is currently row
    /// 0 (serving sessions never trace).
    pub fn evict_slot(&mut self, i: usize) -> Result<()> {
        if i >= self.batch {
            bail!("slot {i} out of bounds for session batch {}", self.batch);
        }
        if self.batch == 1 {
            bail!("cannot evict the last slot; drop the session instead");
        }
        self.core.evict_row(i);
        self.alg.evict_row(i);
        self.batch -= 1;
        Ok(())
    }

    /// Carve sequences `rows` (strictly ascending row indices) out of
    /// this session into a new, independent session, shrinking `self` to
    /// the rows that stay. Call only at an NFE boundary (after an
    /// [`Self::advance`], before the next denoiser call).
    ///
    /// Both halves resume **byte-exactly**: moved rows keep their forked
    /// RNG streams and their event ladders, shared algorithm state is
    /// cloned, and the lane stream is never drawn after construction, so
    /// each half produces exactly the tokens the unsplit session would
    /// have (pinned per kind by `tests/rebalance.rs`). Each half's
    /// [`Self::total_events`] re-merges over its own rows, so the two
    /// totals may each be smaller than the original — splitting can
    /// *reduce* combined denoiser calls for per-seq-𝒯 lanes, never
    /// increase per-row work. The scheduler's lane splitting
    /// (`donate_rows`) is built on this.
    ///
    /// At least one row must move and at least one must stay.
    pub fn split_rows(&mut self, rows: &[usize]) -> Result<SamplerSession> {
        if rows.is_empty() {
            bail!("split_rows needs at least one row to move");
        }
        if rows.len() >= self.batch {
            bail!(
                "cannot split all {} rows out of a {}-row session; move the whole session instead",
                rows.len(),
                self.batch
            );
        }
        if !rows.windows(2).all(|w| w[0] < w[1]) {
            bail!("split_rows indices must be strictly ascending: {rows:?}");
        }
        if *rows.last().unwrap() >= self.batch {
            bail!(
                "row {} out of bounds for session batch {}",
                rows.last().unwrap(),
                self.batch
            );
        }
        let core = self.core.split_rows(rows);
        let alg = self.alg.split_rows(rows);
        self.batch -= rows.len();
        Ok(SamplerSession { core, alg, batch: rows.len() })
    }

    /// Predetermined per-position transition times (DNDM family only).
    pub fn taus(&self) -> Option<&[Vec<usize>]> {
        self.alg.taus()
    }

    pub fn into_result(self) -> GenResult {
        GenResult { tokens: self.core.x.into_rows(), nfe: self.core.nfe, trace: self.core.trace }
    }
}

/// Run a session to completion against a denoiser — the thin driver loop
/// the legacy `generate()` dispatch now reduces to. The time vector and
/// the logits buffer are allocated once and reused for every NFE call.
pub fn drive(
    den: &dyn Denoiser,
    mut sess: SamplerSession,
    src: Option<&TokenBatch>,
) -> Result<GenResult> {
    let mut ts = vec![0.0f32; sess.batch()];
    let mut logits = LogitsBuf::new();
    while let Some(call) = sess.next_event() {
        ts.fill(call.t);
        den.denoise_into(sess.x(), &ts, src, &mut logits)?;
        sess.advance(&logits)?;
    }
    Ok(sess.into_result())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockDenoiser;
    use crate::sampler::{generate, SamplerConfig, SamplerKind};

    fn mock(kind: &str) -> MockDenoiser {
        let cfg = MockDenoiser::test_config(20, 8, 0, kind);
        MockDenoiser::fixed(cfg, vec![10, 11, 12, 13, 14, 15, 16, 17])
    }

    #[test]
    fn hand_stepped_session_matches_generate() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50).with_temperature(1.0);
        let den = mock("absorbing");
        let want = generate(&den, &cfg, None, 2, 7, None).unwrap();

        let den = mock("absorbing");
        let mut sess = SamplerSession::new(den.config(), &cfg, 2, 7).unwrap();
        let mut calls = 0;
        while let Some(call) = sess.next_event() {
            assert_eq!(call.index, calls);
            let logits = den.denoise(sess.x(), &vec![call.t; sess.batch()], None).unwrap();
            sess.advance(&logits).unwrap();
            calls += 1;
        }
        assert!(sess.is_done());
        let got = sess.into_result();
        assert_eq!(got.tokens, want.tokens);
        assert_eq!(got.nfe, want.nfe);
        assert_eq!(calls, got.nfe);
    }

    #[test]
    fn event_times_are_decreasing_for_dndm() {
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50);
        let mut sess = SamplerSession::new(den.config(), &cfg, 1, 3).unwrap();
        let mut prev = f32::INFINITY;
        while let Some(call) = sess.next_event() {
            assert!(call.t < prev, "event times must strictly decrease");
            prev = call.t;
            let logits = den.denoise(sess.x(), &vec![call.t; 1], None).unwrap();
            sess.advance(&logits).unwrap();
        }
    }

    #[test]
    fn advance_rejects_wrong_batch_and_completed_session() {
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 25);
        let mut sess = SamplerSession::new(den.config(), &cfg, 2, 5).unwrap();
        let call = sess.next_event().unwrap();
        let logits = den.denoise(sess.x(), &vec![call.t; 2], None).unwrap();
        assert!(
            sess.advance(logits.view().narrow(0, 1)).is_err(),
            "wrong batch must fail"
        );
        sess.advance(&logits).unwrap();
        while let Some(call) = sess.next_event() {
            let logits = den.denoise(sess.x(), &vec![call.t; 2], None).unwrap();
            sess.advance(&logits).unwrap();
        }
        let logits = den.denoise(sess.x(), &[1.0, 1.0], None).unwrap();
        assert!(sess.advance(&logits).is_err(), "completed session must fail");
    }

    #[test]
    fn advance_rejects_mismatched_dims() {
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 25);
        let mut sess = SamplerSession::new(den.config(), &cfg, 1, 5).unwrap();
        let mut wrong = LogitsBuf::new();
        wrong.reset(1, 8, 21); // vocab 21 != model vocab 20
        assert!(sess.advance(&wrong).is_err());
        let mut wrong = LogitsBuf::new();
        wrong.reset(1, 7, 20); // seq_len 7 != model seq_len 8
        assert!(sess.advance(&wrong).is_err());
    }

    #[test]
    fn dndm_session_exposes_taus_baselines_dont() {
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 25);
        let sess = SamplerSession::new(den.config(), &cfg, 3, 1).unwrap();
        let taus = sess.taus().unwrap();
        assert_eq!(taus.len(), 3);
        assert!(taus.iter().all(|row| row.iter().all(|&t| (1..=25).contains(&t))));

        let cfg = SamplerConfig::new(SamplerKind::D3pm, 25);
        let sess = SamplerSession::new(den.config(), &cfg, 1, 1).unwrap();
        assert!(sess.taus().is_none());
    }

    #[test]
    fn total_events_is_known_up_front_and_matches_final_nfe() {
        let kinds: [(SamplerKind, &str); 10] = [
            (SamplerKind::Dndm, "absorbing"),
            (SamplerKind::DndmV2, "absorbing"),
            (SamplerKind::DndmTopK, "absorbing"),
            (SamplerKind::DndmC, "absorbing"),
            (SamplerKind::D3pm, "absorbing"),
            (SamplerKind::Rdm, "absorbing"),
            (SamplerKind::RdmTopK, "multinomial"),
            (SamplerKind::MaskPredict, "absorbing"),
            (SamplerKind::Ddim, "multinomial"),
            (SamplerKind::Ardm, "absorbing"),
        ];
        for (sk, noise) in kinds {
            let den = mock(noise);
            let cfg = SamplerConfig::new(sk, 25);
            let mut sess = SamplerSession::new(den.config(), &cfg, 2, 11).unwrap();
            let total = sess.total_events();
            assert!(total >= 1, "{}: total must be predetermined", sk.name());
            while let Some(call) = sess.next_event() {
                assert!(call.index < total, "{}: index within total", sk.name());
                let logits = den.denoise(sess.x(), &vec![call.t; 2], None).unwrap();
                sess.advance(&logits).unwrap();
            }
            assert_eq!(
                sess.total_events(),
                sess.nfe(),
                "{}: total_events == final NFE",
                sk.name()
            );
            assert_eq!(sess.nfe(), total, "{}: total is stable over the run", sk.name());
        }
    }

    fn drive_rest(den: &MockDenoiser, mut sess: SamplerSession) -> Vec<Vec<u32>> {
        while let Some(call) = sess.next_event() {
            let logits =
                den.denoise(sess.x(), &vec![call.t; sess.batch()], None).unwrap();
            sess.advance(&logits).unwrap();
        }
        sess.into_result().tokens
    }

    #[test]
    fn split_rows_validates_its_arguments() {
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 25);
        let mut sess = SamplerSession::new(den.config(), &cfg, 3, 5).unwrap();
        assert!(sess.split_rows(&[]).is_err(), "empty split");
        assert!(sess.split_rows(&[0, 1, 2]).is_err(), "cannot move every row");
        assert!(sess.split_rows(&[1, 1]).is_err(), "must be strictly ascending");
        assert!(sess.split_rows(&[2, 1]).is_err(), "must be strictly ascending");
        assert!(sess.split_rows(&[3]).is_err(), "out of bounds");
        assert_eq!(sess.batch(), 3, "failed splits leave the session intact");
    }

    #[test]
    fn split_halves_match_the_unsplit_run() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50).with_temperature(1.0);
        let den = mock("absorbing");
        let want = generate(&den, &cfg, None, 4, 9, None).unwrap();

        let den = mock("absorbing");
        let mut sess = SamplerSession::new(den.config(), &cfg, 4, 9).unwrap();
        // one event together, then carve rows 1 and 3 off mid-flight
        let call = sess.next_event().unwrap();
        let logits = den.denoise(sess.x(), &vec![call.t; 4], None).unwrap();
        sess.advance(&logits).unwrap();
        let moved = sess.split_rows(&[1, 3]).unwrap();
        assert_eq!(sess.batch(), 2);
        assert_eq!(moved.batch(), 2);
        assert_eq!(moved.nfe(), 1, "the split half inherits the event count");
        let keep = drive_rest(&den, sess);
        let split = drive_rest(&den, moved);
        assert_eq!(keep[0], want.tokens[0]);
        assert_eq!(keep[1], want.tokens[2]);
        assert_eq!(split[0], want.tokens[1]);
        assert_eq!(split[1], want.tokens[3]);
    }

    #[test]
    fn sessions_are_send() {
        // the static guarantee lane donation rests on: a live session can
        // move to another engine thread (compile-time check)
        fn assert_send<T: Send>() {}
        assert_send::<SamplerSession>();
    }

    #[test]
    fn session_rejects_model_mismatch() {
        let den = mock("multinomial");
        for kind in [SamplerKind::MaskPredict, SamplerKind::Ardm] {
            let cfg = SamplerConfig::new(kind, 10);
            assert!(SamplerSession::new(den.config(), &cfg, 1, 1).is_err(), "{kind:?}");
        }
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::Ddim, 10);
        assert!(SamplerSession::new(den.config(), &cfg, 1, 1).is_err());
    }
}
