//! Sampling algorithms, structured as per-NFE state machines.
//!
//! Every algorithm is a [`session::SamplerSession`]: it owns its
//! predetermined transition set 𝒯 (DNDM family) or per-step schedule
//! (baselines), exposes `next_event()` / `advance(logits)`, and yields
//! control back to the caller at every denoiser-call boundary. The
//! [`generate`] dispatch is a thin [`session::drive`] loop over a session;
//! the coordinator's continuous scheduler steps sessions by hand to merge
//! requests into in-flight batches.
//!
//! The paper's contributions:
//! * [`dndm`] — Algorithm 1 (DNDM), Algorithm 3 (DNDM-v2, re-update τ≥t)
//!   and Algorithm 2 (DNDM-C, continuous/infinite-step).
//! * [`dndm_topk`] — Algorithm 4 (DNDM-k, top-k transition time).
//!
//! Baselines reproduced for the tables:
//! * [`baselines`] — D3PM ancestral sampling (one NN call per step,
//!   stochastic posterior per token), RDM reparameterized sampling
//!   (Zheng 2023, with/without top-k selection), and Mask-Predict
//!   (Ghazvininejad 2019) for Table 13.
//! * [`ddim`] / [`ardm`] — the Remark 3.5 / 3.7 comparators.

pub mod ardm;
pub mod baselines;
pub mod common;
pub mod ddim;
pub mod dndm;
pub mod dndm_topk;
pub mod session;

use anyhow::{bail, Result};

use crate::metrics::NfeCounter;
use crate::runtime::Denoiser;
use crate::schedule::{AlphaSchedule, TransitionOrder, TransitionSpec};

pub use session::{PendingCall, SamplerSession};

/// Which algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplerKind {
    /// Algorithm 1 — DNDM with predetermined transition times.
    Dndm,
    /// Algorithm 3 — DNDM updating every token with τ ≥ t (more robust).
    DndmV2,
    /// Algorithm 4 — DNDM-k: top-k score-ordered transitions.
    DndmTopK,
    /// Algorithm 2 — DNDM-C: continuous-time (∞-step) sampling.
    DndmC,
    /// Vanilla D3PM ancestral sampling (NFE = T).
    D3pm,
    /// RDM reparameterized sampling (NFE = T).
    Rdm,
    /// RDM with top-k token selection (NFE = T).
    RdmTopK,
    /// Mask-Predict (absorbing models only; NFE = steps).
    MaskPredict,
    /// DDIM-discrete comparator (Appendix B.1; multinomial only, NFE = T).
    Ddim,
    /// ARDM-style order-agnostic AR baseline (Remark 3.7; absorbing, NFE = N).
    Ardm,
}

impl SamplerKind {
    pub fn name(&self) -> &'static str {
        match self {
            SamplerKind::Dndm => "dndm",
            SamplerKind::DndmV2 => "dndm-v2",
            SamplerKind::DndmTopK => "dndm-k",
            SamplerKind::DndmC => "dndm-c",
            SamplerKind::D3pm => "d3pm",
            SamplerKind::Rdm => "rdm",
            SamplerKind::RdmTopK => "rdm-k",
            SamplerKind::MaskPredict => "mask-predict",
            SamplerKind::Ddim => "ddim",
            SamplerKind::Ardm => "ardm",
        }
    }

    pub fn parse(s: &str) -> Option<SamplerKind> {
        match s {
            "dndm" => Some(SamplerKind::Dndm),
            "dndm-v2" | "dndm2" => Some(SamplerKind::DndmV2),
            "dndm-k" | "dndm-topk" => Some(SamplerKind::DndmTopK),
            "dndm-c" | "dndm-inf" => Some(SamplerKind::DndmC),
            "d3pm" | "vanilla" => Some(SamplerKind::D3pm),
            "rdm" => Some(SamplerKind::Rdm),
            "rdm-k" | "rdm-topk" => Some(SamplerKind::RdmTopK),
            "mask-predict" | "maskpredict" => Some(SamplerKind::MaskPredict),
            "ddim" => Some(SamplerKind::Ddim),
            "ardm" => Some(SamplerKind::Ardm),
            _ => None,
        }
    }

    pub fn is_dndm(&self) -> bool {
        matches!(
            self,
            SamplerKind::Dndm | SamplerKind::DndmV2 | SamplerKind::DndmTopK | SamplerKind::DndmC
        )
    }
}

/// Full sampling configuration.
#[derive(Debug, Clone)]
pub struct SamplerConfig {
    pub kind: SamplerKind,
    /// T (discrete step count); ignored by DndmC.
    pub steps: usize,
    /// 𝒟_τ for the DNDM family.
    pub spec: TransitionSpec,
    /// positional τ assignment (Table 6).
    pub order: TransitionOrder,
    /// Gumbel temperature for x̂0 draws; 0.0 = greedy argmax.
    pub temperature: f32,
    /// sample one shared 𝒯 per batch (the paper's batched implementation)
    /// or one per sequence (ablation).
    pub shared_tau: bool,
    /// record per-event snapshots (Figure 2).
    pub trace: bool,
    /// Turbo cap on per-row |𝒯| (serving tiers, `docs/tiers.md`): after 𝒯
    /// is sampled, deterministically drop the lowest-impact transition
    /// times of each row whose ladder exceeds the cap. `None` (the
    /// default) leaves 𝒯 untouched — every pre-tier call site is
    /// byte-identical. Honoured by Dndm / DndmV2 ladders; step-marching
    /// kinds are capped by lowering `steps` at admission instead.
    pub max_nfe: Option<usize>,
}

impl SamplerConfig {
    pub fn new(kind: SamplerKind, steps: usize) -> SamplerConfig {
        SamplerConfig {
            kind,
            steps,
            spec: TransitionSpec::Beta { a: 15.0, b: 7.0 },
            order: TransitionOrder::Random,
            temperature: 0.0,
            shared_tau: true,
            trace: false,
            max_nfe: None,
        }
    }

    pub fn with_spec(mut self, spec: TransitionSpec) -> Self {
        self.spec = spec;
        self
    }

    pub fn with_order(mut self, order: TransitionOrder) -> Self {
        self.order = order;
        self
    }

    pub fn with_temperature(mut self, t: f32) -> Self {
        self.temperature = t;
        self
    }

    pub fn with_trace(mut self) -> Self {
        self.trace = true;
        self
    }

    /// Cap per-row |𝒯| at `n` by Turbo truncation (see `max_nfe`).
    pub fn with_max_nfe(mut self, n: usize) -> Self {
        self.max_nfe = Some(n);
        self
    }

    /// Use the exact 𝒟_τ induced by an α schedule (Theorem 3.6).
    pub fn exact_from_schedule(mut self, sched: AlphaSchedule) -> Self {
        self.spec = TransitionSpec::Exact(sched);
        self
    }
}

/// Snapshot after one NN call (Figure 2 trajectories).
#[derive(Debug, Clone)]
pub struct TracePoint {
    /// normalized time of the call
    pub t: f64,
    /// tokens of sequence 0 after the update
    pub tokens: Vec<u32>,
}

/// Result of one batched generation.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub tokens: Vec<Vec<u32>>,
    /// NN calls made for this batch (= |𝒯| for DNDM, T for baselines)
    pub nfe: usize,
    pub trace: Vec<TracePoint>,
}

/// Dispatch: run `cfg.kind` on `den` for a batch of `batch` sequences.
/// The src rows are flattened once into a [`crate::tensor::TokenBatch`];
/// the per-NFE loop then runs without copying them again.
pub fn generate(
    den: &dyn Denoiser,
    cfg: &SamplerConfig,
    src: Option<&[Vec<u32>]>,
    batch: usize,
    seed: u64,
    counter: Option<&NfeCounter>,
) -> Result<GenResult> {
    if let Some(s) = src {
        if s.len() != batch {
            bail!("src batch {} != batch {}", s.len(), batch);
        }
    } else if den.config().conditional() {
        bail!("conditional model requires src");
    }
    let src_tb = src.map(crate::tensor::TokenBatch::from_rows);
    let sess = SamplerSession::new(den.config(), cfg, batch, seed)?;
    let result = session::drive(den, sess, src_tb.as_ref())?;
    if let Some(c) = counter {
        for _ in 0..result.nfe {
            c.record_call(batch);
        }
        c.record_batch();
    }
    Ok(result)
}
