//! DNDM-k — Algorithm 4: top-k transition time (Appendix E).
//!
//! Instead of binding each transition time to a fixed *position*, the
//! sampled 𝒯 only fixes the *count* sequence K_t = Σ_n 1(τ_n ≥ t): at each
//! event the K_t highest-scoring not-yet-decoded positions transition,
//! where the score s_{t,n} is the denoiser's log-probability of its own
//! decoded token. Same NFE as Algorithm 1; + ~1–2 BLEU in the paper.

use crate::schedule::TransitionTimes;
use crate::tensor::LogitsView;

use super::common::sample_x0;
use super::session::{AlgState, Core};
use super::SamplerConfig;

pub(crate) struct TopKState {
    /// shared 𝒯 fixing the K_t ladder (counts only; positions score-picked)
    tt: TransitionTimes,
    /// decoded-set U per sequence
    updated: Vec<Vec<bool>>,
    idx: usize,
    t_max: usize,
    /// per-advance (pos, token, score) scratch, reused across events to
    /// avoid per-event Vec churn (the score sort itself still pays std's
    /// stable-sort merge buffer at n > 20)
    cand: Vec<(usize, u32, f32)>,
}

impl TopKState {
    pub(crate) fn new(core: &mut Core, cfg: &SamplerConfig, batch: usize) -> TopKState {
        let t_max = cfg.steps;
        let tt = cfg.spec.sample_times(t_max, core.n, cfg.order, &mut core.rng);
        TopKState {
            tt,
            updated: vec![vec![false; core.n]; batch],
            idx: 0,
            t_max,
            cand: Vec::with_capacity(core.n),
        }
    }
}

impl AlgState for TopKState {
    fn next_t(&self, _core: &Core) -> Option<(f32, f64)> {
        self.tt.events().get(self.idx).map(|&t| {
            let t_norm = t as f32 / self.t_max as f32;
            (t_norm, t_norm as f64)
        })
    }

    fn advance(&mut self, core: &mut Core, logits: LogitsView<'_>) -> usize {
        let t = self.tt.events()[self.idx];
        // after this event, k_target tokens must be decoded in total
        let k_target = self.tt.k_t(t);
        let t_norm = t as f32 / self.t_max as f32;
        let moved = core.x.rows();

        for b in 0..moved {
            // decode + score every position, then commit the top scorers
            self.cand.clear();
            for pos in 0..core.n {
                let (tok, score) =
                    sample_x0(logits.row(b, pos), core.temperature, &mut core.row_rngs[b]);
                self.cand.push((pos, tok, score));
            }
            self.cand.sort_by(|a, b| b.2.total_cmp(&a.2));
            let mut committed = self.updated[b].iter().filter(|&&u| u).count();
            for &(pos, tok, _) in &self.cand {
                if committed >= k_target {
                    break;
                }
                if !self.updated[b][pos] {
                    core.x.set(b, pos, tok);
                    self.updated[b][pos] = true;
                    committed += 1;
                }
            }
        }
        self.idx += 1;
        core.finish_event(t_norm as f64);
        moved
    }

    // no taus() override: Algorithm 4 predetermines the K_t counts, not
    // per-position times, so the default `None` is correct.

    fn total_events(&self) -> usize {
        self.tt.events().len()
    }

    fn evict_row(&mut self, row: usize) {
        // the K_t ladder is shared (every row commits at every event — the
        // count sequence is strictly increasing), so only the decoded-set
        // goes; no event can become unique to one row
        self.updated.remove(row);
    }

    fn split_rows(&mut self, rows: &[usize]) -> Box<dyn AlgState> {
        let mut updated = Vec::with_capacity(rows.len());
        for &r in rows {
            updated.push(self.updated[r].clone());
        }
        for &r in rows.iter().rev() {
            self.updated.remove(r);
        }
        Box::new(TopKState {
            tt: self.tt.clone(),
            updated,
            idx: self.idx,
            t_max: self.t_max,
            cand: Vec::with_capacity(self.cand.capacity()),
        })
    }
}

#[cfg(test)]
mod tests {
    use crate::runtime::{Denoiser, MockDenoiser};
    use crate::sampler::{generate, SamplerConfig, SamplerKind};

    fn mock(kind: &str) -> MockDenoiser {
        let cfg = MockDenoiser::test_config(20, 8, 0, kind);
        MockDenoiser::fixed(cfg, vec![10, 11, 12, 13, 14, 15, 16, 17])
    }

    #[test]
    fn converges_and_nfe_matches_dndm() {
        for kind in ["absorbing", "multinomial"] {
            let den = mock(kind);
            let cfg = SamplerConfig::new(SamplerKind::DndmTopK, 50);
            let out = generate(&den, &cfg, None, 2, 7, None).unwrap();
            for seq in &out.tokens {
                assert_eq!(seq, &vec![10, 11, 12, 13, 14, 15, 16, 17], "{kind}");
            }
            assert!(out.nfe <= 8);
            assert_eq!(den.calls() as usize, out.nfe);
        }
    }

    #[test]
    fn all_positions_decoded_exactly_once() {
        // K_1 = N ⇒ by the last event every position must be committed and
        // never recommitted (the U-set discipline of Algorithm 4).
        let den = mock("absorbing");
        let cfg = SamplerConfig::new(SamplerKind::DndmTopK, 25).with_trace();
        let out = generate(&den, &cfg, None, 1, 3, None).unwrap();
        assert!(out.tokens[0].iter().all(|&t| t != 2), "no masks left");
        // trace token counts must be monotonically "revealed"
        let mut revealed_prev = 0;
        for tp in &out.trace {
            let revealed = tp.tokens.iter().filter(|&&t| t != 2).count();
            assert!(revealed >= revealed_prev);
            revealed_prev = revealed;
        }
        assert_eq!(revealed_prev, 8);
    }

    #[test]
    fn score_ordering_decodes_confident_positions_first() {
        // give position 3 a much higher peak than others via a target fn
        // that is only confident on position 3: expose through score order.
        let cfg = MockDenoiser::test_config(20, 4, 0, "absorbing");
        // all positions target token 9; mock peak uniform — scores tie, so
        // any order is valid; we only assert the invariant that the number
        // decoded after event i equals K_{t_i}.
        let den = MockDenoiser::fixed(cfg, vec![9, 9, 9, 9]);
        let cfg = SamplerConfig::new(SamplerKind::DndmTopK, 50).with_trace();
        let out = generate(&den, &cfg, None, 1, 5, None).unwrap();
        assert_eq!(out.tokens[0], vec![9, 9, 9, 9]);
    }
}
