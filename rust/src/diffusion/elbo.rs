//! DNDM evidence lower bound (Appendix B.3).
//!
//! The paper decomposes the ELBO over transition times instead of steps:
//! conditioned on 𝒯, the only stochastic reconstruction each token needs
//! is p_θ(x₀,ₙ | x_{τ_n}) at its own transition time, so
//!
//!   −ELBO(x₀) ≈ E_{𝒯~𝒟_τ} Σ_n −log p_θ(x₀,ₙ | x_{τ_n}, τ_n)  (+ const)
//!
//! with x_{τ_n} drawn from the non-Markov forward (eq. 7): position m is
//! still x₀ if τ_m > τ_n, already noise w_m otherwise. This gives a
//! Monte-Carlo NLL-per-token estimator that costs |𝒯| network calls per
//! sample — the evaluation-side twin of the fast sampler, used by the
//! benches as a likelihood sanity check and by tests to verify that the
//! Markov and non-Markov corruptions score identically in expectation
//! (Theorem 3.1 at the loss level, Appendix B.3's claim).

use anyhow::Result;

use crate::runtime::Denoiser;
use crate::sampler::common::{log_prob, noise_of, row};
use crate::schedule::{SplitMix64, TransitionOrder, TransitionSpec};
use crate::tensor::{LogitsBuf, TokenBatch};

/// Monte-Carlo −ELBO estimate in nats/token for one sequence.
///
/// `samples` independent 𝒯 draws are averaged; each draw costs |𝒯| calls.
pub fn dndm_nll(
    den: &dyn Denoiser,
    x0: &[u32],
    src: Option<&[u32]>,
    spec: &TransitionSpec,
    t_max: usize,
    samples: usize,
    rng: &mut SplitMix64,
) -> Result<f64> {
    let cfg = den.config().clone();
    let (n, v) = (cfg.seq_len, cfg.vocab);
    assert_eq!(x0.len(), n);
    let noise = noise_of(&cfg);

    let src_b = src.map(|s| TokenBatch::from_rows(&[s.to_vec()]));
    let mut x_t = TokenBatch::filled(1, n, 0);
    let mut logits = LogitsBuf::new();
    let mut total = 0.0f64;
    for _ in 0..samples {
        let tt = spec.sample_times(t_max, n, TransitionOrder::Random, rng);
        // per-token time-invariant noise draw w_n (eq. 6)
        let w: Vec<u32> = (0..n).map(|_| noise.sample(rng)).collect();
        for &t in tt.events() {
            // eq. 7 state at time t: x0 where τ > t, w where τ ≤ t
            for m in 0..n {
                x_t.set(0, m, if tt.taus[m] > t { x0[m] } else { w[m] });
            }
            let t_norm = t as f32 / t_max as f32;
            den.denoise_into(&x_t, &[t_norm], src_b.as_ref(), &mut logits)?;
            for m in tt.moves_at(t) {
                total += -f64::from(log_prob(row(logits.seq(0), m, v), x0[m] as usize));
            }
        }
    }
    Ok(total / (samples * n) as f64)
}

/// Control estimator: the same reconstruction loss but with x_t drawn from
/// the *Markov* marginal (eq. 3) at each token's τ — per Theorem 3.1 both
/// corruptions share q(x_t|x0), so the two estimators agree in expectation.
pub fn markov_nll(
    den: &dyn Denoiser,
    x0: &[u32],
    src: Option<&[u32]>,
    spec: &TransitionSpec,
    t_max: usize,
    samples: usize,
    rng: &mut SplitMix64,
) -> Result<f64> {
    let cfg = den.config().clone();
    let (n, v) = (cfg.seq_len, cfg.vocab);
    let noise = noise_of(&cfg);
    let sched = crate::schedule::AlphaSchedule::parse(&cfg.schedule)
        .unwrap_or(crate::schedule::AlphaSchedule::CosineSq);

    let src_b = src.map(|s| TokenBatch::from_rows(&[s.to_vec()]));
    let mut x_t = TokenBatch::filled(1, n, 0);
    let mut logits = LogitsBuf::new();
    let mut total = 0.0f64;
    for _ in 0..samples {
        let tt = spec.sample_times(t_max, n, TransitionOrder::Random, rng);
        for &t in tt.events() {
            // fresh marginal draw per position (Markov chain's q(x_t|x0))
            for m in 0..n {
                x_t.set(
                    0,
                    m,
                    crate::diffusion::forward_marginal(x0[m], sched, t, t_max, noise, rng),
                );
            }
            let t_norm = t as f32 / t_max as f32;
            den.denoise_into(&x_t, &[t_norm], src_b.as_ref(), &mut logits)?;
            for m in tt.moves_at(t) {
                total += -f64::from(log_prob(row(logits.seq(0), m, v), x0[m] as usize));
            }
        }
    }
    Ok(total / (samples * n) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockDenoiser;
    use crate::schedule::AlphaSchedule;

    const TARGET: [u32; 8] = [10, 11, 12, 13, 14, 15, 16, 17];

    fn spec() -> TransitionSpec {
        TransitionSpec::Exact(AlphaSchedule::CosineSq)
    }

    #[test]
    fn perfect_model_has_near_zero_nll() {
        let cfg = MockDenoiser::test_config(20, 8, 0, "absorbing");
        let mut den = MockDenoiser::fixed(cfg, TARGET.to_vec());
        den.peak = 20.0;
        let mut rng = SplitMix64::new(1);
        let nll = dndm_nll(&den, &TARGET, None, &spec(), 50, 4, &mut rng).unwrap();
        assert!(nll < 0.05, "{nll}");
    }

    #[test]
    fn uniform_model_has_log_v_nll() {
        // a mock with peak 0 emits (almost) uniform logits → NLL ≈ ln V
        let cfg = MockDenoiser::test_config(20, 8, 0, "multinomial");
        let mut den = MockDenoiser::fixed(cfg, TARGET.to_vec());
        den.peak = 0.0;
        let mut rng = SplitMix64::new(2);
        let nll = dndm_nll(&den, &TARGET, None, &spec(), 50, 4, &mut rng).unwrap();
        let ln_v = (20f64).ln();
        assert!((nll - ln_v).abs() < 0.4, "{nll} vs ln V = {ln_v}");
    }

    #[test]
    fn wrong_target_scores_worse_than_right_target() {
        let cfg = MockDenoiser::test_config(20, 8, 0, "absorbing");
        let mut den = MockDenoiser::fixed(cfg, TARGET.to_vec());
        den.peak = 6.0;
        let mut rng = SplitMix64::new(3);
        let right = dndm_nll(&den, &TARGET, None, &spec(), 50, 3, &mut rng).unwrap();
        let wrong: Vec<u32> = TARGET.iter().map(|&t| t.wrapping_sub(5) % 20).collect();
        let bad = dndm_nll(&den, &wrong, None, &spec(), 50, 3, &mut rng).unwrap();
        assert!(bad > right + 1.0, "{bad} vs {right}");
    }

    #[test]
    fn markov_and_dndm_estimators_agree_in_expectation() {
        // Theorem 3.1 at the loss level (Appendix B.3): both corruptions
        // have the same q(x_t|x0), so the two NLL estimators converge to
        // the same value. The mock depends only weakly on x_t (the 0.5
        // self-bump), so the agreement is tight even with few samples.
        let cfg = MockDenoiser::test_config(20, 8, 0, "multinomial");
        let mut den = MockDenoiser::fixed(cfg, TARGET.to_vec());
        den.peak = 4.0;
        let mut rng = SplitMix64::new(4);
        let a = dndm_nll(&den, &TARGET, None, &spec(), 30, 24, &mut rng).unwrap();
        let b = markov_nll(&den, &TARGET, None, &spec(), 30, 24, &mut rng).unwrap();
        assert!((a - b).abs() < 0.08, "dndm {a} vs markov {b}");
    }
}
