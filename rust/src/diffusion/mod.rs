//! The discrete diffusion substrate: noise distributions, forward
//! processes (Markov eq. 1 and non-Markov eq. 6), and the reverse-step
//! posteriors the baseline samplers need.

pub mod elbo;
pub mod noise;
pub mod posterior;
pub mod process;

pub use elbo::{dndm_nll, markov_nll};
pub use noise::NoiseKind;
pub use posterior::{absorbing_reverse_step, multinomial_posterior, multinomial_reverse_step};
pub use process::{forward_marginal, forward_markov, forward_non_markov};
