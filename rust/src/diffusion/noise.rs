//! q_noise — the two noise families the paper covers (§2).

use crate::schedule::SplitMix64;

/// The stationary noise distribution q_noise.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NoiseKind {
    /// Uniform over the usable vocabulary [lo, vocab) — multinomial
    /// diffusion (Hoogeboom et al. 2021b). `lo` excludes the special
    /// tokens (<pad>/<unk>/<mask>), mirroring trainer.py::NOISE_LO.
    Multinomial { lo: u32, vocab: u32 },
    /// Point mass on the absorbing `[MASK]` state (Austin et al. 2021).
    Absorbing { mask_id: u32 },
}

impl NoiseKind {
    pub fn parse(kind: &str, noise_lo: u32, vocab: u32, mask_id: u32) -> Option<NoiseKind> {
        match kind {
            "multinomial" => Some(NoiseKind::Multinomial { lo: noise_lo, vocab }),
            "absorbing" => Some(NoiseKind::Absorbing { mask_id }),
            _ => None,
        }
    }

    /// Draw w ~ q_noise.
    #[inline]
    pub fn sample(&self, rng: &mut SplitMix64) -> u32 {
        match *self {
            NoiseKind::Multinomial { lo, vocab } => lo + rng.below((vocab - lo) as u64) as u32,
            NoiseKind::Absorbing { mask_id } => mask_id,
        }
    }

    /// q_noise(x) — the probability the noise assigns to token x.
    pub fn prob(&self, x: u32) -> f64 {
        match *self {
            NoiseKind::Multinomial { lo, vocab } => {
                if x >= lo && x < vocab {
                    1.0 / (vocab - lo) as f64
                } else {
                    0.0
                }
            }
            NoiseKind::Absorbing { mask_id } => {
                if x == mask_id {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }

    /// Fill a whole sequence with noise (the x_T initialization).
    pub fn sample_seq(&self, n: usize, rng: &mut SplitMix64) -> Vec<u32> {
        (0..n).map(|_| self.sample(rng)).collect()
    }

    pub fn is_absorbing(&self) -> bool {
        matches!(self, NoiseKind::Absorbing { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multinomial_avoids_specials_and_is_uniform() {
        let nk = NoiseKind::Multinomial { lo: 3, vocab: 13 };
        let mut rng = SplitMix64::new(1);
        let mut counts = [0usize; 13];
        for _ in 0..50_000 {
            counts[nk.sample(&mut rng) as usize] += 1;
        }
        assert_eq!(counts[0] + counts[1] + counts[2], 0);
        for c in &counts[3..] {
            let f = *c as f64 / 50_000.0;
            assert!((f - 0.1).abs() < 0.01);
        }
    }

    #[test]
    fn absorbing_is_point_mass() {
        let nk = NoiseKind::Absorbing { mask_id: 2 };
        let mut rng = SplitMix64::new(2);
        for _ in 0..100 {
            assert_eq!(nk.sample(&mut rng), 2);
        }
        assert_eq!(nk.prob(2), 1.0);
        assert_eq!(nk.prob(5), 0.0);
    }

    #[test]
    fn prob_sums_to_one() {
        let nk = NoiseKind::Multinomial { lo: 3, vocab: 30 };
        let total: f64 = (0..30).map(|x| nk.prob(x)).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }
}
