//! Reverse-step posteriors q(x_{t−1} | x_t, x̂0) — the per-step machinery
//! of the **baseline** samplers (D3PM ancestral sampling and RDM).
//!
//! DNDM itself never touches these: its reverse step (eq. 9) is the
//! deterministic select in `sampler::dndm`. These formulas are Appendix
//! B.1/B.2 of the paper.

use crate::schedule::{AlphaSchedule, SplitMix64};

use super::noise::NoiseKind;

/// Multinomial posterior over x_{t−1} for one token (Appendix B.2):
/// θ_post(x_t, x̂0) ∝ (β_t·x_t + (1−β_t)·q_noise) ⊙ (α_{t−1}·x̂0 + (1−α_{t−1})·q_noise)
///
/// Returns an unnormalized weight vector over the vocabulary.
pub fn multinomial_posterior(
    x_t: u32,
    x0_hat: u32,
    k: usize,
    t_max: usize,
    sched: AlphaSchedule,
    noise: NoiseKind,
    vocab: usize,
) -> Vec<f64> {
    let beta = sched.beta_discrete(k, t_max);
    let a_prev = sched.alpha_discrete(k - 1, t_max);
    let mut w = vec![0.0f64; vocab];
    for (x, wx) in w.iter_mut().enumerate() {
        let x = x as u32;
        let lhs = if x == x_t { beta } else { 0.0 } + (1.0 - beta) * noise.prob(x);
        let rhs = if x == x0_hat { a_prev } else { 0.0 } + (1.0 - a_prev) * noise.prob(x);
        *wx = lhs * rhs;
    }
    w
}

/// Draw x_{t−1} from the multinomial posterior.
pub fn multinomial_reverse_step(
    x_t: u32,
    x0_hat: u32,
    k: usize,
    t_max: usize,
    sched: AlphaSchedule,
    noise: NoiseKind,
    vocab: usize,
    rng: &mut SplitMix64,
) -> u32 {
    let w = multinomial_posterior(x_t, x0_hat, k, t_max, sched, noise, vocab);
    rng.categorical(&w) as u32
}

/// Absorbing-diffusion reverse step (Appendix B.1):
/// if x_t ≠ `[MASK]`    → x_{t−1} = x_t (already decoded, frozen);
/// if x_t = `[MASK]`    → stay `[MASK]` w.p. (1−α_{t−1})/(1−α_t),
///                      else reveal x̂0.
pub fn absorbing_reverse_step(
    x_t: u32,
    x0_hat: u32,
    k: usize,
    t_max: usize,
    sched: AlphaSchedule,
    mask_id: u32,
    rng: &mut SplitMix64,
) -> u32 {
    if x_t != mask_id {
        return x_t;
    }
    let a_t = sched.alpha_discrete(k, t_max);
    let a_prev = sched.alpha_discrete(k - 1, t_max);
    let stay_mask = if a_t >= 1.0 { 0.0 } else { (1.0 - a_prev) / (1.0 - a_t) };
    if rng.coin(stay_mask) {
        mask_id
    } else {
        x0_hat
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const T: usize = 10;
    const V: usize = 8;

    #[test]
    fn multinomial_posterior_is_valid_and_consistent_with_bayes() {
        // brute-force Bayes check: q(x_{t-1}|x_t,x0) ∝ q(x_t|x_{t-1})·q(x_{t-1}|x0)
        let sched = AlphaSchedule::CosineSq;
        let noise = NoiseKind::Multinomial { lo: 0, vocab: V as u32 };
        let (x_t, x0, k) = (3u32, 5u32, 6usize);
        let w = multinomial_posterior(x_t, x0, k, T, sched, noise, V);
        assert!(w.iter().all(|&p| p >= 0.0));
        assert!(w.iter().sum::<f64>() > 0.0);

        let beta = sched.beta_discrete(k, T);
        let a_prev = sched.alpha_discrete(k - 1, T);
        for x_prev in 0..V as u32 {
            // q(x_t|x_{t-1}) under the Markov kernel (eq. 2)
            let fwd = if x_t == x_prev { beta } else { 0.0 } + (1.0 - beta) / V as f64;
            // q(x_{t-1}|x0) marginal (eq. 3)
            let marg = if x_prev == x0 { a_prev } else { 0.0 } + (1.0 - a_prev) / V as f64;
            let expect = fwd * marg;
            assert!(
                (w[x_prev as usize] - expect).abs() < 1e-12,
                "x_prev={x_prev}: {} vs {expect}",
                w[x_prev as usize]
            );
        }
    }

    #[test]
    fn multinomial_reverse_recovers_x0_at_k1() {
        // at k=1, α_0 = 1 ⇒ posterior puts all non-x_t mass on x̂0
        let sched = AlphaSchedule::Linear;
        let noise = NoiseKind::Multinomial { lo: 0, vocab: V as u32 };
        let mut rng = SplitMix64::new(1);
        let mut hits = 0;
        for _ in 0..2_000 {
            let x = multinomial_reverse_step(2, 5, 1, T, sched, noise, V, &mut rng);
            if x == 5 {
                hits += 1;
            }
        }
        // β_1 < 1 leaves some mass on x_t = 2; everything else goes to 5
        assert!(hits > 1_500, "{hits}");
        let w = multinomial_posterior(2, 5, 1, T, sched, noise, V);
        for (i, &p) in w.iter().enumerate() {
            if i != 2 && i != 5 {
                assert!(p.abs() < 1e-12);
            }
        }
    }

    #[test]
    fn absorbing_freezes_decoded_tokens() {
        let sched = AlphaSchedule::Linear;
        let mut rng = SplitMix64::new(2);
        for k in 2..=T {
            assert_eq!(
                absorbing_reverse_step(4, 6, k, T, sched, 0, &mut rng),
                4,
                "decoded token must not change"
            );
        }
    }

    #[test]
    fn absorbing_reveal_probability_matches_formula() {
        let sched = AlphaSchedule::Linear;
        let (k, mask) = (5usize, 0u32);
        let a_t = sched.alpha_discrete(k, T);
        let a_prev = sched.alpha_discrete(k - 1, T);
        let p_reveal = (a_prev - a_t) / (1.0 - a_t);
        let mut rng = SplitMix64::new(3);
        let n = 40_000;
        let mut revealed = 0;
        for _ in 0..n {
            if absorbing_reverse_step(mask, 7, k, T, sched, mask, &mut rng) == 7 {
                revealed += 1;
            }
        }
        let f = revealed as f64 / n as f64;
        assert!((f - p_reveal).abs() < 0.01, "{f} vs {p_reveal}");
    }

    #[test]
    fn absorbing_always_reveals_at_k1() {
        let sched = AlphaSchedule::CosineSq;
        let mut rng = SplitMix64::new(4);
        for _ in 0..200 {
            assert_eq!(absorbing_reverse_step(0, 3, 1, T, sched, 0, &mut rng), 3);
        }
    }
}
