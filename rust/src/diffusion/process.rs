//! Forward processes: the Markov chain (eq. 1), the non-Markov chain
//! (eq. 6), and the shared marginal (Theorems 3.1 / eq. 3).
//!
//! These exist for testing and documentation — the serving path never runs
//! a forward pass — but they are the executable statement of the paper's
//! central claim: both processes induce the *same* q(x_t | x_0), so a
//! network trained under (1) drives DNDM sampling under (6) unchanged.

use crate::schedule::{AlphaSchedule, SplitMix64};

use super::noise::NoiseKind;

/// One trajectory of the **Markov** forward process (eq. 1):
/// x_t = b_t·x_{t−1} + (1 − b_t)·w_t with fresh noise w_t each step.
/// Returns [x_0, x_1, …, x_T] for a single token.
pub fn forward_markov(
    x0: u32,
    sched: AlphaSchedule,
    t_max: usize,
    noise: NoiseKind,
    rng: &mut SplitMix64,
) -> Vec<u32> {
    let mut traj = Vec::with_capacity(t_max + 1);
    let mut x = x0;
    traj.push(x);
    for k in 1..=t_max {
        let beta = sched.beta_discrete(k, t_max);
        if !rng.coin(beta) {
            x = noise.sample(rng); // fresh w_t
        }
        traj.push(x);
    }
    traj
}

/// One trajectory of the **non-Markov** forward process (eq. 6):
/// x_t = b_t·x_{t−1} + (1 − b_t)·w with a single, time-invariant w.
/// Once transitioned, the token stays at w forever (eq. 7).
pub fn forward_non_markov(
    x0: u32,
    sched: AlphaSchedule,
    t_max: usize,
    noise: NoiseKind,
    rng: &mut SplitMix64,
) -> Vec<u32> {
    let w = noise.sample(rng);
    let mut traj = Vec::with_capacity(t_max + 1);
    let mut transitioned = false;
    traj.push(x0);
    for k in 1..=t_max {
        let beta = sched.beta_discrete(k, t_max);
        if !transitioned && !rng.coin(beta) {
            transitioned = true; // τ = k
        }
        traj.push(if transitioned { w } else { x0 });
    }
    traj
}

/// Direct draw from the shared marginal q(x_t|x_0) =
/// Cat(α_t·x_0 + (1 − α_t)·q_noise) (eq. 3 / Thm 3.1).
pub fn forward_marginal(
    x0: u32,
    sched: AlphaSchedule,
    k: usize,
    t_max: usize,
    noise: NoiseKind,
    rng: &mut SplitMix64,
) -> u32 {
    let a = sched.alpha_discrete(k, t_max);
    if rng.coin(a) {
        x0
    } else {
        noise.sample(rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const T: usize = 20;
    const TRIALS: usize = 30_000;

    fn keep_rate(trajs: &[Vec<u32>], k: usize, x0: u32) -> f64 {
        trajs.iter().filter(|t| t[k] == x0).count() as f64 / trajs.len() as f64
    }

    /// Theorem 3.1, empirically: the Markov and non-Markov processes have
    /// the same marginal ℙ(x_t = x_0) at every t.
    #[test]
    fn markov_and_non_markov_share_marginals() {
        let sched = AlphaSchedule::CosineSq;
        let noise = NoiseKind::Absorbing { mask_id: 99 };
        let x0 = 7u32;
        let mut rng = SplitMix64::new(31);
        let mk: Vec<_> = (0..TRIALS)
            .map(|_| forward_markov(x0, sched, T, noise, &mut rng))
            .collect();
        let nm: Vec<_> = (0..TRIALS)
            .map(|_| forward_non_markov(x0, sched, T, noise, &mut rng))
            .collect();
        for k in [1, 5, 10, 15, 20] {
            let a = sched.alpha_discrete(k, T);
            let fm = keep_rate(&mk, k, x0);
            let fn_ = keep_rate(&nm, k, x0);
            assert!((fm - a).abs() < 0.015, "markov k={k}: {fm} vs α={a}");
            assert!((fn_ - a).abs() < 0.015, "non-markov k={k}: {fn_} vs α={a}");
        }
    }

    /// With multinomial noise the *joint* behaviour differs (w fixed vs
    /// fresh w_t): in the non-Markov chain a token that left x0 never takes
    /// two different noise values; in the Markov chain it can.
    #[test]
    fn non_markov_noise_is_time_invariant() {
        let sched = AlphaSchedule::Linear;
        let noise = NoiseKind::Multinomial { lo: 0, vocab: 50 };
        let x0 = 777; // outside vocab → never equal to noise
        let mut rng = SplitMix64::new(77);
        let mut markov_changed = false;
        for _ in 0..2_000 {
            let nm = forward_non_markov(x0, sched, T, noise, &mut rng);
            let noise_vals: std::collections::HashSet<u32> =
                nm.iter().copied().filter(|&v| v != x0).collect();
            assert!(noise_vals.len() <= 1, "non-markov used two noise values");

            let mk = forward_markov(x0, sched, T, noise, &mut rng);
            let mk_vals: std::collections::HashSet<u32> =
                mk.iter().copied().filter(|&v| v != x0).collect();
            if mk_vals.len() > 1 {
                markov_changed = true;
            }
        }
        assert!(markov_changed, "markov chain should resample noise");
    }

    /// Eq. 7: the non-Markov trajectory is x0 before τ and w after — i.e.
    /// exactly one change point.
    #[test]
    fn non_markov_has_single_change_point() {
        let sched = AlphaSchedule::Cosine;
        let noise = NoiseKind::Multinomial { lo: 0, vocab: 10 };
        let mut rng = SplitMix64::new(5);
        for _ in 0..2_000 {
            let traj = forward_non_markov(1_000, sched, T, noise, &mut rng);
            let changes = traj.windows(2).filter(|w| w[0] != w[1]).count();
            assert!(changes <= 1, "trajectory changed {changes} times: {traj:?}");
            assert_ne!(traj[T], 1_000, "α_T = 0 ⇒ x_T must be noise");
        }
    }

    #[test]
    fn marginal_sampler_matches_alpha() {
        let sched = AlphaSchedule::Linear;
        let noise = NoiseKind::Absorbing { mask_id: 0 };
        let mut rng = SplitMix64::new(13);
        let k = 7;
        let a = sched.alpha_discrete(k, T);
        let kept = (0..TRIALS)
            .filter(|_| forward_marginal(9, sched, k, T, noise, &mut rng) == 9)
            .count();
        let f = kept as f64 / TRIALS as f64;
        assert!((f - a).abs() < 0.01, "{f} vs {a}");
    }
}
