//! Tokenization / vocabulary substrate (mirrors python/compile/common.py).

pub mod bpe;
pub mod vocab;

pub use bpe::Bpe;
pub use vocab::{Vocab, MASK, PAD, UNK};
