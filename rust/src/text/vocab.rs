//! Vocabulary: token ↔ id mapping, padding-aware encode/decode.
//!
//! Mirrors `python/compile/common.py::Vocab` — same special tokens at the
//! same ids (<pad>=0, <unk>=1, <mask>=2) so the trained checkpoints and
//! rust-side data agree. Parity is pinned by `rust/tests/parity.rs`.

use std::collections::HashMap;

pub const PAD: &str = "<pad>";
pub const UNK: &str = "<unk>";
pub const MASK: &str = "<mask>";

#[derive(Debug, Clone)]
pub struct Vocab {
    tokens: Vec<String>,
    index: HashMap<String, u32>,
}

impl Vocab {
    pub fn new(tokens: Vec<String>) -> Self {
        let index = tokens
            .iter()
            .enumerate()
            .map(|(i, t)| (t.clone(), i as u32))
            .collect();
        Self { tokens, index }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn pad_id(&self) -> u32 {
        self.index[PAD]
    }

    pub fn unk_id(&self) -> u32 {
        self.index[UNK]
    }

    pub fn mask_id(&self) -> u32 {
        self.index[MASK]
    }

    pub fn id(&self, token: &str) -> Option<u32> {
        self.index.get(token).copied()
    }

    pub fn token(&self, id: u32) -> &str {
        &self.tokens[id as usize]
    }

    pub fn tokens(&self) -> &[String] {
        &self.tokens
    }

    /// Encode to a fixed length n: truncate then right-pad with <pad>.
    pub fn encode(&self, words: &[&str], n: usize) -> Vec<u32> {
        let unk = self.unk_id();
        let mut ids: Vec<u32> = words
            .iter()
            .take(n)
            .map(|w| self.id(w).unwrap_or(unk))
            .collect();
        ids.resize(n, self.pad_id());
        ids
    }

    /// Encode a whitespace-separated string.
    pub fn encode_str(&self, s: &str, n: usize) -> Vec<u32> {
        let words: Vec<&str> = s.split_whitespace().collect();
        self.encode(&words, n)
    }

    /// Decode, dropping <pad>.
    pub fn decode(&self, ids: &[u32]) -> Vec<&str> {
        ids.iter()
            .map(|&i| self.token(i))
            .filter(|t| *t != PAD)
            .collect()
    }

    pub fn decode_str(&self, ids: &[u32]) -> String {
        self.decode(ids).join(" ")
    }

    /// Decode chars (unconditional corpora) — tokens are single chars.
    pub fn decode_chars(&self, ids: &[u32]) -> String {
        ids.iter()
            .map(|&i| self.token(i))
            .filter(|t| *t != PAD && *t != UNK && *t != MASK)
            .collect()
    }
}

#[cfg(test)]
mod tests {

    use crate::data::words;

    #[test]
    fn special_ids_are_fixed() {
        let v = words::translation_vocab();
        assert_eq!(v.pad_id(), 0);
        assert_eq!(v.unk_id(), 1);
        assert_eq!(v.mask_id(), 2);
    }

    #[test]
    fn encode_pads_and_truncates() {
        let v = words::translation_vocab();
        let ids = v.encode(&["the", "quick", "fox"], 8);
        assert_eq!(ids.len(), 8);
        assert_eq!(&ids[3..], &[0, 0, 0, 0, 0]);
        let trunc = v.encode(&["the"; 20], 4);
        assert_eq!(trunc.len(), 4);
        assert!(trunc.iter().all(|&i| i == v.id("the").unwrap()));
    }

    #[test]
    fn roundtrip() {
        let v = words::translation_vocab();
        let ids = v.encode(&["every", "old", "river"], 6);
        assert_eq!(v.decode(&ids), vec!["every", "old", "river"]);
    }

    #[test]
    fn unknown_maps_to_unk() {
        let v = words::translation_vocab();
        assert_eq!(v.encode(&["zzzz"], 1), vec![1]);
    }

    #[test]
    fn no_duplicate_tokens() {
        for v in [
            words::translation_vocab(),
            words::text8_vocab(),
            words::enwik8_vocab(),
        ] {
            let mut seen = std::collections::HashSet::new();
            for t in v.tokens() {
                assert!(seen.insert(t.clone()), "dup token {t}");
            }
        }
    }
}
