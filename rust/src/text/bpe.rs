//! BPE-lite: learned byte-pair merges (Sennrich et al. 2016), the shared
//! sub-word vocabulary mechanism the paper's fairseq pipeline uses.
//!
//! The synthetic translation tasks are word-level, so the serving path
//! does not need BPE — but a real deployment of this stack would, and the
//! `quickstart`-level API is the same: `Bpe::train` on a corpus, then
//! `encode`/`decode` around the diffusion vocabulary. Tested standalone.

use std::collections::HashMap;

/// A learned BPE model: ordered merge rules over character symbols.
#[derive(Debug, Clone)]
pub struct Bpe {
    /// merge rules in priority order: (left, right) → joined
    merges: Vec<(String, String)>,
    rank: HashMap<(String, String), usize>,
}

impl Bpe {
    /// Learn `n_merges` merges from whitespace-tokenized text. Words are
    /// terminated with the `</w>` marker so merges never cross words.
    pub fn train(corpus: &str, n_merges: usize) -> Bpe {
        // word → frequency
        let mut word_freq: HashMap<Vec<String>, usize> = HashMap::new();
        for w in corpus.split_whitespace() {
            let mut symbols: Vec<String> = w.chars().map(|c| c.to_string()).collect();
            symbols.push("</w>".to_string());
            *word_freq.entry(symbols).or_insert(0) += 1;
        }

        let mut merges = Vec::with_capacity(n_merges);
        for _ in 0..n_merges {
            // count symbol pairs
            let mut pair_freq: HashMap<(String, String), usize> = HashMap::new();
            for (word, &f) in &word_freq {
                for pair in word.windows(2) {
                    *pair_freq
                        .entry((pair[0].clone(), pair[1].clone()))
                        .or_insert(0) += f;
                }
            }
            // best pair (ties broken lexicographically for determinism)
            let Some((best, freq)) = pair_freq
                .into_iter()
                .max_by(|a, b| a.1.cmp(&b.1).then_with(|| b.0.cmp(&a.0)))
            else {
                break;
            };
            if freq < 2 {
                break; // nothing left worth merging
            }
            // apply the merge to every word
            let joined = format!("{}{}", best.0, best.1);
            let mut next: HashMap<Vec<String>, usize> = HashMap::new();
            for (word, f) in word_freq {
                let mut out = Vec::with_capacity(word.len());
                let mut i = 0;
                while i < word.len() {
                    if i + 1 < word.len() && word[i] == best.0 && word[i + 1] == best.1 {
                        out.push(joined.clone());
                        i += 2;
                    } else {
                        out.push(word[i].clone());
                        i += 1;
                    }
                }
                *next.entry(out).or_insert(0) += f;
            }
            word_freq = next;
            merges.push(best);
        }

        let rank = merges
            .iter()
            .enumerate()
            .map(|(i, m)| (m.clone(), i))
            .collect();
        Bpe { merges, rank }
    }

    pub fn n_merges(&self) -> usize {
        self.merges.len()
    }

    /// Encode one word into sub-word symbols (greedy lowest-rank merging,
    /// the standard BPE application order).
    pub fn encode_word(&self, word: &str) -> Vec<String> {
        let mut symbols: Vec<String> = word.chars().map(|c| c.to_string()).collect();
        symbols.push("</w>".to_string());
        loop {
            let mut best: Option<(usize, usize)> = None; // (rank, index)
            for i in 0..symbols.len().saturating_sub(1) {
                if let Some(&r) =
                    self.rank.get(&(symbols[i].clone(), symbols[i + 1].clone()))
                {
                    if best.map(|(br, _)| r < br).unwrap_or(true) {
                        best = Some((r, i));
                    }
                }
            }
            match best {
                Some((_, i)) => {
                    let joined = format!("{}{}", symbols[i], symbols[i + 1]);
                    symbols.splice(i..i + 2, [joined]);
                }
                None => break,
            }
        }
        symbols
    }

    /// Encode whitespace-tokenized text.
    pub fn encode(&self, text: &str) -> Vec<String> {
        text.split_whitespace()
            .flat_map(|w| self.encode_word(w))
            .collect()
    }

    /// Invert encode: join symbols, split words at `</w>`.
    pub fn decode(&self, symbols: &[String]) -> String {
        let mut words = Vec::new();
        let mut cur = String::new();
        for s in symbols {
            if let Some(stripped) = s.strip_suffix("</w>") {
                cur.push_str(stripped);
                words.push(std::mem::take(&mut cur));
            } else {
                cur.push_str(s);
            }
        }
        if !cur.is_empty() {
            words.push(cur);
        }
        words.join(" ")
    }

    /// The sub-word vocabulary implied by the merges over a corpus.
    pub fn vocab_of(&self, corpus: &str) -> Vec<String> {
        let mut set = std::collections::BTreeSet::new();
        for sym in self.encode(corpus) {
            set.insert(sym);
        }
        set.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_pairs, Dataset, Split};

    fn corpus() -> String {
        gen_pairs(Dataset::Iwslt14, Split::Train, 300)
            .iter()
            .map(|(s, t)| format!("{} {}", s.join(" "), t.join(" ")))
            .collect::<Vec<_>>()
            .join(" ")
    }

    #[test]
    fn roundtrip_is_lossless() {
        let c = corpus();
        let bpe = Bpe::train(&c, 80);
        for (src, tgt) in gen_pairs(Dataset::Iwslt14, Split::Test, 20) {
            for text in [src.join(" "), tgt.join(" ")] {
                let enc = bpe.encode(&text);
                assert_eq!(bpe.decode(&enc), text);
            }
        }
    }

    #[test]
    fn merges_compress_frequent_words() {
        let c = corpus();
        let bpe = Bpe::train(&c, 120);
        // "the" is the most frequent word → should encode to 1-2 symbols
        let enc = bpe.encode_word("the");
        assert!(enc.len() <= 2, "{enc:?}");
        // a rare unseen word stays mostly characters
        let rare = bpe.encode_word("zzqx");
        assert!(rare.len() >= 3, "{rare:?}");
    }

    #[test]
    fn training_is_deterministic() {
        let c = corpus();
        let a = Bpe::train(&c, 50);
        let b = Bpe::train(&c, 50);
        assert_eq!(a.merges, b.merges);
    }

    #[test]
    fn more_merges_never_lengthen_encodings() {
        let c = corpus();
        let small = Bpe::train(&c, 20);
        let big = Bpe::train(&c, 200);
        let text = "the quick fox crosses a river";
        assert!(big.encode(text).len() <= small.encode(text).len());
    }

    #[test]
    fn vocab_of_covers_corpus() {
        let c = corpus();
        let bpe = Bpe::train(&c, 60);
        let vocab: std::collections::HashSet<String> =
            bpe.vocab_of(&c).into_iter().collect();
        for sym in bpe.encode(&c) {
            assert!(vocab.contains(&sym));
        }
    }

    #[test]
    fn empty_and_single_char() {
        let bpe = Bpe::train("a b a b", 5);
        assert_eq!(bpe.decode(&bpe.encode("a")), "a");
        assert_eq!(bpe.encode(""), Vec::<String>::new());
    }
}
