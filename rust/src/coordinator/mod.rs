//! L3 coordinator — the serving system around the samplers.
//!
//! * [`engine`] — owns the PJRT runtime + vocab and exposes the
//!   generate/translate API the CLI, examples and benches use.
//! * [`request`] — the client-visible request lifecycle: [`GenRequest`]
//!   (typed builder: src, seed, per-request config, deadline, priority)
//!   and [`Ticket`] (per-NFE [`Event`] stream, boundary cancellation).
//! * [`scheduler`] — the continuous NFE-aligned scheduler: requests join
//!   the in-flight batch at transition-time boundaries (the per-NFE
//!   `SamplerSession` yield points), sequences retire individually when
//!   their last τ fires, freed slots refill; the same boundaries enforce
//!   cancellation/deadlines and emit progress events.
//! * [`server`] — the request loop: multi-producer queue, fixed-batch or
//!   continuous scheduling, per-request latency/NFE accounting. PJRT
//!   handles are not `Send`, so the engine lives on the server thread and
//!   requests travel over channels (the vLLM-router shape, std::thread
//!   edition — tokio is unreachable offline).
//! * [`router`] — [`ServeBuilder`], the single entry point for both
//!   scheduling modes, and [`Router`], which shards requests across N
//!   server threads/engines with spec-affinity placement and least-loaded
//!   fallback.
//! * [`rebalancer`] — the background rebalance loop and its pure decision
//!   policy: queued-request stealing plus **in-flight lane donation** (a
//!   whole live lane moves shards at a transition-time boundary and
//!   resumes byte-exactly — possible because 𝒯 is predetermined). The
//!   same loop supervises **shard failover**: retry/backoff at the
//!   scheduler's denoiser call sites, a circuit breaker that parks lanes
//!   at a boundary, salvage onto healthy shards, engine restart. See
//!   `docs/rebalancing.md` and `docs/robustness.md`.
//! * [`telemetry`] — the per-shard lock-free [`StatsBoard`]: engine
//!   threads publish counters/gauges/seqlock snapshots on every tick and
//!   terminal; the rebalancer's views, admission's pace projection and
//!   the `/metrics` scrape read them without `Msg::Stats` channel
//!   round-trips, so observation never blocks on a parked or dead shard.
//! * [`batcher`] — the legacy fixed batching policy (max size +
//!   collection window), kept as the serving bench's ablation baseline.

pub mod batcher;
pub mod engine;
pub mod rebalancer;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod telemetry;

pub use batcher::{BatchPolicy, Batcher};
pub use engine::{cipher_mock_denoiser, cipher_mock_engine, Engine, GenOutput};
pub use rebalancer::RebalancePolicy;
pub use request::{CancelHandle, Event, GenRequest, Priority, Ticket, TicketSink, Tier, TierDecision};
pub use router::{Router, ServeBuilder};
pub use scheduler::{
    Delivery, DonatedLane, FaultPolicy, Finished, LaneInfo, Outcome, Pending, SchedPolicy,
    Scheduler, SpecKey,
};
pub use server::{Server, ServerStats};
pub use telemetry::{BoardView, PaceView, SeqCell, StatsBoard};
