//! L3 coordinator — the serving system around the samplers.
//!
//! * [`engine`] — owns the PJRT runtime + vocab and exposes the
//!   generate/translate API the CLI, examples and benches use.
//! * [`scheduler`] — the continuous NFE-aligned scheduler: requests join
//!   the in-flight batch at transition-time boundaries (the per-NFE
//!   `SamplerSession` yield points), sequences retire individually when
//!   their last τ fires, freed slots refill.
//! * [`server`] — the request loop: multi-producer queue, fixed-batch or
//!   continuous scheduling, per-request latency/NFE accounting. PJRT
//!   handles are not `Send`, so the engine lives on the server thread and
//!   requests travel over channels (the vLLM-router shape, std::thread
//!   edition — tokio is unreachable offline).
//! * [`batcher`] — the legacy fixed batching policy (max size +
//!   collection window), kept as the serving bench's ablation baseline.

pub mod batcher;
pub mod engine;
pub mod scheduler;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use engine::{cipher_mock_engine, Engine, GenOutput};
pub use scheduler::{LaneInfo, Pending, SchedPolicy, Scheduler, SpecKey};
pub use server::{Server, ServerStats};
