//! L3 coordinator — the serving system around the samplers.
//!
//! * [`engine`] — owns the PJRT runtime + vocab and exposes the
//!   generate/translate API the CLI, examples and benches use.
//! * [`server`] — the request loop: multi-producer queue, NFE-aligned
//!   dynamic batcher, per-request latency/NFE accounting. PJRT handles are
//!   not `Send`, so the engine lives on the server thread and requests
//!   travel over channels (the vLLM-router shape, std::thread edition —
//!   tokio is unreachable offline).
//! * [`batcher`] — the batching policy (max size + collection window).

pub mod batcher;
pub mod engine;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use engine::{Engine, GenOutput};
pub use server::{Server, ServerStats};
