//! The client-visible request lifecycle: typed request builder, streaming
//! ticket, and the serving-side sink that feeds it.
//!
//! DNDM's predetermined transition set makes every denoiser-call boundary
//! a safe point, so a request's life is a small state machine whose edges
//! all sit on those boundaries:
//!
//! ```text
//! submit ─ queued ──▶ Admitted ──▶ Progress* ──▶ Done(GenOutput)
//!    │        │                       │
//!    │        ├──▶ Cancelled          ├──▶ Cancelled          (at a boundary)
//!    │        └──▶ DeadlineExceeded   └──▶ DeadlineExceeded   (at a boundary)
//!    └─ (engine/spec failure anywhere) ──▶ Failed
//! ```
//!
//! [`Ticket`] is the client half: a blocking/non-blocking [`Event`] stream
//! plus [`Ticket::cancel`]. [`TicketSink`] is the serving half, threaded
//! through the scheduler; it holds one **coalescing snapshot** instead of
//! an event queue. Each boundary overwrites the snapshot in place (the
//! per-lane scratch is a reused `Vec`, so emission allocates nothing on
//! the scheduler's hot path), and the ticket turns every observed change
//! into an event. A slow reader skips intermediate snapshots but always
//! sees the final `Progress` and the terminal event; terminal events are
//! never lost.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::sampler::SamplerConfig;

use super::engine::GenOutput;

/// Queue-ordering class of a request. Within one class the scheduler is
/// strictly FIFO; a higher class is admitted first. The fixed-batch policy
/// ignores priority (its `Batcher` is FIFO by construction).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Priority {
    Low,
    #[default]
    Normal,
    High,
}

/// Serving tier of a request (`docs/tiers.md`). The tier is *policy*, not
/// mechanism: admission resolves it into a concrete [`SamplerConfig`] (and
/// a [`TierDecision`] echo) before the scheduler ever sees the request.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum Tier {
    /// Serve the requested spec untouched — byte-identical to the
    /// pre-tier path. The default: every existing call site is `Quality`.
    #[default]
    Quality,
    /// Admission searches the spec space (step count × transition spec)
    /// for the highest-NFE candidate whose projected latency on the best
    /// shard meets the SLO; an unmeetable SLO is rejected with zero NN
    /// calls spent.
    Balanced { slo_ms: u64 },
    /// Hard-cap per-row |𝒯| at `max_nfe` by deterministic Turbo
    /// truncation (DNDM ladders) or step lowering (step-marching kinds).
    Turbo { max_nfe: usize },
}

/// What admission decided for a tiered request — echoed to the client in
/// the SSE `admitted` event and the blocking JSON response.
#[derive(Debug, Clone, PartialEq)]
pub struct TierDecision {
    /// name of the spec actually served, e.g. `"beta:15:7"` / `"uniform"`
    pub chosen_spec: String,
    /// exact |𝒯| the request will be charged and served
    pub projected_nfe: u64,
    /// projected completion latency on the placed shard, in ms
    pub projected_ms: u64,
}

/// A typed generation request — the builder behind
/// [`Server::submit_request`](super::server::Server::submit_request) and
/// [`Router::submit_request`](super::router::Router::submit_request).
///
/// ```
/// use dndm::coordinator::{GenRequest, Priority};
/// use dndm::sampler::{SamplerConfig, SamplerKind};
/// use std::time::Duration;
///
/// let req = GenRequest::new(7)
///     .src("the quick fox crosses a river")
///     .config(SamplerConfig::new(SamplerKind::DndmC, 0))
///     .deadline(Duration::from_secs(2))
///     .priority(Priority::High)
///     .stream_partials();
/// ```
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub(crate) src: Option<String>,
    pub(crate) seed: u64,
    pub(crate) cfg: Option<SamplerConfig>,
    pub(crate) deadline: Option<Duration>,
    pub(crate) priority: Priority,
    pub(crate) stream: bool,
    pub(crate) tenant: Option<String>,
    pub(crate) tier: Tier,
    /// what admission decided (filled by the front door / tier resolver;
    /// `None` on every untiered path)
    pub(crate) decision: Option<TierDecision>,
}

impl GenRequest {
    /// A request with the given RNG seed, no source text, the server-wide
    /// sampler config, no deadline, and [`Priority::Normal`].
    pub fn new(seed: u64) -> GenRequest {
        GenRequest {
            src: None,
            seed,
            cfg: None,
            deadline: None,
            priority: Priority::Normal,
            stream: false,
            tenant: None,
            tier: Tier::Quality,
            decision: None,
        }
    }

    /// Source text (required by conditional models).
    pub fn src(mut self, src: impl Into<String>) -> Self {
        self.src = Some(src.into());
        self
    }

    /// Per-request sampler override. Requests whose spec differs from the
    /// in-flight batch are served in separate batches (continuous mode);
    /// the fixed policy rejects overrides.
    pub fn config(mut self, cfg: SamplerConfig) -> Self {
        self.cfg = Some(cfg);
        self
    }

    /// Relative deadline, measured from submission. A queued request past
    /// its deadline is never admitted; an in-flight one is dropped at the
    /// next transition-time boundary. Either way the ticket receives
    /// [`Event::DeadlineExceeded`].
    pub fn deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    pub fn priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }

    /// Attribute this request to a tenant. Per-tenant submit counts show
    /// up in [`ServerStats::tenant_requests`](super::server::ServerStats)
    /// (counted once, by the submit shard — stolen/donated requests are
    /// not re-counted), and the network front door keys its token-bucket
    /// rate limits on the same identifier. `None` (the default) leaves
    /// every existing call site and byte-parity pin untouched.
    pub fn tenant(mut self, tenant: impl Into<String>) -> Self {
        self.tenant = Some(tenant.into());
        self
    }

    /// Serving tier ([`Tier`], `docs/tiers.md`). [`Tier::Quality`] — the
    /// default — leaves the requested spec untouched, so every pre-tier
    /// call site keeps its exact behavior.
    pub fn tier(mut self, tier: Tier) -> Self {
        self.tier = tier;
        self
    }

    /// Shorthand for `.tier(Tier::Balanced { slo_ms })`: let admission
    /// pick the cheapest spec meeting this latency SLO.
    pub fn latency_slo_ms(mut self, slo_ms: u64) -> Self {
        self.tier = Tier::Balanced { slo_ms };
        self
    }

    /// Subscribe to partial tokens: every [`Event::Progress`] carries the
    /// request's current `x_t`. Off by default — unsubscribed progress
    /// events still report `nfe_done`/`nfe_total` but skip the token copy.
    pub fn stream_partials(mut self) -> Self {
        self.stream = true;
        self
    }
}

/// One lifecycle event observed through a [`Ticket`].
#[derive(Debug, Clone)]
pub enum Event {
    /// The request joined an in-flight batch at a transition-time boundary.
    /// `decision` carries what admission resolved for a tiered request
    /// (`None` on every untiered path).
    Admitted { decision: Option<TierDecision> },
    /// A boundary the request participated in has completed. `partial_tokens`
    /// is the request's current `x_t` when the client subscribed via
    /// [`GenRequest::stream_partials`] (empty otherwise). Progress coalesces:
    /// a slow reader may skip intermediate boundaries, but the final
    /// `Progress` (where `nfe_done == nfe_total`) is always observable and
    /// its tokens equal the [`Event::Done`] output exactly.
    Progress { nfe_done: usize, nfe_total: usize, partial_tokens: Vec<u32> },
    /// Terminal: generation finished.
    Done(GenOutput),
    /// Terminal: the request was cancelled (queue-side before admission, or
    /// at the next boundary while in flight).
    Cancelled,
    /// Terminal: the deadline passed before the request finished.
    DeadlineExceeded,
    /// Terminal: the engine or sampler spec failed.
    Failed(String),
}

enum Terminal {
    Done(GenOutput),
    Cancelled,
    DeadlineExceeded,
    Failed(String),
}

impl Terminal {
    fn to_event(&self) -> Event {
        match self {
            Terminal::Done(out) => Event::Done(out.clone()),
            Terminal::Cancelled => Event::Cancelled,
            Terminal::DeadlineExceeded => Event::DeadlineExceeded,
            Terminal::Failed(msg) => Event::Failed(msg.clone()),
        }
    }
}

/// The coalescing snapshot shared by ticket and sink.
struct SinkState {
    admitted: bool,
    /// tier decision to echo with [`Event::Admitted`]
    decision: Option<TierDecision>,
    nfe_done: usize,
    nfe_total: usize,
    /// reused partial-token scratch — overwritten, never reallocated after
    /// the first boundary
    partial: Vec<u32>,
    terminal: Option<Terminal>,
    /// Router shard load, decremented exactly once at the terminal event.
    /// Lives behind the state mutex (not on `Shared`) so cross-shard work
    /// stealing can re-point it at the thief shard's gauge atomically with
    /// respect to the terminal transition.
    load: Option<Arc<AtomicUsize>>,
}

struct Shared {
    cancelled: AtomicBool,
    /// client subscribed to partial tokens
    stream: bool,
    state: Mutex<SinkState>,
    cv: Condvar,
}

fn lock(shared: &Shared) -> MutexGuard<'_, SinkState> {
    shared.state.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Build a connected ticket/sink pair.
pub(crate) fn lifecycle(
    stream: bool,
    load: Option<Arc<AtomicUsize>>,
    decision: Option<TierDecision>,
) -> (Ticket, TicketSink) {
    let shared = Arc::new(Shared {
        cancelled: AtomicBool::new(false),
        stream,
        state: Mutex::new(SinkState {
            admitted: false,
            decision,
            nfe_done: 0,
            nfe_total: 0,
            partial: Vec::new(),
            terminal: None,
            load,
        }),
        cv: Condvar::new(),
    });
    (
        Ticket { shared: shared.clone(), seen_admitted: false, seen_nfe: 0, seen_terminal: false },
        TicketSink { shared },
    )
}

/// Client handle to one submitted request: an event stream plus
/// boundary-cancellation.
pub struct Ticket {
    shared: Arc<Shared>,
    seen_admitted: bool,
    seen_nfe: usize,
    seen_terminal: bool,
}

impl Ticket {
    /// A ticket/sink pair not attached to any server — for embedding the
    /// [`Scheduler`](super::scheduler::Scheduler) directly (hand-ticked
    /// tests, custom serving loops): put the sink in
    /// [`Pending::ctl`](super::scheduler::Pending) and drive `tick()`.
    pub fn detached(stream: bool) -> (Ticket, TicketSink) {
        lifecycle(stream, None, None)
    }

    /// Request cancellation. Queue-side the request is dropped before
    /// admission (the idle server polls its queue, so this resolves within
    /// tens of milliseconds even under a long grouping window); in flight,
    /// its lane slot is freed at the next transition-time boundary. The
    /// ticket then receives [`Event::Cancelled`] (unless the request
    /// already finished — a terminal event is never overwritten).
    ///
    /// To cancel while another thread is blocked in [`Self::next_event`] /
    /// [`Self::wait`], detach a [`CancelHandle`] first.
    pub fn cancel(&self) {
        self.shared.cancelled.store(true, Ordering::Relaxed);
    }

    /// A cheap cloneable handle that can cancel this request from another
    /// thread — e.g. while this ticket is consumed by a blocking
    /// [`Self::wait`] / [`Self::next_event`] loop.
    pub fn cancel_handle(&self) -> CancelHandle {
        CancelHandle { shared: self.shared.clone() }
    }

    /// `true` once this ticket has delivered its terminal event.
    pub fn finished(&self) -> bool {
        self.seen_terminal
    }

    /// Blocking: the next lifecycle event, or `None` after the terminal
    /// event has been delivered.
    pub fn next_event(&mut self) -> Option<Event> {
        if self.seen_terminal {
            return None;
        }
        // local Arc so the guard's borrow is independent of `self`
        let shared = self.shared.clone();
        let mut st = lock(&shared);
        loop {
            if let Some(ev) = self.diff(&st) {
                return Some(ev);
            }
            st = shared.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Non-blocking variant of [`Self::next_event`]: `None` when no new
    /// event is observable right now (check [`Self::finished`] to
    /// distinguish "stream ended" from "nothing yet").
    pub fn try_next_event(&mut self) -> Option<Event> {
        if self.seen_terminal {
            return None;
        }
        let shared = self.shared.clone();
        let st = lock(&shared);
        self.diff(&st)
    }

    /// Drive the stream to its terminal event and return the output (the
    /// blocking-submit convenience).
    pub fn wait(mut self) -> Result<GenOutput> {
        loop {
            match self.next_event() {
                Some(Event::Done(out)) => return Ok(out),
                Some(Event::Cancelled) => return Err(anyhow!("request cancelled")),
                Some(Event::DeadlineExceeded) => return Err(anyhow!("request deadline exceeded")),
                Some(Event::Failed(msg)) => return Err(anyhow!("{msg}")),
                Some(_) => {}
                None => return Err(anyhow!("event stream ended without a result")),
            }
        }
    }

    /// The oldest not-yet-delivered change in the snapshot, if any.
    fn diff(&mut self, st: &SinkState) -> Option<Event> {
        if st.admitted && !self.seen_admitted {
            self.seen_admitted = true;
            return Some(Event::Admitted { decision: st.decision.clone() });
        }
        if st.nfe_done > self.seen_nfe {
            self.seen_nfe = st.nfe_done;
            return Some(Event::Progress {
                nfe_done: st.nfe_done,
                nfe_total: st.nfe_total,
                partial_tokens: st.partial.clone(),
            });
        }
        if let Some(t) = &st.terminal {
            self.seen_terminal = true;
            return Some(t.to_event());
        }
        None
    }
}

/// Detached cancellation handle (see [`Ticket::cancel_handle`]): `Clone`
/// and `Send`, so a supervisor thread can abort a request whose ticket is
/// tied up in a blocking event loop elsewhere.
#[derive(Clone)]
pub struct CancelHandle {
    shared: Arc<Shared>,
}

impl CancelHandle {
    /// Same semantics as [`Ticket::cancel`].
    pub fn cancel(&self) {
        self.shared.cancelled.store(true, Ordering::Relaxed);
    }
}

/// Serving-side half of a ticket. The scheduler (or the fixed-batch loop)
/// writes lifecycle transitions into it; dropping a sink whose request
/// never reached a terminal state fails the ticket with
/// [`Event::Failed`] — a request can never be silently lost.
pub struct TicketSink {
    shared: Arc<Shared>,
}

impl TicketSink {
    pub(crate) fn is_cancelled(&self) -> bool {
        self.shared.cancelled.load(Ordering::Relaxed)
    }

    /// Did the client subscribe to partial tokens?
    pub(crate) fn wants_partials(&self) -> bool {
        self.shared.stream
    }

    pub(crate) fn set_admitted(&self) {
        let mut st = lock(&self.shared);
        st.admitted = true;
        drop(st);
        self.shared.cv.notify_all();
    }

    /// Overwrite the progress snapshot. `tokens: None` skips the copy
    /// (unsubscribed clients). Allocation-free after the first boundary:
    /// the partial buffer is reused and the lock/notify pair never touch
    /// the heap.
    pub(crate) fn progress(&self, nfe_done: usize, nfe_total: usize, tokens: Option<&[u32]>) {
        let mut st = lock(&self.shared);
        if st.terminal.is_some() {
            return;
        }
        st.nfe_done = nfe_done;
        st.nfe_total = nfe_total;
        if let Some(t) = tokens {
            st.partial.clear();
            st.partial.extend_from_slice(t);
        }
        drop(st);
        self.shared.cv.notify_all();
    }

    pub(crate) fn finish_done(&self, out: GenOutput) {
        self.finish(Terminal::Done(out));
    }

    pub(crate) fn finish_cancelled(&self) {
        self.finish(Terminal::Cancelled);
    }

    pub(crate) fn finish_deadline(&self) {
        self.finish(Terminal::DeadlineExceeded);
    }

    pub(crate) fn finish_failed(&self, msg: &str) {
        self.finish(Terminal::Failed(msg.to_string()));
    }

    /// Re-point the load gauge at another shard's counter — called once
    /// per moved request by both rebalancing paths: queued-request
    /// stealing and in-flight lane donation (every member sink of a
    /// [`DonatedLane`](super::scheduler::DonatedLane) is retargeted as
    /// the lane is packed). The donor's gauge drops, the thief's rises,
    /// and the exactly-once terminal decrement now targets the thief. A
    /// no-op after the terminal event (the old gauge was already
    /// decremented).
    pub(crate) fn retarget_load(&self, new: Arc<AtomicUsize>) {
        let mut st = lock(&self.shared);
        if st.terminal.is_some() {
            return;
        }
        if let Some(old) = st.load.take() {
            old.fetch_sub(1, Ordering::Relaxed);
        }
        new.fetch_add(1, Ordering::Relaxed);
        st.load = Some(new);
    }

    /// First terminal wins; later ones (including the drop guard) no-op.
    fn finish(&self, terminal: Terminal) {
        let mut st = lock(&self.shared);
        if st.terminal.is_none() {
            st.terminal = Some(terminal);
            if let Some(load) = st.load.take() {
                load.fetch_sub(1, Ordering::Relaxed);
            }
        }
        drop(st);
        self.shared.cv.notify_all();
    }
}

impl Drop for TicketSink {
    fn drop(&mut self) {
        // fail-safe: a sink dropped without a terminal (server thread gone,
        // queue discarded) must not leave the client blocked forever
        self.finish(Terminal::Failed("request dropped by the server".into()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn builder_defaults_and_setters() {
        let req = GenRequest::new(3);
        assert!(req.src.is_none() && req.cfg.is_none() && req.deadline.is_none());
        assert_eq!(req.priority, Priority::Normal);
        assert!(!req.stream);
        assert!(req.tenant.is_none());
        assert_eq!(req.tier, Tier::Quality);
        assert!(req.decision.is_none());
        let req = req
            .src("hello")
            .deadline(Duration::from_millis(5))
            .priority(Priority::High)
            .tenant("acme")
            .latency_slo_ms(250)
            .stream_partials();
        assert_eq!(req.src.as_deref(), Some("hello"));
        assert_eq!(req.priority, Priority::High);
        assert!(req.stream && req.deadline.is_some());
        assert_eq!(req.tenant.as_deref(), Some("acme"));
        assert_eq!(req.tier, Tier::Balanced { slo_ms: 250 });
        assert_eq!(req.tier(Tier::Turbo { max_nfe: 4 }).tier, Tier::Turbo { max_nfe: 4 });
    }

    #[test]
    fn priority_orders_low_normal_high() {
        assert!(Priority::Low < Priority::Normal);
        assert!(Priority::Normal < Priority::High);
    }

    #[test]
    fn progress_coalesces_and_terminal_ends_stream() {
        let (mut t, sink) = Ticket::detached(true);
        sink.set_admitted();
        sink.progress(1, 4, Some(&[5, 5]));
        sink.progress(2, 4, Some(&[5, 6]));
        assert!(matches!(t.try_next_event(), Some(Event::Admitted { .. })));
        // the two progress writes coalesced into the latest snapshot
        match t.try_next_event() {
            Some(Event::Progress { nfe_done, nfe_total, partial_tokens }) => {
                assert_eq!((nfe_done, nfe_total), (2, 4));
                assert_eq!(partial_tokens, vec![5, 6]);
            }
            other => panic!("expected progress, got {other:?}"),
        }
        assert!(t.try_next_event().is_none(), "nothing new yet");
        assert!(!t.finished());
        sink.finish_done(GenOutput {
            text: "x".into(),
            tokens: vec![5, 6],
            nfe: 2,
            elapsed: Duration::ZERO,
        });
        assert!(matches!(t.try_next_event(), Some(Event::Done(_))));
        assert!(t.finished());
        assert!(t.try_next_event().is_none());
        assert!(t.next_event().is_none(), "terminal delivered exactly once");
    }

    #[test]
    fn first_terminal_wins() {
        let (t, sink) = Ticket::detached(false);
        sink.finish_cancelled();
        sink.finish_failed("too late");
        drop(sink);
        assert!(t.wait().unwrap_err().to_string().contains("cancelled"));
    }

    #[test]
    fn dropped_sink_fails_the_ticket() {
        let (t, sink) = Ticket::detached(false);
        drop(sink);
        let err = t.wait().unwrap_err().to_string();
        assert!(err.contains("dropped"), "{err}");
    }

    #[test]
    fn cancel_flag_is_visible_to_the_sink() {
        let (t, sink) = Ticket::detached(false);
        assert!(!sink.is_cancelled());
        t.cancel();
        assert!(sink.is_cancelled());
    }

    #[test]
    fn detached_cancel_handle_cancels_while_the_ticket_blocks() {
        let (mut t, sink) = Ticket::detached(false);
        let handle = t.cancel_handle();
        let canceller = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            handle.cancel();
            // the serving side observes the flag at a boundary and
            // resolves the request
            assert!(sink.is_cancelled());
            sink.finish_cancelled();
        });
        // the sole ticket is tied up blocking — only the handle can cancel
        assert!(matches!(t.next_event(), Some(Event::Cancelled)));
        canceller.join().unwrap();
    }

    #[test]
    fn load_decrements_exactly_once_at_terminal() {
        let load = Arc::new(AtomicUsize::new(1));
        let (_t, sink) = lifecycle(false, Some(load.clone()), None);
        sink.finish_cancelled();
        assert_eq!(load.load(Ordering::Relaxed), 0);
        drop(sink); // drop guard must not decrement again
        assert_eq!(load.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn retarget_load_moves_the_gauge_and_the_terminal_decrement() {
        let donor = Arc::new(AtomicUsize::new(1));
        let thief = Arc::new(AtomicUsize::new(0));
        let (_t, sink) = lifecycle(false, Some(donor.clone()), None);
        sink.retarget_load(thief.clone());
        assert_eq!(donor.load(Ordering::Relaxed), 0, "donor released on steal");
        assert_eq!(thief.load(Ordering::Relaxed), 1, "thief acquired on steal");
        sink.finish_cancelled();
        assert_eq!(thief.load(Ordering::Relaxed), 0, "terminal decrements the thief");
        assert_eq!(donor.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn retarget_load_after_terminal_is_a_no_op() {
        let donor = Arc::new(AtomicUsize::new(1));
        let thief = Arc::new(AtomicUsize::new(0));
        let (_t, sink) = lifecycle(false, Some(donor.clone()), None);
        sink.finish_cancelled();
        assert_eq!(donor.load(Ordering::Relaxed), 0);
        sink.retarget_load(thief.clone());
        assert_eq!(thief.load(Ordering::Relaxed), 0, "finished request acquires nothing");
    }

    #[test]
    fn blocking_next_event_wakes_on_progress() {
        let (mut t, sink) = Ticket::detached(false);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            sink.set_admitted();
            sink.progress(1, 2, None);
            sink.finish_cancelled();
        });
        assert!(matches!(t.next_event(), Some(Event::Admitted { .. })));
        assert!(matches!(t.next_event(), Some(Event::Progress { nfe_done: 1, .. })));
        assert!(matches!(t.next_event(), Some(Event::Cancelled)));
        assert!(t.next_event().is_none());
        h.join().unwrap();
    }
}
