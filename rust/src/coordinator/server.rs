//! The request loop: queue → scheduler → engine → responses.
//!
//! PJRT handles are not `Send`, so the engine is built *inside* the server
//! thread from a factory closure; clients hold a cheap cloneable handle
//! and block on a per-request response channel (or use `submit_async` and
//! collect later). Shutdown is explicit or on handle drop.
//!
//! Two scheduling modes share the same client handle:
//!
//! * **Fixed** ([`Server::start`]) — the legacy policy: FIFO batches are
//!   frozen by the [`Batcher`] and run to completion. Kept as the ablation
//!   baseline for the serving bench.
//! * **Continuous** ([`Server::start_continuous`]) — the NFE-aligned
//!   [`Scheduler`]: requests join the in-flight batch at transition-time
//!   boundaries, sequences retire individually, freed slots refill.
//!
//! [`Batcher`]: super::batcher::Batcher
//! [`Scheduler`]: super::scheduler::Scheduler

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::metrics::LatencyStats;
use crate::sampler::SamplerConfig;

use super::batcher::{BatchPolicy, Batcher};
use super::engine::{Engine, GenOutput};
use super::scheduler::{Pending, SchedPolicy, Scheduler};

/// One queued request.
struct Request {
    src: Option<String>,
    seed: u64,
    /// per-request sampler override (continuous mode only; the fixed path
    /// ignores it and uses the server-wide config)
    cfg: Option<SamplerConfig>,
    enqueued: Instant,
    respond: Sender<Result<GenOutput>>,
}

enum Msg {
    Req(Request),
    Stats(Sender<ServerStats>),
    Shutdown,
}

/// Aggregate serving statistics.
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub requests: u64,
    pub batches: u64,
    pub nn_calls: u64,
    pub mean_batch: f64,
    pub queue_p95: Duration,
    pub e2e_p95: Duration,
    pub e2e_p50: Duration,
    /// mean per-request NFE over retired requests (continuous mode;
    /// 0 under the fixed policy, which accounts per batch instead)
    pub avg_request_nfe: f64,
    /// mean in-flight width per denoiser call / slot capacity, in [0, 1]
    pub occupancy: f64,
}

/// Cloneable client handle to a running server.
#[derive(Clone)]
pub struct Server {
    tx: Sender<Msg>,
}

impl Server {
    /// Start a server with the legacy fixed-batch policy. `factory` builds
    /// the engine on the server thread (PJRT is thread-bound); `cfg` is the
    /// sampler every request uses.
    pub fn start<F>(factory: F, cfg: SamplerConfig, policy: BatchPolicy) -> (Server, ServerJoin)
    where
        F: FnOnce() -> Result<Engine> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let handle = std::thread::spawn(move || serve_loop(factory, cfg, policy, rx));
        (Server { tx }, ServerJoin { handle: Some(handle) })
    }

    /// Start a server with the continuous NFE-aligned scheduler: requests
    /// are admitted into the in-flight batch at transition-time boundaries
    /// and retire individually.
    pub fn start_continuous<F>(
        factory: F,
        cfg: SamplerConfig,
        policy: SchedPolicy,
    ) -> (Server, ServerJoin)
    where
        F: FnOnce() -> Result<Engine> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let handle =
            std::thread::spawn(move || serve_continuous_loop(factory, cfg, policy, rx));
        (Server { tx }, ServerJoin { handle: Some(handle) })
    }

    /// Submit and wait for the result.
    pub fn submit(&self, src: Option<String>, seed: u64) -> Result<GenOutput> {
        self.submit_async(src, seed)?
            .recv()
            .map_err(|_| anyhow!("server dropped response"))?
    }

    /// Submit without blocking; returns the response receiver.
    pub fn submit_async(
        &self,
        src: Option<String>,
        seed: u64,
    ) -> Result<Receiver<Result<GenOutput>>> {
        self.submit_with(src, seed, None)
    }

    /// Submit with a per-request sampler override (continuous mode;
    /// requests with different specs are served in separate batches).
    pub fn submit_with(
        &self,
        src: Option<String>,
        seed: u64,
        cfg: Option<SamplerConfig>,
    ) -> Result<Receiver<Result<GenOutput>>> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Msg::Req(Request {
                src,
                seed,
                cfg,
                enqueued: Instant::now(),
                respond: rtx,
            }))
            .map_err(|_| anyhow!("server is down"))?;
        Ok(rrx)
    }

    pub fn stats(&self) -> Result<ServerStats> {
        let (stx, srx) = channel();
        self.tx.send(Msg::Stats(stx)).map_err(|_| anyhow!("server is down"))?;
        srx.recv().map_err(|_| anyhow!("server dropped stats"))
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

/// Joins the server thread on drop.
pub struct ServerJoin {
    handle: Option<std::thread::JoinHandle<()>>,
}

impl ServerJoin {
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerJoin {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct LoopState {
    requests: u64,
    batches: u64,
    batch_sizes: u64,
    queue_lat: LatencyStats,
    e2e_lat: LatencyStats,
    /// slot capacity, for the occupancy statistic
    capacity: usize,
}

impl LoopState {
    fn new(capacity: usize) -> LoopState {
        LoopState {
            requests: 0,
            batches: 0,
            batch_sizes: 0,
            queue_lat: LatencyStats::new(),
            e2e_lat: LatencyStats::new(),
            capacity,
        }
    }
}

/// Drain-and-fail loop for a factory that could not build an engine.
fn fail_engine_loop(rx: Receiver<Msg>, err: anyhow::Error) {
    eprintln!("[server] engine init failed: {err:#}");
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Req(r) => {
                let _ = r.respond.send(Err(anyhow!("engine init failed")));
            }
            Msg::Shutdown => break,
            Msg::Stats(s) => {
                let _ = s.send(empty_stats());
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Fixed-batch mode (legacy policy; the bench's ablation baseline)
// ---------------------------------------------------------------------------

fn serve_loop<F>(factory: F, cfg: SamplerConfig, policy: BatchPolicy, rx: Receiver<Msg>)
where
    F: FnOnce() -> Result<Engine>,
{
    let engine = match factory() {
        Ok(e) => e,
        Err(err) => {
            fail_engine_loop(rx, err);
            return;
        }
    };

    let mut batcher: Batcher<Request> = Batcher::new(policy);
    let mut st = LoopState::new(policy.max_batch);

    loop {
        // wait: bounded by the batch window if one is open
        let msg = match batcher.time_left() {
            Some(left) if !batcher.is_empty() => match rx.recv_timeout(left) {
                Ok(m) => Some(m),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                Err(_) => break,
            },
            _ => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break,
            },
        };

        match msg {
            Some(Msg::Req(r)) => {
                if r.cfg.is_some() {
                    // the fixed path serves one server-wide config; silently
                    // substituting it for the requested one would be wrong
                    let _ = r.respond.send(Err(anyhow!(
                        "per-request sampler config requires a continuous-mode \
                         server (Server::start_continuous)"
                    )));
                    continue;
                }
                st.requests += 1;
                batcher.push(r);
            }
            Some(Msg::Stats(s)) => {
                let _ = s.send(snapshot(&st, &engine));
                continue;
            }
            Some(Msg::Shutdown) => {
                // flush remaining requests before exiting
                while !batcher.is_empty() {
                    dispatch(&engine, &cfg, &mut batcher, &mut st);
                }
                break;
            }
            None => {} // window expired
        }

        while batcher.ready() {
            dispatch(&engine, &cfg, &mut batcher, &mut st);
        }
    }
}

fn dispatch(engine: &Engine, cfg: &SamplerConfig, batcher: &mut Batcher<Request>, st: &mut LoopState) {
    let reqs = batcher.take();
    if reqs.is_empty() {
        return;
    }
    st.batches += 1;
    st.batch_sizes += reqs.len() as u64;
    for r in &reqs {
        st.queue_lat.record(r.enqueued.elapsed());
    }

    let conditional = engine.conditional();
    let srcs: Option<Vec<String>> = if conditional {
        Some(reqs.iter().map(|r| r.src.clone().unwrap_or_default()).collect())
    } else {
        None
    };
    let seed = reqs.first().map(|r| r.seed).unwrap_or(0);

    match engine.generate_batch(srcs.as_deref(), reqs.len(), cfg, seed) {
        Ok((outs, _)) => {
            for (r, o) in reqs.into_iter().zip(outs) {
                st.e2e_lat.record(r.enqueued.elapsed());
                let _ = r.respond.send(Ok(o));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for r in reqs {
                let _ = r.respond.send(Err(anyhow!("{msg}")));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Continuous mode (NFE-aligned scheduler)
// ---------------------------------------------------------------------------

fn serve_continuous_loop<F>(
    factory: F,
    cfg: SamplerConfig,
    policy: SchedPolicy,
    rx: Receiver<Msg>,
) where
    F: FnOnce() -> Result<Engine>,
{
    let engine = match factory() {
        Ok(e) => e,
        Err(err) => {
            fail_engine_loop(rx, err);
            return;
        }
    };

    let mut sched: Scheduler<Sender<Result<GenOutput>>> = Scheduler::new(engine, cfg, policy);
    let mut st = LoopState::new(policy.max_batch);
    let mut draining = false;

    'outer: loop {
        // 1. ingest. While lanes are active, never block — drain whatever
        //    arrived and get back to stepping (admission happens at the
        //    boundary inside tick()). Otherwise block until the grouping
        //    window of the oldest pending request expires, or forever when
        //    fully idle.
        if sched.in_flight() > 0 {
            loop {
                match rx.try_recv() {
                    Ok(m) => {
                        if handle_msg(m, &mut sched, &mut st) {
                            draining = true;
                        }
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        draining = true;
                        sched.flush();
                        break;
                    }
                }
            }
        } else if sched.pending_len() > 0 && !draining {
            let deadline = sched.next_deadline().expect("pending implies a deadline");
            let timeout = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(timeout) {
                Ok(m) => {
                    if handle_msg(m, &mut sched, &mut st) {
                        draining = true;
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    draining = true;
                    sched.flush();
                }
            }
        } else if !sched.has_work() {
            if draining {
                break;
            }
            match rx.recv() {
                Ok(m) => {
                    if handle_msg(m, &mut sched, &mut st) {
                        draining = true;
                        if !sched.has_work() {
                            break;
                        }
                    }
                }
                Err(_) => break,
            }
        }

        // 2. one boundary: admit + one denoiser call; deliver retirements.
        for f in sched.tick() {
            st.queue_lat.record(f.wait);
            if let Ok(out) = &f.result {
                // e2e = queue wait + in-flight generation time
                st.e2e_lat.record(f.wait + out.elapsed);
            }
            let _ = f.payload.send(f.result);
        }
        if draining && !sched.has_work() {
            break 'outer;
        }
    }
}

/// Returns true when the message requests shutdown.
fn handle_msg(
    msg: Msg,
    sched: &mut Scheduler<Sender<Result<GenOutput>>>,
    st: &mut LoopState,
) -> bool {
    match msg {
        Msg::Req(r) => {
            st.requests += 1;
            sched.enqueue(Pending {
                src: r.src,
                seed: r.seed,
                cfg: r.cfg,
                enqueued: r.enqueued,
                payload: r.respond,
            });
            false
        }
        Msg::Stats(s) => {
            // lanes retired so far are the "batches" of continuous mode
            st.batches = sched.engine().nfe.batches();
            st.batch_sizes = sched.engine().nfe.requests();
            let _ = s.send(snapshot(st, sched.engine()));
            false
        }
        Msg::Shutdown => {
            sched.flush();
            true
        }
    }
}

fn snapshot(st: &LoopState, engine: &Engine) -> ServerStats {
    ServerStats {
        requests: st.requests,
        batches: st.batches,
        nn_calls: engine.nfe.calls(),
        mean_batch: if st.batches == 0 {
            0.0
        } else {
            st.batch_sizes as f64 / st.batches as f64
        },
        queue_p95: st.queue_lat.p95(),
        e2e_p95: st.e2e_lat.p95(),
        e2e_p50: st.e2e_lat.p50(),
        avg_request_nfe: engine.nfe.avg_request_nfe(),
        occupancy: engine.nfe.occupancy(st.capacity),
    }
}

fn empty_stats() -> ServerStats {
    ServerStats {
        requests: 0,
        batches: 0,
        nn_calls: 0,
        mean_batch: 0.0,
        queue_p95: Duration::ZERO,
        e2e_p95: Duration::ZERO,
        e2e_p50: Duration::ZERO,
        avg_request_nfe: 0.0,
        occupancy: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::Engine;
    use crate::sampler::{SamplerConfig, SamplerKind};

    fn mock_factory() -> Result<Engine> {
        Ok(crate::coordinator::engine::cipher_mock_engine(8))
    }

    #[test]
    fn serves_concurrent_requests_batched() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50);
        let policy = BatchPolicy { max_batch: 4, window: Duration::from_millis(30) };
        let (srv, join) = Server::start(mock_factory, cfg, policy);

        let mut rxs = Vec::new();
        for i in 0..8 {
            rxs.push(srv.submit_async(Some("the quick fox crosses a river".into()), i).unwrap());
        }
        for rx in rxs {
            let out = rx.recv().unwrap().unwrap();
            assert!(out.nfe >= 1);
        }
        let stats = srv.stats().unwrap();
        assert_eq!(stats.requests, 8);
        assert!(stats.batches <= 4, "8 reqs with max_batch 4 → ≤4 batches, got {}", stats.batches);
        assert!(stats.mean_batch >= 2.0, "batching should coalesce: {}", stats.mean_batch);
        srv.shutdown();
        join.join();
    }

    #[test]
    fn blocking_submit_roundtrip() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 25);
        let (srv, join) =
            Server::start(mock_factory, cfg, BatchPolicy { max_batch: 1, window: Duration::ZERO });
        let out = srv.submit(Some("a small garden".into()), 1).unwrap();
        assert!(!out.text.is_empty());
        srv.shutdown();
        join.join();
    }

    #[test]
    fn shutdown_flushes_pending() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 25);
        let policy = BatchPolicy { max_batch: 64, window: Duration::from_secs(60) };
        let (srv, join) = Server::start(mock_factory, cfg, policy);
        let rx = srv.submit_async(Some("this old road".into()), 2).unwrap();
        srv.shutdown();
        // pending request must still be answered (flush-on-shutdown)
        let out = rx.recv().unwrap().unwrap();
        assert!(!out.tokens.is_empty());
        join.join();
    }

    #[test]
    fn engine_failure_fails_requests_cleanly() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 25);
        let (srv, join) = Server::start(
            || Err(anyhow!("boom")),
            cfg,
            BatchPolicy::default(),
        );
        let r = srv.submit(Some("x".into()), 0);
        assert!(r.is_err());
        srv.shutdown();
        join.join();
    }

    // -- continuous mode --

    #[test]
    fn continuous_serves_and_reports_per_request_nfe() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50);
        let policy = SchedPolicy {
            max_batch: 4,
            window: Duration::from_millis(10),
            shared_tau_groups: true,
        };
        let (srv, join) = Server::start_continuous(mock_factory, cfg, policy);
        let mut rxs = Vec::new();
        for i in 0..8 {
            rxs.push(srv.submit_async(Some("the quick fox crosses a river".into()), i).unwrap());
        }
        for rx in rxs {
            let out = rx.recv().unwrap().unwrap();
            assert!(out.nfe >= 1 && out.nfe <= 8, "per-request NFE = |𝒯| ≤ N");
            assert!(!out.text.is_empty());
        }
        let stats = srv.stats().unwrap();
        assert_eq!(stats.requests, 8);
        assert!(stats.avg_request_nfe >= 1.0 && stats.avg_request_nfe <= 8.0);
        assert!(stats.occupancy > 0.0 && stats.occupancy <= 1.0);
        srv.shutdown();
        join.join();
    }

    #[test]
    fn continuous_shutdown_flushes_pending() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 25);
        let policy = SchedPolicy {
            max_batch: 8,
            window: Duration::from_secs(60), // window must not delay the drain
            shared_tau_groups: true,
        };
        let (srv, join) = Server::start_continuous(mock_factory, cfg, policy);
        let rx = srv.submit_async(Some("this old road".into()), 2).unwrap();
        srv.shutdown();
        let out = rx.recv().unwrap().unwrap();
        assert!(!out.tokens.is_empty());
        join.join();
    }

    #[test]
    fn continuous_engine_failure_fails_requests_cleanly() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 25);
        let (srv, join) = Server::start_continuous(
            || Err(anyhow!("boom")),
            cfg,
            SchedPolicy::default(),
        );
        let r = srv.submit(Some("x".into()), 0);
        assert!(r.is_err());
        srv.shutdown();
        join.join();
    }
}
