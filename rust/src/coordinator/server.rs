//! The request loop: queue → scheduler → engine → responses.
//!
//! PJRT handles are not `Send`, so the engine is built *inside* the server
//! thread from a factory closure; clients hold a cheap cloneable handle.
//! The primary entry point is [`Server::submit_request`]: a typed
//! [`GenRequest`] in, a streaming [`Ticket`] out (per-NFE progress events,
//! boundary cancellation, deadlines). The legacy `submit*` channel
//! wrappers remain as thin deprecated shims over the same path. Shutdown
//! is explicit or on handle drop.
//!
//! Two scheduling modes share the same client handle (unified behind
//! [`ServeBuilder`](super::router::ServeBuilder), which also shards across
//! engines via [`Router`](super::router::Router)):
//!
//! * **Fixed** ([`Server::start`]) — the legacy policy: FIFO batches are
//!   frozen by the [`Batcher`] and run to completion. Kept as the ablation
//!   baseline for the serving bench. Lifecycle support is queue-side only
//!   (no mid-generation boundaries exist): cancellation and deadlines are
//!   enforced at dispatch, and tickets see `Admitted` → `Done` with no
//!   `Progress` events.
//! * **Continuous** ([`Server::start_continuous`]) — the NFE-aligned
//!   [`Scheduler`]: requests join the in-flight batch at transition-time
//!   boundaries, sequences retire individually, freed slots refill, and
//!   every boundary emits progress into subscribed tickets.
//!
//! Continuous mode is fault-tolerant (`docs/robustness.md`): denoiser
//! calls retry transient faults per [`FaultPolicy`], repeated failures
//! trip a circuit breaker that parks the in-flight lanes at a boundary,
//! and a supervisor (the rebalancer's supervision pass) can then salvage
//! the parked work to a healthy shard ([`Msg::Evacuate`]) and rebuild
//! this shard's engine from the retained factory ([`Msg::Restart`]).
//!
//! [`Batcher`]: super::batcher::Batcher
//! [`Scheduler`]: super::scheduler::Scheduler

use std::collections::BTreeMap;
use std::sync::atomic::AtomicUsize;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::metrics::{LatencySnapshot, LatencyStats};
use crate::sampler::SamplerConfig;

use super::batcher::{BatchPolicy, Batcher};
use super::engine::{Engine, GenOutput};
use super::request::{self, GenRequest, Priority, Ticket, TicketSink, Tier};
use super::scheduler::{
    Delivery, DonatedLane, FaultPolicy, Finished, Outcome, Pending, SchedPolicy, Scheduler,
};
use super::telemetry::{StatsBoard, TickStats};

/// Upper bound on idle/parked sleeps in the continuous loop: cancellation
/// has no wake path of its own (the flag lives in the ticket), and the
/// circuit breaker's half-open probe needs the loop to come back to
/// `tick()` after the cooldown — both resolve within one poll interval.
const QUEUE_POLL: Duration = Duration::from_millis(20);

/// Where a finished request's result goes.
enum Reply {
    /// Legacy channel client (`submit*` wrappers).
    Channel(Sender<Result<GenOutput>>),
    /// Ticket client: terminal events travel through the [`TicketSink`],
    /// nothing to send here.
    Ticket,
}

/// One queued request.
struct Request {
    src: Option<String>,
    seed: u64,
    /// per-request sampler override (continuous mode only; the fixed path
    /// ignores it and uses the server-wide config)
    cfg: Option<SamplerConfig>,
    deadline: Option<Instant>,
    priority: Priority,
    ctl: Option<TicketSink>,
    tenant: Option<String>,
    enqueued: Instant,
    /// opt into confidence-based early retirement (Balanced/Turbo tiers;
    /// `docs/tiers.md`) — Quality requests must run their full ladder so
    /// they stay byte-identical to the untiered path
    early_retire: bool,
    reply: Reply,
}

impl Request {
    /// Resolve both delivery legs together — the invariant every exit
    /// path must uphold: the ticket sink (if any) gets the terminal event
    /// matching `outcome`, and the channel client (if any) gets `result`.
    /// Ticket-only requests **move** the output into the sink (no channel
    /// reply exists to want a copy — the retirement-path clone is gone).
    fn resolve(self, result: Result<GenOutput>, outcome: Outcome) {
        match self.reply {
            Reply::Channel(tx) => {
                if let Some(ctl) = &self.ctl {
                    match (&result, outcome) {
                        (Ok(out), _) => ctl.finish_done(out.clone()),
                        (Err(_), Outcome::Cancelled) => ctl.finish_cancelled(),
                        (Err(_), Outcome::DeadlineExceeded) => ctl.finish_deadline(),
                        (Err(e), _) => ctl.finish_failed(&format!("{e:#}")),
                    }
                }
                let _ = tx.send(result);
            }
            Reply::Ticket => {
                if let Some(ctl) = &self.ctl {
                    match (result, outcome) {
                        (Ok(out), _) => ctl.finish_done(out),
                        (Err(_), Outcome::Cancelled) => ctl.finish_cancelled(),
                        (Err(_), Outcome::DeadlineExceeded) => ctl.finish_deadline(),
                        (Err(e), _) => ctl.finish_failed(&format!("{e:#}")),
                    }
                }
            }
        }
    }
}

enum Msg {
    Req(Request),
    /// Donor side of cross-shard work stealing: pop up to `max` queued
    /// same-key requests and forward them to `to` (the thief's channel),
    /// re-pointing each sink's load gauge at `to_load` on the way.
    Steal { max: usize, to: Sender<Msg>, to_load: Arc<AtomicUsize> },
    /// A request donated by another shard — served normally, but not
    /// re-counted in `ServerStats::requests` (its submit shard counted it).
    Donated(Request),
    /// Donor side of in-flight lane donation: at the next boundary, pack
    /// one lane (chosen by the rebalancer's cost model, refusing lanes
    /// with fewer than `min_remaining` calls left) and ship it to `to`,
    /// re-pointing every member sink's load gauge at `to_load`.
    DonateLaneReq { to: Sender<Msg>, to_load: Arc<AtomicUsize>, min_remaining: usize },
    /// Donor side of lane **splitting**: at the next boundary, carve the
    /// back half of the widest splittable lane (width ≥ 2, at least
    /// `min_remaining` calls left) into a donated lane for `to`, keeping
    /// the front half serving here. Covers the case lane donation
    /// refuses: a single wide lane with an empty queue.
    SplitLaneReq { to: Sender<Msg>, to_load: Arc<AtomicUsize>, min_remaining: usize },
    /// Thief side: a live lane donated by another shard, resumed
    /// mid-schedule at its next predetermined event.
    AdoptLane(DonatedLane<Reply>),
    /// Supervisor side of shard failover, stage 1: with the circuit
    /// breaker open, ship this shard's queued requests (as `Donated`)
    /// and every parked in-flight lane (as `AdoptLane`) to `to`, a
    /// healthy shard, re-pointing load gauges at `to_load`. Parked lanes
    /// sit at a transition-time boundary, so the salvage is byte-exact
    /// for the same reason lane donation is. No-op while the breaker is
    /// closed (a stale supervision decision).
    Evacuate { to: Sender<Msg>, to_load: Arc<AtomicUsize> },
    /// Supervisor side of shard failover, stage 2: rebuild the engine
    /// from the retained factory and resume serving (the NFE counter
    /// carries over). No-op while the breaker is closed. If the rebuild
    /// itself fails, the shard fails whatever work it still holds and
    /// drops into the drain-and-fail loop with its real counters.
    Restart,
    Stats(Sender<ServerStats>),
    Shutdown,
}

/// Aggregate serving statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct ServerStats {
    pub requests: u64,
    pub batches: u64,
    pub nn_calls: u64,
    pub mean_batch: f64,
    pub queue_p95: Duration,
    pub e2e_p95: Duration,
    pub e2e_p50: Duration,
    pub e2e_p99: Duration,
    /// The full e2e latency digest (count, mean, p50/p95/p99/p999,
    /// min/max) the flat `e2e_*` fields above are drawn from. Kept as a
    /// snapshot so cross-shard merging can use the weighted-marker
    /// merge ([`LatencySnapshot::merged`]) instead of a per-field max,
    /// and so `/metrics` can expose p999 — the tail the scenario
    /// harness trajectories (`docs/scenarios.md`).
    pub e2e: LatencySnapshot,
    /// Mean per-request NFE over retired requests. This is the
    /// **continuous-only** accounting: each retired request records the
    /// denoiser calls its own session consumed (= |𝒯| for the DNDM
    /// family). Under the fixed policy it stays 0 — that path accounts per
    /// *batch* instead (`nn_calls` / `batches`, the Tables-7/8 statistic;
    /// see [`crate::metrics::NfeCounter::avg_nfe`]). `docs/serving.md`
    /// defers to this comment as the single description of the split.
    pub avg_request_nfe: f64,
    /// mean in-flight width per denoiser call / slot capacity, in [0, 1]
    pub occupancy: f64,
    /// requests dropped by [`Ticket::cancel`]
    pub cancelled: u64,
    /// requests dropped because their deadline passed
    pub deadline_exceeded: u64,
    /// queued low-priority requests at snapshot time (instantaneous
    /// depth; continuous mode only — the fixed policy ignores priority,
    /// so its whole batcher depth reports as `queued_normal`)
    pub queued_low: u64,
    /// queued normal-priority requests at snapshot time (fixed mode:
    /// every queued request, whatever its nominal priority)
    pub queued_normal: u64,
    /// queued high-priority requests at snapshot time (continuous only)
    pub queued_high: u64,
    /// requests this shard donated to other shards (work stealing,
    /// cumulative)
    pub stolen: u64,
    /// in-flight lanes (co-admitted groups) at snapshot time — what the
    /// rebalancer's donor filter reads (instantaneous; continuous only)
    pub lanes: u64,
    /// in-flight sequences (sum of lane widths) at snapshot time
    /// (instantaneous; continuous only)
    pub in_flight: u64,
    /// rebalance actions this shard executed as donor (queued-steal
    /// passes that moved ≥ 1 request + lane donations; cumulative)
    pub rebalances: u64,
    /// whole in-flight lanes this shard donated to other shards
    /// (cumulative; each also counts once in `rebalances`)
    pub lanes_donated: u64,
    /// in-flight lanes this shard **split** — back half of the rows
    /// donated, front half kept (cumulative; each also counts once in
    /// `rebalances`)
    pub lanes_split: u64,
    /// denoiser calls in which some lane advanced an event where **zero**
    /// of its rows moved. Per-row event ladders retire a departing row's
    /// unique events at eviction, so this must stay 0 — the serving bench
    /// gates on it (cumulative; continuous only)
    pub ghost_events_fired: u64,
    /// transient-fault retries the denoiser call sites performed
    /// (cumulative; continuous only — see [`FaultPolicy`])
    pub retries: u64,
    /// denoiser attempts that failed transiently, including
    /// slow-but-successful calls under `FaultPolicy::call_timeout`
    /// (cumulative; continuous only)
    pub faults_transient: u64,
    /// denoiser attempts that failed fatally (non-retryable; cumulative,
    /// continuous only)
    pub faults_fatal: u64,
    /// `true` while this shard's circuit breaker is open: the scheduler
    /// is parked at a boundary and the supervision pass should salvage
    /// its work ([`Server`] internal `Evacuate`/`Restart`). Merged stats
    /// OR this across shards. Instantaneous; continuous only.
    pub breaker_open: bool,
    /// in-flight lanes this shard evacuated to healthy shards during
    /// failover (cumulative; each arrived byte-exact at its next
    /// predetermined event)
    pub lanes_salvaged: u64,
    /// requests retired before their ladder ran dry because every
    /// remaining transition was provably a no-op (confidence-based
    /// early retirement — opt-in for Balanced/Turbo tier requests; an
    /// NFE *refund*, see `docs/tiers.md`; cumulative, continuous only)
    pub early_retired: u64,
    /// merged ladder events dropped by Turbo truncation across admitted
    /// sessions (cumulative; continuous only — `docs/tiers.md`)
    pub turbo_truncated_nfe: u64,
    /// `false` when this shard cannot serve: its engine factory failed at
    /// startup (or a failover restart failed), or its breaker is
    /// currently open. The rebalancer must treat such a shard as neither
    /// donor nor thief (its zeroed/frozen gauges would otherwise make it
    /// look like an ideal idle shard). Merged stats AND this across
    /// shards.
    pub healthy: bool,
    /// Per-tenant submit counts, sorted by tenant name (cumulative;
    /// requests with no [`GenRequest::tenant`] are not listed — subtract
    /// the listed sum from `requests` for the anonymous remainder). Each
    /// request is counted once, by its submit shard; stolen / donated /
    /// salvaged requests are not re-counted. This is what the network
    /// front door's per-tenant rate limiting and `/metrics` labels read.
    pub tenant_requests: Vec<(String, u64)>,
}

impl ServerStats {
    /// Merge per-shard stats into one router-level view. Counters add;
    /// ratios are weighted by their natural denominators. The e2e
    /// percentiles use the count-weighted marker merge
    /// ([`LatencySnapshot::merged`] — exact for one shard, bounded by
    /// one donor marker segment otherwise); `queue_p95` keeps the
    /// per-shard maximum (the queue digest isn't carried in full, and a
    /// conservative upper bound is the right reading for a load gauge).
    pub fn merged<I: IntoIterator<Item = ServerStats>>(stats: I) -> ServerStats {
        let mut out = empty_stats();
        let (mut batch_w, mut nfe_w, mut occ_w) = (0.0, 0.0, 0.0);
        let mut e2e_parts: Vec<LatencySnapshot> = Vec::new();
        // per-request NFE is recorded by the shard that *retires* a
        // request, which under lane donation / stealing is not always
        // the shard that counted it at submit — so the weight for
        // avg_request_nfe is each shard's retired-request count
        // (mean_batch × batches = the engine-side tally), not
        // `requests`
        let mut retired_w = 0.0;
        let mut tenants: BTreeMap<String, u64> = BTreeMap::new();
        for s in stats {
            out.requests += s.requests;
            out.batches += s.batches;
            out.nn_calls += s.nn_calls;
            out.cancelled += s.cancelled;
            out.deadline_exceeded += s.deadline_exceeded;
            out.queued_low += s.queued_low;
            out.queued_normal += s.queued_normal;
            out.queued_high += s.queued_high;
            out.stolen += s.stolen;
            out.lanes += s.lanes;
            out.in_flight += s.in_flight;
            out.rebalances += s.rebalances;
            out.lanes_donated += s.lanes_donated;
            out.lanes_split += s.lanes_split;
            out.ghost_events_fired += s.ghost_events_fired;
            out.retries += s.retries;
            out.faults_transient += s.faults_transient;
            out.faults_fatal += s.faults_fatal;
            out.breaker_open |= s.breaker_open;
            out.lanes_salvaged += s.lanes_salvaged;
            out.early_retired += s.early_retired;
            out.turbo_truncated_nfe += s.turbo_truncated_nfe;
            out.healthy &= s.healthy;
            for (tenant, n) in s.tenant_requests {
                *tenants.entry(tenant).or_insert(0) += n;
            }
            batch_w += s.mean_batch * s.batches as f64;
            let retired = s.mean_batch * s.batches as f64;
            nfe_w += s.avg_request_nfe * retired;
            retired_w += retired;
            occ_w += s.occupancy * s.nn_calls as f64;
            out.queue_p95 = out.queue_p95.max(s.queue_p95);
            e2e_parts.push(s.e2e);
        }
        out.e2e = LatencySnapshot::merged(&e2e_parts);
        out.e2e_p50 = out.e2e.p50;
        out.e2e_p95 = out.e2e.p95;
        out.e2e_p99 = out.e2e.p99;
        if out.batches > 0 {
            out.mean_batch = batch_w / out.batches as f64;
        }
        if retired_w > 0.0 {
            out.avg_request_nfe = nfe_w / retired_w;
        }
        if out.nn_calls > 0 {
            out.occupancy = occ_w / out.nn_calls as f64;
        }
        out.tenant_requests = tenants.into_iter().collect();
        out
    }
}

/// Cloneable client handle to a running server.
#[derive(Clone)]
pub struct Server {
    tx: Sender<Msg>,
    /// The shard's lock-free telemetry board: the serve loop publishes,
    /// anyone holding the handle reads without a channel round-trip.
    board: Arc<StatsBoard>,
}

impl Server {
    /// Start a server with the legacy fixed-batch policy. `factory` builds
    /// the engine on the server thread (PJRT is thread-bound); `cfg` is the
    /// sampler every request uses.
    pub fn start<F>(factory: F, cfg: SamplerConfig, policy: BatchPolicy) -> (Server, ServerJoin)
    where
        F: FnOnce() -> Result<Engine> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let board = Arc::new(StatsBoard::new());
        let b = board.clone();
        let handle = std::thread::spawn(move || serve_loop(factory, cfg, policy, rx, b));
        (Server { tx, board }, ServerJoin { handle: Some(handle) })
    }

    /// Start a server with the continuous NFE-aligned scheduler: requests
    /// are admitted into the in-flight batch at transition-time boundaries
    /// and retire individually. Uses the default [`FaultPolicy`]; the
    /// factory is `Fn` (not `FnOnce`) because the server thread retains
    /// it to rebuild the engine after a failover restart.
    pub fn start_continuous<F>(
        factory: F,
        cfg: SamplerConfig,
        policy: SchedPolicy,
    ) -> (Server, ServerJoin)
    where
        F: Fn() -> Result<Engine> + Send + 'static,
    {
        Server::start_continuous_with(factory, cfg, policy, FaultPolicy::default())
    }

    /// [`Self::start_continuous`] with an explicit retry/breaker
    /// [`FaultPolicy`] for the scheduler's denoiser call sites.
    pub fn start_continuous_with<F>(
        factory: F,
        cfg: SamplerConfig,
        policy: SchedPolicy,
        fault: FaultPolicy,
    ) -> (Server, ServerJoin)
    where
        F: Fn() -> Result<Engine> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let board = Arc::new(StatsBoard::new());
        let b = board.clone();
        let handle = std::thread::spawn(move || {
            serve_continuous_loop(factory, cfg, policy, fault, rx, b)
        });
        (Server { tx, board }, ServerJoin { handle: Some(handle) })
    }

    /// This shard's lock-free [`StatsBoard`]: counters/gauges/latency
    /// digests published by the serve loop on every tick and terminal.
    /// Reading it never blocks on the loop — the non-blocking
    /// alternative to [`Self::stats`] for observers that can tolerate
    /// one boundary of staleness.
    pub fn board(&self) -> &Arc<StatsBoard> {
        &self.board
    }

    /// Submit a typed request; returns the streaming [`Ticket`] (per-NFE
    /// [`Event`](super::request::Event)s, `cancel()`, `wait()`).
    pub fn submit_request(&self, req: GenRequest) -> Result<Ticket> {
        self.submit_ticketed(req, None)
    }

    /// Router entry point: like [`Self::submit_request`] but wires the
    /// shard's load counter into the ticket so it decrements exactly once
    /// at the terminal event.
    pub(crate) fn submit_ticketed(
        &self,
        req: GenRequest,
        load: Option<Arc<AtomicUsize>>,
    ) -> Result<Ticket> {
        let decision = req.decision.clone();
        let (ticket, sink) = request::lifecycle(req.stream, load, decision);
        self.send_req(req, Some(sink), Reply::Ticket)?;
        Ok(ticket)
    }

    /// Submit and wait for the result.
    #[deprecated(note = "build a GenRequest and use submit_request(..).wait() instead")]
    pub fn submit(&self, src: Option<String>, seed: u64) -> Result<GenOutput> {
        let mut req = GenRequest::new(seed);
        if let Some(s) = src {
            req = req.src(s);
        }
        self.submit_request(req)?.wait()
    }

    /// Submit without blocking; returns the response receiver.
    #[deprecated(note = "build a GenRequest and use submit_request for a streaming Ticket")]
    pub fn submit_async(
        &self,
        src: Option<String>,
        seed: u64,
    ) -> Result<Receiver<Result<GenOutput>>> {
        self.submit_channel(src, seed, None)
    }

    /// Submit with a per-request sampler override (continuous mode;
    /// requests with different specs are served in separate batches).
    #[deprecated(note = "build a GenRequest with .config(..) and use submit_request")]
    pub fn submit_with(
        &self,
        src: Option<String>,
        seed: u64,
        cfg: Option<SamplerConfig>,
    ) -> Result<Receiver<Result<GenOutput>>> {
        self.submit_channel(src, seed, cfg)
    }

    /// The shared body of the deprecated channel wrappers: a [`GenRequest`]
    /// with a channel reply instead of a ticket.
    fn submit_channel(
        &self,
        src: Option<String>,
        seed: u64,
        cfg: Option<SamplerConfig>,
    ) -> Result<Receiver<Result<GenOutput>>> {
        let mut req = GenRequest::new(seed);
        if let Some(s) = src {
            req = req.src(s);
        }
        if let Some(c) = cfg {
            req = req.config(c);
        }
        let (rtx, rrx) = channel();
        self.send_req(req, None, Reply::Channel(rtx))?;
        Ok(rrx)
    }

    fn send_req(&self, req: GenRequest, ctl: Option<TicketSink>, reply: Reply) -> Result<()> {
        let now = Instant::now();
        self.tx
            .send(Msg::Req(Request {
                src: req.src,
                seed: req.seed,
                cfg: req.cfg,
                deadline: req.deadline.map(|d| now + d),
                priority: req.priority,
                ctl,
                tenant: req.tenant,
                enqueued: now,
                early_retire: !matches!(req.tier, Tier::Quality),
                reply,
            }))
            .map_err(|_| anyhow!("server is down"))?;
        // after the send, not before: a failed send must not leave the
        // board's in-channel watermark permanently above the loop's
        // ingest count (readers would forever see "unseen submits")
        self.board.note_submitted();
        Ok(())
    }

    /// Ask this shard to donate up to `max` queued requests to `to`
    /// (cross-shard work stealing). Fire-and-forget: the donor pops the
    /// requests between two denoiser calls — boundary granularity — and
    /// forwards them with their sinks, deadlines, priorities, and enqueue
    /// times intact; each stolen sink's load gauge is re-pointed at
    /// `to_load`. No-op if nothing is queued (or the server is down).
    pub(crate) fn steal_into(&self, max: usize, to: &Server, to_load: Arc<AtomicUsize>) {
        let _ = self.tx.send(Msg::Steal { max, to: to.tx.clone(), to_load });
    }

    /// Ask this shard to donate one whole **in-flight** lane to `to` at
    /// its next transition-time boundary (in-flight lane donation — the
    /// rebalancer's stage 2). Fire-and-forget like [`Self::steal_into`]:
    /// the donor packs the lane between two denoiser calls, re-points the
    /// member sinks' load gauges at `to_load`, and the thief resumes the
    /// session mid-schedule. The donor refuses (no-op) when no lane has
    /// at least `min_remaining` calls left or the move would be zero-sum;
    /// see [`Scheduler::donate_lane`].
    pub(crate) fn donate_lane_into(
        &self,
        to: &Server,
        to_load: Arc<AtomicUsize>,
        min_remaining: usize,
    ) {
        let _ = self.tx.send(Msg::DonateLaneReq { to: to.tx.clone(), to_load, min_remaining });
    }

    /// Ask this shard to **split** its widest in-flight lane at the next
    /// boundary: the back half of the rows — with their per-row event
    /// ladders and RNG streams — move to `to` as a donated lane, the
    /// front half keeps serving here (the rebalancer's stage 3, reached
    /// when whole-lane donation would be zero-sum). Fire-and-forget; the
    /// donor refuses (no-op) when no lane has width ≥ 2 with at least
    /// `min_remaining` calls left; see [`Scheduler::donate_rows`].
    pub(crate) fn split_lane_into(
        &self,
        to: &Server,
        to_load: Arc<AtomicUsize>,
        min_remaining: usize,
    ) {
        let _ = self.tx.send(Msg::SplitLaneReq { to: to.tx.clone(), to_load, min_remaining });
    }

    /// Supervisor entry point (shard failover, stage 1): ask this shard
    /// to salvage its work — queued requests plus parked in-flight lanes
    /// — into `to`, re-pointing load gauges at `to_load`.
    /// Fire-and-forget; the shard no-ops unless its breaker is open.
    pub(crate) fn evacuate_into(&self, to: &Server, to_load: Arc<AtomicUsize>) {
        let _ = self.tx.send(Msg::Evacuate { to: to.tx.clone(), to_load });
    }

    /// Supervisor entry point (shard failover, stage 2): ask this shard
    /// to rebuild its engine from the retained factory and resume.
    /// Fire-and-forget; the shard no-ops unless its breaker is open.
    pub(crate) fn restart_engine(&self) {
        let _ = self.tx.send(Msg::Restart);
    }

    /// Channel-synchronous statistics: the reply is computed between two
    /// denoiser calls *after* every message queued before this one, so
    /// it doubles as an ordering barrier (and re-syncs the board — the
    /// loop publishes before replying). Blocks until the loop answers;
    /// use [`Self::board`] for a non-blocking read. Each call is counted
    /// in [`StatsBoard::stats_rpcs`].
    pub fn stats(&self) -> Result<ServerStats> {
        let (stx, srx) = channel();
        self.tx.send(Msg::Stats(stx)).map_err(|_| anyhow!("server is down"))?;
        self.board.note_stats_rpc();
        srx.recv().map_err(|_| anyhow!("server dropped stats"))
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

/// Joins the server thread on drop.
pub struct ServerJoin {
    handle: Option<std::thread::JoinHandle<()>>,
}

impl ServerJoin {
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerJoin {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct LoopState {
    requests: u64,
    batches: u64,
    batch_sizes: u64,
    cancelled: u64,
    deadline_exceeded: u64,
    /// requests donated away via work stealing
    stolen: u64,
    /// rebalance actions executed as donor (steals that moved work +
    /// lane donations)
    rebalances: u64,
    /// whole in-flight lanes donated away
    lanes_donated: u64,
    /// in-flight lanes split (back half donated, front half kept)
    lanes_split: u64,
    /// parked lanes evacuated to healthy shards during failover
    lanes_salvaged: u64,
    queue_lat: LatencyStats,
    e2e_lat: LatencyStats,
    /// per-tenant submit counts (anonymous requests are not listed)
    tenants: BTreeMap<String, u64>,
    /// slot capacity, for the occupancy statistic
    capacity: usize,
    /// client-submitted (`Msg::Req`) messages ingested so far — the
    /// loop-side half of the board's in-channel watermark (published at
    /// every tick; pairs with [`StatsBoard::note_submitted`])
    ingested: u64,
}

impl LoopState {
    fn new(capacity: usize) -> LoopState {
        LoopState {
            requests: 0,
            batches: 0,
            batch_sizes: 0,
            cancelled: 0,
            deadline_exceeded: 0,
            stolen: 0,
            rebalances: 0,
            lanes_donated: 0,
            lanes_split: 0,
            lanes_salvaged: 0,
            queue_lat: LatencyStats::new(),
            e2e_lat: LatencyStats::new(),
            tenants: BTreeMap::new(),
            capacity,
            ingested: 0,
        }
    }

    /// Submit-side accounting shared by both loops: total + per-tenant.
    /// Called only for `Msg::Req` — donated/salvaged requests were
    /// counted by their submit shard.
    fn count_submit(&mut self, tenant: Option<&str>) {
        self.requests += 1;
        if let Some(t) = tenant {
            *self.tenants.entry(t.to_string()).or_insert(0) += 1;
        }
    }
}

/// Drain-and-fail loop for a shard whose engine is gone for good: the
/// factory failed at startup (`base` = empty stats) or a failover
/// restart failed (`base` = the shard's real pre-failure snapshot, so
/// the router still sees the work this shard actually did). Every
/// report carries `healthy: false`; `breaker_open` reads `false` —
/// there is no breaker left to probe, and the supervision pass must
/// stop sending this shard Evacuate/Restart.
fn fail_engine_loop(rx: Receiver<Msg>, err: anyhow::Error, base: ServerStats, board: &StatsBoard) {
    eprintln!("[server] engine failed: {err:#}");
    // sync the board with the channel-visible final state, then freeze:
    // scrapes and rebalancer views of a dead shard must read the same
    // healthy:false / breaker:false answer Stats replies give, without
    // ever blocking on this loop
    board.publish_stats(&base);
    board.set_dead();
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Req(r) => {
                // keep the in-channel watermark paced even in death, or
                // every future board reader would think a submit is
                // forever "unseen" and fall back to a channel round-trip
                board.note_ingested_dead();
                r.resolve(Err(anyhow!("engine unavailable: {err:#}")), Outcome::Failed)
            }
            Msg::Donated(r) => {
                r.resolve(Err(anyhow!("engine unavailable: {err:#}")), Outcome::Failed)
            }
            // nothing here to donate, split, salvage, or restart (the
            // factory already failed; retrying it forever would wedge
            // the supervision pass)
            Msg::Steal { .. }
            | Msg::DonateLaneReq { .. }
            | Msg::SplitLaneReq { .. }
            | Msg::Evacuate { .. }
            | Msg::Restart => {}
            // dropping the lane fires every member sink's drop guard
            // (tickets fail, gauges decrement) — never silently lost
            Msg::AdoptLane(lane) => drop(lane),
            Msg::Shutdown => break,
            Msg::Stats(s) => {
                // healthy: false keeps the rebalancer from ever picking
                // this shard as a thief (its frozen gauges look idle)
                let _ = s.send(ServerStats {
                    healthy: false,
                    breaker_open: false,
                    ..base.clone()
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Fixed-batch mode (legacy policy; the bench's ablation baseline)
// ---------------------------------------------------------------------------

fn serve_loop<F>(
    factory: F,
    cfg: SamplerConfig,
    policy: BatchPolicy,
    rx: Receiver<Msg>,
    board: Arc<StatsBoard>,
) where
    F: FnOnce() -> Result<Engine>,
{
    let engine = match factory() {
        Ok(e) => e,
        Err(err) => {
            fail_engine_loop(rx, err, empty_stats(), &board);
            return;
        }
    };

    let mut batcher: Batcher<Request> = Batcher::new(policy);
    let mut st = LoopState::new(policy.max_batch);

    loop {
        // wait: bounded by the batch window if one is open
        let msg = match batcher.time_left() {
            Some(left) if !batcher.is_empty() => match rx.recv_timeout(left) {
                Ok(m) => Some(m),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                Err(_) => break,
            },
            _ => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break,
            },
        };

        match msg {
            Some(Msg::Req(r)) => {
                if r.cfg.is_some() {
                    // the fixed path serves one server-wide config; silently
                    // substituting it for the requested one would be wrong
                    r.resolve(
                        Err(anyhow!(
                            "per-request sampler config requires a continuous-mode \
                             server (ServeBuilder::continuous)"
                        )),
                        Outcome::Failed,
                    );
                    continue;
                }
                st.count_submit(r.tenant.as_deref());
                st.ingested += 1;
                board.count_submit(r.tenant.as_deref());
                batcher.push(r);
            }
            // a donated request was already counted by its submit shard
            Some(Msg::Donated(r)) => batcher.push(r),
            // fixed batches are FIFO with no spec keys — this mode never
            // donates or splits (the router only rebalances between
            // continuous shards), and it has no retry/breaker machinery
            // to evacuate or restart
            Some(Msg::Steal { .. })
            | Some(Msg::DonateLaneReq { .. })
            | Some(Msg::SplitLaneReq { .. })
            | Some(Msg::Evacuate { .. })
            | Some(Msg::Restart) => continue,
            // unreachable via the router (donation is continuous-only);
            // dropping the lane fail-safes its tickets and load gauges
            Some(Msg::AdoptLane(lane)) => {
                drop(lane);
                continue;
            }
            Some(Msg::Stats(s)) => {
                // publish before replying: a channel stats() call is an
                // ordering barrier, so the board must be at least as
                // fresh as the reply it syncs with
                board.publish_latency(&st.queue_lat.freeze(), &st.e2e_lat.freeze());
                board.publish_tick(fixed_tick_stats(&st, &engine, batcher.len()));
                let _ = s.send(snapshot(
                    &st,
                    &engine,
                    [0, batcher.len(), 0],
                    0,
                    0,
                    0,
                    Faults::NONE,
                    0,
                    0,
                ));
                continue;
            }
            Some(Msg::Shutdown) => {
                // flush remaining requests before exiting
                while !batcher.is_empty() {
                    dispatch(&engine, &cfg, &mut batcher, &mut st);
                }
                break;
            }
            None => {} // window expired
        }

        let mut dispatched = false;
        while batcher.ready() {
            dispatch(&engine, &cfg, &mut batcher, &mut st);
            dispatched = true;
        }
        if dispatched {
            board.publish_latency(&st.queue_lat.freeze(), &st.e2e_lat.freeze());
        }
        board.publish_tick(fixed_tick_stats(&st, &engine, batcher.len()));
    }
}

/// The fixed loop's per-iteration board publish: fixed mode has no
/// lanes, faults, or rebalancing, so most counters are zero and the
/// whole batcher depth reports as normal priority (matching
/// [`snapshot`]'s channel reply).
fn fixed_tick_stats(st: &LoopState, engine: &Engine, queued: usize) -> TickStats {
    TickStats {
        batches: st.batches,
        batch_rows: st.batch_sizes,
        nn_calls: engine.nfe.calls(),
        avg_request_nfe: engine.nfe.avg_request_nfe(),
        occupancy: engine.nfe.occupancy(st.capacity),
        cancelled: st.cancelled,
        deadline_exceeded: st.deadline_exceeded,
        queued: [0, queued, 0],
        ingested: st.ingested,
        ..TickStats::default()
    }
}

fn dispatch(
    engine: &Engine,
    cfg: &SamplerConfig,
    batcher: &mut Batcher<Request>,
    st: &mut LoopState,
) {
    let reqs = batcher.take();
    if reqs.is_empty() {
        return;
    }
    // queue-side lifecycle enforcement: the fixed path has no
    // mid-generation boundaries, so dispatch is the last drop point
    let now = Instant::now();
    let mut live = Vec::with_capacity(reqs.len());
    for r in reqs {
        if r.ctl.as_ref().is_some_and(|c| c.is_cancelled()) {
            st.cancelled += 1;
            r.resolve(Err(anyhow!("request cancelled")), Outcome::Cancelled);
            continue;
        }
        if r.deadline.is_some_and(|d| now >= d) {
            st.deadline_exceeded += 1;
            r.resolve(Err(anyhow!("request deadline exceeded")), Outcome::DeadlineExceeded);
            continue;
        }
        live.push(r);
    }
    if live.is_empty() {
        return;
    }
    st.batches += 1;
    st.batch_sizes += live.len() as u64;
    for r in &live {
        st.queue_lat.record(r.enqueued.elapsed());
        if let Some(ctl) = &r.ctl {
            ctl.set_admitted();
        }
    }

    let conditional = engine.conditional();
    let srcs: Option<Vec<String>> = if conditional {
        Some(live.iter().map(|r| r.src.clone().unwrap_or_default()).collect())
    } else {
        None
    };
    let seed = live.first().map(|r| r.seed).unwrap_or(0);

    match engine.generate_batch(srcs.as_deref(), live.len(), cfg, seed) {
        Ok((outs, _)) => {
            for (r, o) in live.into_iter().zip(outs) {
                st.e2e_lat.record(r.enqueued.elapsed());
                r.resolve(Ok(o), Outcome::Done);
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for r in live {
                r.resolve(Err(anyhow!("{msg}")), Outcome::Failed);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Continuous mode (NFE-aligned scheduler)
// ---------------------------------------------------------------------------

/// What the continuous loop should do after handling one message.
enum Flow {
    Continue,
    /// Shutdown requested: drain remaining work, then exit.
    Drain,
    /// The shard is gone for good (a failover restart failed): fall into
    /// the drain-and-fail loop with the carried error.
    Die(anyhow::Error),
}

/// Deliver one retirement to its client: counters + latency stats (plus
/// the board's pace EWMA — the terminal is the one moment the shard
/// knows a request's true µs/NFE), and the channel reply when one exists
/// (ticket terminals were already emitted inside the scheduler).
fn deliver_finished(f: Finished<Reply>, st: &mut LoopState, board: &StatsBoard) {
    match f.outcome {
        Outcome::Cancelled => st.cancelled += 1,
        Outcome::DeadlineExceeded => st.deadline_exceeded += 1,
        _ => {
            st.queue_lat.record(f.wait);
            if let Ok(d) = &f.result {
                // e2e = queue wait + in-flight generation time
                st.e2e_lat.record(f.wait + d.elapsed());
                board.observe_pace(d.nfe() as u64, d.elapsed());
            }
        }
    }
    if let Reply::Channel(tx) = f.payload {
        // channel requests set wants_result, so the delivery holds the
        // output
        let _ = tx.send(f.result.and_then(Delivery::into_output));
    }
}

/// Terminal failover exit for the continuous loop: an engine restart
/// against an open breaker failed, so this shard can never serve again.
/// Remaining work was already failed by the `Restart` handler; this
/// captures the shard's **real** pre-failure counters and parks in the
/// drain-and-fail loop so stats (and late messages) keep being answered.
fn shard_died(
    rx: Receiver<Msg>,
    sched: &mut Scheduler<Reply>,
    st: &mut LoopState,
    err: anyhow::Error,
    board: &StatsBoard,
) {
    st.batches = sched.engine().nfe.batches();
    st.batch_sizes = sched.engine().nfe.requests();
    let base = snapshot(
        st,
        sched.engine(),
        sched.queue_depths(),
        sched.lane_count(),
        sched.in_flight(),
        sched.ghost_events(),
        Faults::of(sched),
        sched.early_retired(),
        sched.turbo_truncated(),
    );
    fail_engine_loop(rx, err, base, board);
}

fn serve_continuous_loop<F>(
    factory: F,
    cfg: SamplerConfig,
    policy: SchedPolicy,
    fault: FaultPolicy,
    rx: Receiver<Msg>,
    board: Arc<StatsBoard>,
) where
    F: Fn() -> Result<Engine>,
{
    let engine = match factory() {
        Ok(e) => e,
        Err(err) => {
            fail_engine_loop(rx, err, empty_stats(), &board);
            return;
        }
    };

    let mut sched: Scheduler<Reply> =
        Scheduler::new(engine, cfg, policy).with_fault_policy(fault);
    let mut st = LoopState::new(policy.max_batch);
    let mut draining = false;

    'outer: loop {
        // 1. ingest. While lanes are active and the breaker closed, never
        //    block — drain whatever arrived and get back to stepping
        //    (admission happens at the boundary inside tick()). While the
        //    breaker is open (lanes parked at a boundary), block briefly
        //    instead of spinning: the timeout paces the half-open probe
        //    and keeps the loop responsive to Evacuate/Restart. Otherwise
        //    block until the grouping window (or the earliest queued
        //    deadline) of the pending work expires, or forever when idle.
        if sched.in_flight() > 0 && !sched.breaker_open() {
            loop {
                match rx.try_recv() {
                    Ok(m) => match handle_msg(m, &mut sched, &mut st, &factory, &board) {
                        Flow::Continue => {}
                        Flow::Drain => draining = true,
                        Flow::Die(err) => {
                            shard_died(rx, &mut sched, &mut st, err, &board);
                            return;
                        }
                    },
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        draining = true;
                        sched.flush();
                        break;
                    }
                }
            }
        } else if sched.in_flight() > 0 {
            if draining {
                // graceful shutdown cannot finish parked work and no
                // supervisor is coming (shutdown tears the fleet down):
                // fail it cleanly rather than hang the drain
                for f in
                    sched.abort_all("server shut down while its circuit breaker was open")
                {
                    deliver_finished(f, &mut st, &board);
                }
            } else {
                match rx.recv_timeout(QUEUE_POLL) {
                    Ok(m) => match handle_msg(m, &mut sched, &mut st, &factory, &board) {
                        Flow::Continue => {}
                        Flow::Drain => draining = true,
                        Flow::Die(err) => {
                            shard_died(rx, &mut sched, &mut st, err, &board);
                            return;
                        }
                    },
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                        draining = true;
                        sched.flush();
                    }
                }
            }
        } else if sched.pending_len() > 0 && !draining {
            // Cancellation has no wake path of its own (the flag lives in
            // the ticket), so bound the idle sleep: a queued request
            // cancelled during a long grouping window resolves within one
            // poll interval instead of at window expiry. `next_deadline`
            // can report nothing to wait for (e.g. a parked scheduler
            // holding only queued work) — the poll bound covers that too.
            let deadline =
                sched.next_deadline().unwrap_or_else(|| Instant::now() + QUEUE_POLL);
            let timeout =
                deadline.saturating_duration_since(Instant::now()).min(QUEUE_POLL);
            match rx.recv_timeout(timeout) {
                Ok(m) => match handle_msg(m, &mut sched, &mut st, &factory, &board) {
                    Flow::Continue => {}
                    Flow::Drain => draining = true,
                    Flow::Die(err) => {
                        shard_died(rx, &mut sched, &mut st, err, &board);
                        return;
                    }
                },
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    draining = true;
                    sched.flush();
                }
            }
        } else if !sched.has_work() {
            if draining {
                match drain_residual(&rx, &mut sched, &mut st, &factory, &board) {
                    Ok(true) => {}
                    Ok(false) => break,
                    Err(err) => {
                        shard_died(rx, &mut sched, &mut st, err, &board);
                        return;
                    }
                }
            } else {
                match rx.recv() {
                    Ok(m) => match handle_msg(m, &mut sched, &mut st, &factory, &board) {
                        Flow::Continue => {}
                        Flow::Drain => {
                            draining = true;
                            match drain_residual(&rx, &mut sched, &mut st, &factory, &board) {
                                Ok(true) => {}
                                Ok(false) => break,
                                Err(err) => {
                                    shard_died(rx, &mut sched, &mut st, err, &board);
                                    return;
                                }
                            }
                        }
                        Flow::Die(err) => {
                            shard_died(rx, &mut sched, &mut st, err, &board);
                            return;
                        }
                    },
                    Err(_) => break,
                }
            }
        }

        // 2. one boundary: reap/admit + one denoiser call; deliver
        //    retirements (ticket terminals were already emitted inside
        //    tick(), channel replies are sent here).
        let finished = sched.tick();
        let had_terminals = !finished.is_empty();
        for f in finished {
            deliver_finished(f, &mut st, &board);
        }
        // 3. publish the board: latency digests only when a terminal
        //    moved them (freeze() re-sorts the reservoir), the
        //    counters/gauges/pace every iteration — this is the "every
        //    tick" freshness contract readers rely on, and it is
        //    allocation-free (TickStats is all-Copy).
        if had_terminals {
            board.publish_latency(&st.queue_lat.freeze(), &st.e2e_lat.freeze());
        }
        board.publish_tick(cont_tick_stats(&st, &sched));
        if draining && !sched.has_work() {
            match drain_residual(&rx, &mut sched, &mut st, &factory, &board) {
                Ok(true) => {}
                Ok(false) => break 'outer,
                Err(err) => {
                    shard_died(rx, &mut sched, &mut st, err, &board);
                    return;
                }
            }
        }
    }
}

/// Final drain before a shutting-down shard exits: a rebalance pass
/// racing the shutdown may have parked work behind the `Shutdown`
/// message (a donated lane, stolen requests), and dropping the
/// `Receiver` would fail it. Handle everything already queued and
/// report whether any of it is (or produced) servable work — if so, the
/// caller keeps draining instead of exiting. Together with the donor
/// taking back work whose handoff send fails, this keeps graceful
/// shutdown from failing requests that rebalancing happened to be
/// moving.
fn drain_residual<F>(
    rx: &Receiver<Msg>,
    sched: &mut Scheduler<Reply>,
    st: &mut LoopState,
    factory: &F,
    board: &StatsBoard,
) -> Result<bool>
where
    F: Fn() -> Result<Engine>,
{
    while let Ok(m) = rx.try_recv() {
        match handle_msg(m, sched, st, factory, board) {
            Flow::Continue | Flow::Drain => {}
            Flow::Die(err) => return Err(err),
        }
    }
    Ok(sched.has_work())
}

/// Handle one control-plane message between two denoiser calls.
fn handle_msg<F>(
    msg: Msg,
    sched: &mut Scheduler<Reply>,
    st: &mut LoopState,
    factory: &F,
    board: &StatsBoard,
) -> Flow
where
    F: Fn() -> Result<Engine>,
{
    match msg {
        Msg::Req(r) => {
            st.count_submit(r.tenant.as_deref());
            st.ingested += 1;
            board.count_submit(r.tenant.as_deref());
            sched.enqueue(request_to_pending(r));
            Flow::Continue
        }
        // a donated request was already counted by its submit shard
        Msg::Donated(r) => {
            sched.enqueue(request_to_pending(r));
            Flow::Continue
        }
        Msg::Steal { max, to, to_load } => {
            // donor side of work stealing, between two denoiser calls:
            // pop a same-key run off the queue tail and forward it with
            // sinks/deadlines intact, re-pointing each load gauge at the
            // thief. If the thief exited (a rebalance pass racing
            // shutdown), the failed send returns the request and the
            // donor re-enqueues it — live work is never failed by a
            // handoff to a dead shard. (The re-taken request keeps the
            // thief's gauge; it was incremented at retarget and still
            // decrements exactly once at terminal, so the books balance.)
            let mut moved = false;
            for p in sched.steal_pending(max) {
                if let Some(ctl) = &p.ctl {
                    ctl.retarget_load(to_load.clone());
                }
                match to.send(Msg::Donated(pending_to_request(p))) {
                    Ok(()) => {
                        st.stolen += 1;
                        moved = true;
                    }
                    Err(e) => {
                        let Msg::Donated(r) = e.0 else { unreachable!("sent Donated") };
                        sched.enqueue(request_to_pending(r));
                    }
                }
            }
            if moved {
                st.rebalances += 1;
            }
            Flow::Continue
        }
        Msg::DonateLaneReq { to, to_load, min_remaining } => {
            // donor side of lane donation. handle_msg runs between two
            // denoiser calls, so the pack happens exactly at a
            // transition-time boundary: the lane's next predetermined
            // event is where the thief resumes. Refusals (near-retirement
            // lanes, zero-sum moves) are decided by the scheduler.
            if let Some(lane) = sched.donate_lane(min_remaining) {
                lane.retarget_load(&to_load);
                match to.send(Msg::AdoptLane(lane)) {
                    Ok(()) => {
                        st.rebalances += 1;
                        st.lanes_donated += 1;
                    }
                    Err(e) => {
                        // thief exited (shutdown race): resume the lane
                        // right here — byte-exact either way, and no
                        // member ticket is failed by the dead handoff
                        let Msg::AdoptLane(lane) = e.0 else {
                            unreachable!("sent AdoptLane")
                        };
                        sched.adopt_lane(lane);
                    }
                }
            }
            Flow::Continue
        }
        Msg::SplitLaneReq { to, to_load, min_remaining } => {
            // donor side of lane splitting — same boundary discipline as
            // DonateLaneReq, but only the back half of the widest
            // splittable lane moves; the donor keeps serving the front
            // half, so the move is never zero-sum. Refusals (no lane of
            // width ≥ 2, near-retirement) are decided by the scheduler.
            if let Some(lane) = sched.donate_rows(min_remaining) {
                lane.retarget_load(&to_load);
                match to.send(Msg::AdoptLane(lane)) {
                    Ok(()) => {
                        st.rebalances += 1;
                        st.lanes_split += 1;
                    }
                    Err(e) => {
                        // thief exited (shutdown race): resume the split
                        // half right here as its own lane — byte-exact
                        // either way, and no member ticket is failed by
                        // the dead handoff
                        let Msg::AdoptLane(lane) = e.0 else {
                            unreachable!("sent AdoptLane")
                        };
                        sched.adopt_lane(lane);
                    }
                }
            }
            Flow::Continue
        }
        Msg::AdoptLane(lane) => {
            // thief side: resume the donated session mid-schedule; its
            // members were counted by their submit shard already
            sched.adopt_lane(lane);
            Flow::Continue
        }
        Msg::Evacuate { to, to_load } => {
            // supervisor-driven failover, stage 1. Only meaningful while
            // the breaker is open (lanes parked at a boundary); a stale
            // decision against a recovered shard is ignored.
            if !sched.breaker_open() {
                return Flow::Continue;
            }
            // queued requests first — they were counted at submit, so
            // they travel as Donated and keep their enqueue order
            for p in sched.drain_pending() {
                if let Some(ctl) = &p.ctl {
                    ctl.retarget_load(to_load.clone());
                }
                if let Err(e) = to.send(Msg::Donated(pending_to_request(p))) {
                    // target exited (shutdown race): keep the request
                    // here — the supervisor picks a new target next pass
                    let Msg::Donated(r) = e.0 else { unreachable!("sent Donated") };
                    sched.enqueue(request_to_pending(r));
                }
            }
            // then every parked lane: each resumes on the healthy shard
            // byte-exactly at its next predetermined event, because the
            // breaker parked it *between* two denoiser calls
            for lane in sched.evacuate() {
                lane.retarget_load(&to_load);
                match to.send(Msg::AdoptLane(lane)) {
                    Ok(()) => st.lanes_salvaged += 1,
                    Err(e) => {
                        let Msg::AdoptLane(lane) = e.0 else {
                            unreachable!("sent AdoptLane")
                        };
                        sched.adopt_lane(lane);
                    }
                }
            }
            Flow::Continue
        }
        Msg::Restart => {
            // supervisor-driven failover, stage 2. Only meaningful while
            // the breaker is open; a recovered shard keeps its engine.
            if !sched.breaker_open() {
                return Flow::Continue;
            }
            match factory() {
                Ok(engine) => {
                    // reset_engine carries the NfeCounter over, so
                    // nn-call / per-request NFE accounting is continuous
                    // across the restart (tests/chaos.rs pins this)
                    sched.reset_engine(engine);
                    Flow::Continue
                }
                Err(err) => {
                    // the engine is not coming back: fail whatever this
                    // shard still holds (post-evacuation, usually
                    // nothing), then die with the real counters
                    let reason = format!("engine restart failed: {err:#}");
                    for f in sched.abort_all(&reason) {
                        deliver_finished(f, st, board);
                    }
                    Flow::Die(err)
                }
            }
        }
        Msg::Stats(s) => {
            // lanes retired so far are the "batches" of continuous mode
            st.batches = sched.engine().nfe.batches();
            st.batch_sizes = sched.engine().nfe.requests();
            let depths = sched.queue_depths();
            let ghosts = sched.ghost_events();
            let faults = Faults::of(sched);
            // publish before replying: a channel stats() call is an
            // ordering barrier, and its reply must never be fresher
            // than the board (tests pin board == reply at quiesce)
            board.publish_latency(&st.queue_lat.freeze(), &st.e2e_lat.freeze());
            board.publish_tick(cont_tick_stats(st, sched));
            let _ = s.send(snapshot(
                st,
                sched.engine(),
                depths,
                sched.lane_count(),
                sched.in_flight(),
                ghosts,
                faults,
                sched.early_retired(),
                sched.turbo_truncated(),
            ));
            Flow::Continue
        }
        Msg::Shutdown => {
            sched.flush();
            Flow::Drain
        }
    }
}

/// A queued server request as a scheduler entry. Ticket-only requests
/// (`Reply::Ticket`) don't read `Finished::result`, so retirement moves
/// the output into the sink instead of cloning it.
fn request_to_pending(r: Request) -> Pending<Reply> {
    Pending {
        src: r.src,
        seed: r.seed,
        cfg: r.cfg,
        enqueued: r.enqueued,
        deadline: r.deadline,
        priority: r.priority,
        ctl: r.ctl,
        tenant: r.tenant,
        wants_result: matches!(r.reply, Reply::Channel(_)),
        early_retire: r.early_retire,
        payload: r.reply,
    }
}

/// Inverse of [`request_to_pending`] — a stolen queue entry travelling to
/// another shard's channel.
fn pending_to_request(p: Pending<Reply>) -> Request {
    Request {
        src: p.src,
        seed: p.seed,
        cfg: p.cfg,
        deadline: p.deadline,
        priority: p.priority,
        ctl: p.ctl,
        tenant: p.tenant,
        enqueued: p.enqueued,
        early_retire: p.early_retire,
        reply: p.payload,
    }
}

/// Continuous-mode fault counters for a stats snapshot. The fixed path
/// has no retry/breaker machinery and reports [`Faults::NONE`].
#[derive(Clone, Copy)]
struct Faults {
    retries: u64,
    transient: u64,
    fatal: u64,
    breaker_open: bool,
}

impl Faults {
    const NONE: Faults = Faults { retries: 0, transient: 0, fatal: 0, breaker_open: false };

    fn of(sched: &Scheduler<Reply>) -> Faults {
        Faults {
            retries: sched.retries(),
            transient: sched.faults_transient(),
            fatal: sched.faults_fatal(),
            breaker_open: sched.breaker_open(),
        }
    }
}

/// The continuous loop's per-iteration board publish: monotonic tallies
/// from the loop state + engine NFE counter, instantaneous gauges from
/// the scheduler. All-`Copy` construction — zero allocations on the
/// steady-state path the serving bench gates.
fn cont_tick_stats(st: &LoopState, sched: &Scheduler<Reply>) -> TickStats {
    let engine = sched.engine();
    TickStats {
        batches: engine.nfe.batches(),
        batch_rows: engine.nfe.requests(),
        nn_calls: engine.nfe.calls(),
        avg_request_nfe: engine.nfe.avg_request_nfe(),
        occupancy: engine.nfe.occupancy(st.capacity),
        cancelled: st.cancelled,
        deadline_exceeded: st.deadline_exceeded,
        queued: sched.queue_depths(),
        lanes: sched.lane_count(),
        in_flight: sched.in_flight(),
        stolen: st.stolen,
        rebalances: st.rebalances,
        lanes_donated: st.lanes_donated,
        lanes_split: st.lanes_split,
        lanes_salvaged: st.lanes_salvaged,
        ghost_events_fired: sched.ghost_events(),
        retries: sched.retries(),
        faults_transient: sched.faults_transient(),
        faults_fatal: sched.faults_fatal(),
        early_retired: sched.early_retired(),
        turbo_truncated_nfe: sched.turbo_truncated(),
        breaker_open: sched.breaker_open(),
        ingested: st.ingested,
        backlog_nfe: sched.backlog_events(),
    }
}

fn snapshot(
    st: &LoopState,
    engine: &Engine,
    queue_depths: [usize; 3],
    lanes: usize,
    in_flight: usize,
    ghost_events: u64,
    faults: Faults,
    early_retired: u64,
    turbo_truncated_nfe: u64,
) -> ServerStats {
    let e2e = st.e2e_lat.freeze();
    ServerStats {
        requests: st.requests,
        batches: st.batches,
        nn_calls: engine.nfe.calls(),
        mean_batch: if st.batches == 0 {
            0.0
        } else {
            st.batch_sizes as f64 / st.batches as f64
        },
        queue_p95: st.queue_lat.p95(),
        e2e_p95: e2e.p95,
        e2e_p50: e2e.p50,
        e2e_p99: e2e.p99,
        e2e,
        avg_request_nfe: engine.nfe.avg_request_nfe(),
        occupancy: engine.nfe.occupancy(st.capacity),
        cancelled: st.cancelled,
        deadline_exceeded: st.deadline_exceeded,
        queued_low: queue_depths[0] as u64,
        queued_normal: queue_depths[1] as u64,
        queued_high: queue_depths[2] as u64,
        stolen: st.stolen,
        lanes: lanes as u64,
        in_flight: in_flight as u64,
        rebalances: st.rebalances,
        lanes_donated: st.lanes_donated,
        lanes_split: st.lanes_split,
        ghost_events_fired: ghost_events,
        retries: faults.retries,
        faults_transient: faults.transient,
        faults_fatal: faults.fatal,
        breaker_open: faults.breaker_open,
        lanes_salvaged: st.lanes_salvaged,
        early_retired,
        turbo_truncated_nfe,
        // a parked shard can't serve until it recovers or is restarted —
        // the rebalancer must not treat it as donor or thief meanwhile
        healthy: !faults.breaker_open,
        tenant_requests: st.tenants.iter().map(|(t, n)| (t.clone(), *n)).collect(),
    }
}

fn empty_stats() -> ServerStats {
    ServerStats {
        requests: 0,
        batches: 0,
        nn_calls: 0,
        mean_batch: 0.0,
        queue_p95: Duration::ZERO,
        e2e_p95: Duration::ZERO,
        e2e_p50: Duration::ZERO,
        e2e_p99: Duration::ZERO,
        e2e: LatencySnapshot::default(),
        avg_request_nfe: 0.0,
        occupancy: 0.0,
        cancelled: 0,
        deadline_exceeded: 0,
        queued_low: 0,
        queued_normal: 0,
        queued_high: 0,
        stolen: 0,
        lanes: 0,
        in_flight: 0,
        rebalances: 0,
        lanes_donated: 0,
        lanes_split: 0,
        ghost_events_fired: 0,
        retries: 0,
        faults_transient: 0,
        faults_fatal: 0,
        breaker_open: false,
        lanes_salvaged: 0,
        early_retired: 0,
        turbo_truncated_nfe: 0,
        healthy: true,
        tenant_requests: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::Engine;
    use crate::coordinator::request::Event;
    use crate::sampler::{SamplerConfig, SamplerKind};

    fn mock_factory() -> Result<Engine> {
        Ok(crate::coordinator::engine::cipher_mock_engine(8))
    }

    #[test]
    #[allow(deprecated)] // the wrappers must keep working verbatim
    fn serves_concurrent_requests_batched() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50);
        let policy = BatchPolicy { max_batch: 4, window: Duration::from_millis(30) };
        let (srv, join) = Server::start(mock_factory, cfg, policy);

        let mut rxs = Vec::new();
        for i in 0..8 {
            rxs.push(srv.submit_async(Some("the quick fox crosses a river".into()), i).unwrap());
        }
        for rx in rxs {
            let out = rx.recv().unwrap().unwrap();
            assert!(out.nfe >= 1);
        }
        let stats = srv.stats().unwrap();
        assert_eq!(stats.requests, 8);
        assert!(stats.batches <= 4, "8 reqs with max_batch 4 → ≤4 batches, got {}", stats.batches);
        assert!(stats.mean_batch >= 2.0, "batching should coalesce: {}", stats.mean_batch);
        srv.shutdown();
        join.join();
    }

    #[test]
    #[allow(deprecated)]
    fn blocking_submit_roundtrip() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 25);
        let (srv, join) =
            Server::start(mock_factory, cfg, BatchPolicy { max_batch: 1, window: Duration::ZERO });
        let out = srv.submit(Some("a small garden".into()), 1).unwrap();
        assert!(!out.text.is_empty());
        srv.shutdown();
        join.join();
    }

    #[test]
    #[allow(deprecated)]
    fn shutdown_flushes_pending() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 25);
        let policy = BatchPolicy { max_batch: 64, window: Duration::from_secs(60) };
        let (srv, join) = Server::start(mock_factory, cfg, policy);
        let rx = srv.submit_async(Some("this old road".into()), 2).unwrap();
        srv.shutdown();
        // pending request must still be answered (flush-on-shutdown)
        let out = rx.recv().unwrap().unwrap();
        assert!(!out.tokens.is_empty());
        join.join();
    }

    #[test]
    #[allow(deprecated)]
    fn engine_failure_fails_requests_cleanly() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 25);
        let (srv, join) = Server::start(
            || Err(anyhow!("boom")),
            cfg,
            BatchPolicy::default(),
        );
        let r = srv.submit(Some("x".into()), 0);
        assert!(r.is_err());
        srv.shutdown();
        join.join();
    }

    #[test]
    fn fixed_mode_ticket_sees_admitted_then_done() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 25);
        let (srv, join) =
            Server::start(mock_factory, cfg, BatchPolicy { max_batch: 1, window: Duration::ZERO });
        let mut t = srv
            .submit_request(GenRequest::new(1).src("a small garden").stream_partials())
            .unwrap();
        assert!(matches!(t.next_event(), Some(Event::Admitted { .. })));
        // the fixed path has no boundaries, so the next event is terminal
        match t.next_event() {
            Some(Event::Done(out)) => assert!(!out.tokens.is_empty()),
            other => panic!("expected Done, got {other:?}"),
        }
        assert!(t.next_event().is_none());
        srv.shutdown();
        join.join();
    }

    #[test]
    fn fixed_mode_enforces_deadline_at_dispatch() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 25);
        let (srv, join) =
            Server::start(mock_factory, cfg, BatchPolicy { max_batch: 1, window: Duration::ZERO });
        let t = srv
            .submit_request(GenRequest::new(1).src("x").deadline(Duration::ZERO))
            .unwrap();
        assert!(t.wait().unwrap_err().to_string().contains("deadline"));
        let stats = srv.stats().unwrap();
        assert_eq!(stats.deadline_exceeded, 1);
        srv.shutdown();
        join.join();
    }

    // -- continuous mode --

    #[test]
    #[allow(deprecated)]
    fn continuous_serves_and_reports_per_request_nfe() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50);
        let policy = SchedPolicy {
            max_batch: 4,
            window: Duration::from_millis(10),
            shared_tau_groups: true,
        };
        let (srv, join) = Server::start_continuous(mock_factory, cfg, policy);
        let mut rxs = Vec::new();
        for i in 0..8 {
            rxs.push(srv.submit_async(Some("the quick fox crosses a river".into()), i).unwrap());
        }
        for rx in rxs {
            let out = rx.recv().unwrap().unwrap();
            assert!(out.nfe >= 1 && out.nfe <= 8, "per-request NFE = |𝒯| ≤ N");
            assert!(!out.text.is_empty());
        }
        let stats = srv.stats().unwrap();
        assert_eq!(stats.requests, 8);
        assert!(stats.avg_request_nfe >= 1.0 && stats.avg_request_nfe <= 8.0);
        assert!(stats.occupancy > 0.0 && stats.occupancy <= 1.0);
        assert_eq!(stats.cancelled + stats.deadline_exceeded, 0);
        srv.shutdown();
        join.join();
    }

    #[test]
    fn continuous_ticket_streams_progress_to_done() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50);
        let policy = SchedPolicy {
            max_batch: 4,
            window: Duration::ZERO,
            shared_tau_groups: true,
        };
        let (srv, join) = Server::start_continuous(mock_factory, cfg, policy);
        let mut t = srv
            .submit_request(
                GenRequest::new(7).src("the quick fox crosses a river").stream_partials(),
            )
            .unwrap();
        assert!(matches!(t.next_event(), Some(Event::Admitted { .. })));
        let mut last_progress: Option<(usize, usize, Vec<u32>)> = None;
        let done = loop {
            match t.next_event() {
                Some(Event::Progress { nfe_done, nfe_total, partial_tokens }) => {
                    if let Some((prev, _, _)) = &last_progress {
                        assert!(nfe_done > *prev, "progress must be monotonic");
                    }
                    last_progress = Some((nfe_done, nfe_total, partial_tokens));
                }
                Some(Event::Done(out)) => break out,
                other => panic!("unexpected event {other:?}"),
            }
        };
        let (nfe_done, nfe_total, tokens) = last_progress.expect("at least one progress event");
        assert_eq!(nfe_done, done.nfe);
        assert_eq!(nfe_total, done.nfe);
        assert_eq!(tokens, done.tokens, "final progress == done output, byte for byte");
        srv.shutdown();
        join.join();
    }

    #[test]
    #[allow(deprecated)]
    fn continuous_shutdown_flushes_pending() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 25);
        let policy = SchedPolicy {
            max_batch: 8,
            window: Duration::from_secs(60), // window must not delay the drain
            shared_tau_groups: true,
        };
        let (srv, join) = Server::start_continuous(mock_factory, cfg, policy);
        let rx = srv.submit_async(Some("this old road".into()), 2).unwrap();
        srv.shutdown();
        let out = rx.recv().unwrap().unwrap();
        assert!(!out.tokens.is_empty());
        join.join();
    }

    #[test]
    #[allow(deprecated)]
    fn continuous_engine_failure_fails_requests_cleanly() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 25);
        let (srv, join) = Server::start_continuous(
            || Err(anyhow!("boom")),
            cfg,
            SchedPolicy::default(),
        );
        let r = srv.submit(Some("x".into()), 0);
        assert!(r.is_err());
        srv.shutdown();
        join.join();
    }

    #[test]
    fn engine_failure_fails_tickets_cleanly() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 25);
        let (srv, join) = Server::start_continuous(
            || Err(anyhow!("boom")),
            cfg,
            SchedPolicy::default(),
        );
        let t = srv.submit_request(GenRequest::new(0).src("x")).unwrap();
        assert!(t.wait().is_err());
        srv.shutdown();
        join.join();
    }
}
