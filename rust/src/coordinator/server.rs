//! The request loop: queue → batcher → engine → responses.
//!
//! PJRT handles are not `Send`, so the engine is built *inside* the server
//! thread from a factory closure; clients hold a cheap cloneable handle
//! and block on a per-request response channel (or use `submit_async` and
//! collect later). Shutdown is explicit or on handle drop.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::metrics::LatencyStats;
use crate::sampler::SamplerConfig;

use super::batcher::{BatchPolicy, Batcher};
use super::engine::{Engine, GenOutput};

/// One queued request.
struct Request {
    src: Option<String>,
    seed: u64,
    enqueued: Instant,
    respond: Sender<Result<GenOutput>>,
}

enum Msg {
    Req(Request),
    Stats(Sender<ServerStats>),
    Shutdown,
}

/// Aggregate serving statistics.
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub requests: u64,
    pub batches: u64,
    pub nn_calls: u64,
    pub mean_batch: f64,
    pub queue_p95: Duration,
    pub e2e_p95: Duration,
    pub e2e_p50: Duration,
}

/// Cloneable client handle to a running server.
#[derive(Clone)]
pub struct Server {
    tx: Sender<Msg>,
}

impl Server {
    /// Start the server thread. `factory` builds the engine on that thread
    /// (PJRT is thread-bound); `cfg` is the sampler every request uses.
    pub fn start<F>(factory: F, cfg: SamplerConfig, policy: BatchPolicy) -> (Server, ServerJoin)
    where
        F: FnOnce() -> Result<Engine> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let handle = std::thread::spawn(move || serve_loop(factory, cfg, policy, rx));
        (Server { tx }, ServerJoin { handle: Some(handle) })
    }

    /// Submit and wait for the result.
    pub fn submit(&self, src: Option<String>, seed: u64) -> Result<GenOutput> {
        self.submit_async(src, seed)?
            .recv()
            .map_err(|_| anyhow!("server dropped response"))?
    }

    /// Submit without blocking; returns the response receiver.
    pub fn submit_async(
        &self,
        src: Option<String>,
        seed: u64,
    ) -> Result<Receiver<Result<GenOutput>>> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Msg::Req(Request { src, seed, enqueued: Instant::now(), respond: rtx }))
            .map_err(|_| anyhow!("server is down"))?;
        Ok(rrx)
    }

    pub fn stats(&self) -> Result<ServerStats> {
        let (stx, srx) = channel();
        self.tx.send(Msg::Stats(stx)).map_err(|_| anyhow!("server is down"))?;
        srx.recv().map_err(|_| anyhow!("server dropped stats"))
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

/// Joins the server thread on drop.
pub struct ServerJoin {
    handle: Option<std::thread::JoinHandle<()>>,
}

impl ServerJoin {
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerJoin {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct LoopState {
    requests: u64,
    batches: u64,
    batch_sizes: u64,
    queue_lat: LatencyStats,
    e2e_lat: LatencyStats,
}

fn serve_loop<F>(factory: F, cfg: SamplerConfig, policy: BatchPolicy, rx: Receiver<Msg>)
where
    F: FnOnce() -> Result<Engine>,
{
    let engine = match factory() {
        Ok(e) => e,
        Err(err) => {
            // engine failed: drain and fail every request
            eprintln!("[server] engine init failed: {err:#}");
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Req(r) => {
                        let _ = r.respond.send(Err(anyhow!("engine init failed")));
                    }
                    Msg::Shutdown => break,
                    Msg::Stats(s) => {
                        let _ = s.send(empty_stats());
                    }
                }
            }
            return;
        }
    };

    let mut batcher: Batcher<Request> = Batcher::new(policy);
    let mut st = LoopState {
        requests: 0,
        batches: 0,
        batch_sizes: 0,
        queue_lat: LatencyStats::new(),
        e2e_lat: LatencyStats::new(),
    };
    let stats_lock: Arc<Mutex<()>> = Arc::new(Mutex::new(()));
    let _ = stats_lock; // reserved for future concurrent stats readers

    loop {
        // wait: bounded by the batch window if one is open
        let msg = match batcher.time_left() {
            Some(left) if !batcher.is_empty() => match rx.recv_timeout(left) {
                Ok(m) => Some(m),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                Err(_) => break,
            },
            _ => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break,
            },
        };

        match msg {
            Some(Msg::Req(r)) => {
                st.requests += 1;
                batcher.push(r);
            }
            Some(Msg::Stats(s)) => {
                let _ = s.send(snapshot(&st, &engine));
                continue;
            }
            Some(Msg::Shutdown) => {
                // flush remaining requests before exiting
                while !batcher.is_empty() {
                    dispatch(&engine, &cfg, &mut batcher, &mut st);
                }
                break;
            }
            None => {} // window expired
        }

        while batcher.ready() {
            dispatch(&engine, &cfg, &mut batcher, &mut st);
        }
    }
}

fn dispatch(engine: &Engine, cfg: &SamplerConfig, batcher: &mut Batcher<Request>, st: &mut LoopState) {
    let reqs = batcher.take();
    if reqs.is_empty() {
        return;
    }
    st.batches += 1;
    st.batch_sizes += reqs.len() as u64;
    for r in &reqs {
        st.queue_lat.record(r.enqueued.elapsed());
    }

    let conditional = engine.conditional();
    let srcs: Option<Vec<String>> = if conditional {
        Some(reqs.iter().map(|r| r.src.clone().unwrap_or_default()).collect())
    } else {
        None
    };
    let seed = reqs.first().map(|r| r.seed).unwrap_or(0);

    match engine.generate_batch(srcs.as_deref(), reqs.len(), cfg, seed) {
        Ok((outs, _)) => {
            for (r, o) in reqs.into_iter().zip(outs) {
                st.e2e_lat.record(r.enqueued.elapsed());
                let _ = r.respond.send(Ok(o));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for r in reqs {
                let _ = r.respond.send(Err(anyhow!("{msg}")));
            }
        }
    }
}

fn snapshot(st: &LoopState, engine: &Engine) -> ServerStats {
    ServerStats {
        requests: st.requests,
        batches: st.batches,
        nn_calls: engine.nfe.calls(),
        mean_batch: if st.batches == 0 {
            0.0
        } else {
            st.batch_sizes as f64 / st.batches as f64
        },
        queue_p95: st.queue_lat.p95(),
        e2e_p95: st.e2e_lat.p95(),
        e2e_p50: st.e2e_lat.p50(),
    }
}

fn empty_stats() -> ServerStats {
    ServerStats {
        requests: 0,
        batches: 0,
        nn_calls: 0,
        mean_batch: 0.0,
        queue_p95: Duration::ZERO,
        e2e_p95: Duration::ZERO,
        e2e_p50: Duration::ZERO,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::Engine;
    use crate::data::words;
    use crate::runtime::MockDenoiser;
    use crate::sampler::{SamplerConfig, SamplerKind};

    fn mock_factory() -> Result<Engine> {
        let vocab = words::translation_vocab();
        let cfg = MockDenoiser::test_config(vocab.len(), 8, 8, "absorbing");
        let den = MockDenoiser::with_fn(cfg, |src, pos| {
            src.map(|s| (s[pos] + 41).min(98)).unwrap_or(3)
        });
        Ok(Engine::from_denoiser(Box::new(den), vocab, "mock"))
    }

    #[test]
    fn serves_concurrent_requests_batched() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50);
        let policy = BatchPolicy { max_batch: 4, window: Duration::from_millis(30) };
        let (srv, join) = Server::start(mock_factory, cfg, policy);

        let mut rxs = Vec::new();
        for i in 0..8 {
            rxs.push(srv.submit_async(Some("the quick fox crosses a river".into()), i).unwrap());
        }
        for rx in rxs {
            let out = rx.recv().unwrap().unwrap();
            assert!(out.nfe >= 1);
        }
        let stats = srv.stats().unwrap();
        assert_eq!(stats.requests, 8);
        assert!(stats.batches <= 4, "8 reqs with max_batch 4 → ≤4 batches, got {}", stats.batches);
        assert!(stats.mean_batch >= 2.0, "batching should coalesce: {}", stats.mean_batch);
        srv.shutdown();
        join.join();
    }

    #[test]
    fn blocking_submit_roundtrip() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 25);
        let (srv, join) =
            Server::start(mock_factory, cfg, BatchPolicy { max_batch: 1, window: Duration::ZERO });
        let out = srv.submit(Some("a small garden".into()), 1).unwrap();
        assert!(!out.text.is_empty());
        srv.shutdown();
        join.join();
    }

    #[test]
    fn shutdown_flushes_pending() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 25);
        let policy = BatchPolicy { max_batch: 64, window: Duration::from_secs(60) };
        let (srv, join) = Server::start(mock_factory, cfg, policy);
        let rx = srv.submit_async(Some("this old road".into()), 2).unwrap();
        srv.shutdown();
        // pending request must still be answered (flush-on-shutdown)
        let out = rx.recv().unwrap().unwrap();
        assert!(!out.tokens.is_empty());
        join.join();
    }

    #[test]
    fn engine_failure_fails_requests_cleanly() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 25);
        let (srv, join) = Server::start(
            || Err(anyhow!("boom")),
            cfg,
            BatchPolicy::default(),
        );
        let r = srv.submit(Some("x".into()), 0);
        assert!(r.is_err());
        srv.shutdown();
        join.join();
    }
}
