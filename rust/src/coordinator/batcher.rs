//! The dynamic batching policy — the serving-side contribution.
//!
//! DNDM makes batching *cheaper* than for step-marching samplers: a batch
//! shares one predetermined transition set 𝒯, so the whole batch costs
//! |𝒯| NN calls regardless of size (NFE-aligned batching). The batcher
//! therefore wants batches as large as the compiled buckets allow, subject
//! to a latency window:
//!
//! * close a batch as soon as it reaches `max_batch`, or
//! * when `window` has elapsed since the batch's first request.

use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub window: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 16, window: Duration::from_millis(20) }
    }
}

/// Accumulates items into policy-shaped batches.
#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    pending: Vec<T>,
    first_at: Option<Instant>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, pending: Vec::new(), first_at: None }
    }

    pub fn push(&mut self, item: T) {
        if self.pending.is_empty() {
            self.first_at = Some(Instant::now());
        }
        self.pending.push(item);
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Should the current batch be dispatched now?
    pub fn ready(&self) -> bool {
        if self.pending.is_empty() {
            return false;
        }
        if self.pending.len() >= self.policy.max_batch {
            return true;
        }
        self.first_at
            .map(|t0| t0.elapsed() >= self.policy.window)
            .unwrap_or(false)
    }

    /// How long the dispatcher may sleep before this batch must go out.
    pub fn time_left(&self) -> Option<Duration> {
        self.first_at.map(|t0| self.policy.window.saturating_sub(t0.elapsed()))
    }

    /// Take up to `max_batch` items (FIFO), leaving the rest pending.
    pub fn take(&mut self) -> Vec<T> {
        let n = self.pending.len().min(self.policy.max_batch);
        let rest = self.pending.split_off(n);
        let out = std::mem::replace(&mut self.pending, rest);
        self.first_at = if self.pending.is_empty() { None } else { Some(Instant::now()) };
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(max: usize, ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch: max, window: Duration::from_millis(ms) }
    }

    #[test]
    fn dispatches_on_full_batch() {
        let mut b = Batcher::new(policy(3, 10_000));
        b.push(1);
        b.push(2);
        assert!(!b.ready());
        b.push(3);
        assert!(b.ready());
        assert_eq!(b.take(), vec![1, 2, 3]);
        assert!(b.is_empty() && !b.ready());
    }

    #[test]
    fn dispatches_on_window_expiry() {
        let mut b = Batcher::new(policy(100, 5));
        b.push("a");
        assert!(!b.ready());
        std::thread::sleep(Duration::from_millis(7));
        assert!(b.ready());
        assert_eq!(b.take(), vec!["a"]);
    }

    #[test]
    fn take_respects_max_and_keeps_overflow() {
        let mut b = Batcher::new(policy(2, 1));
        for i in 0..5 {
            b.push(i);
        }
        assert_eq!(b.take(), vec![0, 1]);
        assert_eq!(b.len(), 3);
        assert_eq!(b.take(), vec![2, 3]);
        assert_eq!(b.take(), vec![4]);
    }

    #[test]
    fn time_left_counts_down() {
        let mut b = Batcher::new(policy(10, 50));
        assert!(b.time_left().is_none());
        b.push(());
        let left = b.time_left().unwrap();
        assert!(left <= Duration::from_millis(50));
    }
}
