//! The engine: model runtime + vocabulary + sampling entry points.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::data::{words, UncondCorpus};
use crate::metrics::NfeCounter;
use crate::runtime::{Artifacts, Denoiser, ModelRuntime};
use crate::sampler::{self, GenResult, SamplerConfig};
use crate::text::Vocab;

/// One generated sequence plus its accounting.
#[derive(Debug, Clone)]
pub struct GenOutput {
    pub text: String,
    pub tokens: Vec<u32>,
    /// NN calls of the batch this sequence was generated in
    pub nfe: usize,
    /// generation wall time (excludes queue wait in both server modes)
    pub elapsed: Duration,
}

/// Model + vocab + counters; the object everything above L3 talks to.
pub struct Engine {
    den: Box<dyn Denoiser>,
    vocab: Vocab,
    pub name: String,
    pub nfe: Arc<NfeCounter>,
}

impl Engine {
    /// Load a model from artifacts (creates its own PJRT CPU client).
    pub fn new(arts: &Artifacts, model: &str) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e}"))?;
        let rt = ModelRuntime::load(arts, &client, model)?;
        let vocab = vocab_for(&rt.config.dataset)?;
        Ok(Engine {
            name: model.to_string(),
            den: Box::new(rt),
            vocab,
            nfe: Arc::new(NfeCounter::new()),
        })
    }

    /// Wrap any denoiser (tests / mock-backed serving).
    pub fn from_denoiser(den: Box<dyn Denoiser>, vocab: Vocab, name: &str) -> Engine {
        Engine { den, vocab, name: name.to_string(), nfe: Arc::new(NfeCounter::new()) }
    }

    pub fn denoiser(&self) -> &dyn Denoiser {
        self.den.as_ref()
    }

    pub fn vocab(&self) -> &Vocab {
        &self.vocab
    }

    pub fn conditional(&self) -> bool {
        self.den.config().conditional()
    }

    /// Pre-compile the given batch buckets (serving warmup).
    pub fn warmup(&self, buckets: &[usize]) -> Result<()> {
        // only meaningful for the PJRT runtime; a quick denoise forces
        // compilation for the bucket of each size
        let cfg = self.den.config().clone();
        for &b in buckets {
            let x = crate::tensor::TokenBatch::filled(b, cfg.seq_len, cfg.noise_lo);
            let t = vec![1.0f32; b];
            let src = cfg
                .conditional()
                .then(|| crate::tensor::TokenBatch::filled(b, cfg.src_len, cfg.noise_lo));
            self.den.denoise(&x, &t, src.as_ref())?;
        }
        Ok(())
    }

    /// Encode source text to the model's source length.
    pub fn encode_src(&self, text: &str) -> Vec<u32> {
        self.vocab.encode_str(text, self.den.config().src_len)
    }

    /// Decode generated ids to text (word models join with spaces, char
    /// models concatenate).
    pub fn decode(&self, tokens: &[u32]) -> String {
        let cfg = self.den.config();
        if cfg.conditional() {
            self.vocab.decode_str(tokens)
        } else {
            self.vocab.decode_chars(tokens)
        }
    }

    /// Generate a whole batch with one shared sampler run.
    pub fn generate_batch(
        &self,
        srcs: Option<&[String]>,
        batch: usize,
        cfg: &SamplerConfig,
        seed: u64,
    ) -> Result<(Vec<GenOutput>, GenResult)> {
        let t0 = Instant::now();
        let src_ids: Option<Vec<Vec<u32>>> =
            srcs.map(|ss| ss.iter().map(|s| self.encode_src(s)).collect());
        let result = sampler::generate(
            self.den.as_ref(),
            cfg,
            src_ids.as_deref(),
            batch,
            seed,
            Some(&self.nfe),
        )?;
        let elapsed = t0.elapsed();
        let outs = result
            .tokens
            .iter()
            .map(|toks| GenOutput {
                text: self.decode(toks),
                tokens: toks.clone(),
                nfe: result.nfe,
                elapsed,
            })
            .collect();
        Ok((outs, result))
    }

    /// Single-sequence convenience.
    pub fn generate_one(
        &self,
        src: Option<&str>,
        cfg: &SamplerConfig,
        seed: u64,
    ) -> Result<GenOutput> {
        let srcs = src.map(|s| vec![s.to_string()]);
        let (mut outs, _) = self.generate_batch(srcs.as_deref(), 1, cfg, seed)?;
        Ok(outs.remove(0))
    }
}

/// The bare denoiser behind [`cipher_mock_engine`] — exposed so callers
/// can wrap it (e.g. in a fault-injecting
/// [`ChaosDenoiser`](crate::runtime::ChaosDenoiser)) before building the
/// engine with [`Engine::from_denoiser`] and
/// [`words::translation_vocab`].
pub fn cipher_mock_denoiser(seq_len: usize) -> crate::runtime::MockDenoiser {
    use crate::runtime::MockDenoiser;
    let vocab = words::translation_vocab();
    let cfg = MockDenoiser::test_config(vocab.len(), seq_len, seq_len, "absorbing");
    let mut den = MockDenoiser::with_fn(cfg, |src, pos| {
        let s = src.map(|s| s[pos]).unwrap_or(0);
        if s >= 3 && (s as usize) < 3 + 41 {
            s + 41
        } else {
            0
        }
    });
    den.peak = 14.0; // sharp enough that temperature-1 draws stay correct
    den
}

/// Deterministic mock-backed engine implementing the synthetic iwslt
/// cipher (src word id + 41) perfectly — the shared backend for serving
/// tests and artifact-free bench runs.
pub fn cipher_mock_engine(seq_len: usize) -> Engine {
    let den = cipher_mock_denoiser(seq_len);
    Engine::from_denoiser(Box::new(den), words::translation_vocab(), "cipher-mock")
}

/// Vocab for a dataset name (translation share one vocab; uncond per corpus).
pub fn vocab_for(dataset: &str) -> Result<Vocab> {
    if dataset.contains("iwslt") || dataset.contains("wmt") || dataset == "mock" {
        Ok(words::translation_vocab())
    } else if let Some(c) = UncondCorpus::parse(dataset) {
        Ok(c.vocab())
    } else {
        Err(anyhow!("unknown dataset '{dataset}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockDenoiser;
    use crate::sampler::SamplerKind;

    fn mock_engine() -> Engine {
        let vocab = words::translation_vocab();
        let v = vocab.len();
        let cfg = MockDenoiser::test_config(v, 8, 8, "absorbing");
        // target = "identity cipher": src token id + 41 (src word → tgt word)
        let den = MockDenoiser::with_fn(cfg, move |src, pos| {
            let s = src.map(|s| s[pos]).unwrap_or(3);
            if s >= 3 && (s as usize) < 3 + 41 {
                s + 41
            } else {
                0
            }
        });
        Engine::from_denoiser(Box::new(den), vocab, "mock")
    }

    #[test]
    fn generate_one_translates_via_mock() {
        let eng = mock_engine();
        let out = eng
            .generate_one(
                Some("the quick fox"),
                &SamplerConfig::new(SamplerKind::Dndm, 25),
                7,
            )
            .unwrap();
        assert!(out.nfe >= 1 && out.nfe <= 8);
        // every emitted token is a target-language word (id ≥ 44) or pad
        assert!(!out.text.is_empty());
        assert!(eng.nfe.calls() >= 1);
    }

    #[test]
    fn batch_outputs_share_nfe() {
        let eng = mock_engine();
        let srcs: Vec<String> = vec!["the quick fox".into(), "a small river".into()];
        let (outs, res) = eng
            .generate_batch(Some(&srcs), 2, &SamplerConfig::new(SamplerKind::Dndm, 50), 3)
            .unwrap();
        assert_eq!(outs.len(), 2);
        assert!(outs.iter().all(|o| o.nfe == res.nfe));
    }

    #[test]
    fn vocab_for_known_datasets() {
        assert!(vocab_for("synth-iwslt14").is_ok());
        assert!(vocab_for("synth-text8").is_ok());
        assert!(vocab_for("synth-enwik8").is_ok());
        assert!(vocab_for("alien").is_err());
    }

    #[test]
    fn warmup_runs_denoiser() {
        let eng = mock_engine();
        eng.warmup(&[1, 2]).unwrap();
        assert_eq!(eng.denoiser().calls(), 2);
    }
}
