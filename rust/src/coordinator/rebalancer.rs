//! Background rebalancing — the decision policy and cadence loop behind
//! cross-shard work movement.
//!
//! Placement ([`Router::place`]) balances load at **submit** time and the
//! pull-at-submit stealing pass repairs queue imbalance whenever new
//! traffic arrives. Neither helps during a lull: a shard serving a slow
//! spec can sit on a deep queue — or a wide in-flight batch — while its
//! neighbour drains to idle, and with no submissions nothing ever looks
//! at the gauges again. This module closes that gap with a **background
//! rebalance loop** owned by the [`Router`]: on a configurable cadence it
//! snapshots every shard, plans at most one corrective action, and
//! dispatches it.
//!
//! Three kinds of movement, in preference order:
//!
//! 1. **Queued-request stealing** (PR 4's mechanism): the deepest queue
//!    donates up to half of one same-`SpecKey` run to an idle shard.
//!    Cheapest — the requests haven't started, so nothing but queue
//!    entries move.
//! 2. **In-flight lane donation**: when queues are shallow but a shard
//!    holds more than one live lane (or a queued request to refill the
//!    freed capacity), a whole live lane moves. The paper's
//!    predetermined transition-time set 𝒯 is what makes this possible at
//!    all: every lane's remaining denoiser calls are known exactly
//!    (`total_events()` minus the event cursors — exact even after
//!    narrowing, since per-row ladders re-merge over the survivors), so
//!    the donor can pack the lane at a transition-time boundary
//!    ([`Scheduler::donate_lane`] → [`DonatedLane`]) and the thief
//!    resumes it mid-schedule ([`Scheduler::adopt_lane`]) with survivor
//!    byte-parity — the handoff point is well-defined for every
//!    `SamplerKind` because each row's event ladder is predetermined.
//! 3. **Lane splitting** (new): when even donation is refused — one wide
//!    lane is the shard's only work, so moving it whole is zero-sum —
//!    the back half of its *rows* move instead
//!    ([`Scheduler::donate_rows`]). Rows carry their own event ladders
//!    and forked RNG streams, so both halves resume byte-exactly; the
//!    donor keeps serving the front half, which makes the split strictly
//!    parallelism-positive whenever the lane has ≥ 2 rows.
//!
//! The decision policy is **pure** — [`plan`] maps per-shard
//! [`ShardView`]s to at most one [`Action`], and [`pick_donation`] is the
//! lane-level cost model — so both are unit-testable without threads or
//! channels. The thin I/O wrapper [`run_pass`] gathers the views from
//! each shard's lock-free [`StatsBoard`] (no `Msg::Stats` channel
//! round-trips at steady state — the engine loop publishes its gauges
//! between denoiser calls and the pass just reads atomics) and executes
//! the plan; the background thread in `spawn_background` calls it on a
//! timer. One freshness escape hatch remains: a submit the engine has
//! not yet ingested is invisible to the board
//! ([`StatsBoard::has_unseen_submits`]), so for exactly those shards a
//! pass falls back to one channel `stats()` — the reply is answered
//! after the queued `Msg::Req`s, restoring the submit→view ordering
//! that manual `rebalance()` callers (and the steal-count pins in
//! `tests/rebalance.rs`) rely on. The trade: board passes are no longer
//! serialized against the donor's message loop, so two close-together
//! passes can both observe the same imbalance and over-donate
//! transiently — the next pass sees the result and corrects, which is
//! the same self-correction contract the cadence loop already had.
//!
//! [`StatsBoard`]: super::telemetry::StatsBoard
//! [`StatsBoard::has_unseen_submits`]: super::telemetry::StatsBoard::has_unseen_submits
//!
//! The same cadence loop also runs a **supervision pass** first (shard
//! failover, `docs/robustness.md`): a shard whose circuit breaker is
//! open has parked its in-flight lanes at a transition-time boundary,
//! so the supervisor salvages them — queued requests re-enqueue and
//! parked lanes resume byte-exactly on the least-loaded healthy shard —
//! and then asks the broken shard to rebuild its engine from the
//! retained factory. [`plan_supervision`] is the pure decision;
//! `supervise_pass` is the I/O wrapper. Init-dead shards (factory
//! failed at startup: `healthy: false` with the breaker closed) are not
//! actionable — they hold nothing to salvage and have no engine to
//! restart.
//!
//! When is movement **refused**? See `docs/rebalancing.md` for the full
//! table; in short:
//!
//! * no idle thief — adopting into a busy shard would put a second spec
//!   key in flight (mixed-spec), so the planner waits instead;
//! * queues below [`RebalancePolicy::min_queue`] and no donatable lane;
//! * every candidate lane is near retirement
//!   ([`RebalancePolicy::min_remaining`] — a lane about to free its slots
//!   anyway is not worth the handoff);
//! * the donor holds a single lane and an empty queue (moving its only
//!   work is zero-sum: it idles the donor to busy the thief) — unless
//!   that lane is **wide** (≥ 2 in-flight rows), in which case it splits
//!   instead of moving whole.
//!
//! [`Router`]: super::router::Router
//! [`Router::place`]: super::router::Router
//! [`Scheduler::donate_lane`]: super::scheduler::Scheduler::donate_lane
//! [`Scheduler::adopt_lane`]: super::scheduler::Scheduler::adopt_lane
//! [`DonatedLane`]: super::scheduler::DonatedLane

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::server::Server;
use super::telemetry::StatsBoard;

/// When and how aggressively the router rebalances. Defaults are tuned
/// for "always on, never disruptive": a 100 ms cadence is ~10 lock-free
/// board reads per second per shard (channel stats round-trips happen
/// only for a shard with just-submitted, not-yet-ingested work), and
/// the thresholds refuse any move that would not increase parallelism.
#[derive(Debug, Clone, Copy)]
pub struct RebalancePolicy {
    /// Cadence of the background loop. `None` disables the thread
    /// entirely — rebalancing then happens only at submit time (gauge
    /// skew) and on explicit [`Router::rebalance`] calls.
    ///
    /// [`Router::rebalance`]: super::router::Router::rebalance
    pub interval: Option<Duration>,
    /// Minimum queued requests on the donor before queued-request
    /// stealing is worth disrupting admission grouping (a 1-deep queue
    /// admits at the next boundary anyway).
    pub min_queue: usize,
    /// Minimum *remaining* denoiser calls for a lane to be donated.
    /// Near-retirement lanes free their slots in a tick or two; moving
    /// them buys nothing.
    pub min_remaining: usize,
    /// Enable in-flight lane donation (stage 2). With `false` the
    /// rebalancer only ever steals queued requests.
    pub donate_lanes: bool,
}

impl Default for RebalancePolicy {
    fn default() -> Self {
        RebalancePolicy {
            interval: Some(Duration::from_millis(100)),
            min_queue: 2,
            min_remaining: 2,
            donate_lanes: true,
        }
    }
}

impl RebalancePolicy {
    /// No background thread: rebalancing only at submit time and on
    /// explicit [`Router::rebalance`] calls — the pre-PR-5 behaviour,
    /// useful for tests that pin exact steal counts.
    ///
    /// [`Router::rebalance`]: super::router::Router::rebalance
    pub fn manual() -> Self {
        RebalancePolicy { interval: None, ..RebalancePolicy::default() }
    }
}

/// What the planner sees of one shard — a pure-data snapshot, so [`plan`]
/// is testable without servers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardView {
    /// Queued (not yet admitted) requests, all priorities.
    pub queued: usize,
    /// In-flight lanes (co-admitted groups) on the shard's scheduler.
    pub lanes: usize,
    /// In-flight sequences (sum of lane widths). `in_flight >= 2` with
    /// `lanes == 1` is the lane-splitting opportunity: one wide lane
    /// that whole-lane donation would refuse as zero-sum.
    pub in_flight: usize,
    /// The router's load gauge: outstanding (submitted, not yet
    /// terminal) requests routed to this shard. `0` means idle — safe to
    /// adopt a lane without mixing spec keys.
    pub load: usize,
    /// `false` when the shard cannot serve (`ServerStats::healthy`):
    /// its engine failed to build, a failover restart failed, or its
    /// circuit breaker is currently open. Such a shard must be neither
    /// donor nor thief — its zeroed/frozen gauges would otherwise make
    /// it look like a perfect idle shard and every donation to it would
    /// strand (or fail) the moved requests.
    ///
    /// [`ServerStats::healthy`]: super::server::ServerStats
    pub healthy: bool,
    /// `true` while the shard's circuit breaker is open
    /// (`ServerStats::breaker_open`): its scheduler is parked at a
    /// boundary and [`plan_supervision`] should salvage its work and
    /// restart its engine. Always `false` when `healthy` — and also
    /// `false` for init-dead shards, which are beyond supervision.
    pub breaker_open: bool,
}

/// One lane's donation cost-model inputs (see [`pick_donation`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneCost {
    /// Denoiser calls the lane still needs: `total_events()` minus the
    /// event-ladder cursor — exact, because 𝒯 is predetermined.
    pub remaining: usize,
    /// Sequences in the lane.
    pub width: usize,
}

/// The single corrective action of one rebalance pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Move up to `max` queued same-key requests from `donor`'s queue to
    /// `thief` (PR 4's boundary-granular stealing).
    StealQueued { donor: usize, thief: usize, max: usize },
    /// Ask `donor` to pack one in-flight lane at its next boundary and
    /// ship it to `thief`, which resumes it mid-schedule.
    DonateLane { donor: usize, thief: usize },
    /// Ask `donor` to split its widest in-flight lane at its next
    /// boundary: the back half of the rows ship to `thief`, the front
    /// half keep serving on `donor`.
    SplitLane { donor: usize, thief: usize },
}

/// The decision policy: map shard snapshots to at most one [`Action`].
///
/// Stealing queued work is always preferred over donating a lane — it
/// moves requests that haven't consumed any denoiser calls yet. Lane
/// donation is the fallback for the in-flight-only imbalance stealing
/// cannot touch. Exactly one action per pass keeps the pass cheap and
/// lets the next snapshot observe the result before moving more.
pub fn plan(views: &[ShardView], policy: &RebalancePolicy) -> Option<Action> {
    if views.len() < 2 {
        return None;
    }
    // The thief must be idle: its scheduler has drained, so adopting a
    // lane (or a stolen run) cannot put a second spec key in flight.
    // A busy-but-underloaded shard is *not* a thief — refusing here is
    // the planner's mixed-spec guard. All three gauges must read zero:
    // the load gauge alone is blind to requests submitted directly to a
    // shard (no router gauge), which `queued`/`lanes` — ground truth
    // from the scheduler — still see.
    let thief = (0..views.len()).find(|&i| {
        views[i].healthy && views[i].load == 0 && views[i].queued == 0 && views[i].lanes == 0
    })?;

    // stage 1: queued-request stealing from the deepest queue (an
    // unhealthy shard has nothing real to steal — its queue only drains
    // to Failed)
    let donor = (0..views.len())
        .filter(|&i| i != thief && views[i].healthy)
        .max_by_key(|&i| views[i].queued)?;
    if views[donor].queued >= policy.min_queue {
        return Some(Action::StealQueued {
            donor,
            thief,
            max: views[donor].queued.div_ceil(2),
        });
    }

    // stage 2: in-flight lane donation. A donor can give a lane away
    // only if doing so increases parallelism: either a second lane keeps
    // it busy, or a queued request admits into the freed capacity.
    if !policy.donate_lanes {
        return None;
    }
    if let Some(donor) = (0..views.len())
        .filter(|&i| i != thief && views[i].healthy)
        .filter(|&i| views[i].lanes >= 2 || (views[i].lanes >= 1 && views[i].queued >= 1))
        .max_by_key(|&i| views[i].load)
    {
        return Some(Action::DonateLane { donor, thief });
    }

    // stage 3: lane splitting — the fallback for the shape stage 2 just
    // refused: a single wide lane with nothing queued. Splitting keeps
    // the donor serving the front half, so it is never zero-sum; it only
    // needs a lane with ≥ 2 in-flight rows to carve.
    let donor = (0..views.len())
        .filter(|&i| i != thief && views[i].healthy)
        .filter(|&i| views[i].lanes >= 1 && views[i].in_flight >= 2)
        .max_by_key(|&i| views[i].load)?;
    Some(Action::SplitLane { donor, thief })
}

/// The lane-level cost model: which in-flight lane should a donor give
/// away? The lane with the most **remaining** denoiser calls moves — it
/// transfers the most future work per handoff — with width as the
/// tie-break (more sequences moved). Lanes below `min_remaining` (floored
/// at 1: a finished lane cannot be resumed) are refused as
/// near-retirement.
pub fn pick_donation(costs: &[LaneCost], min_remaining: usize) -> Option<usize> {
    let floor = min_remaining.max(1);
    costs
        .iter()
        .enumerate()
        .filter(|(_, c)| c.remaining >= floor)
        .max_by_key(|&(_, c)| (c.remaining, c.width))
        .map(|(i, _)| i)
}

/// The supervision decision, pure like [`plan`]: pair every **broken**
/// shard — circuit breaker open, lanes parked at a boundary — with the
/// least-loaded healthy shard that should adopt its salvaged work.
/// Init-dead shards (`healthy: false` with the breaker closed) are
/// skipped: they hold nothing to salvage and have no engine to restart.
/// With no healthy shard at all there is nowhere to salvage **to**, so
/// every pairing is deferred (the parked work stays byte-exactly
/// resumable where it is).
pub fn plan_supervision(views: &[ShardView]) -> Vec<(usize, usize)> {
    let target = (0..views.len())
        .filter(|&i| views[i].healthy)
        .min_by_key(|&i| views[i].load);
    let Some(target) = target else {
        return Vec::new();
    };
    (0..views.len())
        .filter(|&i| views[i].breaker_open)
        .map(|broken| (broken, target))
        .collect()
}

/// A shard as the rebalancer addresses it: the cloneable server handle,
/// the router's load gauge, and the shard's lock-free stats board —
/// what passes read instead of making `Msg::Stats` round-trips.
#[derive(Clone)]
pub(crate) struct ShardHandle {
    pub(crate) server: Server,
    pub(crate) load: Arc<AtomicUsize>,
    pub(crate) board: Arc<StatsBoard>,
}

/// Snapshot one shard into the planner's pure view (channel-stats path).
fn shard_view(st: &super::server::ServerStats, sh: &ShardHandle) -> ShardView {
    ShardView {
        queued: (st.queued_low + st.queued_normal + st.queued_high) as usize,
        lanes: st.lanes as usize,
        in_flight: st.in_flight as usize,
        load: sh.load.load(Ordering::Relaxed),
        healthy: st.healthy,
        breaker_open: st.breaker_open,
    }
}

/// Gather every shard's [`ShardView`] for one pass. The steady-state
/// path is lock-free: the shard's engine loop publishes its gauges to
/// the [`StatsBoard`] on every tick, and this just reads atomics — a
/// breaker-parked or dead shard can no longer stall supervision (its
/// loop published `healthy: false` / its failure path published a final
/// snapshot before parking). The one exception is a shard whose board
/// is behind its own submit queue ([`StatsBoard::has_unseen_submits`]):
/// only for that shard the pass pays one channel `stats()` round-trip,
/// whose reply — answered after the queued `Msg::Req`s — re-syncs the
/// board and preserves submit→view ordering for manual `rebalance()`
/// callers. Errors only when that fallback shard is gone (shutdown).
pub(crate) fn collect_views(shards: &[ShardHandle]) -> Result<Vec<ShardView>> {
    let mut views = Vec::with_capacity(shards.len());
    for sh in shards {
        if sh.board.alive() && sh.board.has_unseen_submits() {
            views.push(shard_view(&sh.server.stats()?, sh));
        } else {
            let v = sh.board.view();
            views.push(ShardView {
                queued: v.queued,
                lanes: v.lanes,
                in_flight: v.in_flight,
                load: sh.load.load(Ordering::Relaxed),
                healthy: v.healthy,
                breaker_open: v.breaker_open,
            });
        }
    }
    Ok(views)
}

/// One supervision pass (shard failover): snapshot every shard from its
/// board ([`collect_views`] — a parked shard can no longer stall the
/// pass), [`plan_supervision`], and for each broken shard dispatch the
/// two failover stages — salvage (queued requests + parked lanes move
/// to the target, byte-exactly) then an engine restart from the
/// retained factory. Both are fire-and-forget boundary-granular
/// messages; a shard whose breaker closed on its own in the meantime
/// ignores them. Returns how many broken shards were acted on. Errors
/// only when a shard is gone (shutdown) — callers treat that as "stop",
/// not a failure.
pub(crate) fn supervise_pass(shards: &[ShardHandle]) -> Result<usize> {
    let views = collect_views(shards)?;
    let pairs = plan_supervision(&views);
    for &(broken, target) in &pairs {
        shards[broken]
            .server
            .evacuate_into(&shards[target].server, shards[target].load.clone());
        shards[broken].server.restart_engine();
    }
    Ok(pairs.len())
}

/// One rebalance pass: snapshot every shard (board read + load gauge,
/// no channel round-trips at steady state — see [`collect_views`]),
/// [`plan`], dispatch. Returns the action taken, if any. Errors only
/// when a shard is gone (shutdown) — callers treat that as "stop
/// rebalancing", not a failure.
pub(crate) fn run_pass(
    shards: &[ShardHandle],
    policy: &RebalancePolicy,
) -> Result<Option<Action>> {
    let views = collect_views(shards)?;
    let action = plan(&views, policy);
    match action {
        Some(Action::StealQueued { donor, thief, max }) => {
            shards[donor].server.steal_into(
                max,
                &shards[thief].server,
                shards[thief].load.clone(),
            );
        }
        Some(Action::DonateLane { donor, thief }) => {
            shards[donor].server.donate_lane_into(
                &shards[thief].server,
                shards[thief].load.clone(),
                policy.min_remaining,
            );
        }
        Some(Action::SplitLane { donor, thief }) => {
            shards[donor].server.split_lane_into(
                &shards[thief].server,
                shards[thief].load.clone(),
                policy.min_remaining,
            );
        }
        None => {}
    }
    Ok(action)
}

/// Handle to the background rebalance thread. Stops (and joins) the
/// thread on drop; [`Router::shutdown`] stops it explicitly first so
/// shard drains are never raced by a late pass.
///
/// [`Router::shutdown`]: super::router::Router::shutdown
pub(crate) struct RebalancerGuard {
    stop: Arc<(Mutex<bool>, Condvar)>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl RebalancerGuard {
    /// Signal the loop to exit; returns without joining.
    pub(crate) fn stop(&self) {
        let (lock, cv) = &*self.stop;
        *lock.lock().unwrap_or_else(PoisonError::into_inner) = true;
        cv.notify_all();
    }
}

impl Drop for RebalancerGuard {
    fn drop(&mut self) {
        self.stop();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Start the background loop: every `policy.interval`, run one pass.
/// Returns `None` (no thread) when the policy is manual or there is
/// nothing to balance between (< 2 shards).
pub(crate) fn spawn_background(
    shards: Vec<ShardHandle>,
    policy: RebalancePolicy,
) -> Option<RebalancerGuard> {
    let interval = policy.interval?;
    if shards.len() < 2 {
        return None;
    }
    let stop: Arc<(Mutex<bool>, Condvar)> = Arc::new((Mutex::new(false), Condvar::new()));
    let stop2 = stop.clone();
    let handle = std::thread::spawn(move || {
        let (lock, cv) = &*stop2;
        loop {
            // sleep out one interval, waking early only on stop
            let deadline = Instant::now() + interval;
            let mut stopped = lock.lock().unwrap_or_else(PoisonError::into_inner);
            while !*stopped {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                let (g, _) =
                    cv.wait_timeout(stopped, left).unwrap_or_else(PoisonError::into_inner);
                stopped = g;
            }
            if *stopped {
                return;
            }
            drop(stopped);
            // supervision first: a broken shard's parked work must move
            // before the rebalance planner reasons about load (a parked
            // shard reports healthy: false and is invisible to it)
            if supervise_pass(&shards).is_err() || run_pass(&shards, &policy).is_err() {
                // a shard is gone: the router is shutting down
                return;
            }
        }
    });
    Some(RebalancerGuard { stop, handle: Some(handle) })
}

#[cfg(test)]
mod tests {
    use super::*;

    // in_flight defaults to `lanes` (one width-1 row per lane): the
    // narrowest possible lanes, which never qualify for splitting
    fn v(queued: usize, lanes: usize, load: usize) -> ShardView {
        ShardView { queued, lanes, in_flight: lanes, load, healthy: true, breaker_open: false }
    }

    fn idle() -> ShardView {
        v(0, 0, 0)
    }

    #[test]
    fn plan_prefers_stealing_queued_work() {
        let views = [v(5, 1, 6), idle()];
        assert_eq!(
            plan(&views, &RebalancePolicy::default()),
            Some(Action::StealQueued { donor: 0, thief: 1, max: 3 }),
            "deep queue → steal (ceil(5/2) = 3), even though a lane is donatable"
        );
    }

    #[test]
    fn plan_donates_a_lane_when_queues_are_shallow() {
        // two lanes in flight, nothing queued: stealing has nothing to
        // take, but a lane can move
        let views = [v(0, 2, 4), idle()];
        assert_eq!(
            plan(&views, &RebalancePolicy::default()),
            Some(Action::DonateLane { donor: 0, thief: 1 })
        );
        // one lane + one queued request: donating frees capacity the
        // queued request admits into
        let views = [v(1, 1, 2), idle()];
        assert_eq!(
            plan(&views, &RebalancePolicy::default()),
            Some(Action::DonateLane { donor: 0, thief: 1 })
        );
    }

    #[test]
    fn plan_splits_a_single_wide_lane_instead_of_idling() {
        let policy = RebalancePolicy::default();
        // one wide lane, empty queue: whole-lane donation is zero-sum,
        // but the lane's rows can split across both shards
        let wide = ShardView { in_flight: 4, load: 4, ..v(0, 1, 0) };
        assert_eq!(
            plan(&[wide, idle()], &policy),
            Some(Action::SplitLane { donor: 0, thief: 1 })
        );
        // a width-1 lane has nothing to carve — still refused
        assert_eq!(plan(&[v(0, 1, 1), idle()], &policy), None);
        // splitting rides the same knob as donation
        let off = RebalancePolicy { donate_lanes: false, ..policy };
        assert_eq!(plan(&[wide, idle()], &off), None);
    }

    #[test]
    fn plan_refuses_zero_sum_and_busy_thieves() {
        let policy = RebalancePolicy::default();
        // single *narrow* lane, empty queue: moving the only work is
        // zero-sum, and a width-1 lane cannot split
        let views = [v(0, 1, 1), idle()];
        assert_eq!(plan(&views, &policy), None);
        // no idle shard: adopting would mix spec keys — refuse
        let views = [
            v(0, 2, 4),
            v(0, 1, 1),
        ];
        assert_eq!(plan(&views, &policy), None);
        // a queued- or lane-holding-but-gaugeless shard (direct submits
        // bypass the router's load gauge) is not idle either
        let views = [
            v(0, 2, 4),
            v(1, 0, 0),
        ];
        assert_eq!(plan(&views, &policy), None);
        let views = [
            v(0, 2, 4),
            v(0, 1, 0),
        ];
        assert_eq!(plan(&views, &policy), None);
        // single shard / empty cluster
        assert_eq!(plan(&[idle()], &policy), None);
        assert_eq!(plan(&[], &policy), None);
    }

    #[test]
    fn plan_never_uses_an_unhealthy_shard() {
        let policy = RebalancePolicy::default();
        // a failed-engine shard reports all-zero gauges but healthy =
        // false: it must not be chosen as the thief (donating to it
        // would fail every moved request)...
        let dead = ShardView { healthy: false, ..idle() };
        let views = [v(5, 1, 6), dead];
        assert_eq!(plan(&views, &policy), None);
        // ...nor as a donor (its queue only drains to Failed)
        let dead_busy = ShardView { queued: 9, healthy: false, ..idle() };
        let views = [dead_busy, idle(), v(2, 1, 3)];
        assert_eq!(
            plan(&views, &policy),
            Some(Action::StealQueued { donor: 2, thief: 1, max: 1 }),
            "the healthy 2-deep queue wins over the dead 9-deep one"
        );
    }

    #[test]
    fn plan_respects_donate_lanes_and_min_queue_knobs() {
        let policy =
            RebalancePolicy { donate_lanes: false, ..RebalancePolicy::default() };
        let views = [v(0, 2, 4), idle()];
        assert_eq!(plan(&views, &policy), None, "donation disabled");

        let policy = RebalancePolicy { min_queue: 4, ..RebalancePolicy::default() };
        let views = [v(3, 0, 3), idle()];
        assert_eq!(plan(&views, &policy), None, "queue below min_queue, no lanes");
    }

    #[test]
    fn plan_picks_deepest_donor_and_idle_thief() {
        let views = [
            v(2, 1, 3),
            idle(),
            v(6, 1, 7),
        ];
        assert_eq!(
            plan(&views, &RebalancePolicy::default()),
            Some(Action::StealQueued { donor: 2, thief: 1, max: 3 })
        );
    }

    #[test]
    fn supervision_pairs_broken_shards_with_the_least_loaded_healthy_one() {
        // a breaker-open shard reports healthy: false (it can't serve)
        // and breaker_open: true (it is salvageable + restartable)
        let parked = ShardView { healthy: false, breaker_open: true, ..v(1, 2, 3) };
        let views = [parked, v(0, 1, 5), v(0, 0, 1)];
        assert_eq!(plan_supervision(&views), vec![(0, 2)]);
        // two broken shards both salvage to the same best target
        let views = [parked, parked, v(0, 0, 1)];
        assert_eq!(plan_supervision(&views), vec![(0, 2), (1, 2)]);
    }

    #[test]
    fn supervision_skips_init_dead_shards_and_defers_without_a_target() {
        // init-dead (factory failed at startup): healthy false, breaker
        // closed — nothing to salvage, no engine to restart
        let dead = ShardView { healthy: false, ..idle() };
        assert!(plan_supervision(&[dead, idle()]).is_empty());
        // a broken shard with no healthy shard anywhere: nowhere to
        // salvage to — defer, the parked work stays resumable in place
        let parked = ShardView { healthy: false, breaker_open: true, ..idle() };
        assert!(plan_supervision(&[parked, dead]).is_empty());
        assert!(plan_supervision(&[]).is_empty());
    }

    #[test]
    fn pick_donation_maximizes_remaining_work() {
        let costs = [
            LaneCost { remaining: 3, width: 2 },
            LaneCost { remaining: 9, width: 1 },
            LaneCost { remaining: 9, width: 4 },
            LaneCost { remaining: 1, width: 8 },
        ];
        assert_eq!(pick_donation(&costs, 2), Some(2), "ties broken by width");
        assert_eq!(pick_donation(&costs, 10), None, "all below the floor");
        assert_eq!(pick_donation(&[], 0), None);
        // floor clamps to 1: a lane with zero remaining events cannot move
        assert_eq!(pick_donation(&[LaneCost { remaining: 0, width: 2 }], 0), None);
    }
}
