//! Sharded serving frontend: one [`ServeBuilder`] entry point for both
//! scheduling modes, fanned out over N independent server threads
//! (engines) with spec-affinity placement.
//!
//! Each shard is a full [`Server`] — its own thread, engine, queue, and
//! scheduler (PJRT handles are thread-bound, so sharding by thread is the
//! natural unit). The [`Router`] places each [`GenRequest`] by:
//!
//! 1. **Spec affinity** — requests whose [`SpecKey`] (sampler kind, steps,
//!    𝒟_τ, order, temperature, shared-τ) matches a key recently routed
//!    prefer the same shard, maximizing the scheduler's shared-𝒯 batching
//!    (a lane only amortizes denoiser calls across requests with equal
//!    keys, so scattering one spec over all shards wastes the paper's
//!    |𝒯|-per-batch property).
//! 2. **Least-loaded fallback** — a new key (or an affinity shard whose
//!    outstanding load exceeds twice the least-loaded shard's, plus one)
//!    goes to the shard with the fewest outstanding requests; ties rotate
//!    round-robin so idle shards share cold starts.
//!
//! Outstanding load is tracked per shard and decremented exactly once when
//! a request reaches its terminal event (the ticket sink owns the
//! decrement, so cancelled / expired / failed requests release their load
//! the same way completed ones do).
//!
//! Placement alone can strand work: load balances at submit time, but a
//! shard serving a slow spec keeps a deep queue while a neighbour drains
//! to idle — and no new submissions means no re-placement. Rebalancing
//! closes that gap (policy and cost model in
//! [`rebalancer`](super::rebalancer); semantics in
//! `docs/rebalancing.md`), with two movements:
//!
//! * **Queued-request stealing** — the shard with the deepest queue
//!   donates up to half of it to an idle shard, at boundary granularity
//!   (the donor pops requests between two denoiser calls) and with
//!   `SpecKey` affinity preserved — a donation is a single same-key run,
//!   so the thief can still serve it as one shared-𝒯 lane.
//! * **In-flight lane donation** — when queues are shallow but a shard's
//!   in-flight work could be split, a whole live lane moves: the donor
//!   packs the session (state, RNG streams, event-ladder cursor) at a
//!   transition-time boundary and the thief resumes it mid-schedule with
//!   survivor byte-parity — possible only because 𝒯 is predetermined.
//!
//! Moved requests keep their sink, deadline, priority, and enqueue time;
//! their load-gauge accounting follows them. Three triggers share the
//! same planner: a **background cadence loop** owned by the router
//! ([`RebalancePolicy::interval`], on by default, covering traffic
//! lulls), an opportunistic pass from `submit_request` whenever the load
//! gauges show an idle shard next to a loaded one, and explicit
//! [`Router::rebalance`] calls.
//!
//! The same cadence loop supervises **shard failover**
//! (`docs/robustness.md`): a shard whose circuit breaker tripped has its
//! parked lanes salvaged to a healthy shard and its engine rebuilt from
//! the retained factory — [`Router::supervise`] is the manual trigger.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

use anyhow::{anyhow, Result};

use crate::sampler::SamplerConfig;

use super::batcher::BatchPolicy;
use super::engine::{Engine, GenOutput};
use super::rebalancer::{self, RebalancePolicy, RebalancerGuard, ShardHandle};
use super::request::{GenRequest, Ticket};
use super::scheduler::{FaultPolicy, SchedPolicy, SpecKey};
use super::server::{Server, ServerJoin, ServerStats};
use super::telemetry::StatsBoard;

/// Scheduling mode of every shard a [`ServeBuilder`] starts.
#[derive(Debug, Clone, Copy)]
enum ServeMode {
    Fixed(BatchPolicy),
    Continuous(SchedPolicy),
}

/// One builder for the whole serving stack — replaces choosing between
/// `Server::start` and `Server::start_continuous` by hand and adds
/// multi-engine sharding:
///
/// ```no_run
/// use dndm::coordinator::{cipher_mock_engine, GenRequest, ServeBuilder};
/// use dndm::sampler::{SamplerConfig, SamplerKind};
///
/// let router = ServeBuilder::new(
///     || Ok(cipher_mock_engine(16)),
///     SamplerConfig::new(SamplerKind::Dndm, 50),
/// )
/// .shards(2)
/// .start();
/// let out = router.generate(GenRequest::new(7).src("the quick fox")).unwrap();
/// println!("{} (NFE {})", out.text, out.nfe);
/// router.shutdown();
/// ```
///
/// Defaults: continuous scheduling with [`SchedPolicy::default`], one
/// shard. The factory runs once per shard, on that shard's thread.
pub struct ServeBuilder<F> {
    factory: F,
    cfg: SamplerConfig,
    mode: ServeMode,
    shards: usize,
    rebalance: RebalancePolicy,
    fault: FaultPolicy,
}

impl<F> ServeBuilder<F>
where
    F: Fn() -> Result<Engine> + Send + Clone + 'static,
{
    pub fn new(factory: F, cfg: SamplerConfig) -> ServeBuilder<F> {
        ServeBuilder {
            factory,
            cfg,
            mode: ServeMode::Continuous(SchedPolicy::default()),
            shards: 1,
            rebalance: RebalancePolicy::default(),
            fault: FaultPolicy::default(),
        }
    }

    /// Use the legacy fixed-batch policy (the serving bench's ablation
    /// baseline). Tickets still work, but with queue-side lifecycle only —
    /// no per-NFE progress events.
    pub fn fixed(mut self, policy: BatchPolicy) -> Self {
        self.mode = ServeMode::Fixed(policy);
        self
    }

    /// Use the continuous NFE-aligned scheduler (the default) with an
    /// explicit policy.
    pub fn continuous(mut self, policy: SchedPolicy) -> Self {
        self.mode = ServeMode::Continuous(policy);
        self
    }

    /// Number of server threads/engines to shard across (min 1).
    pub fn shards(mut self, n: usize) -> Self {
        self.shards = n.max(1);
        self
    }

    /// Rebalancing policy (cadence + thresholds). The default runs a
    /// background pass every 100 ms on multi-shard continuous routers;
    /// [`RebalancePolicy::manual`] disables the background thread.
    pub fn rebalance(mut self, policy: RebalancePolicy) -> Self {
        self.rebalance = policy;
        self
    }

    /// Retry/breaker [`FaultPolicy`] every continuous shard's scheduler
    /// applies at its denoiser call sites (`docs/robustness.md`).
    /// Ignored in fixed mode, which has no retry machinery.
    pub fn fault_policy(mut self, fault: FaultPolicy) -> Self {
        self.fault = fault;
        self
    }

    /// Start every shard and return the routing frontend.
    pub fn start(self) -> Router {
        let mut shards = Vec::with_capacity(self.shards);
        for _ in 0..self.shards {
            let factory = self.factory.clone();
            let (server, join) = match self.mode {
                ServeMode::Fixed(p) => Server::start(factory, self.cfg.clone(), p),
                ServeMode::Continuous(p) => {
                    Server::start_continuous_with(factory, self.cfg.clone(), p, self.fault)
                }
            };
            shards.push(Shard {
                server,
                load: Arc::new(AtomicUsize::new(0)),
                join: Some(join),
            });
        }
        let continuous = matches!(self.mode, ServeMode::Continuous(_));
        // the background cadence loop only exists where rebalancing can
        // act: multi-shard continuous routers with a non-manual policy
        let rebalancer = if continuous {
            rebalancer::spawn_background(handles_of(&shards), self.rebalance)
        } else {
            None
        };
        Router {
            rebalancer,
            shards,
            affinity: Mutex::new(Vec::new()),
            rr: AtomicUsize::new(0),
            default_cfg: self.cfg,
            continuous,
            rebalance_policy: self.rebalance,
            steal_cooldown: AtomicUsize::new(0),
        }
    }
}

struct Shard {
    server: Server,
    /// outstanding (submitted, not yet terminal) requests on this shard
    load: Arc<AtomicUsize>,
    join: Option<ServerJoin>,
}

/// The shards as the rebalancer addresses them (cheap clones of the
/// server sender + load gauge + stats board).
fn handles_of(shards: &[Shard]) -> Vec<ShardHandle> {
    shards
        .iter()
        .map(|s| ShardHandle {
            server: s.server.clone(),
            load: s.load.clone(),
            board: s.server.board().clone(),
        })
        .collect()
}

/// Keys the router remembers for affinity placement; beyond this the
/// oldest mapping is evicted (plenty for real workloads — distinct specs
/// in flight at once are few).
const AFFINITY_CAP: usize = 64;

/// Submits skipped after a fruitless gauge-triggered rebalance before the
/// gauges are consulted again (each stats pass blocks on every shard's
/// next boundary, so fruitless passes must not run per-submit).
const STEAL_COOLDOWN: usize = 32;

/// The sharding frontend produced by [`ServeBuilder::start`]. Routes each
/// request to a shard (spec affinity, then least-loaded) and exposes the
/// same request surface as a single [`Server`].
pub struct Router {
    // field order is drop order: the background rebalancer joins first
    // (its thread holds server-sender clones), and only then can each
    // `Shard`'s join-on-drop observe its server thread exiting
    /// background cadence loop (`None` for manual policies, fixed mode,
    /// or a single shard)
    rebalancer: Option<RebalancerGuard>,
    shards: Vec<Shard>,
    /// recently routed keys, oldest first (evicted at `AFFINITY_CAP`)
    affinity: Mutex<Vec<(SpecKey, usize)>>,
    /// round-robin cursor for load ties
    rr: AtomicUsize,
    default_cfg: SamplerConfig,
    /// shards run the continuous scheduler (rebalancing requires the
    /// boundary-granular queue; fixed shards neither donate nor steal)
    continuous: bool,
    /// thresholds shared by all three rebalance triggers
    rebalance_policy: RebalancePolicy,
    /// Submits to skip before the next gauge-triggered rebalance attempt.
    /// The load gauges count in-flight + queued, so an in-flight-only
    /// imbalance with nothing movable would otherwise pay the blocking
    /// stats round-trip on *every* submit; a fruitless pass arms this
    /// cooldown.
    steal_cooldown: AtomicUsize,
}

impl Router {
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Direct handle to one shard's server (tests, gradual migration).
    pub fn shard(&self, i: usize) -> &Server {
        &self.shards[i].server
    }

    /// Submit a typed request to the shard chosen by the placement policy;
    /// returns the streaming [`Ticket`]. When the load gauges show an idle
    /// shard next to a loaded one, a work-stealing pass runs first (the
    /// imbalance placement can't fix — queued work stranded behind a slow
    /// shard — is exactly what new-traffic moments should repair).
    pub fn submit_request(&self, req: GenRequest) -> Result<Ticket> {
        self.submit_request_routed(req).map(|(t, _)| t)
    }

    /// [`Self::submit_request`], additionally reporting which shard the
    /// placement policy chose. The network front door's admission control
    /// keys its per-shard queued-cost backlog and wall-µs/NFE EWMA on
    /// this index, so its completion-time projection charges the shard
    /// that actually serves the request.
    pub fn submit_request_routed(&self, req: GenRequest) -> Result<(Ticket, usize)> {
        if self.steal_worthwhile() {
            let _ = self.rebalance();
        }
        let key = SpecKey::of(req.cfg.as_ref().unwrap_or(&self.default_cfg));
        let idx = self.place(&key);
        let load = self.shards[idx].load.clone();
        load.fetch_add(1, Ordering::Relaxed);
        // On failure the sink travels inside the rejected message, is
        // dropped with it, and its drop guard emits the Failed terminal —
        // which performs the exactly-once load decrement. Decrementing
        // here as well would double-count and underflow the gauge.
        self.shards[idx].server.submit_ticketed(req, Some(load)).map(|t| (t, idx))
    }

    /// Submit to an explicitly chosen shard, bypassing the placement
    /// policy. This is the serve half of admission-aware placement
    /// (`docs/tiers.md`): the front door's
    /// [`place_and_charge`](crate::net::Admission::place_and_charge)
    /// picks the shard with the lowest *projected wait* (backlog NFE ×
    /// that shard's EWMA µs/NFE) and charges it, then routes here — so
    /// the charge and the serve land on the same shard by construction
    /// instead of via the peek-then-charge race. The affinity table is
    /// refreshed toward the chosen shard so later same-spec requests
    /// placed by [`Self::submit_request`] keep batching with it.
    pub fn submit_request_to(&self, shard: usize, req: GenRequest) -> Result<Ticket> {
        let n = self.shards.len();
        if shard >= n {
            return Err(anyhow!("shard {shard} out of range ({n} shards)"));
        }
        let key = SpecKey::of(req.cfg.as_ref().unwrap_or(&self.default_cfg));
        {
            let mut aff = self.affinity.lock().unwrap_or_else(PoisonError::into_inner);
            if let Some(pos) = aff.iter().position(|(k, _)| k == &key) {
                aff.remove(pos);
            } else if aff.len() >= AFFINITY_CAP {
                aff.remove(0);
            }
            aff.push((key, shard));
        }
        let load = self.shards[shard].load.clone();
        load.fetch_add(1, Ordering::Relaxed);
        self.shards[shard].server.submit_ticketed(req, Some(load))
    }

    /// Where would [`Self::submit_request`] place this request *right
    /// now*? A pure read: neither the affinity table nor the round-robin
    /// cursor moves, so peeking is free to call on every admission
    /// decision. The answer can go stale the moment other submissions
    /// land — callers (admission control projecting queue wait before
    /// deciding to submit) treat it as the projection shard, not a
    /// reservation.
    pub fn peek_placement(&self, req: &GenRequest) -> usize {
        let n = self.shards.len();
        if n == 1 {
            return 0;
        }
        let key = SpecKey::of(req.cfg.as_ref().unwrap_or(&self.default_cfg));
        let loads: Vec<usize> =
            self.shards.iter().map(|s| s.load.load(Ordering::Relaxed)).collect();
        let least = (0..n).min_by_key(|&i| loads[i]).unwrap_or(0);
        let aff = self.affinity.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some((_, shard)) = aff.iter().find(|(k, _)| k == &key) {
            if loads[*shard] <= 2 * loads[least] + 1 {
                return *shard;
            }
        }
        least
    }

    /// Submit and wait — the blocking convenience.
    pub fn generate(&self, req: GenRequest) -> Result<GenOutput> {
        self.submit_request(req)?.wait()
    }

    /// Pick a shard: spec affinity first, least-loaded (round-robin on
    /// ties) otherwise. Also refreshes the affinity table.
    fn place(&self, key: &SpecKey) -> usize {
        let n = self.shards.len();
        if n == 1 {
            return 0;
        }
        let loads: Vec<usize> =
            self.shards.iter().map(|s| s.load.load(Ordering::Relaxed)).collect();
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        let mut least = start;
        for off in 1..n {
            let i = (start + off) % n;
            if loads[i] < loads[least] {
                least = i;
            }
        }
        let mut aff = self.affinity.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(pos) = aff.iter().position(|(k, _)| k == key) {
            let (k, shard) = aff.remove(pos);
            // affinity holds while the preferred shard isn't overloaded
            // relative to the least-loaded one
            let chosen = if loads[shard] <= 2 * loads[least] + 1 { shard } else { least };
            aff.push((k, chosen));
            return chosen;
        }
        if aff.len() >= AFFINITY_CAP {
            aff.remove(0);
        }
        aff.push((key.clone(), least));
        least
    }

    /// Cheap gauge-only pre-check: is there an idle shard while another
    /// holds enough outstanding work to be worth a stats round-trip? The
    /// gauges include in-flight work, so this over-triggers on lanes with
    /// nothing queued — the cooldown armed by a fruitless [`Self::
    /// rebalance`] keeps that from taxing every submit.
    fn steal_worthwhile(&self) -> bool {
        if self.shards.len() < 2 || !self.continuous {
            return false;
        }
        let cooldown = self.steal_cooldown.load(Ordering::Relaxed);
        if cooldown > 0 {
            self.steal_cooldown.store(cooldown - 1, Ordering::Relaxed);
            return false;
        }
        let mut min = usize::MAX;
        let mut max = 0usize;
        for s in &self.shards {
            let l = s.load.load(Ordering::Relaxed);
            min = min.min(l);
            max = max.max(l);
        }
        min == 0 && max >= self.rebalance_policy.min_queue + 1
    }

    /// One rebalance pass, shared by all three triggers (background
    /// cadence, gauge-triggered submit pass, and this direct call):
    /// snapshot every shard, let [`rebalancer::plan`] pick at most one
    /// action, dispatch it. Stage 1 moves up to half of the deepest
    /// queue (one same-`SpecKey` run, so the idle thief can batch it
    /// into a single shared-𝒯 lane); stage 2 donates one whole
    /// **in-flight** lane at the donor's next boundary, resumed
    /// mid-schedule by the thief (see `docs/rebalancing.md` for the cost
    /// model and the refusal table). Everything moved keeps its sinks,
    /// deadlines, priorities, enqueue times, and load accounting. No-op
    /// with one shard or in fixed mode; the movement itself is
    /// asynchronous — this returns once the donor has been asked.
    pub fn rebalance(&self) -> Result<()> {
        if self.shards.len() < 2 || !self.continuous {
            return Ok(());
        }
        rebalancer::run_pass(&handles_of(&self.shards), &self.rebalance_policy)?;
        // arm the cooldown whatever happened: a fruitless pass must not
        // re-run per submit, and after a move the queues need boundaries
        // to shift before another stats pass can learn anything
        self.steal_cooldown.store(STEAL_COOLDOWN, Ordering::Relaxed);
        Ok(())
    }

    /// One supervision pass (shard failover, `docs/robustness.md`): find
    /// shards whose circuit breaker is open, salvage their work —
    /// queued requests re-enqueue, parked in-flight lanes resume
    /// byte-exactly — onto the least-loaded healthy shard, then ask each
    /// broken shard to rebuild its engine from the retained factory.
    /// Returns how many broken shards were acted on. The background
    /// rebalance loop runs this automatically every cadence tick; call
    /// it directly under [`RebalancePolicy::manual`]. No-op with a
    /// single shard (nowhere to salvage to) or in fixed mode.
    pub fn supervise(&self) -> Result<usize> {
        if self.shards.len() < 2 || !self.continuous {
            return Ok(0);
        }
        rebalancer::supervise_pass(&handles_of(&self.shards))
    }

    /// Merged statistics across shards (see [`ServerStats::merged`] for
    /// the merge semantics); use [`Self::shard_stats`] for the raw
    /// per-shard view.
    pub fn stats(&self) -> Result<ServerStats> {
        Ok(ServerStats::merged(self.shard_stats()?))
    }

    pub fn shard_stats(&self) -> Result<Vec<ServerStats>> {
        self.shards.iter().map(|s| s.server.stats()).collect()
    }

    /// Each shard's lock-free [`StatsBoard`] (index-aligned with
    /// [`Self::shard`]). The network front door reads these directly —
    /// admission's pace projection and the `/metrics` scrape never pay
    /// a channel round-trip.
    pub fn boards(&self) -> Vec<Arc<StatsBoard>> {
        self.shards.iter().map(|s| s.server.board().clone()).collect()
    }

    /// [`Self::stats`] served entirely from the shards' lock-free
    /// boards: same merge semantics, zero `Msg::Stats` round-trips, and
    /// — unlike the channel path — it cannot block on a breaker-parked
    /// or dead shard, whose loop stopped answering messages but whose
    /// board still holds its last published snapshot. The board lags
    /// the channel view only by work the engine has accepted but not
    /// yet reached a publish point for (sub-tick staleness; the two
    /// agree exactly at quiesce — pinned in `tests/scenarios.rs`).
    pub fn board_stats(&self) -> ServerStats {
        ServerStats::merged(self.board_shard_stats())
    }

    /// Per-shard stats from the boards (the non-blocking counterpart of
    /// [`Self::shard_stats`]).
    pub fn board_shard_stats(&self) -> Vec<ServerStats> {
        self.shards.iter().map(|s| s.server.board().snapshot()).collect()
    }

    /// Ask every shard to drain and exit. Follow with [`Self::join`] (or
    /// drop the router) to wait for the threads. Signals the background
    /// rebalancer to stop first; a pass already in flight is harmless —
    /// a donor whose handoff reaches an already-exited thief takes the
    /// work back (re-enqueue / re-adopt) and drains it itself.
    pub fn shutdown(&self) {
        if let Some(r) = &self.rebalancer {
            r.stop();
        }
        for s in &self.shards {
            s.server.shutdown();
        }
    }

    /// Wait for every shard thread to finish. Dropping the router joins
    /// implicitly (each shard's [`ServerJoin`] joins on drop).
    pub fn join(mut self) {
        for s in &mut self.shards {
            if let Some(j) = s.join.take() {
                j.join();
            }
        }
    }
}

impl std::fmt::Debug for Router {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let loads: Vec<usize> =
            self.shards.iter().map(|s| s.load.load(Ordering::Relaxed)).collect();
        f.debug_struct("Router").field("shards", &self.shards.len()).field("loads", &loads).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::cipher_mock_engine;
    use crate::coordinator::request::Event;
    use crate::sampler::{SamplerConfig, SamplerKind};
    use crate::schedule::{AlphaSchedule, TransitionSpec};
    use std::time::Duration;

    fn builder() -> ServeBuilder<impl Fn() -> Result<Engine> + Send + Clone + 'static> {
        ServeBuilder::new(
            || Ok(cipher_mock_engine(8)),
            SamplerConfig::new(SamplerKind::Dndm, 50),
        )
    }

    fn policy() -> SchedPolicy {
        SchedPolicy { max_batch: 4, window: Duration::ZERO, shared_tau_groups: true }
    }

    #[test]
    fn single_shard_roundtrip_via_generate() {
        let router = builder().continuous(policy()).start();
        let out = router
            .generate(GenRequest::new(7).src("the quick fox crosses a river"))
            .unwrap();
        assert!(out.nfe >= 1 && out.nfe <= 8);
        assert!(!out.text.is_empty());
        let stats = router.stats().unwrap();
        assert_eq!(stats.requests, 1);
        router.shutdown();
        router.join();
    }

    #[test]
    fn same_spec_keeps_affinity_to_one_shard() {
        let router = builder().continuous(policy()).shards(2).start();
        for i in 0..3 {
            router
                .generate(GenRequest::new(i).src("the quick fox"))
                .unwrap();
        }
        let per_shard = router.shard_stats().unwrap();
        let reqs: Vec<u64> = per_shard.iter().map(|s| s.requests).collect();
        assert_eq!(reqs.iter().sum::<u64>(), 3);
        assert!(
            reqs.contains(&3),
            "one shard must own the whole spec (affinity), got {reqs:?}"
        );
        router.shutdown();
        router.join();
    }

    #[test]
    fn distinct_specs_spread_over_idle_shards() {
        let router = builder().continuous(policy()).shards(2).start();
        let spec_b = SamplerConfig::new(SamplerKind::DndmC, 0)
            .with_spec(TransitionSpec::Exact(AlphaSchedule::Linear));
        router.generate(GenRequest::new(1).src("the quick fox")).unwrap();
        router
            .generate(GenRequest::new(2).src("the quick fox").config(spec_b))
            .unwrap();
        let per_shard = router.shard_stats().unwrap();
        let reqs: Vec<u64> = per_shard.iter().map(|s| s.requests).collect();
        assert_eq!(reqs, vec![1, 1], "two keys, two idle shards → one each");
        router.shutdown();
        router.join();
    }

    #[test]
    fn fixed_mode_router_serves_tickets() {
        let router = builder()
            .fixed(BatchPolicy { max_batch: 2, window: Duration::from_millis(5) })
            .start();
        let mut t = router
            .submit_request(GenRequest::new(3).src("a small garden"))
            .unwrap();
        let mut saw_done = false;
        while let Some(ev) = t.next_event() {
            match ev {
                Event::Admitted { .. } => {}
                Event::Done(out) => {
                    assert!(!out.tokens.is_empty());
                    saw_done = true;
                }
                Event::Progress { .. } => panic!("fixed mode has no boundaries"),
                other => panic!("unexpected {other:?}"),
            }
        }
        assert!(saw_done);
        router.shutdown();
        router.join();
    }

    #[test]
    fn rebalance_steals_queued_work_for_an_idle_shard() {
        // capacity 1 so the donor can hold at most one request in flight
        // and the rest stay visibly queued
        let narrow = SchedPolicy {
            max_batch: 1,
            window: Duration::ZERO,
            shared_tau_groups: true,
        };
        // manual policy: the background loop would also steal/donate and
        // make the exact counts below timing-dependent
        let router = builder()
            .continuous(narrow)
            .shards(2)
            .rebalance(RebalancePolicy::manual())
            .start();
        // pile work directly onto shard 0 (bypassing placement, like a
        // burst that landed before its neighbour existed); a slow spec
        // keeps the donor busy long enough that the queue is still there
        // when the steal lands
        let slow = SamplerConfig::new(SamplerKind::D3pm, 3000);
        let mut tickets = Vec::new();
        for i in 0..4 {
            let req = GenRequest::new(i)
                .src("the quick fox")
                .config(slow.clone());
            tickets.push(router.shard(0).submit_request(req).unwrap());
        }
        // shard 0: 1 in flight (max_batch 1) + 3 queued; shard 1 idle
        router.rebalance().unwrap();
        for t in tickets {
            t.wait().unwrap();
        }
        let per_shard = router.shard_stats().unwrap();
        assert_eq!(per_shard[0].stolen, 2, "donor gave away half its queue");
        assert!(
            per_shard[1].nn_calls >= 3000,
            "thief served at least one stolen request: {} calls",
            per_shard[1].nn_calls
        );
        // nothing lost, nothing double-served: 4 requests × 3000 calls
        assert_eq!(per_shard[0].nn_calls + per_shard[1].nn_calls, 4 * 3000);
        let merged = router.stats().unwrap();
        assert_eq!(merged.stolen, 2);
        assert_eq!(merged.queued_low + merged.queued_normal + merged.queued_high, 0);
        router.shutdown();
        router.join();
    }

    #[test]
    fn submit_request_to_targets_the_exact_shard_and_refreshes_affinity() {
        let router = builder().continuous(policy()).shards(2).start();
        let out = router
            .submit_request_to(1, GenRequest::new(5).src("the quick fox"))
            .unwrap()
            .wait()
            .unwrap();
        assert!(out.nfe >= 1);
        // the explicit placement refreshed affinity: the same spec now
        // prefers shard 1 through the normal placement path too
        router.generate(GenRequest::new(6).src("the quick fox")).unwrap();
        let per_shard = router.shard_stats().unwrap();
        let reqs: Vec<u64> = per_shard.iter().map(|s| s.requests).collect();
        assert_eq!(reqs, vec![0, 2], "explicit shard serves; affinity follows it");
        assert!(router.submit_request_to(9, GenRequest::new(7)).is_err());
        router.shutdown();
        router.join();
    }

    #[test]
    fn rebalance_is_a_no_op_for_fixed_mode_and_single_shard() {
        let router = builder()
            .fixed(BatchPolicy { max_batch: 2, window: Duration::from_millis(1) })
            .shards(2)
            .start();
        router.rebalance().unwrap();
        assert_eq!(router.stats().unwrap().stolen, 0);
        router.shutdown();
        router.join();

        let router = builder().continuous(policy()).start();
        router.rebalance().unwrap();
        assert_eq!(router.stats().unwrap().stolen, 0);
        router.shutdown();
        router.join();
    }

    #[test]
    fn merged_stats_accumulate_counters() {
        let router = builder().continuous(policy()).shards(2).start();
        for i in 0..4 {
            router.generate(GenRequest::new(i).src("the quick fox")).unwrap();
        }
        let merged = router.stats().unwrap();
        assert_eq!(merged.requests, 4);
        assert!(merged.nn_calls >= 1);
        assert!(merged.avg_request_nfe >= 1.0);
        router.shutdown();
        router.join();
    }
}
