//! Lock-free per-shard telemetry: the [`StatsBoard`].
//!
//! Before this module, every consumer of a shard's statistics — the
//! rebalancer's per-pass [`ShardView`](super::rebalancer::ShardView)s,
//! the network front door's `/metrics` scrape and `/healthz` probe —
//! paid a `Msg::Stats` **channel round-trip** into the shard's message
//! loop. That loop answers between two denoiser calls, so every reader
//! serialized behind the engine; worse, a breaker-parked shard only
//! polls its channel every `QUEUE_POLL`, and a *dead* shard answers
//! from its drain-and-fail loop — a scrape could block on exactly the
//! shard an operator most wants to observe.
//!
//! The board inverts the flow: the engine thread **publishes** into
//! shared atomics at every tick and terminal, and readers load them
//! with zero coordination:
//!
//! * **counters** (requests, nn_calls, faults, …) are monotonic
//!   `AtomicU64`s — either incremented in place on the publishing
//!   thread or overwritten with a monotonically-growing absolute from
//!   the engine's own tally, so a reader can never observe a decrease;
//! * **gauges** (queue depths, lanes, in-flight, occupancy) are relaxed
//!   single-word stores — instantaneous values where torn *sets* across
//!   words are acceptable and torn *words* are impossible;
//! * **multi-word snapshots** that must be mutually consistent — the
//!   pace pair (EWMA µs/NFE + in-flight backlog) that admission
//!   projects wait times from, and the queue/e2e latency digests — go
//!   through a [`SeqCell`], a seqlock-style epoch pair: the writer
//!   flips the epoch odd, stores the words, flips it even; a reader
//!   retries while the epoch is odd or changed across its loads. All
//!   payload words are themselves atomics, so the retry loop is safe
//!   Rust with no UB — a torn read is *detected*, never *returned*.
//!
//! The one non-atomic member is the per-tenant submit map, behind a
//! `Mutex` held only for O(log n) map operations on the submit path and
//! a clone at snapshot time — never across a denoiser call, a park, or
//! a backoff, so readers may briefly spin but can never block on a
//! stuck shard.
//!
//! **Freshness.** Publishes happen at the end of every loop iteration
//! (after `tick()` delivered its retirements) and before every channel
//! `Msg::Stats` reply, so the board is never staler than one boundary
//! behind the loop — and a channel `stats()` reply doubles as a board
//! sync barrier. For callers that race the loop's wakeup (submit, then
//! immediately plan a rebalance), [`StatsBoard::has_unseen_submits`]
//! compares client-side sends against engine-side ingests published
//! with the same tick: the rebalancer falls back to one channel
//! round-trip for exactly the shards that still have submits in their
//! channel, which at steady state is none (`tests/scenarios.rs` pins
//! zero `Msg::Stats` round-trips via [`StatsBoard::stats_rpcs`]).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Duration;

use crate::metrics::LatencySnapshot;

use super::server::ServerStats;

/// Smoothing factor for the board's measured pace EWMA — the same
/// default as `AdmissionPolicy::ewma_alpha`, but tracked engine-side
/// from actual terminal `(served NFE, generation time)` pairs instead
/// of front-door observations.
const PACE_EWMA_ALPHA: f64 = 0.2;

/// A seqlock-style cell of `N` words that a reader can snapshot
/// consistently without blocking the writer.
///
/// The epoch is even when the payload is stable and odd while a write
/// is in flight. Writers enter by CASing the even epoch to odd —
/// production has a single writer (the shard's engine thread), but the
/// CAS entry makes concurrent writers safe too (they serialize on the
/// epoch, each write remains internally consistent). Readers load the
/// epoch, load every word, and re-load the epoch: any write that
/// overlapped is detected and the read retries. Every word is an
/// `AtomicU64`, so the optimistic read races on nothing.
pub struct SeqCell<const N: usize> {
    epoch: AtomicU64,
    words: [AtomicU64; N],
}

impl<const N: usize> Default for SeqCell<N> {
    fn default() -> Self {
        SeqCell { epoch: AtomicU64::new(0), words: std::array::from_fn(|_| AtomicU64::new(0)) }
    }
}

impl<const N: usize> SeqCell<N> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Publish `words` as one consistent snapshot.
    pub fn write(&self, words: [u64; N]) {
        self.write_paced(words, || {});
    }

    /// [`Self::write`] with a hook between the odd flip and the payload
    /// stores — the zero-cost production path passes a no-op; tests
    /// pass a pause to hold the cell observably mid-write and pin the
    /// reader's retry path deterministically.
    fn write_paced(&self, words: [u64; N], mid: impl FnOnce()) {
        let mut entered = self.epoch.load(Ordering::Acquire);
        loop {
            if entered % 2 == 0 {
                match self.epoch.compare_exchange_weak(
                    entered,
                    entered + 1,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => break,
                    Err(seen) => entered = seen,
                }
            } else {
                std::hint::spin_loop();
                entered = self.epoch.load(Ordering::Acquire);
            }
        }
        mid();
        for (w, v) in self.words.iter().zip(words) {
            w.store(v, Ordering::Release);
        }
        self.epoch.store(entered + 2, Ordering::Release);
    }

    /// A consistent snapshot of the cell's words.
    pub fn read(&self) -> [u64; N] {
        self.read_counting().0
    }

    /// [`Self::read`] plus the number of retries the optimistic loop
    /// took — the concurrency tests use it to prove the odd/even
    /// detection path actually ran.
    pub fn read_counting(&self) -> ([u64; N], u64) {
        let mut retries = 0u64;
        loop {
            let before = self.epoch.load(Ordering::Acquire);
            if before % 2 == 1 {
                retries += 1;
                std::hint::spin_loop();
                continue;
            }
            let mut out = [0u64; N];
            for (o, w) in out.iter_mut().zip(&self.words) {
                *o = w.load(Ordering::Acquire);
            }
            if self.epoch.load(Ordering::Acquire) == before {
                return (out, retries);
            }
            retries += 1;
        }
    }
}

/// Encode a [`LatencySnapshot`] into a [`SeqCell<8>`] word array
/// (durations as whole microseconds — exactly the resolution
/// `LatencyStats` records at, so the round-trip is lossless).
fn latency_words(s: &LatencySnapshot) -> [u64; 8] {
    [
        s.count,
        s.mean.as_micros() as u64,
        s.p50.as_micros() as u64,
        s.p95.as_micros() as u64,
        s.p99.as_micros() as u64,
        s.p999.as_micros() as u64,
        s.min.as_micros() as u64,
        s.max.as_micros() as u64,
    ]
}

fn latency_from_words(w: [u64; 8]) -> LatencySnapshot {
    LatencySnapshot {
        count: w[0],
        mean: Duration::from_micros(w[1]),
        p50: Duration::from_micros(w[2]),
        p95: Duration::from_micros(w[3]),
        p99: Duration::from_micros(w[4]),
        p999: Duration::from_micros(w[5]),
        min: Duration::from_micros(w[6]),
        max: Duration::from_micros(w[7]),
    }
}

/// The alloc-free subset of a shard's gauges that the rebalancer's
/// planner reads every pass (`ShardView` minus the router-side load
/// gauge, which lives on the `ShardHandle`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BoardView {
    /// queued requests across all three priority classes
    pub queued: usize,
    /// in-flight lanes
    pub lanes: usize,
    /// in-flight sequences (sum of lane widths)
    pub in_flight: usize,
    pub healthy: bool,
    pub breaker_open: bool,
}

/// The admission-facing pace pair, read as one consistent seqlock
/// snapshot: a stale EWMA paired with a fresh backlog (or vice versa)
/// would skew the projected-wait ranking between shards.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PaceView {
    /// measured denoiser pace in µs per NFE (EWMA over terminal
    /// observations; `0.0` until the first request retires)
    pub ewma_us_per_nfe: f64,
    /// denoiser calls the in-flight lanes still owe — the predetermined
    /// remainder of every lane's merged ladder, known exactly because 𝒯
    /// is fixed at admission
    pub backlog_nfe: u64,
}

/// One engine-loop publish: the absolute values of everything the loop
/// and scheduler already track, captured between two denoiser calls.
/// All `Copy` — building one allocates nothing, keeping the per-tick
/// publish inside the zero-alloc hot-path budget the serving bench
/// gates.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct TickStats {
    pub batches: u64,
    pub batch_rows: u64,
    pub nn_calls: u64,
    pub avg_request_nfe: f64,
    pub occupancy: f64,
    pub cancelled: u64,
    pub deadline_exceeded: u64,
    pub queued: [usize; 3],
    pub lanes: usize,
    pub in_flight: usize,
    pub stolen: u64,
    pub rebalances: u64,
    pub lanes_donated: u64,
    pub lanes_split: u64,
    pub lanes_salvaged: u64,
    pub ghost_events_fired: u64,
    pub retries: u64,
    pub faults_transient: u64,
    pub faults_fatal: u64,
    pub early_retired: u64,
    pub turbo_truncated_nfe: u64,
    pub breaker_open: bool,
    /// client-submitted requests the loop has ingested so far (pairs
    /// with [`StatsBoard::note_submitted`] for quiesce detection)
    pub ingested: u64,
    /// remaining in-flight denoiser calls, for the pace cell
    pub backlog_nfe: u64,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Per-shard lock-free stats board (module docs for the full design).
/// The engine thread writes; anyone may read at any time.
#[derive(Default)]
pub struct StatsBoard {
    // -- monotonic counters, incremented in place --
    requests: AtomicU64,
    submitted: AtomicU64,
    stats_rpcs: AtomicU64,
    // -- monotonic counters, published as absolutes from the loop's
    //    own tallies (single engine writer, values only grow) --
    ingested: AtomicU64,
    batches: AtomicU64,
    batch_rows: AtomicU64,
    nn_calls: AtomicU64,
    cancelled: AtomicU64,
    deadline_exceeded: AtomicU64,
    stolen: AtomicU64,
    rebalances: AtomicU64,
    lanes_donated: AtomicU64,
    lanes_split: AtomicU64,
    lanes_salvaged: AtomicU64,
    ghost_events_fired: AtomicU64,
    retries: AtomicU64,
    faults_transient: AtomicU64,
    faults_fatal: AtomicU64,
    early_retired: AtomicU64,
    turbo_truncated_nfe: AtomicU64,
    // -- gauges --
    queued_low: AtomicU64,
    queued_normal: AtomicU64,
    queued_high: AtomicU64,
    lanes: AtomicU64,
    in_flight: AtomicU64,
    avg_request_nfe_bits: AtomicU64,
    occupancy_bits: AtomicU64,
    healthy: AtomicBool,
    breaker_open: AtomicBool,
    /// `false` once the shard's engine is gone for good (startup factory
    /// failure or a failed failover restart) — gauges freeze at their
    /// last published values, mirroring the drain-and-fail loop's
    /// channel replies
    alive: AtomicBool,
    // -- pace accumulator + seqlock cells --
    ewma_us_per_nfe_bits: AtomicU64,
    pace: SeqCell<2>,
    queue_lat: SeqCell<8>,
    e2e_lat: SeqCell<8>,
    tenants: Mutex<BTreeMap<String, u64>>,
}

impl StatsBoard {
    pub fn new() -> StatsBoard {
        let b = StatsBoard::default();
        b.healthy.store(true, Ordering::Relaxed);
        b.alive.store(true, Ordering::Relaxed);
        b
    }

    // -- writer side (the shard's threads) --

    /// Client-side send accounting (`Server::send_req`), *before* the
    /// engine has necessarily woken: pairs with `TickStats::ingested`.
    pub(crate) fn note_submitted(&self) {
        self.submitted.fetch_add(1, Ordering::SeqCst);
    }

    /// One channel `Msg::Stats` round-trip was made. The board exists
    /// to make this counter stop moving: `tests/scenarios.rs` pins it
    /// flat across steady-state rebalancer passes and `/metrics`
    /// scrapes.
    pub(crate) fn note_stats_rpc(&self) {
        self.stats_rpcs.fetch_add(1, Ordering::Relaxed);
    }

    /// Submit-path accounting, mirrored off `LoopState::count_submit`
    /// on the engine thread. Allocates only on a tenant's first-ever
    /// submit (the map entry's key).
    pub(crate) fn count_submit(&self, tenant: Option<&str>) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        if let Some(t) = tenant {
            let mut map = lock(&self.tenants);
            match map.get_mut(t) {
                Some(n) => *n += 1,
                None => {
                    map.insert(t.to_string(), 1);
                }
            }
        }
    }

    /// Publish both latency digests as consistent snapshots (terminal
    /// path — freezing sorts the reservoir in place, no allocation
    /// after warmup).
    pub(crate) fn publish_latency(&self, queue: &LatencySnapshot, e2e: &LatencySnapshot) {
        self.queue_lat.write(latency_words(queue));
        self.e2e_lat.write(latency_words(e2e));
    }

    /// Fold one terminal observation into the measured pace EWMA. The
    /// pace *pair* becomes visible to readers at the next
    /// [`Self::publish_tick`], which immediately follows the delivering
    /// tick.
    pub(crate) fn observe_pace(&self, served_nfe: u64, elapsed: Duration) {
        let sample = elapsed.as_micros() as f64 / served_nfe.max(1) as f64;
        let mut cur = self.ewma_us_per_nfe_bits.load(Ordering::Relaxed);
        loop {
            let prev = f64::from_bits(cur);
            let next = if prev == 0.0 {
                sample
            } else {
                PACE_EWMA_ALPHA * sample + (1.0 - PACE_EWMA_ALPHA) * prev
            };
            match self.ewma_us_per_nfe_bits.compare_exchange_weak(
                cur,
                next.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// The end-of-iteration publish: absolute stores of the loop's own
    /// monotonic tallies plus the instantaneous gauges, then the pace
    /// cell. Allocation-free.
    pub(crate) fn publish_tick(&self, t: TickStats) {
        self.batches.store(t.batches, Ordering::Relaxed);
        self.batch_rows.store(t.batch_rows, Ordering::Relaxed);
        self.nn_calls.store(t.nn_calls, Ordering::Relaxed);
        self.cancelled.store(t.cancelled, Ordering::Relaxed);
        self.deadline_exceeded.store(t.deadline_exceeded, Ordering::Relaxed);
        self.stolen.store(t.stolen, Ordering::Relaxed);
        self.rebalances.store(t.rebalances, Ordering::Relaxed);
        self.lanes_donated.store(t.lanes_donated, Ordering::Relaxed);
        self.lanes_split.store(t.lanes_split, Ordering::Relaxed);
        self.lanes_salvaged.store(t.lanes_salvaged, Ordering::Relaxed);
        self.ghost_events_fired.store(t.ghost_events_fired, Ordering::Relaxed);
        self.retries.store(t.retries, Ordering::Relaxed);
        self.faults_transient.store(t.faults_transient, Ordering::Relaxed);
        self.faults_fatal.store(t.faults_fatal, Ordering::Relaxed);
        self.early_retired.store(t.early_retired, Ordering::Relaxed);
        self.turbo_truncated_nfe.store(t.turbo_truncated_nfe, Ordering::Relaxed);
        self.queued_low.store(t.queued[0] as u64, Ordering::Relaxed);
        self.queued_normal.store(t.queued[1] as u64, Ordering::Relaxed);
        self.queued_high.store(t.queued[2] as u64, Ordering::Relaxed);
        self.lanes.store(t.lanes as u64, Ordering::Relaxed);
        self.in_flight.store(t.in_flight as u64, Ordering::Relaxed);
        self.avg_request_nfe_bits.store(t.avg_request_nfe.to_bits(), Ordering::Relaxed);
        self.occupancy_bits.store(t.occupancy.to_bits(), Ordering::Relaxed);
        self.breaker_open.store(t.breaker_open, Ordering::Relaxed);
        self.healthy.store(!t.breaker_open, Ordering::Relaxed);
        self.pace
            .write([self.ewma_us_per_nfe_bits.load(Ordering::Relaxed), t.backlog_nfe]);
        // the ingest watermark last (SeqCst): a reader that observes
        // `ingested == submitted` is guaranteed to also observe gauges
        // at least as fresh as the ingest of those submits
        self.ingested.store(t.ingested, Ordering::SeqCst);
    }

    /// Overwrite the board from an assembled [`ServerStats`] — the dead
    /// shard's final sync: `fail_engine_loop` publishes its `base`
    /// snapshot so board readers see exactly what channel Stats replies
    /// report, then freezes via [`Self::set_dead`]. Only the fields
    /// `ServerStats` carries are restored (the queue digest keeps just
    /// its p95 — the one queue word `ServerStats` surfaces).
    pub(crate) fn publish_stats(&self, s: &ServerStats) {
        self.publish_tick(TickStats {
            batches: s.batches,
            batch_rows: (s.mean_batch * s.batches as f64).round() as u64,
            nn_calls: s.nn_calls,
            avg_request_nfe: s.avg_request_nfe,
            occupancy: s.occupancy,
            cancelled: s.cancelled,
            deadline_exceeded: s.deadline_exceeded,
            queued: [s.queued_low as usize, s.queued_normal as usize, s.queued_high as usize],
            lanes: s.lanes as usize,
            in_flight: s.in_flight as usize,
            stolen: s.stolen,
            rebalances: s.rebalances,
            lanes_donated: s.lanes_donated,
            lanes_split: s.lanes_split,
            lanes_salvaged: s.lanes_salvaged,
            ghost_events_fired: s.ghost_events_fired,
            retries: s.retries,
            faults_transient: s.faults_transient,
            faults_fatal: s.faults_fatal,
            early_retired: s.early_retired,
            turbo_truncated_nfe: s.turbo_truncated_nfe,
            breaker_open: s.breaker_open,
            ingested: self.ingested.load(Ordering::SeqCst),
            backlog_nfe: self.pace.read()[1],
        });
        let queue = LatencySnapshot { p95: s.queue_p95, ..LatencySnapshot::default() };
        self.queue_lat.write(latency_words(&queue));
        self.e2e_lat.write(latency_words(&s.e2e));
        self.healthy.store(s.healthy, Ordering::Relaxed);
    }

    /// Terminal transition into the dead state (`fail_engine_loop`):
    /// freeze the last published gauges, report `healthy: false`,
    /// `breaker_open: false` — matching the drain-and-fail loop's
    /// channel replies byte for byte.
    pub(crate) fn set_dead(&self) {
        self.alive.store(false, Ordering::Relaxed);
        self.healthy.store(false, Ordering::Relaxed);
        self.breaker_open.store(false, Ordering::Relaxed);
    }

    /// The drain-and-fail loop's ingest accounting: it keeps receiving
    /// (and failing) client submits, so the quiesce watermark must keep
    /// pace or every future rebalancer pass would fall back to a
    /// channel round-trip against this shard.
    pub(crate) fn note_ingested_dead(&self) {
        self.ingested.fetch_add(1, Ordering::SeqCst);
    }

    // -- reader side (anyone, any time) --

    /// `true` while client-side submits are still in the shard's
    /// channel, not yet reflected in the published gauges. The
    /// rebalancer uses this to decide when one channel round-trip is
    /// still warranted.
    pub fn has_unseen_submits(&self) -> bool {
        self.submitted.load(Ordering::SeqCst) > self.ingested.load(Ordering::SeqCst)
    }

    /// Cumulative channel `Msg::Stats` round-trips made against this
    /// shard (via `Server::stats()`).
    pub fn stats_rpcs(&self) -> u64 {
        self.stats_rpcs.load(Ordering::Relaxed)
    }

    pub fn healthy(&self) -> bool {
        self.healthy.load(Ordering::Relaxed)
    }

    pub fn breaker_open(&self) -> bool {
        self.breaker_open.load(Ordering::Relaxed)
    }

    /// `false` once the shard's engine is gone for good.
    pub fn alive(&self) -> bool {
        self.alive.load(Ordering::Relaxed)
    }

    /// The rebalancer's alloc-free per-pass read.
    pub fn view(&self) -> BoardView {
        BoardView {
            queued: (self.queued_low.load(Ordering::Relaxed)
                + self.queued_normal.load(Ordering::Relaxed)
                + self.queued_high.load(Ordering::Relaxed)) as usize,
            lanes: self.lanes.load(Ordering::Relaxed) as usize,
            in_flight: self.in_flight.load(Ordering::Relaxed) as usize,
            healthy: self.healthy(),
            breaker_open: self.breaker_open(),
        }
    }

    /// The admission-facing pace pair as one consistent snapshot.
    pub fn pace(&self) -> PaceView {
        let w = self.pace.read();
        PaceView { ewma_us_per_nfe: f64::from_bits(w[0]), backlog_nfe: w[1] }
    }

    /// The e2e latency digest as last published (terminal granularity).
    pub fn e2e_latency(&self) -> LatencySnapshot {
        latency_from_words(self.e2e_lat.read())
    }

    /// A full [`ServerStats`] assembled from the board — what the
    /// `/metrics` scrape renders. Never blocks on the shard (the only
    /// lock is the tenant map, held for a clone). At quiesce this
    /// equals the channel `stats()` reply exactly
    /// (`tests/scenarios.rs`).
    pub fn snapshot(&self) -> ServerStats {
        let queue = latency_from_words(self.queue_lat.read());
        let e2e = latency_from_words(self.e2e_lat.read());
        let batches = self.batches.load(Ordering::Relaxed);
        let rows = self.batch_rows.load(Ordering::Relaxed);
        ServerStats {
            requests: self.requests.load(Ordering::Relaxed),
            batches,
            nn_calls: self.nn_calls.load(Ordering::Relaxed),
            mean_batch: if batches == 0 { 0.0 } else { rows as f64 / batches as f64 },
            queue_p95: queue.p95,
            e2e_p95: e2e.p95,
            e2e_p50: e2e.p50,
            e2e_p99: e2e.p99,
            e2e,
            avg_request_nfe: f64::from_bits(self.avg_request_nfe_bits.load(Ordering::Relaxed)),
            occupancy: f64::from_bits(self.occupancy_bits.load(Ordering::Relaxed)),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            deadline_exceeded: self.deadline_exceeded.load(Ordering::Relaxed),
            queued_low: self.queued_low.load(Ordering::Relaxed),
            queued_normal: self.queued_normal.load(Ordering::Relaxed),
            queued_high: self.queued_high.load(Ordering::Relaxed),
            stolen: self.stolen.load(Ordering::Relaxed),
            lanes: self.lanes.load(Ordering::Relaxed),
            in_flight: self.in_flight.load(Ordering::Relaxed),
            rebalances: self.rebalances.load(Ordering::Relaxed),
            lanes_donated: self.lanes_donated.load(Ordering::Relaxed),
            lanes_split: self.lanes_split.load(Ordering::Relaxed),
            ghost_events_fired: self.ghost_events_fired.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            faults_transient: self.faults_transient.load(Ordering::Relaxed),
            faults_fatal: self.faults_fatal.load(Ordering::Relaxed),
            breaker_open: self.breaker_open(),
            lanes_salvaged: self.lanes_salvaged.load(Ordering::Relaxed),
            early_retired: self.early_retired.load(Ordering::Relaxed),
            turbo_truncated_nfe: self.turbo_truncated_nfe.load(Ordering::Relaxed),
            healthy: self.healthy(),
            tenant_requests: lock(&self.tenants).iter().map(|(t, n)| (t.clone(), *n)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn seqcell_roundtrips_a_snapshot() {
        let c: SeqCell<3> = SeqCell::new();
        assert_eq!(c.read(), [0, 0, 0]);
        c.write([7, 14, 21]);
        assert_eq!(c.read(), [7, 14, 21]);
    }

    /// The deterministic torn-read pin: hold the cell mid-write (odd
    /// epoch, payload half-stale) and prove the reader retries instead
    /// of returning the torn words.
    #[test]
    fn seqcell_reader_retries_through_an_in_flight_write() {
        let cell: Arc<SeqCell<2>> = Arc::new(SeqCell::new());
        cell.write([1, 2]);
        let gate = Arc::new(AtomicBool::new(false));
        let (wc, wg) = (cell.clone(), gate.clone());
        let writer = std::thread::spawn(move || {
            wc.write_paced([100, 200], || {
                wg.store(true, Ordering::SeqCst);
                // hold the epoch odd long enough for the reader to
                // observe it mid-write
                std::thread::sleep(Duration::from_millis(50));
            });
        });
        while !gate.load(Ordering::SeqCst) {
            std::hint::spin_loop();
        }
        // the write is provably in flight: the read must retry (odd
        // epoch) and then return only the *completed* snapshot
        let (words, retries) = cell.read_counting();
        assert!(retries > 0, "reader must have taken the odd-epoch retry path");
        assert_eq!(words, [100, 200], "a torn [100, 2] must never be returned");
        writer.join().unwrap();
    }

    /// Concurrency property: hammered from N writer threads, reader
    /// snapshots are never torn — the invariant word pair (x, 2x) holds
    /// in every read — and the CAS entry keeps concurrent writers from
    /// corrupting the epoch.
    #[test]
    fn seqcell_snapshots_never_tear_under_contention() {
        let cell: Arc<SeqCell<2>> = Arc::new(SeqCell::new());
        let stop = Arc::new(AtomicBool::new(false));
        let writers: Vec<_> = (1..=3u64)
            .map(|w| {
                let (c, s) = (cell.clone(), stop.clone());
                std::thread::spawn(move || {
                    let mut i = 1u64;
                    while !s.load(Ordering::Relaxed) {
                        let x = w * 1_000_000 + i;
                        c.write([x, 2 * x]);
                        i += 1;
                    }
                })
            })
            .collect();
        let mut total_retries = 0u64;
        for _ in 0..200_000 {
            let (w, r) = cell.read_counting();
            total_retries += r;
            assert_eq!(w[1], 2 * w[0], "torn snapshot: {w:?}");
        }
        stop.store(true, Ordering::Relaxed);
        for w in writers {
            w.join().unwrap();
        }
        // not asserted (scheduling-dependent), but almost always > 0 —
        // the deterministic pin above covers the retry path
        let _ = total_retries;
    }

    /// Board counters are monotonic under concurrent writers following
    /// the production discipline: many threads on the increment paths,
    /// one "engine" thread publishing growing absolutes.
    #[test]
    fn board_counters_never_decrease_under_concurrent_publish() {
        let board = Arc::new(StatsBoard::new());
        let stop = Arc::new(AtomicBool::new(false));
        let submitters: Vec<_> = (0..2)
            .map(|_| {
                let (b, s) = (board.clone(), stop.clone());
                std::thread::spawn(move || {
                    let mut n = 0u64;
                    while !s.load(Ordering::Relaxed) {
                        b.count_submit(Some("acme"));
                        b.note_submitted();
                        n += 1;
                    }
                    n
                })
            })
            .collect();
        let engine = {
            let (b, s) = (board.clone(), stop.clone());
            std::thread::spawn(move || {
                let mut t = TickStats::default();
                while !s.load(Ordering::Relaxed) {
                    t.nn_calls += 3;
                    t.batches += 1;
                    t.batch_rows += 2;
                    t.retries += 1;
                    t.backlog_nfe = t.nn_calls % 17;
                    b.publish_tick(t);
                }
            })
        };
        let (mut last_req, mut last_calls, mut last_batches) = (0u64, 0u64, 0u64);
        for _ in 0..100_000 {
            let s = board.snapshot();
            assert!(s.requests >= last_req, "requests decreased");
            assert!(s.nn_calls >= last_calls, "nn_calls decreased");
            assert!(s.batches >= last_batches, "batches decreased");
            // the pace pair is seqlock-consistent: backlog always
            // matches the nn_calls of the same publish
            let pace = board.pace();
            let _ = pace.backlog_nfe;
            (last_req, last_calls, last_batches) = (s.requests, s.nn_calls, s.batches);
        }
        stop.store(true, Ordering::Relaxed);
        let submitted: u64 = submitters.into_iter().map(|h| h.join().unwrap()).sum();
        engine.join().unwrap();
        let s = board.snapshot();
        assert_eq!(s.requests, submitted, "every submit counted exactly once");
        assert_eq!(s.tenant_requests, vec![("acme".to_string(), submitted)]);
    }

    #[test]
    fn pace_ewma_matches_admission_arithmetic() {
        let b = StatsBoard::new();
        assert_eq!(b.pace(), PaceView { ewma_us_per_nfe: 0.0, backlog_nfe: 0 });
        // first observation seeds the EWMA outright
        b.observe_pace(4, Duration::from_micros(4000));
        b.publish_tick(TickStats { backlog_nfe: 12, ..TickStats::default() });
        assert_eq!(b.pace(), PaceView { ewma_us_per_nfe: 1000.0, backlog_nfe: 12 });
        // second folds in at α = 0.2: 0.2·5000 + 0.8·1000
        b.observe_pace(2, Duration::from_micros(10_000));
        b.publish_tick(TickStats { backlog_nfe: 5, ..TickStats::default() });
        let pace = b.pace();
        assert!((pace.ewma_us_per_nfe - (0.2 * 5000.0 + 0.8 * 1000.0)).abs() < 1e-9);
        assert_eq!(pace.backlog_nfe, 5);
    }

    #[test]
    fn latency_cells_roundtrip_snapshots_losslessly() {
        let b = StatsBoard::new();
        let mut stats = crate::metrics::LatencyStats::new();
        for i in 1..=1500u64 {
            stats.record(Duration::from_micros(i * 7));
        }
        let snap = stats.freeze();
        b.publish_latency(&snap, &snap);
        assert_eq!(b.e2e_latency(), snap);
        let s = b.snapshot();
        assert_eq!(s.e2e, snap);
        assert_eq!(s.queue_p95, snap.p95);
        assert_eq!(s.e2e_p50, snap.p50);
    }

    #[test]
    fn unseen_submit_watermark_and_dead_transition() {
        let b = StatsBoard::new();
        assert!(!b.has_unseen_submits());
        b.note_submitted();
        assert!(b.has_unseen_submits(), "send not yet ingested");
        b.publish_tick(TickStats { ingested: 1, ..TickStats::default() });
        assert!(!b.has_unseen_submits(), "publish carries the ingest watermark");
        assert!(b.healthy() && b.alive());
        b.set_dead();
        assert!(!b.healthy() && !b.alive() && !b.breaker_open());
        // the fail loop keeps the watermark paced
        b.note_submitted();
        b.note_ingested_dead();
        assert!(!b.has_unseen_submits());
    }
}
