//! Continuous NFE-aligned scheduling — step-decoupled serving.
//!
//! The legacy batcher freezes a FIFO batch, runs it to completion, and
//! only then looks at the queue again. Because every sampler is now a
//! [`SamplerSession`] (one denoiser call per `next_event`/`advance`
//! round-trip), the scheduler can instead keep a *rolling* batch:
//!
//! * Pending requests are admitted **at transition-time boundaries** —
//!   between two denoiser calls — never mid-call. A group admitted
//!   together forms one *lane* (one session); with
//!   [`SchedPolicy::shared_tau_groups`] the lane shares a single 𝒯, the
//!   paper's batched fast path. Lanes admitted at different boundaries
//!   union their event ladders simply by coexisting: the denoiser takes a
//!   per-sequence time vector, so one call advances every lane by one
//!   event of its own ladder.
//! * A lane retires the moment its last τ fires; its slots free up and are
//!   refilled at the next boundary.
//! * Requests whose sampler spec differs from the in-flight batch's spec
//!   (different kind/steps/𝒟_τ/order/temperature) are **not** merged —
//!   they wait until the batch drains and then form their own batch, so a
//!   mixed-spec workload degrades to separate batches instead of
//!   corrupting the shared ladder.
//!
//! Per-request NFE (= the number of calls the request's session consumed,
//! |𝒯| for DNDM), queue wait, and in-flight occupancy are recorded on the
//! engine's [`NfeCounter`] (`metrics::nfe`).
//!
//! [`NfeCounter`]: crate::metrics::NfeCounter

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::sampler::{SamplerConfig, SamplerKind, SamplerSession};
use crate::schedule::{TransitionOrder, TransitionSpec};
use crate::tensor::{LogitsBuf, TokenBatch};

use super::engine::{Engine, GenOutput};

/// Admission policy of the continuous scheduler.
#[derive(Debug, Clone, Copy)]
pub struct SchedPolicy {
    /// Slot capacity: total in-flight sequences across all lanes.
    pub max_batch: usize,
    /// How long the oldest pending request may wait before an *empty*
    /// scheduler starts a batch anyway (grouping window). While a batch is
    /// in flight, compatible requests join at the next boundary regardless.
    pub window: Duration,
    /// Admit a same-boundary group as one shared-𝒯 session (the paper's
    /// batched implementation) instead of one session per request.
    ///
    /// Note on reproducibility: a shared lane is seeded from its *first*
    /// member's seed (like the fixed path's batch seed), so a request's
    /// output then depends on admission grouping. Set this to `false` when
    /// per-request (src, seed) → tokens reproducibility matters more than
    /// the shared-𝒯 call amortization.
    pub shared_tau_groups: bool,
}

impl Default for SchedPolicy {
    fn default() -> Self {
        SchedPolicy {
            max_batch: 16,
            window: Duration::from_millis(20),
            shared_tau_groups: true,
        }
    }
}

/// A queued request, generic over the caller's payload (response channel,
/// test id, …).
pub struct Pending<P> {
    pub src: Option<String>,
    pub seed: u64,
    /// per-request sampler override; `None` = the scheduler's default
    pub cfg: Option<SamplerConfig>,
    pub enqueued: Instant,
    pub payload: P,
}

struct Member<P> {
    payload: P,
    enqueued: Instant,
    admitted: Instant,
}

/// One co-admitted group: a session of `members.len()` sequences. Source
/// ids are flattened into a [`TokenBatch`] once at admission, so every
/// subsequent NFE call gathers them with a single memcpy instead of
/// re-cloning one `Vec` per sequence per call.
struct Lane<P> {
    session: SamplerSession,
    src_ids: Option<TokenBatch>,
    members: Vec<Member<P>>,
    admitted_boundary: u64,
}

/// Observable lane state (tests, debugging).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneInfo {
    pub width: usize,
    /// boundary index (completed-call count) at which the lane joined
    pub admitted_boundary: u64,
    /// denoiser calls this lane has consumed so far
    pub nfe: usize,
}

/// A retired (or failed) request handed back to the caller.
pub struct Finished<P> {
    pub payload: P,
    pub result: Result<GenOutput>,
    /// queue wait: enqueue → admission into a lane
    pub wait: Duration,
}

/// Admission-compatibility key: two requests may share an in-flight batch
/// iff their effective sampler configs agree on everything that shapes the
/// event ladder and the update rule.
///
/// A plain derived-`PartialEq` struct (no heap) — it replaces a
/// `format!`-built `String` that was allocated per pending request on
/// every `admit()` pass. Holding the full [`TransitionSpec`] (not just its
/// name) also stops e.g. `Beta(15, 7)` and `Beta(2, 3)` requests from
/// being merged into one ladder. Derived float equality means a config
/// carrying NaN (already nonsensical for sampling) is never equal to
/// itself and degrades to singleton lanes — correct output, just no
/// batching for that pathological request.
#[derive(Debug, Clone, PartialEq)]
pub struct SpecKey {
    kind: SamplerKind,
    steps: usize,
    spec: TransitionSpec,
    order: TransitionOrder,
    temperature: f32,
    shared_tau: bool,
}

impl SpecKey {
    fn of(cfg: &SamplerConfig) -> SpecKey {
        SpecKey {
            kind: cfg.kind,
            steps: cfg.steps,
            spec: cfg.spec.clone(),
            order: cfg.order,
            temperature: cfg.temperature,
            shared_tau: cfg.shared_tau,
        }
    }
}

/// Persistent per-tick buffers: the batch the denoiser sees is gathered
/// into these (one memcpy per lane) and the logits come back into the same
/// `LogitsBuf` every call — after the first tick, steady-state `tick()`
/// performs zero heap allocations outside the denoiser itself for the
/// non-sorting samplers (pinned by `steady_state_tick_is_allocation_free`
/// below; the score-ranking kinds may allocate std's stable-sort merge
/// buffer inside `advance` at seq_len > 20 — see `docs/perf.md`).
#[derive(Default)]
struct StepScratch {
    xs: TokenBatch,
    ts: Vec<f32>,
    srcs: TokenBatch,
    logits: LogitsBuf,
}

/// The continuous scheduler. Owns the engine; single-threaded by design
/// (PJRT handles are not `Send`) — the server wraps it in a thread + queue.
pub struct Scheduler<P> {
    engine: Engine,
    default_cfg: SamplerConfig,
    policy: SchedPolicy,
    pending: VecDeque<Pending<P>>,
    lanes: Vec<Lane<P>>,
    /// spec key of the in-flight batch (`None` when no lanes are active)
    key: Option<SpecKey>,
    /// completed denoiser calls — the boundary clock
    boundary: u64,
    /// shutdown/drain mode: ignore the grouping window
    flushing: bool,
    /// reusable per-tick buffers (see [`StepScratch`])
    scratch: StepScratch,
}

impl<P> Scheduler<P> {
    pub fn new(engine: Engine, default_cfg: SamplerConfig, policy: SchedPolicy) -> Scheduler<P> {
        Scheduler {
            engine,
            default_cfg,
            policy,
            pending: VecDeque::new(),
            lanes: Vec::new(),
            key: None,
            boundary: 0,
            flushing: false,
            scratch: StepScratch::default(),
        }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn policy(&self) -> SchedPolicy {
        self.policy
    }

    /// Completed-call count — admissions only ever happen between calls,
    /// i.e. at a value of this clock.
    pub fn boundary(&self) -> u64 {
        self.boundary
    }

    /// Total in-flight sequences (sum of lane widths).
    pub fn in_flight(&self) -> usize {
        self.lanes.iter().map(|l| l.session.batch()).sum()
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn has_work(&self) -> bool {
        !self.lanes.is_empty() || !self.pending.is_empty()
    }

    pub fn lane_info(&self) -> Vec<LaneInfo> {
        self.lanes
            .iter()
            .map(|l| LaneInfo {
                width: l.session.batch(),
                admitted_boundary: l.admitted_boundary,
                nfe: l.session.nfe(),
            })
            .collect()
    }

    /// Spec key of the in-flight batch, if any.
    pub fn current_key(&self) -> Option<&SpecKey> {
        self.key.as_ref()
    }

    /// Queue a request; it will be admitted at a future boundary.
    pub fn enqueue(&mut self, req: Pending<P>) {
        self.pending.push_back(req);
    }

    /// Enter drain mode: admit pending work immediately (ignore the
    /// grouping window) until the queue is empty.
    pub fn flush(&mut self) {
        self.flushing = true;
    }

    /// When idle with pending work, the instant by which the grouping
    /// window forces a batch to start. `None` while lanes are active (the
    /// scheduler should keep stepping) or when nothing is pending.
    pub fn next_deadline(&self) -> Option<Instant> {
        if !self.lanes.is_empty() {
            return None;
        }
        self.pending.front().map(|p| p.enqueued + self.policy.window)
    }

    fn effective_key(&self, p: &Pending<P>) -> SpecKey {
        SpecKey::of(p.cfg.as_ref().unwrap_or(&self.default_cfg))
    }

    /// Admit pending requests into free slots. Called only between calls
    /// (from [`Self::tick`]) — the transition-time-boundary rule. Returns
    /// requests resolved at admission: failed (bad spec for this engine)
    /// or degenerate zero-call completions.
    fn admit(&mut self) -> Vec<Finished<P>> {
        let mut resolved = Vec::new();
        if self.pending.is_empty() {
            return resolved;
        }
        if self.lanes.is_empty() {
            // an idle scheduler starts a batch when the queue fills the
            // capacity, the oldest request has waited out the window, or
            // we are draining
            let full = self.pending.len() >= self.policy.max_batch;
            let waited = self
                .pending
                .front()
                .map(|p| p.enqueued.elapsed() >= self.policy.window)
                .unwrap_or(false);
            if !(full || waited || self.flushing) {
                return resolved;
            }
            self.key = None;
        }

        loop {
            let free = self.policy.max_batch.saturating_sub(self.in_flight());
            if free == 0 {
                break;
            }
            // strict FIFO: take the longest front run with a matching key
            let mut group: Vec<Pending<P>> = Vec::new();
            while group.len() < free {
                let Some(front) = self.pending.front() else { break };
                let fkey = self.effective_key(front);
                match &self.key {
                    Some(k) if *k != fkey => break,
                    _ => {}
                }
                if self.key.is_none() {
                    self.key = Some(fkey);
                }
                group.push(self.pending.pop_front().expect("front exists"));
            }
            if group.is_empty() {
                break;
            }
            if self.policy.shared_tau_groups {
                self.push_lane(group, &mut resolved);
            } else {
                for req in group {
                    self.push_lane(vec![req], &mut resolved);
                }
            }
            if self.lanes.is_empty() {
                // the whole group resolved without a lane (bad spec /
                // zero-call): drop its key so the next front request is
                // considered this same tick instead of after its window
                self.key = None;
            }
        }
        if self.lanes.is_empty() {
            self.key = None;
        }
        resolved
    }

    /// Build one lane (one session) from a co-admitted group. Requests that
    /// resolve without a lane (bad spec, zero-call specs) go to `out`.
    fn push_lane(&mut self, group: Vec<Pending<P>>, out: &mut Vec<Finished<P>>) {
        let cfg = group[0].cfg.clone().unwrap_or_else(|| self.default_cfg.clone());
        let width = group.len();
        let seed = group[0].seed;
        let session =
            match SamplerSession::new(self.engine.denoiser().config(), &cfg, width, seed) {
                Ok(s) => s,
                Err(e) => {
                    let msg = format!("{e:#}");
                    for p in group {
                        out.push(Finished {
                            payload: p.payload,
                            result: Err(anyhow!("{msg}")),
                            wait: p.enqueued.elapsed(),
                        });
                    }
                    return;
                }
            };
        if session.is_done() {
            // degenerate spec (e.g. 0 steps): nothing to denoise — complete
            // immediately with x_T as drawn
            self.engine.nfe.record_batch();
            let nfe = session.nfe();
            let res = session.into_result();
            for (i, p) in group.into_iter().enumerate() {
                let wait = p.enqueued.elapsed();
                self.engine.nfe.record_request(nfe, wait);
                let tokens = res.tokens[i].clone();
                out.push(Finished {
                    payload: p.payload,
                    result: Ok(GenOutput {
                        text: self.engine.decode(&tokens),
                        tokens,
                        nfe,
                        // zero denoiser calls were made for this request
                        elapsed: Duration::ZERO,
                    }),
                    wait,
                });
            }
            return;
        }
        let src_ids = if self.engine.conditional() {
            // pre-flatten once at admission; the per-NFE gather is then a
            // single memcpy into the step scratch
            let src_len = self.engine.denoiser().config().src_len;
            let mut tb = TokenBatch::new(src_len);
            for p in &group {
                tb.push_row(&self.engine.encode_src(p.src.as_deref().unwrap_or("")));
            }
            Some(tb)
        } else {
            None
        };
        let now = Instant::now();
        let members = group
            .into_iter()
            .map(|p| Member { payload: p.payload, enqueued: p.enqueued, admitted: now })
            .collect();
        self.lanes.push(Lane { session, src_ids, members, admitted_boundary: self.boundary });
    }

    /// One denoiser call over every active lane: each lane advances by one
    /// event of its own ladder (its own time, via the per-sequence time
    /// vector), finished lanes retire and their requests are returned.
    ///
    /// The batch is gathered into the persistent [`StepScratch`] (one
    /// memcpy per lane, no per-row clones) and the logits are written back
    /// into the same reusable buffer; each lane then advances on a
    /// `narrow`ed view of its own rows. Steady-state (no admission, no
    /// retirement) this performs zero heap allocations outside the
    /// denoiser, modulo std's stable-sort scratch inside the score-ranking
    /// samplers' `advance` (see `docs/perf.md`).
    fn step(&mut self) -> Vec<Finished<P>> {
        if self.lanes.is_empty() {
            return Vec::new();
        }
        let conditional = self.engine.conditional();
        let mcfg = self.engine.denoiser().config();
        self.scratch.xs.reset(mcfg.seq_len);
        self.scratch.ts.clear();
        self.scratch.srcs.reset(mcfg.src_len);
        for lane in &self.lanes {
            let call = lane.session.next_event().expect("active lane has a pending call");
            self.scratch.xs.extend_from(lane.session.x());
            for _ in 0..lane.session.batch() {
                self.scratch.ts.push(call.t);
            }
            if conditional {
                self.scratch
                    .srcs
                    .extend_from(lane.src_ids.as_ref().expect("conditional lane has srcs"));
            }
        }
        let src_opt = if conditional { Some(&self.scratch.srcs) } else { None };
        let width = self.scratch.xs.rows();
        if let Err(e) = self.engine.denoiser().denoise_into(
            &self.scratch.xs,
            &self.scratch.ts,
            src_opt,
            &mut self.scratch.logits,
        ) {
            return self.fail_all(&e);
        }
        self.engine.nfe.record_call(width);
        self.boundary += 1;

        let view = self.scratch.logits.view();
        let mut off = 0usize;
        let mut step_err = None;
        for lane in &mut self.lanes {
            let w = lane.session.batch();
            if let Err(e) = lane.session.advance(view.narrow(off, w)) {
                step_err = Some(e);
                break;
            }
            off += w;
        }
        if let Some(e) = step_err {
            return self.fail_all(&e);
        }

        // retire finished lanes in place (no mem::take + re-push, which
        // would re-allocate the lane vector on every boundary)
        let mut finished = Vec::new();
        let mut i = 0usize;
        while i < self.lanes.len() {
            if !self.lanes[i].session.is_done() {
                i += 1;
                continue;
            }
            let lane = self.lanes.remove(i);
            self.engine.nfe.record_batch();
            let nfe = lane.session.nfe();
            let res = lane.session.into_result();
            for (j, m) in lane.members.into_iter().enumerate() {
                let wait = m.admitted.duration_since(m.enqueued);
                self.engine.nfe.record_request(nfe, wait);
                let tokens = res.tokens[j].clone();
                finished.push(Finished {
                    payload: m.payload,
                    result: Ok(GenOutput {
                        text: self.engine.decode(&tokens),
                        tokens,
                        nfe,
                        // generation time only (same meaning as the
                        // fixed path); queue wait travels separately
                        elapsed: m.admitted.elapsed(),
                    }),
                    wait,
                });
            }
        }
        if self.lanes.is_empty() {
            self.key = None;
        }
        finished
    }

    fn fail_all(&mut self, e: &anyhow::Error) -> Vec<Finished<P>> {
        let msg = format!("{e:#}");
        let mut out = Vec::new();
        for lane in std::mem::take(&mut self.lanes) {
            for m in lane.members {
                out.push(Finished {
                    payload: m.payload,
                    result: Err(anyhow!("{msg}")),
                    wait: m.admitted.duration_since(m.enqueued),
                });
            }
        }
        self.key = None;
        out
    }

    /// One boundary: admit pending work into free slots, then make one
    /// denoiser call. Returns every request that finished (or failed) at
    /// this boundary.
    pub fn tick(&mut self) -> Vec<Finished<P>> {
        let mut out = self.admit();
        out.extend(self.step());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::cipher_mock_engine;
    use crate::sampler::SamplerKind;

    fn mock_engine() -> Engine {
        cipher_mock_engine(8)
    }

    fn req(id: usize, seed: u64, cfg: Option<SamplerConfig>) -> Pending<usize> {
        Pending {
            src: Some("the quick fox".into()),
            seed,
            cfg,
            enqueued: Instant::now(),
            payload: id,
        }
    }

    fn policy(max_batch: usize) -> SchedPolicy {
        SchedPolicy { max_batch, window: Duration::ZERO, shared_tau_groups: true }
    }

    #[test]
    fn single_request_completes_with_session_nfe() {
        let mut s: Scheduler<usize> =
            Scheduler::new(mock_engine(), SamplerConfig::new(SamplerKind::Dndm, 50), policy(4));
        s.enqueue(req(0, 7, None));
        let mut done = Vec::new();
        while s.has_work() {
            done.extend(s.tick());
        }
        assert_eq!(done.len(), 1);
        let out = done[0].result.as_ref().unwrap();
        assert!(out.nfe >= 1 && out.nfe <= 8);
        assert_eq!(s.engine().nfe.requests(), 1);
        assert_eq!(s.engine().nfe.calls() as usize, out.nfe);
    }

    #[test]
    fn spec_key_separates_differing_specs_and_matches_equal_ones() {
        let a = SamplerConfig::new(SamplerKind::Dndm, 50);
        let b = SamplerConfig::new(SamplerKind::Dndm, 50);
        assert_eq!(SpecKey::of(&a), SpecKey::of(&b));
        assert_ne!(SpecKey::of(&a), SpecKey::of(&SamplerConfig::new(SamplerKind::DndmV2, 50)));
        assert_ne!(SpecKey::of(&a), SpecKey::of(&SamplerConfig::new(SamplerKind::Dndm, 25)));
        assert_ne!(SpecKey::of(&a), SpecKey::of(&a.clone().with_temperature(1.0)));
        // differing 𝒟_τ parameters must not share a ladder (the String key
        // only compared the spec *name* and would have merged these)
        use crate::schedule::TransitionSpec;
        let beta_a = a.clone().with_spec(TransitionSpec::Beta { a: 15.0, b: 7.0 });
        let beta_b = a.clone().with_spec(TransitionSpec::Beta { a: 2.0, b: 3.0 });
        assert_ne!(SpecKey::of(&beta_a), SpecKey::of(&beta_b));
    }

    /// The tentpole guarantee: between admission and retirement, `tick()`
    /// allocates nothing — token gather, time vector, src gather, and the
    /// logits all live in buffers reused across calls (the mock denoiser
    /// writes in place, so the whole boundary is heap-silent).
    #[test]
    fn steady_state_tick_is_allocation_free() {
        use crate::util::bench::alloc_count::thread_allocs;

        let eng = mock_engine();
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50);
        // pick a seed whose session spans enough events that some ticks
        // neither admit nor retire (DNDM's |𝒯| varies with the seed)
        let seed = (0..64u64)
            .find(|&s| {
                let sess =
                    SamplerSession::new(eng.denoiser().config(), &cfg, 1, s).unwrap();
                let distinct: std::collections::BTreeSet<usize> =
                    sess.taus().unwrap().iter().flatten().copied().collect();
                distinct.len() >= 4
            })
            .expect("some seed in 0..64 must give >= 4 events");

        let mut s: Scheduler<usize> = Scheduler::new(eng, cfg, policy(4));
        s.enqueue(req(0, seed, None));
        // boundary 1: admission + first call — warms every scratch buffer
        let first = s.tick();
        assert!(first.is_empty(), ">= 4 events, so the first tick cannot retire");

        let mut steady = 0usize;
        let mut done = Vec::new();
        while s.has_work() {
            let before = thread_allocs();
            let out = s.tick();
            let delta = thread_allocs() - before;
            if out.is_empty() {
                assert_eq!(delta, 0, "steady-state tick() allocated {delta} time(s)");
                steady += 1;
            }
            done.extend(out);
        }
        assert!(steady >= 2, "expected >= 2 steady-state ticks, saw {steady}");
        assert_eq!(done.len(), 1);
        assert!(done[0].result.is_ok());
    }

    #[test]
    fn group_admitted_together_shares_one_lane() {
        let mut s: Scheduler<usize> =
            Scheduler::new(mock_engine(), SamplerConfig::new(SamplerKind::Dndm, 50), policy(4));
        for i in 0..3 {
            s.enqueue(req(i, 9, None));
        }
        let done = s.tick();
        assert!(done.is_empty() || done.len() == 3);
        let lanes = s.lane_info();
        if !lanes.is_empty() {
            assert_eq!(lanes.len(), 1, "one shared-𝒯 lane");
            assert_eq!(lanes[0].width, 3);
            assert_eq!(lanes[0].admitted_boundary, 0);
        }
        let mut all = done;
        while s.has_work() {
            all.extend(s.tick());
        }
        assert_eq!(all.len(), 3);
        // shared 𝒯 ⇒ identical per-request NFE
        let nfes: Vec<usize> =
            all.iter().map(|f| f.result.as_ref().unwrap().nfe).collect();
        assert!(nfes.windows(2).all(|w| w[0] == w[1]), "{nfes:?}");
    }
}
