//! Continuous NFE-aligned scheduling — step-decoupled serving.
//!
//! The legacy batcher freezes a FIFO batch, runs it to completion, and
//! only then looks at the queue again. Because every sampler is now a
//! [`SamplerSession`] (one denoiser call per `next_event`/`advance`
//! round-trip), the scheduler can instead keep a *rolling* batch:
//!
//! * Pending requests are admitted **at transition-time boundaries** —
//!   between two denoiser calls — never mid-call. A group admitted
//!   together forms one *lane* (one session); with
//!   [`SchedPolicy::shared_tau_groups`] the lane shares a single 𝒯, the
//!   paper's batched fast path. Lanes admitted at different boundaries
//!   union their event ladders simply by coexisting: the denoiser takes a
//!   per-sequence time vector, so one call advances every lane by one
//!   event of its own ladder.
//! * A lane retires the moment its last τ fires; its slots free up and are
//!   refilled at the next boundary.
//! * Requests whose sampler spec differs from the in-flight batch's spec
//!   (different kind/steps/𝒟_τ/order/temperature) are **not** merged —
//!   they wait until the batch drains and then form their own batch, so a
//!   mixed-spec workload degrades to separate batches instead of
//!   corrupting the shared ladder.
//!
//! Per-request NFE (= the number of calls the request's session consumed,
//! |𝒯| for DNDM), queue wait, and in-flight occupancy are recorded on the
//! engine's [`NfeCounter`] (`metrics::nfe`).
//!
//! [`NfeCounter`]: crate::metrics::NfeCounter

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::sampler::{SamplerConfig, SamplerSession};

use super::engine::{Engine, GenOutput};

/// Admission policy of the continuous scheduler.
#[derive(Debug, Clone, Copy)]
pub struct SchedPolicy {
    /// Slot capacity: total in-flight sequences across all lanes.
    pub max_batch: usize,
    /// How long the oldest pending request may wait before an *empty*
    /// scheduler starts a batch anyway (grouping window). While a batch is
    /// in flight, compatible requests join at the next boundary regardless.
    pub window: Duration,
    /// Admit a same-boundary group as one shared-𝒯 session (the paper's
    /// batched implementation) instead of one session per request.
    ///
    /// Note on reproducibility: a shared lane is seeded from its *first*
    /// member's seed (like the fixed path's batch seed), so a request's
    /// output then depends on admission grouping. Set this to `false` when
    /// per-request (src, seed) → tokens reproducibility matters more than
    /// the shared-𝒯 call amortization.
    pub shared_tau_groups: bool,
}

impl Default for SchedPolicy {
    fn default() -> Self {
        SchedPolicy {
            max_batch: 16,
            window: Duration::from_millis(20),
            shared_tau_groups: true,
        }
    }
}

/// A queued request, generic over the caller's payload (response channel,
/// test id, …).
pub struct Pending<P> {
    pub src: Option<String>,
    pub seed: u64,
    /// per-request sampler override; `None` = the scheduler's default
    pub cfg: Option<SamplerConfig>,
    pub enqueued: Instant,
    pub payload: P,
}

struct Member<P> {
    payload: P,
    enqueued: Instant,
    admitted: Instant,
}

/// One co-admitted group: a session of `members.len()` sequences.
struct Lane<P> {
    session: SamplerSession,
    src_ids: Option<Vec<Vec<u32>>>,
    members: Vec<Member<P>>,
    admitted_boundary: u64,
}

/// Observable lane state (tests, debugging).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneInfo {
    pub width: usize,
    /// boundary index (completed-call count) at which the lane joined
    pub admitted_boundary: u64,
    /// denoiser calls this lane has consumed so far
    pub nfe: usize,
}

/// A retired (or failed) request handed back to the caller.
pub struct Finished<P> {
    pub payload: P,
    pub result: Result<GenOutput>,
    /// queue wait: enqueue → admission into a lane
    pub wait: Duration,
}

/// Admission-compatibility key: two requests may share an in-flight batch
/// iff their effective sampler configs agree on everything that shapes the
/// event ladder and the update rule.
fn spec_key(cfg: &SamplerConfig) -> String {
    format!(
        "{}|T{}|{}|{:?}|temp{}|shared{}",
        cfg.kind.name(),
        cfg.steps,
        cfg.spec.name(),
        cfg.order,
        cfg.temperature,
        cfg.shared_tau
    )
}

/// The continuous scheduler. Owns the engine; single-threaded by design
/// (PJRT handles are not `Send`) — the server wraps it in a thread + queue.
pub struct Scheduler<P> {
    engine: Engine,
    default_cfg: SamplerConfig,
    policy: SchedPolicy,
    pending: VecDeque<Pending<P>>,
    lanes: Vec<Lane<P>>,
    /// spec key of the in-flight batch (`None` when no lanes are active)
    key: Option<String>,
    /// completed denoiser calls — the boundary clock
    boundary: u64,
    /// shutdown/drain mode: ignore the grouping window
    flushing: bool,
}

impl<P> Scheduler<P> {
    pub fn new(engine: Engine, default_cfg: SamplerConfig, policy: SchedPolicy) -> Scheduler<P> {
        Scheduler {
            engine,
            default_cfg,
            policy,
            pending: VecDeque::new(),
            lanes: Vec::new(),
            key: None,
            boundary: 0,
            flushing: false,
        }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn policy(&self) -> SchedPolicy {
        self.policy
    }

    /// Completed-call count — admissions only ever happen between calls,
    /// i.e. at a value of this clock.
    pub fn boundary(&self) -> u64 {
        self.boundary
    }

    /// Total in-flight sequences (sum of lane widths).
    pub fn in_flight(&self) -> usize {
        self.lanes.iter().map(|l| l.session.batch()).sum()
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn has_work(&self) -> bool {
        !self.lanes.is_empty() || !self.pending.is_empty()
    }

    pub fn lane_info(&self) -> Vec<LaneInfo> {
        self.lanes
            .iter()
            .map(|l| LaneInfo {
                width: l.session.batch(),
                admitted_boundary: l.admitted_boundary,
                nfe: l.session.nfe(),
            })
            .collect()
    }

    /// Spec key of the in-flight batch, if any.
    pub fn current_key(&self) -> Option<&str> {
        self.key.as_deref()
    }

    /// Queue a request; it will be admitted at a future boundary.
    pub fn enqueue(&mut self, req: Pending<P>) {
        self.pending.push_back(req);
    }

    /// Enter drain mode: admit pending work immediately (ignore the
    /// grouping window) until the queue is empty.
    pub fn flush(&mut self) {
        self.flushing = true;
    }

    /// When idle with pending work, the instant by which the grouping
    /// window forces a batch to start. `None` while lanes are active (the
    /// scheduler should keep stepping) or when nothing is pending.
    pub fn next_deadline(&self) -> Option<Instant> {
        if !self.lanes.is_empty() {
            return None;
        }
        self.pending.front().map(|p| p.enqueued + self.policy.window)
    }

    fn effective_key(&self, p: &Pending<P>) -> String {
        spec_key(p.cfg.as_ref().unwrap_or(&self.default_cfg))
    }

    /// Admit pending requests into free slots. Called only between calls
    /// (from [`Self::tick`]) — the transition-time-boundary rule. Returns
    /// requests resolved at admission: failed (bad spec for this engine)
    /// or degenerate zero-call completions.
    fn admit(&mut self) -> Vec<Finished<P>> {
        let mut resolved = Vec::new();
        if self.pending.is_empty() {
            return resolved;
        }
        if self.lanes.is_empty() {
            // an idle scheduler starts a batch when the queue fills the
            // capacity, the oldest request has waited out the window, or
            // we are draining
            let full = self.pending.len() >= self.policy.max_batch;
            let waited = self
                .pending
                .front()
                .map(|p| p.enqueued.elapsed() >= self.policy.window)
                .unwrap_or(false);
            if !(full || waited || self.flushing) {
                return resolved;
            }
            self.key = None;
        }

        loop {
            let free = self.policy.max_batch.saturating_sub(self.in_flight());
            if free == 0 {
                break;
            }
            // strict FIFO: take the longest front run with a matching key
            let mut group: Vec<Pending<P>> = Vec::new();
            while group.len() < free {
                let Some(front) = self.pending.front() else { break };
                let fkey = self.effective_key(front);
                match &self.key {
                    Some(k) if *k != fkey => break,
                    _ => {}
                }
                if self.key.is_none() {
                    self.key = Some(fkey);
                }
                group.push(self.pending.pop_front().expect("front exists"));
            }
            if group.is_empty() {
                break;
            }
            if self.policy.shared_tau_groups {
                self.push_lane(group, &mut resolved);
            } else {
                for req in group {
                    self.push_lane(vec![req], &mut resolved);
                }
            }
            if self.lanes.is_empty() {
                // the whole group resolved without a lane (bad spec /
                // zero-call): drop its key so the next front request is
                // considered this same tick instead of after its window
                self.key = None;
            }
        }
        if self.lanes.is_empty() {
            self.key = None;
        }
        resolved
    }

    /// Build one lane (one session) from a co-admitted group. Requests that
    /// resolve without a lane (bad spec, zero-call specs) go to `out`.
    fn push_lane(&mut self, group: Vec<Pending<P>>, out: &mut Vec<Finished<P>>) {
        let cfg = group[0].cfg.clone().unwrap_or_else(|| self.default_cfg.clone());
        let width = group.len();
        let seed = group[0].seed;
        let session =
            match SamplerSession::new(self.engine.denoiser().config(), &cfg, width, seed) {
                Ok(s) => s,
                Err(e) => {
                    let msg = format!("{e:#}");
                    for p in group {
                        out.push(Finished {
                            payload: p.payload,
                            result: Err(anyhow!("{msg}")),
                            wait: p.enqueued.elapsed(),
                        });
                    }
                    return;
                }
            };
        if session.is_done() {
            // degenerate spec (e.g. 0 steps): nothing to denoise — complete
            // immediately with x_T as drawn
            self.engine.nfe.record_batch();
            let nfe = session.nfe();
            let res = session.into_result();
            for (i, p) in group.into_iter().enumerate() {
                let wait = p.enqueued.elapsed();
                self.engine.nfe.record_request(nfe, wait);
                let tokens = res.tokens[i].clone();
                out.push(Finished {
                    payload: p.payload,
                    result: Ok(GenOutput {
                        text: self.engine.decode(&tokens),
                        tokens,
                        nfe,
                        // zero denoiser calls were made for this request
                        elapsed: Duration::ZERO,
                    }),
                    wait,
                });
            }
            return;
        }
        let src_ids = if self.engine.conditional() {
            Some(
                group
                    .iter()
                    .map(|p| self.engine.encode_src(p.src.as_deref().unwrap_or("")))
                    .collect(),
            )
        } else {
            None
        };
        let now = Instant::now();
        let members = group
            .into_iter()
            .map(|p| Member { payload: p.payload, enqueued: p.enqueued, admitted: now })
            .collect();
        self.lanes.push(Lane { session, src_ids, members, admitted_boundary: self.boundary });
    }

    /// One denoiser call over every active lane: each lane advances by one
    /// event of its own ladder (its own time, via the per-sequence time
    /// vector), finished lanes retire and their requests are returned.
    fn step(&mut self) -> Vec<Finished<P>> {
        if self.lanes.is_empty() {
            return Vec::new();
        }
        let conditional = self.engine.conditional();
        let mut xs: Vec<Vec<u32>> = Vec::with_capacity(self.in_flight());
        let mut ts: Vec<f32> = Vec::with_capacity(self.in_flight());
        let mut srcs: Vec<Vec<u32>> = Vec::new();
        for lane in &self.lanes {
            let call = lane.session.next_event().expect("active lane has a pending call");
            for seq in lane.session.x() {
                xs.push(seq.clone());
            }
            ts.extend(std::iter::repeat(call.t).take(lane.session.batch()));
            if conditional {
                srcs.extend(lane.src_ids.as_ref().expect("conditional lane has srcs").iter().cloned());
            }
        }
        let src_opt: Option<&[Vec<u32>]> = if conditional { Some(&srcs) } else { None };
        let logits = match self.engine.denoiser().denoise(&xs, &ts, src_opt) {
            Ok(l) => l,
            Err(e) => return self.fail_all(&e),
        };
        self.engine.nfe.record_call(xs.len());
        self.boundary += 1;

        let mut off = 0usize;
        let mut step_err = None;
        for lane in &mut self.lanes {
            let w = lane.session.batch();
            if let Err(e) = lane.session.advance(&logits[off..off + w]) {
                step_err = Some(e);
                break;
            }
            off += w;
        }
        if let Some(e) = step_err {
            return self.fail_all(&e);
        }

        let mut finished = Vec::new();
        let lanes = std::mem::take(&mut self.lanes);
        for lane in lanes {
            if lane.session.is_done() {
                self.engine.nfe.record_batch();
                let nfe = lane.session.nfe();
                let res = lane.session.into_result();
                for (i, m) in lane.members.into_iter().enumerate() {
                    let wait = m.admitted.duration_since(m.enqueued);
                    self.engine.nfe.record_request(nfe, wait);
                    let tokens = res.tokens[i].clone();
                    finished.push(Finished {
                        payload: m.payload,
                        result: Ok(GenOutput {
                            text: self.engine.decode(&tokens),
                            tokens,
                            nfe,
                            // generation time only (same meaning as the
                            // fixed path); queue wait travels separately
                            elapsed: m.admitted.elapsed(),
                        }),
                        wait,
                    });
                }
            } else {
                self.lanes.push(lane);
            }
        }
        if self.lanes.is_empty() {
            self.key = None;
        }
        finished
    }

    fn fail_all(&mut self, e: &anyhow::Error) -> Vec<Finished<P>> {
        let msg = format!("{e:#}");
        let mut out = Vec::new();
        for lane in std::mem::take(&mut self.lanes) {
            for m in lane.members {
                out.push(Finished {
                    payload: m.payload,
                    result: Err(anyhow!("{msg}")),
                    wait: m.admitted.duration_since(m.enqueued),
                });
            }
        }
        self.key = None;
        out
    }

    /// One boundary: admit pending work into free slots, then make one
    /// denoiser call. Returns every request that finished (or failed) at
    /// this boundary.
    pub fn tick(&mut self) -> Vec<Finished<P>> {
        let mut out = self.admit();
        out.extend(self.step());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::cipher_mock_engine;
    use crate::sampler::SamplerKind;

    fn mock_engine() -> Engine {
        cipher_mock_engine(8)
    }

    fn req(id: usize, seed: u64, cfg: Option<SamplerConfig>) -> Pending<usize> {
        Pending {
            src: Some("the quick fox".into()),
            seed,
            cfg,
            enqueued: Instant::now(),
            payload: id,
        }
    }

    fn policy(max_batch: usize) -> SchedPolicy {
        SchedPolicy { max_batch, window: Duration::ZERO, shared_tau_groups: true }
    }

    #[test]
    fn single_request_completes_with_session_nfe() {
        let mut s: Scheduler<usize> =
            Scheduler::new(mock_engine(), SamplerConfig::new(SamplerKind::Dndm, 50), policy(4));
        s.enqueue(req(0, 7, None));
        let mut done = Vec::new();
        while s.has_work() {
            done.extend(s.tick());
        }
        assert_eq!(done.len(), 1);
        let out = done[0].result.as_ref().unwrap();
        assert!(out.nfe >= 1 && out.nfe <= 8);
        assert_eq!(s.engine().nfe.requests(), 1);
        assert_eq!(s.engine().nfe.calls() as usize, out.nfe);
    }

    #[test]
    fn group_admitted_together_shares_one_lane() {
        let mut s: Scheduler<usize> =
            Scheduler::new(mock_engine(), SamplerConfig::new(SamplerKind::Dndm, 50), policy(4));
        for i in 0..3 {
            s.enqueue(req(i, 9, None));
        }
        let done = s.tick();
        assert!(done.is_empty() || done.len() == 3);
        let lanes = s.lane_info();
        if !lanes.is_empty() {
            assert_eq!(lanes.len(), 1, "one shared-𝒯 lane");
            assert_eq!(lanes[0].width, 3);
            assert_eq!(lanes[0].admitted_boundary, 0);
        }
        let mut all = done;
        while s.has_work() {
            all.extend(s.tick());
        }
        assert_eq!(all.len(), 3);
        // shared 𝒯 ⇒ identical per-request NFE
        let nfes: Vec<usize> =
            all.iter().map(|f| f.result.as_ref().unwrap().nfe).collect();
        assert!(nfes.windows(2).all(|w| w[0] == w[1]), "{nfes:?}");
    }
}
