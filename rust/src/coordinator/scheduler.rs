//! Continuous NFE-aligned scheduling — step-decoupled serving.
//!
//! The legacy batcher freezes a FIFO batch, runs it to completion, and
//! only then looks at the queue again. Because every sampler is now a
//! [`SamplerSession`] (one denoiser call per `next_event`/`advance`
//! round-trip), the scheduler can instead keep a *rolling* batch:
//!
//! * Pending requests are admitted **at transition-time boundaries** —
//!   between two denoiser calls — never mid-call. A group admitted
//!   together forms one *lane* (one session); with
//!   [`SchedPolicy::shared_tau_groups`] the lane shares a single 𝒯, the
//!   paper's batched fast path. Lanes admitted at different boundaries
//!   union their event ladders simply by coexisting: the denoiser takes a
//!   per-sequence time vector, so one call advances every lane by one
//!   event of its own ladder.
//! * A lane retires the moment its last τ fires; its slots free up and are
//!   refilled at the next boundary. A *member* can also leave early: at
//!   the boundary where its cancellation/deadline is observed, its session
//!   row is evicted ([`SamplerSession::evict_slot`]) and the lane narrows
//!   in place — the next denoiser call is one row cheaper and the freed
//!   slot refills the same tick, while survivors stay byte-identical
//!   (per-row RNG streams).
//! * Requests whose sampler spec differs from the in-flight batch's spec
//!   (different kind/steps/𝒟_τ/order/temperature) are **not** merged —
//!   they wait until the batch drains and then form their own batch, so a
//!   mixed-spec workload degrades to separate batches instead of
//!   corrupting the shared ladder.
//! * A whole lane can also **move shards** mid-run:
//!   [`Scheduler::donate_lane`] packs it (live session + members + flat
//!   src rows) at a boundary into a [`DonatedLane`] and
//!   [`Scheduler::adopt_lane`] resumes it on another scheduler at the
//!   exact next event — the predetermined ladder makes the handoff point
//!   well-defined. When a scheduler has only one (wide) lane to give,
//!   [`Scheduler::donate_rows`] instead **splits** it: the back half of
//!   the rows move — with their per-row event ladders and RNG streams —
//!   while the front half keeps serving here. See
//!   `coordinator::rebalancer` and `docs/rebalancing.md` for the policy
//!   that drives both movements.
//!
//! The same boundaries carry the request lifecycle
//! (`coordinator::request`): a [`Pending`] may hold a [`TicketSink`], and
//! the scheduler emits `Admitted`/`Progress` into it at each boundary the
//! request participates in, honours [`Ticket::cancel`] and deadlines by
//! dropping the request at the next boundary (queue-side: before it is
//! ever admitted), and orders the queue by [`Priority`] (FIFO within a
//! class).
//!
//! Per-request NFE (= the number of calls the request's session consumed,
//! |𝒯| for DNDM), queue wait, and in-flight occupancy are recorded on the
//! engine's [`NfeCounter`] (`metrics::nfe`).
//!
//! [`NfeCounter`]: crate::metrics::NfeCounter
//! [`Ticket::cancel`]: super::request::Ticket::cancel

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::runtime::{is_transient, Denoiser};
use crate::sampler::{SamplerConfig, SamplerKind, SamplerSession};
use crate::schedule::{TransitionOrder, TransitionSpec};
use crate::tensor::{LogitsBuf, TokenBatch};

use super::engine::{Engine, GenOutput};
use super::rebalancer::{pick_donation, LaneCost};
use super::request::{Priority, TicketSink};

/// Admission policy of the continuous scheduler.
#[derive(Debug, Clone, Copy)]
pub struct SchedPolicy {
    /// Slot capacity: total in-flight sequences across all lanes.
    pub max_batch: usize,
    /// How long the oldest pending request may wait before an *empty*
    /// scheduler starts a batch anyway (grouping window). While a batch is
    /// in flight, compatible requests join at the next boundary regardless.
    pub window: Duration,
    /// Admit a same-boundary group as one shared-𝒯 session (the paper's
    /// batched implementation) instead of one session per request.
    ///
    /// Note on reproducibility: a shared lane is seeded from its *first*
    /// member's seed (like the fixed path's batch seed), so a request's
    /// output then depends on admission grouping. Set this to `false` when
    /// per-request (src, seed) → tokens reproducibility matters more than
    /// the shared-𝒯 call amortization.
    pub shared_tau_groups: bool,
}

impl Default for SchedPolicy {
    fn default() -> Self {
        SchedPolicy {
            max_batch: 16,
            window: Duration::from_millis(20),
            shared_tau_groups: true,
        }
    }
}

/// Fault handling at the scheduler's denoiser call sites (separate from
/// [`SchedPolicy`], which stays a pure admission policy).
///
/// A denoiser call is a pure function of `(x, t, src)` — per-row RNG
/// streams live in the session, not the network — so retrying a transient
/// fault is byte-identical to the fault never having happened (pinned for
/// all ten `SamplerKind`s by `tests/chaos.rs`). The escalation ladder on
/// top of that: **retry** transient faults up to `max_retries` with
/// exponential backoff; a call that still fails (or fails fatally — see
/// [`is_transient`]) triggers **lane isolation**, re-running the boundary
/// lane by lane so only the lanes the fault follows are failed; and once
/// `breaker_threshold` consecutive attempts have failed, the **circuit
/// breaker opens**: the scheduler parks (lanes halt *at* a boundary,
/// untouched and salvageable via [`Scheduler::evacuate`]) and only sends
/// a probe call after `breaker_cooldown`. See `docs/robustness.md`.
#[derive(Debug, Clone, Copy)]
pub struct FaultPolicy {
    /// Retries per denoiser call for transient faults (0 = fail fast).
    pub max_retries: u32,
    /// Backoff before the first retry; doubles per retry up to
    /// `max_backoff`. `Duration::ZERO` retries immediately.
    pub backoff: Duration,
    /// Ceiling on the exponential backoff.
    pub max_backoff: Duration,
    /// A *successful* call slower than this is counted as a transient
    /// fault for breaker accounting (its result is still used — the call
    /// is pure, only the shard's health is in question). `None` = never.
    pub call_timeout: Option<Duration>,
    /// Consecutive failed attempts (across retries and boundaries) that
    /// open the breaker.
    pub breaker_threshold: u32,
    /// How long an open breaker parks before letting one probe through.
    pub breaker_cooldown: Duration,
}

impl Default for FaultPolicy {
    fn default() -> Self {
        FaultPolicy {
            max_retries: 3,
            backoff: Duration::from_millis(5),
            max_backoff: Duration::from_millis(100),
            call_timeout: None,
            breaker_threshold: 5,
            breaker_cooldown: Duration::from_millis(250),
        }
    }
}

/// A queued request, generic over the caller's payload (response channel,
/// test id, …). Lifecycle fields are optional: a bare payload request
/// (no sink, no deadline, [`Priority::Normal`]) behaves exactly like the
/// pre-lifecycle scheduler.
pub struct Pending<P> {
    pub src: Option<String>,
    pub seed: u64,
    /// per-request sampler override; `None` = the scheduler's default
    pub cfg: Option<SamplerConfig>,
    pub enqueued: Instant,
    /// absolute deadline; queue-side expiry is checked before admission,
    /// in-flight expiry at every boundary
    pub deadline: Option<Instant>,
    pub priority: Priority,
    /// lifecycle sink (`Admitted`/`Progress`/terminal events + the
    /// cancellation flag); `None` = no client subscribed
    pub ctl: Option<TicketSink>,
    /// Tenant attribution carried for the request's whole life so stolen
    /// / donated / salvaged requests keep their identity (submit-side
    /// stats counted it already; the scheduler itself never reads it).
    pub tenant: Option<String>,
    /// Does the caller consume [`Finished::result`]? `false` (ticket-only
    /// requests: the sink is the sole reader) lets retirement **move** the
    /// [`GenOutput`] into the sink instead of cloning it — see
    /// [`Delivery::SinkOwned`].
    pub wants_result: bool,
    /// Opt in to confidence-based early retirement (`docs/tiers.md`): at
    /// every boundary after the request's row advanced, the scheduler asks
    /// its session whether the row's remaining events are provably no-ops
    /// and, if so, finishes the request right there — refunding the
    /// remaining denoiser calls. `false` (the default and every untiered
    /// path) keeps the full schedule, byte-identical to the pre-tier
    /// scheduler; the front door sets it for `Balanced`/`Turbo` requests.
    pub early_retire: bool,
    pub payload: P,
}

impl<P> Pending<P> {
    /// A plain request: no deadline, no lifecycle sink, normal priority,
    /// result delivered through [`Finished::result`].
    pub fn new(
        src: Option<String>,
        seed: u64,
        cfg: Option<SamplerConfig>,
        payload: P,
    ) -> Pending<P> {
        Pending {
            src,
            seed,
            cfg,
            enqueued: Instant::now(),
            deadline: None,
            priority: Priority::Normal,
            ctl: None,
            tenant: None,
            wants_result: true,
            early_retire: false,
            payload,
        }
    }
}

struct Member<P> {
    payload: P,
    ctl: Option<TicketSink>,
    wants_result: bool,
    deadline: Option<Instant>,
    enqueued: Instant,
    admitted: Instant,
    early_retire: bool,
}

/// One co-admitted group: a session of `members.len()` sequences (the two
/// stay index-aligned for the lane's whole life — an early-departing
/// member takes its session row with it via
/// [`SamplerSession::evict_slot`]). Source ids are flattened into a
/// [`TokenBatch`] once at admission, so every subsequent NFE call gathers
/// them with a single memcpy instead of re-cloning one `Vec` per sequence
/// per call; eviction compacts the same buffer.
struct Lane<P> {
    session: SamplerSession,
    src_ids: Option<TokenBatch>,
    members: Vec<Member<P>>,
    admitted_boundary: u64,
    /// admission key of this lane's members. Normally equal to the
    /// scheduler-wide in-flight key, but tracked per lane so a lane can
    /// be donated to (or adopted from) another shard, where the
    /// surrounding in-flight key may differ (see [`Scheduler::adopt_lane`]).
    key: SpecKey,
}

impl<P> Lane<P> {
    /// Denoiser calls this lane still needs. The session's per-row event
    /// ladders keep `total_events()` exact across evictions and splits,
    /// so this never over-values a narrowed lane; the `saturating_sub`
    /// plus debug assert guard the serving thread against any future
    /// regression where a stale total could dip below the cursor.
    fn remaining_events(&self) -> usize {
        let total = self.session.total_events();
        let nfe = self.session.nfe();
        debug_assert!(nfe <= total, "lane nfe {nfe} exceeds total_events {total}");
        total.saturating_sub(nfe)
    }
}

/// A whole in-flight lane packed for cross-shard donation: the live
/// [`SamplerSession`] (its `AlgState`, per-row RNG streams, and
/// event-ladder cursor travel by move — session state is pure host data,
/// so the handoff is byte-exact by construction), the pre-flattened
/// source [`TokenBatch`] moved flat, and every member with its lifecycle
/// sink, deadline, priority accounting, and timestamps intact.
///
/// Produced by [`Scheduler::donate_lane`] on the donor **between two
/// denoiser calls** (the transition-time boundary — the predetermined
/// event ladder makes the handoff point well-defined for every
/// `SamplerKind`), shipped over the shard channel, and resumed by
/// [`Scheduler::adopt_lane`] on the thief, which continues the session
/// mid-schedule at the exact event the donor would have fired next.
/// Dropping an undelivered `DonatedLane` is fail-safe: each member's
/// sink drop-guard fails its ticket, so requests are never silently
/// lost.
pub struct DonatedLane<P> {
    session: SamplerSession,
    src_ids: Option<TokenBatch>,
    members: Vec<Member<P>>,
    key: SpecKey,
}

impl<P> DonatedLane<P> {
    /// Number of sequences (= live members) travelling in this lane.
    pub fn width(&self) -> usize {
        self.session.batch()
    }

    /// Denoiser calls this lane still needs — the donation cost model's
    /// currency: `total_events()` minus the event-ladder cursors, known
    /// exactly because 𝒯 is predetermined and re-merged over exactly the
    /// rows travelling in this lane (evictions and splits included).
    pub fn remaining_events(&self) -> usize {
        let total = self.session.total_events();
        let nfe = self.session.nfe();
        debug_assert!(nfe <= total, "donated lane nfe {nfe} exceeds total_events {total}");
        total.saturating_sub(nfe)
    }

    /// Admission key of the lane's members.
    pub fn key(&self) -> &SpecKey {
        &self.key
    }

    /// Re-point every member sink's load gauge at the thief shard
    /// (exactly-once terminal decrement follows the lane).
    pub(crate) fn retarget_load(&self, to: &std::sync::Arc<std::sync::atomic::AtomicUsize>) {
        for m in &self.members {
            if let Some(ctl) = &m.ctl {
                ctl.retarget_load(to.clone());
            }
        }
    }
}

/// Observable lane state (tests, debugging).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneInfo {
    pub width: usize,
    /// boundary index (completed-call count) at which the lane joined
    pub admitted_boundary: u64,
    /// denoiser calls this lane has consumed so far
    pub nfe: usize,
}

/// How a request left the scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Generation completed; `result` holds the output.
    Done,
    /// Engine or sampler-spec failure; `result` holds the error.
    Failed,
    /// Dropped on [`Ticket::cancel`](super::request::Ticket::cancel) —
    /// queue-side before admission, or at a boundary while in flight.
    Cancelled,
    /// The deadline passed before completion.
    DeadlineExceeded,
}

/// Where a completed request's [`GenOutput`] ended up.
#[derive(Debug)]
pub enum Delivery {
    /// The caller owns the output ([`Pending::wants_result`] was `true`).
    Output(GenOutput),
    /// The output was **moved** into the request's ticket sink
    /// (`wants_result == false`), eliminating the per-request clone the
    /// old always-both delivery paid; only the accounting travels here.
    SinkOwned { nfe: usize, elapsed: Duration },
}

impl Delivery {
    /// NN calls of the batch this request was generated in.
    pub fn nfe(&self) -> usize {
        match self {
            Delivery::Output(out) => out.nfe,
            Delivery::SinkOwned { nfe, .. } => *nfe,
        }
    }

    /// Generation wall time (excludes queue wait).
    pub fn elapsed(&self) -> Duration {
        match self {
            Delivery::Output(out) => out.elapsed,
            Delivery::SinkOwned { elapsed, .. } => *elapsed,
        }
    }

    /// The output, when the caller owns it.
    pub fn output(&self) -> Option<&GenOutput> {
        match self {
            Delivery::Output(out) => Some(out),
            Delivery::SinkOwned { .. } => None,
        }
    }

    /// Consume into the output; errors when the sink took ownership.
    pub fn into_output(self) -> Result<GenOutput> {
        match self {
            Delivery::Output(out) => Ok(out),
            Delivery::SinkOwned { .. } => {
                Err(anyhow!("output was delivered through the ticket sink"))
            }
        }
    }
}

/// A retired (or failed/dropped) request handed back to the caller. The
/// lifecycle sink, if any, has already received the matching terminal
/// event by the time this is returned from [`Scheduler::tick`].
pub struct Finished<P> {
    pub payload: P,
    pub result: Result<Delivery>,
    /// queue wait: enqueue → admission into a lane (or → drop, for
    /// requests that never made it in)
    pub wait: Duration,
    pub outcome: Outcome,
}

/// Admission-compatibility key: two requests may share an in-flight batch
/// iff their effective sampler configs agree on everything that shapes the
/// event ladder and the update rule.
///
/// A plain derived-`PartialEq` struct (no heap) — it replaces a
/// `format!`-built `String` that was allocated per pending request on
/// every `admit()` pass. Holding the full [`TransitionSpec`] (not just its
/// name) also stops e.g. `Beta(15, 7)` and `Beta(2, 3)` requests from
/// being merged into one ladder. Derived float equality means a config
/// carrying NaN (already nonsensical for sampling) is never equal to
/// itself and degrades to singleton lanes — correct output, just no
/// batching for that pathological request.
///
/// The [`Router`](super::router::Router) uses the same key for
/// spec-affinity placement: requests sharing a key prefer the engine
/// already serving that key, maximizing shared-𝒯 batching.
#[derive(Debug, Clone, PartialEq)]
pub struct SpecKey {
    kind: SamplerKind,
    steps: usize,
    spec: TransitionSpec,
    order: TransitionOrder,
    temperature: f32,
    shared_tau: bool,
    /// Turbo truncation cap — it reshapes the event ladder, so capped and
    /// uncapped requests must not share a lane.
    max_nfe: Option<usize>,
}

impl SpecKey {
    /// The admission key of a sampler config.
    pub fn of(cfg: &SamplerConfig) -> SpecKey {
        SpecKey {
            kind: cfg.kind,
            steps: cfg.steps,
            spec: cfg.spec.clone(),
            order: cfg.order,
            temperature: cfg.temperature,
            shared_tau: cfg.shared_tau,
            max_nfe: cfg.max_nfe,
        }
    }
}

/// Persistent per-tick buffers: the batch the denoiser sees is gathered
/// into these (one memcpy per lane) and the logits come back into the same
/// `LogitsBuf` every call — after the first tick, steady-state `tick()`
/// performs zero heap allocations outside the denoiser itself for the
/// non-sorting samplers (pinned by `steady_state_tick_is_allocation_free`
/// below, including with an active event subscriber; the score-ranking
/// kinds keep std's stable-sort scratch — see `docs/perf.md`). Lifecycle
/// emission stays heap-silent because each sink overwrites a reused
/// snapshot buffer instead of queueing events.
#[derive(Default)]
struct StepScratch {
    xs: TokenBatch,
    ts: Vec<f32>,
    srcs: TokenBatch,
    logits: LogitsBuf,
}

/// The continuous scheduler. Owns the engine; single-threaded by design
/// (PJRT handles are not `Send`) — the server wraps it in a thread + queue.
pub struct Scheduler<P> {
    engine: Engine,
    default_cfg: SamplerConfig,
    policy: SchedPolicy,
    pending: VecDeque<Pending<P>>,
    lanes: Vec<Lane<P>>,
    /// spec key of the in-flight batch (`None` when no lanes are active)
    key: Option<SpecKey>,
    /// completed denoiser calls — the boundary clock
    boundary: u64,
    /// denoiser calls in which some lane moved zero rows — per-row event
    /// ladders make this impossible (a lane only fires at a surviving
    /// row's event), so serving surfaces it as `ghost_events_fired` and
    /// CI gates it at 0 for the narrowing scenario
    ghost_events: u64,
    /// shutdown/drain mode: ignore the grouping window
    flushing: bool,
    /// reusable per-tick buffers (see [`StepScratch`])
    scratch: StepScratch,
    /// retry/breaker policy for the denoiser call sites
    fault: FaultPolicy,
    /// cumulative: transient-fault retries performed
    retries: u64,
    /// cumulative: attempts that failed transiently (incl. slow calls
    /// counted under [`FaultPolicy::call_timeout`])
    faults_transient: u64,
    /// cumulative: attempts that failed fatally
    faults_fatal: u64,
    /// consecutive failed attempts; reset by any clean success
    fail_streak: u32,
    /// circuit breaker: while open, [`Self::step`] parks — lanes halt at
    /// the boundary, byte-exactly salvageable via [`Self::evacuate`]
    breaker_open: bool,
    /// when the breaker (last) opened, for the cooldown-then-probe cycle
    breaker_opened_at: Option<Instant>,
    /// cumulative: members finished by confidence-based early retirement
    /// ([`Pending::early_retire`], `docs/tiers.md`)
    early_retired: u64,
    /// cumulative: merged events dropped by Turbo truncation across every
    /// lane built here ([`SamplerConfig::max_nfe`])
    turbo_truncated: u64,
}

impl<P> Scheduler<P> {
    pub fn new(engine: Engine, default_cfg: SamplerConfig, policy: SchedPolicy) -> Scheduler<P> {
        Scheduler {
            engine,
            default_cfg,
            policy,
            pending: VecDeque::new(),
            lanes: Vec::new(),
            key: None,
            boundary: 0,
            ghost_events: 0,
            flushing: false,
            scratch: StepScratch::default(),
            fault: FaultPolicy::default(),
            retries: 0,
            faults_transient: 0,
            faults_fatal: 0,
            fail_streak: 0,
            breaker_open: false,
            breaker_opened_at: None,
            early_retired: 0,
            turbo_truncated: 0,
        }
    }

    /// Replace the default [`FaultPolicy`] (builder style).
    pub fn with_fault_policy(mut self, fault: FaultPolicy) -> Scheduler<P> {
        self.fault = fault;
        self
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn policy(&self) -> SchedPolicy {
        self.policy
    }

    /// Completed-call count — admissions only ever happen between calls,
    /// i.e. at a value of this clock.
    pub fn boundary(&self) -> u64 {
        self.boundary
    }

    /// Denoiser calls in which a lane advanced without moving any row.
    /// Per-row event ladders retire a departed row's unique events with
    /// it, so this stays 0 (surfaced as `ServerStats::ghost_events_fired`
    /// and gated in CI for the narrowing bench scenario).
    pub fn ghost_events(&self) -> u64 {
        self.ghost_events
    }

    /// Cumulative transient-fault retries performed at the call sites.
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// Cumulative denoiser attempts that failed transiently (including
    /// slow-but-successful calls under [`FaultPolicy::call_timeout`]).
    pub fn faults_transient(&self) -> u64 {
        self.faults_transient
    }

    /// Cumulative denoiser attempts that failed fatally.
    pub fn faults_fatal(&self) -> u64 {
        self.faults_fatal
    }

    /// Members finished by confidence-based early retirement — their
    /// remaining events were provably no-ops and were refunded
    /// (`docs/tiers.md`).
    pub fn early_retired(&self) -> u64 {
        self.early_retired
    }

    /// Merged events dropped by Turbo truncation across every lane built
    /// on this scheduler ([`SamplerConfig::max_nfe`]).
    pub fn turbo_truncated(&self) -> u64 {
        self.turbo_truncated
    }

    /// True while the circuit breaker is open: [`Self::tick`] makes no
    /// denoiser calls and admits nothing; in-flight lanes sit parked at a
    /// boundary (byte-exactly resumable), waiting for a cooldown probe to
    /// close the breaker or for a supervisor to [`Self::evacuate`] them.
    pub fn breaker_open(&self) -> bool {
        self.breaker_open
    }

    fn open_breaker(&mut self) {
        self.breaker_open = true;
        self.breaker_opened_at = Some(Instant::now());
    }

    fn close_breaker(&mut self) {
        self.breaker_open = false;
        self.breaker_opened_at = None;
    }

    /// Failover: pack **every** in-flight lane for adoption elsewhere.
    /// Unlike [`Self::donate_lane`] this never refuses — the caller has
    /// decided this scheduler's engine is not coming back soon, so
    /// zero-sum and near-retirement considerations don't apply. Lanes are
    /// parked at a boundary (between two denoiser calls), so each handoff
    /// is byte-exact for the same reason donation is.
    pub fn evacuate(&mut self) -> Vec<DonatedLane<P>> {
        self.key = None;
        self.lanes
            .drain(..)
            .map(|lane| DonatedLane {
                session: lane.session,
                src_ids: lane.src_ids,
                members: lane.members,
                key: lane.key,
            })
            .collect()
    }

    /// Failover: remove every queued request, queue order preserved, for
    /// re-enqueueing on a healthy scheduler.
    pub fn drain_pending(&mut self) -> Vec<Pending<P>> {
        self.pending.drain(..).collect()
    }

    /// Terminal failure: resolve everything queued and in flight as
    /// [`Outcome::Failed`] with `msg`. Used when a shard dies for good
    /// (engine restart failed) and nothing is left to salvage to.
    pub fn abort_all(&mut self, msg: &str) -> Vec<Finished<P>> {
        let mut out = Vec::new();
        for p in std::mem::take(&mut self.pending) {
            if let Some(ctl) = &p.ctl {
                ctl.finish_failed(msg);
            }
            out.push(Finished {
                payload: p.payload,
                result: Err(anyhow!("{msg}")),
                wait: p.enqueued.elapsed(),
                outcome: Outcome::Failed,
            });
        }
        for lane in std::mem::take(&mut self.lanes) {
            fail_members(lane.members, msg, &mut out);
        }
        self.key = None;
        out
    }

    /// Swap in a freshly built engine after a shard restart. The old
    /// engine's [`NfeCounter`](crate::metrics::NfeCounter) is carried
    /// over (nn-call/request accounting is cumulative per shard — exact
    /// NFE conservation across a restart is what `tests/chaos.rs` pins),
    /// the failure streak resets, and the breaker closes. The cumulative
    /// fault counters survive: they are career totals, not incident
    /// state.
    pub fn reset_engine(&mut self, mut engine: Engine) {
        engine.nfe = self.engine.nfe.clone();
        self.engine = engine;
        self.fail_streak = 0;
        self.close_breaker();
    }

    /// Total in-flight sequences (sum of lane widths). Lane widths shrink
    /// when members depart early (slot eviction at the boundary), so this
    /// equals the number of live requests in flight.
    pub fn in_flight(&self) -> usize {
        self.lanes.iter().map(|l| l.session.batch()).sum()
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Number of in-flight lanes (co-admitted groups). What the
    /// rebalancer's donor filter reads: a shard with ≥ 2 lanes (or ≥ 1
    /// lane plus queued work) can donate one without going idle.
    pub fn lane_count(&self) -> usize {
        self.lanes.len()
    }

    /// Remaining denoiser calls the in-flight lanes still owe — the sum
    /// of every lane's unfired merged-ladder events. Exact, not an
    /// estimate: 𝒯 is predetermined, so each lane's remaining event
    /// count is known the moment it is admitted. This is the backlog
    /// figure the telemetry board publishes for admission's pace
    /// projection.
    pub fn backlog_events(&self) -> u64 {
        self.lanes.iter().map(|l| l.remaining_events() as u64).sum()
    }

    /// Queued requests per priority class, indexed `[Low, Normal, High]`
    /// — the instantaneous depths behind `ServerStats::queued_*`.
    pub fn queue_depths(&self) -> [usize; 3] {
        let mut d = [0usize; 3];
        for p in &self.pending {
            d[match p.priority {
                Priority::Low => 0,
                Priority::Normal => 1,
                Priority::High => 2,
            }] += 1;
        }
        d
    }

    /// Donate up to `max` queued requests to another shard (cross-shard
    /// work stealing — the donor side). All stolen requests share one
    /// [`SpecKey`] so the thief can still batch them into one shared-𝒯
    /// lane. The steal key is chosen from the **back** of the
    /// priority-ordered queue (lowest priority, youngest), preferring a
    /// key that differs from the in-flight batch's key — requests that
    /// match it would refill this shard's own slots at the next boundary
    /// anyway. Every queued request with the chosen key is then eligible
    /// (youngest taken first), wherever it sits in the queue, so a run
    /// within `max` moves whole — but a run *larger* than `max` is
    /// split: its youngest `max` members move and the oldest keep their
    /// queue positions on the donor (both halves still batch shared-𝒯 on
    /// their own shard). Returned requests keep their enqueue time,
    /// deadline, priority, and sink; the caller re-enqueues them
    /// elsewhere.
    pub fn steal_pending(&mut self, max: usize) -> Vec<Pending<P>> {
        if max == 0 || self.pending.is_empty() {
            return Vec::new();
        }
        // pick the steal key: scan from the back for the first request
        // whose key differs from the in-flight key; fall back to the back
        // request's key when everything matches it
        let steal_key = self
            .pending
            .iter()
            .rev()
            .map(|p| self.effective_key(p))
            .find(|k| self.key.as_ref() != Some(k))
            .unwrap_or_else(|| {
                self.effective_key(self.pending.back().expect("non-empty"))
            });
        let mut stolen = Vec::new();
        let mut i = self.pending.len();
        while i > 0 && stolen.len() < max {
            i -= 1;
            if self.effective_key(&self.pending[i]) == steal_key {
                let p = self.pending.remove(i).expect("index in bounds");
                stolen.push(p);
            }
        }
        // removal walked back-to-front; restore queue order for the thief
        stolen.reverse();
        stolen
    }

    pub fn has_work(&self) -> bool {
        !self.lanes.is_empty() || !self.pending.is_empty()
    }

    pub fn lane_info(&self) -> Vec<LaneInfo> {
        self.lanes
            .iter()
            .map(|l| LaneInfo {
                width: l.session.batch(),
                admitted_boundary: l.admitted_boundary,
                nfe: l.session.nfe(),
            })
            .collect()
    }

    /// Spec key of the in-flight batch, if any.
    pub fn current_key(&self) -> Option<&SpecKey> {
        self.key.as_ref()
    }

    /// Queue a request; it will be admitted at a future boundary. The
    /// queue is ordered by [`Priority`] (higher first), FIFO within a
    /// class.
    pub fn enqueue(&mut self, req: Pending<P>) {
        let mut idx = self.pending.len();
        while idx > 0 && self.pending[idx - 1].priority < req.priority {
            idx -= 1;
        }
        self.pending.insert(idx, req);
    }

    /// Enter drain mode: admit pending work immediately (ignore the
    /// grouping window) until the queue is empty.
    pub fn flush(&mut self) {
        self.flushing = true;
    }

    /// When idle with pending work, the instant by which the scheduler
    /// must wake: the grouping window of the oldest pending request, or
    /// the earliest queued deadline, whichever comes first. `None` while
    /// lanes are active (the scheduler should keep stepping) or when
    /// nothing is pending.
    pub fn next_deadline(&self) -> Option<Instant> {
        if !self.lanes.is_empty() {
            return None;
        }
        // oldest enqueue, not front: priority insertion can put a younger
        // request at the head of the queue
        let window = self.oldest_enqueue().map(|e| e + self.policy.window);
        let deadline = self.pending.iter().filter_map(|p| p.deadline).min();
        match (window, deadline) {
            (Some(w), Some(d)) => Some(w.min(d)),
            (w, d) => w.or(d),
        }
    }

    /// Enqueue instant of the longest-waiting pending request — the queue
    /// is priority-ordered, so this is not necessarily the front.
    fn oldest_enqueue(&self) -> Option<Instant> {
        self.pending.iter().map(|p| p.enqueued).min()
    }

    fn effective_key(&self, p: &Pending<P>) -> SpecKey {
        SpecKey::of(p.cfg.as_ref().unwrap_or(&self.default_cfg))
    }

    /// Boundary enforcement of cancellation and deadlines. Queue-side:
    /// cancelled/expired requests are dropped before they can be admitted.
    /// Lane-side: an early-departing member's terminal event fires now and
    /// its session row is **evicted** — the lane narrows in place
    /// ([`SamplerSession::evict_slot`] + src compaction), so the very next
    /// denoiser call is one row cheaper and the freed slot can refill at
    /// this same boundary. Survivors are byte-exact (per-row RNG streams;
    /// pinned by `tests/narrowing.rs`). A lane whose last member departs
    /// is dropped whole.
    fn reap(&mut self, out: &mut Vec<Finished<P>>) {
        if self.pending.is_empty() && self.lanes.is_empty() {
            return;
        }
        let now = Instant::now();
        // queue side: never admit a dead request
        let mut i = 0;
        while i < self.pending.len() {
            let cancelled =
                self.pending[i].ctl.as_ref().is_some_and(|c| c.is_cancelled());
            let expired = self.pending[i].deadline.is_some_and(|d| now >= d);
            if !(cancelled || expired) {
                i += 1;
                continue;
            }
            let p = self.pending.remove(i).expect("index in bounds");
            let wait = p.enqueued.elapsed();
            out.push(resolve_drop(p.payload, p.ctl.as_ref(), cancelled, wait));
        }
        // lane side: boundary cancellation narrows the lane in place
        let mut li = 0;
        while li < self.lanes.len() {
            let lane = &mut self.lanes[li];
            let mut j = 0;
            while j < lane.members.len() {
                let m = &lane.members[j];
                let cancelled = m.ctl.as_ref().is_some_and(|c| c.is_cancelled());
                let expired = m.deadline.is_some_and(|d| now >= d);
                if !(cancelled || expired) {
                    j += 1;
                    continue;
                }
                let m = lane.members.remove(j);
                out.push(resolve_drop(
                    m.payload,
                    m.ctl.as_ref(),
                    cancelled,
                    m.admitted.duration_since(m.enqueued),
                ));
                if lane.members.is_empty() {
                    // last member gone: the whole lane dies below
                    break;
                }
                // members and session rows are index-aligned: row j now
                // belongs to the departed member — compact it out
                lane.session.evict_slot(j).expect("evict within lane bounds");
                if let Some(src) = &mut lane.src_ids {
                    src.narrow_remove(j);
                }
            }
            if self.lanes[li].members.is_empty() {
                self.lanes.remove(li);
            } else {
                li += 1;
            }
        }
        if self.lanes.is_empty() {
            self.key = None;
        }
    }

    /// Admit pending requests into free slots. Called only between calls
    /// (from [`Self::tick`]) — the transition-time-boundary rule. Returns
    /// requests resolved at admission: failed (bad spec for this engine)
    /// or degenerate zero-call completions.
    fn admit(&mut self) -> Vec<Finished<P>> {
        let mut resolved = Vec::new();
        if self.pending.is_empty() {
            return resolved;
        }
        if self.breaker_open {
            let cooled = self
                .breaker_opened_at
                .map(|at| at.elapsed() >= self.fault.breaker_cooldown)
                .unwrap_or(true);
            if !cooled {
                // a parked scheduler admits nothing: queued requests stay
                // queued (cheap to evacuate to a healthy shard as-is)
                // instead of being promoted into lanes that cannot progress
                return resolved;
            }
            // half-open after cooldown: admit normally so the probe
            // boundary in step() has a batch to try even when every
            // parked lane was evacuated or reaped in the meantime
        }
        if self.lanes.is_empty() {
            // an idle scheduler starts a batch when the queue fills the
            // capacity, the oldest request has waited out the window, or
            // we are draining
            let full = self.pending.len() >= self.policy.max_batch;
            let waited = self
                .oldest_enqueue()
                .map(|e| e.elapsed() >= self.policy.window)
                .unwrap_or(false);
            if !(full || waited || self.flushing) {
                return resolved;
            }
            self.key = None;
        }

        loop {
            let free = self.policy.max_batch.saturating_sub(self.in_flight());
            if free == 0 {
                break;
            }
            // strict priority-FIFO: take the longest front run with a
            // matching key
            let mut group: Vec<Pending<P>> = Vec::new();
            while group.len() < free {
                let Some(front) = self.pending.front() else { break };
                let fkey = self.effective_key(front);
                match &self.key {
                    Some(k) if *k != fkey => break,
                    _ => {}
                }
                if self.key.is_none() {
                    self.key = Some(fkey);
                }
                group.push(self.pending.pop_front().expect("front exists"));
            }
            if group.is_empty() {
                break;
            }
            if self.policy.shared_tau_groups {
                self.push_lane(group, &mut resolved);
            } else {
                for req in group {
                    self.push_lane(vec![req], &mut resolved);
                }
            }
            if self.lanes.is_empty() {
                // the whole group resolved without a lane (bad spec /
                // zero-call): drop its key so the next front request is
                // considered this same tick instead of after its window
                self.key = None;
            }
        }
        if self.lanes.is_empty() {
            self.key = None;
        }
        resolved
    }

    /// Build one lane (one session) from a co-admitted group. Requests that
    /// resolve without a lane (bad spec, zero-call specs) go to `out`.
    fn push_lane(&mut self, group: Vec<Pending<P>>, out: &mut Vec<Finished<P>>) {
        let cfg = group[0].cfg.clone().unwrap_or_else(|| self.default_cfg.clone());
        let key = SpecKey::of(&cfg);
        let width = group.len();
        let seed = group[0].seed;
        let session =
            match SamplerSession::new(self.engine.denoiser().config(), &cfg, width, seed) {
                Ok(s) => s,
                Err(e) => {
                    let msg = format!("{e:#}");
                    for p in group {
                        if let Some(ctl) = &p.ctl {
                            ctl.finish_failed(&msg);
                        }
                        out.push(Finished {
                            payload: p.payload,
                            result: Err(anyhow!("{msg}")),
                            wait: p.enqueued.elapsed(),
                            outcome: Outcome::Failed,
                        });
                    }
                    return;
                }
            };
        // counted at construction (the only place truncation happens);
        // donated lanes were already counted by their builder
        self.turbo_truncated += session.truncated_events() as u64;
        if session.is_done() {
            // degenerate spec (e.g. 0 steps): nothing to denoise — complete
            // immediately with x_T as drawn
            self.engine.nfe.record_batch();
            let nfe = session.nfe();
            let res = session.into_result();
            for (i, p) in group.into_iter().enumerate() {
                let wait = p.enqueued.elapsed();
                self.engine.nfe.record_request(nfe, wait);
                let tokens = res.tokens[i].clone();
                let output = GenOutput {
                    text: self.engine.decode(&tokens),
                    tokens,
                    nfe,
                    // zero denoiser calls were made for this request
                    elapsed: Duration::ZERO,
                };
                if let Some(ctl) = &p.ctl {
                    ctl.set_admitted();
                }
                let delivered = deliver(p.ctl.as_ref(), p.wants_result, output);
                out.push(Finished {
                    payload: p.payload,
                    result: Ok(delivered),
                    wait,
                    outcome: Outcome::Done,
                });
            }
            return;
        }
        let src_ids = if self.engine.conditional() {
            // pre-flatten once at admission; the per-NFE gather is then a
            // single memcpy into the step scratch
            let src_len = self.engine.denoiser().config().src_len;
            let mut tb = TokenBatch::new(src_len);
            for p in &group {
                tb.push_row(&self.engine.encode_src(p.src.as_deref().unwrap_or("")));
            }
            Some(tb)
        } else {
            None
        };
        let now = Instant::now();
        let members = group
            .into_iter()
            .map(|p| {
                if let Some(ctl) = &p.ctl {
                    ctl.set_admitted();
                }
                Member {
                    payload: p.payload,
                    ctl: p.ctl,
                    wants_result: p.wants_result,
                    deadline: p.deadline,
                    enqueued: p.enqueued,
                    admitted: now,
                    early_retire: p.early_retire,
                }
            })
            .collect();
        self.lanes.push(Lane {
            session,
            src_ids,
            members,
            admitted_boundary: self.boundary,
            key,
        });
    }

    /// Donor side of in-flight lane donation: pack one whole lane for
    /// another shard and remove it from this scheduler. Must only be
    /// called between two denoiser calls (the server handles donation
    /// requests exactly there), so the handoff sits on a transition-time
    /// boundary: the packed session's next event is precisely the call
    /// the donor would have made next.
    ///
    /// The lane is chosen by the cost model in
    /// [`rebalancer`](super::rebalancer): the lane with the most
    /// **remaining** denoiser calls (`total_events()` minus the event
    /// cursors — exact even after narrowing, because per-row ladders
    /// re-merge over the surviving rows) moves, since it transfers the
    /// most future work per handoff. Donation is refused (`None`) when
    ///
    /// * no lane has at least `min_remaining` calls left (near-retirement
    ///   lanes are not worth the move — they free their slots here in a
    ///   tick or two anyway), or
    /// * this scheduler holds exactly one lane and nothing is queued:
    ///   moving the only in-flight work would just idle the donor and
    ///   busy the thief (zero-sum), not increase parallelism. (When that
    ///   one lane is wide, [`Self::donate_rows`] can still split it.)
    pub fn donate_lane(&mut self, min_remaining: usize) -> Option<DonatedLane<P>> {
        if self.lanes.len() == 1 && self.pending.is_empty() {
            return None;
        }
        let costs: Vec<LaneCost> = self
            .lanes
            .iter()
            .map(|l| LaneCost { remaining: l.remaining_events(), width: l.session.batch() })
            .collect();
        let i = pick_donation(&costs, min_remaining)?;
        let lane = self.lanes.remove(i);
        if self.lanes.is_empty() {
            self.key = None;
        }
        Some(DonatedLane {
            session: lane.session,
            src_ids: lane.src_ids,
            members: lane.members,
            key: lane.key,
        })
    }

    /// Split donation: carve the back half of the widest splittable lane
    /// into a [`DonatedLane`] and keep the front half serving here. This
    /// is the rebalancer's third movement — it covers exactly the gap the
    /// other two leave: one wide lane holding most of a shard's work,
    /// with an empty queue (nothing to steal) and no second lane to
    /// donate. Splitting is never zero-sum, because the donor keeps half
    /// the rows.
    ///
    /// Mechanics: [`SamplerSession::split_rows`] moves the rows with
    /// their event ladders and forked RNG streams, the members and
    /// pre-flattened src rows partition index-aligned, and both halves
    /// resume byte-exactly at the next boundary (pinned per kind by
    /// `tests/rebalance.rs`). Each half's `total_events()` re-merges over
    /// its own rows, so for per-seq-𝒯 lanes the split can *shrink* the
    /// combined remaining-call count.
    ///
    /// Refused (`None`) when no lane has width ≥ 2, or when no such lane
    /// has at least `min_remaining` calls left.
    pub fn donate_rows(&mut self, min_remaining: usize) -> Option<DonatedLane<P>> {
        let floor = min_remaining.max(1);
        let i = self
            .lanes
            .iter()
            .enumerate()
            .filter(|(_, l)| l.session.batch() >= 2 && l.remaining_events() >= floor)
            .max_by_key(|(_, l)| (l.session.batch(), l.remaining_events()))
            .map(|(i, _)| i)?;
        let lane = &mut self.lanes[i];
        let w = lane.session.batch();
        let half = w / 2;
        let rows: Vec<usize> = (w - half..w).collect();
        let session = lane
            .session
            .split_rows(&rows)
            .expect("split of a width >= 2 lane's back half is valid");
        let members = lane.members.split_off(w - half);
        let src_ids = lane.src_ids.as_mut().map(|src| {
            let mut tb = TokenBatch::new(src.cols());
            for r in w - half..w {
                tb.push_row(src.row(r));
            }
            for r in (w - half..w).rev() {
                src.narrow_remove(r);
            }
            tb
        });
        Some(DonatedLane { session, src_ids, members, key: lane.key.clone() })
    }

    /// Thief side of lane donation: resume a donated lane mid-schedule.
    /// The session continues at the exact event the donor would have
    /// fired next, so survivors are byte-identical to the undonated run
    /// (pinned per kind by `tests/rebalance.rs`).
    ///
    /// Adoption is total — it never refuses. The rebalancer only donates
    /// to idle shards, so the adopted key normally *becomes* the
    /// in-flight key; in the race window where a submit landed on the
    /// thief first, the donated lane coexists with a different in-flight
    /// key. That is mechanically sound — each lane is its own session and
    /// the denoiser takes a per-sequence time vector — it only forgoes
    /// shared-𝒯 amortization for the adopted lane, and queue admission
    /// keeps matching against the primary key.
    pub fn adopt_lane(&mut self, lane: DonatedLane<P>) {
        if self.key.is_none() {
            self.key = Some(lane.key.clone());
        }
        self.lanes.push(Lane {
            session: lane.session,
            src_ids: lane.src_ids,
            members: lane.members,
            admitted_boundary: self.boundary,
            key: lane.key,
        });
    }

    /// One denoiser call over every active lane: each lane advances by one
    /// event of its own ladder (its own time, via the per-sequence time
    /// vector), finished lanes retire and their requests are returned.
    ///
    /// The batch is gathered into the persistent [`StepScratch`] (one
    /// memcpy per lane, no per-row clones) and the logits are written back
    /// into the same reusable buffer; each lane then advances on a
    /// `narrow`ed view of its own rows, after which every live subscribed
    /// member gets a progress snapshot (reused buffer — no allocation).
    /// Steady-state (no admission, no retirement) this performs zero heap
    /// allocations outside the denoiser, modulo std's stable-sort scratch
    /// inside the score-ranking samplers' `advance` (see `docs/perf.md`).
    fn step(&mut self) -> Vec<Finished<P>> {
        if self.lanes.is_empty() {
            return Vec::new();
        }
        if self.breaker_open {
            let cooled = self
                .breaker_opened_at
                .map(|at| at.elapsed() >= self.fault.breaker_cooldown)
                .unwrap_or(true);
            if !cooled {
                // parked: lanes sit untouched at the boundary, byte-exactly
                // resumable — deliberately NOT a failure path
                return Vec::new();
            }
            // half-open: let one probe boundary through; a clean success
            // closes the breaker, a failure re-arms the cooldown
        }
        let conditional = self.engine.conditional();
        let mcfg = self.engine.denoiser().config();
        self.scratch.xs.reset(mcfg.seq_len);
        self.scratch.ts.clear();
        self.scratch.srcs.reset(mcfg.src_len);
        for lane in &self.lanes {
            let call = lane.session.next_event().expect("active lane has a pending call");
            self.scratch.xs.extend_from(lane.session.x());
            for _ in 0..lane.session.batch() {
                self.scratch.ts.push(call.t);
            }
            if conditional {
                self.scratch
                    .srcs
                    .extend_from(lane.src_ids.as_ref().expect("conditional lane has srcs"));
            }
        }
        let src_opt = if conditional { Some(&self.scratch.srcs) } else { None };
        let width = self.scratch.xs.rows();
        // per-lane failure verdicts, in lane order: empty = the batched
        // call succeeded and every lane advances from the shared logits
        let lane_errs: Vec<Option<anyhow::Error>> = match call_with_retry(
            self.engine.denoiser(),
            &self.fault,
            &self.scratch.xs,
            &self.scratch.ts,
            src_opt,
            &mut self.scratch.logits,
            FaultCounters {
                retries: &mut self.retries,
                faults_transient: &mut self.faults_transient,
                faults_fatal: &mut self.faults_fatal,
                fail_streak: &mut self.fail_streak,
            },
        ) {
            Ok(()) => {
                if self.fail_streak >= self.fault.breaker_threshold {
                    // successful but consistently slow (call_timeout):
                    // use this boundary's result, then park
                    self.open_breaker();
                } else if self.fail_streak == 0 {
                    self.close_breaker();
                }
                self.engine.nfe.record_call(width);
                Vec::new()
            }
            Err(_) if self.fail_streak >= self.fault.breaker_threshold => {
                // the engine looks down — not one lane's inputs: park with
                // every lane intact at the boundary instead of failing
                // anyone, so a supervisor can evacuate them byte-exactly
                self.open_breaker();
                return Vec::new();
            }
            // the batched error itself is dropped here: its classification
            // was already counted, and each isolated lane produces its own
            Err(_) => self.isolate_lanes(),
        };
        if !lane_errs.is_empty() && lane_errs.iter().all(Option::is_some) {
            // every lane's isolated call failed too: no logits anywhere,
            // nothing advances — fail them all and skip the advance loop
            let mut out = Vec::new();
            for (lane, e) in self.lanes.drain(..).zip(&lane_errs) {
                let msg = format!("{:#}", e.as_ref().expect("all-failed branch"));
                fail_members(lane.members, &msg, &mut out);
            }
            self.key = None;
            return out;
        }
        self.boundary += 1;

        let view = self.scratch.logits.view();
        let mut out = Vec::new();
        let mut off = 0usize;
        let mut ghosts = 0u64;
        let mut i = 0usize;
        let mut li = 0usize; // index into lane_errs (original lane order)
        while i < self.lanes.len() {
            let lane = &mut self.lanes[i];
            let w = lane.session.batch();
            let verdict = lane_errs.get(li).and_then(|v| v.as_ref());
            li += 1;
            if let Some(e) = verdict {
                // this lane's isolated call failed beyond retry: fail its
                // members only — the shard keeps serving everyone else
                off += w;
                let msg = format!("{e:#}");
                let lane = self.lanes.remove(i);
                fail_members(lane.members, &msg, &mut out);
                continue;
            }
            match lane.session.advance(view.narrow(off, w)) {
                Err(e) => {
                    // sampler-side failure is lane-local by construction
                    // (each lane is its own session): fail this lane and
                    // keep advancing the others
                    off += w;
                    let msg = format!("{e:#}");
                    let lane = self.lanes.remove(i);
                    fail_members(lane.members, &msg, &mut out);
                    continue;
                }
                // a denoiser call where no row of this lane moved — only
                // possible if an eviction left a stale event behind, which
                // per-row ladders rule out; counted so the bench gate can
                // pin it at zero
                Ok(0) => ghosts += 1,
                Ok(_) => {}
            }
            off += w;
            // boundary event: every subscribed member sees this lane's
            // new snapshot (nfe + optionally its own token row)
            let nfe = lane.session.nfe();
            let total = lane.session.total_events();
            for (j, m) in lane.members.iter().enumerate() {
                if let Some(ctl) = &m.ctl {
                    let tokens =
                        ctl.wants_partials().then(|| lane.session.x().row(j));
                    ctl.progress(nfe, total, tokens);
                }
            }
            // early retirement (serving tiers, docs/tiers.md): an opted-in
            // member whose row provably has only no-op events left exits
            // NOW through the eviction path — its remaining calls are
            // refunded to this shard. Each row is probed against the same
            // logits slice its advance just consumed; walking from the
            // back keeps the surviving rows' view indices aligned with
            // their session rows across evictions.
            let probe =
                lane.members.iter().any(|m| m.early_retire) && !lane.session.is_done();
            if probe {
                let lane_view = view.narrow(off - w, w);
                let mut j = self.lanes[i].members.len();
                let mut died = false;
                while j > 0 {
                    j -= 1;
                    let settled = self.lanes[i].members[j].early_retire
                        && self.lanes[i].session.row_settled(j, lane_view);
                    if !settled {
                        continue;
                    }
                    let m = self.lanes[i].members.remove(j);
                    let nfe = self.lanes[i].session.nfe();
                    let wait = m.admitted.duration_since(m.enqueued);
                    self.engine.nfe.record_request(nfe, wait);
                    let tokens = self.lanes[i].session.x().row(j).to_vec();
                    let output = GenOutput {
                        text: self.engine.decode(&tokens),
                        tokens,
                        nfe,
                        elapsed: m.admitted.elapsed(),
                    };
                    let delivered = deliver(m.ctl.as_ref(), m.wants_result, output);
                    out.push(Finished {
                        payload: m.payload,
                        result: Ok(delivered),
                        wait,
                        outcome: Outcome::Done,
                    });
                    self.early_retired += 1;
                    if self.lanes[i].members.is_empty() {
                        // last member settled: the whole lane retires early
                        self.engine.nfe.record_batch();
                        died = true;
                        break;
                    }
                    self.lanes[i]
                        .session
                        .evict_slot(j)
                        .expect("evict within lane bounds");
                    if let Some(src) = &mut self.lanes[i].src_ids {
                        src.narrow_remove(j);
                    }
                }
                if died {
                    self.lanes.remove(i);
                    continue; // off already advanced past this lane
                }
            }
            i += 1;
        }
        self.ghost_events += ghosts;

        // retire finished lanes in place (no mem::take + re-push, which
        // would re-allocate the lane vector on every boundary)
        let mut finished = out;
        let mut i = 0usize;
        while i < self.lanes.len() {
            if !self.lanes[i].session.is_done() {
                i += 1;
                continue;
            }
            let lane = self.lanes.remove(i);
            self.engine.nfe.record_batch();
            let nfe = lane.session.nfe();
            let mut res = lane.session.into_result();
            for (j, m) in lane.members.into_iter().enumerate() {
                let wait = m.admitted.duration_since(m.enqueued);
                self.engine.nfe.record_request(nfe, wait);
                let tokens = std::mem::take(&mut res.tokens[j]);
                let output = GenOutput {
                    text: self.engine.decode(&tokens),
                    tokens,
                    nfe,
                    // generation time only (same meaning as the
                    // fixed path); queue wait travels separately
                    elapsed: m.admitted.elapsed(),
                };
                let delivered = deliver(m.ctl.as_ref(), m.wants_result, output);
                finished.push(Finished {
                    payload: m.payload,
                    result: Ok(delivered),
                    wait,
                    outcome: Outcome::Done,
                });
            }
        }
        if self.lanes.is_empty() {
            self.key = None;
        }
        finished
    }

    /// A batched denoiser call failed beyond retry, but the batch mixes
    /// lanes and the fault may follow only some of them (poisoned inputs,
    /// a width-specific backend bug). Re-run the same boundary lane by
    /// lane — the same `(x, t, src)` rows, so a lane that succeeds here
    /// gets logits byte-identical to the batched call's — and return one
    /// verdict per lane in lane order: `None` = this lane's logits landed
    /// in the shared buffer at its offset and it advances normally;
    /// `Some(e)` = fail this lane's members. Cold path — allocates freely.
    fn isolate_lanes(&mut self) -> Vec<Option<anyhow::Error>> {
        let (seq, vocab) = {
            let mcfg = self.engine.denoiser().config();
            (mcfg.seq_len, mcfg.vocab)
        };
        let conditional = self.engine.conditional();
        let width = self.scratch.xs.rows();
        // surviving lanes overwrite their slice via the copy below; failed
        // lanes' (stale) slices are never read — the advance loop skips them
        self.scratch.logits.reset_for_overwrite(width, seq, vocab);
        let mut cx = TokenBatch::new(self.scratch.xs.cols());
        let mut cs = TokenBatch::new(self.scratch.srcs.cols());
        let mut cout = LogitsBuf::new();
        let mut verdicts = Vec::with_capacity(self.lanes.len());
        let mut off = 0usize;
        for lane in &self.lanes {
            let w = lane.session.batch();
            cx.reset(self.scratch.xs.cols());
            for r in off..off + w {
                cx.push_row(self.scratch.xs.row(r));
            }
            let src_ref = if conditional {
                cs.reset(self.scratch.srcs.cols());
                for r in off..off + w {
                    cs.push_row(self.scratch.srcs.row(r));
                }
                Some(&cs)
            } else {
                None
            };
            let res = call_with_retry(
                self.engine.denoiser(),
                &self.fault,
                &cx,
                &self.scratch.ts[off..off + w],
                src_ref,
                &mut cout,
                FaultCounters {
                    retries: &mut self.retries,
                    faults_transient: &mut self.faults_transient,
                    faults_fatal: &mut self.faults_fatal,
                    fail_streak: &mut self.fail_streak,
                },
            );
            match res {
                Ok(()) => {
                    self.scratch.logits.flat_mut()
                        [off * seq * vocab..(off + w) * seq * vocab]
                        .copy_from_slice(cout.flat());
                    self.engine.nfe.record_call(w);
                    verdicts.push(None);
                }
                Err(e) => {
                    verdicts.push(Some(e.context("lane isolated after a failed batched call")));
                }
            }
            off += w;
        }
        verdicts
    }

    /// One boundary: enforce cancellations/deadlines (freed slots become
    /// available immediately), admit pending work into free slots, then
    /// make one denoiser call. Returns every request that finished (or
    /// failed, or was dropped) at this boundary.
    pub fn tick(&mut self) -> Vec<Finished<P>> {
        let mut out = Vec::new();
        self.reap(&mut out);
        out.extend(self.admit());
        out.extend(self.step());
        out
    }
}

/// Resolve every member of one (dead) lane as [`Outcome::Failed`]:
/// terminal sink event + `Finished` record. Lane-granular by design —
/// callers decide which lanes die; nothing here touches the scheduler.
fn fail_members<P>(members: Vec<Member<P>>, msg: &str, out: &mut Vec<Finished<P>>) {
    for m in members {
        if let Some(ctl) = &m.ctl {
            ctl.finish_failed(msg);
        }
        out.push(Finished {
            payload: m.payload,
            result: Err(anyhow!("{msg}")),
            wait: m.admitted.duration_since(m.enqueued),
            outcome: Outcome::Failed,
        });
    }
}

/// The scheduler counters a retried call mutates — passed as disjoint
/// `&mut` field borrows so [`call_with_retry`] can run against
/// `engine.denoiser()` (an immutable borrow of a sibling field).
struct FaultCounters<'a> {
    retries: &'a mut u64,
    faults_transient: &'a mut u64,
    faults_fatal: &'a mut u64,
    fail_streak: &'a mut u32,
}

/// One denoiser call under a [`FaultPolicy`]: transient faults (per
/// [`is_transient`]) retry up to `max_retries` times with exponential
/// backoff; fatal faults and exhausted retries return the error. The call
/// is pure in `(x, t, src)` and `out` is fully overwritten per attempt,
/// so a successful retry is byte-identical to an untroubled call.
///
/// The happy path (no fault, no timeout) touches only the clock and the
/// streak reset — it keeps `tick()`'s zero-allocation steady state.
fn call_with_retry(
    den: &dyn Denoiser,
    fault: &FaultPolicy,
    x: &TokenBatch,
    t: &[f32],
    src: Option<&TokenBatch>,
    out: &mut LogitsBuf,
    c: FaultCounters<'_>,
) -> Result<()> {
    let mut attempt = 0u32;
    loop {
        let started = Instant::now();
        match den.denoise_into(x, t, src, out) {
            Ok(()) => {
                if let Some(limit) = fault.call_timeout {
                    if started.elapsed() > limit {
                        // slow but successful: the result is valid (the
                        // call is pure) and is used, but count it toward
                        // the breaker so a crawling shard eventually parks
                        // and its lanes move somewhere faster
                        *c.faults_transient += 1;
                        *c.fail_streak += 1;
                        return Ok(());
                    }
                }
                *c.fail_streak = 0;
                return Ok(());
            }
            Err(e) if is_transient(&e) => {
                *c.faults_transient += 1;
                *c.fail_streak += 1;
                if attempt >= fault.max_retries {
                    return Err(e.context(format!(
                        "transient fault persisted through {} retries",
                        fault.max_retries
                    )));
                }
                attempt += 1;
                *c.retries += 1;
                let backoff = fault
                    .backoff
                    .saturating_mul(1u32 << (attempt - 1).min(16))
                    .min(fault.max_backoff);
                if backoff > Duration::ZERO {
                    std::thread::sleep(backoff);
                }
            }
            Err(e) => {
                *c.faults_fatal += 1;
                *c.fail_streak += 1;
                return Err(e);
            }
        }
    }
}

/// Deliver a completed output to the sink and/or the [`Finished`] record,
/// moving (not cloning) whenever only one side consumes it: ticket-only
/// requests (`wants_result == false`) hand the sink ownership, channel /
/// embedded callers get it in [`Finished::result`]. Only a request wired
/// to *both* (hand-built `Pending`s in tests) still pays a clone.
fn deliver(ctl: Option<&TicketSink>, wants_result: bool, output: GenOutput) -> Delivery {
    match ctl {
        Some(ctl) if !wants_result => {
            let (nfe, elapsed) = (output.nfe, output.elapsed);
            ctl.finish_done(output);
            Delivery::SinkOwned { nfe, elapsed }
        }
        Some(ctl) => {
            ctl.finish_done(output.clone());
            Delivery::Output(output)
        }
        None => Delivery::Output(output),
    }
}

/// Resolve a dropped request (cancellation or expiry, queue-side or
/// in-flight) into its terminal event + a [`Finished`] record.
fn resolve_drop<P>(
    payload: P,
    ctl: Option<&TicketSink>,
    cancelled: bool,
    wait: Duration,
) -> Finished<P> {
    if let Some(ctl) = ctl {
        if cancelled {
            ctl.finish_cancelled();
        } else {
            ctl.finish_deadline();
        }
    }
    let (outcome, err) = if cancelled {
        (Outcome::Cancelled, "request cancelled")
    } else {
        (Outcome::DeadlineExceeded, "request deadline exceeded")
    };
    Finished { payload, result: Err(anyhow!("{err}")), wait, outcome }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::cipher_mock_engine;
    use crate::coordinator::request::{Event, Ticket};
    use crate::sampler::SamplerKind;

    fn mock_engine() -> Engine {
        cipher_mock_engine(8)
    }

    fn req(id: usize, seed: u64, cfg: Option<SamplerConfig>) -> Pending<usize> {
        Pending::new(Some("the quick fox".into()), seed, cfg, id)
    }

    fn policy(max_batch: usize) -> SchedPolicy {
        SchedPolicy { max_batch, window: Duration::ZERO, shared_tau_groups: true }
    }

    #[test]
    fn single_request_completes_with_session_nfe() {
        let mut s: Scheduler<usize> =
            Scheduler::new(mock_engine(), SamplerConfig::new(SamplerKind::Dndm, 50), policy(4));
        s.enqueue(req(0, 7, None));
        let mut done = Vec::new();
        while s.has_work() {
            done.extend(s.tick());
        }
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].outcome, Outcome::Done);
        let out = done[0].result.as_ref().unwrap().output().unwrap();
        assert!(out.nfe >= 1 && out.nfe <= 8);
        assert_eq!(s.engine().nfe.requests(), 1);
        assert_eq!(s.engine().nfe.calls() as usize, out.nfe);
    }

    #[test]
    fn spec_key_separates_differing_specs_and_matches_equal_ones() {
        let a = SamplerConfig::new(SamplerKind::Dndm, 50);
        let b = SamplerConfig::new(SamplerKind::Dndm, 50);
        assert_eq!(SpecKey::of(&a), SpecKey::of(&b));
        assert_ne!(SpecKey::of(&a), SpecKey::of(&SamplerConfig::new(SamplerKind::DndmV2, 50)));
        assert_ne!(SpecKey::of(&a), SpecKey::of(&SamplerConfig::new(SamplerKind::Dndm, 25)));
        assert_ne!(SpecKey::of(&a), SpecKey::of(&a.clone().with_temperature(1.0)));
        // differing 𝒟_τ parameters must not share a ladder (the String key
        // only compared the spec *name* and would have merged these)
        use crate::schedule::TransitionSpec;
        let beta_a = a.clone().with_spec(TransitionSpec::Beta { a: 15.0, b: 7.0 });
        let beta_b = a.clone().with_spec(TransitionSpec::Beta { a: 2.0, b: 3.0 });
        assert_ne!(SpecKey::of(&beta_a), SpecKey::of(&beta_b));
    }

    /// The tentpole guarantee: between admission and retirement, `tick()`
    /// allocates nothing — token gather, time vector, src gather, logits,
    /// *and* lifecycle event emission all live in buffers reused across
    /// calls (the mock denoiser writes in place, so the whole boundary is
    /// heap-silent). Runs with an active streaming subscriber attached, so
    /// per-boundary progress emission is covered by the same pin — with a
    /// second lane member that is cancelled mid-flight, so a tick that
    /// **narrows** the batch (slot eviction + compaction) is covered too,
    /// and with a **rebalance** after the narrow: the lane is donated to
    /// a second scheduler at a boundary and resumed there, and every tick
    /// after the thief's scratch warms must be exactly as heap-silent as
    /// on the donor.
    #[test]
    fn steady_state_tick_is_allocation_free() {
        use crate::util::bench::alloc_count::thread_allocs;

        let eng = mock_engine();
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50);
        // pick a seed whose *width-2* session (the lane below is a width-2
        // shared-𝒯 group, and 𝒯 depends on the batch size) spans enough
        // events that, after the admission tick, the narrowing tick, and
        // the thief's warm-up tick, some ticks still neither admit nor
        // retire
        let seed = (0..256u64)
            .find(|&s| {
                let sess =
                    SamplerSession::new(eng.denoiser().config(), &cfg, 2, s).unwrap();
                sess.total_events() >= 7
            })
            .expect("some seed in 0..256 must give >= 7 events");

        let (mut ticket, sink) = Ticket::detached(true);
        let (victim, victim_sink) = Ticket::detached(false);
        let mut s: Scheduler<usize> = Scheduler::new(eng, cfg.clone(), policy(4));
        let mut p = req(0, seed, None);
        p.ctl = Some(sink);
        s.enqueue(p);
        // a second member of the same shared-𝒯 lane, cancelled mid-flight
        // so the lane must *narrow* (evict the row, keep the survivor)
        let mut v = req(1, seed, None);
        v.ctl = Some(victim_sink);
        s.enqueue(v);
        // boundary 1: co-admission into one width-2 lane + first call —
        // warms every scratch buffer, including the subscriber's
        // partial-token snapshot
        let first = s.tick();
        assert!(first.is_empty(), ">= 7 events, so the first tick cannot retire");
        assert_eq!(s.in_flight(), 2);
        assert_eq!(s.lane_info().len(), 1, "one shared-𝒯 lane");
        victim.cancel();
        let narrow = s.tick();
        // the narrowing tick resolves the victim and shrinks the lane
        assert_eq!(narrow.len(), 1);
        assert_eq!(narrow[0].outcome, Outcome::Cancelled);
        assert_eq!(s.in_flight(), 1, "victim's row evicted before the call");
        assert_eq!(s.lane_info()[0].width, 1, "the lane narrowed in place");

        // rebalance at this boundary: donate the narrowed lane to a
        // second scheduler (the filler request keeps the move from being
        // zero-sum) and resume it there mid-schedule
        s.enqueue(req(2, seed, None));
        let lane = s.donate_lane(1).expect("plenty of events remain");
        assert_eq!(lane.width(), 1);
        let mut s2: Scheduler<usize> = Scheduler::new(mock_engine(), cfg, policy(4));
        s2.adopt_lane(lane);
        assert_eq!(s2.in_flight(), 1, "the thief resumes the lane");
        // the donor serves its filler to completion (allocation pin not
        // re-asserted here — admission/retirement may allocate)
        while s.has_work() {
            s.tick();
        }

        let mut steady = 0usize;
        let mut done = Vec::new();
        let mut warmed = false;
        while s2.has_work() {
            let before = thread_allocs();
            let out = s2.tick();
            let delta = thread_allocs() - before;
            if out.is_empty() {
                if warmed {
                    assert_eq!(delta, 0, "steady-state tick() allocated {delta} time(s)");
                    steady += 1;
                }
                // the thief's first call warms its own scratch buffers
                warmed = true;
            }
            done.extend(out);
        }
        assert!(
            steady >= 2,
            "expected >= 2 steady-state ticks after the rebalance, saw {steady}"
        );
        assert_eq!(done.len(), 1);
        let out = done[0].result.as_ref().unwrap().output().unwrap();
        // the subscriber observed the full lifecycle, and its final
        // progress snapshot is exactly the finished tokens
        assert!(matches!(ticket.try_next_event(), Some(Event::Admitted { .. })));
        match ticket.try_next_event() {
            Some(Event::Progress { nfe_done, nfe_total, partial_tokens }) => {
                assert_eq!(nfe_done, out.nfe);
                assert_eq!(nfe_total, out.nfe);
                assert_eq!(partial_tokens, out.tokens);
            }
            other => panic!("expected progress, got {other:?}"),
        }
        assert!(matches!(ticket.try_next_event(), Some(Event::Done(_))));
    }

    #[test]
    fn group_admitted_together_shares_one_lane() {
        let mut s: Scheduler<usize> =
            Scheduler::new(mock_engine(), SamplerConfig::new(SamplerKind::Dndm, 50), policy(4));
        for i in 0..3 {
            s.enqueue(req(i, 9, None));
        }
        let done = s.tick();
        assert!(done.is_empty() || done.len() == 3);
        let lanes = s.lane_info();
        if !lanes.is_empty() {
            assert_eq!(lanes.len(), 1, "one shared-𝒯 lane");
            assert_eq!(lanes[0].width, 3);
            assert_eq!(lanes[0].admitted_boundary, 0);
        }
        let mut all = done;
        while s.has_work() {
            all.extend(s.tick());
        }
        assert_eq!(all.len(), 3);
        // shared 𝒯 ⇒ identical per-request NFE
        let nfes: Vec<usize> =
            all.iter().map(|f| f.result.as_ref().unwrap().nfe()).collect();
        assert!(nfes.windows(2).all(|w| w[0] == w[1]), "{nfes:?}");
    }

    #[test]
    fn late_high_priority_arrival_does_not_reset_the_grouping_window() {
        let mut s: Scheduler<usize> = Scheduler::new(
            mock_engine(),
            SamplerConfig::new(SamplerKind::Dndm, 50),
            SchedPolicy {
                max_batch: 4,
                window: Duration::from_millis(10),
                shared_tau_groups: true,
            },
        );
        s.enqueue(req(0, 3, None));
        std::thread::sleep(Duration::from_millis(15));
        // a fresh high-priority request jumps to the queue front — the
        // window gate must still key off the oldest enqueue, not the front
        let mut high = req(1, 4, None);
        high.priority = Priority::High;
        s.enqueue(high);
        s.tick();
        assert_eq!(s.pending_len(), 0, "batch starts on the oldest request's window");
        assert_eq!(s.boundary(), 1, "the first denoiser call was made");
    }

    #[test]
    fn queue_depths_count_per_priority() {
        let mut s: Scheduler<usize> =
            Scheduler::new(mock_engine(), SamplerConfig::new(SamplerKind::Dndm, 50), policy(4));
        assert_eq!(s.queue_depths(), [0, 0, 0]);
        let mut low = req(0, 1, None);
        low.priority = Priority::Low;
        let mut high = req(1, 2, None);
        high.priority = Priority::High;
        s.enqueue(low);
        s.enqueue(high);
        s.enqueue(req(2, 3, None));
        s.enqueue(req(3, 4, None));
        assert_eq!(s.queue_depths(), [1, 2, 1]);
    }

    #[test]
    fn steal_pending_takes_a_same_key_run_from_the_tail() {
        let mut s: Scheduler<usize> =
            Scheduler::new(mock_engine(), SamplerConfig::new(SamplerKind::Dndm, 50), policy(1));
        // in-flight key becomes the default spec
        s.enqueue(req(0, 1, None));
        assert!(s.tick().is_empty() || !s.has_work());
        // queue: two default-key requests, then two with a distinct key
        let other = SamplerConfig::new(SamplerKind::DndmV2, 50);
        s.enqueue(req(1, 2, None));
        s.enqueue(req(2, 3, None));
        s.enqueue(req(3, 4, Some(other.clone())));
        s.enqueue(req(4, 5, Some(other.clone())));
        let stolen = s.steal_pending(10);
        // prefers the key that differs from the in-flight batch, takes the
        // whole run, and preserves FIFO order
        assert_eq!(stolen.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![3, 4]);
        assert!(stolen
            .iter()
            .all(|p| SpecKey::of(p.cfg.as_ref().unwrap()) == SpecKey::of(&other)));
        assert_eq!(s.pending_len(), 2, "default-key requests stay with the donor");
        // a second steal falls back to the in-flight key's queued run
        let stolen = s.steal_pending(1);
        assert_eq!(stolen.len(), 1);
        assert_eq!(stolen[0].payload, 2, "taken from the back (youngest first)");
        assert_eq!(s.pending_len(), 1);
        while s.has_work() {
            s.tick();
        }
    }

    #[test]
    fn steal_pending_respects_max_and_empty_queue() {
        let mut s: Scheduler<usize> =
            Scheduler::new(mock_engine(), SamplerConfig::new(SamplerKind::Dndm, 50), policy(4));
        assert!(s.steal_pending(4).is_empty());
        for i in 0..3 {
            s.enqueue(req(i, i as u64, None));
        }
        assert!(s.steal_pending(0).is_empty());
        let stolen = s.steal_pending(2);
        assert_eq!(stolen.len(), 2);
        assert_eq!(s.pending_len(), 1);
    }

    #[test]
    fn donate_lane_refuses_zero_sum_and_near_retirement() {
        // D3pm makes the event count deterministic (= steps)
        let cfg = SamplerConfig::new(SamplerKind::D3pm, 50);
        let mut s: Scheduler<usize> = Scheduler::new(mock_engine(), cfg, policy(1));
        assert!(s.donate_lane(1).is_none(), "nothing in flight");
        s.enqueue(req(0, 7, None));
        assert!(s.tick().is_empty(), "50 events: far from retirement");
        // single lane + empty queue: moving the only work is zero-sum
        assert!(s.donate_lane(1).is_none());
        // queued work lifts the zero-sum refusal, but an absurd
        // min_remaining still refuses as near-retirement
        s.enqueue(req(1, 8, None));
        assert!(s.donate_lane(1000).is_none());
        assert!(s.donate_lane(2).is_some(), "49 calls left ≥ 2");
        while s.has_work() {
            s.tick();
        }
    }

    #[test]
    fn donated_lane_resumes_on_the_thief_with_accounting_intact() {
        let cfg = SamplerConfig::new(SamplerKind::D3pm, 20);
        let mut s: Scheduler<usize> = Scheduler::new(mock_engine(), cfg.clone(), policy(2));
        s.enqueue(req(0, 3, None));
        s.enqueue(req(1, 4, None)); // same key → one co-admitted width-2 lane
        assert!(s.tick().is_empty()); // admission + call 1
        assert!(s.tick().is_empty()); // call 2
        s.enqueue(req(2, 5, None)); // filler: donation must not be zero-sum
        let lane = s.donate_lane(2).expect("18 calls remain");
        assert_eq!(lane.width(), 2);
        assert_eq!(lane.remaining_events(), 18, "cursor travels with the lane");
        assert_eq!(s.in_flight(), 0, "donor released the lane's slots");

        let mut t: Scheduler<usize> = Scheduler::new(mock_engine(), cfg, policy(2));
        t.adopt_lane(lane);
        assert_eq!(t.in_flight(), 2);
        assert_eq!(t.lane_count(), 1);
        let mut done = Vec::new();
        while t.has_work() {
            done.extend(t.tick());
        }
        assert_eq!(done.len(), 2);
        for f in &done {
            assert_eq!(f.outcome, Outcome::Done);
            assert_eq!(
                f.result.as_ref().unwrap().nfe(),
                20,
                "per-request NFE spans donor + thief calls"
            );
        }
        // the donor admits and serves its filler independently
        let mut rest = Vec::new();
        while s.has_work() {
            rest.extend(s.tick());
        }
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].outcome, Outcome::Done);
    }

    #[test]
    fn donate_rows_splits_a_wide_lane_and_both_halves_finish() {
        let cfg = SamplerConfig::new(SamplerKind::D3pm, 20);
        let mut s: Scheduler<usize> = Scheduler::new(mock_engine(), cfg.clone(), policy(4));
        assert!(s.donate_rows(1).is_none(), "nothing in flight");
        for i in 0..3 {
            s.enqueue(req(i, 3 + i as u64, None)); // one co-admitted width-3 lane
        }
        assert!(s.tick().is_empty()); // admission + call 1
        assert!(s.tick().is_empty()); // call 2
        assert!(s.donate_rows(1000).is_none(), "18 calls left < absurd floor");
        // splitting is legal even with a single lane and an empty queue:
        // the donor keeps the front ⌈w/2⌉ rows, so it is never zero-sum
        let lane = s.donate_rows(2).expect("width 3 >= 2 and 18 calls remain");
        assert_eq!(lane.width(), 1, "back ⌊3/2⌋ = 1 row moved");
        assert_eq!(lane.remaining_events(), 18, "cursor travels with the split half");
        assert_eq!(s.in_flight(), 2, "donor keeps the front rows serving");
        assert_eq!(s.lane_info()[0].width, 2);
        // a width-1 lane can no longer split once this one retires down
        let mut t: Scheduler<usize> = Scheduler::new(mock_engine(), cfg, policy(4));
        t.adopt_lane(lane);
        assert!(t.donate_rows(1).is_none(), "width-1 lanes are unsplittable");
        let mut done = Vec::new();
        while t.has_work() {
            done.extend(t.tick());
        }
        while s.has_work() {
            done.extend(s.tick());
        }
        assert_eq!(done.len(), 3);
        for f in &done {
            assert_eq!(f.outcome, Outcome::Done);
            assert_eq!(
                f.result.as_ref().unwrap().nfe(),
                20,
                "per-request NFE spans donor + thief calls"
            );
        }
    }

    // ---- fault handling (the full cross-shard story is tests/chaos.rs) ----

    use crate::coordinator::engine::cipher_mock_denoiser;
    use crate::data::words;
    use crate::runtime::{ChaosDenoiser, ChaosSwitch, FaultKind, MockDenoiser};

    fn chaos_engine(chaos: ChaosDenoiser<MockDenoiser>) -> Engine {
        Engine::from_denoiser(Box::new(chaos), words::translation_vocab(), "cipher-chaos")
    }

    /// A retry policy that cannot plausibly exhaust or trip the breaker —
    /// for pins where chaos must be absorbed entirely.
    fn absorb_policy() -> FaultPolicy {
        FaultPolicy {
            max_retries: 16,
            backoff: Duration::ZERO,
            breaker_threshold: 1000,
            ..FaultPolicy::default()
        }
    }

    fn tokens_by_payload(done: &[Finished<usize>]) -> Vec<(usize, Vec<u32>)> {
        let mut v: Vec<(usize, Vec<u32>)> = done
            .iter()
            .map(|f| {
                (f.payload, f.result.as_ref().unwrap().output().unwrap().tokens.clone())
            })
            .collect();
        v.sort_by_key(|(id, _)| *id);
        v
    }

    #[test]
    fn transient_faults_retry_to_the_fault_free_output() {
        let cfg = SamplerConfig::new(SamplerKind::Dndm, 50);
        let run = |eng: Engine| {
            let mut s: Scheduler<usize> =
                Scheduler::new(eng, cfg.clone(), policy(4)).with_fault_policy(absorb_policy());
            s.enqueue(req(0, 7, None));
            s.enqueue(req(1, 9, None));
            let mut done = Vec::new();
            while s.has_work() {
                done.extend(s.tick());
            }
            assert_eq!(done.len(), 2);
            assert!(done.iter().all(|f| f.outcome == Outcome::Done));
            let toks = tokens_by_payload(&done);
            (toks, s.retries(), s.faults_transient(), s.faults_fatal())
        };
        let (want, r0, t0, f0) = run(mock_engine());
        assert_eq!((r0, t0, f0), (0, 0, 0), "clean engine records no faults");
        let (got, retries, transients, fatals) = run(chaos_engine(
            ChaosDenoiser::new(cipher_mock_denoiser(8), 0xC4A05).transient_rate(0.3),
        ));
        assert_eq!(got, want, "retried run must be byte-identical to the clean run");
        assert!(retries > 0 && transients > 0, "the chaos must actually have fired");
        assert_eq!(fatals, 0);
    }

    #[test]
    fn fatal_fault_fails_only_the_culprit_lane() {
        // D3pm: per-request NFE is deterministically = steps
        let cfg = SamplerConfig::new(SamplerKind::D3pm, 10);
        // widths 3 (the batched call) and 1 (the culprit lane's isolated
        // call) fault fatally; the width-2 lane's isolated call succeeds
        let eng = chaos_engine(
            ChaosDenoiser::new(cipher_mock_denoiser(8), 1)
                .fail_on_widths(&[3, 1], FaultKind::Fatal),
        );
        let mut s: Scheduler<usize> = Scheduler::new(eng, cfg, policy(4));
        s.enqueue(req(0, 3, None));
        s.enqueue(req(1, 4, None)); // co-admitted: one width-2 lane
        assert!(s.tick().is_empty(), "boundary 1: width 2, clean");
        s.enqueue(req(2, 5, None)); // second lane, width 1
        let mut done = s.tick(); // width-3 call faults → isolation
        assert_eq!(done.len(), 1, "only the width-1 lane fails");
        assert_eq!(done[0].payload, 2);
        assert_eq!(done[0].outcome, Outcome::Failed);
        assert_eq!(s.in_flight(), 2, "the width-2 lane is untouched");
        assert!(!s.breaker_open());
        while s.has_work() {
            done.extend(s.tick());
        }
        assert_eq!(done.len(), 3);
        for f in &done[1..] {
            assert_eq!(f.outcome, Outcome::Done);
            assert_eq!(f.result.as_ref().unwrap().nfe(), 10, "survivors keep exact NFE");
        }
        assert_eq!(s.faults_fatal(), 2, "one batched + one isolated fatal attempt");
        assert_eq!(s.retries(), 0, "fatal faults never retry");
    }

    #[test]
    fn breaker_parks_lanes_for_byte_exact_evacuation() {
        let cfg = SamplerConfig::new(SamplerKind::D3pm, 20);
        // reference: the same pair served with no faults at all
        let mut r: Scheduler<usize> = Scheduler::new(mock_engine(), cfg.clone(), policy(2));
        r.enqueue(req(0, 3, None));
        r.enqueue(req(1, 4, None));
        let mut want = Vec::new();
        while r.has_work() {
            want.extend(r.tick());
        }
        let want = tokens_by_payload(&want);

        // the engine dies (transiently, forever) from call 4 on
        let eng = chaos_engine(
            ChaosDenoiser::new(cipher_mock_denoiser(8), 1)
                .fail_from_call(4, FaultKind::Transient),
        );
        let mut s: Scheduler<usize> = Scheduler::new(eng, cfg.clone(), policy(2))
            .with_fault_policy(FaultPolicy {
                max_retries: 1,
                backoff: Duration::ZERO,
                breaker_threshold: 2,
                breaker_cooldown: Duration::from_secs(3600),
                ..FaultPolicy::default()
            });
        s.enqueue(req(0, 3, None));
        s.enqueue(req(1, 4, None));
        let mut early = Vec::new();
        for _ in 0..8 {
            early.extend(s.tick());
        }
        assert!(s.breaker_open(), "exhausted retries past the threshold open the breaker");
        assert!(early.is_empty(), "parking fails nobody");
        assert_eq!(s.in_flight(), 2, "lanes sit intact at the boundary");

        // salvage: evacuate the parked lanes onto a healthy scheduler
        let lanes = s.evacuate();
        assert_eq!(lanes.len(), 1);
        assert_eq!(s.in_flight(), 0);
        let mut t: Scheduler<usize> = Scheduler::new(mock_engine(), cfg, policy(2));
        for lane in lanes {
            t.adopt_lane(lane);
        }
        let mut done = Vec::new();
        while t.has_work() {
            done.extend(t.tick());
        }
        assert_eq!(done.len(), 2);
        for f in &done {
            assert_eq!(f.outcome, Outcome::Done);
            assert_eq!(
                f.result.as_ref().unwrap().nfe(),
                20,
                "per-request NFE spans donor + salvage calls exactly"
            );
        }
        assert_eq!(tokens_by_payload(&done), want, "salvaged run is byte-identical");
    }

    #[test]
    fn breaker_probe_closes_after_recovery() {
        let sw = ChaosSwitch::new();
        let eng = chaos_engine(
            ChaosDenoiser::new(cipher_mock_denoiser(8), 1).with_switch(sw.clone()),
        );
        let mut s: Scheduler<usize> =
            Scheduler::new(eng, SamplerConfig::new(SamplerKind::D3pm, 20), policy(2))
                .with_fault_policy(FaultPolicy {
                    max_retries: 0,
                    backoff: Duration::ZERO,
                    breaker_threshold: 1,
                    breaker_cooldown: Duration::ZERO,
                    ..FaultPolicy::default()
                });
        s.enqueue(req(0, 3, None));
        sw.arm(FaultKind::Transient);
        assert!(s.tick().is_empty());
        assert!(s.breaker_open());
        // cooldown ZERO: every tick probes; still armed → stays open
        assert!(s.tick().is_empty());
        assert!(s.breaker_open());
        sw.disarm();
        let mut done = Vec::new();
        while s.has_work() {
            done.extend(s.tick());
        }
        assert!(!s.breaker_open(), "a clean probe closes the breaker");
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].outcome, Outcome::Done);
        assert_eq!(done[0].result.as_ref().unwrap().nfe(), 20);
        assert!(s.faults_transient() >= 2);
    }

    #[test]
    fn reset_engine_preserves_the_nfe_counter_and_closes_the_breaker() {
        let cfg = SamplerConfig::new(SamplerKind::D3pm, 20);
        let eng = chaos_engine(
            ChaosDenoiser::new(cipher_mock_denoiser(8), 1)
                .fail_from_call(3, FaultKind::Fatal),
        );
        let mut s: Scheduler<usize> = Scheduler::new(eng, cfg, policy(2))
            .with_fault_policy(FaultPolicy {
                max_retries: 0,
                backoff: Duration::ZERO,
                breaker_threshold: 1,
                breaker_cooldown: Duration::from_secs(3600),
                ..FaultPolicy::default()
            });
        s.enqueue(req(0, 3, None));
        assert!(s.tick().is_empty()); // call 1
        assert!(s.tick().is_empty()); // call 2
        assert!(s.tick().is_empty()); // call 3 faults → breaker opens
        assert!(s.breaker_open());
        let calls_before = s.engine().nfe.calls();
        assert_eq!(calls_before, 2);
        assert_eq!(s.drain_pending().len(), 0);

        s.reset_engine(mock_engine());
        assert!(!s.breaker_open(), "a fresh engine starts with a closed breaker");
        assert_eq!(
            s.engine().nfe.calls(),
            calls_before,
            "the NFE counter survives the restart"
        );
        let mut done = Vec::new();
        while s.has_work() {
            done.extend(s.tick());
        }
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].outcome, Outcome::Done);
        assert_eq!(done[0].result.as_ref().unwrap().nfe(), 20);
        assert_eq!(s.engine().nfe.calls(), 20, "restart lost no call accounting");
        assert!(s.faults_fatal() >= 1, "fault totals are career counters");
    }

    #[test]
    fn abort_all_fails_queued_and_in_flight_work() {
        let cfg = SamplerConfig::new(SamplerKind::D3pm, 20);
        let mut s: Scheduler<usize> = Scheduler::new(mock_engine(), cfg, policy(1));
        s.enqueue(req(0, 3, None));
        assert!(s.tick().is_empty()); // payload 0 in flight
        s.enqueue(req(1, 4, None)); // payload 1 stays queued (capacity 1)
        let mut done = s.abort_all("shard lost for good");
        done.sort_by_key(|f| f.payload);
        assert_eq!(done.len(), 2);
        assert!(done.iter().all(|f| f.outcome == Outcome::Failed));
        assert!(!s.has_work());
        let msg = format!("{:#}", done[0].result.as_ref().unwrap_err());
        assert!(msg.contains("shard lost for good"), "{msg}");
    }

    #[test]
    fn early_retirement_refunds_remaining_calls_for_settled_absorbing_rows() {
        // D3pm-Absorb reveals everything well before the grid ends; with
        // early_retire the request must finish at the first boundary where
        // its row is mask-free — strictly fewer than `steps` calls — while
        // the untiered twin still runs the full grid.
        // A row whose last reveal lands on the very last step never gets a
        // settled boundary, so sweep a few seeds: nearly all retire early,
        // and every one must serve the same tokens as its untiered twin.
        let cfg = SamplerConfig::new(SamplerKind::D3pm, 30);
        let mut retired_early = 0u64;
        for seed in 0..6u64 {
            let mut s: Scheduler<usize> =
                Scheduler::new(mock_engine(), cfg.clone(), policy(2));
            let mut p = req(0, seed, None);
            p.early_retire = true;
            s.enqueue(p);
            let mut done = Vec::new();
            while s.has_work() {
                done.extend(s.tick());
            }
            assert_eq!(done.len(), 1);
            assert_eq!(done[0].outcome, Outcome::Done);
            let out = done[0].result.as_ref().unwrap().output().unwrap();
            assert!(out.nfe >= 1 && out.nfe <= 30);
            assert_eq!(s.ghost_events(), 0);
            if out.nfe < 30 {
                assert_eq!(s.early_retired(), 1);
                assert_eq!(
                    s.engine().nfe.calls(),
                    out.nfe as u64,
                    "refund: the shard stopped calling when the lane retired"
                );
                retired_early += 1;
            }

            // the opted-out twin serves the full grid — early_retire is
            // the only thing that changed
            let mut q: Scheduler<usize> = Scheduler::new(mock_engine(), cfg.clone(), policy(2));
            q.enqueue(req(0, seed, None));
            let mut full = Vec::new();
            while q.has_work() {
                full.extend(q.tick());
            }
            let fout = full[0].result.as_ref().unwrap().output().unwrap();
            assert_eq!(fout.nfe, 30);
            assert_eq!(q.early_retired(), 0);
            assert_eq!(
                fout.tokens, out.tokens,
                "seed {seed}: retiring early must not change the served tokens"
            );
        }
        assert!(retired_early >= 1, "no seed in 0..6 settled before the grid ended");
    }

    #[test]
    fn turbo_truncation_is_counted_and_spec_keyed() {
        let base = SamplerConfig::new(SamplerKind::Dndm, 200);
        let capped = base.clone().with_max_nfe(2);
        assert_ne!(SpecKey::of(&base), SpecKey::of(&capped), "caps must not share a lane");
        let mut s: Scheduler<usize> = Scheduler::new(mock_engine(), base, policy(2));
        s.enqueue(req(0, 11, Some(capped)));
        let mut done = Vec::new();
        while s.has_work() {
            done.extend(s.tick());
        }
        assert_eq!(done.len(), 1);
        let out = done[0].result.as_ref().unwrap().output().unwrap();
        assert!(out.nfe <= 2, "Turbo cap bounds the served |𝒯|, got {}", out.nfe);
        assert!(s.turbo_truncated() > 0, "a 200-step ladder capped at 2 must drop events");
        assert_eq!(s.ghost_events(), 0);
    }

    #[test]
    fn priority_orders_admission_within_the_queue() {
        let mut s: Scheduler<usize> =
            Scheduler::new(mock_engine(), SamplerConfig::new(SamplerKind::Dndm, 50), policy(1));
        let mut low = req(0, 3, None);
        low.priority = Priority::Low;
        let mut high = req(1, 4, None);
        high.priority = Priority::High;
        s.enqueue(low);
        s.enqueue(high);
        let mut done = Vec::new();
        while s.has_work() {
            done.extend(s.tick());
        }
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].payload, 1, "high priority admitted (and finished) first");
        assert_eq!(done[1].payload, 0);
    }
}
