//! The three synthetic seq2seq tasks (IWSLT14/WMT14/WMT16 analogs).
//!
//! Difficulty ordering is engineered to mirror the paper's Tables 2/3:
//! * `Iwslt14` — positionwise word cipher (easy → highest BLEU);
//! * `Wmt16`  — cipher + adjacent-pair swap (medium);
//! * `Wmt14`  — cipher + full reversal + *genuinely ambiguous* synonym
//!   choices (hard → BLEU ceiling < 100, like real WMT14 being the hardest
//!   benchmark in the paper).

use crate::schedule::SplitMix64;

use super::grammar::gen_sentence;
use super::words::lexicon;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataset {
    Iwslt14,
    Wmt14,
    Wmt16,
}

impl Dataset {
    pub const ALL: [Dataset; 3] = [Dataset::Iwslt14, Dataset::Wmt14, Dataset::Wmt16];

    /// python common.DATASET_SEED
    pub fn seed(&self) -> u64 {
        match self {
            Dataset::Iwslt14 => 0x1E51_0014,
            Dataset::Wmt14 => 0x3A7B_0014,
            Dataset::Wmt16 => 0x3A7B_0016,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dataset::Iwslt14 => "synth-iwslt14",
            Dataset::Wmt14 => "synth-wmt14",
            Dataset::Wmt16 => "synth-wmt16",
        }
    }

    pub fn short(&self) -> &'static str {
        match self {
            Dataset::Iwslt14 => "iwslt14",
            Dataset::Wmt14 => "wmt14",
            Dataset::Wmt16 => "wmt16",
        }
    }

    pub fn parse(s: &str) -> Option<Dataset> {
        match s {
            "synth-iwslt14" | "iwslt14" | "IWSLT14" => Some(Dataset::Iwslt14),
            "synth-wmt14" | "wmt14" | "WMT14" => Some(Dataset::Wmt14),
            "synth-wmt16" | "wmt16" | "WMT16" => Some(Dataset::Wmt16),
            _ => None,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Valid,
    Test,
}

impl Split {
    /// python common.SPLIT_STREAM
    pub fn stream(&self) -> u64 {
        match self {
            Split::Train => 1,
            Split::Valid => 2,
            Split::Test => 3,
        }
    }
}

/// source → target (mirror of common.py::translate, incl. rng call order).
pub fn translate(dataset: Dataset, src: &[&str], rng: &mut SplitMix64) -> Vec<String> {
    let lex = lexicon();
    let base: Vec<&str> = src
        .iter()
        .map(|w| lex.tgt_words[lex.src_index(w).expect("word in lexicon")].as_str())
        .collect();
    match dataset {
        Dataset::Iwslt14 => base.iter().map(|s| s.to_string()).collect(),
        Dataset::Wmt16 => {
            let mut out: Vec<String> = base.iter().map(|s| s.to_string()).collect();
            let mut i = 0;
            while i + 1 < out.len() {
                out.swap(i, i + 1);
                i += 2;
            }
            out
        }
        Dataset::Wmt14 => {
            let mut out = Vec::with_capacity(src.len());
            for w in src.iter().rev() {
                let i = lex.src_index(w).unwrap();
                // short-circuit exactly like python: coin only drawn when a
                // synonym exists (rng call parity!)
                match lex.synonym_for(i) {
                    Some(syn) if rng.coin(0.5) => out.push(syn.to_string()),
                    _ => out.push(lex.tgt_words[i].clone()),
                }
            }
            out
        }
    }
}

/// Deterministic sentence pairs for (dataset, split).
pub fn gen_pairs(
    dataset: Dataset,
    split: Split,
    count: usize,
) -> Vec<(Vec<&'static str>, Vec<String>)> {
    let mut root = SplitMix64::new(dataset.seed());
    let mut rng = root.fork(split.stream());
    (0..count)
        .map(|_| {
            let src = gen_sentence(&mut rng);
            let tgt = translate(dataset, &src, &mut rng);
            (src, tgt)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_split_disjoint() {
        let a = gen_pairs(Dataset::Iwslt14, Split::Test, 5);
        let b = gen_pairs(Dataset::Iwslt14, Split::Test, 5);
        assert_eq!(a, b);
        let tr = gen_pairs(Dataset::Iwslt14, Split::Train, 5);
        assert_ne!(tr, a);
    }

    #[test]
    fn iwslt_positionwise_cipher() {
        let lex = lexicon();
        let mut rng = SplitMix64::new(0);
        let src = gen_sentence(&mut rng);
        let tgt = translate(Dataset::Iwslt14, &src, &mut rng);
        assert_eq!(tgt.len(), src.len());
        for (s, t) in src.iter().zip(&tgt) {
            assert_eq!(t, &lex.tgt_words[lex.src_index(s).unwrap()]);
        }
    }

    #[test]
    fn wmt16_swaps_pairs() {
        let lex = lexicon();
        let mut rng = SplitMix64::new(0);
        let src = ["the", "fox", "crosses", "a", "river"];
        let tgt = translate(Dataset::Wmt16, &src, &mut rng);
        let base: Vec<&str> = src
            .iter()
            .map(|w| lex.tgt_words[lex.src_index(w).unwrap()].as_str())
            .collect();
        assert_eq!(tgt[0], base[1]);
        assert_eq!(tgt[1], base[0]);
        assert_eq!(tgt[4], base[4]); // odd tail unswapped
    }

    #[test]
    fn wmt14_reverses_and_is_ambiguous() {
        let src = ["the", "fox", "crosses", "a", "river"];
        let mut outs = std::collections::HashSet::new();
        for seed in 0..20 {
            let mut rng = SplitMix64::new(seed);
            outs.insert(translate(Dataset::Wmt14, &src, &mut rng));
        }
        // "a" (src idx 0) has a synonym → at least two realizations
        assert!(outs.len() >= 2, "{outs:?}");
        for t in &outs {
            assert_eq!(t.len(), src.len());
        }
    }

    #[test]
    fn difficulty_ordering_via_reference_agreement() {
        // iwslt references are unique per source; wmt14's are not — this is
        // the BLEU-ceiling mechanism.
        let uniq = |d: Dataset| {
            let pairs = gen_pairs(d, Split::Test, 200);
            let mut by_src: std::collections::HashMap<_, std::collections::HashSet<_>> =
                Default::default();
            for (s, t) in pairs {
                by_src.entry(s).or_default().insert(t);
            }
            by_src.values().all(|v| v.len() == 1)
        };
        assert!(uniq(Dataset::Iwslt14));
        assert!(uniq(Dataset::Wmt16));
        // wmt14 ambiguity only matters across repeated sources, which the
        // test split may not contain — assert instead at translate level
        // (covered by wmt14_reverses_and_is_ambiguous).
    }
}
