//! Unconditional corpora (text8 / enwik8 analogs) — char streams from the
//! grammar source (mirror of common.py::gen_text_stream / gen_text_chunks).

use crate::schedule::SplitMix64;
use crate::text::Vocab;

use super::grammar::gen_sentence;
use super::translation::Split;
use super::words::{enwik8_vocab, text8_vocab};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UncondCorpus {
    Text8,
    Enwik8,
}

impl UncondCorpus {
    pub fn seed(&self) -> u64 {
        match self {
            UncondCorpus::Text8 => 0x7E87_0008,
            UncondCorpus::Enwik8 => 0xE9B1_0008,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            UncondCorpus::Text8 => "synth-text8",
            UncondCorpus::Enwik8 => "synth-enwik8",
        }
    }

    pub fn parse(s: &str) -> Option<UncondCorpus> {
        match s {
            "synth-text8" | "text8" => Some(UncondCorpus::Text8),
            "synth-enwik8" | "enwik8" => Some(UncondCorpus::Enwik8),
            _ => None,
        }
    }

    pub fn vocab(&self) -> Vocab {
        match self {
            UncondCorpus::Text8 => text8_vocab(),
            UncondCorpus::Enwik8 => enwik8_vocab(),
        }
    }
}

/// Character stream for (corpus, split), exactly `n_chars` long.
pub fn gen_text_stream(corpus: UncondCorpus, split: Split, n_chars: usize) -> String {
    let mut root = SplitMix64::new(corpus.seed());
    let mut rng = root.fork(split.stream());
    let mut parts: Vec<String> = Vec::new();
    let mut total = 0usize;
    while total < n_chars {
        let words = gen_sentence(&mut rng);
        let mut s = words.join(" ");
        if corpus == UncondCorpus::Enwik8 {
            if rng.coin(0.3) {
                let tag = if rng.coin(0.5) { "p" } else { "b" };
                s = format!("<{tag}>{s}</{tag}>");
            }
            if rng.coin(0.2) {
                let year = 1900 + rng.below(120);
                s = format!("{s} {year};");
            }
        }
        total += s.len() + 1;
        parts.push(s);
    }
    let joined = parts.join(" ");
    joined.chars().take(n_chars).collect()
}

/// `count` fixed-length id chunks.
pub fn gen_text_chunks(
    corpus: UncondCorpus,
    split: Split,
    count: usize,
    seq_len: usize,
) -> Vec<Vec<u32>> {
    let vocab = corpus.vocab();
    let stream = gen_text_stream(corpus, split, count * seq_len + seq_len);
    let chars: Vec<char> = stream.chars().collect();
    (0..count)
        .map(|i| {
            chars[i * seq_len..(i + 1) * seq_len]
                .iter()
                .map(|c| vocab.id(&c.to_string()).unwrap_or(vocab.unk_id()))
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text8_charset() {
        let s = gen_text_stream(UncondCorpus::Text8, Split::Test, 500);
        assert_eq!(s.chars().count(), 500);
        assert!(s.chars().all(|c| c == ' ' || c.is_ascii_lowercase()));
    }

    #[test]
    fn enwik8_has_markup() {
        let s = gen_text_stream(UncondCorpus::Enwik8, Split::Test, 2000);
        assert!(s.contains('<') && s.contains('>'));
        let allowed: std::collections::HashSet<char> =
            " abcdefghijklmnopqrstuvwxyz0123456789<>/=&;.,".chars().collect();
        assert!(s.chars().all(|c| allowed.contains(&c)));
    }

    #[test]
    fn chunks_shape_and_range() {
        let chunks = gen_text_chunks(UncondCorpus::Text8, Split::Valid, 4, 64);
        assert_eq!(chunks.len(), 4);
        let v = text8_vocab_len();
        for c in &chunks {
            assert_eq!(c.len(), 64);
            assert!(c.iter().all(|&id| (id as usize) < v));
        }
    }

    fn text8_vocab_len() -> usize {
        UncondCorpus::Text8.vocab().len()
    }

    #[test]
    fn deterministic() {
        let a = gen_text_stream(UncondCorpus::Enwik8, Split::Train, 300);
        let b = gen_text_stream(UncondCorpus::Enwik8, Split::Train, 300);
        assert_eq!(a, b);
        let c = gen_text_stream(UncondCorpus::Enwik8, Split::Valid, 300);
        assert_ne!(a, c);
    }
}
