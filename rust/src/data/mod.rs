//! Synthetic corpora — the data substrate (mirrors python/compile/common.py
//! exactly; parity pinned against artifacts/fixtures.json).
//!
//! The paper evaluates on IWSLT14/WMT14/WMT16 (translation) and
//! text8/enwik8 (unconditional). Those datasets and the pretrained
//! checkpoints are not available in this environment, so we substitute
//! seeded synthetic analogs with the same *difficulty ordering* — see
//! DESIGN.md §3 for the substitution argument.

pub mod corpus;
pub mod grammar;
pub mod translation;
pub mod words;

pub use corpus::{gen_text_chunks, gen_text_stream, UncondCorpus};
pub use grammar::gen_sentence;
pub use translation::{gen_pairs, translate, Dataset, Split};
